"""Deterministic synthetic data pipeline for LM training.

Contract used by the fault-tolerant loop: ``batch_for_step(step)`` is a pure
function of (seed, step, shape) — restarted/replayed steps see identical
data on every host, and each host materializes only its shard (sharded by
``process_index`` in a multi-process deployment; on one process the whole
batch).  A background prefetcher keeps ``depth`` batches ahead of the
consumer so host-side generation overlaps device compute.
"""

from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import jax
import numpy as np

from repro.configs.base import ArchConfig, ShapeSpec


NOISE = 0.1  # structured-stream corruption rate (loss floor ~ -0.9 ln 0.9
#              - 0.1 ln(0.1/V) << ln V — a learnable signal, unlike uniform
#              random tokens whose optimal loss IS ln V)


def _structured_tokens(rng, b: int, length: int, vocab: int) -> np.ndarray:
    """Affine-recurrence token stream with epsilon-noise: learnable synthetic
    language.  t_{i+1} = (5 t_i + 1) mod V with prob 1-NOISE, else uniform."""
    toks = np.empty((b, length), np.int32)
    toks[:, 0] = rng.integers(0, vocab, b)
    noise = rng.random((b, length - 1)) < NOISE
    rand = rng.integers(0, vocab, (b, length - 1)).astype(np.int32)
    for i in range(length - 1):
        nxt = (5 * toks[:, i] + 1) % vocab
        toks[:, i + 1] = np.where(noise[:, i], rand[:, i], nxt)
    return toks


def batch_for_step(cfg: ArchConfig, shape: ShapeSpec, step: int, *,
                   seed: int = 0, batch_override: Optional[int] = None) -> dict:
    """One training batch as host numpy arrays (tokens/embeds + labels)."""
    b = batch_override or shape.global_batch
    s = shape.seq_len
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    if cfg.frontend == "audio_stub":
        embeds = rng.standard_normal((b, s, cfg.frontend_dim),
                                     dtype=np.float32)
        labels = rng.integers(0, cfg.vocab_size, (b, s), dtype=np.int32)
        return {"embeds": embeds, "labels": labels}
    if cfg.frontend == "vision_stub":
        text = s - cfg.num_prefix_embeds
        image = rng.standard_normal((b, cfg.num_prefix_embeds,
                                     cfg.frontend_dim), dtype=np.float32)
        toks = _structured_tokens(rng, b, text + 1, cfg.vocab_size)
        return {"image_embeds": image, "tokens": toks[:, :-1],
                "labels": toks[:, 1:]}
    toks = _structured_tokens(rng, b, s + 1, cfg.vocab_size)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class Prefetcher:
    """Host-side prefetch: generates batches for steps [start, ...) in a
    daemon thread, ``depth`` ahead.  ``get(step)`` enforces the deterministic
    step->batch mapping (out-of-order gets fall back to direct generation,
    e.g. after a restart rewind)."""

    def __init__(self, cfg: ArchConfig, shape: ShapeSpec, *, start: int = 0,
                 depth: int = 2, seed: int = 0,
                 batch_override: Optional[int] = None):
        self.cfg, self.shape, self.seed = cfg, shape, seed
        self.batch_override = batch_override
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._next = start
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._fill, args=(start,),
                                        daemon=True)
        self._thread.start()

    def _fill(self, start: int) -> None:
        step = start
        while not self._stop.is_set():
            batch = batch_for_step(self.cfg, self.shape, step,
                                   seed=self.seed,
                                   batch_override=self.batch_override)
            try:
                self._q.put((step, batch), timeout=0.2)
                step += 1
            except queue.Full:
                continue

    def get(self, step: int) -> dict:
        try:
            while True:
                got_step, batch = self._q.get(timeout=5.0)
                if got_step == step:
                    return batch
                if got_step > step:  # rewound (restart): regenerate directly
                    return batch_for_step(self.cfg, self.shape, step,
                                          seed=self.seed,
                                          batch_override=self.batch_override)
        except queue.Empty:  # pragma: no cover
            return batch_for_step(self.cfg, self.shape, step, seed=self.seed,
                                  batch_override=self.batch_override)

    def close(self) -> None:
        self._stop.set()
        self._thread.join(timeout=1.0)
