from repro.data.synthetic import (  # noqa: F401
    spiral, crescent_fullmoon, gaussian_blobs, synthetic_image,
)
