"""Synthetic datasets used by the paper's experiments (Section 6).

* spiral           — 3-D conical spiral with C classes (Fig. 2a; the paper
                     uses generateSpiralDataWithLabels.m).  Our geometry is
                     calibrated so that with sigma = 3.5 the three NFFT
                     accuracy setups reproduce the paper's error tiers
                     (~1e-3 / ~1e-9 / <1e-14) — see tests/test_lanczos.py.
* crescent_fullmoon — 2-D two-class set (Fig. 2b; crescentfullmoon.m), full
                     moon inside a crescent, 1-to-3 class ratio.
* gaussian_blobs   — C isotropic clusters (Fig. 6 relabeled spiral analogue).
* synthetic_image  — piecewise-constant RGB image + noise for the spectral
                     clustering experiment (Fig. 5 stand-in).
"""

from __future__ import annotations

from typing import Tuple

import numpy as np


def spiral(n: int, n_classes: int = 5, h: float = 8.0, r: float = 2.0,
           noise: float = 0.1, seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """3-D spiral with ``n_classes`` arms.  Returns (points (n,3), labels)."""
    rng = np.random.default_rng(seed)
    per = n // n_classes
    pts, labs = [], []
    for c in range(n_classes):
        count = per + (1 if c < n % n_classes else 0)
        t = rng.uniform(0, 2 * np.pi, count)
        phi = 2 * np.pi * c / n_classes
        rad = r * (1 + t / np.pi)
        x = rad * np.cos(t + phi)
        y = rad * np.sin(t + phi)
        z = h * (t / np.pi - 1.0)
        pts.append(np.stack([x, y, z], -1) + rng.normal(0, noise, (count, 3)))
        labs.append(np.full(count, c, dtype=np.int32))
    points = np.concatenate(pts).astype(np.float64)
    labels = np.concatenate(labs)
    order = rng.permutation(points.shape[0])
    return points[order], labels[order]


def crescent_fullmoon(n: int, r1: float = 5.0, r2: float = 5.0, r3: float = 8.0,
                      seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """2-D crescent + full moon (paper Section 6.2.3), 1-to-3 class ratio.

    Class 0: disk of radius r1 at the origin (the "full moon"), n/4 points.
    Class 1: half-annulus with radii (r2+r1, r3+r1) (the "crescent"), 3n/4.
    """
    rng = np.random.default_rng(seed)
    n_moon = n // 4
    n_cres = n - n_moon

    ang = rng.uniform(0, 2 * np.pi, n_moon)
    rad = r1 * np.sqrt(rng.uniform(0, 1, n_moon))
    moon = np.stack([rad * np.cos(ang), rad * np.sin(ang)], -1)

    inner, outer = r1 + r2, r1 + r3
    ang_c = rng.uniform(np.pi, 2 * np.pi, n_cres)  # lower half-plane arc
    rad_c = np.sqrt(rng.uniform(inner ** 2, outer ** 2, n_cres))
    cres = np.stack([rad_c * np.cos(ang_c), rad_c * np.sin(ang_c) + r1], -1)

    points = np.concatenate([moon, cres]).astype(np.float64)
    labels = np.concatenate([np.zeros(n_moon, np.int32), np.ones(n_cres, np.int32)])
    order = rng.permutation(n)
    return points[order], labels[order]


def gaussian_blobs(n: int, n_classes: int = 5, d: int = 3, spread: float = 6.0,
                   scale: float = 1.0, seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """C isotropic Gaussian clusters around random centers (Section 6.2.2)."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(0, spread, (n_classes, d))
    labels = rng.integers(0, n_classes, n)
    points = centers[labels] + rng.normal(0, scale, (n, d))
    # true label = nearest center (paper Section 6.2.2)
    d2 = ((points[:, None, :] - centers[None, :, :]) ** 2).sum(-1)
    labels = np.argmin(d2, axis=1).astype(np.int32)
    return points.astype(np.float64), labels


def synthetic_image(height: int = 60, width: int = 90, noise: float = 8.0,
                    seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Piecewise-constant RGB image (values 0..255) + truth segmentation.

    Four regions: sky, ground, a disk ("sun"), a rectangle ("building") —
    a controllable stand-in for the paper's 533x800 photograph (Fig. 5).
    Returns (image (H, W, 3) float64, labels (H, W) int32).
    """
    rng = np.random.default_rng(seed)
    img = np.zeros((height, width, 3))
    lab = np.zeros((height, width), np.int32)
    img[:] = (70.0, 120.0, 200.0)  # sky

    horizon = int(height * 0.65)
    img[horizon:] = (60.0, 160.0, 70.0)  # ground
    lab[horizon:] = 1

    cy, cx, rad = int(height * 0.2), int(width * 0.75), max(3, height // 8)
    yy, xx = np.mgrid[0:height, 0:width]
    disk = (yy - cy) ** 2 + (xx - cx) ** 2 <= rad ** 2
    img[disk] = (250.0, 220.0, 60.0)  # sun
    lab[disk] = 2

    y0, y1 = int(height * 0.35), horizon
    x0, x1 = int(width * 0.15), int(width * 0.4)
    img[y0:y1, x0:x1] = (150.0, 60.0, 50.0)  # building
    lab[y0:y1, x0:x1] = 3

    img = np.clip(img + rng.normal(0, noise, img.shape), 0.0, 255.0)
    return img, lab
