"""Training substrate: optimizers, train step, checkpointing, fault tolerance."""

from repro.training.optimizer import (  # noqa: F401
    OptimizerConfig, init_optimizer, make_schedule,
)
from repro.training.train_loop import (  # noqa: F401
    TrainConfig, TrainState, init_train_state, make_train_step,
)
from repro.training.checkpoint import (  # noqa: F401
    latest_step, restore_checkpoint, save_checkpoint,
)
