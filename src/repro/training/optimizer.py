"""Optimizers: AdamW (fp32 master + moments) and Adafactor (factored second
moment, no master) — the latter is what makes the 405B/671B configs fit the
v5e 16 GB HBM budget (DESIGN.md §7: 2.1 bytes/param state vs Adam's 12).

Pure-pytree implementation (no optax dependency): ``init(params) -> state``,
``update(grads, state, params, lr) -> (new_params, new_state)``.  All state
leaves inherit the parameter sharding (same tree structure), so ZeRO-style
optimizer-state sharding falls out of the param sharding rules for free.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"  # 'adamw' | 'adafactor'
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    # adafactor
    decay_offset: float = 1e-30
    factored_min_dim: int = 128


def make_schedule(cfg: OptimizerConfig):
    def schedule(step: Array) -> Array:
        step = step.astype(jnp.float32)
        # (step+1)/warmup: step 0 must have a nonzero LR or it is a no-op
        warm = cfg.peak_lr * (step + 1.0) / jnp.maximum(cfg.warmup_steps, 1)
        frac = jnp.clip((step - cfg.warmup_steps)
                        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                        0.0, 1.0)
        cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
            1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < cfg.warmup_steps, warm, cfg.peak_lr * cos)
    return schedule


def global_norm(tree) -> Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

class AdamWState(NamedTuple):
    master: Any  # fp32 master params
    m: Any
    v: Any


def _adamw_init(params) -> AdamWState:
    f32 = lambda p: p.astype(jnp.float32)
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(master=jax.tree.map(f32, params),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def _adamw_update(cfg: OptimizerConfig, grads, state: AdamWState, params,
                  lr: Array, step: Array):
    b1, b2 = cfg.b1, cfg.b2
    t = step.astype(jnp.float32) + 1.0
    corr1 = 1.0 - b1 ** t
    corr2 = 1.0 - b2 ** t

    def upd(g, m, v, master):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        u = (m / corr1) / (jnp.sqrt(v / corr2) + cfg.eps)
        if master.ndim >= 2:  # decoupled weight decay on matrices only
            u = u + cfg.weight_decay * master
        master = master - lr * u
        return m, v, master

    out = jax.tree.map(upd, grads, state.m, state.v, state.master)
    m = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    v = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    master = jax.tree.map(lambda o: o[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_params = jax.tree.map(lambda mast, p: mast.astype(p.dtype), master,
                              params)
    return new_params, AdamWState(master=master, m=m, v=v)


# ---------------------------------------------------------------------------
# Adafactor
# ---------------------------------------------------------------------------

class AdafactorState(NamedTuple):
    v_row: Any  # factored second moment (rows) or full v for small leaves
    v_col: Any
    v_full: Any


def _factored(p, min_dim: int) -> bool:
    return p.ndim >= 2 and p.shape[-1] >= min_dim and p.shape[-2] >= min_dim


def _adafactor_init(params, cfg: OptimizerConfig) -> AdafactorState:
    def row(p):
        return (jnp.zeros(p.shape[:-1], jnp.float32)
                if _factored(p, cfg.factored_min_dim) else jnp.zeros((), jnp.float32))

    def col(p):
        return (jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                if _factored(p, cfg.factored_min_dim) else jnp.zeros((), jnp.float32))

    def full(p):
        return (jnp.zeros((), jnp.float32)
                if _factored(p, cfg.factored_min_dim)
                else jnp.zeros(p.shape, jnp.float32))

    return AdafactorState(v_row=jax.tree.map(row, params),
                          v_col=jax.tree.map(col, params),
                          v_full=jax.tree.map(full, params))


def _adafactor_update(cfg: OptimizerConfig, grads, state: AdafactorState,
                      params, lr: Array, step: Array):
    t = step.astype(jnp.float32) + 1.0
    beta2 = 1.0 - t ** (-0.8)  # Shazeer-Stern decay schedule

    def upd(g, vr, vc, vf, p):
        g = g.astype(jnp.float32)
        g2 = g * g + cfg.decay_offset
        if _factored(p, cfg.factored_min_dim):
            vr = beta2 * vr + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc = beta2 * vc + (1 - beta2) * jnp.mean(g2, axis=-2)
            r = vr / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), 1e-30)
            u = g / (jnp.sqrt(r)[..., None] * jnp.sqrt(vc)[..., None, :]
                     + 1e-30)
        else:
            vf = beta2 * vf + (1 - beta2) * g2
            u = g / (jnp.sqrt(vf) + 1e-30)
        # update clipping (RMS <= 1) stabilizes bf16-weight training
        rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
        u = u / jnp.maximum(1.0, rms)
        if p.ndim >= 2:
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
        return vr, vc, vf, new_p

    out = jax.tree.map(upd, grads, state.v_row, state.v_col, state.v_full,
                       params)
    pick = lambda i: jax.tree.map(lambda o: o[i], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
    return pick(3), AdafactorState(v_row=pick(0), v_col=pick(1),
                                   v_full=pick(2))


# ---------------------------------------------------------------------------
# Facade
# ---------------------------------------------------------------------------

class Optimizer(NamedTuple):
    init: Any
    update: Any  # (grads, state, params, lr, step) -> (params, state)
    config: OptimizerConfig


def init_optimizer(cfg: OptimizerConfig) -> Optimizer:
    if cfg.name == "adamw":
        return Optimizer(
            init=_adamw_init,
            update=lambda g, s, p, lr, step: _adamw_update(cfg, g, s, p, lr, step),
            config=cfg)
    if cfg.name == "adafactor":
        return Optimizer(
            init=lambda p: _adafactor_init(p, cfg),
            update=lambda g, s, p, lr, step: _adafactor_update(cfg, g, s, p, lr, step),
            config=cfg)
    raise ValueError(cfg.name)
