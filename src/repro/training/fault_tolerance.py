"""Fault-tolerant training loop: checkpoint/restart, failure injection,
straggler monitoring.

``run_resilient``: drives ``train_step`` for ``total_steps``, checkpointing
every ``ckpt_every`` (async).  Any exception inside a step (device loss,
injected fault, preemption signal) triggers restore-from-latest and replay.
Steps are deterministic functions of (state, batch), and the data pipeline
is seeded by step number, so replayed steps reproduce bit-identical results
— the recovery is exactly-once in effect.

Straggler mitigation (DESIGN.md §7): at SPMD scale a straggler manifests as
a slow *step*, not a slow worker (collectives synchronize everyone).  The
:class:`StepTimer` tracks an EWMA/variance of step latency and flags
outliers; the hook is where a production deployment triggers its response
(re-slice the job around the slow host via elastic restore — which this
checkpoint format supports — or re-route traffic for serving).  On a
single-process CPU run the monitor is exercised by tests with synthetic
timings.
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Optional

import jax

from repro.training import checkpoint as ckpt

log = logging.getLogger("repro.fault_tolerance")


@dataclasses.dataclass
class StepTimer:
    """EWMA step-latency monitor; flags steps slower than mean + k*std."""

    alpha: float = 0.1
    threshold_sigmas: float = 4.0
    warmup: int = 3
    mean: float = 0.0
    var: float = 0.0
    count: int = 0
    stragglers: int = 0

    def observe(self, dt: float) -> bool:
        """Record one step time; returns True if it is a straggler."""
        self.count += 1
        if self.count <= self.warmup:
            # initialize on early steps (first steps include compile time)
            self.mean = dt if self.count == 1 else (
                self.mean + (dt - self.mean) / self.count)
            return False
        is_straggler = False
        std = self.var ** 0.5
        if std > 0 and dt > self.mean + self.threshold_sigmas * std:
            is_straggler = True
            self.stragglers += 1
        delta = dt - self.mean
        self.mean += self.alpha * delta
        self.var = (1 - self.alpha) * (self.var + self.alpha * delta * delta)
        return is_straggler


class InjectedFault(RuntimeError):
    """Raised by test fault hooks to simulate a node failure."""


def run_resilient(
    train_step: Callable,
    state,
    batch_fn: Callable[[int], Any],
    *,
    total_steps: int,
    ckpt_dir: str,
    ckpt_every: int = 50,
    max_restarts: int = 10,
    fault_hook: Optional[Callable[[int], None]] = None,
    on_straggler: Optional[Callable[[int, float], None]] = None,
    state_shardings=None,
    log_every: int = 10,
):
    """Run ``total_steps`` steps with checkpoint/restart fault tolerance.

    ``batch_fn(step)`` must be a deterministic function of the step index
    (the data pipeline contract) so restarts replay identical batches.
    Returns (final_state, info dict).
    """
    abstract = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    initial_state = state  # pre-first-checkpoint restart target

    start, restored = ckpt.restore_latest_valid(ckpt_dir, abstract,
                                                shardings=state_shardings)
    if start is not None:
        state = restored
        log.info("resumed from checkpoint step %d", start)
    step = int(start) if start is not None else 0

    timer = StepTimer()
    restarts = 0
    pending = None
    metrics = None
    while step < total_steps:
        try:
            if fault_hook is not None:
                fault_hook(step)
            t0 = time.perf_counter()
            state, metrics = train_step(state, batch_fn(step))
            jax.block_until_ready(metrics)
            dt = time.perf_counter() - t0
            if timer.observe(dt) and on_straggler is not None:
                on_straggler(step, dt)
            step += 1
            if step % log_every == 0:
                loss = float(jax.device_get(metrics.get("loss", 0.0)))
                log.info("step %d loss %.4f (%.3fs)", step, loss, dt)
            if step % ckpt_every == 0 or step == total_steps:
                pending = ckpt.save_checkpoint(ckpt_dir, step, state,
                                               blocking=False)
        except InjectedFault as e:
            restarts += 1
            if restarts > max_restarts:
                raise
            log.warning("fault at step %d (%s); restarting from checkpoint",
                        step, e)
            if pending is not None:
                pending.join()  # let any in-flight write land
            last, restored = ckpt.restore_latest_valid(
                ckpt_dir, abstract, shardings=state_shardings)
            if last is None:
                # fault before the first checkpoint landed: restart from the
                # caller's initial state like any other restart (replay from
                # step 0 is deterministic — batch_fn is a function of the
                # step index), still bounded by max_restarts above
                state = initial_state
                step = 0
            else:
                state = restored
                step = int(last)
    if pending is not None:
        pending.join()
    return state, {
        "steps": step,
        "restarts": restarts,
        "stragglers": timer.stragglers,
        "mean_step_time": timer.mean,
        "final_metrics": metrics,
    }
