"""Checkpointing: leaf-per-file pytree snapshots with an atomic manifest.

Format (``<dir>/step_<n>/``):
    manifest.json   — tree structure, leaf paths, shapes, dtypes, step
    leaf_<i>.npy    — one array per leaf (host-gathered)

Properties needed for fault tolerance at scale:
  * **atomic**: written to ``step_<n>.tmp`` then ``os.rename``d — a crash
    mid-write never corrupts the latest checkpoint;
  * **async**: ``save_checkpoint(..., blocking=False)`` snapshots to host
    memory synchronously (cheap) and writes in a daemon thread so the train
    loop keeps stepping;
  * **elastic**: ``restore_checkpoint(..., shardings=...)`` re-device_puts
    onto *any* mesh — restarting 512-chip training on 256 chips (or a
    different DP/TP split) is a restore with different shardings.

Production note (DESIGN.md §7): at 405B params a host-gathered npy snapshot
is not viable; the format boundary is this module's API, and the production
implementation swaps in per-shard tensorstore writes (Orbax-style) behind
the same three functions.  Every consumer in this repo (train loop, examples,
fault-tolerance tests) goes through this API only.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

_SENTINEL = "manifest.json"


def _tree_paths(tree) -> list[str]:
    paths, _ = zip(*jax.tree_util.tree_flatten_with_path(tree)[0]) \
        if jax.tree_util.tree_leaves(tree) else ((), None)
    return [jax.tree_util.keystr(p) for p in paths]


def save_checkpoint(directory: str, step: int, tree: Any, *,
                    blocking: bool = True, keep: int = 3) -> threading.Thread:
    """Snapshot ``tree`` at ``step``.  Returns the writer thread."""
    flat, treedef = jax.tree_util.tree_flatten(tree)
    host = [np.asarray(jax.device_get(x)) for x in flat]
    paths = _tree_paths(tree)

    def write():
        final = os.path.join(directory, f"step_{step:08d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        for i, arr in enumerate(host):
            np.save(os.path.join(tmp, f"leaf_{i}.npy"), arr)
        manifest = {
            "step": step,
            "num_leaves": len(host),
            "paths": paths,
            "shapes": [list(a.shape) for a in host],
            "dtypes": [str(a.dtype) for a in host],
        }
        with open(os.path.join(tmp, _SENTINEL), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _garbage_collect(directory, keep)

    t = threading.Thread(target=write, daemon=True)
    t.start()
    if blocking:
        t.join()
    return t


def _garbage_collect(directory: str, keep: int) -> None:
    steps = sorted(all_steps(directory))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)


def all_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        full = os.path.join(directory, name)
        if (name.startswith("step_") and not name.endswith(".tmp")
                and os.path.exists(os.path.join(full, _SENTINEL))):
            out.append(int(name[len("step_"):]))
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = all_steps(directory)
    return steps[-1] if steps else None


def restore_checkpoint(directory: str, step: int, like: Any, *,
                       shardings: Any = None) -> Any:
    """Restore into the structure of ``like`` (a pytree or eval_shape tree).

    ``shardings``: optional pytree of Shardings (same structure) — enables
    elastic restore onto a different mesh than the one that saved.
    """
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, _SENTINEL)) as f:
        manifest = json.load(f)
    flat_like, treedef = jax.tree_util.tree_flatten(like)
    assert manifest["num_leaves"] == len(flat_like), \
        (manifest["num_leaves"], len(flat_like))
    arrs = []
    for i, ref in enumerate(flat_like):
        arr = np.load(os.path.join(path, f"leaf_{i}.npy"))
        assert tuple(arr.shape) == tuple(ref.shape), \
            (i, arr.shape, ref.shape)
        arrs.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, arrs)
    if shardings is not None:
        tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree,
                            shardings)
    else:
        tree = jax.tree.map(jax.numpy.asarray, tree)
    return tree
