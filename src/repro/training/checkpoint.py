"""Checkpointing: leaf-per-file pytree snapshots with an atomic manifest.

Format (``<dir>/step_<n>/``):
    manifest.json   — tree structure, leaf paths, shapes, dtypes, per-leaf
                      CRC32 checksums, step, writer process layout
    leaf_<i>.npy    — one array per leaf (host-gathered)

Properties needed for fault tolerance at scale:
  * **atomic**: written to ``step_<n>.tmp`` then ``os.rename``d — a crash
    mid-write never corrupts the latest checkpoint; orphaned ``.tmp``
    directories left by a killed writer are swept by later saves;
  * **async**: ``save_checkpoint(..., blocking=False)`` snapshots to host
    memory synchronously (cheap) and writes in a daemon thread so the train
    loop keeps stepping;
  * **elastic**: ``restore_checkpoint(..., shardings=...)`` re-device_puts
    onto *any* mesh — restarting 512-chip training on 256 chips (or a
    different DP/TP split) is a restore with different shardings;
  * **checksummed**: every leaf carries a CRC32 in the manifest; restore
    verifies it, so a truncated or bit-flipped leaf raises
    :class:`CheckpointCorruptionError` instead of restoring garbage, and
    :func:`restore_latest_valid` falls back to the newest *intact* step;
  * **multi-host**: leaves are partitioned round-robin over processes
    (``owner = leaf_index % process_count``); every process writes only its
    own leaves plus a shard manifest, and process 0 merges the shards and
    publishes the final manifest — save I/O no longer funnels through one
    host.  With one process this degenerates to the single-host format
    (same files, same manifest), so the two layouts restore identically.

Production note (DESIGN.md §7): at 405B params a host-gathered npy snapshot
is not viable; the format boundary is this module's API, and the production
implementation swaps in per-shard tensorstore writes (Orbax-style) behind
the same three functions.  Every consumer in this repo (train loop, examples,
fault-tolerance tests, the durable Krylov driver, the serving journal's
blob store) goes through this API only.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
import zlib
from typing import Any, Optional

import jax
import numpy as np

_SENTINEL = "manifest.json"
_SHARD_MANIFEST = "manifest_shard_{p}.json"

#: Orphaned ``step_*.tmp`` directories older than this many seconds are
#: swept by the next save (a live non-blocking writer's tmp dir is younger).
TMP_SWEEP_TTL_S = 600.0


class CheckpointError(RuntimeError):
    """A checkpoint could not be restored into the requested tree."""


class CheckpointCorruptionError(CheckpointError):
    """A checkpoint step is damaged on disk (checksum/shape/missing file)."""


def leaf_crc32(arr: np.ndarray) -> int:
    """CRC32 of a leaf's raw bytes (shape/dtype are covered by the manifest)."""
    return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF


def _leaf_dtype(x) -> np.dtype:
    """Leaf dtype without a device transfer (arrays, ShapeDtypeStructs,
    python scalars alike)."""
    dt = getattr(x, "dtype", None)
    return np.dtype(dt) if dt is not None else np.asarray(x).dtype


def _tree_paths(tree) -> list[str]:
    paths, _ = zip(*jax.tree_util.tree_flatten_with_path(tree)[0]) \
        if jax.tree_util.tree_leaves(tree) else ((), None)
    return [jax.tree_util.keystr(p) for p in paths]


def _sweep_orphaned_tmp(directory: str, ttl_s: float, *,
                        skip: Optional[str] = None) -> int:
    """Remove ``step_*.tmp`` dirs older than ``ttl_s`` (killed writers).

    ``skip`` protects the calling writer's own tmp dir; any *other* tmp dir
    younger than the TTL is assumed to belong to a live concurrent writer
    and left alone — the sweep only collects genuinely orphaned wreckage.
    """
    if not os.path.isdir(directory):
        return 0
    now = time.time()
    swept = 0
    for name in os.listdir(directory):
        if not (name.startswith("step_") and name.endswith(".tmp")):
            continue
        full = os.path.join(directory, name)
        if full == skip:
            continue
        try:
            age = now - os.path.getmtime(full)
        except OSError:  # concurrent writer renamed/removed it: not ours
            continue
        if age >= ttl_s:
            shutil.rmtree(full, ignore_errors=True)
            swept += 1
    return swept


class CheckpointWriter(threading.Thread):
    """Async checkpoint writer: captures a write failure instead of dying
    silently.  ``check()`` (after ``join()``) re-raises it; blocking saves
    call it for the caller, so a failed blocking save raises."""

    def __init__(self, target):
        super().__init__(daemon=True)
        self._write = target
        self.exception: Optional[BaseException] = None

    def run(self):
        try:
            self._write()
        except BaseException as e:  # surfaced via check()
            self.exception = e

    def check(self) -> None:
        if self.exception is not None:
            raise self.exception


def save_checkpoint(directory: str, step: int, tree: Any, *,
                    blocking: bool = True, keep: int = 3,
                    process_index: Optional[int] = None,
                    process_count: Optional[int] = None,
                    tmp_ttl_s: float = TMP_SWEEP_TTL_S,
                    barrier_timeout_s: float = 300.0) -> CheckpointWriter:
    """Snapshot ``tree`` at ``step``.  Returns the writer thread.

    Multi-host: every process calls this with the same ``step``/``tree``
    structure; leaves are partitioned ``i % process_count == process_index``
    and each process host-gathers + writes only its own.  Process 0 waits
    for every shard manifest, merges them, writes the final manifest, and
    atomically publishes the step.  The defaults read
    ``jax.process_index()``/``jax.process_count()``, so single-process
    callers never see the machinery.
    """
    p = jax.process_index() if process_index is None else process_index
    np_procs = jax.process_count() if process_count is None else process_count
    flat, treedef = jax.tree_util.tree_flatten(tree)
    owned = [i for i in range(len(flat)) if i % np_procs == p]
    host = {i: np.asarray(jax.device_get(flat[i])) for i in owned}
    paths = _tree_paths(tree)
    shapes = [list(np.shape(x)) for x in flat]
    dtypes = [str(_leaf_dtype(x)) for x in flat]

    def write():
        final = os.path.join(directory, f"step_{step:08d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        _sweep_orphaned_tmp(directory, tmp_ttl_s, skip=tmp)
        crcs = {}
        for i, arr in host.items():
            np.save(os.path.join(tmp, f"leaf_{i}.npy"), arr)
            crcs[i] = leaf_crc32(arr)
        shard = {
            "process_index": p,
            "leaves": sorted(host),
            "crc32": {str(i): crcs[i] for i in host},
        }
        shard_path = os.path.join(tmp, _SHARD_MANIFEST.format(p=p))
        with open(shard_path + ".part", "w") as f:
            json.dump(shard, f)
        os.rename(shard_path + ".part", shard_path)  # shard commit point
        if p != 0:
            return  # process 0 merges and publishes
        # merge: wait for every process's shard manifest (each is tiny)
        deadline = time.time() + barrier_timeout_s
        crc_all: dict[int, int] = dict(crcs)
        for q in range(1, np_procs):
            qpath = os.path.join(tmp, _SHARD_MANIFEST.format(p=q))
            while not os.path.exists(qpath):
                if time.time() > deadline:
                    raise CheckpointError(
                        f"multi-host save barrier timed out waiting for "
                        f"process {q}'s shard manifest at step {step}")
                time.sleep(0.01)
            with open(qpath) as f:
                qshard = json.load(f)
            crc_all.update({int(k): v for k, v in qshard["crc32"].items()})
        manifest = {
            "step": step,
            "num_leaves": len(flat),
            "paths": paths,
            "shapes": shapes,
            "dtypes": dtypes,
            "crc32": [crc_all[i] for i in range(len(flat))],
            "process_count": np_procs,
        }
        with open(os.path.join(tmp, _SENTINEL), "w") as f:
            json.dump(manifest, f)
        try:
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
        except OSError:
            # a concurrent writer published this step first: keep theirs
            shutil.rmtree(tmp, ignore_errors=True)
        _garbage_collect(directory, keep)

    t = CheckpointWriter(write)
    t.start()
    if blocking:
        t.join()
        t.check()  # a failed blocking save must raise, not return
    return t


def _garbage_collect(directory: str, keep: int) -> None:
    """Drop all but the newest ``keep`` steps (``keep <= 0`` disables GC).

    Tolerates concurrent non-blocking writers: ``.tmp`` dirs are never
    touched (``all_steps`` excludes them) and a step that vanishes between
    listing and removal — another GC racing us — is ignored.
    """
    steps = sorted(all_steps(directory))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)


def _manifest_path(directory: str, step: int) -> str:
    return os.path.join(directory, f"step_{step:08d}", _SENTINEL)


def _load_manifest(directory: str, step: int) -> dict:
    path = _manifest_path(directory, step)
    if not os.path.exists(path):
        raise CheckpointCorruptionError(
            f"step {step} in {directory!r} has no manifest "
            f"(partially written or deleted)")
    try:
        with open(path) as f:
            return json.load(f)
    except (json.JSONDecodeError, OSError) as e:
        raise CheckpointCorruptionError(
            f"step {step} manifest in {directory!r} is unreadable: {e}")


def all_steps(directory: str) -> list[int]:
    """Steps with a *parseable* manifest — a torn manifest never lists."""
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if not (name.startswith("step_") and not name.endswith(".tmp")):
            continue
        try:
            step = int(name[len("step_"):])
        except ValueError:
            continue
        try:
            _load_manifest(directory, step)
        except CheckpointCorruptionError:
            continue
        out.append(step)
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = all_steps(directory)
    return steps[-1] if steps else None


def _restore_arrays(directory: str, step: int, like: Any) -> tuple:
    """Load + validate every leaf; raises CheckpointError subclasses."""
    path = os.path.join(directory, f"step_{step:08d}")
    manifest = _load_manifest(directory, step)
    flat_like, treedef = jax.tree_util.tree_flatten(like)
    like_paths = _tree_paths(like)
    if manifest["num_leaves"] != len(flat_like):
        raise CheckpointError(
            f"checkpoint step {step} holds {manifest['num_leaves']} leaves "
            f"but the target tree has {len(flat_like)}")
    # a drifted state *definition* (renamed/reordered fields) must not
    # restore silently into the wrong leaves — compare leaf paths when the
    # manifest recorded them
    for i, (mp, lp) in enumerate(zip(manifest.get("paths", like_paths),
                                     like_paths)):
        if mp != lp:
            raise CheckpointError(
                f"checkpoint step {step} leaf {i} was saved at tree path "
                f"{mp!r} but the target tree expects {lp!r} — the state "
                f"definition drifted since this checkpoint was written")
    crcs = manifest.get("crc32")
    arrs = []
    for i, ref in enumerate(flat_like):
        leaf_path = os.path.join(path, f"leaf_{i}.npy")
        name = like_paths[i] or f"leaf_{i}"
        try:
            arr = np.load(leaf_path)
        except (OSError, ValueError) as e:
            raise CheckpointCorruptionError(
                f"checkpoint step {step} leaf {name!r} ({leaf_path}) is "
                f"missing or unreadable: {e}")
        if tuple(arr.shape) != tuple(ref.shape):
            raise CheckpointError(
                f"checkpoint step {step} leaf {name!r} has shape "
                f"{tuple(arr.shape)} but the target tree expects "
                f"{tuple(ref.shape)}")
        ref_dtype = _leaf_dtype(ref)
        if arr.dtype != ref_dtype:
            raise CheckpointError(
                f"checkpoint step {step} leaf {name!r} has dtype "
                f"{arr.dtype} but the target tree expects {ref_dtype}")
        if crcs is not None:
            crc = leaf_crc32(arr)
            if crc != crcs[i]:
                raise CheckpointCorruptionError(
                    f"checkpoint step {step} leaf {name!r} failed its CRC32 "
                    f"check (stored {crcs[i]:#010x}, got {crc:#010x}) — "
                    f"the file was truncated or bit-flipped on disk")
        arrs.append(arr)
    return treedef, arrs


def restore_checkpoint(directory: str, step: int, like: Any, *,
                       shardings: Any = None) -> Any:
    """Restore into the structure of ``like`` (a pytree or eval_shape tree).

    ``shardings``: optional pytree of Shardings (same structure) — enables
    elastic restore onto a different mesh than the one that saved.

    Every leaf is validated against ``like`` (count, tree path, shape,
    dtype) and against its manifest CRC32; violations raise
    :class:`CheckpointError` / :class:`CheckpointCorruptionError` naming
    the offending leaf instead of restoring silently into the wrong state.
    """
    treedef, arrs = _restore_arrays(directory, step, like)
    tree = jax.tree_util.tree_unflatten(treedef, arrs)
    if shardings is not None:
        tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree,
                            shardings)
    else:
        tree = jax.tree.map(jax.numpy.asarray, tree)
    return tree


def restore_latest_valid(directory: str, like: Any, *,
                         shardings: Any = None) -> tuple[Optional[int], Any]:
    """Restore the newest step that passes full validation.

    Walks steps newest-first; a step that fails its checksum / shape /
    manifest validation is skipped (corruption detection) and the previous
    one is tried — a bit-flipped latest snapshot costs one step of
    progress, never a crash loop or silent garbage.  Returns
    ``(step, tree)``; ``(None, None)`` when no intact step exists.
    """
    for step in sorted(all_steps(directory), reverse=True):
        try:
            return step, restore_checkpoint(directory, step, like,
                                            shardings=shardings)
        except CheckpointError:
            continue
    return None, None
