"""Train-step factory: microbatched grad accumulation, clipping, optional
int8 error-feedback gradient compression, metrics.

The returned ``train_step(state, batch)`` is a single jittable function —
the dry-run lowers exactly this function on the production mesh.  Gradient
accumulation runs as a ``lax.scan`` over microbatches so the activation
footprint is one microbatch regardless of the global batch; the fp32
gradient accumulator inherits the (fully sharded) parameter sharding.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist.compression import (
    CompressionState, apply_error_feedback, init_compression_state)
from repro.models import model as model_mod
from repro.training.optimizer import (
    Optimizer, OptimizerConfig, clip_by_global_norm, init_optimizer,
    make_schedule)

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: OptimizerConfig = OptimizerConfig()
    num_microbatches: int = 1
    compress_grads: bool = False
    remat: bool = True

    @staticmethod
    def for_arch(arch: ArchConfig, **overrides) -> "TrainConfig":
        """Production defaults: Adafactor for >=100B-param archs."""
        big = arch.param_count() >= 100e9
        opt = OptimizerConfig(name="adafactor" if big else "adamw")
        base = TrainConfig(optimizer=opt)
        return dataclasses.replace(base, **overrides)


class TrainState(NamedTuple):
    step: Array
    params: Any
    opt_state: Any
    ef_state: Optional[CompressionState]  # error-feedback residuals


def init_train_state(key: Array, cfg: ArchConfig,
                     tc: TrainConfig) -> TrainState:
    params = model_mod.init_params(key, cfg)
    opt = init_optimizer(tc.optimizer)
    opt_state = opt.init(params)
    ef = (init_compression_state(params) if tc.compress_grads else None)
    return TrainState(step=jnp.zeros((), jnp.int32), params=params,
                      opt_state=opt_state, ef_state=ef)


def _split_microbatches(batch: dict, n: int) -> dict:
    def split(x):
        b = x.shape[0]
        assert b % n == 0, (b, n)
        return x.reshape(n, b // n, *x.shape[1:])
    return jax.tree.map(split, batch)


def make_train_step(cfg: ArchConfig, tc: TrainConfig):
    opt = init_optimizer(tc.optimizer)
    schedule = make_schedule(tc.optimizer)

    def loss_fn(params, microbatch):
        loss, metrics = model_mod.forward_train(params, cfg, microbatch,
                                                remat=tc.remat)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, batch: dict):
        params = state.params

        if tc.num_microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        else:
            micro = _split_microbatches(batch, tc.num_microbatches)

            def acc_body(carry, mb):
                g_acc, loss_acc = carry
                (loss, _), g = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, loss_acc + loss), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (grads, loss_sum), _ = jax.lax.scan(
                acc_body, (g0, jnp.zeros((), jnp.float32)), micro)
            inv = 1.0 / tc.num_microbatches
            grads = jax.tree.map(lambda g: g * inv, grads)
            loss = loss_sum * inv
            metrics = {"loss": loss}

        ef_state = state.ef_state
        if tc.compress_grads:
            grads, ef_state = apply_error_feedback(grads, ef_state)

        grads, gnorm = clip_by_global_norm(grads, tc.optimizer.clip_norm)
        lr = schedule(state.step)
        new_params, new_opt = opt.update(grads, state.opt_state, params, lr,
                                         state.step)
        metrics = dict(metrics)
        metrics.update(grad_norm=gnorm, lr=lr, loss=loss)
        new_state = TrainState(step=state.step + 1, params=new_params,
                               opt_state=new_opt, ef_state=ef_state)
        return new_state, metrics

    return train_step
