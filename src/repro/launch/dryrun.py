import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes and extract roofline terms.

MUST be run as its own process (``python -m repro.launch.dryrun``): the
host-device-count flag above is set before any jax import, and jax locks the
device count at first init.  Nothing here allocates device memory for the
big configs — inputs are ShapeDtypeStructs and states come from
``jax.eval_shape``.

Per cell it records: compile wall-time, ``compiled.memory_analysis()``
(proves the per-chip footprint), ``cost_analysis()`` FLOPs/bytes, the
collective schedule parsed from the optimized per-device HLO, and the three
roofline terms (launch/roofline.py).  Results go to JSON for
EXPERIMENTS.md §Dry-run / §Roofline.

Also includes the *paper-technique* cells (``graph-fastsum-*`` and
``graph-fastsum-pencil-*``): the shipped fused distributed NFFT fast-
summation matvec (dist/fastsum_dist.py) lowered on the same meshes with
node counts up to 2^27, in both spectral modes — the psum cells prove the
O(n/P)-local + O(half-spectrum)-allreduce pattern shards to 512 chips; the
pencil cells record the per-device collective-payload drop
(``collective_payload_bytes``, ~1/P) from reduce-scattering the spectrum
into pencils.
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ALL_ARCHS, EXTRA_ARCHS, get_config
from repro.launch import hlo_analysis as hlo_mod
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh, mesh_chip_count
from repro.launch.steps import lower_cell
from repro.training.train_loop import TrainConfig


def _memory_analysis_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    if ma is None:
        return {}
    out = {}
    for name in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        if hasattr(ma, name):
            out[name] = int(getattr(ma, name))
    if not out:
        out["repr"] = repr(ma)
    return out


def _cost_analysis_dict(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return {k: float(v) for k, v in ca.items()
            if isinstance(v, (int, float)) and not k.startswith("utilization")}


def run_cell(arch_name: str, shape_name: str, multi_pod: bool, *,
             microbatch_override: int | None = None,
             compress_grads: bool = False,
             hlo_dir: str | None = None) -> dict:
    cfg = get_config(arch_name)
    shape = next(s for s in cfg.shapes if s.name == shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh_chip_count(mesh)
    rec = {
        "arch": arch_name, "shape": shape_name,
        "mesh": "x".join(str(v) for v in mesh.shape.values()),
        "chips": chips, "kind": shape.kind,
    }
    if shape.skip_reason:
        rec.update(status="skipped", reason=shape.skip_reason)
        return rec

    tc = TrainConfig.for_arch(cfg)
    if microbatch_override:
        tc = dataclasses.replace(tc, num_microbatches=microbatch_override)
    if compress_grads:
        tc = dataclasses.replace(tc, compress_grads=True)
    try:
        t0 = time.perf_counter()
        lowered, kind = lower_cell(cfg, shape, mesh, tc=tc)
        t1 = time.perf_counter()
        compiled = lowered.compile()
        t2 = time.perf_counter()
        mem = _memory_analysis_dict(compiled)
        cost = _cost_analysis_dict(compiled)
        hlo = compiled.as_text()
        if hlo_dir:
            os.makedirs(hlo_dir, exist_ok=True)
            fn = f"{arch_name}__{shape_name}__{rec['mesh']}.hlo"
            with open(os.path.join(hlo_dir, fn), "w") as f:
                f.write(hlo)
        stats = hlo_mod.analyze(hlo, pod_boundary=256)
        roof = rl.roofline_from_stats(
            stats, kind=kind,
            active_params=float(cfg.active_param_count()),
            batch=shape.global_batch, seq=shape.seq_len, chips=chips)
        rec.update(
            status="ok",
            lower_s=round(t1 - t0, 2), compile_s=round(t2 - t1, 2),
            memory=mem, cost_analysis_raw=cost,
            hlo_stats=stats.to_json(),
            roofline=roof.to_json(),
            params=int(cfg.param_count()),
            active_params=int(cfg.active_param_count()),
            num_microbatches=tc.num_microbatches,
        )
    except Exception as e:  # noqa: BLE001 — report, don't crash the sweep
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    return rec


# ---------------------------------------------------------------------------
# Paper-technique cells: distributed fastsum matvec
# ---------------------------------------------------------------------------

def run_graph_cell(n_nodes: int, d: int, multi_pod: bool, *,
                   setup_name: str = "setup2", spectral_mode: str = "psum",
                   mesh=None, bank_size: int = 1) -> dict:
    """Lower the distributed Algorithm 3.1 matvec at cluster scale.

    Lowers the *shipped* fused per-shard body (``dist.fastsum_dist.
    make_sharded_matvec``) — half-spectrum support-block psum in
    ``spectral_mode="psum"``, reduce-scattered pencil FFT in ``"pencil"`` —
    so the 512-chip cells measure exactly what the runtime executes.
    ``mesh`` overrides the production mesh (small-mesh subprocess tests).
    ``bank_size > 1`` lowers the multiplier-*bank* body instead
    (``make_sharded_matvec_bank``, lockstep flavor — the shape one bank
    Krylov iteration executes for an S-point sweep).
    """
    from repro.core.fastsum import SETUP_1, SETUP_2, SETUP_3
    from repro.dist import fastsum_dist
    from jax.sharding import PartitionSpec as P

    params = {"setup1": SETUP_1, "setup2": SETUP_2, "setup3": SETUP_3}[setup_name]
    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh_chip_count(mesh)
    axes = tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)
    plan = params.nfft_plan(d)
    grid, taps = plan.grid_size, plan.taps
    tag = "-pencil" if spectral_mode == "pencil" else ""
    banktag = f"-bank{bank_size}" if bank_size > 1 else ""
    # "pencil" silently runs the psum body when the mesh can't pencil the
    # grid — record the *effective* mode so a fallback cell can't publish
    # flat psum stats under the pencil label
    effective = spectral_mode
    if spectral_mode == "pencil" and fastsum_dist.resolve_pencil_spec(
            plan, mesh, axes) is None:
        effective = "psum"
    n_nodes += (-n_nodes) % chips  # ghost-pad so the node dim shards evenly
    rec = {
        "arch": f"graph-fastsum{tag}{banktag}-{setup_name}-d{d}",
        "shape": f"n{n_nodes}", "mesh": "x".join(map(str, mesh.shape.values())),
        "chips": chips, "kind": "graph_matvec",
        "spectral_mode": spectral_mode,
        "spectral_mode_effective": effective,
        "bank": bank_size,
    }
    try:
        spectrum = (grid,) * (d - 1) + (grid // 2 + 1,)
        base = jax.ShapeDtypeStruct((n_nodes, d), jnp.int32)
        w1d = jax.ShapeDtypeStruct((n_nodes, d, taps), jnp.float32)

        from repro.dist.sharding import named
        t0 = time.perf_counter()
        if bank_size > 1:
            mult = jax.ShapeDtypeStruct((bank_size,) + spectrum,
                                        jnp.complex64)
            x = jax.ShapeDtypeStruct((bank_size, n_nodes, 1), jnp.float32)
            matvec = fastsum_dist.make_sharded_matvec_bank(
                plan, mesh, axes, lockstep=True,
                spectral_mode=spectral_mode, jit=False)
            in_sh = (named(mesh, P()), named(mesh, P(axes, None)),
                     named(mesh, P(axes, None, None)),
                     named(mesh, P(None, axes, None)))
            out_sh = named(mesh, P(None, axes, None))
        else:
            mult = jax.ShapeDtypeStruct(spectrum, jnp.complex64)
            x = jax.ShapeDtypeStruct((n_nodes, 1), jnp.float32)
            matvec = fastsum_dist.make_sharded_matvec(
                plan, mesh, axes, spectral_mode=spectral_mode, jit=False)
            in_sh = (named(mesh, P()), named(mesh, P(axes, None)),
                     named(mesh, P(axes, None, None)),
                     named(mesh, P(axes, None)))
            out_sh = named(mesh, P(axes, None))
        lowered = jax.jit(
            matvec, in_shardings=in_sh, out_shardings=out_sh
        ).lower(mult, base, w1d, x)
        t1 = time.perf_counter()
        compiled = lowered.compile()
        t2 = time.perf_counter()
        cost = _cost_analysis_dict(compiled)
        stats = hlo_mod.analyze(compiled.as_text(), pod_boundary=256)
        # useful work model: direct dense matvec is 2 n^2 (d+2) flops; the
        # fastsum does O(n) — report the dense-equivalent ratio instead.
        roof = rl.roofline_from_stats(
            stats, kind="prefill", active_params=float(n_nodes),
            batch=1, seq=1, chips=chips)
        rec.update(status="ok", lower_s=round(t1 - t0, 2),
                   compile_s=round(t2 - t1, 2),
                   memory=_memory_analysis_dict(compiled),
                   cost_analysis_raw=cost,
                   hlo_stats=stats.to_json(), roofline=roof.to_json(),
                   grid=grid, bandwidth=plan.n_bandwidth, d=d)
    except Exception as e:  # noqa: BLE001
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    return rec


def run_graph_serve_cell(slots: int, chunk: int, d: int, multi_pod: bool, *,
                         setup_name: str = "setup2", mesh=None,
                         guarded: bool = True) -> dict:
    """Lower the graph-predict serve tick body at cluster scale.

    The tick body of :class:`repro.serving.GraphServeEngine` — the packed
    O(m) target window geometry build plus the ragged column gather over
    the resident tenant grids (:func:`repro.core.fastsum_exec.
    fused_gather_columns`) — is the entire steady-state per-tick work of
    the serving tier (grids are cache-resident, nothing replans).  Query
    rows shard across the mesh; the grid stack is replicated (it is
    O(M^d * slots), small next to node data).

    ``guarded=True`` (default) fuses the engine's runtime guard into the
    lowered body: a per-row validity mask (finite query, inside the torus
    fundamental domain, finite gathered output) rides out alongside the
    predictions, so the host retires poisoned rows without a second device
    pass — the cell proves the guard lowers to elementwise ops with no
    extra collective.
    """
    from repro.core import fastsum_exec
    from repro.core import nfft as nfft_mod
    from repro.core.fastsum import SETUP_1, SETUP_2, SETUP_3
    from repro.dist.sharding import named
    from jax.sharding import PartitionSpec as P

    params = {"setup1": SETUP_1, "setup2": SETUP_2,
              "setup3": SETUP_3}[setup_name]
    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh_chip_count(mesh)
    axes = tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)
    plan = params.nfft_plan(d)
    m_pack = slots * chunk
    m_pack += (-m_pack) % chips  # pad rows so the pack shards evenly
    rec = {
        "arch": f"graph-serve-{setup_name}-d{d}",
        "shape": f"slots{slots}x{chunk}",
        "mesh": "x".join(map(str, mesh.shape.values())),
        "chips": chips, "kind": "graph_serve_tick",
        "rows": m_pack, "channels": slots, "guarded": guarded,
    }
    try:
        def tick(points, grid, col_index):
            tgt = nfft_mod.build_window_geometry(plan, points)
            out = fastsum_exec.fused_gather_columns(
                plan, tgt, grid, col_index)
            if not guarded:
                return out
            # fused runtime guard: out-of-domain / non-finite rows flagged
            # on-device (elementwise only — no collective, no extra pass)
            ok = (jnp.all(jnp.isfinite(points), axis=1)
                  & jnp.all(jnp.abs(points) < 0.5, axis=1)
                  & jnp.isfinite(out))
            return jnp.where(ok, out, 0.0), ok

        pts = jax.ShapeDtypeStruct((m_pack, d), jnp.float32)
        grid_s = jax.ShapeDtypeStruct((plan.grid_size,) * d + (slots,),
                                      jnp.float32)
        ci = jax.ShapeDtypeStruct((m_pack,), jnp.int32)
        in_sh = (named(mesh, P(axes, None)), named(mesh, P()),
                 named(mesh, P(axes)))
        out_sh = (named(mesh, P(axes)), named(mesh, P(axes))) \
            if guarded else named(mesh, P(axes))
        t0 = time.perf_counter()
        lowered = jax.jit(tick, in_shardings=in_sh,
                          out_shardings=out_sh).lower(pts, grid_s, ci)
        t1 = time.perf_counter()
        compiled = lowered.compile()
        t2 = time.perf_counter()
        stats = hlo_mod.analyze(compiled.as_text(), pod_boundary=256)
        rec.update(status="ok", lower_s=round(t1 - t0, 2),
                   compile_s=round(t2 - t1, 2),
                   memory=_memory_analysis_dict(compiled),
                   cost_analysis_raw=_cost_analysis_dict(compiled),
                   hlo_stats=stats.to_json(),
                   grid=plan.grid_size, bandwidth=plan.n_bandwidth, d=d)
    except Exception as e:  # noqa: BLE001
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch name, comma list, or 'all'")
    ap.add_argument("--shapes", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--graph", action="store_true",
                    help="also run the paper-technique fastsum cells")
    ap.add_argument("--graph-n", type=int, default=2 ** 27)
    ap.add_argument("--graph-bank", type=int, default=8,
                    help="bank size S for the graph-fastsum-bank cells "
                         "(<2 disables them)")
    ap.add_argument("--graph-serve", action="store_true",
                    help="also lower the serving-tier tick body "
                         "(packed target geometry + ragged gather)")
    ap.add_argument("--serve-slots", type=int, default=64)
    ap.add_argument("--serve-chunk", type=int, default=256)
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--hlo-dir", default=None)
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    if args.arch == "all":
        archs = [c.name for c in ALL_ARCHS + EXTRA_ARCHS]
    else:
        archs = args.arch.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    results = []
    for name in archs:
        cfg = get_config(name)
        shape_names = ([s.name for s in cfg.shapes] if args.shapes == "all"
                       else args.shapes.split(","))
        for sn in shape_names:
            if sn not in {s.name for s in cfg.shapes}:
                continue
            for mp in meshes:
                rec = run_cell(name, sn, mp,
                               microbatch_override=args.microbatches,
                               compress_grads=args.compress_grads,
                               hlo_dir=args.hlo_dir)
                results.append(rec)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (f" compile={rec['compile_s']}s"
                             f" dominant={r['dominant']}"
                             f" compute={r['compute_s']:.3e}s"
                             f" memory={r['memory_s']:.3e}s"
                             f" coll={r['collective_s']:.3e}s")
                elif status == "error":
                    extra = " " + rec["error"][:160]
                print(f"[{status:7s}] {name} x {sn} @ {rec['mesh']}{extra}",
                      flush=True)

    if args.graph:
        for mp in meshes:
            for setup in ("setup1", "setup2", "setup3"):
                # bank cells (S=8, the benchmark sweep width) sit next to
                # the single-operator cells: same body, multiplier bank +
                # S·C channels through the one collective
                cells = [("psum", 1), ("pencil", 1)]
                if args.graph_bank >= 2:
                    cells += [("psum", args.graph_bank),
                              ("pencil", args.graph_bank)]
                for mode, bank in cells:
                    rec = run_graph_cell(args.graph_n, 3, mp,
                                         setup_name=setup,
                                         spectral_mode=mode,
                                         bank_size=bank)
                    results.append(rec)
                    extra = ""
                    if rec["status"] == "ok":
                        pay = rec["hlo_stats"]["collective_payload_bytes"]
                        extra = f" coll_payload={pay:.3e}B"
                    print(f"[{rec['status']:7s}] {rec['arch']} x "
                          f"{rec['shape']} @ {rec['mesh']}{extra}",
                          flush=True)

    if args.graph_serve:
        for mp in meshes:
            for setup in ("setup1", "setup2", "setup3"):
                rec = run_graph_serve_cell(args.serve_slots,
                                           args.serve_chunk, 3, mp,
                                           setup_name=setup)
                results.append(rec)
                extra = ""
                if rec["status"] == "ok":
                    extra = (f" compile={rec['compile_s']}s"
                             f" rows={rec['rows']}")
                print(f"[{rec['status']:7s}] {rec['arch']} x "
                      f"{rec['shape']} @ {rec['mesh']}{extra}",
                      flush=True)

    suffix = f"_{args.tag}" if args.tag else ""
    path = os.path.join(args.out, f"dryrun{suffix}.json")
    with open(path, "w") as f:
        json.dump(results, f, indent=1)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\n{n_ok} ok, {n_skip} skipped, {n_err} errors -> {path}")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
