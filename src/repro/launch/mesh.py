"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run sets its host
device count before first jax init, and smoke tests see the 1 real device.

Mesh geometry (TPU v5e target):
  * single pod:  (16, 16)  -> ("data", "model")   256 chips
  * multi-pod:   (2, 16, 16) -> ("pod", "data", "model")   512 chips

"data" (and "pod") carry batch + FSDP sharding; "model" carries
tensor/expert/sequence parallelism.  The "pod" axis crosses the
data-center interconnect, so collectives on it are the expensive ones —
the sharding rules put only DP gradient reduction there.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_parallel: int = 1) -> Mesh:
    """Mesh over whatever devices exist (CPU smoke / small real runs)."""
    n = jax.device_count()
    assert n % model_parallel == 0, (n, model_parallel)
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))


def mesh_chip_count(mesh: Mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))
