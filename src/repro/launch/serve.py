"""Serving driver: ``python -m repro.launch.serve --arch granite-3-2b --reduced``

Spins up the continuous-batching engine on a reduced (or full, on real
hardware) config and runs a batch of synthetic prompts through it.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced_config
from repro.models import model as M
from repro.serving.engine import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    if cfg.frontend != "none" or cfg.encoder_only:
        raise SystemExit(f"{cfg.name}: engine demo serves token-LM archs")

    params = M.init_params(jax.random.PRNGKey(args.seed), cfg)
    engine = ServeEngine(cfg, params, slots=args.slots, max_seq=args.max_seq)

    rng = np.random.default_rng(args.seed)
    reqs = []
    for i in range(args.requests):
        plen = int(rng.integers(4, 17))
        reqs.append(Request(
            uid=i, tokens=rng.integers(0, cfg.vocab_size, plen).tolist(),
            max_new_tokens=args.max_new))
        engine.submit(reqs[-1])

    t0 = time.perf_counter()
    engine.run_until_drained()
    dt = time.perf_counter() - t0
    total_new = sum(len(r.output) for r in reqs)
    assert all(r.done for r in reqs)
    print(f"served {len(reqs)} requests / {total_new} tokens in {dt:.2f}s "
          f"({total_new / dt:.1f} tok/s, slots={args.slots})")
    for r in reqs[:3]:
        print(f"  req {r.uid}: prompt_len={len(r.tokens)} -> {r.output[:8]}…")


if __name__ == "__main__":
    main()
