"""ShapeDtypeStruct input stand-ins for every (arch x shape) cell.

``input_specs(cfg, shape)`` mirrors data/pipeline.py batch structures but
allocates nothing — the dry-run lowers against these.  ``abstract_*`` build
the matching abstract state/caches via ``jax.eval_shape``.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models import model as model_mod
from repro.training.train_loop import TrainConfig, init_train_state

SDS = jax.ShapeDtypeStruct


def train_input_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    b, s = shape.global_batch, shape.seq_len
    if cfg.frontend == "audio_stub":
        return {"embeds": SDS((b, s, cfg.frontend_dim), jnp.float32),
                "labels": SDS((b, s), jnp.int32)}
    if cfg.frontend == "vision_stub":
        text = s - cfg.num_prefix_embeds
        return {"image_embeds": SDS((b, cfg.num_prefix_embeds,
                                     cfg.frontend_dim), jnp.float32),
                "tokens": SDS((b, text), jnp.int32),
                "labels": SDS((b, text), jnp.int32)}
    return {"tokens": SDS((b, s), jnp.int32),
            "labels": SDS((b, s), jnp.int32)}


def prefill_input_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    spec = train_input_specs(cfg, shape)
    spec.pop("labels", None)
    return spec


def decode_input_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    b = shape.global_batch
    return {"token": SDS((b, 1), jnp.int32), "pos": SDS((b,), jnp.int32)}


def abstract_params(cfg: ArchConfig):
    key = SDS((2,), jnp.uint32)
    return jax.eval_shape(functools.partial(model_mod.init_params, cfg=cfg),
                          key)


def abstract_train_state(cfg: ArchConfig, tc: TrainConfig):
    key = SDS((2,), jnp.uint32)
    return jax.eval_shape(
        lambda k: init_train_state(k, cfg, tc), key)


def abstract_caches(cfg: ArchConfig, batch: int, max_seq: int):
    return jax.eval_shape(
        functools.partial(model_mod.init_caches, cfg, batch, max_seq))


def input_specs(cfg: ArchConfig, shape: ShapeSpec, *,
                tc: TrainConfig | None = None) -> dict[str, Any]:
    """All abstract inputs for the step this cell lowers.

    Returns {'kind', 'args': tuple of abstract pytrees} matching the
    signature of the lowered step function (see launch/steps.py).
    """
    tc = tc or TrainConfig.for_arch(cfg)
    if shape.kind == "train":
        state = abstract_train_state(cfg, tc)
        return {"kind": "train",
                "args": (state, train_input_specs(cfg, shape))}
    params = abstract_params(cfg)
    if shape.kind == "prefill":
        caches = abstract_caches(cfg, shape.global_batch, shape.seq_len)
        return {"kind": "prefill",
                "args": (params, prefill_input_specs(cfg, shape), caches)}
    if shape.kind == "decode":
        caches = abstract_caches(cfg, shape.global_batch, shape.seq_len)
        d = decode_input_specs(cfg, shape)
        return {"kind": "decode",
                "args": (params, d["token"], d["pos"], caches)}
    raise ValueError(shape.kind)
