"""Loop-aware analysis of optimized (post-SPMD) HLO text.

Why this exists: ``compiled.cost_analysis()`` visits every ``while`` body
exactly ONCE — a scan-over-layers model under-reports FLOPs/bytes/collective
traffic by the trip count (126x for llama3-405b).  This module re-derives
the per-device roofline quantities with loop multipliers:

  1. split the module into named computations (headers start at column 0
     and end with '{'; instruction lines are indented 'name = type op(...)'
     with operands referenced BY NAME — types resolved via a global
     name->shape table);
  2. build the call graph (fusion ``calls=``, ``while`` body/condition,
     ``to_apply=``, conditional ``branch_computations``);
  3. extract each while loop's trip count from its condition computation
     (the loop bound is the max integer constant there — exact for
     lax.scan / fori_loop conditions);
  4. effective multiplier of a computation = product of trip counts of the
     enclosing while loops (ENTRY = 1);
  5. FLOPs: every ``dot``, 2 * prod(result dims) * contraction size
     (einsum models put essentially all FLOPs in dots), x multiplier;
  6. memory bytes: resolved operand + result bytes of memory-level ops at
     the top level of non-fusion computations (fusion internals are
     on-chip), x multiplier;
  7. collective bytes: ring model per op kind, x multiplier, split into
     ICI vs cross-pod DCI traffic by replica-group analysis.

All quantities are per-device: the post-partitioning module is the
single-device SPMD program.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_TYPE_RE = re.compile(
    r"\b(pred|bf16|f16|f32|f64|c64|c128|s4|u4|s8|u8|s16|u16|s32|u32|s64|u64"
    r"|f8e4m3fn|f8e5m2)\[([0-9,]*)\]")
_HDR_NAME_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_NAME_RE = re.compile(r"%([\w.\-]+)")
_CONST_RE = re.compile(r"\b[su](?:8|16|32|64)\[\]\s+constant\((\d+)\)")
_DOT_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPCODE_RE = re.compile(r"([a-z][a-z0-9\-]*)\(")
_WHILE_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_WHILE_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "reshape", "after-all", "partition-id", "replica-id", "opt-barrier",
    "while", "call", "conditional", "domain", "get-dimension-size",
    "add-dependency", "custom-call",  # custom-calls counted separately below
}


def _shape_elems(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _token_bytes(dtype: str, dims: str) -> int:
    return _shape_elems(dims) * _DTYPE_BYTES[dtype]


@dataclasses.dataclass
class Instruction:
    name: str
    opcode: str
    line: str
    result_tokens: list  # [(dtype, dims)]
    operand_names: list

    @property
    def result_bytes(self) -> int:
        return sum(_token_bytes(d, s) for d, s in self.result_tokens)


@dataclasses.dataclass
class Computation:
    name: str
    instructions: list
    multiplier: float = 0.0
    is_fusion_body: bool = False


def _parse_instruction(stripped: str) -> Optional[Instruction]:
    eq = stripped.find(" = ")
    if eq < 0:
        return None
    lhs = stripped[:eq].strip()
    if lhs.startswith("ROOT"):
        lhs = lhs[4:].strip()
    name = lhs.lstrip("%")
    rest = stripped[eq + 3:]
    m = _OPCODE_RE.search(rest)
    if m is None:
        return None
    opcode = m.group(1)
    # result type tokens live before the opcode
    result_tokens = [mm.groups() for mm in _TYPE_RE.finditer(rest[:m.start()])]
    # operand names: inside opcode( ... up to the first ')'
    args_start = m.end()
    args_end = rest.find(")", args_start)
    args = rest[args_start:args_end if args_end > 0 else None]
    operand_names = _NAME_RE.findall(args)
    return Instruction(name=name, opcode=opcode, line=stripped,
                       result_tokens=result_tokens,
                       operand_names=operand_names)


def parse_module(hlo_text: str):
    """Returns (computations dict incl '__entry__', name->Instruction)."""
    comps: dict[str, Computation] = {}
    by_name: dict[str, Instruction] = {}
    cur: Optional[Computation] = None
    entry_name = None
    for raw in hlo_text.splitlines():
        if not raw:
            continue
        if raw[0] not in " \t":  # potential computation header / module line
            if raw.rstrip().endswith("{"):
                m = _HDR_NAME_RE.match(raw)
                if m:
                    cur = Computation(name=m.group(1), instructions=[])
                    comps[cur.name] = cur
                    if raw.startswith("ENTRY"):
                        entry_name = m.group(1)
            elif raw.strip() == "}":
                cur = None
            continue
        stripped = raw.strip()
        if stripped == "}":
            cur = None
            continue
        if cur is None or " = " not in stripped:
            continue
        ins = _parse_instruction(stripped)
        if ins is not None:
            cur.instructions.append(ins)
            by_name[ins.name] = ins
    return comps, by_name, entry_name


def _trip_count(cond: Computation) -> int:
    consts = []
    for ins in cond.instructions:
        consts += [int(x) for x in _CONST_RE.findall(ins.line)]
    return max(consts) if consts else 1


def assign_multipliers(comps: dict, entry_name) -> None:
    entry = comps.get(entry_name)
    if entry is None:  # pragma: no cover
        for c in comps.values():
            c.multiplier = 1.0
        return
    seen = set()

    def visit(comp: Computation, mult: float):
        comp.multiplier = max(comp.multiplier, mult)
        key = (comp.name, mult)
        if key in seen:
            return
        seen.add(key)
        for ins in comp.instructions:
            if ins.opcode == "while":
                mc = _WHILE_COND_RE.search(ins.line)
                mb = _WHILE_BODY_RE.search(ins.line)
                cond = comps.get(mc.group(1)) if mc else None
                body = comps.get(mb.group(1)) if mb else None
                trips = _trip_count(cond) if cond else 1
                if body:
                    visit(body, mult * trips)
                if cond:
                    visit(cond, mult * trips)
            elif ins.opcode == "fusion":
                m = _CALLS_RE.search(ins.line)
                if m and m.group(1) in comps:
                    body = comps[m.group(1)]
                    body.is_fusion_body = True
                    visit(body, mult)
            elif ins.opcode == "conditional":
                m = _BRANCHES_RE.search(ins.line)
                if m:
                    for nm in m.group(1).replace("%", "").split(","):
                        nm = nm.strip()
                        if nm in comps:
                            visit(comps[nm], mult)
            else:
                for rx in (_TO_APPLY_RE, _CALLS_RE):
                    m = rx.search(ins.line)
                    if m and m.group(1) in comps:
                        visit(comps[m.group(1)], mult)

    visit(entry, 1.0)


def _operand_bytes(ins: Instruction, by_name: dict) -> int:
    total = 0
    for nm in ins.operand_names:
        ref = by_name.get(nm)
        if ref is not None:
            total += ref.result_bytes
    return total


_PARAM_IDX_RE = re.compile(r"parameter\((\d+)\)")
_SLICE_OPS = {"dynamic-slice", "slice", "gather"}


def _fusion_traffic(ins: Instruction, comps: dict, by_name: dict) -> int:
    """HBM traffic of one fusion op, slice-aware.

    Scan-over-layers bodies dynamic-slice the current layer's weights out of
    the period-stacked arrays: charging the full stacked operand per
    iteration would over-count by the trip count.  A fusion parameter whose
    only direct consumers are slice/dynamic-slice/gather ops is charged the
    sliced bytes; everything else is charged in full.  Symmetrically, a
    fusion whose root is a dynamic-update-slice writes only the update
    (XLA aliases the rest in place).
    """
    m = _CALLS_RE.search(ins.line)
    body = comps.get(m.group(1)) if m else None
    if body is None:
        return ins.result_bytes + _operand_bytes(ins, by_name)

    # map body parameter name -> (index, full bytes)
    params: dict[str, int] = {}
    for bins in body.instructions:
        if bins.opcode == "parameter":
            params[bins.name] = bins.result_bytes
    # direct consumers of each parameter
    sliced_bytes: dict[str, int] = {}
    full_use: set = set()
    root_ins = body.instructions[-1] if body.instructions else None
    for bins in body.instructions:
        if bins.opcode == "parameter":
            continue
        for nm in bins.operand_names:
            if nm not in params:
                continue
            if bins.opcode in _SLICE_OPS:
                sliced_bytes[nm] = sliced_bytes.get(nm, 0) + bins.result_bytes
            elif (bins.opcode == "dynamic-update-slice"
                  and bins.operand_names and nm == bins.operand_names[0]):
                # DUS destination param: in-place aliased, charge nothing
                # here (the update operand is charged by its own producer)
                pass
            else:
                full_use.add(nm)

    total = 0
    for nm, full in params.items():
        if nm in full_use or nm not in sliced_bytes:
            if nm in full_use:
                total += full
            elif nm in sliced_bytes:  # pragma: no cover
                total += min(sliced_bytes[nm], full)
            else:
                # parameter only consumed by DUS-destination: free
                total += 0 if _is_dus_dest_only(nm, body) else full
        else:
            total += min(sliced_bytes[nm], full)

    # result side: DUS-rooted fusions write only the update slice
    if root_ins is not None and root_ins.opcode == "dynamic-update-slice":
        upd = (by_name.get(root_ins.operand_names[1])
               if len(root_ins.operand_names) > 1 else None)
        # update operand may be body-local: look it up in the body first
        upd_local = next((b for b in body.instructions
                          if len(root_ins.operand_names) > 1
                          and b.name == root_ins.operand_names[1]), None)
        upd_bytes = (upd_local.result_bytes if upd_local is not None
                     else (upd.result_bytes if upd is not None else
                           ins.result_bytes))
        total += upd_bytes
    else:
        total += ins.result_bytes
    return total


def _is_dus_dest_only(param_name: str, body: Computation) -> bool:
    for bins in body.instructions:
        if bins.opcode == "parameter":
            continue
        if param_name in bins.operand_names:
            if not (bins.opcode == "dynamic-update-slice"
                    and bins.operand_names[0] == param_name):
                return False
    return True


def _dot_flops(ins: Instruction, by_name: dict) -> float:
    if not ins.operand_names:
        return 0.0
    lhs = by_name.get(ins.operand_names[0])
    if lhs is None or not lhs.result_tokens:
        return 0.0
    dims_str = lhs.result_tokens[0][1]
    lhs_dims = [int(x) for x in dims_str.split(",")] if dims_str else []
    m = _DOT_CDIMS_RE.search(ins.line)
    contracting = ([int(x) for x in m.group(1).split(",")]
                   if m and m.group(1) else [])
    csize = 1
    for c in contracting:
        if c < len(lhs_dims):
            csize *= lhs_dims[c]
    out = (_shape_elems(ins.result_tokens[0][1])
           if ins.result_tokens else 1)
    return 2.0 * out * csize


def _collective_moved_bytes(ins: Instruction, by_name: dict) -> int:
    rb = ins.result_bytes
    ob = _operand_bytes(ins, by_name) or rb
    if ins.opcode.startswith("all-gather"):
        return rb
    if ins.opcode.startswith("reduce-scatter"):
        return ob
    if ins.opcode.startswith("all-reduce"):
        return 2 * ob
    return ob


def _collective_payload_bytes(ins: Instruction, by_name: dict) -> int:
    """Per-device *shard payload* of a collective.

    The bytes this device uniquely contributes to or keeps from the op: the
    operand (its shard) for all-gather / all-to-all / collective-permute,
    the result (its reduced shard) for reduce-scatter, and twice the operand
    for all-reduce, which is unsharded at both ends.  This is the
    bandwidth-optimal per-device lower bound; ``collective_bytes`` keeps the
    ring-wire model above, which is up to group_size x larger for the
    gather/scatter ops.  Sharded-spectrum paths (the pencil-mode fastsum
    matvec) scale this quantity ~1/P while the psum path stays flat — it is
    the column the dry-run pencil cells are asserted against.
    """
    rb = ins.result_bytes
    ob = _operand_bytes(ins, by_name) or rb
    if ins.opcode.startswith("all-gather"):
        return ob
    if ins.opcode.startswith("reduce-scatter"):
        return rb
    if ins.opcode.startswith("all-reduce"):
        return 2 * ob
    return ob


_BF16_CONVERT_RE = re.compile(r"=\s*bf16\[")


def _is_bf16_wire(ins: Instruction, by_name: dict, comps: dict) -> bool:
    """True when an f32 collective carries a value that is semantically bf16.

    XLA:CPU's float-normalization pass upcasts bf16 dots AND bf16 collectives
    to f32 (the CPU has no native bf16 reductions), leaving telltale
    f32->bf16->f32 round-trips in the producing fusion.  On the TPU target
    the same program moves bf16 over the wire, so these collectives are
    counted at 2 bytes/element (raw f32 figures are reported alongside).
    """
    if not ins.result_tokens or ins.result_tokens[0][0] != "f32":
        if not any(d == "f32" for d, _ in ins.result_tokens):
            return False
    for nm in ins.operand_names:
        prod = by_name.get(nm)
        if prod is None:
            continue
        if prod.opcode == "convert" and "bf16" in prod.line.split("convert", 1)[1]:
            return True
        if prod.opcode == "fusion":
            m = _CALLS_RE.search(prod.line)
            body = comps.get(m.group(1)) if m else None
            if body and any(_BF16_CONVERT_RE.search(b.line)
                            for b in body.instructions):
                return True
    return False


_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")


def _crosses_pod(line: str, pod_boundary: int) -> bool:
    """Exact replica-group evaluation: a group crosses the pod boundary iff
    it mixes device ids below and at/above ``pod_boundary``."""
    m = _GROUPS_LIST_RE.search(line)
    if m:
        try:
            ids = [int(x) for x in m.group(1).split(",") if x.strip()]
        except ValueError:
            return False
        return (any(i < pod_boundary for i in ids)
                and any(i >= pod_boundary for i in ids))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        import numpy as _np
        n_groups, group_size = int(m.group(1)), int(m.group(2))
        reshape = [int(x) for x in m.group(3).split(",")]
        total = n_groups * group_size
        if total <= pod_boundary:
            return False
        ids = _np.arange(total).reshape(reshape)
        if m.group(4):
            perm = [int(x) for x in m.group(4).split(",")]
            ids = ids.transpose(perm)
        groups = ids.reshape(n_groups, group_size)
        lo = (groups < pod_boundary).any(axis=1)
        hi = (groups >= pod_boundary).any(axis=1)
        return bool((lo & hi).any())
    return False


@dataclasses.dataclass
class HloStats:
    flops: float
    hbm_bytes: float
    collective_bytes: float  # bf16-wire-corrected (TPU-projected)
    dci_bytes: float
    collective_by_kind: dict
    collective_ops: int
    dot_flops_by_shape: dict
    largest_collectives: list
    while_trip_counts: list
    collective_bytes_raw: float = 0.0  # as seen in CPU-legalized HLO
    collective_payload_bytes: float = 0.0  # per-device shard payload

    def to_json(self):
        d = dataclasses.asdict(self)
        d["dot_flops_by_shape"] = dict(sorted(
            self.dot_flops_by_shape.items(), key=lambda kv: -kv[1])[:12])
        return d


def analyze(hlo_text: str, *, pod_boundary: int = 256) -> HloStats:
    comps, by_name, entry_name = parse_module(hlo_text)
    assign_multipliers(comps, entry_name)

    flops = 0.0
    hbm = 0.0
    coll = 0.0
    coll_raw = 0.0
    payload = 0.0
    dci = 0.0
    by_kind: dict[str, float] = {}
    n_coll = 0
    dot_by_shape: dict[str, float] = {}
    largest: list = []
    trips: list = []

    for comp in comps.values():
        mult = comp.multiplier
        if mult <= 0:
            continue  # dead computation
        for ins in comp.instructions:
            if ins.opcode == "while":
                mc = _WHILE_COND_RE.search(ins.line)
                if mc and mc.group(1) in comps:
                    trips.append(_trip_count(comps[mc.group(1)]))
            if ins.opcode == "dot":
                f = _dot_flops(ins, by_name) * mult
                flops += f
                key = (ins.result_tokens[0][1] if ins.result_tokens else "?")
                dot_by_shape[key] = dot_by_shape.get(key, 0.0) + f
            kind = next((k for k in _COLLECTIVES
                         if ins.opcode == k or ins.opcode == k + "-start"),
                        None)
            if kind is not None:
                moved_raw = _collective_moved_bytes(ins, by_name) * mult
                bf16_wire = _is_bf16_wire(ins, by_name, comps)
                moved = moved_raw // 2 if bf16_wire else moved_raw
                pay = _collective_payload_bytes(ins, by_name) * mult
                payload += pay // 2 if bf16_wire else pay
                coll_raw += moved_raw
                coll += moved
                by_kind[kind] = by_kind.get(kind, 0.0) + moved
                n_coll += 1
                largest.append((moved, kind, ins.line[:140]))
                if _crosses_pod(ins.line, pod_boundary):
                    dci += moved
            # HBM bytes: top-level ops of non-fusion computations
            if comp.is_fusion_body or ins.opcode in _SKIP_BYTES_OPS:
                continue
            if ins.opcode == "fusion":
                hbm += _fusion_traffic(ins, comps, by_name) * mult
            elif ins.opcode in _SLICE_OPS:
                hbm += 2 * ins.result_bytes * mult  # read slice + write
            elif ins.opcode == "dynamic-update-slice":
                upd = (by_name.get(ins.operand_names[1])
                       if len(ins.operand_names) > 1 else None)
                ub = upd.result_bytes if upd is not None else ins.result_bytes
                hbm += 2 * ub * mult
            else:
                hbm += (ins.result_bytes
                        + _operand_bytes(ins, by_name)) * mult

    largest.sort(key=lambda t: -t[0])
    return HloStats(
        flops=flops, hbm_bytes=hbm, collective_bytes=coll, dci_bytes=dci,
        collective_by_kind=by_kind, collective_ops=n_coll,
        dot_flops_by_shape=dot_by_shape,
        largest_collectives=[(int(b), k, l) for b, k, l in largest[:10]],
        while_trip_counts=sorted(trips, reverse=True)[:8],
        collective_bytes_raw=coll_raw,
        collective_payload_bytes=payload)
