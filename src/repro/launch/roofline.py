"""Roofline-term extraction from compiled dry-run artifacts.

Hardware model (TPU v5e, per chip):
    PEAK_FLOPS = 197e12  bf16 FLOP/s (MXU)
    HBM_BW     = 819e9   bytes/s
    LINK_BW    = 50e9    bytes/s per ICI link (we charge one link; a 2D
                 torus has more, so this is conservative)

The compiled module is the *per-device* SPMD program, so cost_analysis()
FLOPs/bytes and the collective operand bytes parsed from its HLO text are
already per-chip quantities:

    compute_s    = flops / PEAK_FLOPS
    memory_s     = bytes_accessed / HBM_BW
    collective_s = comm_bytes / LINK_BW

Communicated-bytes model per op (ring algorithms, factor (n-1)/n ~ 1):
    all-gather        -> result bytes
    reduce-scatter    -> operand bytes
    all-reduce        -> 2 x operand bytes  (RS + AG)
    all-to-all        -> operand bytes
    collective-permute-> operand bytes

Ops whose replica groups cross the pod boundary (any group mixing device
ids < 256 and >= 256 on the 512-chip mesh) are tallied separately as DCI
traffic — the scarce resource in multi-pod training.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_TYPE_RE = re.compile(r"\b(pred|[suf](?:8|16|32|64)|bf16|c64|c128)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{(.*?)\}\s*(?:,|$)")


def _token_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict
    total_bytes: int
    dci_bytes: int
    op_count: int

    def to_json(self):
        return dataclasses.asdict(self)


def _crosses_pod(line: str, pod_boundary: int) -> bool:
    m = _GROUPS_RE.search(line)
    if not m:
        return False
    groups = m.group(1)
    first = groups.split("}")[0].lstrip("{")
    try:
        ids = [int(x) for x in first.split(",") if x.strip()]
    except ValueError:
        return False
    return (any(i < pod_boundary for i in ids)
            and any(i >= pod_boundary for i in ids))


def parse_collectives(hlo_text: str, *, pod_boundary: int = 256
                      ) -> CollectiveStats:
    by_kind: dict[str, int] = {}
    total = 0
    dci = 0
    count = 0
    for line in hlo_text.splitlines():
        kind = None
        for k in _COLLECTIVES:
            # match the opcode position "= <types...> opcode(" to avoid
            # matching e.g. metadata op_name paths
            if f" {k}(" in line or f" {k}-start(" in line:
                kind = k
                break
        if kind is None:
            continue
        if "-done(" in line:
            continue  # async pair: count the -start only
        tokens = _TYPE_RE.findall(line)
        if not tokens:
            continue
        eq = line.find("=")
        opcode_pos = line.find(f" {kind}")
        # tokens before the opcode are the result type(s); after: operands
        result_tokens, operand_tokens = [], []
        for m in _TYPE_RE.finditer(line):
            (result_tokens if m.start() < opcode_pos else operand_tokens
             ).append(m.groups())
        rb = sum(_token_bytes(d, s) for d, s in result_tokens)
        ob = sum(_token_bytes(d, s) for d, s in operand_tokens) or rb
        if kind == "all-gather":
            moved = rb
        elif kind == "reduce-scatter":
            moved = ob
        elif kind == "all-reduce":
            moved = 2 * ob
        else:
            moved = ob
        by_kind[kind] = by_kind.get(kind, 0) + moved
        total += moved
        count += 1
        if _crosses_pod(line, pod_boundary):
            dci += moved
    return CollectiveStats(bytes_by_kind=by_kind, total_bytes=total,
                           dci_bytes=dci, op_count=count)


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    flops: float
    bytes_accessed: float
    comm_bytes: int
    dci_bytes: int
    model_flops_per_chip: float
    useful_flop_ratio: float  # MODEL_FLOPS / HLO_FLOPS (per chip)

    def to_json(self):
        return dataclasses.asdict(self)


def model_flops(kind: str, active_params: float, batch: int, seq: int) -> float:
    """Whole-job useful FLOPs: 6ND train, 2ND prefill, 2N*batch decode."""
    if kind == "train":
        return 6.0 * active_params * batch * seq
    if kind == "prefill":
        return 2.0 * active_params * batch * seq
    return 2.0 * active_params * batch  # decode: one token per request


def roofline_from_stats(stats, *, kind: str, active_params: float,
                        batch: int, seq: int, chips: int) -> Roofline:
    """Roofline from loop-aware HLO stats (launch/hlo_analysis.py)."""
    compute_s = stats.flops / PEAK_FLOPS
    memory_s = stats.hbm_bytes / HBM_BW
    collective_s = stats.collective_bytes / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(kind, active_params, batch, seq) / chips
    return Roofline(
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, flops=stats.flops,
        bytes_accessed=stats.hbm_bytes,
        comm_bytes=int(stats.collective_bytes),
        dci_bytes=int(stats.dci_bytes),
        model_flops_per_chip=mf,
        useful_flop_ratio=(mf / stats.flops if stats.flops > 0 else 0.0))


def compute_roofline(cost: dict, coll: CollectiveStats, *, kind: str,
                     active_params: float, batch: int, seq: int,
                     chips: int) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    by = float(cost.get("bytes accessed", 0.0))
    compute_s = flops / PEAK_FLOPS
    memory_s = by / HBM_BW
    collective_s = coll.total_bytes / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(kind, active_params, batch, seq) / chips
    return Roofline(
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, flops=flops, bytes_accessed=by,
        comm_bytes=coll.total_bytes, dci_bytes=coll.dci_bytes,
        model_flops_per_chip=mf,
        useful_flop_ratio=(mf / flops if flops > 0 else 0.0))
