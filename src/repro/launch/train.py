"""Training driver: ``python -m repro.launch.train --arch granite-3-2b ...``

Runs on whatever devices exist (CPU smoke, real TPU slices) via a local
mesh; reduced configs via --reduced for laptop-scale runs.  Fault tolerance
is on by default: checkpoint every --ckpt-every steps, auto-resume from the
latest checkpoint in --ckpt-dir.
"""

from __future__ import annotations

import argparse
import dataclasses
import logging
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced_config
from repro.data.pipeline import Prefetcher, batch_for_step
from repro.dist import sharding as shr
from repro.launch.mesh import make_local_mesh
from repro.models.common import set_mesh
from repro.training.fault_tolerance import run_resilient
from repro.training.optimizer import OptimizerConfig
from repro.training.train_loop import (
    TrainConfig, init_train_state, make_train_step)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced same-family config (CPU-scale)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg, seq_len=args.seq_len or 128,
                             global_batch=args.batch or 8)
    shape = next(s for s in cfg.shapes if s.name == args.shape)
    if args.seq_len or args.batch:
        shape = dataclasses.replace(
            shape, seq_len=args.seq_len or shape.seq_len,
            global_batch=args.batch or shape.global_batch)

    opt = OptimizerConfig(
        name="adafactor" if cfg.param_count() >= 100e9 else "adamw",
        peak_lr=args.lr, total_steps=args.steps,
        warmup_steps=max(args.steps // 20, 5))
    tc = TrainConfig(optimizer=opt, num_microbatches=args.microbatches,
                     compress_grads=args.compress_grads)

    mesh = make_local_mesh(args.model_parallel)
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"shape={shape.name} batch={shape.global_batch} seq={shape.seq_len} "
          f"mesh={dict(mesh.shape)} optimizer={opt.name}")

    with mesh, set_mesh(mesh):
        state = init_train_state(jax.random.PRNGKey(args.seed), cfg, tc)
        step_fn = jax.jit(make_train_step(cfg, tc))

        pf = Prefetcher(cfg, shape, seed=args.seed)
        try:
            t0 = time.perf_counter()
            state, info = run_resilient(
                step_fn, state,
                lambda s: jax.tree.map(jnp.asarray, pf.get(s)),
                total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                ckpt_every=args.ckpt_every, log_every=args.log_every)
            dt = time.perf_counter() - t0
        finally:
            pf.close()

    loss = float(jax.device_get(info["final_metrics"]["loss"]))
    tok_per_step = shape.global_batch * shape.seq_len
    print(f"done: {info['steps']} steps in {dt:.1f}s "
          f"({dt / max(info['steps'], 1):.3f}s/step, "
          f"{tok_per_step / (dt / max(info['steps'], 1)):.0f} tok/s) "
          f"final loss {loss:.4f} restarts={info['restarts']}")


if __name__ == "__main__":
    main()
