"""The three step functions each (arch x shape) cell lowers, plus their
sharding assignments.  Shared by dryrun.py (abstract) and train.py/serve.py
(concrete execution)."""

from __future__ import annotations

import functools
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec
from repro.dist import sharding as shr
from repro.models import model as model_mod
from repro.models.common import set_mesh
from repro.training.train_loop import TrainConfig, TrainState, make_train_step


def make_step_fn(cfg: ArchConfig, kind: str, tc: TrainConfig):
    """Returns the function the cell lowers (closure over cfg)."""
    if kind == "train":
        inner = make_train_step(cfg, tc)

        def train_step(state: TrainState, batch: dict):
            return inner(state, batch)
        return train_step

    if kind == "prefill":
        def prefill_step(params, batch, caches):
            return model_mod.forward_prefill(params, cfg, batch, caches)
        return prefill_step

    if kind == "decode":
        def serve_step(params, token, pos, caches):
            return model_mod.forward_decode(params, cfg, token, pos, caches)
        return serve_step

    raise ValueError(kind)


def _state_shardings(state_abs, mesh: Mesh):
    """TrainState shardings: params rules applied to params & optimizer."""
    params_sh = shr.param_specs(state_abs.params, mesh)
    opt_sh = jax.tree.map(
        lambda leaf: None, state_abs.opt_state)  # placeholder, replaced below
    # optimizer state mirrors the param tree per field; apply the same rules
    opt_sh = jax.tree_util.tree_map_with_path(
        lambda path, leaf: shr.named(mesh, shr._rule_for(path, leaf),
                                     tuple(leaf.shape)),
        state_abs.opt_state)
    ef_sh = None
    if state_abs.ef_state is not None:
        ef_sh = jax.tree_util.tree_map_with_path(
            lambda path, leaf: shr.named(mesh, shr._rule_for(path, leaf),
                                         tuple(leaf.shape)),
            state_abs.ef_state)
    return TrainState(step=shr.named(mesh, P()), params=params_sh,
                      opt_state=opt_sh, ef_state=ef_sh)


HBM_SERVE_BUDGET = 8e9  # bytes/device available for TP-resident weights


def _serve_replicated(cfg: ArchConfig, mesh: Mesh) -> bool:
    """True when bf16 weights / model-axis fit the serving HBM budget —
    then serving drops FSDP weight sharding (no per-step weight gathers)."""
    model_ways = mesh.shape.get("model", 1)
    return cfg.param_count() * 2 / model_ways <= HBM_SERVE_BUDGET


def shardings_for(kind: str, args: tuple, mesh: Mesh,
                  cfg: ArchConfig | None = None):
    """in_shardings pytree matching input_specs(...)['args']."""
    if kind == "train":
        state_abs, batch_abs = args
        return (_state_shardings(state_abs, mesh),
                shr.batch_specs(batch_abs, mesh))
    rep = cfg is not None and _serve_replicated(cfg, mesh)
    if kind == "prefill":
        params_abs, batch_abs, caches_abs = args
        return (shr.param_specs(params_abs, mesh, serve_replicated=rep),
                shr.batch_specs(batch_abs, mesh),
                shr.cache_specs(caches_abs, mesh))
    if kind == "decode":
        params_abs, token_abs, pos_abs, caches_abs = args
        return (shr.param_specs(params_abs, mesh, serve_replicated=rep),
                shr.named(mesh, P(shr.FSDP_AXES), tuple(token_abs.shape)),
                shr.named(mesh, P(shr.FSDP_AXES), tuple(pos_abs.shape)),
                shr.cache_specs(caches_abs, mesh))
    raise ValueError(kind)


def out_shardings_for(kind: str, args: tuple, mesh: Mesh,
                      cfg: ArchConfig | None = None):
    ins = shardings_for(kind, args, mesh, cfg)
    if kind == "train":
        # (new_state, metrics)
        return (ins[0], None)
    if kind == "prefill":
        # (last logits, caches)
        return (None, ins[2])
    if kind == "decode":
        return (None, ins[3])
    raise ValueError(kind)


def lower_cell(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh, *,
               tc: TrainConfig | None = None):
    """jit(step).lower(...) for one (arch x shape) on ``mesh``."""
    from repro.launch import specs as specs_mod

    tc = tc or TrainConfig.for_arch(cfg)
    spec = specs_mod.input_specs(cfg, shape, tc=tc)
    kind, args = spec["kind"], spec["args"]
    step = make_step_fn(cfg, kind, tc)
    in_sh = shardings_for(kind, args, mesh, cfg)
    out_sh = out_shardings_for(kind, args, mesh, cfg)
    with mesh, set_mesh(mesh):
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
        lowered = jitted.lower(*args)
    return lowered, kind
