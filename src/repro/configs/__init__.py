"""Config registry: ``get_config(name)`` and reduced smoke variants."""

from __future__ import annotations

import dataclasses

from repro.configs.base import (  # noqa: F401
    ArchConfig, MLAConfig, MambaConfig, MoEConfig, NFFTAttentionConfig,
    ShapeSpec, TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K,
)
from repro.configs.archs import (  # noqa: F401
    ALL_ARCHS, EXTRA_ARCHS, DEEPSEEK_V3_671B, GEMMA_7B, GRANITE_3_2B,
    GRANITE_3_2B_NFFT, HUBERT_XLARGE, JAMBA_1_5_LARGE, LLAMA3_405B,
    MAMBA2_1_3B, OLMOE_1B_7B, PALIGEMMA_3B, QWEN15_32B,
)

_REGISTRY = {c.name: c for c in ALL_ARCHS + EXTRA_ARCHS}


def list_archs() -> list[str]:
    return sorted(_REGISTRY)


def get_config(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; available: {list_archs()}")
    return _REGISTRY[name]


def reduced_config(cfg: ArchConfig, *, seq_len: int = 64,
                   global_batch: int = 2) -> ArchConfig:
    """Small same-family config for CPU smoke tests.

    Preserves the structural pattern (GQA ratio, MoE/hybrid periodicity, MLA,
    frontends) while shrinking widths/depths/vocab.
    """
    num_layers = 4
    if cfg.attn_every > 1:
        # keep the hybrid interleave pattern visible: one full period
        num_layers = 2 * cfg.attn_every
    if cfg.moe is not None and cfg.moe.first_dense_layers > 0:
        num_layers = max(num_layers, cfg.moe.first_dense_layers + 2)

    kv_ratio = max(1, (cfg.num_heads or 1) // max(cfg.num_kv_heads or 1, 1))
    heads = 4
    kv_heads = max(1, heads // kv_ratio)

    moe = None
    if cfg.moe is not None:
        moe = dataclasses.replace(
            cfg.moe, num_experts=min(8, cfg.moe.num_experts), top_k=2,
            d_ff_expert=64,
            num_shared_experts=min(1, cfg.moe.num_shared_experts),
            first_dense_layers=min(1, cfg.moe.first_dense_layers))
    mla = None
    if cfg.mla is not None:
        mla = MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
                        qk_rope_head_dim=8, v_head_dim=16)
    mamba = None
    if cfg.mamba is not None:
        mamba = dataclasses.replace(cfg.mamba, d_state=16, head_dim=16,
                                    chunk_size=16)

    shapes = tuple(
        dataclasses.replace(s, seq_len=seq_len, global_batch=global_batch)
        for s in cfg.shapes)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        num_layers=num_layers, d_model=64, num_heads=heads if cfg.num_heads else 0,
        num_kv_heads=kv_heads if cfg.num_kv_heads else 0,
        d_ff=128 if cfg.d_ff else 0, vocab_size=128,
        head_dim=16 if cfg.head_dim else None,
        moe=moe, mla=mla, mamba=mamba,
        frontend_dim=32 if cfg.frontend_dim else 0,
        num_prefix_embeds=min(4, cfg.num_prefix_embeds),
        shapes=shapes,
        param_dtype="float32", activation_dtype="float32",
    )
