"""Architecture + shape configuration schema.

Every assigned architecture is an :class:`ArchConfig`; the four LM shape
cells are :class:`ShapeSpec`s.  ``skip_reason`` marks (arch x shape) cells
that are skipped *by instruction* (encoder-only decode, full-attention
long-context) — the dry-run reports them as skipped, not failed.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001
    # layers whose FFN is dense instead of MoE (e.g. deepseek first 3)
    first_dense_layers: int = 0
    # jamba: MoE only every k-th layer (1 = every layer)
    moe_every: int = 1
    # routing token groups, aligned with the data shards (grouped routing:
    # local scatter/gather + one all-to-all reshard; see models/mlp.py)
    token_groups: int = 16


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek multi-head latent attention dims."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk_size: int = 128


@dataclasses.dataclass(frozen=True)
class NFFTAttentionConfig:
    """Paper-integration: O(n) Gaussian-kernel attention on low-d features."""
    feature_dim: int = 2
    bandwidth: int = 32  # N per dim
    window_cutoff: int = 4  # m
    # kernel width in feature space (features live in ~[-0.17, 0.17]^d);
    # sigma = 0.15 keeps both the bandwidth-truncation and periodization
    # errors of K_RF below ~1e-5 at N = 32 (see models/nfft_attention.py)
    sigma: float = 0.15
    # learn the kernel width: adds a log_sigma parameter leaf and routes
    # b_hat through the differentiable kernel_fourier_coefficients path
    learn_sigma: bool = False


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # 'train' | 'prefill' | 'decode'
    seq_len: int
    global_batch: int
    skip_reason: Optional[str] = None


TRAIN_4K = ShapeSpec("train_4k", "train", 4096, 256)
PREFILL_32K = ShapeSpec("prefill_32k", "prefill", 32768, 32)
DECODE_32K = ShapeSpec("decode_32k", "decode", 32768, 128)
LONG_500K = ShapeSpec("long_500k", "decode", 524288, 1)


def _skip(shape: ShapeSpec, reason: str) -> ShapeSpec:
    return dataclasses.replace(shape, skip_reason=reason)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # 'dense' | 'moe' | 'ssm' | 'hybrid' | 'vlm' | 'audio'
    source: str  # provenance string from the assignment table

    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None  # default d_model // num_heads
    encoder_only: bool = False
    causal: bool = True
    activation: str = "silu"  # 'silu' (SwiGLU), 'geglu', 'gelu'
    norm_eps: float = 1e-5
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    tie_embeddings: bool = False
    embedding_scale: bool = False  # gemma: multiply embeds by sqrt(d)
    logit_softcap: Optional[float] = None

    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    mamba: Optional[MambaConfig] = None
    # hybrid: attention every k-th layer (jamba 1:7 -> attn_every=8), 0 = all
    attn_every: int = 1
    mtp_depth: int = 0  # deepseek multi-token prediction heads

    # modality frontend stub: inputs are precomputed embeddings, not tokens
    frontend: str = "none"  # 'none' | 'audio_stub' | 'vision_stub'
    frontend_dim: int = 0  # raw embedding dim fed by the stub
    num_prefix_embeds: int = 0  # vlm: image patch positions prepended

    # paper integration: replace softmax attention by NFFT kernel attention
    nfft_attention: Optional[NFFTAttentionConfig] = None

    shapes: Tuple[ShapeSpec, ...] = ()

    param_dtype: str = "bfloat16"
    activation_dtype: str = "bfloat16"

    # ---- derived ----
    @property
    def head_dim_eff(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def dtype(self):
        return jnp.dtype(self.activation_dtype)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def is_attention_layer(self, layer_idx: int) -> bool:
        if self.mamba is not None and self.attn_every == 0:
            return False  # pure SSM
        if self.attn_every <= 1:
            return True
        return (layer_idx % self.attn_every) == self.attn_every - 1

    def is_moe_layer(self, layer_idx: int) -> bool:
        if self.moe is None:
            return False
        if layer_idx < self.moe.first_dense_layers:
            return False
        return ((layer_idx - self.moe.first_dense_layers)
                % self.moe.moe_every) == 0

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks + head)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim_eff
        total = v * d  # embedding
        if not self.tie_embeddings and not self.encoder_only:
            total += v * d
        for i in range(self.num_layers):
            if self.is_attention_layer(i):
                if self.mla is not None:
                    m = self.mla
                    total += d * m.q_lora_rank
                    total += m.q_lora_rank * self.num_heads * (
                        m.qk_nope_head_dim + m.qk_rope_head_dim)
                    total += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    total += m.kv_lora_rank * self.num_heads * (
                        m.qk_nope_head_dim + m.v_head_dim)
                    total += self.num_heads * m.v_head_dim * d
                else:
                    total += d * self.num_heads * hd  # Q
                    total += 2 * d * self.num_kv_heads * hd  # K, V
                    total += self.num_heads * hd * d  # O
            elif self.mamba is not None:
                mc = self.mamba
                d_in = mc.expand * d
                n_h = d_in // mc.head_dim
                conv_dim = d_in + 2 * mc.n_groups * mc.d_state
                total += d * (2 * d_in + 2 * mc.n_groups * mc.d_state + n_h)
                total += conv_dim * mc.d_conv
                total += d_in * d
            # FFN
            n_mats = 3 if self.activation in ("silu", "geglu") else 2
            if self.is_moe_layer(i):
                total += self.moe.num_experts * n_mats * d * self.moe.d_ff_expert
                total += (self.moe.num_shared_experts * n_mats * d
                          * self.moe.d_ff_expert)
                total += d * self.moe.num_experts  # router
            else:
                total += n_mats * d * ff
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        n_mats = 3 if self.activation in ("silu", "geglu") else 2
        total = self.param_count()
        for i in range(self.num_layers):
            if self.is_moe_layer(i):
                inactive = (self.moe.num_experts - self.moe.top_k)
                total -= inactive * n_mats * d * self.moe.d_ff_expert
        return total
