"""The 10 assigned architectures (exact figures from the assignment table),
plus the beyond-paper `granite-3-2b-nfft` variant that swaps softmax
attention for the paper's O(n) NFFT kernel attention.

Shape-cell skips follow the assignment rules:
  * encoder-only archs skip decode shapes,
  * pure full-attention archs skip long_500k (needs sub-quadratic attention),
  * SSM / hybrid archs run long_500k natively.
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import (
    ArchConfig, DECODE_32K, LONG_500K, MLAConfig, MambaConfig, MoEConfig,
    NFFTAttentionConfig, PREFILL_32K, TRAIN_4K, _skip,
)

_FULL_ATTN_SKIP = "pure full-attention arch: long_500k needs sub-quadratic attention (DESIGN.md §5)"
_ENCODER_SKIP = "encoder-only arch: no decode step"


HUBERT_XLARGE = ArchConfig(
    name="hubert-xlarge", family="audio",
    source="arXiv:2106.07447; unverified",
    num_layers=48, d_model=1280, num_heads=16, num_kv_heads=16,
    d_ff=5120, vocab_size=504,
    encoder_only=True, causal=False, activation="gelu",
    frontend="audio_stub", frontend_dim=512,
    shapes=(TRAIN_4K, PREFILL_32K,
            _skip(DECODE_32K, _ENCODER_SKIP),
            _skip(LONG_500K, _ENCODER_SKIP + "; full attention")),
)

DEEPSEEK_V3_671B = ArchConfig(
    name="deepseek-v3-671b", family="moe",
    source="arXiv:2412.19437; hf",
    num_layers=61, d_model=7168, num_heads=128, num_kv_heads=128,
    d_ff=18432,  # dense layers (first 3); routed experts use d_ff_expert
    vocab_size=129280,
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(num_experts=256, top_k=8, d_ff_expert=2048,
                  num_shared_experts=1, first_dense_layers=3),
    mtp_depth=1,
    shapes=(TRAIN_4K, PREFILL_32K, DECODE_32K,
            _skip(LONG_500K, _FULL_ATTN_SKIP)),
)

OLMOE_1B_7B = ArchConfig(
    name="olmoe-1b-7b", family="moe",
    source="arXiv:2409.02060; hf",
    num_layers=16, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1024, vocab_size=50304,
    moe=MoEConfig(num_experts=64, top_k=8, d_ff_expert=1024),
    shapes=(TRAIN_4K, PREFILL_32K, DECODE_32K,
            _skip(LONG_500K, _FULL_ATTN_SKIP)),
)

LLAMA3_405B = ArchConfig(
    name="llama3-405b", family="dense",
    source="arXiv:2407.21783; unverified",
    num_layers=126, d_model=16384, num_heads=128, num_kv_heads=8,
    d_ff=53248, vocab_size=128256, rope_theta=500_000.0,
    shapes=(TRAIN_4K, PREFILL_32K, DECODE_32K,
            _skip(LONG_500K, _FULL_ATTN_SKIP)),
)

GRANITE_3_2B = ArchConfig(
    name="granite-3-2b", family="dense",
    source="hf:ibm-granite/granite-3.0-2b-base; hf",
    num_layers=40, d_model=2048, num_heads=32, num_kv_heads=8,
    d_ff=8192, vocab_size=49155, head_dim=64, tie_embeddings=True,
    shapes=(TRAIN_4K, PREFILL_32K, DECODE_32K,
            _skip(LONG_500K, _FULL_ATTN_SKIP)),
)

GRANITE_3_2B_NFFT = dataclasses.replace(
    GRANITE_3_2B,
    name="granite-3-2b-nfft",
    nfft_attention=NFFTAttentionConfig(feature_dim=2, bandwidth=32,
                                       window_cutoff=4, sigma=0.15),
    shapes=(TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K),
)

GEMMA_7B = ArchConfig(
    name="gemma-7b", family="dense",
    source="arXiv:2403.08295; hf",
    num_layers=28, d_model=3072, num_heads=16, num_kv_heads=16,
    d_ff=24576, vocab_size=256_000, head_dim=256, activation="geglu",
    tie_embeddings=True, embedding_scale=True,
    shapes=(TRAIN_4K, PREFILL_32K, DECODE_32K,
            _skip(LONG_500K, _FULL_ATTN_SKIP)),
)

QWEN15_32B = ArchConfig(
    name="qwen1.5-32b", family="dense",
    source="hf:Qwen/Qwen1.5-0.5B; hf",
    num_layers=64, d_model=5120, num_heads=40, num_kv_heads=40,
    d_ff=27392, vocab_size=152064, qkv_bias=True,
    shapes=(TRAIN_4K, PREFILL_32K, DECODE_32K,
            _skip(LONG_500K, _FULL_ATTN_SKIP)),
)

MAMBA2_1_3B = ArchConfig(
    name="mamba2-1.3b", family="ssm",
    source="arXiv:2405.21060; unverified",
    num_layers=48, d_model=2048, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280,
    attn_every=0,  # attention-free
    mamba=MambaConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                      n_groups=1, chunk_size=128),
    tie_embeddings=True,
    shapes=(TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K),
)

PALIGEMMA_3B = ArchConfig(
    name="paligemma-3b", family="vlm",
    source="arXiv:2407.07726; hf",
    num_layers=18, d_model=2048, num_heads=8, num_kv_heads=1,
    d_ff=16384, vocab_size=257_216, head_dim=256, activation="geglu",
    tie_embeddings=True, embedding_scale=True,
    frontend="vision_stub", frontend_dim=1152, num_prefix_embeds=256,
    shapes=(TRAIN_4K, PREFILL_32K, DECODE_32K,
            _skip(LONG_500K, _FULL_ATTN_SKIP)),
)

JAMBA_1_5_LARGE = ArchConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    source="arXiv:2403.19887; hf",
    num_layers=72, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=24576, vocab_size=65536,
    attn_every=8,  # 1 attention : 7 mamba
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2, head_dim=64,
                      n_groups=1, chunk_size=128),
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=24576, moe_every=2),
    shapes=(TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K),
)

ALL_ARCHS = (
    HUBERT_XLARGE, DEEPSEEK_V3_671B, OLMOE_1B_7B, LLAMA3_405B, GRANITE_3_2B,
    GEMMA_7B, QWEN15_32B, MAMBA2_1_3B, PALIGEMMA_3B, JAMBA_1_5_LARGE,
)

EXTRA_ARCHS = (GRANITE_3_2B_NFFT,)
