"""Graph-predict serving tier: continuous-batched NFFT kernel predictions.

The engine serves ``F(x) = sum_i alpha_i K(x_i - x)`` to many concurrent
users with the request/slot/recycle idiom of :mod:`repro.serving.engine`,
but the "decode step" is a fastsum gather instead of a transformer forward:

* A :class:`GraphModelRegistry` holds multi-tenant :class:`~repro.graph.
  krr.KRRModel`\\ s grouped by training points: every model fitted on the
  same nodes shares ONE :class:`~repro.core.fastsum.PredictionPlan` (node
  scaling, NFFT plan, Morton-sorted source geometry) and contributes only
  its O(N^d) spectral multiplier — the bank layout of
  :class:`~repro.core.fastsum.FastsumOperatorBank`.

* Per (model, dual-vector) column the registry caches the *transformed
  grid* — spread -> rfftn -> multiply -> irfftn of the dual vector
  (:func:`repro.core.fastsum_exec.fused_transform_columns`).  The grid
  depends only on the source side, so it plays the paged-KV role: built
  once (cold columns of one tick batch share one bank transform — one
  spread + one FFT pair for all of them), reused by every later tick.

* A predict tick packs the due chunk of every active request's query
  points into ONE target set, builds one O(m) window geometry, and runs
  ONE ragged gather (:func:`repro.core.fastsum_exec.fused_gather_columns`)
  where each packed row reads its request's grid channel.  Steady-state
  traffic therefore replans *nothing*: per tick the only work is the
  target geometry build and the gather.

* Requests longer than ``chunk`` query points span multiple ticks with a
  per-slot ``pos`` cursor; finished slots are recycled immediately by
  :meth:`GraphServeEngine._admit`, so the tick never drains while the
  queue is non-empty.  Pack and channel widths are padded to fixed sizes,
  so the jitted tick body compiles once per tenant group.

Observability: the registry counts plan/multiplier/grid builds and grid
cache hits; the engine records per-tick queue depth, slot occupancy, and
rows served (:class:`TickStats`) — the counters the serving benchmark's
numbers are explained with, and the ones the zero-replan regression test
asserts on.

Guarded execution (``guards=True``, default): per-request deadlines with
slot-recycling eviction, bounded-queue admission with backpressure
rejection, out-of-domain query handling (reject, or re-plan through the
exact :func:`~repro.graph.krr.krr_predict` slow path — never a silently
wrong torus wraparound), a non-finite output guard, plan-invariant
validation with automatic group rebuild, and a per-tenant circuit breaker
that trips on repeated failures, invalidates the tenant's cached grids
(the poisoned-state recovery path), and sheds that tenant's load for a
cooldown.  Deterministic fault injection hooks in via
``GraphServeEngine(chaos=...)`` (see :mod:`repro.runtime.faultinject`).
"""

from __future__ import annotations

import contextlib
import dataclasses
import queue
import threading
import time
from collections import OrderedDict
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fastsum_exec
from repro.core.fastsum import (
    PredictionPlan, make_prediction_plan, prediction_multiplier,
)
from repro.graph.krr import KRRModel, krr_predict, points_fingerprint

Array = jax.Array

_ALPHA = "alpha"  # column id for a request served with the model's own dual


@dataclasses.dataclass
class PredictRequest:
    """One user's prediction request.

    ``rhs`` overrides the model's dual vector (length n_train) — e.g. a
    per-user fine-tuned alpha; ``None`` serves the registered model's own.
    """

    uid: int
    model_id: str
    query_points: np.ndarray  # (m, d)
    rhs: Optional[np.ndarray] = None
    deadline_s: Optional[float] = None  # relative to submit; None = none
    # filled by the engine:
    output: Optional[np.ndarray] = None  # (m,) predictions
    done: bool = False
    error: Optional[str] = None
    submitted_at: float = 0.0
    finished_at: float = 0.0

    @property
    def latency(self) -> float:
        return self.finished_at - self.submitted_at


@dataclasses.dataclass
class TickStats:
    """Per-tick observability record (appended to ``engine.tick_log``)."""

    queue_depth: int  # waiting requests after admission
    occupancy: int  # active slots this tick
    groups: int  # tenant groups touched
    rows: int  # query rows served
    grid_builds: int  # cold (model, rhs) columns transformed this tick
    grid_hits: int  # columns served from the grid cache
    finished: int  # requests retired this tick
    seconds: float
    # guard counters (0 / False on a healthy tick):
    evicted: int = 0  # deadline-expired requests evicted (slot recycled)
    out_of_domain: int = 0  # inadmissible queries rejected or re-planned
    nonfinite: int = 0  # requests failed by the non-finite output guard
    rebuilds: int = 0  # corrupted-plan group rebuilds triggered
    dropped: bool = False  # tick dropped by fault injection


@dataclasses.dataclass
class _ModelEntry:
    model: KRRModel
    member: int  # index into the group's multiplier stack


class _TenantGroup:
    """Models sharing training points (hence plan, scaling, geometry)."""

    def __init__(self, pred: PredictionPlan, grid_cache_slots: int):
        self.pred = pred
        self.gkey: Optional[tuple] = None  # registry group key
        self.domain_args: tuple = (None, 0.5)  # (domain_points, margin)
        self.entries: dict[str, _ModelEntry] = {}
        self.multipliers: list[Array] = []  # one folded half-spectrum each
        self.mult_stack: Optional[Array] = None  # (S,) + half-spectrum
        # transformed-grid LRU keyed (model_id, rhs fingerprint | "alpha")
        self.grids: OrderedDict[tuple, Array] = OrderedDict()
        self.grid_cache_slots = grid_cache_slots
        self._zero_grid: Optional[Array] = None

    def add(self, model_id: str, model: KRRModel, mult: Array) -> None:
        self.multipliers.append(mult)
        self.mult_stack = jnp.stack(self.multipliers)
        self.entries[model_id] = _ModelEntry(model, len(self.multipliers) - 1)
        # a re-registered model invalidates its cached grids
        for key in [k for k in self.grids if k[0] == model_id]:
            del self.grids[key]

    def zero_grid(self) -> Array:
        """A zero channel for padding the tick grid to its fixed width."""
        if self._zero_grid is None:
            plan = self.pred.plan
            self._zero_grid = jnp.zeros(
                (plan.grid_size,) * plan.d, self.pred.scaled_src.dtype)
        return self._zero_grid


class GraphModelRegistry:
    """Multi-tenant model registry with per-group plan + grid caches.

    Thread-safe: registration and grid-cache access are guarded by one lock
    (the engine tick loop and an enqueue/registration thread may interleave).
    """

    def __init__(self, *, grid_cache_slots: int = 32, journal=None):
        """``journal`` is an optional :class:`~repro.serving.journal.
        RegistryJournal`: every registration/eviction appends one
        checksummed record, making the registry warm-restartable via
        :func:`~repro.serving.journal.recover_registry`."""
        self._groups: dict[tuple, _TenantGroup] = {}
        self._model_group: dict[str, _TenantGroup] = {}
        self._lock = threading.Lock()
        self._journal = journal
        self._journal_local = threading.local()
        self.grid_cache_slots = grid_cache_slots
        self.counters = {
            "plan_builds": 0,        # PredictionPlan constructions
            "multiplier_builds": 0,  # per-model spectral multipliers
            "grid_builds": 0,        # (model, rhs) transform-to-grid runs
            "grid_hits": 0,          # columns served from the grid cache
            "bank_transforms": 0,    # fused_transform_columns invocations
            "grid_invalidations": 0,  # cached grids dropped by the guards
            "group_rebuilds": 0,     # corrupted-plan group rebuilds
        }

    # -- journal plumbing ----------------------------------------------------
    def attach_journal(self, journal) -> None:
        """Journal future registrations/evictions (recovery replay attaches
        the journal only *after* replay, so replay re-appends nothing)."""
        self._journal = journal

    @contextlib.contextmanager
    def _suppress_journal(self):
        """Internal re-registrations (group rebuilds, evictions of group
        siblings) must not append duplicate journal records."""
        prev = getattr(self._journal_local, "suppress", False)
        self._journal_local.suppress = True
        try:
            yield
        finally:
            self._journal_local.suppress = prev

    def _journal_append(self, record: dict) -> None:
        if (self._journal is not None
                and not getattr(self._journal_local, "suppress", False)):
            self._journal.append(record)

    def register(self, model_id: str, model: KRRModel, *,
                 domain_points: Optional[Array] = None,
                 margin: float = 0.5) -> None:
        """Add (or replace) a servable model.

        Models fitted on the same training points (same content, params,
        and declared domain) join one tenant group and share its
        prediction plan; only the model's spectral multiplier is built.
        With a journal attached, the registration is made durable *before*
        it becomes servable.
        """
        if self._journal is not None and not getattr(
                self._journal_local, "suppress", False):
            from repro.serving import journal as journal_mod
            self._journal.append(journal_mod.register_record(
                model_id, model, domain_points=domain_points, margin=margin))
        with self._lock:
            gkey = (points_fingerprint(model.train_points), model.params,
                    None if domain_points is None
                    else points_fingerprint(domain_points), margin)
            group = self._groups.get(gkey)
            if group is None:
                pred = make_prediction_plan(
                    model.train_points, model.params,
                    domain_points=domain_points, margin=margin)
                group = _TenantGroup(pred, self.grid_cache_slots)
                group.gkey = gkey
                group.domain_args = (domain_points, margin)
                self._groups[gkey] = group
                self.counters["plan_builds"] += 1
            mult = prediction_multiplier(model.kernel, group.pred,
                                         model.params)
            self.counters["multiplier_builds"] += 1
            group.add(model_id, model, mult)
            self._model_group[model_id] = group

    def unregister(self, model_id: str) -> bool:
        """Evict a model from serving (journaled as an eviction record).

        The multiplier stack and grid cache are group-shared, so eviction
        rebuilds the tenant group from its *remaining* models — same
        recovery path as :meth:`rebuild_group`; sibling grids re-derive
        lazily.  Returns False when the model is unknown."""
        with self._lock:
            group = self._model_group.get(model_id)
            if group is None:
                return False
            survivors = [(mid, e) for mid, e in group.entries.items()
                         if mid != model_id]
            domain_points, margin = group.domain_args
            self._groups.pop(group.gkey, None)
            for mid in list(group.entries):
                self._model_group.pop(mid, None)
        from repro.serving import journal as journal_mod
        self._journal_append(journal_mod.unregister_record(model_id))
        with self._suppress_journal():  # siblings are already journaled
            for mid, entry in survivors:
                self.register(mid, entry.model, domain_points=domain_points,
                              margin=margin)
        return True

    def group_of(self, model_id: str) -> Optional[_TenantGroup]:
        with self._lock:
            return self._model_group.get(model_id)

    def model_ids(self) -> list:
        with self._lock:
            return list(self._model_group)

    def stats(self) -> dict:
        with self._lock:
            out = dict(self.counters)
            out["groups"] = len(self._groups)
            out["models"] = len(self._model_group)
            out["grids_resident"] = sum(
                len(g.grids) for g in self._groups.values())
            return out

    # -- guarded-execution surface -----------------------------------------
    def invalidate_grids(self, model_id: str) -> int:
        """Drop every cached grid of ``model_id`` (poisoned-state recovery).

        The dual vectors live in the registered models, so the next request
        rebuilds clean grids from them; only the cache is discarded."""
        with self._lock:
            group = self._model_group.get(model_id)
            if group is None:
                return 0
            keys = [k for k in group.grids if k[0] == model_id]
            for k in keys:
                del group.grids[k]
            self.counters["grid_invalidations"] += len(keys)
            return len(keys)

    @staticmethod
    def plan_valid(group: _TenantGroup) -> bool:
        """Invariant check for a group's frozen plan: the plan's own source
        set must be finite and admissible under its own scaling.  A
        corrupted plan (bit-flipped shift, clobbered geometry) violates
        this; a healthy one never does."""
        src = np.asarray(group.pred.scaled_src)
        if not np.all(np.isfinite(src)):
            return False
        return bool(np.all(np.asarray(group.pred.admissible(
            group.pred.scaled_src))))

    def rebuild_group(self, model_id: str) -> bool:
        """Rebuild ``model_id``'s whole tenant group from its registered
        models: fresh prediction plan, fresh multipliers, empty grid cache.
        The recovery path for a corrupted plan — the models themselves are
        the source of truth."""
        with self._lock:
            group = self._model_group.get(model_id)
            if group is None:
                return False
            items = list(group.entries.items())
            domain_points, margin = group.domain_args
            self._groups.pop(group.gkey, None)
            for mid, _ in items:
                self._model_group.pop(mid, None)
            self.counters["group_rebuilds"] += 1
        with self._suppress_journal():  # a rebuild is not a new registration
            for mid, entry in items:  # register() takes the lock itself
                self.register(mid, entry.model, domain_points=domain_points,
                              margin=margin)
        return True

    # -- grid cache ---------------------------------------------------------
    def ensure_grids(self, group: _TenantGroup,
                     columns: Sequence[tuple], rhs_arrays: dict, *,
                     pad_to: int, backend: Optional[str] = None) -> tuple:
        """Return the cached grid of every column, building cold ones.

        ``columns`` is a list of (model_id, rhs_key); ``rhs_arrays`` maps a
        non-``"alpha"`` rhs_key to its dual vector.  All cold columns of the
        call ride ONE bank transform — one spread + one FFT pair — padded to
        ``pad_to`` channels so the jitted transform compiles once.
        """
        with self._lock:
            missing = [c for c in columns if c not in group.grids]
            if missing:
                cols, members = [], []
                for model_id, rhs_key in missing:
                    entry = group.entries[model_id]
                    vec = (entry.model.alpha if rhs_key == _ALPHA
                           else rhs_arrays[rhs_key])
                    cols.append(jnp.asarray(
                        vec, group.pred.scaled_src.dtype))
                    members.append(entry.member)
                k = len(cols)
                width = max(pad_to, k)
                if k < width:  # zero columns keep the compiled shape fixed
                    cols += [jnp.zeros_like(cols[0])] * (width - k)
                    members += [members[0]] * (width - k)
                xb = jnp.stack(cols, axis=1)  # (n, width)
                mult_cols = group.mult_stack[jnp.asarray(members)]
                grids = fastsum_exec.fused_transform_columns(
                    group.pred.plan, mult_cols, group.pred.src_window, xb,
                    backend=backend)
                for i, ckey in enumerate(missing):
                    group.grids[ckey] = grids[..., i]
                while len(group.grids) > group.grid_cache_slots:
                    group.grids.popitem(last=False)  # evict LRU
                self.counters["grid_builds"] += k
                self.counters["bank_transforms"] += 1
            out = []
            for ckey in columns:
                grid = group.grids[ckey]
                group.grids.move_to_end(ckey)  # mark most recently used
                out.append(grid)
            self.counters["grid_hits"] += len(columns) - len(missing)
            return out, len(missing)


class GraphServeEngine:
    """Slot-based continuous-batching engine for graph predictions.

    ``slots`` bounds concurrent in-flight requests; each slot serves up to
    ``chunk`` query rows per tick, so long requests stream across ticks
    while short ones recycle their slot immediately.  Every tick runs, per
    touched tenant group, exactly one packed gather (plus one bank
    transform when cold columns appear).
    """

    def __init__(self, registry: GraphModelRegistry, *, slots: int = 8,
                 chunk: int = 128, backend: Optional[str] = None,
                 max_queue: Optional[int] = None, guards: bool = True,
                 out_of_domain: str = "reject",
                 breaker_threshold: int = 3, breaker_cooldown: int = 8,
                 chaos=None):
        """``max_queue`` bounds admission (submit rejects with backpressure
        when full); ``guards=False`` disables the runtime guards (deadline
        eviction, non-finite output checks, circuit breaker, plan
        validation) for overhead benchmarking; ``out_of_domain`` is
        ``"reject"`` or ``"replan"`` (exact slow-path predict);
        ``chaos`` is an optional fault-injection schedule with an
        ``apply(engine, tick) -> drop`` method
        (:class:`repro.runtime.faultinject.TickChaos`)."""
        if out_of_domain not in ("reject", "replan"):
            raise ValueError(f"out_of_domain must be 'reject' or 'replan', "
                             f"got {out_of_domain!r}")
        self.registry = registry
        self.slots = slots
        self.chunk = chunk
        self.backend = backend
        self.guards = guards
        self.out_of_domain = out_of_domain
        self.breaker_threshold = breaker_threshold
        self.breaker_cooldown = breaker_cooldown
        self.chaos = chaos
        self.queue: "queue.Queue[PredictRequest]" = \
            queue.Queue(maxsize=max_queue or 0)
        self.active: list[Optional[PredictRequest]] = [None] * slots
        self.pos = np.zeros((slots,), np.int64)
        self._scaled: list[Optional[np.ndarray]] = [None] * slots
        self._group: list[Optional[_TenantGroup]] = [None] * slots
        self._breaker_fails: dict[str, int] = {}
        self._breaker_open_until: dict[str, int] = {}
        self.tick_log: list[TickStats] = []
        self.counters = {"ticks": 0, "rows": 0, "admitted": 0,
                         "finished": 0, "rejected": 0,
                         "geometry_builds": 0,
                         # guard counters
                         "backpressure": 0, "deadline_evicted": 0,
                         "out_of_domain": 0, "replans": 0,
                         "nonfinite": 0, "plan_rebuilds": 0,
                         "breaker_trips": 0, "breaker_rejections": 0,
                         "dropped_ticks": 0}

    # -- public -------------------------------------------------------------
    def submit(self, req: PredictRequest) -> bool:
        """Enqueue a request; False (request failed immediately) when the
        bounded queue is full — backpressure instead of unbounded growth."""
        req.submitted_at = time.perf_counter()
        try:
            self.queue.put_nowait(req)
        except queue.Full:
            req.error = "queue full (backpressure)"
            req.done = True
            req.finished_at = time.perf_counter()
            self.counters["backpressure"] += 1
            return False
        return True

    def step(self) -> TickStats:
        """One engine tick: admit, one packed gather per touched group,
        retire finished requests.  Returns this tick's stats."""
        t0 = time.perf_counter()
        tick = self.counters["ticks"]
        self._tick_guard = {"evicted": 0, "out_of_domain": 0,
                            "nonfinite": 0, "rebuilds": 0}
        dropped = bool(self.chaos is not None
                       and self.chaos.apply(self, tick))
        rows = builds = hits = finished = 0
        by_group: dict[int, list[int]] = {}
        if dropped:
            self.counters["dropped_ticks"] += 1
            occupancy = sum(1 for r in self.active if r is not None)
        else:
            if self.guards:
                self._evict_expired()
            self._admit()
            groups: dict[int, _TenantGroup] = {}
            for slot, req in enumerate(self.active):
                if req is None:
                    continue
                g = self._group[slot]
                by_group.setdefault(id(g), []).append(slot)
                groups[id(g)] = g
            occupancy = sum(len(s) for s in by_group.values())
            for gid, slot_ids in by_group.items():
                r, b, h, f = self._tick_group(groups[gid], slot_ids)
                rows += r
                builds += b
                hits += h
                finished += f
        stats = TickStats(
            queue_depth=self.queue.qsize(),
            occupancy=occupancy,
            groups=len(by_group), rows=rows, grid_builds=builds,
            grid_hits=hits, finished=finished,
            seconds=time.perf_counter() - t0,
            evicted=self._tick_guard["evicted"],
            out_of_domain=self._tick_guard["out_of_domain"],
            nonfinite=self._tick_guard["nonfinite"],
            rebuilds=self._tick_guard["rebuilds"],
            dropped=dropped)
        self.tick_log.append(stats)
        self.counters["ticks"] += 1
        self.counters["rows"] += rows
        self.counters["finished"] += finished
        return stats

    def run_until_drained(self, max_ticks: int = 10_000) -> None:
        for _ in range(max_ticks):
            stats = self.step()
            if stats.occupancy == 0 and self.queue.empty():
                return

    # -- internals ----------------------------------------------------------
    def _fail(self, req: PredictRequest, msg: str) -> None:
        req.error = msg
        req.done = True
        req.finished_at = time.perf_counter()
        self.counters["rejected"] += 1

    def _release(self, slot: int) -> None:
        self.active[slot] = None
        self._scaled[slot] = None
        self._group[slot] = None

    def _evict(self, slot: int, msg: str, counter: str) -> None:
        """Fail an in-flight request and recycle its slot immediately."""
        req = self.active[slot]
        req.error = msg
        req.done = True
        req.finished_at = time.perf_counter()
        self._release(slot)
        self.counters[counter] += 1

    def _evict_expired(self) -> None:
        now = time.perf_counter()
        for slot, req in enumerate(self.active):
            if req is None or req.deadline_s is None:
                continue
            if now - req.submitted_at > req.deadline_s:
                self._evict(slot, "deadline exceeded", "deadline_evicted")
                self._tick_guard["evicted"] += 1

    def _evict_queued(self, req: PredictRequest) -> None:
        """A request whose deadline expired while still queued."""
        req.error = "deadline exceeded"
        req.done = True
        req.finished_at = time.perf_counter()
        self.counters["deadline_evicted"] += 1
        self._tick_guard["evicted"] += 1

    def _handle_inadmissible(self, req: PredictRequest, group: _TenantGroup,
                             q: np.ndarray, scaled: np.ndarray):
        """Admission found inadmissible scaled queries.  Three causes, in
        the order checked: a corrupted plan (detect via the plan invariant,
        rebuild the group from its models, retry), non-finite query points
        (always rejected), or genuinely out-of-domain queries (rejected or
        served via the exact replan slow path per ``out_of_domain``).

        Returns ``(group, scaled)`` when the request may proceed onto a
        slot, ``(None, None)`` when it was finished here (failed or
        replan-served)."""
        if self.guards and not self.registry.plan_valid(group):
            # corrupted plan: rebuild the whole group from the registered
            # models (the source of truth), then retry this admission
            if self.registry.rebuild_group(req.model_id):
                self.counters["plan_rebuilds"] += 1
                self._tick_guard["rebuilds"] += 1
                group = self.registry.group_of(req.model_id)
                if group is not None:
                    scaled = np.asarray(group.pred.scale_targets(q))
                    if bool(np.all(np.asarray(
                            group.pred.admissible(scaled)))):
                        return group, scaled
            if group is None or not self.registry.plan_valid(group):
                self._fail(req, "serving plan corrupted and rebuild failed")
                return None, None
        self._tick_guard["out_of_domain"] += 1
        if not np.all(np.isfinite(q)):
            self._fail(req, "non-finite query points")
            self.counters["out_of_domain"] += 1
            return None, None
        if self.out_of_domain == "replan":
            self._replan(req)
            return None, None
        self._fail(req, "query points outside the registered serving "
                        "domain (inadmissible after scaling)")
        self.counters["out_of_domain"] += 1
        return None, None

    # -- circuit breaker ----------------------------------------------------
    def _breaker_allow(self, model_id: str) -> bool:
        return (self.counters["ticks"]
                >= self._breaker_open_until.get(model_id, 0))

    def _breaker_failure(self, model_id: str) -> None:
        if not self.guards:
            return
        fails = self._breaker_fails.get(model_id, 0) + 1
        if fails >= self.breaker_threshold:
            # trip: shed this tenant's load for the cooldown, and drop its
            # cached grids — poisoned serving state is the likely cause,
            # and the registered models can rebuild clean grids on demand
            self._breaker_open_until[model_id] = (
                self.counters["ticks"] + 1 + self.breaker_cooldown)
            self.counters["breaker_trips"] += 1
            # half-open after cooldown: a single failure re-trips
            self._breaker_fails[model_id] = self.breaker_threshold - 1
            self.registry.invalidate_grids(model_id)
        else:
            self._breaker_fails[model_id] = fails

    def _breaker_success(self, model_id: str) -> None:
        self._breaker_fails.pop(model_id, None)

    def _replan(self, req: PredictRequest) -> None:
        """Serve an out-of-domain request through the exact slow path.

        A full :func:`~repro.graph.krr.krr_predict` replans a prediction
        operator over train ∪ query jointly, so any (finite) query
        location is served correctly — at one-off replan cost instead of a
        silently wrong torus wraparound."""
        group = self.registry.group_of(req.model_id)
        model = group.entries[req.model_id].model
        if req.rhs is not None:
            model = model._replace(
                alpha=jnp.asarray(req.rhs, model.alpha.dtype))
        out = np.asarray(krr_predict(model, jnp.asarray(req.query_points)))
        if not np.all(np.isfinite(out)):
            self._breaker_failure(req.model_id)
            self._fail(req, "non-finite output from out-of-domain replan")
            self.counters["nonfinite"] += 1
            self._tick_guard["nonfinite"] += 1
            return
        req.output = out
        req.done = True
        req.finished_at = time.perf_counter()
        self.counters["replans"] += 1

    def _admit(self) -> None:
        """Fill free slots from the queue (prefill = scale + admissibility).

        Runs at the top of every tick, so a recycled slot is refilled in
        the same tick it was freed — the batch never drains while requests
        wait."""
        for slot in range(self.slots):
            if self.active[slot] is not None:
                continue
            # a rejected request does not consume slot capacity: keep
            # pulling until this slot is filled or the queue is empty
            while True:
                try:
                    req = self.queue.get_nowait()
                except queue.Empty:
                    return
                if (self.guards and req.deadline_s is not None
                        and time.perf_counter() - req.submitted_at
                        > req.deadline_s):
                    self._evict_queued(req)
                    continue
                group = self.registry.group_of(req.model_id)
                if group is None:
                    self._fail(req, f"unknown model_id {req.model_id!r}")
                    continue
                if self.guards and not self._breaker_allow(req.model_id):
                    self._fail(req, f"circuit open for model "
                                    f"{req.model_id!r} (repeated failures)")
                    self.counters["breaker_rejections"] += 1
                    continue
                q = np.asarray(req.query_points)
                if (q.ndim != 2
                        or q.shape[1] != group.pred.scaled_src.shape[1]):
                    self._fail(req,
                               f"query_points shape {q.shape} does not "
                               f"match d={group.pred.scaled_src.shape[1]}")
                    continue
                if (req.rhs is not None
                        and np.asarray(req.rhs).shape !=
                        (group.pred.n_source,)):
                    self._fail(req,
                               f"rhs shape {np.asarray(req.rhs).shape} != "
                               f"({group.pred.n_source},)")
                    continue
                if (self.guards and req.rhs is not None
                        and not np.all(np.isfinite(np.asarray(req.rhs)))):
                    self._fail(req, "non-finite rhs")
                    continue
                scaled = np.asarray(group.pred.scale_targets(q))
                if not bool(np.all(np.asarray(
                        group.pred.admissible(scaled)))):
                    group, scaled = self._handle_inadmissible(
                        req, group, q, scaled)
                    if group is None:
                        continue  # rejected or served via replan
                req.output = np.zeros((q.shape[0],), scaled.dtype)
                self.active[slot] = req
                self.pos[slot] = 0
                self._scaled[slot] = scaled
                self._group[slot] = group
                self.counters["admitted"] += 1
                break

    def _tick_group(self, group: _TenantGroup,
                    slot_ids: list) -> tuple:
        """One packed predict for every active slot of one tenant group."""
        pred = group.pred
        d = pred.scaled_src.shape[1]
        dtype = np.dtype(pred.scaled_src.dtype)

        # resolve (model, dual-vector) columns, deduped across slots
        columns: list[tuple] = []
        col_of_slot: dict[int, int] = {}
        rhs_arrays: dict = {}
        for slot in slot_ids:
            req = self.active[slot]
            if req.rhs is None:
                ckey = (req.model_id, _ALPHA)
            else:
                fp = points_fingerprint(req.rhs)
                rhs_arrays[fp] = req.rhs
                ckey = (req.model_id, fp)
            if ckey not in columns:
                columns.append(ckey)
            col_of_slot[slot] = columns.index(ckey)

        grids, n_built = self.registry.ensure_grids(
            group, columns, rhs_arrays, pad_to=min(self.slots, 8),
            backend=self.backend)

        # fixed-width tick grid: pad channels so the gather compiles once
        width = self.slots
        chans = list(grids) + [group.zero_grid()] * (width - len(grids))
        grid = jnp.stack(chans[:width], axis=-1)

        # pack this tick's chunk of every slot's scaled queries (ragged ->
        # fixed slots*chunk rows; pad rows sit at the origin, always
        # admissible, and their gathered values are discarded)
        m_pack = self.slots * self.chunk
        packed = np.zeros((m_pack, d), dtype)
        col_index = np.zeros((m_pack,), np.int32)
        takes = []
        row = 0
        for slot in slot_ids:
            req = self.active[slot]
            pos = int(self.pos[slot])
            take = min(self.chunk, req.query_points.shape[0] - pos)
            packed[row:row + take] = self._scaled[slot][pos:pos + take]
            col_index[row:row + take] = col_of_slot[slot]
            takes.append((slot, row, pos, take))
            row += take

        tgt = pred.target_window(jnp.asarray(packed))
        self.counters["geometry_builds"] += 1
        out = np.asarray(fastsum_exec.fused_gather_columns(
            pred.plan, tgt, grid, jnp.asarray(col_index),
            backend=self.backend))

        finished = 0
        for slot, row0, pos, take in takes:
            req = self.active[slot]
            seg = out[row0:row0 + take]
            if self.guards and not np.all(np.isfinite(seg)):
                # poisoned grid / multiplier: fail the request, feed the
                # tenant's circuit breaker (tripping invalidates its grids)
                self._evict(slot, "non-finite prediction output",
                            "nonfinite")
                self._tick_guard["nonfinite"] += 1
                self._breaker_failure(req.model_id)
                finished += 1
                continue
            req.output[pos:pos + take] = seg
            self.pos[slot] += take
            if self.pos[slot] >= req.query_points.shape[0]:
                req.done = True
                req.finished_at = time.perf_counter()
                self._release(slot)
                self._breaker_success(req.model_id)
                finished += 1
        return row, n_built, len(columns) - n_built, finished
