"""Serving: batched prefill+decode engine with continuous batching."""

from repro.serving.engine import ServeEngine, Request  # noqa: F401
