"""Serving: continuous-batching engines.

``engine`` is the token-LM prefill+decode engine; ``graph`` is the
graph-predict tier (batched NFFT kernel predictions for multi-tenant KRR
models — see the README "Serving" section); ``journal`` makes the graph
registry durable (checksummed append-only journal + warm-restart replay).
"""

from repro.serving.engine import ServeEngine, Request  # noqa: F401
from repro.serving.graph import (  # noqa: F401
    GraphModelRegistry, GraphServeEngine, PredictRequest, TickStats,
)
from repro.serving.journal import (  # noqa: F401
    RecoveryReport, RegistryJournal, recover_registry,
)
