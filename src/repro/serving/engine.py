"""Batched serving engine with continuous batching.

A fixed-size decode batch of ``slots``; requests queue up, prefill runs
per-request (cache written into the request's slot), decode steps run for
the whole batch every tick with per-slot positions.  Finished slots (EOS or
max tokens) are recycled immediately — the decode batch never drains.

The decode step is the same jitted ``forward_decode`` the dry-run lowers;
per-slot positions exercise the position-masked cache attention, so a batch
can mix requests at wildly different progress (the static-shape analogue of
paged attention).
"""

from __future__ import annotations

import dataclasses
import queue
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import model as M

Array = jax.Array


@dataclasses.dataclass
class Request:
    uid: int
    tokens: list  # prompt token ids
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # filled by the engine:
    output: list = dataclasses.field(default_factory=list)
    done: bool = False
    error: Optional[str] = None


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, *, slots: int = 4,
                 max_seq: int = 256, greedy: bool = True,
                 max_queue: Optional[int] = None):
        assert cfg.frontend == "none", "engine serves token-only archs"
        self.cfg, self.params = cfg, params
        self.slots, self.max_seq = slots, max_seq
        self.caches = M.init_caches(cfg, slots, max_seq)
        self.pos = np.zeros((slots,), np.int32)
        self.active: list[Optional[Request]] = [None] * slots
        self.last_token = np.zeros((slots, 1), np.int32)
        # max_queue bounds admission: submit rejects with backpressure
        # instead of growing the queue without limit (mirrors
        # serving.graph.GraphServeEngine)
        self.queue: "queue.Queue[Request]" = \
            queue.Queue(maxsize=max_queue or 0)
        self.greedy = greedy
        self.backpressure_rejections = 0

        self._decode = jax.jit(
            lambda p, t, q, c: M.forward_decode(p, cfg, t, q, c))
        # one prefill per prompt length bucket (static shapes)
        self._prefill_cache: dict[int, Callable] = {}

    # -- internals ----------------------------------------------------------
    def _prefill_fn(self, length: int):
        if length not in self._prefill_cache:
            cfg = self.cfg

            def fn(params, tokens, caches):
                return M.forward_prefill(params, cfg, {"tokens": tokens},
                                         caches)
            self._prefill_cache[length] = jax.jit(fn)
        return self._prefill_cache[length]

    @staticmethod
    def _batch_axis(path) -> int:
        """Batch axis per cache leaf: period-stacked leaves ('stack' subtree)
        carry a leading n_periods axis, so batch is axis 1 there."""
        names = [str(p.key) for p in path
                 if isinstance(p, jax.tree_util.DictKey)]
        return 1 if "stack" in names else 0

    def _slot_caches(self, slot: int):
        """View of one slot's caches as a batch-1 pytree."""
        return jax.tree_util.tree_map_with_path(
            lambda path, a: jax.lax.slice_in_dim(
                a, slot, slot + 1, axis=self._batch_axis(path)),
            self.caches)

    def _write_slot(self, slot: int, sub):
        def write(path, full, one):
            ax = self._batch_axis(path)
            idx = tuple(slice(slot, slot + 1) if i == ax else slice(None)
                        for i in range(full.ndim))
            return full.at[idx].set(one.astype(full.dtype))
        self.caches = jax.tree_util.tree_map_with_path(
            write, self.caches, sub)

    def _admit(self) -> None:
        for slot in range(self.slots):
            if self.active[slot] is not None:
                continue
            try:
                req = self.queue.get_nowait()
            except queue.Empty:
                return
            prompt = np.asarray(req.tokens, np.int32)[None, :]
            # zero the slot's cache then prefill into it
            zeroed = jax.tree.map(jnp.zeros_like, self._slot_caches(slot))
            logits, sub = self._prefill_fn(prompt.shape[1])(
                self.params, jnp.asarray(prompt), zeroed)
            self._write_slot(slot, sub)
            nxt = self._sample(logits[:, -1, :])
            self.active[slot] = req
            self.pos[slot] = prompt.shape[1]
            self.last_token[slot, 0] = nxt
            req.output.append(int(nxt))

    def _sample(self, logits: Array) -> int:
        return int(jnp.argmax(logits, axis=-1)[0])

    # -- public -------------------------------------------------------------
    def submit(self, req: Request) -> bool:
        """Enqueue a request; False (request failed immediately) when the
        bounded queue is full — backpressure instead of unbounded growth."""
        try:
            self.queue.put_nowait(req)
        except queue.Full:
            req.error = "queue full (backpressure)"
            req.done = True
            self.backpressure_rejections += 1
            return False
        return True

    def step(self) -> int:
        """One engine tick: admit waiting requests, one decode step for the
        whole batch, retire finished slots.  Returns #active slots."""
        self._admit()
        if not any(r is not None for r in self.active):
            return 0
        logits, self.caches = self._decode(
            self.params, jnp.asarray(self.last_token),
            jnp.asarray(self.pos), self.caches)
        nxt = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1), np.int32)
        n_active = 0
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            tok = int(nxt[slot])
            req.output.append(tok)
            self.pos[slot] += 1
            self.last_token[slot, 0] = tok
            finished = (len(req.output) >= req.max_new_tokens
                        or (req.eos_id is not None and tok == req.eos_id)
                        or self.pos[slot] >= self.max_seq - 1)
            if finished:
                req.done = True
                self.active[slot] = None
            else:
                n_active += 1
        return n_active

    def run_until_drained(self, max_ticks: int = 10_000) -> None:
        for _ in range(max_ticks):
            n = self.step()
            if n == 0 and self.queue.empty():
                return
