"""Journaled serving registry: checksummed append-only durability.

A :class:`~repro.serving.graph.GraphModelRegistry` is pure derived state
over its registered models: prediction plans, multiplier stacks, and grid
caches can all be rebuilt from (model, domain contract) pairs.  This module
makes that source of truth durable with an append-only on-disk journal:

* every registration / eviction appends ONE self-contained JSONL record —
  the model's dual vector and training points (base64-encoded raw bytes +
  dtype/shape), kernel name + scalar parameter, frozen
  :class:`~repro.core.fastsum.FastsumParams` fields, and the group's domain
  contract (domain points + admissibility margin);
* each record carries a CRC32 over its canonical JSON encoding, so a torn
  final line (crash mid-append) or a bit-flipped historical record is
  *detected* — replay skips it and surfaces it in the
  :class:`RecoveryReport` instead of silently serving a corrupted model;
* :func:`recover_registry` replays the journal in order: plans and
  multipliers are rebuilt from the recovered models (the registry's normal
  ``register`` path), grid caches re-derive lazily on first demand, and the
  returned report gives per-tenant status.  The recovered registry has the
  journal re-attached, so post-recovery registrations keep appending.

The journal is the registry analogue of the checkpoint manifest's per-leaf
CRC32 (:mod:`repro.training.checkpoint`): both make corruption a detected,
recoverable event rather than a wrong answer.
"""

from __future__ import annotations

import base64
import dataclasses
import json
import os
import threading
import zlib
from typing import Iterator, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.fastsum import FastsumParams
from repro.core.kernels import KERNEL_PARAM_NAME, kernel_from_param
from repro.graph.krr import KRRModel
from repro.serving.graph import GraphModelRegistry

JOURNAL_VERSION = 1


class JournalError(RuntimeError):
    """A journal record could not be encoded or decoded."""


# ---------------------------------------------------------------------------
# Record encoding
# ---------------------------------------------------------------------------

def encode_array(arr) -> dict:
    """Array -> JSON-safe {dtype, shape, data(base64 of raw bytes)}."""
    a = np.ascontiguousarray(np.asarray(arr))
    return {"dtype": a.dtype.str, "shape": list(a.shape),
            "data": base64.b64encode(a.tobytes()).decode("ascii")}


def decode_array(obj: dict) -> np.ndarray:
    raw = base64.b64decode(obj["data"].encode("ascii"))
    a = np.frombuffer(raw, dtype=np.dtype(obj["dtype"]))
    return a.reshape(tuple(obj["shape"])).copy()


def _canonical(record: dict) -> bytes:
    """Canonical bytes the CRC is computed over (crc field excluded)."""
    body = {k: v for k, v in record.items() if k != "crc"}
    return json.dumps(body, sort_keys=True,
                      separators=(",", ":")).encode("utf-8")


def record_crc(record: dict) -> int:
    return zlib.crc32(_canonical(record)) & 0xFFFFFFFF


def register_record(model_id: str, model: KRRModel, *,
                    domain_points=None, margin: float = 0.5) -> dict:
    """The append-only record for one model registration.

    Self-contained: everything the registry derives (plan, multiplier,
    grids) is a function of this record's contents.
    """
    kname = model.kernel.name
    pname = KERNEL_PARAM_NAME.get(kname)
    if pname is None or pname not in model.kernel.params:
        raise JournalError(
            f"kernel {kname!r} is not journal-serializable (custom phi); "
            f"only named kernels {sorted(KERNEL_PARAM_NAME)} round-trip")
    return {
        "v": JOURNAL_VERSION,
        "op": "register",
        "model_id": model_id,
        "alpha": encode_array(model.alpha),
        "train_points": encode_array(model.train_points),
        "kernel": {"name": kname,
                   "param": float(model.kernel.params[pname])},
        "params": dataclasses.asdict(model.params),
        "num_iters": int(np.asarray(model.num_iters)),
        "converged": bool(np.asarray(model.converged)),
        "domain_points": (None if domain_points is None
                          else encode_array(domain_points)),
        "margin": float(margin),
    }


def unregister_record(model_id: str) -> dict:
    return {"v": JOURNAL_VERSION, "op": "unregister", "model_id": model_id}


def decode_register(record: dict):
    """register record -> (KRRModel, domain_points | None, margin)."""
    model = KRRModel(
        alpha=jnp.asarray(decode_array(record["alpha"])),
        train_points=jnp.asarray(decode_array(record["train_points"])),
        kernel=kernel_from_param(record["kernel"]["name"],
                                 record["kernel"]["param"]),
        params=FastsumParams(**record["params"]),
        num_iters=jnp.asarray(record["num_iters"], jnp.int32),
        converged=jnp.asarray(record["converged"]),
    )
    domain = record.get("domain_points")
    domain_points = None if domain is None else jnp.asarray(
        decode_array(domain))
    return model, domain_points, float(record.get("margin", 0.5))


# ---------------------------------------------------------------------------
# The journal file
# ---------------------------------------------------------------------------

class RegistryJournal:
    """Append-only CRC-checked JSONL journal for a serving registry.

    Appends are synchronous (write + flush + fsync) under a lock: when
    ``append`` returns, the record survives a process kill.  A crash *during*
    an append leaves at most one torn final line, which replay detects via
    JSON-parse/CRC failure and skips.
    """

    def __init__(self, path: str):
        self.path = path
        self._lock = threading.Lock()
        self._fh = None

    def append(self, record: dict) -> None:
        record = dict(record)
        record["crc"] = record_crc(record)
        line = json.dumps(record, sort_keys=True,
                          separators=(",", ":")) + "\n"
        with self._lock:
            if self._fh is None:
                parent = os.path.dirname(os.path.abspath(self.path))
                os.makedirs(parent, exist_ok=True)
                self._fh = open(self.path, "a", encoding="utf-8")
            self._fh.write(line)
            self._fh.flush()
            os.fsync(self._fh.fileno())

    def close(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def iter_records(path: str) -> Iterator[tuple]:
    """Yield ``(line_no, record | None, error | None)`` per journal line.

    A line that fails to parse or whose CRC mismatches yields
    ``(line_no, None, reason)`` — the caller decides whether a skipped
    record is fatal (for replay it never is: the journal's source-of-truth
    records are independent, so one corrupt record costs one tenant, not
    the registry)."""
    if not os.path.exists(path):
        return
    with open(path, "r", encoding="utf-8") as fh:
        for line_no, line in enumerate(fh, start=1):
            if not line.strip():
                continue
            try:
                record = json.loads(line)
            except json.JSONDecodeError as e:
                yield line_no, None, f"unparseable record (torn write?): {e}"
                continue
            crc = record.get("crc")
            want = record_crc(record)
            if crc != want:
                yield (line_no, None,
                       f"checksum mismatch (stored {crc}, computed {want})")
                continue
            yield line_no, record, None


# ---------------------------------------------------------------------------
# Recovery
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RecoveryReport:
    """What :func:`recover_registry` found and rebuilt."""

    journal_path: str
    records_total: int = 0    # journal lines examined
    records_applied: int = 0  # records replayed successfully
    records_skipped: int = 0  # corrupt / unreplayable records skipped
    corrupt: list = dataclasses.field(default_factory=list)  # (line, reason)
    tenants: dict = dataclasses.field(default_factory=dict)  # id -> status

    @property
    def clean(self) -> bool:
        """True when every record replayed and every tenant recovered."""
        return (not self.corrupt and all(
            s in ("recovered", "evicted") for s in self.tenants.values()))

    def summary(self) -> str:
        n_rec = sum(1 for s in self.tenants.values() if s == "recovered")
        return (f"replayed {self.records_applied}/{self.records_total} "
                f"records from {self.journal_path}: {n_rec} models "
                f"recovered, {self.records_skipped} records skipped"
                + ("" if self.clean else " [DEGRADED]"))


def recover_registry(journal_path: str, *, grid_cache_slots: int = 32,
                     ) -> tuple[GraphModelRegistry, RecoveryReport]:
    """Warm-restart a registry by replaying its journal.

    Replays registrations/evictions in journal order through the registry's
    normal ``register``/``unregister`` paths, so prediction plans and
    multiplier stacks are rebuilt exactly as live registration built them;
    grid caches re-derive lazily on first request.  Corrupt records are
    skipped and surfaced in the report (per-tenant ``failed: ...`` status
    when a specific model could not be rebuilt).  The journal is attached
    to the recovered registry afterwards, so subsequent registrations
    continue the same journal — replay itself appends nothing.
    """
    registry = GraphModelRegistry(grid_cache_slots=grid_cache_slots)
    report = RecoveryReport(journal_path=journal_path)
    for line_no, record, err in iter_records(journal_path):
        report.records_total += 1
        if err is not None:
            report.records_skipped += 1
            report.corrupt.append((line_no, err))
            continue
        op = record.get("op")
        model_id = record.get("model_id", "?")
        try:
            if op == "register":
                model, domain_points, margin = decode_register(record)
                registry.register(model_id, model,
                                  domain_points=domain_points, margin=margin)
                report.tenants[model_id] = "recovered"
            elif op == "unregister":
                registry.unregister(model_id)
                report.tenants[model_id] = "evicted"
            else:
                raise JournalError(f"unknown journal op {op!r}")
        except Exception as e:  # one bad record loses one tenant, not all
            report.records_skipped += 1
            report.corrupt.append((line_no, f"{type(e).__name__}: {e}"))
            report.tenants[model_id] = f"failed: {e}"
            continue
        report.records_applied += 1
    registry.attach_journal(RegistryJournal(journal_path))
    return registry, report
