"""FSDP/TP named-sharding placement rules.

Convention (mirrors ``repro.models.common``): the batch dimension and the
fully-sharded (ZeRO-3 style) weight dimension live on the ``("pod", "data")``
axes; tensor parallelism lives on ``"model"``.  All rules are *logical* —
:func:`named` drops axis names missing from the concrete mesh and axes whose
size does not divide the array dimension, so the same rules drive the 2-axis
single-pod mesh, the 3-axis multi-pod mesh, and the 1-device CPU smoke mesh.

Placement is a performance choice, not a correctness one: GSPMD produces
bit-identical semantics (modulo reduction order) for any valid placement, so
a dropped axis merely costs replication, never wrong answers.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Batch + fully-sharded-parameter axes, widest mesh first.  "pod" crosses the
# DCN; it only ever carries batch/FSDP sharding, never TP.
FSDP_AXES = ("pod", "data")
MODEL_AXIS = "model"


def _entry_axes(entry) -> tuple:
    """Spec entry (None | name | tuple of names) -> tuple of axis names."""
    if entry is None:
        return ()
    return (entry,) if isinstance(entry, str) else tuple(entry)


def _collapse(axes: tuple):
    """Axis-name tuple -> canonical spec entry (None | name | tuple)."""
    if not axes:
        return None
    return axes[0] if len(axes) == 1 else axes


def _fit_entry(dim: int, entry, sizes: dict[str, int]):
    """Largest usable suffix of ``entry``'s axes for an array dim.

    Filters axis names not present in ``sizes`` (the mesh), then drops
    leading axes until the combined axis size divides ``dim``.  Returns
    ``None`` (replicate), a single axis name, or a tuple of names.
    """
    axes = tuple(a for a in _entry_axes(entry) if a in sizes)
    while axes and dim % int(np.prod([sizes[a] for a in axes])) != 0:
        axes = axes[1:]
    return _collapse(axes)


def named(mesh: Mesh, spec: P, shape: Optional[tuple] = None) -> NamedSharding:
    """NamedSharding for ``spec`` sanitized against ``mesh`` (and ``shape``).

    Without ``shape``, only filters axis names absent from the mesh.  With
    ``shape``, also truncates the spec to the array rank and replicates any
    dimension the named axes cannot evenly divide.
    """
    sizes = dict(mesh.shape)
    entries = tuple(spec)
    if shape is None:
        # shape-free path: keep axes present in the mesh, divisibility unknown
        clean = [_collapse(tuple(a for a in _entry_axes(e) if a in sizes))
                 for e in entries]
        return NamedSharding(mesh, P(*clean))
    entries = entries[: len(shape)]
    clean = [_fit_entry(int(d), e, sizes)
             for d, e in zip(shape, entries)]
    return NamedSharding(mesh, P(*clean))


def _path_names(path) -> tuple[str, ...]:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "name"):
            out.append(str(k.name))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        else:  # pragma: no cover
            out.append(str(k))
    return tuple(out)


def _rule_for(path, leaf) -> P:
    """Logical PartitionSpec for one parameter (or optimizer-state) leaf.

    Matrix-shaped leaves get FSDP on the second-to-last dim and TP on the
    last dim; vectors/scalars (norm gains, biases, factored Adafactor rows)
    are replicated — they are tiny.  Leaves under the scan-stacked ``stack``
    subtree carry a leading ``n_periods`` dim which is never sharded.
    """
    shape = tuple(leaf.shape)
    ndim = len(shape)
    if ndim == 0:
        return P()
    names = _path_names(path)
    lead = 1 if ("stack" in names and ndim >= 2) else 0
    body = ndim - lead
    if body < 2:
        return P()
    # Tables read by token gathers: any sharding makes the partitioner
    # rewrite the gather as dynamic-slices, which miscompiles on some jax
    # versions — replicate (matches the tied-embedding read in lm_logits too).
    if names and names[-1] == "embed":
        return P()
    pad = (None,) * (ndim - 2)
    return P(*pad, FSDP_AXES, MODEL_AXIS)


def _drop_fsdp(spec: P) -> P:
    """Remove FSDP axes from a spec (serving keeps only TP sharding)."""
    fsdp = set(FSDP_AXES)
    return P(*(_collapse(tuple(a for a in _entry_axes(e) if a not in fsdp))
               for e in tuple(spec)))


def param_specs(params: Any, mesh: Mesh, *,
                serve_replicated: bool = False) -> Any:
    """Pytree of NamedShardings for a parameter tree.

    ``serve_replicated=True`` drops the FSDP weight sharding (keeping TP) —
    used by the serving path when bf16 weights fit the per-device HBM
    budget, avoiding per-step weight all-gathers.
    """
    def f(path, leaf):
        spec = _rule_for(path, leaf)
        if serve_replicated:
            spec = _drop_fsdp(spec)
        return named(mesh, spec, tuple(leaf.shape))

    return jax.tree_util.tree_map_with_path(f, params)


def batch_specs(batch: Any, mesh: Mesh) -> Any:
    """Shard every batch leaf's leading (batch) dim over the FSDP axes."""
    return jax.tree.map(
        lambda leaf: named(mesh, P(FSDP_AXES), tuple(leaf.shape)), batch)


def cache_specs(caches: Any, mesh: Mesh) -> Any:
    """KV/SSM cache shardings: batch dim over FSDP axes.

    Scan-stacked caches (under ``stack``) carry a leading ``n_periods`` dim
    which stays replicated, batch is then dim 1.
    """
    def f(path, leaf):
        shape = tuple(leaf.shape)
        if "stack" in _path_names(path) and len(shape) >= 2:
            return named(mesh, P(None, FSDP_AXES), shape)
        return named(mesh, P(FSDP_AXES), shape)

    return jax.tree_util.tree_map_with_path(f, caches)
