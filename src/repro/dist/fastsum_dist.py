"""Sharded NFFT fast summation (distributed Algorithm 3.1).

The dense kernel matvec ``y = W̃ x`` factors as

    adjoint NFFT  ->  multiply by kernel coefficients b_hat  ->  forward NFFT

and only the adjoint's accumulation couples nodes across shards.  We shard
the *node* dimension: each device runs the full adjoint NFFT on its local
nodes (spread + FFT + deconvolve), a single ``psum`` of the resulting
``N^d`` spectral coefficients over the mesh axes completes the adjoint
(the adjoint is linear in the nodes, so summing per-shard coefficient
grids is exact), and the spectral multiply + forward NFFT back to the
local nodes are again purely local.  Communication per matvec is therefore
O(N^d), independent of ``n`` — the O(n/P)-local + O(grid)-allreduce
pattern the dry-run cells measure at 512 chips.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import nfft as nfft_mod
from repro.core.nfft import NfftGeometry, NfftPlan
from repro.dist.compat import shard_map

Array = jax.Array


def _spectral_matvec_local(plan: NfftPlan, b_hat: Array,
                           geometry: NfftGeometry, x: Array,
                           axes: tuple[str, ...],
                           tgt_geometry: NfftGeometry | None = None) -> Array:
    """Per-shard body of the distributed matvec (runs inside shard_map).

    ``geometry``/``x`` hold this shard's slice of the node dimension;
    ``b_hat`` is replicated.  The one cross-shard collective is the psum of
    the adjoint's spectral coefficients — the accumulation that crosses
    shards.  Both transforms reuse the single-device NFFT kernels, so the
    distributed and local matvecs cannot drift apart.
    """
    tgt = geometry if tgt_geometry is None else tgt_geometry
    x_hat = nfft_mod.nfft_adjoint(plan, geometry, x)
    if axes:
        x_hat = jax.lax.psum(x_hat, axes)
    f_hat = b_hat[..., None] * x_hat if x.ndim == 2 else b_hat * x_hat
    f = nfft_mod.nfft_forward(plan, tgt, f_hat)
    return jnp.real(f).astype(x.dtype)


def distributed_matvec_fn(op, mesh, axes):
    """Sharded drop-in for ``op.matvec`` (op: :class:`FastsumOperator`).

    Returns ``mv(x)`` computing ``W x = (W̃ - K(0) I) x`` for ``x`` of shape
    (n,) or (n, C), with the node dimension sharded over ``axes`` of
    ``mesh``.  The node count is padded with zero-weight ghost nodes to a
    multiple of the shard count, so any (n, mesh) combination works.
    """
    plan = op.plan
    axes = tuple(axes)
    # op.matvec's own contract: the K(0)-diagonal subtraction is only valid
    # when source and target nodes coincide.  A same-length but distinct
    # target set (e.g. the KRR prediction operator) must fail loudly here,
    # not silently evaluate the forward NFFT at the wrong nodes.
    assert op.tgt_geometry is op.src_geometry, \
        "distributed matvec requires src == tgt nodes (shared geometry)"
    n = op.n_source
    nshard = int(np.prod([mesh.shape[a] for a in axes]))
    pad = (-n) % nshard

    idx = op.src_geometry.indices
    w = op.src_geometry.weights
    if pad:
        idx = jnp.pad(idx, ((0, pad), (0, 0)))
        w = jnp.pad(w, ((0, pad), (0, 0)))  # ghost nodes: weight 0

    spec_geom = P(axes, None)

    @jax.jit
    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P(), spec_geom, spec_geom, spec_geom),
                       out_specs=spec_geom, check_rep=False)
    def _mv(b_hat, idx_, w_, x_):
        geom = NfftGeometry(indices=idx_, weights=w_)
        return _spectral_matvec_local(plan, b_hat, geom, x_, axes)

    out_scale = op.output_scale
    k0 = op.kernel_at_zero

    def matvec(x: Array) -> Array:
        batched = x.ndim == 2
        xp = x if batched else x[:, None]
        if pad:
            xp = jnp.pad(xp, ((0, pad), (0, 0)))
        y = _mv(op.b_hat, idx, w, xp)
        if pad:
            y = y[:n]
        if not batched:
            y = y[..., 0]
        return y * out_scale - k0 * x

    return matvec
