"""Sharded NFFT fast summation (distributed Algorithm 3.1).

The dense kernel matvec ``y = W̃ x`` factors as

    spread  ->  FFT  ->  spectral multiply  ->  IFFT  ->  gather

and only the spectral accumulation couples nodes across shards.  We shard
the *node* dimension and offer two spectral modes for that one cross-shard
accumulation (``distributed_matvec_fn(..., spectral_mode=...)``):

``"psum"`` (default)
    Each device spreads its local nodes onto the oversampled grid and runs
    the real-to-complex FFT locally; a single ``psum`` over the mesh axes of
    the *support block* of the multiplied half-spectrum (~``N^d/2`` complex,
    independent of ``n``) completes the reduction, and the inverse FFT +
    gather are again purely local.  Per-device spectrum memory and wire
    payload are constant in the mesh size.

``"pencil"``
    The transform itself is sharded (:mod:`repro.dist.pencil_fft`): the
    cross-shard accumulation becomes a ``reduce_scatter`` of the spread grid
    into per-device pencils, the distributed rfftn runs local trailing-axis
    FFTs plus ``all_to_all`` transposes, the spectral multiply hits each
    device's multiplier *slab*, and an ``all_gather`` of the
    inverse-transformed pencils feeds the local window gather.  Per-device
    spectrum memory, FFT flops, and collective payload all scale ~1/P with
    the pencil group size — the regime past ~64 devices where the psum
    payload stops improving.  ``d = 1`` has no trailing axis to keep local
    and falls back to the psum path, as does a mesh where no axis divides
    the grid (a degenerate pencil would psum the full grid — strictly
    worse).

``_spectral_matvec_local`` keeps the seed two-NFFT body (full ``N^d``
psum); it survives only as an oracle.
"""

from __future__ import annotations

import functools
import warnings

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import fastsum_exec, nfft as nfft_mod
from repro.core.nfft import NfftGeometry, NfftPlan, WindowGeometry
from repro.dist import pencil_fft
from repro.dist.compat import shard_map

Array = jax.Array

SPECTRAL_MODES = ("psum", "pencil")


def _spectral_matvec_local(plan: NfftPlan, b_hat: Array,
                           geometry: NfftGeometry, x: Array,
                           axes: tuple[str, ...],
                           tgt_geometry: NfftGeometry | None = None) -> Array:
    """Per-shard body of the seed two-NFFT distributed matvec (oracle only).

    ``geometry``/``x`` hold this shard's slice of the node dimension;
    ``b_hat`` is replicated.  The one cross-shard collective is the psum of
    the adjoint's full ``N^d`` spectral coefficients.
    """
    tgt = geometry if tgt_geometry is None else tgt_geometry
    x_hat = nfft_mod.nfft_adjoint(plan, geometry, x)
    if axes:
        x_hat = jax.lax.psum(x_hat, axes)
    f_hat = b_hat[..., None] * x_hat if x.ndim == 2 else b_hat * x_hat
    f = nfft_mod.nfft_forward(plan, tgt, f_hat)
    return jnp.real(f).astype(x.dtype)


def _fused_matvec_local(plan: NfftPlan, mult_half: Array,
                        geometry: WindowGeometry, x: Array,
                        axes: tuple[str, ...],
                        backend: str | None = None) -> Array:
    """Per-shard psum-mode body of the fused distributed matvec.

    The one cross-shard collective is the psum of the multiplied
    half-spectrum restricted to the multiplier's support block (~N^d/2
    complex: the entire wire payload), injected into the shared
    single-device pipeline via its ``spectral_reduce`` hook.
    """
    reduce = (lambda block: jax.lax.psum(block, axes)) if axes else None
    return fastsum_exec.fused_pipeline(plan, mult_half, geometry, geometry,
                                       x, spectral_reduce=reduce,
                                       backend=backend)


def _pencil_matvec_local(plan: NfftPlan, mult_half: Array,
                         geometry: WindowGeometry, x: Array,
                         spec: pencil_fft.PencilSpec,
                         backend: str | None = None) -> Array:
    """Per-shard pencil-mode body: the ``spectral_op`` hook replaces the
    whole rfftn -> multiply -> irfftn mid-section with the reduce-scattered,
    slab-sharded transform."""

    def spectral_op(g):
        pencil = pencil_fft.pencil_accumulate(g, spec)
        gh = pencil_fft.pencil_rfftn(pencil, spec)
        slab = pencil_fft.multiplier_slab(mult_half, spec)
        gh = gh * slab.astype(gh.dtype)[..., None]
        y = pencil_fft.pencil_irfftn(gh, spec)
        return pencil_fft.pencil_allgather(y, spec).astype(g.dtype)

    return fastsum_exec.fused_pipeline(plan, mult_half, geometry, geometry,
                                       x, backend=backend,
                                       spectral_op=spectral_op)


def _fused_matvec_bank_local(plan: NfftPlan, mult_bank: Array,
                             geometry: WindowGeometry, x: Array,
                             axes: tuple[str, ...],
                             backend: str | None = None) -> Array:
    """Per-shard psum-mode bank body: ONE psum of the *stacked* multiplier
    support blocks (the S·C system columns ride the channel axis, so the
    wire payload is the single-operator support block times S·C — still one
    collective, and still one spread + one forward FFT per shard)."""
    reduce = (lambda block: jax.lax.psum(block, axes)) if axes else None
    return fastsum_exec.fused_pipeline_bank(plan, mult_bank, geometry,
                                            geometry, x,
                                            spectral_reduce=reduce,
                                            backend=backend)


def _pencil_matvec_bank_local(plan: NfftPlan, mult_bank: Array,
                              geometry: WindowGeometry, x: Array,
                              spec: pencil_fft.PencilSpec,
                              backend: str | None = None) -> Array:
    """Per-shard pencil-mode bank body: per-device ``(S, slab)`` multiplier
    slabs (the vmapped :func:`pencil_fft.multiplier_slab`) multiply the
    shared pencil spectrum member-wise; one reduce_scatter / all_gather pair
    moves the S·C-channel pencils."""
    nb = mult_bank.shape[0]
    c = x.shape[-1] if x.ndim >= 2 else 1
    lockstep = x.ndim == 3

    def spectral_op(g):
        pencil = pencil_fft.pencil_accumulate(g, spec)
        gh = pencil_fft.pencil_rfftn(pencil, spec)
        slabs = jax.vmap(
            lambda m: pencil_fft.multiplier_slab(m, spec))(mult_bank)
        slabs = jnp.moveaxis(slabs, 0, -1)  # slab spectrum + (S,)
        if lockstep:
            ghb = gh.reshape(gh.shape[:-1] + (nb, c))
        else:
            ghb = gh[..., None, :]  # broadcast the shared spectrum over S
        prod = slabs[..., :, None].astype(gh.dtype) * ghb
        flat = prod.reshape(prod.shape[:-2] + (nb * c,))
        y = pencil_fft.pencil_irfftn(flat, spec)
        return pencil_fft.pencil_allgather(y, spec).astype(g.dtype)

    return fastsum_exec.fused_pipeline_bank(plan, mult_bank, geometry,
                                            geometry, x, backend=backend,
                                            spectral_op=spectral_op)


def resolve_pencil_spec(plan: NfftPlan, mesh, axes, pencil_axes=None):
    """PencilSpec the pencil mode would use, or None when it degenerates.

    None means the psum path runs instead: d = 1 (no trailing axis to keep
    local), or a mesh where no axis divides the grid (a degenerate pencil
    would psum the full grid — strictly worse than the support-block psum).
    Callers that label artifacts by spectral mode should consult this to
    report the *effective* mode.
    """
    if plan.d < 2:
        return None
    spec = pencil_fft.make_pencil_spec(mesh, tuple(axes), plan.grid_size,
                                       plan.d, pencil_axes=pencil_axes)
    return None if spec.row_size * spec.col_size == 1 else spec


# One warning per process when a *requested* pencil mode degenerates: the
# silent psum substitution is correct (same math, one collective) but the
# scaling profile the caller asked for is not what runs — say so once.
_PENCIL_FALLBACK_WARNED = [False]


def _note_pencil_fallback(plan: NfftPlan, mesh) -> None:
    if _PENCIL_FALLBACK_WARNED[0]:
        return
    _PENCIL_FALLBACK_WARNED[0] = True
    warnings.warn(
        f"spectral_mode='pencil' degenerates on this configuration "
        f"(d={plan.d}, grid={plan.grid_size}, mesh shape "
        f"{dict(mesh.shape)}): no mesh axis divides the grid into pencils; "
        "degrading to the support-block psum path (same result, "
        "replicated-spectrum scaling)",
        RuntimeWarning, stacklevel=3)


def make_sharded_matvec(plan: NfftPlan, mesh, axes, *,
                        spectral_mode: str = "psum",
                        backend: str | None = None, pencil_axes=None,
                        jit: bool = True):
    """shard_map'd matvec body ``(mult_half, base, w1d, x) -> y`` (row order).

    Operands 1..3 are sharded along the node dimension over ``axes``; the
    multiplier is replicated.  Shared by :func:`distributed_matvec_fn` and
    the dry-run graph cells, so what the 512-chip cells lower is literally
    the shipped matvec.  ``jit=False`` returns the bare shard_map'd function
    (the dry-run jits it with explicit in_shardings).
    """
    axes = tuple(axes)
    if spectral_mode not in SPECTRAL_MODES:
        raise ValueError(
            f"spectral_mode must be one of {SPECTRAL_MODES}, "
            f"got {spectral_mode!r}")
    spec = None
    if spectral_mode == "pencil":
        spec = resolve_pencil_spec(plan, mesh, axes, pencil_axes)
        if spec is None:
            _note_pencil_fallback(plan, mesh)

    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P(), P(axes, None), P(axes, None, None),
                                 P(axes, None)),
                       out_specs=P(axes, None), check_rep=False)
    def _mv(mult_half, base_, w_, x_):
        # rows are globally Morton-sorted; the caller pre-permutes x, so the
        # per-shard geometry uses an identity perm over its local rows.
        local = WindowGeometry(
            base=base_, weights=w_,
            perm=jnp.arange(base_.shape[0], dtype=jnp.int32))
        if spec is not None:
            return _pencil_matvec_local(plan, mult_half, local, x_, spec,
                                        backend=backend)
        return _fused_matvec_local(plan, mult_half, local, x_, axes,
                                   backend=backend)

    return jax.jit(_mv) if jit else _mv


def make_sharded_matvec_bank(plan: NfftPlan, mesh, axes, *,
                             lockstep: bool,
                             spectral_mode: str = "psum",
                             backend: str | None = None, pencil_axes=None,
                             jit: bool = True):
    """shard_map'd bank matvec body ``(mult_bank, base, w1d, x) -> y``.

    The bank analogue of :func:`make_sharded_matvec`: the multiplier *bank*
    ``(S,) + half-spectrum`` is replicated, the window geometry and the node
    dimension of ``x`` are sharded over ``axes``, and the output is
    ``(S, rows, C)`` with only the row axis sharded.  ``lockstep`` is the
    static input flavor: False takes ``x`` (rows, C) (every member applied
    to the same columns — spread runs with C channels), True takes ``x``
    (S, rows, C) (member s applied to x[s], the bank Krylov shape — the S·C
    system columns ride the channel axis).  Either way each shard runs ONE
    spread and ONE forward transform, and the cross-shard accumulation is a
    single collective: the psum of the stacked support blocks, or the
    pencil reduce_scatter with per-device ``(S, slab)`` multiplier slabs.
    """
    axes = tuple(axes)
    if spectral_mode not in SPECTRAL_MODES:
        raise ValueError(
            f"spectral_mode must be one of {SPECTRAL_MODES}, "
            f"got {spectral_mode!r}")
    spec = None
    if spectral_mode == "pencil":
        spec = resolve_pencil_spec(plan, mesh, axes, pencil_axes)
        if spec is None:
            _note_pencil_fallback(plan, mesh)
    x_spec = P(None, axes, None) if lockstep else P(axes, None)

    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P(), P(axes, None), P(axes, None, None),
                                 x_spec),
                       out_specs=P(None, axes, None), check_rep=False)
    def _mv(mult_bank, base_, w_, x_):
        local = WindowGeometry(
            base=base_, weights=w_,
            perm=jnp.arange(base_.shape[0], dtype=jnp.int32))
        if spec is not None:
            return _pencil_matvec_bank_local(plan, mult_bank, local, x_,
                                             spec, backend=backend)
        return _fused_matvec_bank_local(plan, mult_bank, local, x_, axes,
                                        backend=backend)

    return jax.jit(_mv) if jit else _mv


def _pad_ghost_geometry(win: WindowGeometry, n: int, nshard: int):
    """Ghost-pad a window geometry so the node dimension shards evenly.

    Ghost rows carry zero window weights (no spread/gather contribution)
    and identity perm entries.  Returns ``(base, w1d, perm, inv_perm,
    pad)``; ``inv_perm`` (a concrete numpy argsort) lets callers unsort
    results with a row *take* — the equivalent multi-channel row scatter
    costs ~10x more on XLA CPU.
    """
    pad = (-n) % nshard
    base, w1d, perm = win.base, win.weights, win.perm
    if pad:
        base = jnp.pad(base, ((0, pad), (0, 0)))
        w1d = jnp.pad(w1d, ((0, pad), (0, 0), (0, 0)))
        perm = jnp.concatenate(
            [perm, jnp.arange(n, n + pad, dtype=perm.dtype)])
    inv_perm = jnp.asarray(np.argsort(np.asarray(perm)), perm.dtype)
    return base, w1d, perm, inv_perm, pad


def distributed_matvec_fn(op, mesh, axes, *, backend: str | None = None,
                          spectral_mode: str = "psum", pencil_axes=None):
    """Sharded drop-in for ``op.matvec`` (op: :class:`FastsumOperator`).

    Returns ``mv(x)`` computing ``W x = (W̃ - K(0) I) x`` for ``x`` of shape
    (n,) or (n, C), with the node dimension sharded over ``axes`` of
    ``mesh``.  The node count is padded with zero-weight ghost nodes to a
    multiple of the shard count, so any (n, mesh) combination works.
    ``backend`` selects the per-shard window-step backend (default "auto":
    pallas on TPU, xla elsewhere); ``spectral_mode`` selects the cross-shard
    spectral accumulation (see module docstring); ``pencil_axes`` optionally
    overrides the pencil row/col mesh-axis split.
    """
    plan = op.plan
    axes = tuple(axes)
    # op.matvec's own contract: the K(0)-diagonal subtraction is only valid
    # when source and target nodes coincide.  A same-length but distinct
    # target set (e.g. the KRR prediction operator) must fail loudly here,
    # not silently evaluate the forward NFFT at the wrong nodes.
    assert op.scaled_tgt is None, \
        "distributed matvec requires src == tgt nodes (shared geometry)"
    assert op.multiplier_half is not None and op.src_window is not None, \
        "distributed matvec requires a fused operator (build via make_fastsum)"
    n = op.n_source
    nshard = int(np.prod([mesh.shape[a] for a in axes]))
    base, w1d, perm, inv_perm, pad = _pad_ghost_geometry(
        op.src_window, n, nshard)

    _mv = make_sharded_matvec(plan, mesh, axes, spectral_mode=spectral_mode,
                              backend=backend, pencil_axes=pencil_axes)

    out_scale = op.output_scale
    k0 = op.kernel_at_zero

    def matvec(x: Array) -> Array:
        batched = x.ndim == 2
        xp = x if batched else x[:, None]
        if pad:
            xp = jnp.pad(xp, ((0, pad), (0, 0)))
        y_sorted = _mv(op.multiplier_half, base, w1d, xp[perm])
        y = y_sorted[inv_perm]
        if pad:
            y = y[:n]
        if not batched:
            y = y[..., 0]
        return y * out_scale - k0 * x

    return matvec


def distributed_matvec_bank_fn(bank, mesh, axes, *,
                               backend: str | None = None,
                               spectral_mode: str = "psum",
                               pencil_axes=None):
    """Sharded drop-in for ``bank.matvec`` (bank: ``FastsumOperatorBank``).

    Returns ``mv(x)`` computing ``y[s] = (W̃_s - K_s(0) I) x`` for ``x`` of
    shape (n,) or (n, C) (broadcast), or ``y[s] = (W̃_s - K_s(0) I) x[s]``
    for ``x`` of shape (S, n, C) (lockstep — what a bank Krylov solver
    iterates on), with the node dimension sharded over ``axes`` of ``mesh``.
    Same ghost-node padding, backends, and spectral modes as
    :func:`distributed_matvec_fn`; the one cross-shard collective carries
    the bank stacked into the channel axis.
    """
    plan = bank.plan
    axes = tuple(axes)
    assert bank.scaled_tgt is None, \
        "distributed bank matvec requires src == tgt nodes (shared geometry)"
    n = bank.n_source
    nshard = int(np.prod([mesh.shape[a] for a in axes]))
    base, w1d, perm, inv_perm, pad = _pad_ghost_geometry(
        bank.src_window, n, nshard)

    kw = dict(spectral_mode=spectral_mode, backend=backend,
              pencil_axes=pencil_axes)
    # both flavors are lazy (jax.jit traces on first call), so building the
    # unused one costs nothing
    _mv_bcast = make_sharded_matvec_bank(plan, mesh, axes, lockstep=False,
                                         **kw)
    _mv_lock = make_sharded_matvec_bank(plan, mesh, axes, lockstep=True,
                                        **kw)
    k0 = bank.kernel_at_zero  # (S,); output scales are folded into the bank

    def matvec(x: Array) -> Array:
        lockstep = x.ndim == 3
        if lockstep:
            xp = jnp.pad(x, ((0, 0), (0, pad), (0, 0))) if pad else x
            y_sorted = _mv_lock(bank.multiplier_bank, base, w1d, xp[:, perm])
        else:
            batched = x.ndim == 2
            xb = x if batched else x[:, None]
            xp = jnp.pad(xb, ((0, pad), (0, 0))) if pad else xb
            y_sorted = _mv_bcast(bank.multiplier_bank, base, w1d, xp[perm])
        y = y_sorted[:, inv_perm]
        if pad:
            y = y[:, :n]
        if lockstep:
            return y - k0[:, None, None] * x
        if not batched:
            return y[..., 0] - k0[:, None] * x
        return y - k0[:, None, None] * x[None]

    return matvec
