"""Sharded NFFT fast summation (distributed Algorithm 3.1).

The dense kernel matvec ``y = W̃ x`` factors as

    spread  ->  FFT  ->  spectral multiply  ->  IFFT  ->  gather

and only the spectral accumulation couples nodes across shards.  We shard
the *node* dimension: each device spreads its local nodes onto the
oversampled grid and runs the real-to-complex FFT locally, a single
``psum`` over the mesh axes of the *support block* of the multiplied
half-spectrum completes the reduction (the transform is linear in the
nodes, so summing per-shard coefficients is exact), and the inverse FFT +
gather back to the local nodes are again purely local.

The fused engine's combined multiplier is zero outside the embedded
``I_N^d`` block, and the real half-spectrum halves it again, so the
all-reduce payload is ~``N^d/2`` complex — half the seed's full ``N^d``
psum — independent of ``n``: the O(n/P)-local + O(grid)-allreduce pattern
the dry-run cells measure at 512 chips.

``_spectral_matvec_local`` keeps the seed two-NFFT body (full ``N^d``
psum); it survives as the oracle and is what the dry-run cells lower.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import fastsum_exec, nfft as nfft_mod
from repro.core.nfft import NfftGeometry, NfftPlan, WindowGeometry
from repro.dist.compat import shard_map

Array = jax.Array


def _spectral_matvec_local(plan: NfftPlan, b_hat: Array,
                           geometry: NfftGeometry, x: Array,
                           axes: tuple[str, ...],
                           tgt_geometry: NfftGeometry | None = None) -> Array:
    """Per-shard body of the distributed matvec (runs inside shard_map).

    ``geometry``/``x`` hold this shard's slice of the node dimension;
    ``b_hat`` is replicated.  The one cross-shard collective is the psum of
    the adjoint's spectral coefficients — the accumulation that crosses
    shards.  Both transforms reuse the single-device NFFT kernels, so the
    distributed and local matvecs cannot drift apart.
    """
    tgt = geometry if tgt_geometry is None else tgt_geometry
    x_hat = nfft_mod.nfft_adjoint(plan, geometry, x)
    if axes:
        x_hat = jax.lax.psum(x_hat, axes)
    f_hat = b_hat[..., None] * x_hat if x.ndim == 2 else b_hat * x_hat
    f = nfft_mod.nfft_forward(plan, tgt, f_hat)
    return jnp.real(f).astype(x.dtype)


def _fused_matvec_local(plan: NfftPlan, mult_half: Array,
                        geometry: WindowGeometry, x: Array,
                        axes: tuple[str, ...],
                        backend: str | None = None) -> Array:
    """Per-shard body of the fused distributed matvec (inside shard_map).

    ``geometry``/``x`` hold this shard's slice of the (Morton-sorted) node
    dimension; the multiplier is replicated.  The one cross-shard collective
    is the psum of the multiplied half-spectrum restricted to the
    multiplier's support block (~N^d/2 complex: the entire wire payload),
    injected into the shared single-device pipeline via its
    ``spectral_reduce`` hook — the distributed and local matvecs literally
    run the same body and cannot drift apart.
    """
    reduce = (lambda block: jax.lax.psum(block, axes)) if axes else None
    return fastsum_exec.fused_pipeline(plan, mult_half, geometry, geometry,
                                       x, spectral_reduce=reduce,
                                       backend=backend)


def distributed_matvec_fn(op, mesh, axes, *, backend: str | None = None):
    """Sharded drop-in for ``op.matvec`` (op: :class:`FastsumOperator`).

    Returns ``mv(x)`` computing ``W x = (W̃ - K(0) I) x`` for ``x`` of shape
    (n,) or (n, C), with the node dimension sharded over ``axes`` of
    ``mesh``.  The node count is padded with zero-weight ghost nodes to a
    multiple of the shard count, so any (n, mesh) combination works.
    ``backend`` selects the per-shard window-step backend (default "auto":
    pallas on TPU, xla elsewhere).
    """
    plan = op.plan
    axes = tuple(axes)
    # op.matvec's own contract: the K(0)-diagonal subtraction is only valid
    # when source and target nodes coincide.  A same-length but distinct
    # target set (e.g. the KRR prediction operator) must fail loudly here,
    # not silently evaluate the forward NFFT at the wrong nodes.
    assert op.scaled_tgt is None, \
        "distributed matvec requires src == tgt nodes (shared geometry)"
    assert op.multiplier_half is not None and op.src_window is not None, \
        "distributed matvec requires a fused operator (build via make_fastsum)"
    n = op.n_source
    nshard = int(np.prod([mesh.shape[a] for a in axes]))
    pad = (-n) % nshard

    win = op.src_window
    base, w1d, perm = win.base, win.weights, win.perm
    if pad:
        # ghost nodes: zero window weights (no spread/gather contribution)
        base = jnp.pad(base, ((0, pad), (0, 0)))
        w1d = jnp.pad(w1d, ((0, pad), (0, 0), (0, 0)))
        perm = jnp.concatenate(
            [perm, jnp.arange(n, n + pad, dtype=perm.dtype)])

    spec_geom = P(axes, *([None] * (w1d.ndim - 1)))

    @jax.jit
    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P(), P(axes, None), spec_geom, P(axes, None)),
                       out_specs=P(axes, None), check_rep=False)
    def _mv(mult_half, base_, w_, x_):
        # rows are globally Morton-sorted; the caller pre-permutes x, so the
        # per-shard geometry uses an identity perm over its local rows.
        local = WindowGeometry(
            base=base_, weights=w_,
            perm=jnp.arange(base_.shape[0], dtype=jnp.int32))
        return _fused_matvec_local(plan, mult_half, local, x_, axes,
                                   backend=backend)

    out_scale = op.output_scale
    k0 = op.kernel_at_zero

    def matvec(x: Array) -> Array:
        batched = x.ndim == 2
        xp = x if batched else x[:, None]
        if pad:
            xp = jnp.pad(xp, ((0, pad), (0, 0)))
        y_sorted = _mv(op.multiplier_half, base, w1d, xp[perm])
        y = jnp.zeros_like(y_sorted).at[perm].set(y_sorted)
        if pad:
            y = y[:n]
        if not batched:
            y = y[..., 0]
        return y * out_scale - k0 * x

    return matvec
