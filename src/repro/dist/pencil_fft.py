"""Pencil-decomposed distributed real FFT (sharded spectrum past 64 devices).

The psum spectral mode of :mod:`repro.dist.fastsum_dist` keeps the full
oversampled grid on every device and all-reduces the multiplied
half-spectrum's support block — per-device spectrum memory and wire payload
stop improving as the mesh grows.  This module shards the transform itself:

    forward (``pencil_rfftn``), grid sharded along its leading axes:
        local rfftn over the unsharded trailing axes
        -> all_to_all transpose (spectrum axis <-> grid axis 1)
        -> FFT along grid axis 1
        -> all_to_all transpose (grid axis 1 <-> grid axis 0)
        -> FFT along the formerly sharded leading axis.
    inverse (``pencil_irfftn``) mirrors the forward exactly.

Sharding is described by a :class:`PencilSpec`: grid axis 0 is sharded over
the ``row`` mesh-axis group (size R <= M) and — for d >= 3 — grid axis 1
over the ``col`` group (size C <= M), so up to M^2 devices hold
(M/R, M/C, M, ...) pencils; a slab decomposition (col empty) caps at M
devices.  Mesh axes that fit in neither group land in ``extra`` and are
closed by a plain psum on the already-scattered pencil (cheap: the operand
is the pencil, not the grid).  d = 1 has no trailing axis to keep local, so
callers fall back to the psum mode.

All functions run *inside* ``shard_map``.  Group order follows jax's
convention for multi-name collectives (first axis name is major), which
:func:`group_index` reproduces so multiplier slabs line up with
``psum_scatter``/``all_gather`` block placement.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class PencilSpec:
    """Static description of how the oversampled grid is penciled.

    ``row_axes``/``col_axes`` shard grid axes 0/1 (sizes must divide the
    grid); ``extra_axes`` are the remaining node-shard mesh axes whose
    partial sums are closed by psum.  Hashable, so it can be closed over by
    jit/shard_map traces.
    """

    d: int
    grid: int  # oversampled grid size M per dimension
    row_axes: tuple[str, ...]
    row_sizes: tuple[int, ...]
    col_axes: tuple[str, ...] = ()
    col_sizes: tuple[int, ...] = ()
    extra_axes: tuple[str, ...] = ()

    def __post_init__(self):
        assert self.d >= 2, "pencil decomposition needs a trailing grid axis"
        assert self.grid % self.row_size == 0, (self.grid, self.row_axes)
        assert self.grid % self.col_size == 0, (self.grid, self.col_axes)
        assert not (self.col_axes and self.d < 3), \
            "d=2 has a single shardable grid axis (slab decomposition only)"

    @property
    def row_size(self) -> int:
        return int(np.prod(self.row_sizes)) if self.row_axes else 1

    @property
    def col_size(self) -> int:
        return int(np.prod(self.col_sizes)) if self.col_axes else 1

    @property
    def half(self) -> int:
        """rfft-axis length K = M//2 + 1."""
        return self.grid // 2 + 1

    def padded_half(self, group: int) -> int:
        """K rounded up so the rfft axis splits evenly over ``group``."""
        return -(-self.half // group) * group


def make_pencil_spec(mesh, axes, grid: int, d: int, *,
                     pencil_axes=None) -> PencilSpec:
    """Partition the node-shard mesh ``axes`` into row/col/extra groups.

    Greedy: each axis (in order) joins the row group if the grown product
    still divides ``grid``, else — for d >= 3 — the col group likewise;
    axes that fit neither become extra (psum) axes.  ``pencil_axes=
    (row_axes, col_axes)`` overrides the split explicitly (must be disjoint
    subsets of ``axes``).
    """
    axes = tuple(axes)
    sizes = {a: int(mesh.shape[a]) for a in axes}
    if pencil_axes is not None:
        row, col = (tuple(pencil_axes[0]), tuple(pencil_axes[1]))
        assert set(row) | set(col) <= set(axes) and not set(row) & set(col), \
            (row, col, axes)
    else:
        row, col = [], []
        prods = {0: 1, 1: 1}
        for a in axes:
            for group, target in ((row, 0),) + (((col, 1),) if d >= 3 else ()):
                grown = prods[target] * sizes[a]
                if grown <= grid and grid % grown == 0:
                    prods[target] = grown
                    group.append(a)
                    break
        row, col = tuple(row), tuple(col)
    extra = tuple(a for a in axes if a not in row and a not in col)
    return PencilSpec(
        d=d, grid=grid,
        row_axes=row, row_sizes=tuple(sizes[a] for a in row),
        col_axes=col, col_sizes=tuple(sizes[a] for a in col),
        extra_axes=extra)


def group_index(axes: tuple[str, ...], sizes: tuple[int, ...]) -> Array:
    """Flattened position of this device in the axis group (first name major).

    Matches the linearization jax collectives use for multi-name groups, so
    the index addresses the same block ``psum_scatter``/``all_gather``
    assign to this device.
    """
    idx = jnp.zeros((), jnp.int32)
    for name, size in zip(axes, sizes):
        idx = idx * size + jax.lax.axis_index(name)
    return idx


def _a2a(x: Array, axes, sizes, split_axis: int, concat_axis: int) -> Array:
    if not axes or int(np.prod(sizes)) == 1:
        return x  # size-1 group: tiled all_to_all is the identity
    return jax.lax.all_to_all(x, axes, split_axis=split_axis,
                              concat_axis=concat_axis, tiled=True)


def pencil_accumulate(g: Array, spec: PencilSpec) -> Array:
    """Sum per-shard spread grids across the mesh, scattered into pencils.

    ``g``: this shard's full local grid ``(M,)*d + (C,)``.  Returns this
    device's ``(M/R, M/C, M, ..., M, C)`` pencil of the global sum.  The
    scatters run first so the residual psum over the extra axes moves
    pencils, not grids.
    """
    if spec.row_axes and spec.row_size > 1:
        g = jax.lax.psum_scatter(g, spec.row_axes, scatter_dimension=0,
                                 tiled=True)
    if spec.col_axes and spec.col_size > 1:
        g = jax.lax.psum_scatter(g, spec.col_axes, scatter_dimension=1,
                                 tiled=True)
    if spec.extra_axes:
        g = jax.lax.psum(g, spec.extra_axes)
    return g


def pencil_allgather(y: Array, spec: PencilSpec) -> Array:
    """Reassemble the full local grid from per-device pencils (inverse of
    the scatter half of :func:`pencil_accumulate`)."""
    if spec.col_axes and spec.col_size > 1:
        y = jax.lax.all_gather(y, spec.col_axes, axis=1, tiled=True)
    if spec.row_axes and spec.row_size > 1:
        y = jax.lax.all_gather(y, spec.row_axes, axis=0, tiled=True)
    return y


def pencil_rfftn(g: Array, spec: PencilSpec) -> Array:
    """Distributed rfftn of a grid pencil (real -> half-spectrum slab).

    Input: ``(M/R, M/C, M, ..., M, C)`` real pencil (d=2: ``(M/R, M, C)``).
    Output layout (grid axes keep their identity; only the sharding moves):

        d == 2 : ``(M, Kp/R, C)``          axis 1 = padded rfft axis, row-sharded
        d >= 3 : ``(M, M/R, M, ..., Kp/C, C)``  axis 1 row-sharded, last
                 grid axis = padded rfft axis, col-sharded

    with ``Kp = padded_half(group)`` (K = M//2+1 zero-padded so it splits
    evenly; the pad carries exact zeros end to end).
    """
    d, R, C = spec.d, spec.row_size, spec.col_size
    if d == 2:
        h = jnp.fft.rfft(g, axis=1)
        if R > 1:
            pad = spec.padded_half(R) - spec.half
            h = jnp.pad(h, [(0, 0), (0, pad), (0, 0)])
            h = _a2a(h, spec.row_axes, spec.row_sizes, 1, 0)
        return jnp.fft.fft(h, axis=0)
    h = jnp.fft.rfftn(g, axes=tuple(range(2, d)))
    if C > 1:
        pad = spec.padded_half(C) - spec.half
        h = jnp.pad(h, [(0, 0)] * (d - 1) + [(0, pad), (0, 0)])
        h = _a2a(h, spec.col_axes, spec.col_sizes, d - 1, 1)
    h = jnp.fft.fft(h, axis=1)
    if R > 1:
        h = _a2a(h, spec.row_axes, spec.row_sizes, 1, 0)
    return jnp.fft.fft(h, axis=0)


def pencil_irfftn(gh: Array, spec: PencilSpec) -> Array:
    """Exact mirror of :func:`pencil_rfftn` (half-spectrum slab -> real)."""
    d, R, C, grid = spec.d, spec.row_size, spec.col_size, spec.grid
    if d == 2:
        h = jnp.fft.ifft(gh, axis=0)
        if R > 1:
            h = _a2a(h, spec.row_axes, spec.row_sizes, 0, 1)
            h = h[:, : spec.half]
        return jnp.fft.irfft(h, n=grid, axis=1)
    h = jnp.fft.ifft(gh, axis=0)
    if R > 1:
        h = _a2a(h, spec.row_axes, spec.row_sizes, 0, 1)
    h = jnp.fft.ifft(h, axis=1)
    if C > 1:
        h = _a2a(h, spec.col_axes, spec.col_sizes, 1, d - 1)
        h = h[..., : spec.half, :]
    return jnp.fft.irfftn(h, s=(grid,) * (d - 2), axes=tuple(range(2, d)))


def multiplier_slab(mult_half: Array, spec: PencilSpec) -> Array:
    """This device's slab of the fused spectral multiplier.

    ``mult_half``: replicated ``(M,)*(d-1) + (K,)`` half-spectrum multiplier
    (FFT order).  Returns the block matching the :func:`pencil_rfftn` output
    layout for this device (dynamic-sliced by :func:`group_index`, rfft axis
    zero-padded like the spectrum so pad bins multiply to exact zeros).
    """
    d, grid = spec.d, spec.grid
    r = group_index(spec.row_axes, spec.row_sizes)
    if d == 2:
        kp = spec.padded_half(spec.row_size)
        m = jnp.pad(mult_half, [(0, 0), (0, kp - spec.half)])
        s = kp // spec.row_size
        return jax.lax.dynamic_slice(m, (jnp.zeros((), jnp.int32), r * s),
                                     (grid, s))
    kp = spec.padded_half(spec.col_size)
    m = jnp.pad(mult_half, [(0, 0)] * (d - 1) + [(0, kp - spec.half)])
    c = group_index(spec.col_axes, spec.col_sizes)
    s = kp // spec.col_size
    zero = jnp.zeros((), jnp.int32)
    starts = (zero, r * (grid // spec.row_size)) + (zero,) * (d - 3) \
        + (c * s,)
    sizes = (grid, grid // spec.row_size) + (grid,) * (d - 3) + (s,)
    return jax.lax.dynamic_slice(m, starts, sizes)
