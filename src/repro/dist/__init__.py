"""Distributed execution layer: sharding rules, the sharded Algorithm 3.1
matvec, and int8 error-feedback gradient compression.

Modules
-------
``sharding``
    Named-sharding placement rules (FSDP over the ``("pod", "data")`` axes,
    tensor parallelism over ``"model"``) consumed by ``launch/steps.py``.
``fastsum_dist``
    ``shard_map``-based distributed NFFT fast summation: the node dimension
    is sharded, the small oversampled spectral grid is all-reduced once per
    matvec (O(n/P) local work + O(M^d) communication).
``compression``
    Block-wise int8 quantization with error feedback for gradient
    all-reduce (``compress_psum``) and per-step compression in the train
    loop (``apply_error_feedback``).
``compat``
    ``shard_map`` import shim across jax versions (``check_rep`` vs
    ``check_vma`` keyword, ``jax.experimental`` vs top-level export).
"""

from repro.dist.compat import shard_map
from repro.dist.compression import (
    BLOCK, CompressionState, apply_error_feedback, compress_decompress,
    compress_psum, init_compression_state)
from repro.dist.fastsum_dist import distributed_matvec_fn
from repro.dist.sharding import (
    FSDP_AXES, MODEL_AXIS, batch_specs, cache_specs, named, param_specs)

__all__ = [
    "BLOCK", "CompressionState", "FSDP_AXES", "MODEL_AXIS",
    "apply_error_feedback", "batch_specs", "cache_specs",
    "compress_decompress", "compress_psum", "distributed_matvec_fn",
    "init_compression_state", "named", "param_specs", "shard_map",
]
