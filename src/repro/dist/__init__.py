"""Distributed execution layer: sharding rules, the sharded Algorithm 3.1
matvec, and int8 error-feedback gradient compression.

Modules
-------
``sharding``
    Named-sharding placement rules (FSDP over the ``("pod", "data")`` axes,
    tensor parallelism over ``"model"``) consumed by ``launch/steps.py``.
``fastsum_dist``
    ``shard_map``-based distributed NFFT fast summation: the node dimension
    is sharded; the spectral accumulation is either one psum of the
    half-spectrum support block per matvec (``spectral_mode="psum"``) or a
    reduce-scattered pencil-decomposed FFT (``"pencil"``) whose per-device
    spectrum memory, FFT flops, and collective payload scale ~1/P.
``pencil_fft``
    The distributed ``rfftn``/``irfftn`` pair behind the pencil mode: grid
    axes 0 (and 1, d >= 3) sharded over row x col mesh-axis groups, local
    trailing-axis FFTs + one ``all_to_all`` transpose per sharded axis.
``compression``
    Block-wise int8 quantization with error feedback for gradient
    all-reduce (``compress_psum``) and per-step compression in the train
    loop (``apply_error_feedback``).
``compat``
    ``shard_map`` import shim across jax versions (``check_rep`` vs
    ``check_vma`` keyword, ``jax.experimental`` vs top-level export).
"""

from repro.dist.compat import shard_map
from repro.dist.compression import (
    BLOCK, CompressionState, apply_error_feedback, compress_decompress,
    compress_psum, init_compression_state)
from repro.dist.fastsum_dist import (
    SPECTRAL_MODES, distributed_matvec_fn, make_sharded_matvec,
    resolve_pencil_spec)
from repro.dist.pencil_fft import (
    PencilSpec, make_pencil_spec, pencil_irfftn, pencil_rfftn)
from repro.dist.sharding import (
    FSDP_AXES, MODEL_AXIS, batch_specs, cache_specs, named, param_specs)

__all__ = [
    "BLOCK", "CompressionState", "FSDP_AXES", "MODEL_AXIS", "PencilSpec",
    "SPECTRAL_MODES", "apply_error_feedback", "batch_specs", "cache_specs",
    "compress_decompress", "compress_psum", "distributed_matvec_fn",
    "init_compression_state", "make_pencil_spec", "make_sharded_matvec",
    "named", "param_specs", "pencil_irfftn", "pencil_rfftn",
    "resolve_pencil_spec", "shard_map",
]
