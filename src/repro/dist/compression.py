"""Block-wise int8 gradient compression with error feedback.

Quantization: gradients are flattened, padded, and cut into blocks of
``BLOCK`` elements; each block is scaled by ``max|block| / 127`` and rounded
to int8, giving a per-element error of at most half a quantization step
(``max|block| / 254``).  Error feedback (Seide et al. / Karimireddy et al.)
adds the previous step's quantization residual to the gradient before
compressing, so no signal is ever lost permanently — SGD with EF-compressed
gradients converges to the uncompressed optimum.

``compress_psum`` is the cross-replica reduction used under ``shard_map``:
each shard quantizes locally (with its own residual), and the mean of the
dequantized values is psum'd.  The values crossing the wire are
int8-representable per block, but the collective itself still moves fp32 —
routing the actual int8 payload through a custom collective is an open
ROADMAP item.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array

BLOCK = 256  # elements per quantization block (one scale per block)


def _quantize(x: Array) -> tuple[Array, Array]:
    """Flatten + pad ``x`` into (blocks, BLOCK) int8 with per-block scales."""
    flat = x.reshape(-1).astype(jnp.float32)
    pad = (-flat.size) % BLOCK
    blocks = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.round(blocks / safe[:, None]).astype(jnp.int8)
    return q, scale


def _dequantize(q: Array, scale: Array, n: int) -> Array:
    """Inverse of :func:`_quantize`; returns the first ``n`` elements flat."""
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    return flat[:n]


def compress_decompress(g: Array, resid: Array) -> tuple[Array, Array]:
    """One error-feedback round: quantize ``g + resid``.

    Returns ``(out, new_resid)`` with ``out + new_resid == g + resid``
    exactly — the residual is precisely the signal the int8 lattice lost
    this step, fed back into the next one.
    """
    total = g.astype(jnp.float32) + resid.astype(jnp.float32)
    q, scale = _quantize(total)
    out = _dequantize(q, scale, total.size).reshape(g.shape)
    return out, total - out


class CompressionState(NamedTuple):
    residuals: Any  # pytree mirroring the grads, fp32


def init_compression_state(params: Any) -> CompressionState:
    return CompressionState(residuals=jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def apply_error_feedback(
        grads: Any, state: CompressionState) -> tuple[Any, CompressionState]:
    """Compress a gradient pytree leaf-wise, carrying residuals in ``state``."""
    g_leaves, tdef = jax.tree_util.tree_flatten(grads)
    r_leaves = jax.tree_util.tree_leaves(state.residuals)
    pairs = [compress_decompress(g, r) for g, r in zip(g_leaves, r_leaves)]
    out = tdef.unflatten([p[0] for p in pairs])
    resid = tdef.unflatten([p[1] for p in pairs])
    return out, CompressionState(residuals=resid)


def compress_psum(g: Array, axis_name, resid: Array) -> tuple[Array, Array]:
    """Error-feedback-compressed mean over a shard_map/pmap axis.

    Each replica quantizes its local ``g + resid``; the dequantized values
    are averaged with ``pmean`` so every replica holds the same approximate
    mean.  Per-element error of the mean is bounded by the mean of the
    per-replica quantization errors, i.e. <= max|g| / 254 globally.
    """
    out, new_resid = compress_decompress(g, resid)
    mean = jax.lax.pmean(out, axis_name)
    return mean, new_resid
