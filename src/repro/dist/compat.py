"""shard_map across jax versions.

jax moved ``shard_map`` from ``jax.experimental.shard_map`` to the top-level
namespace and renamed the replication-check keyword from ``check_rep`` to
``check_vma`` along the way.  Callers here (and the test suite) use this one
wrapper so the same code runs on both API generations.
"""

from __future__ import annotations

try:  # jax >= 0.6: top-level export, check_vma keyword
    from jax import shard_map as _native_shard_map  # type: ignore[attr-defined]
    _CHECK_KW = "check_vma"
except ImportError:  # older jax: experimental module, check_rep keyword
    from jax.experimental.shard_map import shard_map as _native_shard_map
    _CHECK_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None,
              check_rep=None):
    """``shard_map`` accepting either replication-check keyword spelling."""
    check = check_vma if check_vma is not None else check_rep
    kwargs = {} if check is None else {_CHECK_KW: check}
    return _native_shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kwargs)
