"""Kernel ridge regression with NFFT-accelerated Gram matvecs (Section 6.3).

Dual solve:  alpha = (K + beta I)^{-1} f  by CG, where the Gram matrix
K_ij = K(x_i - x_j) (note: *with* diagonal K(0), unlike the graph weight
matrix) is applied via Algorithm 3.1.  Prediction at new points x uses the
separate-target fast summation:  F(x) = sum_i alpha_i K(x_i - x).

Model selection (``krr_fit_sweep``) runs the whole (sigma, beta) grid as ONE
lockstep bank solve: the Gram operators for all sigmas share their NFFT plan
and window geometry (they differ only in the spectral multiplier), so every
CG iteration costs one bank matvec — one spread + one forward FFT for the
entire grid — instead of |sigmas| x |betas| sequential solves.
"""

from __future__ import annotations

import hashlib
import threading
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fastsum import (
    FastsumOperator, FastsumParams, make_fastsum, make_fastsum_bank,
)
from repro.core.kernels import Kernel, kernel_from_param, make_kernel
from repro.core.solvers import cg

Array = jax.Array

# serving cache capacity: how many target sets a model keeps planned
# operators for (small keyed LRU — e.g. a validation set and a live set
# alternating must both stay resident)
PRED_CACHE_SLOTS = 4


def points_fingerprint(arr: Array) -> tuple:
    """Content key for a point set: (shape, dtype, sha1 of the raw bytes).

    Prediction-cache lookups key on this instead of array *object identity*:
    a request queue reconstructs logically-identical query arrays every tick
    (deserialization, host round-trips, ``jnp.asarray`` copies), and an
    identity-keyed cache replans the operator on every one of them.  Content
    keys make any round-tripped copy of a resident target set a hit.  The
    O(n) hash is orders of magnitude cheaper than the plan it saves.
    """
    a = np.ascontiguousarray(np.asarray(arr))
    return (a.shape, a.dtype.str, hashlib.sha1(a.tobytes()).digest())


class KRRModel(NamedTuple):
    alpha: Array
    train_points: Array
    kernel: Kernel
    params: FastsumParams
    num_iters: Array
    converged: Array
    # keyed LRU {insertion-ordered list of (key..., FastsumOperator)} of the
    # last PRED_CACHE_SLOTS serving target sets; mutable on purpose (shared
    # by every copy of this immutable model).  All access goes through the
    # lock stored inside the dict (see _pred_cache_lock): the serving
    # engine's enqueue thread and tick loop mutate it concurrently.
    pred_cache: dict | None = None


def krr_fit(kernel: Kernel, points: Array, f: Array, beta: float,
            params: FastsumParams, *, tol: float = 1e-8,
            maxiter: int = 1000) -> KRRModel:
    """Fit the dual variable alpha = (K + beta I)^{-1} f via CG."""
    gram = make_fastsum(kernel, points, params)

    def matvec(x):
        # Gram matrix = W̃ (diagonal K(0) kept)
        return gram.matvec_tilde(x) + beta * x

    sol = cg(matvec, f, tol=tol, maxiter=maxiter)
    return KRRModel(alpha=sol.x, train_points=points, kernel=kernel,
                    params=params, num_iters=sol.num_iters,
                    converged=sol.converged, pred_cache={})


class KRRSweepResult(NamedTuple):
    """One lockstep fit of the whole (sigma, beta) model-selection grid.

    ``alphas[i, :, j]`` is the dual variable for ``(sigmas[i], betas[j])``;
    ``num_iters``/``residual_norm``/``converged`` are (|sigmas|, |betas|)
    per-system diagnostics from the lockstep CG (each system has its own
    tolerance mask — an easy (sigma, beta) cell freezes once converged while
    harder cells keep iterating).
    """

    alphas: Array  # (S_sigma, n, S_beta)
    sigmas: tuple
    betas: tuple
    num_iters: Array  # (S_sigma, S_beta)
    residual_norm: Array  # (S_sigma, S_beta)
    converged: Array  # (S_sigma, S_beta)
    kernel_name: str
    train_points: Array
    params: FastsumParams


def krr_fit_sweep(kernel_name: str, points: Array, f: Array,
                  betas: Sequence[float], sigmas: Sequence[float],
                  params: FastsumParams, *, tol: float = 1e-8,
                  maxiter: int = 1000) -> KRRSweepResult:
    """Fit alpha = (K_sigma + beta I)^{-1} f for a whole (sigma, beta) grid.

    Builds ONE operator bank over the shared training points (one member per
    sigma; plan/geometry computed once) and solves all |sigmas| x |betas|
    systems by lockstep bank CG: per iteration, one spread, one forward
    rfftn, |sigmas| spectral multiplies, one batched inverse transform, one
    gather — the beta shifts ride the channel axis for free.  ``kernel_name``
    is a sigma-parameterized kernel ("gaussian" or "laplacian_rbf").
    """
    sigmas = tuple(float(s) for s in sigmas)
    betas = tuple(float(b) for b in betas)
    ns, nb = len(sigmas), len(betas)
    kernels = [make_kernel(kernel_name, sigma=s) for s in sigmas]
    bank = make_fastsum_bank(kernels, points, params)
    # flat bank-major columns: column s*nb + j is the (sigmas[s], betas[j])
    # system — the zero-transpose solver layout (matvec_tilde_columns)
    beta_cols = jnp.tile(jnp.asarray(betas, f.dtype), ns)  # (S*B,)

    def matvec_cols(u):  # (n, S*B) -> (n, S*B)
        return bank.matvec_tilde_columns(u) + beta_cols[None, :] * u

    rhs = jnp.broadcast_to(f[:, None], (f.shape[0], ns * nb))
    sol = cg(matvec_cols, rhs, tol=tol, maxiter=maxiter)
    alphas = jnp.moveaxis(sol.x.reshape(f.shape[0], ns, nb), 1, 0)
    stats = [a.reshape(ns, nb) for a in
             (sol.num_iters, sol.residual_norm, sol.converged)]
    return KRRSweepResult(
        alphas=alphas, sigmas=sigmas, betas=betas, num_iters=stats[0],
        residual_norm=stats[1], converged=stats[2],
        kernel_name=kernel_name, train_points=points, params=params)


def krr_validation_loss(kernel_name: str, gram_op: FastsumOperator,
                        pred_op: FastsumOperator, f_train: Array,
                        f_val: Array, log_sigma, log_beta, *,
                        tol: float = 1e-10, maxiter: int = 1000):
    """Validation MSE of a KRR fit, differentiable w.r.t. (log σ, log β).

    The full gradient path: (log σ, log β) → traced kernel →
    ``FastsumOperator.with_kernel`` re-spectralization (differentiable
    ``b_hat`` / multiplier) → implicit-diff CG on the Gram system →
    separate-target prediction pipeline → MSE.  ``gram_op`` is a square
    operator over the training points and ``pred_op`` a train→validation
    operator (each keeps its own plan-time ``rho``); both are reused across
    optimization steps — only the spectral data is rebuilt per step.
    """
    kern = kernel_from_param(kernel_name, jnp.exp(log_sigma))
    beta = jnp.exp(log_beta)
    gram = gram_op.with_kernel(kern)

    def matvec(x):  # Gram matrix = W̃ (diagonal K(0) kept)
        return gram.matvec_tilde(x) + beta * x

    sol = cg(matvec, f_train, tol=tol, maxiter=maxiter)
    pred = pred_op.with_kernel(kern).matvec_tilde(sol.x)
    return jnp.mean((pred - f_val) ** 2)


class KRRGradResult(NamedTuple):
    """Gradient-based model selection trace (see :func:`krr_fit_grad`)."""

    model: KRRModel
    kernel_name: str
    sigma: float  # selected kernel parameter (sigma or c)
    beta: float
    val_loss: float
    log_sigma_path: Array  # (steps + 1,) iterates, init first
    log_beta_path: Array
    loss_path: Array  # (steps + 1,) validation loss at each iterate


def krr_fit_grad(kernel_name: str, points: Array, f: Array,
                 val_points: Array, val_f: Array, params: FastsumParams, *,
                 init_sigma: float = 0.5, init_beta: float = 1e-2,
                 steps: int = 40, lr: float = 0.25, tol: float = 1e-10,
                 maxiter: int = 1000) -> KRRGradResult:
    """Gradient-based (σ, β) model selection on a validation loss.

    Replaces the :func:`krr_fit_sweep` grid with Adam on
    ``(log σ, log β)``: the validation MSE is differentiated through the
    implicit-diff CG solve and the custom-VJP fastsum pipeline
    (:func:`krr_validation_loss`), so each step costs two solves (forward +
    adjoint) regardless of grid resolution.  Plans are built once — the
    per-step work re-spectralizes two operators and runs the solves.

    Returns the best-validation-loss iterate refit as a servable
    :class:`KRRModel`, plus the optimization trace.
    """
    points, f = jnp.asarray(points), jnp.asarray(f)
    val_points, val_f = jnp.asarray(val_points), jnp.asarray(val_f)
    init_kernel = kernel_from_param(kernel_name, float(init_sigma))
    gram_op = make_fastsum(init_kernel, points, params)
    pred_op = make_fastsum(init_kernel, points, params,
                           target_points=val_points)

    @jax.jit
    def value_and_grads(gop, pop, ls, lb):
        loss = lambda a, b: krr_validation_loss(
            kernel_name, gop, pop, f, val_f, a, b, tol=tol, maxiter=maxiter)
        return jax.value_and_grad(loss, argnums=(0, 1))(ls, lb)

    ls = jnp.asarray(np.log(float(init_sigma)))
    lb = jnp.asarray(np.log(float(init_beta)))
    m = jnp.zeros(2, ls.dtype)
    v = jnp.zeros(2, ls.dtype)
    ls_path, lb_path, loss_path = [], [], []
    best = (np.inf, float(ls), float(lb))
    for t in range(steps):
        val, (gs, gb) = value_and_grads(gram_op, pred_op, ls, lb)
        ls_path.append(float(ls))
        lb_path.append(float(lb))
        loss_path.append(float(val))
        if float(val) < best[0]:
            best = (float(val), float(ls), float(lb))
        g = jnp.stack([gs, gb])
        # quarantined/failed solves surface as zero cotangents (see cg's
        # implicit_diff contract) — scrub any residual non-finite values so
        # the optimizer state never poisons
        g = jnp.where(jnp.isfinite(g), g, 0.0)
        m = 0.9 * m + 0.1 * g
        v = 0.999 * v + 0.001 * g * g
        mh = m / (1.0 - 0.9 ** (t + 1))
        vh = v / (1.0 - 0.999 ** (t + 1))
        upd = lr * mh / (jnp.sqrt(vh) + 1e-8)
        ls, lb = ls - upd[0], lb - upd[1]
    final_val, _ = value_and_grads(gram_op, pred_op, ls, lb)
    ls_path.append(float(ls))
    lb_path.append(float(lb))
    loss_path.append(float(final_val))
    if float(final_val) < best[0]:
        best = (float(final_val), float(ls), float(lb))

    sigma_best = float(np.exp(best[1]))
    beta_best = float(np.exp(best[2]))
    model = krr_fit(kernel_from_param(kernel_name, sigma_best), points, f,
                    beta_best, params, tol=min(tol, 1e-8), maxiter=maxiter)
    return KRRGradResult(
        model=model, kernel_name=kernel_name, sigma=sigma_best,
        beta=beta_best, val_loss=best[0],
        log_sigma_path=jnp.asarray(ls_path),
        log_beta_path=jnp.asarray(lb_path),
        loss_path=jnp.asarray(loss_path))


def krr_sweep_model(sweep: KRRSweepResult, i_sigma: int,
                    j_beta: int) -> KRRModel:
    """Extract one (sigma, beta) cell of a sweep as a servable KRRModel."""
    return KRRModel(
        alpha=sweep.alphas[i_sigma, :, j_beta],
        train_points=sweep.train_points,
        kernel=make_kernel(sweep.kernel_name, sigma=sweep.sigmas[i_sigma]),
        params=sweep.params,
        num_iters=sweep.num_iters[i_sigma, j_beta],
        converged=sweep.converged[i_sigma, j_beta],
        pred_cache={})


def _pred_cache_lock(cache: dict) -> threading.Lock:
    """The cache's lock, created on first use.

    The dict is shared by every ``_replace`` copy of the model and mutated
    (insert + LRU reorder + evict) by both the serving engine's enqueue
    thread and its tick loop; unsynchronized list surgery corrupts the
    insertion order (lost inserts, duplicated entries).  ``dict.setdefault``
    is atomic under the GIL, so concurrent first calls agree on one lock.
    """
    lock = cache.get("lock")
    if lock is None:
        lock = cache.setdefault("lock", threading.Lock())
    return lock


def krr_pred_cache_stats(model: KRRModel) -> dict:
    """Snapshot of the prediction-cache counters: hits / misses / plans."""
    cache = model.pred_cache
    if cache is None:
        return {"hits": 0, "misses": 0, "plans": 0, "resident": 0}
    with _pred_cache_lock(cache):
        return {"hits": cache.get("hits", 0),
                "misses": cache.get("misses", 0),
                "plans": cache.get("plans", 0),
                "resident": len(cache.get("targets", []))}


def krr_prediction_operator(model: KRRModel, new_points: Array, *,
                            cache_key=None):
    """Plan-once prediction operator for ``new_points`` (serving hot path).

    Building the separate-target fast summation means recomputing the kernel
    Fourier coefficients, the Morton-sorted window geometries, and the fused
    spectral multiplier — none of which depend on ``alpha``.  Operators are
    cached on the model in a small keyed LRU (:data:`PRED_CACHE_SLOTS`
    entries), so alternating between a handful of serving target sets —
    e.g. a validation set and a live traffic set — re-plans nothing; only a
    genuinely new target set pays the planning cost and evicts the least
    recently used entry.

    Two target sets are "the same" when their *content* matches: the key is
    (shape, dtype, byte fingerprint) of the target and training arrays plus
    kernel/params equality (:func:`points_fingerprint`) — a round-tripped
    copy of a resident target set is a hit.  Callers that already know the
    identity of their target set (e.g. a request queue with stable query-set
    ids) can pass ``cache_key`` to skip hashing the target array; the caller
    then owns the contract that equal keys mean equal content.
    """
    cache = model.pred_cache
    # a hit must match everything the operator was built from, not just the
    # target points: the dict is shared by NamedTuple._replace copies
    key = (cache_key if cache_key is not None
           else points_fingerprint(new_points),
           points_fingerprint(model.train_points), model.kernel, model.params)
    if cache is not None:
        with _pred_cache_lock(cache):
            entries = cache.setdefault("targets", [])
            for i, (ek, op) in enumerate(entries):
                if ek == key:
                    if i:  # move to front (most recently used)
                        entries.insert(0, entries.pop(i))
                    cache["hits"] = cache.get("hits", 0) + 1
                    return op
            cache["misses"] = cache.get("misses", 0) + 1
    # plan outside the lock: planning is the expensive part, and holding the
    # lock across it would serialize the engine's enqueue thread against the
    # tick loop for the whole build
    op = make_fastsum(model.kernel, model.train_points, model.params,
                      target_points=new_points)
    if cache is not None:
        with _pred_cache_lock(cache):
            cache["plans"] = cache.get("plans", 0) + 1
            entries = cache.setdefault("targets", [])
            if not any(ek == key for ek, _ in entries):  # racing builder won
                entries.insert(0, (key, op))
                del entries[PRED_CACHE_SLOTS:]
    return op


def krr_predict(model: KRRModel, new_points: Array, *, op=None,
                cache_key=None) -> Array:
    """F(x) = sum_i alpha_i K(x_i - x) via separate-target fast summation.

    The prediction operator is planned once per target set and cached on the
    model (see :func:`krr_prediction_operator`); pass a prebuilt ``op`` to
    manage caching yourself.
    """
    if op is None:
        op = krr_prediction_operator(model, new_points, cache_key=cache_key)
    return op.matvec_tilde(model.alpha)


def krr_predict_many(model: KRRModel, queries: Sequence[Array],
                     rhs: Sequence[Array | None] | None = None, *,
                     cache_key=None) -> list:
    """Batched prediction: many query sets through ONE plan application.

    Packs all query sets into one concatenated target set (one prediction
    operator — a cache hit when the packed content repeats), dedupes the
    per-request dual vectors into channel columns (``rhs[i] is None`` means
    the model's own ``alpha``; requests sharing a dual vector share a
    column), runs one multi-RHS ``matvec_tilde``, and splits the rows back
    per request.  R requests cost one spread + one FFT pair + one gather
    instead of R full pipelines.
    """
    queries = [jnp.asarray(q) for q in queries]
    if rhs is None:
        rhs = [None] * len(queries)
    if len(rhs) != len(queries):
        raise ValueError(f"got {len(queries)} query sets but {len(rhs)} rhs")
    packed = jnp.concatenate(queries, axis=0)
    op = krr_prediction_operator(model, packed, cache_key=cache_key)

    # dedupe dual vectors into columns (None -> the model's alpha)
    cols, col_of_req = [], []
    col_ids: dict = {}
    for r in rhs:
        cid = "alpha" if r is None else points_fingerprint(r)
        if cid not in col_ids:
            col_ids[cid] = len(cols)
            cols.append(model.alpha if r is None else jnp.asarray(r))
        col_of_req.append(col_ids[cid])

    if len(cols) == 1:
        out = op.matvec_tilde(cols[0])[:, None]  # (m_total, 1)
    else:
        out = op.matvec_tilde(jnp.stack(cols, axis=1))  # (m_total, C)
    results, row = [], 0
    for q, c in zip(queries, col_of_req):
        m = q.shape[0]
        results.append(out[row:row + m, c])
        row += m
    return results


def krr_predict_direct(model: KRRModel, new_points: Array) -> Array:
    """O(n m) dense prediction (oracle for tests)."""
    diff = new_points[:, None, :] - model.train_points[None, :, :]
    r = jnp.sqrt(jnp.maximum(jnp.sum(diff * diff, -1), 0.0))
    return model.kernel.phi(r) @ model.alpha
