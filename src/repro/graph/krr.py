"""Kernel ridge regression with NFFT-accelerated Gram matvecs (Section 6.3).

Dual solve:  alpha = (K + beta I)^{-1} f  by CG, where the Gram matrix
K_ij = K(x_i - x_j) (note: *with* diagonal K(0), unlike the graph weight
matrix) is applied via Algorithm 3.1.  Prediction at new points x uses the
separate-target fast summation:  F(x) = sum_i alpha_i K(x_i - x).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.fastsum import FastsumOperator, FastsumParams, make_fastsum
from repro.core.kernels import Kernel
from repro.core.solvers import cg

Array = jax.Array


class KRRModel(NamedTuple):
    alpha: Array
    train_points: Array
    kernel: Kernel
    params: FastsumParams
    num_iters: Array
    converged: Array


def krr_fit(kernel: Kernel, points: Array, f: Array, beta: float,
            params: FastsumParams, *, tol: float = 1e-8,
            maxiter: int = 1000) -> KRRModel:
    """Fit the dual variable alpha = (K + beta I)^{-1} f via CG."""
    gram = make_fastsum(kernel, points, params)

    def matvec(x):
        # Gram matrix = W̃ (diagonal K(0) kept)
        return gram.matvec_tilde(x) + beta * x

    sol = cg(matvec, f, tol=tol, maxiter=maxiter)
    return KRRModel(alpha=sol.x, train_points=points, kernel=kernel,
                    params=params, num_iters=sol.num_iters,
                    converged=sol.converged)


def krr_predict(model: KRRModel, new_points: Array) -> Array:
    """F(x) = sum_i alpha_i K(x_i - x) via separate-target fast summation."""
    op = make_fastsum(model.kernel, model.train_points, model.params,
                      target_points=new_points)
    return op.matvec_tilde(model.alpha)


def krr_predict_direct(model: KRRModel, new_points: Array) -> Array:
    """O(n m) dense prediction (oracle for tests)."""
    diff = new_points[:, None, :] - model.train_points[None, :, :]
    r = jnp.sqrt(jnp.maximum(jnp.sum(diff * diff, -1), 0.0))
    return model.kernel.phi(r) @ model.alpha
