"""Kernel ridge regression with NFFT-accelerated Gram matvecs (Section 6.3).

Dual solve:  alpha = (K + beta I)^{-1} f  by CG, where the Gram matrix
K_ij = K(x_i - x_j) (note: *with* diagonal K(0), unlike the graph weight
matrix) is applied via Algorithm 3.1.  Prediction at new points x uses the
separate-target fast summation:  F(x) = sum_i alpha_i K(x_i - x).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.fastsum import FastsumOperator, FastsumParams, make_fastsum
from repro.core.kernels import Kernel
from repro.core.solvers import cg

Array = jax.Array


class KRRModel(NamedTuple):
    alpha: Array
    train_points: Array
    kernel: Kernel
    params: FastsumParams
    num_iters: Array
    converged: Array
    # single-slot serving cache {"target": (new_points, FastsumOperator)};
    # mutable on purpose (shared by every copy of this immutable model).
    pred_cache: dict | None = None


def krr_fit(kernel: Kernel, points: Array, f: Array, beta: float,
            params: FastsumParams, *, tol: float = 1e-8,
            maxiter: int = 1000) -> KRRModel:
    """Fit the dual variable alpha = (K + beta I)^{-1} f via CG."""
    gram = make_fastsum(kernel, points, params)

    def matvec(x):
        # Gram matrix = W̃ (diagonal K(0) kept)
        return gram.matvec_tilde(x) + beta * x

    sol = cg(matvec, f, tol=tol, maxiter=maxiter)
    return KRRModel(alpha=sol.x, train_points=points, kernel=kernel,
                    params=params, num_iters=sol.num_iters,
                    converged=sol.converged, pred_cache={})


def krr_prediction_operator(model: KRRModel, new_points: Array):
    """Plan-once prediction operator for ``new_points`` (serving hot path).

    Building the separate-target fast summation means recomputing the kernel
    Fourier coefficients, the Morton-sorted window geometries, and the fused
    spectral multiplier — none of which depend on ``alpha``.  The operator
    is cached on the model (single slot, keyed by target identity), so
    repeated predicts against the same target set plan once and only pay the
    O(n + m) pipeline per call.
    """
    cache = model.pred_cache
    # the dict is shared by NamedTuple._replace copies, so a hit must match
    # everything the operator was built from, not just the target points
    key = (new_points, model.train_points, model.kernel, model.params)
    if cache is not None:
        hit = cache.get("target")
        if (hit is not None and hit[0] is key[0] and hit[1] is key[1]
                and hit[2] == key[2] and hit[3] == key[3]):
            return hit[4]
    op = make_fastsum(model.kernel, model.train_points, model.params,
                      target_points=new_points)
    if cache is not None:
        cache["target"] = key + (op,)
    return op


def krr_predict(model: KRRModel, new_points: Array, *, op=None) -> Array:
    """F(x) = sum_i alpha_i K(x_i - x) via separate-target fast summation.

    The prediction operator is planned once per target set and cached on the
    model (see :func:`krr_prediction_operator`); pass a prebuilt ``op`` to
    manage caching yourself.
    """
    if op is None:
        op = krr_prediction_operator(model, new_points)
    return op.matvec_tilde(model.alpha)


def krr_predict_direct(model: KRRModel, new_points: Array) -> Array:
    """O(n m) dense prediction (oracle for tests)."""
    diff = new_points[:, None, :] - model.train_points[None, :, :]
    r = jnp.sqrt(jnp.maximum(jnp.sum(diff * diff, -1), 0.0))
    return model.kernel.phi(r) @ model.alpha
