from repro.graph.spectral import (  # noqa: F401
    kmeans, spectral_clustering, clustering_agreement, SpectralResult,
)
from repro.graph.ssl import (  # noqa: F401
    allen_cahn_ssl, allen_cahn_multiclass, kernel_ssl_cg,
    kernel_ssl_cg_multilayer, kernel_ssl_eig, make_training_vector,
)
from repro.graph.krr import (  # noqa: F401
    krr_fit, krr_fit_grad, krr_fit_sweep, krr_pred_cache_stats, krr_predict,
    krr_predict_direct, krr_predict_many, krr_prediction_operator,
    krr_sweep_model, krr_validation_loss, points_fingerprint, KRRModel,
    KRRGradResult, KRRSweepResult)
