"""Semi-supervised learning on graphs (paper Sections 6.2.2 and 6.2.3).

1. Phase-field / Allen–Cahn method (Bertozzi–Flenner [5]):
   convexity-split semi-implicit time stepping of

       u_t = -eps L_s u - (1/eps) psi'(u) + Omega (f - u)

   projected on the k smallest eigenpairs of L_s.  Binary labels +-1; the
   multiclass driver runs one-vs-rest.

2. Kernel method (Zhou et al. [48]):  solve  (I + beta L_s) u = f  by CG with
   NFFT matvecs (Eq. (6.4)), or with a truncated eigenapproximation
   V_k diag(1-lam_k) V_k^T of A for O(nk) solves.
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fastsum import (
    FastsumParams, NormalizedAdjacencyOperator,
    make_normalized_adjacency_mixture,
)
from repro.core.lanczos import eigsh
from repro.core.solvers import cg

Array = jax.Array


def make_training_vector(labels: Array, n_samples_per_class: int, n_classes: int,
                         *, key: Array, positive_class: int) -> tuple[Array, Array]:
    """Binary training vector f (+1 for positive class samples, -1 for other
    class samples, 0 elsewhere) and the sample mask (paper Section 6.2.2).

    Per-class sample counts are clamped to the class size, so classes with
    fewer than ``n_samples_per_class`` members contribute all their members
    and nothing else (the selection must never spill past the class into the
    sentinel rows and label wrong-class nodes).  Eager-only: the clamp reads
    concrete class sizes from ``labels``.
    """
    n = labels.shape[0]
    f = jnp.zeros((n,))
    mask = jnp.zeros((n,), bool)
    keys = jax.random.split(key, n_classes)
    for c in range(n_classes):
        members = labels == c
        take = min(n_samples_per_class, int(jnp.sum(members)))
        if take == 0:
            continue
        idx = jnp.where(members, jax.random.uniform(keys[c], (n,)), 2.0)
        chosen = jnp.argsort(idx)[:take]
        sign = jnp.where(c == positive_class, 1.0, -1.0)
        f = f.at[chosen].set(sign)
        mask = mask.at[chosen].set(True)
    return f, mask


class PhaseFieldResult(NamedTuple):
    u: Array
    num_steps: int


def allen_cahn_ssl(eigenvalues_ls: Array, eigenvectors: Array, f: Array,
                   *, eps: float = 10.0, tau: float = 0.1,
                   omega0: float = 10_000.0, c: float | None = None,
                   max_steps: int = 500, rtol: float = 1e-10) -> PhaseFieldResult:
    """Allen–Cahn SSL in the truncated eigenbasis (Section 6.2.2).

    ``eigenvalues_ls``: k smallest eigenvalues of L_s; ``eigenvectors``:
    corresponding (n, k) eigenvectors; ``f``: training vector (+-1 / 0).
    """
    if c is None:
        c = 2.0 / eps + omega0
    v = eigenvectors  # (n, k)
    lam = eigenvalues_ls  # (k,)
    omega = (f != 0).astype(f.dtype) * omega0

    denom = 1.0 + tau * (eps * lam + c)  # (k,)

    u0 = f
    a0 = v.T @ u0

    def step(carry):
        a_bar, u_bar, i, _ = carry
        psi_prime = 4.0 * u_bar * (u_bar * u_bar - 1.0)
        # Discrete convexity-split form (paper Section 6.2.2):
        # (1 + tau(eps lam + c)) a = a_bar + tau(-(1/eps) v^T psi'(u_bar)
        #                                        + c a_bar + v^T Omega (f-u_bar))
        rhs = (a_bar
               + tau * (-(1.0 / eps) * (v.T @ psi_prime)
                        + c * a_bar
                        + v.T @ (omega * (f - u_bar))))
        a_new = rhs / denom
        u_new = v @ a_new
        rel = jnp.sum((u_new - u_bar) ** 2) / jnp.maximum(jnp.sum(u_bar ** 2), 1e-30)
        return a_new, u_new, i + 1, rel

    def cond(carry):
        _, _, i, rel = carry
        return jnp.logical_and(i < max_steps, rel > rtol)

    a, u, steps, _ = jax.lax.while_loop(
        cond, step, (a0, u0, jnp.zeros((), jnp.int32), jnp.ones(())))
    return PhaseFieldResult(u=u, num_steps=int(steps))


def allen_cahn_multiclass(adjacency: NormalizedAdjacencyOperator, labels: Array,
                          n_classes: int, n_samples_per_class: int, *,
                          k: int = 5, key: Array,
                          num_lanczos_iters: int | None = None,
                          eigsh_fn: Callable | None = None,
                          **ac_kwargs) -> Array:
    """One-vs-rest Allen–Cahn classification.  Returns predicted labels."""
    res = (eigsh_fn or (lambda: eigsh(
        adjacency.matvec, adjacency.n, k, num_iters=num_lanczos_iters,
        key=key, dtype=adjacency.inv_sqrt_deg.dtype)))()
    lam_ls = 1.0 - res.eigenvalues  # smallest of L_s
    scores = []
    for cls in range(n_classes):
        f, _ = make_training_vector(labels, n_samples_per_class, n_classes,
                                    key=jax.random.fold_in(key, cls),
                                    positive_class=cls)
        out = allen_cahn_ssl(lam_ls, res.eigenvectors, f, **ac_kwargs)
        scores.append(out.u)
    return jnp.argmax(jnp.stack(scores, axis=1), axis=1)


class KernelSSLResult(NamedTuple):
    u: Array
    num_iters: Array
    converged: Array


def kernel_ssl_cg(adjacency: NormalizedAdjacencyOperator, f: Array, beta: float,
                  *, tol: float = 1e-4, maxiter: int = 1000) -> KernelSSLResult:
    """Solve (I + beta L_s) u = f with CG + NFFT matvecs (Eq. (6.4))."""

    def matvec(x):
        return x + beta * adjacency.laplacian_matvec(x)

    sol = cg(matvec, f, tol=tol, maxiter=maxiter)
    return KernelSSLResult(u=sol.x, num_iters=sol.num_iters,
                           converged=sol.converged)


def kernel_ssl_cg_multilayer(kernels, weights, points: Array,
                             params: FastsumParams, f: Array, beta: float,
                             *, tol: float = 1e-4, maxiter: int = 1000
                             ) -> KernelSSLResult:
    """Kernel SSL on an aggregated multilayer graph (one matvec per layer sum).

    The multilayer extension (Bergermann–Stoll–Volkmer 2020) builds the
    weight matrix as a fixed-weight sum of per-layer kernels,
    ``W = sum_l w_l (W̃_l - K_l(0) I)``, over shared nodes.  Because the
    per-layer operators share their NFFT plan and window geometry, the
    mixture collapses to a *single* summed spectral multiplier
    (:func:`repro.core.fastsum.make_normalized_adjacency_mixture`): every CG
    iteration on (I + beta L_s) costs exactly one fused matvec, the same as
    a single-layer graph — not |layers| of them.
    """
    adjacency = make_normalized_adjacency_mixture(kernels, weights, points,
                                                  params)
    return kernel_ssl_cg(adjacency, f, beta, tol=tol, maxiter=maxiter)


def kernel_ssl_eig(eigenvalues_a: Array, eigenvectors: Array, f: Array,
                   beta: float) -> Array:
    """Same solve via truncated eigenapproximation of A (Section 6.2.3).

    With A ≈ V diag(theta) V^T:  L_s ≈ I - V diag(theta) V^T, and by
    Sherman–Morrison–Woodbury
        (I + beta L_s)^{-1} = ((1+beta) I - beta V diag(theta) V^T)^{-1}
      = (1/(1+beta)) [ I + V diag( beta theta / (1+beta-beta theta) ) V^T ].
    """
    theta = eigenvalues_a
    coeff = beta * theta / (1.0 + beta - beta * theta)
    vtf = eigenvectors.T @ f
    return (f + eigenvectors @ (coeff * vtf)) / (1.0 + beta)
