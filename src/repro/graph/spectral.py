"""Spectral clustering (paper Section 6.2.1; Ng–Jordan–Weiss [28]).

Pipeline: k largest eigenvectors of A = D^{-1/2} W D^{-1/2} (computed by the
NFFT-based Lanczos method, the hybrid Nyström, or a direct solver) ->
row-normalize -> k-means on the embedded rows.

k-means (kmeans++ init + Lloyd iterations) is implemented in JAX so the whole
pipeline is one jittable program.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.fastsum import NormalizedAdjacencyOperator
from repro.core.lanczos import eigsh

Array = jax.Array


class KMeansResult(NamedTuple):
    assignments: Array  # (n,)
    centers: Array  # (k, d)
    inertia: Array


def _kmeanspp_init(key: Array, points: Array, k: int) -> Array:
    n = points.shape[0]
    keys = jax.random.split(key, k)
    first = jax.random.randint(keys[0], (), 0, n)
    centers = jnp.zeros((k, points.shape[1]), points.dtype).at[0].set(points[first])

    def body(i, centers):
        d2 = jnp.min(
            jnp.sum((points[:, None, :] - centers[None, :, :]) ** 2, -1)
            + jnp.where(jnp.arange(k)[None, :] < i, 0.0, jnp.inf), axis=1)
        probs = d2 / jnp.maximum(jnp.sum(d2), 1e-30)
        idx = jax.random.choice(keys[i], n, p=probs)
        return centers.at[i].set(points[idx])

    return jax.lax.fori_loop(1, k, body, centers)


@functools.partial(jax.jit, static_argnames=("k", "num_iters"))
def kmeans(key: Array, points: Array, k: int, num_iters: int = 50) -> KMeansResult:
    centers = _kmeanspp_init(key, points, k)

    def step(_, centers):
        d2 = jnp.sum((points[:, None, :] - centers[None, :, :]) ** 2, -1)
        assign = jnp.argmin(d2, axis=1)
        one_hot = jax.nn.one_hot(assign, k, dtype=points.dtype)
        counts = jnp.maximum(one_hot.sum(0), 1.0)
        new_centers = (one_hot.T @ points) / counts[:, None]
        # keep empty clusters where they were
        new_centers = jnp.where((one_hot.sum(0) > 0)[:, None], new_centers, centers)
        return new_centers

    centers = jax.lax.fori_loop(0, num_iters, step, centers)
    d2 = jnp.sum((points[:, None, :] - centers[None, :, :]) ** 2, -1)
    assign = jnp.argmin(d2, axis=1)
    inertia = jnp.sum(jnp.min(d2, axis=1))
    return KMeansResult(assignments=assign, centers=centers, inertia=inertia)


class SpectralResult(NamedTuple):
    assignments: Array
    eigenvalues: Array
    eigenvectors: Array


def spectral_clustering(adjacency: NormalizedAdjacencyOperator, k: int,
                        *, key: Array, num_lanczos_iters: int | None = None,
                        block_size: int = 1,
                        eigenvectors: Array | None = None,
                        eigenvalues: Array | None = None) -> SpectralResult:
    """NJW spectral clustering with NFFT-accelerated eigenvectors.

    Pass precomputed ``eigenvectors`` to reuse (e.g. from Nyström) — then the
    adjacency operator is only used for its size.  ``block_size > 1`` uses
    block Lanczos: the fused fastsum engine applies the operator to whole
    (n, block) batches, amortizing spread/gather across the block.
    """
    # independent streams for the Lanczos start vector and the k-means++
    # init — reusing one key would correlate the two randomizations
    key_eigs, key_kmeans = jax.random.split(key)
    if eigenvectors is None:
        res = eigsh(adjacency.matvec, adjacency.n, k,
                    num_iters=num_lanczos_iters, key=key_eigs,
                    block_size=block_size,
                    dtype=adjacency.inv_sqrt_deg.dtype)
        eigenvectors, eigenvalues = res.eigenvectors, res.eigenvalues
    rows = eigenvectors / jnp.maximum(
        jnp.linalg.norm(eigenvectors, axis=1, keepdims=True), 1e-30)
    km = kmeans(key_kmeans, rows, k)
    return SpectralResult(assignments=km.assignments,
                          eigenvalues=eigenvalues, eigenvectors=eigenvectors)


def clustering_agreement(a: Array, b: Array, k: int) -> float:
    """Fraction of points whose cluster assignment agrees between two
    labelings, maximized over label permutations (greedy Hungarian-lite,
    exact for k <= 6 via brute force)."""
    import itertools

    import numpy as np

    a = np.asarray(a)
    b = np.asarray(b)
    best = 0.0
    if k <= 6:
        for perm in itertools.permutations(range(k)):
            mapped = np.asarray(perm)[b]
            best = max(best, float(np.mean(a == mapped)))
        return best
    # greedy fallback
    remaining = set(range(k))
    mapping = {}
    for c in range(k):
        counts = [(np.sum((b == c) & (a == t)), t) for t in remaining]
        cnt, t = max(counts)
        mapping[c] = t
        remaining.discard(t)
    mapped = np.asarray([mapping[x] for x in b])
    return float(np.mean(a == mapped))
