"""Durable execution: preemption-safe Krylov solves with exactly-once replay.

The paper's workloads are long-iteration by construction — hundreds of
Lanczos/CG steps per spectrum or SSL solve — and a preempted process used
to restart them from iteration 0.  This module is the Krylov analogue of
:func:`repro.training.fault_tolerance.run_resilient`: the solvers expose
their complete loop state as a checkpointable pytree
(:class:`~repro.core.solvers.CGLoopState`,
:class:`~repro.core.solvers.MinresLoopState`,
:class:`~repro.core.lanczos.LanczosLoopState`,
:class:`~repro.core.lanczos.BlockLanczosLoopState` — iterate, residual and
search directions, Lanczos basis + tridiagonal blocks, per-column
convergence/quarantine masks, SolveHealth counters), and the drivers here
run the loop in bounded segments, snapshotting the state through the
:mod:`repro.training.checkpoint` API every ``snapshot_every`` iterations.

Contract:

* **bit-identical trajectories** — the loop bodies are deterministic
  functions of the state pytree alone, and segmenting a
  ``while_loop``/``fori_loop`` does not change the sequence of body
  applications, so a run killed at any iteration and resumed from its
  latest snapshot produces the same iterates (and hence the same
  eigenvalues / solutions) as an uninterrupted run;
* **exactly-once in effect** — at most ``snapshot_every`` iterations are
  re-executed on restart, and re-executed iterations reproduce the
  originals exactly (the replay is idempotent);
* **crash-safe snapshots** — the checkpoint layer's atomic rename, per-leaf
  CRC32 checksums, and :func:`~repro.training.checkpoint.
  restore_latest_valid` fallback mean a snapshot torn or bit-flipped by the
  crash costs one snapshot interval of progress, never a wrong answer;
* **restart-storm bounded** — in-process restarts (injected preemptions)
  are capped by ``max_restarts`` with exponential backoff, mirroring
  ``run_resilient``; a cross-process resume is simply calling the same
  function again with the same arguments and ``ckpt_dir``.

PRNG determinism: :func:`resumable_eigsh` derives its start vectors through
:func:`~repro.core.lanczos.eigsh_setup` from the caller's ``key`` — the
same resolution :func:`~repro.core.lanczos.eigsh` uses — so a resumed run
rebuilds identical start vectors without checkpointing the key itself.
"""

from __future__ import annotations

import dataclasses
import functools
import logging
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import lanczos as _lanczos
from repro.core import solvers as _solvers
from repro.core.lanczos import (
    BlockLanczosLoopState, EigshResult, LanczosLoopState,
    block_lanczos_machine, eigsh_setup, lanczos_machine, ritz_from_block,
    ritz_from_lanczos,
)
from repro.core.solvers import KrylovMachine, SolveResult
from repro.training import checkpoint as ckpt
from repro.training.fault_tolerance import InjectedFault

Array = jax.Array
log = logging.getLogger("repro.durable")


@dataclasses.dataclass(frozen=True)
class DurablePolicy:
    """Snapshot cadence + restart discipline for the durable drivers.

    ``snapshot_every`` counts *operator applications* (CG/MINRES/Lanczos
    iterations; block-Lanczos block steps).  ``keep`` snapshots stay on
    disk so a corrupted latest snapshot still has an intact predecessor.
    ``max_restarts`` bounds in-process restart storms; restart ``r`` sleeps
    ``backoff_base_s * 2**(r-1)`` (capped at ``backoff_max_s``) before
    restoring, so a crash-looping fault cannot spin the host.
    """

    snapshot_every: int = 25
    keep: int = 2
    max_restarts: int = 10
    backoff_base_s: float = 0.0
    backoff_max_s: float = 30.0


@dataclasses.dataclass
class DurableReport:
    """What the durable driver did for one logical solve."""

    resumed_from: Optional[int]  # snapshot iteration resumed from, or None
    snapshots: int = 0           # snapshots written by this run
    segments: int = 0            # loop segments executed
    restarts: int = 0            # in-process restarts absorbed
    final_iteration: int = 0


@functools.partial(jax.jit, static_argnums=(0, 1, 2))
def _advance_while(cond, body, steps, state):
    """Run ``body`` while ``cond`` holds, at most ``steps`` more iterations.

    The loop body is the *same* callable the plain solver runs, so the
    segmented trajectory is the uninterrupted trajectory.
    """
    limit = state.i + steps
    return jax.lax.while_loop(
        lambda s: jnp.logical_and(cond(s), s.i < limit), body, state)


@functools.partial(jax.jit, static_argnums=(0,))
def _advance_fori(body, i0, i1, carry):
    return jax.lax.fori_loop(i0, i1, body, carry)


def _drive(state0, advance: Callable, done: Callable, ckpt_dir: str,
           policy: DurablePolicy,
           fault_hook: Optional[Callable[[int], None]]):
    """Segment/snapshot/restart loop shared by both drivers.

    ``advance(state) -> state`` runs one bounded segment; ``done(state)``
    says whether the loop condition is exhausted; ``fault_hook(iteration)``
    is the preemption kill-point seam (raises
    :class:`~repro.training.fault_tolerance.InjectedFault` to simulate a
    kill — a real SIGKILL is recovered by simply calling the durable
    function again, which lands in the same restore path).
    """
    abstract = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state0)
    start, restored = ckpt.restore_latest_valid(ckpt_dir, abstract)
    report = DurableReport(resumed_from=start)
    state = state0 if start is None else restored
    if start is not None:
        log.info("resumed solve from snapshot at iteration %d", start)
    pending = None
    while True:
        try:
            if fault_hook is not None:
                fault_hook(int(jax.device_get(state.i)))
            if bool(jax.device_get(done(state))):
                break
            state = advance(state)
            report.segments += 1
            it = int(jax.device_get(state.i))
            pending = ckpt.save_checkpoint(ckpt_dir, it, state,
                                           blocking=False, keep=policy.keep)
            report.snapshots += 1
        except InjectedFault as e:
            report.restarts += 1
            if pending is not None:
                pending.join()  # let any in-flight snapshot land
            if report.restarts > policy.max_restarts:
                raise
            if policy.backoff_base_s:
                delay = min(
                    policy.backoff_base_s * 2 ** (report.restarts - 1),
                    policy.backoff_max_s)
                log.warning("preempted (%s); backing off %.3fs before "
                            "restart %d", e, delay, report.restarts)
                time.sleep(delay)
            start, restored = ckpt.restore_latest_valid(ckpt_dir, abstract)
            state = state0 if start is None else restored
    if pending is not None:
        pending.join()
    report.final_iteration = int(jax.device_get(state.i))
    return state, report


def _machine_done(machine: KrylovMachine):
    return lambda s: jnp.logical_not(machine.cond(s))


def _resumable_columns(matvec, b, *, ckpt_dir, method, x0, tol, maxiter,
                       preconditioner, stall_window, policy, fault_hook):
    if method == "cg":
        machine = _solvers.cg_machine(
            matvec, b, x0=x0, tol=tol, maxiter=maxiter,
            preconditioner=preconditioner, stall_window=stall_window)
    elif method == "minres":
        if preconditioner is not None:
            raise ValueError("minres does not take a preconditioner")
        machine = _solvers.minres_machine(
            matvec, b, x0=x0, tol=tol, maxiter=maxiter,
            stall_window=stall_window)
    else:
        raise ValueError(f"method must be 'cg' or 'minres', got {method!r}")

    def advance(state):
        return _advance_while(machine.cond, machine.body,
                              policy.snapshot_every, state)

    final, report = _drive(machine.state, advance, _machine_done(machine),
                           ckpt_dir, policy, fault_hook)
    return machine.finish(final), report


def resumable_solve(matvec, b: Array, *, ckpt_dir: str, method: str = "cg",
                    bank: bool = False, x0: Array | None = None,
                    tol: float = 1e-8, maxiter: int = 1000,
                    preconditioner=None, stall_window: int = 250,
                    policy: DurablePolicy | None = None,
                    fault_hook: Optional[Callable[[int], None]] = None,
                    ) -> tuple[SolveResult, DurableReport]:
    """Preemption-safe :func:`~repro.core.solvers.cg` /
    :func:`~repro.core.solvers.minres` (and their lockstep bank flavors).

    Runs the solver loop in ``policy.snapshot_every``-iteration segments,
    snapshotting the full loop state into ``ckpt_dir`` between segments.
    Killed and re-invoked (same arguments, same ``ckpt_dir``), it resumes
    from the latest intact snapshot and produces the bit-identical
    trajectory of an uninterrupted run; at most one snapshot interval is
    re-executed.  ``bank=True`` treats ``b`` as (S, n) / (S, n, C) with a
    bank matvec — the :func:`~repro.core.solvers.cg_bank` layout — so an
    entire hyperparameter sweep becomes one durable solve.

    Returns ``(SolveResult, DurableReport)``.  Delete ``ckpt_dir`` (or use
    a fresh one) to start a new logical solve; a stale snapshot from a
    different problem shape is rejected by the checkpoint validators and
    the solve starts fresh.
    """
    policy = policy or DurablePolicy()
    if bank:
        cell = {}

        def solver(flat_mv, bflat, x0=None, **kw):
            res, rep = _resumable_columns(
                flat_mv, bflat, ckpt_dir=ckpt_dir, method=method, x0=x0,
                preconditioner=preconditioner, policy=policy,
                fault_hook=fault_hook, **kw)
            cell["report"] = rep
            return res

        sol = _solvers._bank_solve(
            solver, matvec, b, x0,
            dict(tol=tol, maxiter=maxiter, stall_window=stall_window))
        return sol, cell["report"]
    return _resumable_columns(
        matvec, b, ckpt_dir=ckpt_dir, method=method, x0=x0, tol=tol,
        maxiter=maxiter, preconditioner=preconditioner,
        stall_window=stall_window, policy=policy, fault_hook=fault_hook)


def resumable_eigsh(matvec, n: int, k: int, *, ckpt_dir: str,
                    num_iters: int | None = None, which: str = "LA",
                    key: Array | None = None, dtype=jnp.float64,
                    v0: Array | None = None, block_size: int = 1,
                    policy: DurablePolicy | None = None,
                    fault_hook: Optional[Callable[[int], None]] = None,
                    ) -> tuple[EigshResult, DurableReport]:
    """Preemption-safe :func:`~repro.core.lanczos.eigsh`.

    The (block-)Lanczos factorization — the dominant cost — runs in
    snapshot-bounded segments; the Ritz extraction happens once, after the
    factorization completes.  Start vectors are re-derived from ``key``
    through the same :func:`~repro.core.lanczos.eigsh_setup` resolution
    ``eigsh`` uses, so a resumed run continues the identical iteration.
    Returns ``(EigshResult, DurableReport)``.
    """
    policy = policy or DurablePolicy()
    setup = eigsh_setup(n, k, num_iters=num_iters, which=which, key=key,
                        dtype=dtype, v0=v0, block_size=block_size)
    if setup.num_blocks:
        state0, body, finish = block_lanczos_machine(
            matvec, setup.v0, setup.num_blocks)
        total, state_cls = setup.num_blocks, BlockLanczosLoopState
    else:
        state0, body, finish = lanczos_machine(
            matvec, setup.v0, setup.num_iters)
        total, state_cls = setup.num_iters, LanczosLoopState

    def advance(state):
        i1 = jnp.minimum(state.i + policy.snapshot_every,
                         jnp.asarray(total, jnp.int32))
        carry = _advance_fori(body, state.i, i1, tuple(state)[:-1])
        return state_cls(*carry, i=i1)

    def done(state):
        return state.i >= total

    final, report = _drive(state0, advance, done, ckpt_dir, policy,
                           fault_hook)
    res = finish(final)
    if setup.num_blocks:
        return ritz_from_block(res, setup, n), report
    return ritz_from_lanczos(res, setup), report
