"""Guarded execution: runtime accuracy guards + deterministic fault injection.

``repro.runtime`` is the layer that makes the fast paths *safe to trust* in
production: the paper's Lemma 3.1 a-posteriori error bound consulted live
(with automatic bandwidth escalation and a dense-fallback floor), durable
(preemption-safe, snapshot-resumable) Krylov drivers, and seeded chaos
injectors for driving the solve/serve stack through failures in tests.
See ``guards``, ``durable``, and ``faultinject``.
"""

from repro.runtime.durable import (
    DurablePolicy, DurableReport, resumable_eigsh, resumable_solve,
)
from repro.runtime.faultinject import (
    KillPoint, KillSchedule, Preemption, TickChaos, chaos_schedule,
    corrupt_group_plan, nan_poison_grid, poison_bank_member, poison_columns,
    poison_registry_grids, SlowMatvec,
)
from repro.runtime.guards import (
    DirectKernelOperator, GuardPolicy, GuardReport, ProbeReport,
    guarded_fastsum, guarded_normalized_adjacency, probe_fastsum,
)

__all__ = [
    "DirectKernelOperator",
    "DurablePolicy",
    "DurableReport",
    "GuardPolicy",
    "GuardReport",
    "KillPoint",
    "KillSchedule",
    "Preemption",
    "ProbeReport",
    "SlowMatvec",
    "TickChaos",
    "chaos_schedule",
    "corrupt_group_plan",
    "guarded_fastsum",
    "guarded_normalized_adjacency",
    "nan_poison_grid",
    "poison_bank_member",
    "poison_columns",
    "poison_registry_grids",
    "probe_fastsum",
    "resumable_eigsh",
    "resumable_solve",
]
