"""Runtime accuracy guards: Lemma 3.1 consulted live (paper Section 3.1).

``core/error.py`` implements the paper's a-posteriori bound

    ||A - A_E||_inf <= eps (1 + eta) / (eta (eta - eps)),   eps < eta,

but nothing in the live stack consulted it — a mis-sized bandwidth produced
silently wrong eigenvalues and predictions.  This module closes that gap
with a *cheap* probe (no O(n^2) dense matrix):

* ``eta = d_min / ||W||_inf`` from one approximate-degree matvec (Eq. 3.5:
  for non-negative W the inf-norm is the max row sum, i.e. the max degree);
* ``eps`` from the Monte-Carlo regularization-error sweep of
  :func:`repro.core.error.estimate_epsilon` (Eq. 3.6) — O(n_samples)
  kernel evaluations against the trigonometric polynomial.

:func:`guarded_fastsum` builds an operator, probes it, and escalates the
bandwidth ``N`` (doubling up to ``GuardPolicy.max_bandwidth``) until the
bound meets the declared tolerance.  If escalation runs out and the problem
is small enough, it degrades to the exact O(n^2)
:class:`DirectKernelOperator` (the bottom rung of the degradation ladder:
pallas -> xla, pencil -> psum, fastsum -> direct); otherwise it returns the
best attempt with ``GuardReport.ok = False`` and a warning — degraded,
never silently wrong.
"""

from __future__ import annotations

import dataclasses
import warnings

import jax
import jax.numpy as jnp

from repro.core.error import estimate_epsilon, lemma31_bound
from repro.core.fastsum import (
    FastsumOperator, FastsumParams, _normalized_adjacency_from,
    direct_matvec_tiled, make_fastsum, scale_nodes,
)
from repro.core.kernels import Kernel

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class GuardPolicy:
    """Knobs for the accuracy guard (see README "Robustness").

    ``bound_tol``
        maximum admissible Lemma 3.1 bound on ``||A - A_E||_inf``.
    ``max_bandwidth``
        escalation ceiling for the fastsum bandwidth ``N``.
    ``direct_threshold``
        problem size at/below which the exact O(n^2) fallback is allowed
        when escalation runs out.
    ``n_probe_samples`` / ``seed``
        Monte-Carlo budget for the eps estimator (deterministic per seed).
    """

    bound_tol: float = 5e-2
    max_bandwidth: int = 256
    direct_threshold: int = 8192
    n_probe_samples: int = 2048
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class ProbeReport:
    """One probe of one operator: the Lemma 3.1 ingredients + bound."""

    n_bandwidth: int
    eta: float
    eps: float
    bound: float


@dataclasses.dataclass
class GuardReport:
    """Outcome of a guarded build: every attempt, and what was returned.

    ``fallback`` is ``"none"`` (a fastsum operator was returned) or
    ``"direct"`` (the exact dense-math fallback).  ``ok`` is False only
    when no attempt met the tolerance *and* the direct fallback was not
    admissible — the returned operator is then the best attempt and its
    bound is ``final.bound``.
    """

    attempts: list[ProbeReport]
    fallback: str
    ok: bool

    @property
    def final(self) -> ProbeReport:
        return self.attempts[-1]

    @property
    def escalations(self) -> int:
        return len(self.attempts) - 1


@dataclasses.dataclass
class DirectKernelOperator:
    """Exact O(n^2)-FLOP kernel-sum operator — the degradation-ladder floor.

    Duck-compatible with :class:`~repro.core.fastsum.FastsumOperator`'s
    matvec surface (``matvec`` / ``matvec_tilde`` / ``degrees`` /
    ``n_source``), backed by :func:`~repro.core.fastsum.direct_matvec_tiled`
    (O(n*tile) memory, never materializes W).  Its error is exactly zero:
    below ``GuardPolicy.direct_threshold`` the guard prefers slow-and-exact
    over fast-and-out-of-tolerance.
    """

    kernel: Kernel
    points: Array
    tile: int = 2048

    @property
    def n_source(self) -> int:
        return self.points.shape[0]

    @property
    def n_target(self) -> int:
        return self.n_source

    def matvec(self, x: Array, *, backend: str | None = None) -> Array:
        del backend  # dense path has no window backend
        return direct_matvec_tiled(self.kernel, self.points, x,
                                   tile=self.tile)

    def matvec_tilde(self, x: Array, *, backend: str | None = None) -> Array:
        del backend
        return self.matvec(x) + self.kernel.at_zero() * x

    def degrees(self) -> Array:
        return self.matvec(jnp.ones((self.n_source,), self.points.dtype))


def probe_fastsum(kernel: Kernel, points: Array, params: FastsumParams,
                  fastsum: FastsumOperator | None = None, *,
                  n_samples: int = 2048, seed: int = 0) -> ProbeReport:
    """Cheap a-posteriori probe of one operator (no dense W).

    One approximate-degree matvec gives ``eta`` (Eq. 3.5); the Monte-Carlo
    regularization-error sweep gives ``eps`` (Eq. 3.6).  O(n + n_samples).
    """
    if fastsum is None:
        fastsum = make_fastsum(kernel, points, params)
    deg = fastsum.degrees()
    if not bool(jnp.all(jnp.isfinite(deg))):
        # a poisoned operator cannot even report degrees: worst bound
        return ProbeReport(params.n_bandwidth, 0.0, float("inf"),
                           float("inf"))
    w_inf = max(float(jnp.max(deg)), float(jnp.finfo(deg.dtype).tiny))
    eta = max(float(jnp.min(deg)), 0.0) / w_inf
    _, rho, _ = scale_nodes(jnp.asarray(points), params.eps_b_eff)
    eps = estimate_epsilon(kernel.rescaled(float(rho)), fastsum,
                           points.shape[0], w_inf,
                           n_samples=n_samples, seed=seed)
    return ProbeReport(params.n_bandwidth, eta, eps,
                       lemma31_bound(eta, eps))


def guarded_fastsum(kernel: Kernel, points: Array, params: FastsumParams,
                    *, policy: GuardPolicy = GuardPolicy()):
    """Build a fastsum operator whose Lemma 3.1 bound meets the tolerance.

    Returns ``(operator, GuardReport)``.  Escalates ``N`` (doubling) while
    the bound exceeds ``policy.bound_tol``; degrades to
    :class:`DirectKernelOperator` below ``policy.direct_threshold`` when the
    ceiling is reached; past the threshold returns the best attempt with
    ``report.ok = False`` and a warning.
    """
    points = jnp.asarray(points)
    attempts: list[ProbeReport] = []
    p = params
    while True:
        op = make_fastsum(kernel, points, p)
        rep = probe_fastsum(kernel, points, p, op,
                            n_samples=policy.n_probe_samples,
                            seed=policy.seed)
        attempts.append(rep)
        if rep.bound <= policy.bound_tol:
            return op, GuardReport(attempts, "none", True)
        if 2 * p.n_bandwidth > policy.max_bandwidth:
            break
        p = dataclasses.replace(p, n_bandwidth=2 * p.n_bandwidth)
    if points.shape[0] <= policy.direct_threshold:
        return (DirectKernelOperator(kernel, points),
                GuardReport(attempts, "direct", True))
    warnings.warn(
        f"accuracy guard: Lemma 3.1 bound {attempts[-1].bound:.3g} exceeds "
        f"tol {policy.bound_tol:.3g} at the bandwidth ceiling "
        f"N={attempts[-1].n_bandwidth} and n={points.shape[0]} is above the "
        f"direct-fallback threshold; returning the best attempt UNGUARDED",
        RuntimeWarning, stacklevel=2)
    return op, GuardReport(attempts, "none", False)


def guarded_normalized_adjacency(kernel: Kernel, points: Array,
                                 params: FastsumParams, *,
                                 policy: GuardPolicy = GuardPolicy()):
    """Guarded Algorithm 3.2: normalized adjacency over a guarded operator.

    Returns ``(NormalizedAdjacencyOperator, GuardReport)`` — the adjacency
    is built over whichever operator (escalated fastsum or exact direct)
    the guard settled on; Lanczos/eigsh consumers read the report to know
    the error budget their Ritz values inherit.
    """
    op, report = guarded_fastsum(kernel, points, params, policy=policy)
    return _normalized_adjacency_from(op), report
