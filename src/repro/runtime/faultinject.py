"""Deterministic fault injection for the solve + serve paths.

Chaos testing needs faults that are *seeded and reproducible* — a flaky
injector makes a flaky test.  Everything here is deterministic given its
arguments:

* solver-side injectors wrap a matvec so chosen columns (or bank members)
  always emit NaN — the execution shape of a poisoned spectral multiplier,
  whose every matvec is non-finite.  (Injectors must be trace-safe: a
  ``lax.while_loop`` body executes compiled, so Python-side call counting
  cannot gate a fault per iteration; data-independent poisoning can.)

* serving-side injectors mutate a :class:`~repro.serving.graph.
  GraphModelRegistry` white-box style (NaN-poisoned cached grids, corrupted
  prediction plans), and :class:`TickChaos` schedules drops / delays /
  poisonings per engine tick via the ``GraphServeEngine(chaos=...)`` hook.

The chaos test suite (``pytest -m chaos``) drives the engine and the bank
solvers through these and asserts recovery, isolation, and counters.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Mapping

import jax
import jax.numpy as jnp
import numpy as np

from repro.training.fault_tolerance import InjectedFault

Array = jax.Array


class Preemption(InjectedFault):
    """A simulated process kill (SIGKILL/preemption) at a host sync point."""


@dataclasses.dataclass
class KillPoint:
    """Deterministic preemption injector for the durable drivers.

    Passed as ``fault_hook`` to :func:`repro.runtime.durable.resumable_solve`
    / :func:`~repro.runtime.durable.resumable_eigsh` (or
    :func:`repro.training.fault_tolerance.run_resilient`): raises
    :class:`Preemption` the first ``kills`` times the driver's iteration
    counter reaches ``at_iteration``.  Because the hook fires at segment
    boundaries — the host sync points where a real kill would lose in-flight
    work — the driver loses exactly the un-snapshotted tail, the scenario
    the resume contract must survive.
    """

    at_iteration: int
    kills: int = 1
    fired: int = 0

    def __call__(self, i: int) -> None:
        if self.fired < self.kills and i >= self.at_iteration:
            self.fired += 1
            raise Preemption(
                f"injected preemption at iteration {i} "
                f"(kill {self.fired}/{self.kills})")


@dataclasses.dataclass
class KillSchedule:
    """Multiple kill-points in one run (a preemption storm).

    ``at_iterations`` is consumed in order: each entry fires once, when the
    driver's counter first reaches it.
    """

    at_iterations: tuple
    next_idx: int = 0

    def __call__(self, i: int) -> None:
        if (self.next_idx < len(self.at_iterations)
                and i >= self.at_iterations[self.next_idx]):
            self.next_idx += 1
            raise Preemption(
                f"injected preemption at iteration {i} "
                f"(kill {self.next_idx}/{len(self.at_iterations)})")


# ---------------------------------------------------------------------------
# Solver-side injectors
# ---------------------------------------------------------------------------

def poison_columns(matvec: Callable, columns) -> Callable:
    """Wrap an (n, C) -> (n, C) matvec so ``columns`` always emit NaN.

    Models a poisoned per-column operator in a lockstep solve; the guarded
    solvers must quarantine exactly these columns (``health.nonfinite``)
    while the siblings converge untouched.
    """
    cols = jnp.asarray(tuple(columns), jnp.int32)

    def wrapped(x):
        y = matvec(x)
        return y.at[:, cols].set(jnp.nan)

    return wrapped


def poison_bank_member(bank_matvec: Callable, members) -> Callable:
    """Wrap an (S, n, C) -> (S, n, C) bank matvec so ``members`` emit NaN.

    One bad tenant's operator in an ``cg_bank``/``minres_bank`` sweep: all
    its columns must be quarantined without touching sibling systems.
    """
    mem = jnp.asarray(tuple(members), jnp.int32)

    def wrapped(xb):
        yb = bank_matvec(xb)
        return yb.at[mem].set(jnp.nan)

    return wrapped


@dataclasses.dataclass
class SlowMatvec:
    """Host-side matvec delay + call counter (straggler injection)."""

    inner: Callable
    delay_s: float = 0.0
    calls: int = 0

    def __call__(self, x):
        self.calls += 1
        if self.delay_s:
            time.sleep(self.delay_s)
        return self.inner(x)


# ---------------------------------------------------------------------------
# Grid / plan injectors (serving registry, white-box)
# ---------------------------------------------------------------------------

def nan_poison_grid(grid: Array, *, frac: float = 0.02,
                    seed: int = 0) -> Array:
    """NaN a seeded random subset of grid entries (memory-corruption model)."""
    rng = np.random.default_rng(seed)
    mask = jnp.asarray(rng.random(grid.shape) < frac)
    return jnp.where(mask, jnp.nan, grid)


def poison_registry_grids(registry, model_id: str, *, frac: float = 0.02,
                          seed: int = 0) -> int:
    """NaN-poison every cached transformed grid of ``model_id`` in place.

    Returns the number of grids poisoned.  The engine's non-finite output
    guard must fail affected requests, trip the model's circuit breaker,
    and invalidate the poisoned grids so later requests rebuild clean ones
    from the (uncorrupted) dual vectors.
    """
    group = registry.group_of(model_id)
    if group is None:
        return 0
    poisoned = 0
    with registry._lock:
        for key in list(group.grids):
            if key[0] == model_id:
                group.grids[key] = nan_poison_grid(
                    group.grids[key], frac=frac, seed=seed + poisoned)
                poisoned += 1
    return poisoned


def corrupt_group_plan(registry, model_id: str, *,
                       shift_by: float = 10.0) -> bool:
    """Corrupt ``model_id``'s frozen PredictionPlan in place.

    Translates the plan's ``shift`` AND its scaled source set out of the
    admissible ball — the memory-corruption model for the plan object.  The
    corruption is *detectable*: the plan's own sources violate the
    admissibility invariant, which the engine checks when an admission
    starts failing, and recoverable: ``registry.rebuild_group`` rebuilds
    the plan from the registered models.
    """
    group = registry.group_of(model_id)
    if group is None:
        return False
    with registry._lock:
        pred = group.pred
        bad_src = pred.scaled_src + 2.0 * pred.radius
        group.pred = dataclasses.replace(
            pred, shift=pred.shift + shift_by, scaled_src=bad_src)
    return True


# ---------------------------------------------------------------------------
# Engine tick chaos
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TickChaos:
    """Seeded per-tick fault schedule for ``GraphServeEngine(chaos=...)``.

    The engine calls :meth:`apply` at the top of every tick; a True return
    drops the tick entirely (requests wait — recovery is later ticks plus
    deadline eviction).  ``slow_ticks`` injects host-side delay;
    ``poison_grids`` / ``corrupt_plans`` fire the registry injectors above
    at the scheduled tick.
    """

    drop_ticks: frozenset = frozenset()
    slow_ticks: Mapping[int, float] = dataclasses.field(default_factory=dict)
    poison_grids: Mapping[int, str] = dataclasses.field(default_factory=dict)
    corrupt_plans: Mapping[int, str] = dataclasses.field(default_factory=dict)
    seed: int = 0

    def apply(self, engine, tick: int) -> bool:
        delay = self.slow_ticks.get(tick)
        if delay:
            time.sleep(delay)
        model_id = self.poison_grids.get(tick)
        if model_id is not None:
            poison_registry_grids(engine.registry, model_id, seed=self.seed)
        model_id = self.corrupt_plans.get(tick)
        if model_id is not None:
            corrupt_group_plan(engine.registry, model_id)
        return tick in self.drop_ticks


def chaos_schedule(seed: int, *, ticks: int, models=(),
                   p_drop: float = 0.05, p_slow: float = 0.05,
                   slow_s: float = 0.002, p_poison: float = 0.0) -> TickChaos:
    """A seeded random TickChaos over ``ticks`` engine ticks."""
    rng = np.random.default_rng(seed)
    drops, slows, poisons = set(), {}, {}
    for t in range(ticks):
        r = rng.random()
        if r < p_drop:
            drops.add(t)
        elif r < p_drop + p_slow:
            slows[t] = slow_s
        elif models and r < p_drop + p_slow + p_poison:
            poisons[t] = models[int(rng.integers(len(models)))]
    return TickChaos(drop_ticks=frozenset(drops), slow_ticks=slows,
                     poison_grids=poisons, seed=seed)
