"""Attention layers: GQA/MQA/MHA softmax attention and DeepSeek-style MLA.

Full-sequence paths are einsum-based (XLA) with an optional Pallas flash
path (``use_flash``) for real TPUs; decode paths operate on a static-shape
KV cache with position masking.

MLA (multi-head latent attention): training/prefill uses the expanded form;
decode uses the *absorbed* form operating directly on the compressed
(c_kv, k_rope) cache — the cache stores only kv_lora_rank + rope_dim floats
per position.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import (
    BATCH_AXES, MODEL_AXIS, apply_rope, dense_init, rms_norm, shard,
)

Array = jax.Array


# ---------------------------------------------------------------------------
# Standard GQA attention
# ---------------------------------------------------------------------------

def init_attention(key: Array, cfg: ArchConfig) -> dict:
    d, h, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_eff
    ks = jax.random.split(key, 4)
    params = {
        "wq": dense_init(ks[0], (d, h * hd), cfg.pdtype),
        "wk": dense_init(ks[1], (d, hkv * hd), cfg.pdtype),
        "wv": dense_init(ks[2], (d, hkv * hd), cfg.pdtype),
        "wo": dense_init(ks[3], (h * hd, d), cfg.pdtype),
    }
    if cfg.qkv_bias:
        params["bq"] = jnp.zeros((h * hd,), cfg.pdtype)
        params["bk"] = jnp.zeros((hkv * hd,), cfg.pdtype)
        params["bv"] = jnp.zeros((hkv * hd,), cfg.pdtype)
    return params


def _project_qkv(params: dict, x: Array, cfg: ArchConfig):
    b, s, _ = x.shape
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_eff
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, hkv, hd)
    v = v.reshape(b, s, hkv, hd)
    return q, k, v


def attention_forward(params: dict, x: Array, positions: Array,
                      cfg: ArchConfig, *, use_flash: bool = False,
                      prefix_len: int = 0) -> Array:
    """Full-sequence attention.  x: (b, s, d); positions: (b, s).

    ``prefix_len > 0`` relaxes the causal mask to prefix-LM semantics: every
    query may attend to all keys with position < prefix_len (PaliGemma's
    bidirectional image prefix).
    """
    b, s, _ = x.shape
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_eff
    group = h // hkv
    q, k, v = _project_qkv(params, x, cfg)
    q = apply_rope(q.transpose(0, 2, 1, 3), positions[:, None, :], cfg.rope_theta)
    k = apply_rope(k.transpose(0, 2, 1, 3), positions[:, None, :], cfg.rope_theta)
    v = v.transpose(0, 2, 1, 3)
    q = shard(q, BATCH_AXES, MODEL_AXIS, None, None)
    k = shard(k, BATCH_AXES, MODEL_AXIS, None, None)

    if use_flash and prefix_len == 0:
        from repro.kernels import ops
        ctx = ops.flash_attention(q, k, v, causal=cfg.causal)
    else:
        qg = q.reshape(b, hkv, group, s, hd)
        logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k) / (hd ** 0.5)
        logits = logits.astype(jnp.float32)
        if cfg.causal:
            mask = positions[:, None, None, None, :] <= positions[:, None, None, :, None]
            if prefix_len > 0:
                mask = mask | (positions[:, None, None, None, :] < prefix_len)
            logits = jnp.where(mask, logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
        ctx = jnp.einsum("bhgqk,bhkd->bhgqd", probs, v).reshape(b, h, s, hd)

    ctx = ctx.transpose(0, 2, 1, 3).reshape(b, s, h * hd)
    return ctx @ params["wo"]


class KVCache(NamedTuple):
    k: Array  # (b, s_max, hkv, hd)
    v: Array  # (b, s_max, hkv, hd)


def init_kv_cache(cfg: ArchConfig, batch: int, max_seq: int) -> KVCache:
    hkv, hd = cfg.num_kv_heads, cfg.head_dim_eff
    shape = (batch, max_seq, hkv, hd)
    return KVCache(k=jnp.zeros(shape, cfg.dtype), v=jnp.zeros(shape, cfg.dtype))


def attention_prefill(params: dict, x: Array, positions: Array,
                      cfg: ArchConfig, cache: KVCache,
                      *, use_flash: bool = False,
                      prefix_len: int = 0) -> tuple[Array, KVCache]:
    """Full-seq attention that also fills the cache prefix [0, s)."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(params, x, cfg)
    k_rot = apply_rope(k.transpose(0, 2, 1, 3), positions[:, None, :],
                       cfg.rope_theta).transpose(0, 2, 1, 3)
    new_cache = KVCache(
        k=jax.lax.dynamic_update_slice_in_dim(cache.k, k_rot.astype(cache.k.dtype), 0, axis=1),
        v=jax.lax.dynamic_update_slice_in_dim(cache.v, v.astype(cache.v.dtype), 0, axis=1),
    )
    out = attention_forward(params, x, positions, cfg, use_flash=use_flash,
                            prefix_len=prefix_len)
    return out, new_cache


def attention_decode(params: dict, x: Array, pos: Array, cfg: ArchConfig,
                     cache: KVCache) -> tuple[Array, KVCache]:
    """One-token decode.  x: (b, 1, d); pos: (b,) current positions."""
    b = x.shape[0]
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_eff
    group = h // hkv
    q, k, v = _project_qkv(params, x, cfg)
    q = apply_rope(q.transpose(0, 2, 1, 3), pos[:, None, None], cfg.rope_theta)
    k = apply_rope(k.transpose(0, 2, 1, 3), pos[:, None, None], cfg.rope_theta)

    # write k/v at position pos (batched scatter along seq axis)
    k_new = k.transpose(0, 2, 1, 3)  # (b, 1, hkv, hd)
    v_new = v
    idx = pos[:, None]  # (b, 1)
    cache_k = _scatter_seq(cache.k, k_new.astype(cache.k.dtype), idx)
    cache_v = _scatter_seq(cache.v, v_new.astype(cache.v.dtype), idx)
    cache = KVCache(k=cache_k, v=cache_v)

    # attend over the cache with position masking
    kk = cache.k.transpose(0, 2, 1, 3)  # (b, hkv, s_max, hd)
    vv = cache.v.transpose(0, 2, 1, 3)
    kk = shard(kk, BATCH_AXES, None, MODEL_AXIS, None)
    vv = shard(vv, BATCH_AXES, None, MODEL_AXIS, None)
    qg = q.reshape(b, hkv, group, 1, hd)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kk) / (hd ** 0.5)
    logits = logits.astype(jnp.float32)
    s_max = cache.k.shape[1]
    valid = jnp.arange(s_max)[None, :] <= pos[:, None]  # (b, s_max)
    logits = jnp.where(valid[:, None, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(vv.dtype)
    ctx = jnp.einsum("bhgqk,bhkd->bhgqd", probs, vv)
    ctx = ctx.reshape(b, h, 1, hd).transpose(0, 2, 1, 3).reshape(b, 1, h * hd)
    return ctx @ params["wo"], cache


def _scatter_seq(cache: Array, new: Array, idx: Array) -> Array:
    """Write new (b, 1, ...) into cache (b, s, ...) at per-batch index."""
    b = cache.shape[0]
    onehot = (jnp.arange(cache.shape[1])[None, :] == idx).astype(cache.dtype)
    # (b, s, 1, 1) * (b, 1, ...) broadcast — avoids gather/scatter lowering
    expand = onehot.reshape(b, cache.shape[1], *([1] * (cache.ndim - 2)))
    return cache * (1 - expand) + expand * new


# ---------------------------------------------------------------------------
# MLA (DeepSeek multi-head latent attention)
# ---------------------------------------------------------------------------

def init_mla(key: Array, cfg: ArchConfig) -> dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq_a": dense_init(ks[0], (d, m.q_lora_rank), cfg.pdtype),
        "q_norm": jnp.zeros((m.q_lora_rank,), cfg.pdtype),
        "wq_b": dense_init(ks[1], (m.q_lora_rank, h * qk_dim), cfg.pdtype),
        "wkv_a": dense_init(ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim),
                            cfg.pdtype),
        "kv_norm": jnp.zeros((m.kv_lora_rank,), cfg.pdtype),
        "wkv_b": dense_init(ks[3], (m.kv_lora_rank,
                                    h * (m.qk_nope_head_dim + m.v_head_dim)),
                            cfg.pdtype),
        "wo": dense_init(ks[4], (h * m.v_head_dim, d), cfg.pdtype),
    }


def _mla_q(params, x, positions, cfg):
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.num_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    q_c = rms_norm(x @ params["wq_a"], params["q_norm"], cfg.norm_eps)
    q = (q_c @ params["wq_b"]).reshape(b, s, h, qk_dim)
    q_nope = q[..., :m.qk_nope_head_dim]
    q_rope = apply_rope(q[..., m.qk_nope_head_dim:].transpose(0, 2, 1, 3),
                        positions[:, None, :], cfg.rope_theta).transpose(0, 2, 1, 3)
    return q_nope, q_rope


def _mla_kv_latent(params, x, positions, cfg):
    m = cfg.mla
    kv_all = x @ params["wkv_a"]
    c_kv = rms_norm(kv_all[..., :m.kv_lora_rank], params["kv_norm"], cfg.norm_eps)
    k_rope = kv_all[..., m.kv_lora_rank:]  # (b, s, rope_dim), shared heads
    k_rope = apply_rope(k_rope[:, None], positions[:, None, :],
                        cfg.rope_theta)[:, 0]
    return c_kv, k_rope


def mla_forward(params: dict, x: Array, positions: Array,
                cfg: ArchConfig) -> Array:
    """Expanded MLA for train/prefill."""
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.num_heads
    q_nope, q_rope = _mla_q(params, x, positions, cfg)
    c_kv, k_rope = _mla_kv_latent(params, x, positions, cfg)
    kv = (c_kv @ params["wkv_b"]).reshape(
        b, s, h, m.qk_nope_head_dim + m.v_head_dim)
    k_nope, v = kv[..., :m.qk_nope_head_dim], kv[..., m.qk_nope_head_dim:]

    scale = 1.0 / ((m.qk_nope_head_dim + m.qk_rope_head_dim) ** 0.5)
    logits = (jnp.einsum("bqhd,bkhd->bhqk", q_nope, k_nope)
              + jnp.einsum("bqhd,bkd->bhqk", q_rope, k_rope)) * scale
    logits = logits.astype(jnp.float32)
    if cfg.causal:
        mask = positions[:, None, None, :] <= positions[:, None, :, None]
        logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    ctx = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    return ctx.reshape(b, s, h * m.v_head_dim) @ params["wo"]


class MLACache(NamedTuple):
    c_kv: Array  # (b, s_max, kv_lora_rank)
    k_rope: Array  # (b, s_max, rope_dim)


def init_mla_cache(cfg: ArchConfig, batch: int, max_seq: int) -> MLACache:
    m = cfg.mla
    return MLACache(
        c_kv=jnp.zeros((batch, max_seq, m.kv_lora_rank), cfg.dtype),
        k_rope=jnp.zeros((batch, max_seq, m.qk_rope_head_dim), cfg.dtype))


def mla_prefill(params: dict, x: Array, positions: Array, cfg: ArchConfig,
                cache: MLACache) -> tuple[Array, MLACache]:
    c_kv, k_rope = _mla_kv_latent(params, x, positions, cfg)
    cache = MLACache(
        c_kv=jax.lax.dynamic_update_slice_in_dim(
            cache.c_kv, c_kv.astype(cache.c_kv.dtype), 0, axis=1),
        k_rope=jax.lax.dynamic_update_slice_in_dim(
            cache.k_rope, k_rope.astype(cache.k_rope.dtype), 0, axis=1))
    return mla_forward(params, x, positions, cfg), cache


def mla_decode(params: dict, x: Array, pos: Array, cfg: ArchConfig,
               cache: MLACache) -> tuple[Array, MLACache]:
    """Absorbed-form decode on the compressed cache."""
    m = cfg.mla
    b = x.shape[0]
    h = cfg.num_heads
    q_nope, q_rope = _mla_q(params, x, pos[:, None], cfg)  # (b,1,h,*)
    c_new, kr_new = _mla_kv_latent(params, x, pos[:, None], cfg)
    idx = pos[:, None]
    cache = MLACache(
        c_kv=_scatter_seq(cache.c_kv, c_new.astype(cache.c_kv.dtype), idx),
        k_rope=_scatter_seq(cache.k_rope, kr_new.astype(cache.k_rope.dtype), idx))

    wkv_b = params["wkv_b"].reshape(m.kv_lora_rank, h,
                                    m.qk_nope_head_dim + m.v_head_dim)
    w_uk = wkv_b[..., :m.qk_nope_head_dim]  # (c, h, nope)
    w_uv = wkv_b[..., m.qk_nope_head_dim:]  # (c, h, v)

    q_lat = jnp.einsum("bqhn,chn->bqhc", q_nope, w_uk)  # absorb W_UK
    scale = 1.0 / ((m.qk_nope_head_dim + m.qk_rope_head_dim) ** 0.5)
    logits = (jnp.einsum("bqhc,bsc->bhqs", q_lat, cache.c_kv)
              + jnp.einsum("bqhd,bsd->bhqs", q_rope, cache.k_rope)) * scale
    logits = logits.astype(jnp.float32)
    s_max = cache.c_kv.shape[1]
    valid = jnp.arange(s_max)[None, :] <= pos[:, None]
    logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(cache.c_kv.dtype)
    ctx_lat = jnp.einsum("bhqs,bsc->bqhc", probs, cache.c_kv)
    ctx = jnp.einsum("bqhc,chv->bqhv", ctx_lat, w_uv)
    return ctx.reshape(b, 1, h * m.v_head_dim) @ params["wo"], cache
