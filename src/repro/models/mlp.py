"""Feed-forward layers: gated MLPs (SwiGLU/GeGLU/GELU) and einsum MoE.

MoE uses the GShard/Switch capacity-based dispatch: softmax router -> top-k
-> per-expert capacity C -> one-hot dispatch/combine einsums.  Experts are
sharded over the ``model`` mesh axis (EP); the dispatch einsum generates the
all-to-all on that axis under GSPMD.  A load-balancing auxiliary loss is
returned alongside the output.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MoEConfig
from repro.models.common import BATCH_AXES, MODEL_AXIS, dense_init, shard

Array = jax.Array


# ---------------------------------------------------------------------------
# Dense MLP
# ---------------------------------------------------------------------------

def init_mlp(key: Array, d_model: int, d_ff: int, activation: str,
             dtype) -> dict:
    ks = jax.random.split(key, 3)
    gated = activation in ("silu", "geglu")
    params = {
        "w_up": dense_init(ks[0], (d_model, d_ff), dtype),
        "w_down": dense_init(ks[1], (d_ff, d_model), dtype),
    }
    if gated:
        params["w_gate"] = dense_init(ks[2], (d_model, d_ff), dtype)
    return params


def mlp_forward(params: dict, x: Array, activation: str) -> Array:
    up = x @ params["w_up"]
    if activation == "silu":
        h = jax.nn.silu(x @ params["w_gate"]) * up
    elif activation == "geglu":
        h = jax.nn.gelu(x @ params["w_gate"]) * up
    else:
        h = jax.nn.gelu(up)
    if h.ndim == 3:
        h = shard(h, BATCH_AXES, None, MODEL_AXIS)
    else:  # (tokens, ff) — MoE shared-expert path
        h = shard(h, BATCH_AXES, MODEL_AXIS)
    return h @ params["w_down"]


# ---------------------------------------------------------------------------
# Mixture of Experts
# ---------------------------------------------------------------------------

def init_moe(key: Array, cfg: ArchConfig) -> dict:
    moe = cfg.moe
    d, e, f = cfg.d_model, moe.num_experts, moe.d_ff_expert
    ks = jax.random.split(key, 5)
    gated = cfg.activation in ("silu", "geglu")
    params = {
        "router": dense_init(ks[0], (d, e), jnp.float32),
        "w_up": dense_init(ks[1], (e, d, f), cfg.pdtype),
        "w_down": dense_init(ks[2], (e, f, d), cfg.pdtype),
    }
    if gated:
        params["w_gate"] = dense_init(ks[3], (e, d, f), cfg.pdtype)
    if moe.num_shared_experts > 0:
        params["shared"] = init_mlp(ks[4], d,
                                    moe.num_shared_experts * f,
                                    cfg.activation, cfg.pdtype)
    return params


def moe_forward(params: dict, x: Array, cfg: ArchConfig) -> tuple[Array, Array]:
    """Returns (output, aux_loss).  x: (b, s, d).

    Routing is gather/scatter-based (sort-free capacity assignment): each
    (token, choice) gets a slot ``top_idx * capacity + pos_in_expert``; the
    expert input buffer (e, c, d) is built with one scatter of token rows
    and results come back with one gather.  Unlike the GShard one-hot
    dispatch einsum (2*t*e*c*d FLOPs — 1600x the expert compute for
    DeepSeek's e=256), routing costs O(t*k*d) memory traffic and no MXU
    time.  Under GSPMD the scatter/gather across the EP (model) axis lowers
    to all-to-all — the communication pattern real MoE deployments use.
    """
    moe = cfg.moe
    b, s, d = x.shape
    e, k = moe.num_experts, moe.top_k
    t = b * s
    xt = x.reshape(t, d)

    logits = (xt.astype(jnp.float32) @ params["router"])  # (t, e)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_idx = jax.lax.top_k(probs, k)  # (t, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch): e * sum_e f_e * P_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        (jax.nn.one_hot(top_idx, e).sum(1)).astype(jnp.float32), axis=0) / k
    aux = e * jnp.sum(me * ce)

    # ---- grouped routing (perf iteration 1, EXPERIMENTS.md §Perf) --------
    # Tokens are split into G groups aligned with the data shards; capacity
    # is per (group, expert).  All scatter/gather runs group-LOCALLY (both
    # sides share the group sharding, so GSPMD keeps it on-chip), and the
    # only cross-device movement is the (G, e, c_g, d) buffer resharding
    # from group-sharded to expert-sharded — a single all-to-all.  The
    # naive global scatter instead lowered to full-buffer all-reduces
    # (2.3 GB x 58 layers for deepseek-v3: the dominant baseline cost).
    # G must MATCH the active mesh's pod*data extent: a 16-group buffer on a
    # 32-shard multi-pod mesh gets padded 2x by GSPMD and the reshard
    # degenerates (measured 7.6x collective blowup on deepseek 2x16x16).
    from repro.models.common import current_mesh
    mesh = current_mesh()
    if mesh is not None:
        fsdp = 1
        for ax in ("pod", "data"):
            fsdp *= mesh.shape.get(ax, 1)
        groups = fsdp
    else:
        groups = moe.token_groups
    while t % groups != 0:  # smoke configs with tiny t
        groups //= 2
    tg = t // groups
    xg = xt.reshape(groups, tg, d)
    xg = shard(xg, ("pod", "data"), None, None)
    top_idx_g = top_idx.reshape(groups, tg, k)
    top_p_g = top_p.reshape(groups, tg, k)

    capacity = max(1, int(moe.capacity_factor * tg * k / e))
    choice_one_hot = jax.nn.one_hot(top_idx_g, e, dtype=jnp.int32)  # (g,t,k,e)
    flat = choice_one_hot.reshape(groups, tg * k, e)
    pos_in_expert = jnp.cumsum(flat, axis=1) - flat  # exclusive, per group
    pos = (pos_in_expert * flat).sum(-1).reshape(groups, tg, k)
    within = pos < capacity

    slot = top_idx_g * capacity + jnp.minimum(pos, capacity - 1)
    # dropped rows scatter OUT OF BOUNDS with mode='drop'; the surviving
    # indices are unique by construction (expert*capacity + position), so
    # unique_indices=True holds and XLA emits one plain scatter instead of
    # the (u32 index-race + f32) companion pair the duplicate-tolerant
    # lowering needs — halving dispatch HBM traffic (deepseek train_4k).
    slot = jnp.where(within, slot, e * capacity)  # e*capacity = OOB
    src = jnp.broadcast_to(jnp.arange(tg)[None, :, None], (groups, tg, k))

    def scatter_group(x_g, slot_g, src_g):
        buf = jnp.zeros((e * capacity, d), x_g.dtype)
        return buf.at[slot_g.reshape(-1)].set(
            x_g[src_g.reshape(-1)], unique_indices=True, mode="drop")

    expert_in = jax.vmap(scatter_group)(xg, slot, src)  # (g, e*c, d)
    expert_in = expert_in.reshape(groups, e, capacity, d)
    expert_in = shard(expert_in, ("pod", "data"), None, None, None)
    # reshard: group-sharded -> expert-sharded (the MoE all-to-all).
    # IMPORTANT: annotate the transposed 4-D buffer BEFORE merging (g, c) —
    # resharding dim0->dim1 of an intact transpose is GSPMD's all-to-all
    # pattern; reshaping first degrades it to a full-buffer all-gather
    # (measured 1.1e12 B/device per layer in the deepseek baseline).
    expert_in = expert_in.transpose(1, 0, 2, 3)  # (e, g, c, d)
    # dual sharding: e over model AND g stays on the data shards — slicing a
    # replicated-on-model dim to model-sharded is free, so this reshard
    # moves nothing; the expert GEMM batches over the (g, c) slice locally.
    expert_in = shard(expert_in, MODEL_AXIS, ("pod", "data"), None, None)
    expert_in = expert_in.reshape(e, groups * capacity, d)
    expert_in = shard(expert_in, MODEL_AXIS, ("pod", "data"), None)

    up = jnp.einsum("ecd,edf->ecf", expert_in, params["w_up"])
    if cfg.activation in ("silu", "geglu"):
        act = jax.nn.silu if cfg.activation == "silu" else jax.nn.gelu
        gate = jnp.einsum("ecd,edf->ecf", expert_in, params["w_gate"])
        h = act(gate) * up
    else:
        h = jax.nn.gelu(up)
    expert_out = jnp.einsum("ecf,efd->ecd", h, params["w_down"])
    expert_out = shard(expert_out, MODEL_AXIS, ("pod", "data"), None)

    # reshard back: the e dim must be gathered per group owner (all-gather
    # over model — the minimal output movement, ~1.25x the t*k*d rows the
    # combine actually reads) and g stays data-sharded throughout.
    back = expert_out.reshape(e, groups, capacity, d)
    back = shard(back, MODEL_AXIS, ("pod", "data"), None, None)
    back = back.transpose(1, 0, 2, 3)  # (g, e, c, d)
    back = shard(back, ("pod", "data"), None, None, None)
    back = back.reshape(groups, e * capacity, d)
    back = shard(back, ("pod", "data"), None, None)

    def gather_group(buf_g, slot_g):
        idx = jnp.minimum(slot_g, e * capacity - 1)  # overflow -> masked out
        return buf_g[idx]  # (tg, k, d); gate_w zeroes dropped rows

    rows = jax.vmap(gather_group)(back, slot)  # (g, tg, k, d)
    gate_w = (top_p_g * within.astype(top_p_g.dtype)).astype(rows.dtype)
    out = jnp.einsum("gtkd,gtk->gtd", rows, gate_w).reshape(t, d)

    if moe.num_shared_experts > 0:
        from repro.models.mlp import mlp_forward  # self-import for clarity
        out = out + mlp_forward(params["shared"], xt, cfg.activation)

    return out.reshape(b, s, d).astype(x.dtype), aux.astype(jnp.float32)
