"""Mamba2 (state-space duality) block — chunked SSD for train/prefill,
O(1)-state recurrence for decode.

Layout follows the Mamba2 reference: input projections produce
[z | x | B | C | dt]; a short causal conv over x and (B,C); SSD with per-head
scalar decay A and per-head skip D; gated RMSNorm; output projection.

Sharding note (perf iteration 2, EXPERIMENTS.md §Perf): the reference packs
[x|B|C] into ONE input projection and slices afterwards.  With the projection
output sharded over the `model` axis, those slices cross shard boundaries
and GSPMD materializes state-sized all-gathers/all-reduces (the dominant
collective in the mamba2 prefill_32k baseline).  Here x/z/dt project through
model-sharded matrices while the tiny B/C projection (2*n_groups*d_state
columns) is replicated — every slice is then local, and the SSD einsums
contract within a head shard.

The chunked SSD computes, per chunk of length Q:
  * intra-chunk: causal (C_q . B_k) pairs weighted by decay segments,
  * chunk states: S = sum_k decay_to_end(k) * B_k x_k^T,
  * inter-chunk: sequential scan over chunk states with chunk-level decay,
  * output: Y = intra + C . carried_state (+ D * x).

Decode recurrence per token: h = exp(dt*A) h + dt * B x ;  y = C.h + D*x,
with a rolling conv-state buffer of width d_conv-1.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import (
    BATCH_AXES, MODEL_AXIS, dense_init, rms_norm, shard,
)

Array = jax.Array


def _dims(cfg: ArchConfig):
    mc = cfg.mamba
    d_in = mc.expand * cfg.d_model
    n_heads = d_in // mc.head_dim
    bc_dim = 2 * mc.n_groups * mc.d_state
    return mc, d_in, n_heads, bc_dim


def init_mamba(key: Array, cfg: ArchConfig) -> dict:
    mc, d_in, n_heads, bc_dim = _dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 7)
    return {
        "w_x": dense_init(ks[0], (d, d_in), cfg.pdtype),
        "w_z": dense_init(ks[1], (d, d_in), cfg.pdtype),
        "w_bc": dense_init(ks[2], (d, bc_dim), cfg.pdtype),
        "w_dt": dense_init(ks[3], (d, n_heads), cfg.pdtype),
        "conv_x_w": dense_init(ks[4], (mc.d_conv, d_in), cfg.pdtype),
        "conv_x_b": jnp.zeros((d_in,), cfg.pdtype),
        "conv_bc_w": dense_init(ks[5], (mc.d_conv, bc_dim), cfg.pdtype),
        "conv_bc_b": jnp.zeros((bc_dim,), cfg.pdtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)).astype(jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "out_norm": jnp.zeros((d_in,), cfg.pdtype),
        "w_out": dense_init(ks[6], (d_in, d), cfg.pdtype),
    }


def _project(params, u, cfg):
    """u: (b, s, d) -> x (model-sharded), z, bc (replicated), dt."""
    x = u @ params["w_x"]
    z = u @ params["w_z"]
    bc = u @ params["w_bc"]
    dt = u @ params["w_dt"]
    x = shard(x, BATCH_AXES, None, MODEL_AXIS)
    z = shard(z, BATCH_AXES, None, MODEL_AXIS)
    return x, z, bc, dt


def _causal_conv(x: Array, w: Array, b: Array, d_conv: int) -> Array:
    """Depthwise causal conv over sequence.  x: (b, s, c); w: (d_conv, c)."""
    pad = d_conv - 1
    xp = jnp.pad(x, ((0, 0), (pad, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(d_conv))
    return jax.nn.silu(out + b)


def _ssd_chunked(x, dt, a, b_mat, c_mat, d_skip, chunk):
    """Chunked SSD.  x: (b, s, h, p); dt: (b, s, h); a: (h,) (negative);
    b_mat/c_mat: (b, s, g, n); heads h grouped into g groups."""
    bsz, s, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    heads_per_group = h // g

    # broadcast groups to heads
    bh = jnp.repeat(b_mat, heads_per_group, axis=2)  # (b, s, h, n)
    ch = jnp.repeat(c_mat, heads_per_group, axis=2)

    x = x.reshape(bsz, nc, chunk, h, p)
    dt = dt.reshape(bsz, nc, chunk, h)
    bh = bh.reshape(bsz, nc, chunk, h, n)
    ch = ch.reshape(bsz, nc, chunk, h, n)

    da = dt * a[None, None, None, :]  # (b, nc, q, h) negative decay exps
    cum = jnp.cumsum(da, axis=2)  # inclusive within chunk

    # intra-chunk: L[q, k] = exp(cum[q] - cum[k]) for q >= k.  Mask the
    # exponent BEFORE exp: for q < k the difference is positive and exp
    # overflows; a post-hoc where() would leak NaN into the backward pass.
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (b,nc,q,k,h)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    seg = jnp.where(causal[None, None, :, :, None], seg, -jnp.inf)
    l_mat = jnp.exp(seg)
    scores = jnp.einsum("bcqhn,bckhn->bcqkh", ch, bh) * l_mat
    xdt = x * dt[..., None]
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", scores, xdt)

    # chunk end-states: S_c = sum_k exp(cum[-1] - cum[k]) B_k (dt x)_k
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)  # (b,nc,q,h)
    states = jnp.einsum("bcqhn,bcqhp->bchnp", bh * decay_to_end[..., None], xdt)

    # inter-chunk scan: H_{c} = exp(sum da_c) H_{c-1} + S_c  (carry prefix)
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (b, nc, h)

    def scan_fn(carry, inp):
        s_c, dec = inp
        new = carry * dec[..., None, None] + s_c
        return new, carry  # emit the *previous* state (exclusive prefix)

    init = jnp.zeros_like(states[:, 0])
    _, prev_states = jax.lax.scan(
        scan_fn, init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (b,nc,h,n,p)

    # inter-chunk contribution: C_q . (decay_from_start(q) * H_prev)
    decay_from_start = jnp.exp(cum)  # (b,nc,q,h)
    y_inter = jnp.einsum("bcqhn,bchnp->bcqhp",
                         ch * decay_from_start[..., None], prev_states)

    y = (y_intra + y_inter).reshape(bsz, s, h, p)
    y = y + x.reshape(bsz, s, h, p) * d_skip[None, None, :, None]
    # final state (for prefill -> decode handoff)
    final_state = init * 0 + (prev_states[:, -1] * chunk_decay[:, -1][..., None, None]
                              + states[:, -1])
    return y, final_state


def mamba_forward(params: dict, u: Array, cfg: ArchConfig,
                  return_state: bool = False):
    """u: (b, s, d) -> (b, s, d) [, (conv_x_state, conv_bc_state, ssm)]."""
    mc, d_in, n_heads, bc_dim = _dims(cfg)
    bsz, s, _ = u.shape
    x_raw, z, bc_raw, dt = _project(params, u, cfg)
    x = _causal_conv(x_raw, params["conv_x_w"], params["conv_x_b"], mc.d_conv)
    bc = _causal_conv(bc_raw, params["conv_bc_w"], params["conv_bc_b"],
                      mc.d_conv)
    b_mat = bc[..., :mc.n_groups * mc.d_state]
    c_mat = bc[..., mc.n_groups * mc.d_state:]

    x = x.reshape(bsz, s, n_heads, mc.head_dim).astype(jnp.float32)
    b_mat = b_mat.reshape(bsz, s, mc.n_groups, mc.d_state).astype(jnp.float32)
    c_mat = c_mat.reshape(bsz, s, mc.n_groups, mc.d_state).astype(jnp.float32)
    dt_f = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["a_log"])  # (h,) negative

    chunk = min(mc.chunk_size, s)
    y, final_state = _ssd_chunked(x, dt_f, a, b_mat, c_mat, params["d_skip"],
                                  chunk)
    y = y.reshape(bsz, s, d_in).astype(u.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["out_norm"], cfg.norm_eps)
    out = y @ params["w_out"]
    if not return_state:
        return out
    keep = mc.d_conv - 1
    if keep > 0:
        conv_x_state = x_raw[:, -keep:, :]
        conv_bc_state = bc_raw[:, -keep:, :]
    else:  # pragma: no cover
        conv_x_state = jnp.zeros((bsz, 0, d_in), u.dtype)
        conv_bc_state = jnp.zeros((bsz, 0, bc_dim), u.dtype)
    return out, (conv_x_state.astype(jnp.float32),
                 conv_bc_state.astype(jnp.float32), final_state)


class MambaCache(NamedTuple):
    conv_x: Array  # (b, d_conv-1, d_in) rolling raw x projections
    conv_bc: Array  # (b, d_conv-1, 2*g*n) rolling raw B/C projections
    ssm: Array  # (b, h, n, p) state


def init_mamba_cache(cfg: ArchConfig, batch: int) -> MambaCache:
    mc, d_in, n_heads, bc_dim = _dims(cfg)
    return MambaCache(
        conv_x=jnp.zeros((batch, mc.d_conv - 1, d_in), jnp.float32),
        conv_bc=jnp.zeros((batch, mc.d_conv - 1, bc_dim), jnp.float32),
        ssm=jnp.zeros((batch, n_heads, mc.d_state, mc.head_dim), jnp.float32))


def mamba_decode(params: dict, u: Array, cfg: ArchConfig,
                 cache: MambaCache) -> tuple[Array, MambaCache]:
    """One-token recurrent step.  u: (b, 1, d)."""
    mc, d_in, n_heads, bc_dim = _dims(cfg)
    bsz = u.shape[0]
    x_raw, z, bc_raw, dt = _project(params, u, cfg)
    x_raw, z, bc_raw, dt = x_raw[:, 0], z[:, 0], bc_raw[:, 0], dt[:, 0]

    # conv step on rolling buffers
    def conv_step(cache_buf, new_col, w, b):
        window = jnp.concatenate(
            [cache_buf, new_col[:, None, :].astype(jnp.float32)], axis=1)
        out = jnp.einsum("btc,tc->bc", window, w.astype(jnp.float32))
        return jax.nn.silu(out + b.astype(jnp.float32)), window[:, 1:]

    x_act, new_conv_x = conv_step(cache.conv_x, x_raw,
                                  params["conv_x_w"], params["conv_x_b"])
    bc_act, new_conv_bc = conv_step(cache.conv_bc, bc_raw,
                                    params["conv_bc_w"], params["conv_bc_b"])

    x = x_act.reshape(bsz, n_heads, mc.head_dim)
    b_mat = bc_act[..., :mc.n_groups * mc.d_state].reshape(
        bsz, mc.n_groups, mc.d_state)
    c_mat = bc_act[..., mc.n_groups * mc.d_state:].reshape(
        bsz, mc.n_groups, mc.d_state)
    heads_per_group = n_heads // mc.n_groups
    bh = jnp.repeat(b_mat, heads_per_group, axis=1)  # (b, h, n)
    ch = jnp.repeat(c_mat, heads_per_group, axis=1)

    dt_f = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (b,h)
    a = -jnp.exp(params["a_log"])
    decay = jnp.exp(dt_f * a[None, :])  # (b, h)

    xdt = x * dt_f[..., None]  # (b, h, p)
    new_ssm = (cache.ssm * decay[..., None, None]
               + bh[..., None] * xdt[:, :, None, :])  # (b,h,n,p)
    y = jnp.einsum("bhn,bhnp->bhp", ch, new_ssm)
    y = y + x * params["d_skip"][None, :, None]
    y = y.reshape(bsz, d_in).astype(u.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["out_norm"], cfg.norm_eps)
    out = (y @ params["w_out"])[:, None, :]
    return out, MambaCache(conv_x=new_conv_x, conv_bc=new_conv_bc,
                           ssm=new_ssm)
