"""Full model assembly for all assigned architectures.

A model is a stack of pre-norm residual blocks.  Each block has

    mixer : 'attn' (GQA/MQA softmax), 'mla' (DeepSeek latent), 'mamba'
            (Mamba2 SSD), or 'nfft' (the paper's O(n) kernel attention)
    ffn   : 'dense' (SwiGLU/GeGLU/GELU), 'moe', or None (pure-SSM blocks)

Heterogeneous stacks (Jamba 1-attn:7-mamba with MoE-every-other, DeepSeek
3-dense-then-MoE) are handled by the *layer plan*: the layer-signature
sequence is split into a short explicit ``prefix`` and a repeating ``period``;
the periodic part runs under ``jax.lax.scan`` over period-stacked parameters
with one ``jax.checkpoint`` (remat) boundary per period.  This keeps the HLO
size proportional to the period (<= 8 blocks), not the depth (126 layers for
llama3-405b), which is what makes the 512-way dry-run compiles tractable.

Three entry points per architecture:

    forward_train   (tokens/embeds, labels)  -> (loss, metrics)
    forward_prefill (tokens/embeds, caches)  -> (logits_last, caches)
    forward_decode  (token, pos, caches)     -> (logits, caches)    # 1 token

Modality frontends are stubs per the assignment: hubert (audio) and
paligemma (vision) consume *precomputed* frame/patch embeddings through a
single linear projection; everything downstream is the real backbone.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn_mod
from repro.models import mamba as mamba_mod
from repro.models import mlp as mlp_mod
from repro.models import nfft_attention as nfft_mod
from repro.models.common import (
    BATCH_AXES, MODEL_AXIS, dense_init, embed_init, init_rms_norm, rms_norm,
    shard,
)

Array = jax.Array


# ---------------------------------------------------------------------------
# Layer plan
# ---------------------------------------------------------------------------

class LayerSig(NamedTuple):
    mixer: str  # 'attn' | 'mla' | 'mamba' | 'nfft'
    ffn: Optional[str]  # 'dense' | 'moe' | None


class LayerPlan(NamedTuple):
    prefix: tuple[LayerSig, ...]  # explicit leading layers
    period: tuple[LayerSig, ...]  # repeating pattern
    n_periods: int

    @property
    def num_layers(self) -> int:
        return len(self.prefix) + len(self.period) * self.n_periods


def layer_signature(cfg: ArchConfig, i: int) -> LayerSig:
    if cfg.is_attention_layer(i):
        if cfg.nfft_attention is not None:
            mixer = "nfft"
        elif cfg.mla is not None:
            mixer = "mla"
        else:
            mixer = "attn"
    else:
        mixer = "mamba"
    if cfg.is_moe_layer(i):
        ffn = "moe"
    elif cfg.d_ff > 0:
        ffn = "dense"
    else:
        ffn = None
    return LayerSig(mixer, ffn)


def make_layer_plan(cfg: ArchConfig, max_period: int = 16) -> LayerPlan:
    """Smallest (prefix, period) decomposition of the signature sequence."""
    sigs = tuple(layer_signature(cfg, i) for i in range(cfg.num_layers))
    n = len(sigs)
    for p_len in range(0, n + 1):
        rest = sigs[p_len:]
        if not rest:
            return LayerPlan(prefix=sigs, period=(), n_periods=0)
        for period in range(1, min(max_period, len(rest)) + 1):
            if len(rest) % period != 0:
                continue
            pat = rest[:period]
            if all(rest[j] == pat[j % period] for j in range(len(rest))):
                return LayerPlan(prefix=sigs[:p_len], period=pat,
                                 n_periods=len(rest) // period)
    return LayerPlan(prefix=sigs, period=(), n_periods=0)  # pragma: no cover


# ---------------------------------------------------------------------------
# Per-block params
# ---------------------------------------------------------------------------

def _init_block(key: Array, sig: LayerSig, cfg: ArchConfig) -> dict:
    k_mix, k_ffn = jax.random.split(key)
    params: dict[str, Any] = {"norm_mixer": init_rms_norm(cfg.d_model, cfg.pdtype)}
    if sig.mixer == "attn":
        params["attn"] = attn_mod.init_attention(k_mix, cfg)
    elif sig.mixer == "mla":
        params["mla"] = attn_mod.init_mla(k_mix, cfg)
    elif sig.mixer == "mamba":
        params["mamba"] = mamba_mod.init_mamba(k_mix, cfg)
    elif sig.mixer == "nfft":
        params["nfft"] = nfft_mod.init_nfft_attention(k_mix, cfg)
    else:  # pragma: no cover
        raise ValueError(sig.mixer)
    if sig.ffn is not None:
        params["norm_ffn"] = init_rms_norm(cfg.d_model, cfg.pdtype)
        if sig.ffn == "moe":
            params["moe"] = mlp_mod.init_moe(k_ffn, cfg)
        else:
            params["mlp"] = mlp_mod.init_mlp(k_ffn, cfg.d_model, cfg.d_ff,
                                             cfg.activation, cfg.pdtype)
    return params


def _init_block_cache(sig: LayerSig, cfg: ArchConfig, batch: int,
                      max_seq: int):
    if sig.mixer == "attn":
        return attn_mod.init_kv_cache(cfg, batch, max_seq)
    if sig.mixer == "mla":
        return attn_mod.init_mla_cache(cfg, batch, max_seq)
    if sig.mixer == "mamba":
        return mamba_mod.init_mamba_cache(cfg, batch)
    if sig.mixer == "nfft":
        return nfft_mod.init_nfft_cache(cfg, batch)
    raise ValueError(sig.mixer)  # pragma: no cover


def _apply_block(params: dict, sig: LayerSig, x: Array, positions: Array,
                 cfg: ArchConfig, *, mode: str, cache, prefix_len: int = 0):
    """One residual block.  mode in {'train', 'prefill', 'decode'}.

    Returns (x, new_cache, aux_loss).
    """
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, params["norm_mixer"], cfg.norm_eps)
    new_cache = cache
    if sig.mixer == "attn":
        if mode == "train":
            mix = attn_mod.attention_forward(params["attn"], h, positions,
                                             cfg, prefix_len=prefix_len)
        elif mode == "prefill":
            mix, new_cache = attn_mod.attention_prefill(
                params["attn"], h, positions, cfg, cache,
                prefix_len=prefix_len)
        else:
            mix, new_cache = attn_mod.attention_decode(
                params["attn"], h, positions, cfg, cache)
    elif sig.mixer == "mla":
        if mode == "train":
            mix = attn_mod.mla_forward(params["mla"], h, positions, cfg)
        elif mode == "prefill":
            mix, new_cache = attn_mod.mla_prefill(params["mla"], h, positions,
                                                  cfg, cache)
        else:
            mix, new_cache = attn_mod.mla_decode(params["mla"], h, positions,
                                                 cfg, cache)
    elif sig.mixer == "mamba":
        if mode == "train":
            mix = mamba_mod.mamba_forward(params["mamba"], h, cfg)
        elif mode == "prefill":
            mix, (conv_x, conv_bc, ssm_state) = mamba_mod.mamba_forward(
                params["mamba"], h, cfg, return_state=True)
            pad = cfg.mamba.d_conv - 1 - conv_x.shape[1]
            if pad > 0:  # sequences shorter than the conv receptive field
                conv_x = jnp.pad(conv_x, ((0, 0), (pad, 0), (0, 0)))
                conv_bc = jnp.pad(conv_bc, ((0, 0), (pad, 0), (0, 0)))
            new_cache = mamba_mod.MambaCache(conv_x=conv_x, conv_bc=conv_bc,
                                             ssm=ssm_state)
        else:
            mix, new_cache = mamba_mod.mamba_decode(params["mamba"], h, cfg,
                                                    cache)
    elif sig.mixer == "nfft":
        if mode == "train":
            mix = nfft_mod.nfft_attention_forward(params["nfft"], h, cfg)
        elif mode == "prefill":
            mix, new_cache = nfft_mod.nfft_attention_prefill(
                params["nfft"], h, cfg, cache)
        else:
            mix, new_cache = nfft_mod.nfft_attention_decode(
                params["nfft"], h, cfg, cache)
    else:  # pragma: no cover
        raise ValueError(sig.mixer)
    x = x + mix
    x = shard(x, BATCH_AXES, None, None)

    if sig.ffn is not None:
        h2 = rms_norm(x, params["norm_ffn"], cfg.norm_eps)
        if sig.ffn == "moe":
            out, aux = mlp_mod.moe_forward(params["moe"], h2, cfg)
        else:
            out = mlp_mod.mlp_forward(params["mlp"], h2, cfg.activation)
        x = x + out
        x = shard(x, BATCH_AXES, None, None)
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Whole-model params
# ---------------------------------------------------------------------------

def init_params(key: Array, cfg: ArchConfig) -> dict:
    plan = make_layer_plan(cfg)
    keys = jax.random.split(key, 8)
    params: dict[str, Any] = {}

    if cfg.frontend == "none" or cfg.frontend == "vision_stub":
        params["embed"] = embed_init(keys[0], (cfg.vocab_size, cfg.d_model),
                                     cfg.pdtype)
    if cfg.frontend != "none":
        params["frontend_proj"] = dense_init(
            keys[1], (cfg.frontend_dim, cfg.d_model), cfg.pdtype)

    # prefix blocks: a list of per-layer param trees
    if plan.prefix:
        pk = jax.random.split(keys[2], len(plan.prefix))
        params["prefix"] = [
            _init_block(pk[i], sig, cfg) for i, sig in enumerate(plan.prefix)]

    # periodic blocks: one stacked tree per slot-in-period
    if plan.n_periods > 0:
        slot_params = []
        sk = jax.random.split(keys[3], len(plan.period))
        for slot, sig in enumerate(plan.period):
            per_period = jax.random.split(sk[slot], plan.n_periods)
            slot_params.append(
                jax.vmap(lambda k: _init_block(k, sig, cfg))(per_period))
        params["stack"] = slot_params

    params["final_norm"] = init_rms_norm(cfg.d_model, cfg.pdtype)
    if not cfg.tie_embeddings or cfg.frontend == "audio_stub":
        params["lm_head"] = dense_init(keys[4], (cfg.d_model, cfg.vocab_size),
                                       cfg.pdtype)
    if cfg.mtp_depth > 0:
        # DeepSeek-style MTP: per extra depth, a combiner + one extra block.
        mtp = []
        mk = jax.random.split(keys[5], cfg.mtp_depth)
        sig = layer_signature(cfg, cfg.num_layers - 1)
        for t in range(cfg.mtp_depth):
            bk, ck = jax.random.split(mk[t])
            mtp.append({
                "combine": dense_init(ck, (2 * cfg.d_model, cfg.d_model),
                                      cfg.pdtype),
                "norm_h": init_rms_norm(cfg.d_model, cfg.pdtype),
                "norm_e": init_rms_norm(cfg.d_model, cfg.pdtype),
                "block": _init_block(bk, sig, cfg),
            })
        params["mtp"] = mtp
    return params


def param_count(params) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params))


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def embed_inputs(params: dict, cfg: ArchConfig, batch: dict) -> tuple[Array, Array, int]:
    """Returns (x (b, s, d), positions (b, s), prefix_len)."""
    prefix_len = 0
    if cfg.frontend == "audio_stub":
        x = batch["embeds"].astype(cfg.dtype) @ params["frontend_proj"]
    elif cfg.frontend == "vision_stub":
        img = batch["image_embeds"].astype(cfg.dtype) @ params["frontend_proj"]
        tok = jnp.take(params["embed"], batch["tokens"], axis=0)
        if cfg.embedding_scale:
            tok = tok * jnp.asarray(math.sqrt(cfg.d_model), cfg.dtype)
        x = jnp.concatenate([img, tok], axis=1)
        prefix_len = cfg.num_prefix_embeds
    else:
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
        if cfg.embedding_scale:
            x = x * jnp.asarray(math.sqrt(cfg.d_model), cfg.dtype)
    b, s = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = shard(x.astype(cfg.dtype), BATCH_AXES, None, None)
    return x, positions, prefix_len


def lm_logits(params: dict, cfg: ArchConfig, h: Array) -> Array:
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    if "lm_head" in params:
        logits = h @ params["lm_head"]
    else:
        logits = h @ params["embed"].T
    logits = shard(logits, BATCH_AXES, None, MODEL_AXIS)
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    return logits


# ---------------------------------------------------------------------------
# Backbone (shared by all three modes)
# ---------------------------------------------------------------------------

def _run_backbone(params: dict, cfg: ArchConfig, x: Array, positions: Array,
                  *, mode: str, caches=None, prefix_len: int = 0,
                  remat: bool = True):
    """Run prefix + scan-over-periods.  Returns (h, new_caches, aux_sum)."""
    plan = make_layer_plan(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    new_caches: dict[str, Any] = {}

    for i, sig in enumerate(plan.prefix):
        cache_i = None if caches is None else caches["prefix"][i]
        x, c, aux = _apply_block(params["prefix"][i], sig, x, positions, cfg,
                                 mode=mode, cache=cache_i,
                                 prefix_len=prefix_len)
        aux_total = aux_total + aux
        if caches is not None:
            new_caches.setdefault("prefix", {})[i] = c

    if plan.n_periods > 0:
        def period_body(carry, per_step):
            xx, aux_acc = carry
            step_params, step_caches = per_step
            out_caches = []
            for slot, sig in enumerate(plan.period):
                cache_s = None if step_caches is None else step_caches[slot]
                xx, c, aux = _apply_block(step_params[slot], sig, xx,
                                          positions, cfg, mode=mode,
                                          cache=cache_s,
                                          prefix_len=prefix_len)
                aux_acc = aux_acc + aux
                out_caches.append(c)
            emitted = tuple(out_caches) if step_caches is not None else None
            return (xx, aux_acc), emitted

        body = jax.checkpoint(period_body) if (remat and mode == "train") \
            else period_body
        stack_caches = None if caches is None else caches["stack"]
        (x, aux_total), emitted = jax.lax.scan(
            body, (x, aux_total), (params["stack"], stack_caches))
        if caches is not None:
            new_caches["stack"] = list(emitted)

    return x, (new_caches if caches is not None else None), aux_total


# ---------------------------------------------------------------------------
# Training forward + loss
# ---------------------------------------------------------------------------

def cross_entropy(logits: Array, labels: Array, mask: Array) -> Array:
    """Stable CE in fp32; mask selects counted positions."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)


def forward_train(params: dict, cfg: ArchConfig, batch: dict,
                  *, remat: bool = True) -> tuple[Array, dict]:
    """batch: tokens/embeds (+ labels, optional loss_mask).  -> (loss, metrics)."""
    x, positions, prefix_len = embed_inputs(params, cfg, batch)
    h, _, aux = _run_backbone(params, cfg, x, positions, mode="train",
                              prefix_len=prefix_len, remat=remat)
    logits = lm_logits(params, cfg, h)

    labels = batch["labels"]
    if cfg.frontend == "vision_stub":
        # loss only over the text segment (labels align with tokens)
        text_logits = logits[:, cfg.num_prefix_embeds:, :]
        mask = batch.get("loss_mask", jnp.ones(labels.shape, jnp.float32))
        loss = cross_entropy(text_logits, labels, mask)
    elif cfg.encoder_only:
        mask = batch.get("loss_mask", jnp.ones(labels.shape, jnp.float32))
        loss = cross_entropy(logits, labels, mask)
    else:
        # next-token: predict labels[t] = tokens[t+1]; last position masked
        mask = batch.get("loss_mask", jnp.ones(labels.shape, jnp.float32))
        loss = cross_entropy(logits, labels, mask)

    metrics = {"ce_loss": loss, "aux_loss": aux}
    if cfg.moe is not None:
        loss = loss + cfg.moe.router_aux_weight * aux

    if cfg.mtp_depth > 0 and not cfg.encoder_only:
        mtp_loss = _mtp_loss(params, cfg, h, batch, positions)
        metrics["mtp_loss"] = mtp_loss
        loss = loss + 0.3 * mtp_loss

    metrics["loss"] = loss
    return loss, metrics


def _mtp_loss(params: dict, cfg: ArchConfig, h: Array, batch: dict,
              positions: Array) -> Array:
    """DeepSeek multi-token prediction: chain one extra block per depth.

    Depth t predicts token_{i+t+1} from (h_i, embed(token_{i+t})) — we reuse
    ``labels`` (already tokens shifted by 1) as the future-token stream.
    """
    labels = batch["labels"]
    b, s = labels.shape
    sig = layer_signature(cfg, cfg.num_layers - 1)
    loss = jnp.zeros((), jnp.float32)
    cur = h
    for t, mtp in enumerate(params["mtp"]):
        shift = t + 1
        fut = jnp.roll(labels, -t, axis=1)  # token_{i+1+t} stream
        fut_e = jnp.take(params["embed"], fut, axis=0)
        merged = jnp.concatenate([
            rms_norm(cur, mtp["norm_h"], cfg.norm_eps),
            rms_norm(fut_e.astype(cur.dtype), mtp["norm_e"], cfg.norm_eps),
        ], axis=-1) @ mtp["combine"]
        cur, _, _ = _apply_block(mtp["block"], sig, merged, positions, cfg,
                                 mode="train", cache=None)
        logits = lm_logits(params, cfg, cur)
        tgt = jnp.roll(labels, -shift, axis=1)
        mask = (jnp.arange(s)[None, :] < s - shift).astype(jnp.float32)
        mask = jnp.broadcast_to(mask, (b, s))
        loss = loss + cross_entropy(logits, tgt, mask)
    return loss / max(cfg.mtp_depth, 1)


# ---------------------------------------------------------------------------
# Serving forwards
# ---------------------------------------------------------------------------

def init_caches(cfg: ArchConfig, batch: int, max_seq: int) -> dict:
    plan = make_layer_plan(cfg)
    caches: dict[str, Any] = {}
    if plan.prefix:
        caches["prefix"] = {
            i: _init_block_cache(sig, cfg, batch, max_seq)
            for i, sig in enumerate(plan.prefix)}
    if plan.n_periods > 0:
        def stack_cache(sig):
            one = _init_block_cache(sig, cfg, batch, max_seq)
            return jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (plan.n_periods,) + a.shape),
                one)
        caches["stack"] = [stack_cache(sig) for sig in plan.period]
    return caches


def forward_prefill(params: dict, cfg: ArchConfig, batch: dict,
                    caches: dict) -> tuple[Array, dict]:
    """Process the full prompt; returns (last-position logits, caches)."""
    x, positions, prefix_len = embed_inputs(params, cfg, batch)
    h, caches, _ = _run_backbone(params, cfg, x, positions, mode="prefill",
                                 caches=caches, prefix_len=prefix_len,
                                 remat=False)
    logits = lm_logits(params, cfg, h[:, -1:, :])
    return logits, caches


def forward_decode(params: dict, cfg: ArchConfig, token: Array, pos: Array,
                   caches: dict) -> tuple[Array, dict]:
    """One decode step.  token: (b, 1) int32; pos: (b,) current position."""
    x = jnp.take(params["embed"], token, axis=0)
    if cfg.embedding_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), cfg.dtype)
    x = shard(x.astype(cfg.dtype), BATCH_AXES, None, None)
    h, caches, _ = _run_backbone(params, cfg, x, pos, mode="decode",
                                 caches=caches, remat=False)
    logits = lm_logits(params, cfg, h)
    return logits, caches


# ---------------------------------------------------------------------------
# Reference (oracle) forward — plain per-layer loop, no scan/remat.  Used by
# tests to check the scan-over-periods backbone is exactly the layer loop.
# ---------------------------------------------------------------------------

def forward_train_reference(params: dict, cfg: ArchConfig,
                            batch: dict) -> tuple[Array, dict]:
    plan = make_layer_plan(cfg)
    x, positions, prefix_len = embed_inputs(params, cfg, batch)
    aux_total = jnp.zeros((), jnp.float32)
    for i, sig in enumerate(plan.prefix):
        x, _, aux = _apply_block(params["prefix"][i], sig, x, positions, cfg,
                                 mode="train", cache=None,
                                 prefix_len=prefix_len)
        aux_total = aux_total + aux
    for p in range(plan.n_periods):
        for slot, sig in enumerate(plan.period):
            blk = jax.tree.map(lambda a: a[p], params["stack"][slot])
            x, _, aux = _apply_block(blk, sig, x, positions, cfg,
                                     mode="train", cache=None,
                                     prefix_len=prefix_len)
            aux_total = aux_total + aux
    logits = lm_logits(params, cfg, x)
    labels = batch["labels"]
    if cfg.frontend == "vision_stub":
        logits = logits[:, cfg.num_prefix_embeds:, :]
    mask = batch.get("loss_mask", jnp.ones(labels.shape, jnp.float32))
    loss = cross_entropy(logits, labels, mask)
    if cfg.moe is not None:
        loss = loss + cfg.moe.router_aux_weight * aux_total
    if cfg.mtp_depth > 0 and not cfg.encoder_only:
        loss = loss + 0.3 * _mtp_loss(params, cfg, x, batch, positions)
    return loss, {"loss": loss}
