"""NFFT kernel attention — the paper's fast summation as an O(n) attention.

The paper's core identity (Section 3):

    K(q - k) ≈ K_RF(q - k) = sum_{l in I_N^d} b_hat[l] e^{2 pi i l.(q - k)}
             = phi(q)^H diag(b_hat) phi(k),     phi(x)[l] = e^{-2 pi i l.x}

separates queries from keys.  Attention with Gaussian-kernel scores and
row-stochastic normalization (the paper's D^{-1} W̃, i.e. L_w) becomes a
*linear attention* whose feature map is the lattice of trigonometric
features with the paper's regularized Fourier coefficients:

    out(q) = sum_i K(q-k_i) v_i / sum_i K(q-k_i)
           = Re[phi(q)^H (b ⊙ S)] / Re[phi(q)^H (b ⊙ z)],
      S = sum_i phi(k_i) v_i^T   (N^d x d_v),    z = sum_i phi(k_i).

Causality comes for free: S, z are prefix sums.  Training uses the standard
chunked scheme (inter-chunk via the running (S, z) state — this is exactly
Algorithm 3.1's adjoint->multiply->forward structure per chunk; intra-chunk
via exact O(Q^2) kernel evaluation).  Decode keeps (S, z) as the *entire*
cache: O(N^d) memory independent of context length, O(N^d d_v) per step —
the long_500k cell runs with a constant-size cache.

Hardware adaptation note (DESIGN.md §3/§4): at model-internal sizes
(N^d ≈ 1024 coefficients) the direct phase matmul (MXU) beats the
window+FFT NFFT pipeline, so the transforms here are exact truncated NDFTs;
the full NFFT machinery (repro.core.nfft) is the right tool on the graph
side where N^d is large.  The two are mathematically interchangeable.

Features are bounded into the admissible box by 0.17*tanh(.), so the node
rescaling rho of Algorithm 3.2 is the identity by construction.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.common import dense_init

Array = jax.Array

FEATURE_BOX = 0.17  # ||f||_inf <= 0.17 -> ||f||_2 <= 0.24 < 1/4 for d=2


def lattice_frequencies(bandwidth: int, d: int) -> np.ndarray:
    """I_N^d integer frequency lattice, FFT order, shape (N^d, d)."""
    freqs = np.fft.fftfreq(bandwidth, d=1.0 / bandwidth).astype(np.float32)
    grids = np.meshgrid(*([freqs] * d), indexing="ij")
    return np.stack([g.reshape(-1) for g in grids], axis=-1)


@functools.lru_cache(maxsize=32)
def kernel_coefficients(bandwidth: int, d: int, sigma: float) -> np.ndarray:
    """Regularized Gaussian Fourier coefficients b_hat (Eq. 3.4), flat (N^d,).

    Computed once per (N, d, sigma) on host; eps_B = 0 (the Gaussian at the
    feature-box scale decays well inside the torus).
    """
    from repro.core.kernels import make_kernel
    from repro.core.regularization import kernel_fourier_coefficients

    kern = make_kernel("gaussian", sigma=sigma)
    with jax.ensure_compile_time_eval():
        b = kernel_fourier_coefficients(kern, d, bandwidth, p=4, eps_b=0.0)
        out = np.asarray(jax.device_get(jnp.real(b)), dtype=np.float32)
    return out.reshape(-1)


def kernel_coefficients_traced(bandwidth: int, d: int, sigma: Array) -> Array:
    """Differentiable b_hat for a traced Gaussian width (learn_sigma path).

    Same quantity as :func:`kernel_coefficients` but computed in-graph so
    gradients flow sigma -> profile samples -> FFT -> b_hat -> attention.
    """
    from repro.core.kernels import make_kernel
    from repro.core.regularization import kernel_fourier_coefficients

    kern = make_kernel("gaussian", sigma=sigma)
    b = kernel_fourier_coefficients(kern, d, bandwidth, p=4, eps_b=0.0)
    return jnp.real(b).reshape(-1).astype(jnp.float32)


def _sigma_and_bhat(params: dict, nc) -> tuple[Array | float, Array]:
    """Kernel width + flat Fourier coefficients, traced iff learn_sigma."""
    if "log_sigma" in params:
        sigma = jnp.exp(params["log_sigma"].astype(jnp.float32))
        return sigma, kernel_coefficients_traced(nc.bandwidth,
                                                 nc.feature_dim, sigma)
    return nc.sigma, jnp.asarray(
        kernel_coefficients(nc.bandwidth, nc.feature_dim, nc.sigma))


def phase_features(x: Array, freqs: Array) -> tuple[Array, Array]:
    """cos/sin features (real pair of phi(x)).  x: (..., d) -> (..., N^d)."""
    angles = 2.0 * jnp.pi * jnp.einsum("...d,ld->...l",
                                       x.astype(jnp.float32), freqs)
    return jnp.cos(angles), jnp.sin(angles)


def init_nfft_attention(key: Array, cfg: ArchConfig) -> dict:
    nc = cfg.nfft_attention
    d, h, hd = cfg.d_model, cfg.num_heads, cfg.head_dim_eff
    ks = jax.random.split(key, 4)
    params = {
        "wqf": dense_init(ks[0], (d, h * nc.feature_dim), cfg.pdtype),
        "wkf": dense_init(ks[1], (d, h * nc.feature_dim), cfg.pdtype),
        "wv": dense_init(ks[2], (d, h * hd), cfg.pdtype),
        "wo": dense_init(ks[3], (h * hd, d), cfg.pdtype),
    }
    if getattr(nc, "learn_sigma", False):
        params["log_sigma"] = jnp.asarray(np.log(nc.sigma), jnp.float32)
    return params


def _features(params, x, cfg):
    nc = cfg.nfft_attention
    b, s, _ = x.shape
    h = cfg.num_heads
    qf = FEATURE_BOX * jnp.tanh((x @ params["wqf"]).astype(jnp.float32))
    kf = FEATURE_BOX * jnp.tanh((x @ params["wkf"]).astype(jnp.float32))
    qf = qf.reshape(b, s, h, nc.feature_dim)
    kf = kf.reshape(b, s, h, nc.feature_dim)
    v = (x @ params["wv"]).reshape(b, s, h, cfg.head_dim_eff)
    return qf, kf, v


def nfft_attention_forward(params: dict, x: Array, cfg: ArchConfig) -> Array:
    """Chunked causal kernel attention (train/prefill)."""
    nc = cfg.nfft_attention
    b, s, _ = x.shape
    h, hd = cfg.num_heads, cfg.head_dim_eff
    chunk = min(128, s)
    assert s % chunk == 0, (s, chunk)
    n_chunks = s // chunk

    freqs = jnp.asarray(lattice_frequencies(nc.bandwidth, nc.feature_dim))
    sigma, bhat = _sigma_and_bhat(params, nc)
    qf, kf, v = _features(params, x, cfg)
    # (b, h, n_chunks, chunk, *)
    qf = qf.transpose(0, 2, 1, 3).reshape(b, h, n_chunks, chunk, -1)
    kf = kf.transpose(0, 2, 1, 3).reshape(b, h, n_chunks, chunk, -1)
    vc = v.astype(jnp.float32).transpose(0, 2, 1, 3).reshape(
        b, h, n_chunks, chunk, hd)

    kcos, ksin = phase_features(kf, freqs)  # (b,h,c,Q,L)
    qcos, qsin = phase_features(qf, freqs)

    # per-chunk adjoint "NDFT": S_c = sum_i phi(k_i) [v_i; 1]
    vc1 = jnp.concatenate([vc, jnp.ones_like(vc[..., :1])], -1)  # (.., hd+1)
    s_cos = jnp.einsum("bhcql,bhcqe->bhcle", kcos, vc1)
    s_sin = jnp.einsum("bhcql,bhcqe->bhcle", ksin, vc1)

    # prefix-sum (exclusive) over chunks — the inter-chunk state
    pre_cos = jnp.cumsum(s_cos, axis=2) - s_cos
    pre_sin = jnp.cumsum(s_sin, axis=2) - s_sin

    # inter-chunk: Re[phi(q)^H (b ⊙ S_prefix)]
    #   = qcos . (b ⊙ S_cos) + qsin . (b ⊙ S_sin)   (cos/sin expansion)
    inter = (jnp.einsum("bhcql,bhcle->bhcqe", qcos, bhat[:, None] * pre_cos)
             + jnp.einsum("bhcql,bhcle->bhcqe", qsin, bhat[:, None] * pre_sin))

    # intra-chunk: exact kernel, causal (diag included: K(0) self-weight)
    diff = qf[..., :, None, :] - kf[..., None, :, :]
    r2 = jnp.sum(diff * diff, -1)
    w = jnp.exp(-r2 / (sigma ** 2))
    causal = (jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None, :])
    w = w * causal
    intra = jnp.einsum("bhcqk,bhcke->bhcqe", w, vc1)

    total = inter + intra
    num, den = total[..., :hd], total[..., hd:]
    out = num / jnp.maximum(den, 1e-6)
    out = out.reshape(b, h, s, hd).transpose(0, 2, 1, 3).reshape(b, s, h * hd)
    return out.astype(x.dtype) @ params["wo"]


class NFFTCache(NamedTuple):
    """Constant-size decode state: accumulated spectral sums (S, z) pair.

    s_cos/s_sin: (b, h, N^d, hd+1) — value+degree channels.  Memory is
    independent of context length (the paper's O(n) made O(1)-per-step).
    """
    s_cos: Array
    s_sin: Array


def init_nfft_cache(cfg: ArchConfig, batch: int) -> NFFTCache:
    nc = cfg.nfft_attention
    n_coef = nc.bandwidth ** nc.feature_dim
    shape = (batch, cfg.num_heads, n_coef, cfg.head_dim_eff + 1)
    return NFFTCache(s_cos=jnp.zeros(shape, jnp.float32),
                     s_sin=jnp.zeros(shape, jnp.float32))


def nfft_attention_prefill(params: dict, x: Array, cfg: ArchConfig,
                           cache: NFFTCache) -> tuple[Array, NFFTCache]:
    """Forward + produce the accumulated state over the whole prefix."""
    nc = cfg.nfft_attention
    b, s, _ = x.shape
    hd = cfg.head_dim_eff
    freqs = jnp.asarray(lattice_frequencies(nc.bandwidth, nc.feature_dim))
    out = nfft_attention_forward(params, x, cfg)
    _, kf, v = _features(params, x, cfg)
    kcos, ksin = phase_features(kf, freqs)  # (b,s,h,L)
    v1 = jnp.concatenate([v.astype(jnp.float32),
                          jnp.ones_like(v[..., :1], jnp.float32)], -1)
    s_cos = jnp.einsum("bshl,bshe->bhle", kcos, v1)
    s_sin = jnp.einsum("bshl,bshe->bhle", ksin, v1)
    return out, NFFTCache(s_cos=cache.s_cos + s_cos,
                          s_sin=cache.s_sin + s_sin)


def nfft_attention_decode(params: dict, x: Array, cfg: ArchConfig,
                          cache: NFFTCache) -> tuple[Array, NFFTCache]:
    """O(N^d) decode step on the constant-size cache.  x: (b, 1, d)."""
    nc = cfg.nfft_attention
    b = x.shape[0]
    h, hd = cfg.num_heads, cfg.head_dim_eff
    freqs = jnp.asarray(lattice_frequencies(nc.bandwidth, nc.feature_dim))
    _, bhat = _sigma_and_bhat(params, nc)
    qf, kf, v = _features(params, x, cfg)  # (b,1,h,*)
    kcos, ksin = phase_features(kf[:, 0], freqs)  # (b,h,L)
    v1 = jnp.concatenate([v[:, 0].astype(jnp.float32),
                          jnp.ones((b, h, 1), jnp.float32)], -1)
    cache = NFFTCache(
        s_cos=cache.s_cos + kcos[..., None] * v1[:, :, None, :],
        s_sin=cache.s_sin + ksin[..., None] * v1[:, :, None, :])

    qcos, qsin = phase_features(qf[:, 0], freqs)  # (b,h,L)
    total = (jnp.einsum("bhl,bhle->bhe", qcos, bhat[:, None] * cache.s_cos)
             + jnp.einsum("bhl,bhle->bhe", qsin, bhat[:, None] * cache.s_sin))
    num, den = total[..., :hd], total[..., hd:]
    out = (num / jnp.maximum(den, 1e-6)).reshape(b, 1, h * hd)
    return out.astype(x.dtype) @ params["wo"], cache
