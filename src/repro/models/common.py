"""Shared model components: norms, RoPE, initializers, sharding helper.

Sharding convention (DESIGN.md §6): model code annotates activations/params
with *logical* :class:`jax.sharding.PartitionSpec`s over the axis names
``("pod", "data", "model")``.  On a single device (CPU smoke tests) the
constraints are no-ops; under the dry-run / training meshes they pin GSPMD's
propagation.  ``shard()`` is safe to call anywhere — it only applies the
constraint when a mesh is active via ``set_mesh``.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Array = jax.Array

_STATE = threading.local()


def current_mesh() -> Optional[Mesh]:
    return getattr(_STATE, "mesh", None)


@contextlib.contextmanager
def set_mesh(mesh: Optional[Mesh]):
    prev = current_mesh()
    _STATE.mesh = mesh
    try:
        yield
    finally:
        _STATE.mesh = prev


def shard(x: Array, *spec) -> Array:
    """with_sharding_constraint against the active mesh (no-op without one).

    Axis-name entries that don't exist in the active mesh are dropped, so the
    same annotations work on the 2-axis single-pod and 3-axis multi-pod mesh.
    """
    mesh = current_mesh()
    if mesh is None:
        return x
    names = set(mesh.axis_names)

    def _filter(entry):
        if entry is None:
            return None
        if isinstance(entry, str):
            return entry if entry in names else None
        entry = tuple(e for e in entry if e in names)
        return entry if entry else None

    clean = P(*(_filter(e) for e in spec))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, clean))


# Logical activation shardings:
BATCH_AXES = ("pod", "data")  # batch dim is sharded over pod x data
MODEL_AXIS = "model"


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: Array, weight: Array, eps: float = 1e-5) -> Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def init_rms_norm(d: int, dtype) -> Array:
    return jnp.zeros((d,), dtype)  # stored as (weight - 1)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., seq, head_dim); positions: (..., seq) int32."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)  # (half,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., s, half)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    rotated = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return rotated.astype(x.dtype)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key: Array, shape, dtype, in_axis: int = 0) -> Array:
    fan_in = shape[in_axis]
    scale = (1.0 / max(fan_in, 1)) ** 0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def embed_init(key: Array, shape, dtype) -> Array:
    return (jax.random.normal(key, shape, jnp.float32)
            * (1.0 / shape[1] ** 0.5)).astype(dtype)


def activation_fn(name: str):
    if name in ("silu", "geglu"):  # gating handled by caller
        return jax.nn.silu if name == "silu" else jax.nn.gelu
    if name == "gelu":
        return jax.nn.gelu
    raise ValueError(name)
