"""Error propagation for normalized matrices (paper Section 3.1, Lemma 3.1).

Lemma 3.1: for W with non-negative entries, E an error matrix, and the
normalized matrices A, A_E built from W and W_E = W + E, with

    eta = d_min / ||W||_inf,    eps = ||E||_inf / ||W||_inf,   eps < eta,

it holds  ||A - A_E||_inf <= eps (1 + eta) / (eta (eta - eps)).

This module provides the bound, a-posteriori estimators for eps/eta from the
fast-summation operator (Eq. 3.5/3.6), and the exact O(n^2) probe (Eq. 3.7).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fastsum import FastsumOperator, dense_weight_matrix
from repro.core.kernels import Kernel
from repro.core.regularization import trigonometric_eval

Array = jax.Array


def lemma31_bound(eta: float, eps: float) -> float:
    """The Lemma 3.1 right-hand side; inf if the eps < eta condition fails.

    Degenerate estimates (non-finite eta/eps from a poisoned operator, or
    eta <= 0 from an isolated node) also map to inf — the runtime guard
    (:mod:`repro.runtime.guards`) relies on "bound can never be optimistic
    garbage": every invalid input reads as the worst case, never NaN."""
    if not (np.isfinite(eta) and eta > 0.0 and np.isfinite(eps)):
        return float("inf")
    if eps >= eta:
        return float("inf")
    return eps * (1.0 + eta) / (eta * (eta - eps))


def normalized_from_dense(w: Array) -> Array:
    deg = jnp.sum(w, axis=1)
    inv_sqrt = 1.0 / jnp.sqrt(deg)
    return inv_sqrt[:, None] * w * inv_sqrt[None, :]


def estimate_epsilon(kernel_rescaled: Kernel, fastsum: FastsumOperator,
                     n_nodes: int, w_inf_norm: float,
                     n_samples: int = 4096, seed: int = 0) -> float:
    """eps ≈ n ||K - K_RF||_inf / ||W||_inf  (Eq. 3.6), Monte-Carlo K_ERR."""
    d = fastsum.plan.d
    rng = np.random.default_rng(seed)
    dirs = rng.normal(size=(n_samples, d))
    dirs /= np.linalg.norm(dirs, axis=1, keepdims=True)
    radii = rng.uniform(0.0, 0.5, size=(n_samples, 1))
    y = jnp.asarray(dirs * radii)
    k_rf = jnp.real(trigonometric_eval(fastsum.b_hat, y))
    k_true = kernel_rescaled.phi(jnp.linalg.norm(y, axis=-1))
    k_err = float(jnp.max(jnp.abs(k_rf - k_true)))
    return n_nodes * k_err / w_inf_norm


def exact_error_norm(kernel: Kernel, points: Array,
                     fastsum: FastsumOperator) -> float:
    """||E||_inf computed exactly via unit-vector probes (Eq. 3.7). O(n^2)."""
    n = points.shape[0]
    w = dense_weight_matrix(kernel, points)
    eye = jnp.eye(n, dtype=w.dtype)
    approx_cols = fastsum.matvec(eye)  # W_E columns (batched matvec)
    return float(jnp.max(jnp.sum(jnp.abs(approx_cols - w), axis=1)))


def aposteriori_report(kernel: Kernel, points: Array,
                       fastsum: FastsumOperator) -> dict:
    """eta, exact eps, Lemma 3.1 bound, and the exact ||A - A_E||_inf."""
    w = dense_weight_matrix(kernel, points)
    deg = jnp.sum(w, axis=1)
    w_inf = float(jnp.max(jnp.sum(jnp.abs(w), axis=1)))
    eta = float(jnp.min(deg)) / w_inf
    n = points.shape[0]
    eye = jnp.eye(n, dtype=w.dtype)
    w_e = fastsum.matvec(eye)
    eps = float(jnp.max(jnp.sum(jnp.abs(w_e - w), axis=1))) / w_inf
    a = normalized_from_dense(w)
    deg_e = jnp.maximum(w_e @ jnp.ones((n,), w.dtype), jnp.finfo(w.dtype).tiny)
    inv_sqrt_e = 1.0 / jnp.sqrt(deg_e)
    a_e = inv_sqrt_e[:, None] * w_e * inv_sqrt_e[None, :]
    a_diff = float(jnp.max(jnp.sum(jnp.abs(a - a_e), axis=1)))
    return {
        "eta": eta,
        "eps": eps,
        "bound": lemma31_bound(eta, eps),
        "a_err_inf": a_diff,
    }
