"""Rotation-invariant kernel functions (paper Section 2, Eq. (2.2)/(2.3)).

Every kernel is represented by a :class:`Kernel` instance exposing the radial
profile ``phi(r) = K(y)`` for ``r = ||y||``, its value at the origin, and the
parameter rescaling used by Algorithm 3.2 step 2 when nodes are shrunk by the
correction factor ``rho`` (Gaussian / Laplacian RBF rescale ``sigma``;
(inverse) multiquadric rescale ``c`` and additionally scale the *output*).

``Kernel`` is a registered pytree whose leaves are the parameter values
(``sigma`` / ``c``).  Parameters may be plain floats *or* traced jnp scalars:
``make_kernel`` keeps concrete inputs as Python floats (so kernels built
eagerly stay hashable and valid jit static arguments) and passes tracers
through untouched, which makes ``at_zero`` / ``rescaled`` / the spectral setup
differentiable w.r.t. sigma and c.  Crossing a jit/grad boundary as a pytree
rebuilds ``phi`` from the (possibly traced) leaves via the shared profile
builders, so the closure and the ``params`` dict can never drift apart.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


def _as_param(v):
    """Concrete scalars -> Python float (hashable); tracers pass through."""
    if isinstance(v, jax.core.Tracer):
        return v
    try:
        return float(v)
    except (TypeError, ValueError):
        return v


# Shared radial-profile builders.  make_kernel and pytree unflattening both go
# through these, so a kernel round-tripped through tree_flatten/unflatten (or
# re-materialized from traced leaves inside grad/jit) compares equal to a
# freshly built one with the same concrete parameters: the closure location
# and captured cell values — which feed Kernel._phi_key — are identical.

def _phi_gaussian(params):
    sigma = params["sigma"]

    def phi(r):
        return jnp.exp(-(r * r) / (sigma * sigma))

    return phi


def _phi_laplacian_rbf(params):
    sigma = params["sigma"]

    def phi(r):
        return jnp.exp(-r / sigma)

    return phi


def _phi_multiquadric(params):
    c = params["c"]

    def phi(r):
        return jnp.sqrt(r * r + c * c)

    return phi


def _phi_inverse_multiquadric(params):
    c = params["c"]

    def phi(r):
        return 1.0 / jnp.sqrt(r * r + c * c)

    return phi


# name -> (profile builder, output_scale_exponent)
_PHI_BUILDERS = {
    "gaussian": (_phi_gaussian, 0),
    "laplacian_rbf": (_phi_laplacian_rbf, 0),
    "multiquadric": (_phi_multiquadric, -1),
    "inverse_multiquadric": (_phi_inverse_multiquadric, 1),
}


@dataclasses.dataclass(frozen=True, eq=False)
class Kernel:
    """A rotation-invariant kernel ``K(y) = phi(||y||)``.

    Attributes:
      name: identifier used in configs / benchmarks.
      phi: radial profile, vectorized over ``r >= 0``.
      params: kernel parameters (``sigma`` or ``c``); floats or traced jnp
        scalars — the pytree leaves of this Kernel.
      output_scale_exponent: after rescaling nodes by ``rho`` (and parameters
        per :meth:`rescaled`), the fast-summation output must be multiplied by
        ``rho**output_scale_exponent`` to recover the original-kernel sums.
        0 for Gaussian/Laplacian RBF (exactly invariant), -1 for multiquadric
        (K scales like 1/rho), +1 for inverse multiquadric.
      singular_at_origin: True for kernels needing near-origin regularization
        (none of the paper's four, but supported by the regularizer).
    """

    name: str
    phi: Callable[[jnp.ndarray], jnp.ndarray]
    params: dict
    output_scale_exponent: int = 0
    singular_at_origin: bool = False

    # Value-based identity makes Kernel a valid hashable jit static argument:
    # two make_kernel('gaussian', sigma=s) instances share compiled code.
    # phi itself cannot be hashed by value, so its defining code location
    # plus its captured closure values join the key — a hand-built Kernel
    # with a custom phi (even one built in a loop from the same lambda with
    # different captured parameters) never aliases another kernel in a jit
    # cache just because the (name, params) pair matches.
    def _phi_key(self):
        phi = self.phi
        loc = (getattr(phi, "__module__", None),
               getattr(phi, "__qualname__", repr(phi)),
               getattr(getattr(phi, "__code__", None), "co_firstlineno", None))
        cells = getattr(phi, "__closure__", None) or ()
        try:
            captured = tuple(c.cell_contents for c in cells)
            hash(captured)
        except Exception:  # unhashable capture: fall back to object identity
            return loc + (id(phi),)
        return loc + captured

    def _key(self):
        return (self.name, tuple(sorted(self.params.items())),
                self.output_scale_exponent, self.singular_at_origin,
                self._phi_key())

    def __eq__(self, other):
        return isinstance(other, Kernel) and self._key() == other._key()

    def __hash__(self):
        return hash(self._key())

    def __call__(self, r):
        return self.phi(jnp.asarray(r))

    def at_zero(self) -> jnp.ndarray:
        """K(0) — used for the W = W̃ − K(0)·I correction.

        Returns a jnp scalar (differentiable w.r.t. the kernel parameters
        when they are traced); wrap in ``float()`` for host-side use.
        """
        return self.phi(jnp.asarray(0.0))

    def rescaled(self, rho) -> "Kernel":
        """Kernel with parameters adjusted for nodes scaled by ``rho``.

        Algorithm 3.2 step 2: Gaussian/Laplacian RBF replace sigma by
        ``rho*sigma``; multiquadric kernels replace c by ``c*rho`` (so that
        ``K_rescaled(rho*y) = rho**(-output_scale_exponent) * K(y)``).
        ``rho`` may be a traced jnp scalar.
        """
        if self.name in ("gaussian", "laplacian_rbf"):
            return make_kernel(self.name, sigma=self.params["sigma"] * rho)
        if self.name in ("multiquadric", "inverse_multiquadric"):
            return make_kernel(self.name, c=self.params["c"] * rho)
        raise ValueError(f"unknown kernel {self.name!r}")


def _kernel_flatten(kernel: Kernel):
    keys = tuple(sorted(kernel.params))
    children = tuple(kernel.params[k] for k in keys)
    # phi is rebuilt from the leaves for the named kernels; a custom phi is
    # carried in the static aux (its closure then ignores new leaf values —
    # custom-phi kernels are opaque to parameter differentiation).
    phi = None if kernel.name in _PHI_BUILDERS else kernel.phi
    aux = (kernel.name, keys, kernel.output_scale_exponent,
           kernel.singular_at_origin, phi)
    return children, aux


def _kernel_unflatten(aux, children) -> Kernel:
    name, keys, exponent, singular, phi = aux
    params = dict(zip(keys, children))
    if phi is None:
        phi = _PHI_BUILDERS[name][0](params)
    return Kernel(name, phi, params, exponent, singular)


jax.tree_util.register_pytree_node(Kernel, _kernel_flatten, _kernel_unflatten)


def make_kernel(name: str, *, sigma=None, c=None) -> Kernel:
    """Factory for the paper's four kernels (Section 2).

    ``sigma`` / ``c`` may be Python floats (eager, hashable kernel) or traced
    jnp scalars (differentiable kernel inside grad/jit).
    """
    if name not in _PHI_BUILDERS:
        raise ValueError(f"unknown kernel {name!r}")
    builder, exponent = _PHI_BUILDERS[name]
    if name in ("gaussian", "laplacian_rbf"):
        assert sigma is not None
        params = {"sigma": _as_param(sigma)}
    else:
        assert c is not None
        params = {"c": _as_param(c)}
    return Kernel(name, builder(params), params,
                  output_scale_exponent=exponent)


GAUSSIAN = "gaussian"
LAPLACIAN_RBF = "laplacian_rbf"
MULTIQUADRIC = "multiquadric"
INVERSE_MULTIQUADRIC = "inverse_multiquadric"

ALL_KERNELS = (GAUSSIAN, LAPLACIAN_RBF, MULTIQUADRIC, INVERSE_MULTIQUADRIC)

#: The parameter name each named kernel exposes (sigma or c) — handy for
#: generic parameter sweeps / gradient-based model selection.
KERNEL_PARAM_NAME = {
    "gaussian": "sigma",
    "laplacian_rbf": "sigma",
    "multiquadric": "c",
    "inverse_multiquadric": "c",
}


def kernel_from_param(name: str, value) -> Kernel:
    """Build a named kernel from its single scalar parameter (float or traced)."""
    return make_kernel(name, **{KERNEL_PARAM_NAME[name]: value})
