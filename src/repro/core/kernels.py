"""Rotation-invariant kernel functions (paper Section 2, Eq. (2.2)/(2.3)).

Every kernel is represented by a :class:`Kernel` instance exposing the radial
profile ``phi(r) = K(y)`` for ``r = ||y||``, its value at the origin, and the
parameter rescaling used by Algorithm 3.2 step 2 when nodes are shrunk by the
correction factor ``rho`` (Gaussian / Laplacian RBF rescale ``sigma``;
(inverse) multiquadric rescale ``c`` and additionally scale the *output*).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True, eq=False)
class Kernel:
    """A rotation-invariant kernel ``K(y) = phi(||y||)``.

    Attributes:
      name: identifier used in configs / benchmarks.
      phi: radial profile, vectorized over ``r >= 0``.
      params: kernel parameters (``sigma`` or ``c``).
      output_scale_exponent: after rescaling nodes by ``rho`` (and parameters
        per :meth:`rescaled`), the fast-summation output must be multiplied by
        ``rho**output_scale_exponent`` to recover the original-kernel sums.
        0 for Gaussian/Laplacian RBF (exactly invariant), -1 for multiquadric
        (K scales like 1/rho), +1 for inverse multiquadric.
      singular_at_origin: True for kernels needing near-origin regularization
        (none of the paper's four, but supported by the regularizer).
    """

    name: str
    phi: Callable[[jnp.ndarray], jnp.ndarray]
    params: dict
    output_scale_exponent: int = 0
    singular_at_origin: bool = False

    # Value-based identity makes Kernel a valid hashable jit static argument:
    # two make_kernel('gaussian', sigma=s) instances share compiled code.
    # phi itself cannot be hashed by value, so its defining code location
    # plus its captured closure values join the key — a hand-built Kernel
    # with a custom phi (even one built in a loop from the same lambda with
    # different captured parameters) never aliases another kernel in a jit
    # cache just because the (name, params) pair matches.
    def _phi_key(self):
        phi = self.phi
        loc = (getattr(phi, "__module__", None),
               getattr(phi, "__qualname__", repr(phi)),
               getattr(getattr(phi, "__code__", None), "co_firstlineno", None))
        cells = getattr(phi, "__closure__", None) or ()
        try:
            captured = tuple(c.cell_contents for c in cells)
            hash(captured)
        except Exception:  # unhashable capture: fall back to object identity
            return loc + (id(phi),)
        return loc + captured

    def _key(self):
        return (self.name, tuple(sorted(self.params.items())),
                self.output_scale_exponent, self.singular_at_origin,
                self._phi_key())

    def __eq__(self, other):
        return isinstance(other, Kernel) and self._key() == other._key()

    def __hash__(self):
        return hash(self._key())

    def __call__(self, r):
        return self.phi(jnp.asarray(r))

    def at_zero(self) -> float:
        """K(0) — used for the W = W̃ − K(0)·I correction."""
        return float(self.phi(jnp.asarray(0.0)))

    def rescaled(self, rho: float) -> "Kernel":
        """Kernel with parameters adjusted for nodes scaled by ``rho``.

        Algorithm 3.2 step 2: Gaussian/Laplacian RBF replace sigma by
        ``rho*sigma``; multiquadric kernels replace c by ``c*rho`` (so that
        ``K_rescaled(rho*y) = rho**(-output_scale_exponent) * K(y)``).
        """
        if self.name in ("gaussian", "laplacian_rbf"):
            return make_kernel(self.name, sigma=self.params["sigma"] * rho)
        if self.name in ("multiquadric", "inverse_multiquadric"):
            return make_kernel(self.name, c=self.params["c"] * rho)
        raise ValueError(f"unknown kernel {self.name!r}")


def make_kernel(name: str, *, sigma: float | None = None, c: float | None = None) -> Kernel:
    """Factory for the paper's four kernels (Section 2)."""
    if name == "gaussian":
        assert sigma is not None
        s2 = float(sigma) ** 2

        def phi(r):
            return jnp.exp(-(r * r) / s2)

        return Kernel("gaussian", phi, {"sigma": float(sigma)})

    if name == "laplacian_rbf":
        assert sigma is not None
        s = float(sigma)

        def phi(r):
            return jnp.exp(-r / s)

        return Kernel("laplacian_rbf", phi, {"sigma": s})

    if name == "multiquadric":
        assert c is not None
        c2 = float(c) ** 2

        def phi(r):
            return jnp.sqrt(r * r + c2)

        # K(rho*y) with c->c*rho equals rho*K(y): output must be scaled by 1/rho
        # => exponent -1 in the convention output *= rho**exponent ... we store
        # the exponent such that  original = rho**exponent * rescaled_output.
        return Kernel("multiquadric", phi, {"c": float(c)}, output_scale_exponent=-1)

    if name == "inverse_multiquadric":
        assert c is not None
        c2 = float(c) ** 2

        def phi(r):
            return 1.0 / jnp.sqrt(r * r + c2)

        return Kernel("inverse_multiquadric", phi, {"c": float(c)}, output_scale_exponent=1)

    raise ValueError(f"unknown kernel {name!r}")


GAUSSIAN = "gaussian"
LAPLACIAN_RBF = "laplacian_rbf"
MULTIQUADRIC = "multiquadric"
INVERSE_MULTIQUADRIC = "inverse_multiquadric"

ALL_KERNELS = (GAUSSIAN, LAPLACIAN_RBF, MULTIQUADRIC, INVERSE_MULTIQUADRIC)
