"""Krylov linear solvers driven by fast matvecs (paper Sections 4, 6.2.3, 6.3).

Conjugate Gradients (Hestenes–Stiefel) and MINRES (Paige–Saunders), both
matrix-free and jit-compatible (``lax.while_loop``).  Used for

    (I + beta L_s) u = f        (kernel SSL, Eq. 6.4)
    (K + beta I) alpha = f      (kernel ridge regression, Section 6.3)

with the matvec supplied by Algorithm 3.1/3.2 operators.

Batched right-hand sides ``b`` of shape (n, C) run C *independent*
recurrences in lockstep: per-column step sizes, per-column tolerances
(``tol * max(||b_c||, 1)``), and per-column convergence masks that freeze a
column's iterate once it converges while the others continue — one easy
column can no longer mask (or distort, through a shared global step size)
the convergence of the others.  The matvec is still invoked once per
iteration on the whole (n, C) block, so the fused fastsum engine amortizes
its spread/FFT/gather over all active systems.

``cg_bank`` / ``minres_bank`` lift the same lockstep machinery over a
*bank* axis: ``b`` of shape (S, n) or (S, n, C) with a bank matvec
``(S, n, C) -> (S, n, C)`` (e.g. ``FastsumOperatorBank.matvec``'s lockstep
flavor) solves all S·C systems with ONE bank matvec per iteration — the
execution shape of a hyperparameter sweep.

All solvers recompute the true residual ``||b - A x||`` (per column) at
exit: the recurrence residual drifts on ill-conditioned operators, so the
reported ``residual_norm`` / ``converged`` always describe the returned
iterate.
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array
Matvec = Callable[[Array], Array]


class SolveResult(NamedTuple):
    x: Array
    num_iters: Array
    residual_norm: Array
    converged: Array


def _col_norms(v: Array) -> Array:
    """Per-column 2-norms of (n, C) -> (C,); complex-safe (|v|^2)."""
    return jnp.sqrt(jnp.sum(jnp.real(v * jnp.conj(v)), axis=0))


def _col_dot(u: Array, v: Array) -> Array:
    """Per-column <u, v> (conjugating, real part) of (n, C) -> (C,).

    The column-wise analogue of ``jnp.vdot(u, v).real`` — keeps the
    complex-HPD case working; for real dtypes XLA folds conj/real away.
    """
    return jnp.real(jnp.sum(jnp.conj(u) * v, axis=0))


def _as_columns(matvec: Matvec, b: Array, x0: Array | None,
                preconditioner: Matvec | None):
    """Normalize a (n,)- or (n, C)-shaped solve to the (n, C) layout."""
    batched = b.ndim == 2
    if batched:
        return matvec, b, x0, preconditioner, True
    mv = lambda u: matvec(u[:, 0])[:, None]
    pc = None if preconditioner is None \
        else (lambda u: preconditioner(u[:, 0])[:, None])
    return mv, b[:, None], None if x0 is None else x0[:, None], pc, False


def _squeeze_result(res: SolveResult, batched: bool) -> SolveResult:
    if batched:
        return res
    return SolveResult(x=res.x[:, 0], num_iters=res.num_iters[0],
                       residual_norm=res.residual_norm[0],
                       converged=res.converged[0])


def cg(matvec: Matvec, b: Array, *, x0: Array | None = None,
       tol: float = 1e-8, maxiter: int = 1000,
       preconditioner: Matvec | None = None) -> SolveResult:
    """Preconditioned conjugate gradients for SPD operators.

    ``b`` (n,): scalar recurrence, scalar result fields.  ``b`` (n, C):
    per-column recurrences in lockstep (see module docstring); ``x``
    (n, C) and ``num_iters`` / ``residual_norm`` / ``converged`` (C,).
    """
    matvec, b, x0, preconditioner, batched = _as_columns(
        matvec, b, x0, preconditioner)
    if x0 is None:
        # r0 = b - A·0 = b: skipping the matvec drops one of three copies
        # of the operator graph from the trace (faster compile, same math)
        x, r = jnp.zeros_like(b), b
    else:
        x, r = x0, b - matvec(x0)
    z = preconditioner(r) if preconditioner is not None else r
    p = z
    rz = _col_dot(r, z)  # (C,)
    tol_abs = tol * jnp.maximum(_col_norms(b), 1.0)  # (C,)
    iters0 = jnp.zeros(b.shape[1:], jnp.int32)

    def cond(state):
        x, r, z, p, rz, iters, i = state
        return jnp.logical_and(i < maxiter,
                               jnp.any(_col_norms(r) > tol_abs))

    def body(state):
        x, r, z, p, rz, iters, i = state
        active = _col_norms(r) > tol_abs  # (C,)
        ap = matvec(p)
        denom = _col_dot(p, ap)
        alpha = rz / jnp.where(denom != 0, denom, 1.0)
        # freeze converged columns: zero step keeps x, r (and hence the
        # active mask) fixed while the remaining columns keep iterating
        alpha = jnp.where(active, alpha, 0.0)
        x = x + alpha * p
        r = r - alpha * ap
        z_new = preconditioner(r) if preconditioner is not None else r
        rz_new = _col_dot(r, z_new)
        beta = jnp.where(active, rz_new / jnp.where(rz != 0, rz, 1.0), 0.0)
        p = z_new + beta * p
        return x, r, z_new, p, rz_new, iters + active, i + 1

    x, r, z, p, rz, iters, _ = jax.lax.while_loop(
        cond, body, (x, r, z, p, rz, iters0, jnp.zeros((), jnp.int32)))
    # The recurrence residual r drifts from b - A x on ill-conditioned
    # operators (finite-precision rounding breaks the exact update
    # invariant), so the loop can report convergence the iterate doesn't
    # have.  One extra matvec recomputes the true residual at exit so
    # residual_norm / converged reflect the returned x.
    res = _col_norms(b - matvec(x))
    return _squeeze_result(
        SolveResult(x=x, num_iters=iters, residual_norm=res,
                    converged=res <= tol_abs), batched)


def minres(matvec: Matvec, b: Array, *, x0: Array | None = None,
           tol: float = 1e-8, maxiter: int = 1000) -> SolveResult:
    """MINRES for symmetric (possibly indefinite) operators.

    Batched ``b`` (n, C) runs per-column Lanczos + Givens recurrences in
    lockstep (all scalar recurrence state becomes (C,)-shaped); converged
    columns stop updating their iterate while the rest continue.
    """
    matvec, b, x0, _, batched = _as_columns(matvec, b, x0, None)
    if x0 is None:
        x, r = jnp.zeros_like(b), b  # r0 = b - A·0 (matvec elided)
    else:
        x, r = x0, b - matvec(x0)
    beta1 = _col_norms(r)  # (C,)
    tol_abs = tol * jnp.maximum(_col_norms(b), 1.0)
    dtype = b.dtype
    eps = jnp.finfo(dtype).tiny
    cshape = beta1.shape

    # Lanczos + Givens QR recurrences (standard MINRES state machine),
    # one independent recurrence per column
    v = r / jnp.maximum(beta1, eps)
    v_prev = jnp.zeros_like(b)
    w = jnp.zeros_like(b)
    w_prev = jnp.zeros_like(b)
    phi_bar = beta1
    delta1 = jnp.zeros(cshape, dtype)
    eps_k = jnp.zeros(cshape, dtype)
    cs = -jnp.ones(cshape, dtype)
    sn = jnp.zeros(cshape, dtype)
    beta = beta1
    iters0 = jnp.zeros(cshape, jnp.int32)

    def cond(state):
        (x, v, v_prev, w, w_prev, phi_bar, delta1, eps_k, cs, sn, beta,
         iters, i) = state
        return jnp.logical_and(i < maxiter, jnp.any(jnp.abs(phi_bar) > tol_abs))

    def body(state):
        (x, v, v_prev, w, w_prev, phi_bar, delta1, eps_k, cs, sn, beta,
         iters, i) = state
        active = jnp.abs(phi_bar) > tol_abs  # (C,)
        av = matvec(v)
        alpha = _col_dot(v, av).astype(dtype)
        av = av - alpha * v - beta * v_prev
        beta_new = _col_norms(av)
        v_new = av / jnp.maximum(beta_new, eps)

        # previous rotation
        delta2 = cs * delta1 + sn * alpha
        gamma1 = sn * delta1 - cs * alpha
        eps_next = sn * beta_new
        delta1_next = -cs * beta_new

        # new rotation
        gamma2 = jnp.sqrt(gamma1 * gamma1 + beta_new * beta_new)
        gamma2 = jnp.maximum(gamma2, eps)
        cs_new = gamma1 / gamma2
        sn_new = beta_new / gamma2
        tau = cs_new * phi_bar
        phi_bar_new = jnp.where(active, sn_new * phi_bar, phi_bar)

        w_new = (v - delta2 * w - eps_k * w_prev) / gamma2
        # converged columns take a zero step (their Lanczos recurrence keeps
        # running harmlessly; only the iterate and phi_bar are frozen)
        x_new = x + jnp.where(active, tau, 0.0) * w_new
        return (x_new, v_new, v, w_new, w, phi_bar_new, delta1_next,
                eps_next, cs_new, sn_new, beta_new, iters + active, i + 1)

    init = (x, v, v_prev, w, w_prev, phi_bar, delta1, eps_k, cs, sn, beta,
            iters0, jnp.zeros((), jnp.int32))
    (x, v, v_prev, w, w_prev, phi_bar, delta1, eps_k, cs, sn, beta, iters,
     _) = jax.lax.while_loop(cond, body, init)
    # |phi_bar| is the QR-recurrence residual; like CG's it drifts from
    # ||b - A x|| in finite precision.  Recompute the true residual once at
    # exit (one matvec) so the reported norm matches the returned iterate.
    res = _col_norms(b - matvec(x))
    return _squeeze_result(
        SolveResult(x=x, num_iters=iters, residual_norm=res,
                    converged=res <= tol_abs), batched)


# ---------------------------------------------------------------------------
# Lockstep bank solvers: one bank matvec per iteration for S·C systems.
# ---------------------------------------------------------------------------

def _bank_solve(solver, bank_matvec: Matvec, b: Array, x0: Array | None,
                kwargs) -> SolveResult:
    """Flatten the bank axis into the column axis and run a lockstep solve.

    ``bank_matvec`` maps (S, n, C) -> (S, n, C) applying operator ``s`` to
    ``x[s]`` (e.g. the lockstep flavor of ``FastsumOperatorBank.matvec``);
    the per-column machinery of :func:`cg`/:func:`minres` then gives every
    (s, c) system its own step sizes, tolerance ``tol * max(||b[s,:,c]||,
    1)``, and convergence mask — while each iteration costs exactly one bank
    matvec (one spread + one forward FFT for the whole sweep).
    """
    if b.ndim not in (2, 3):
        raise ValueError(f"bank rhs must be (S, n) or (S, n, C), got {b.shape}")
    squeeze = b.ndim == 2
    b3 = b[..., None] if squeeze else b
    s, n, c = b3.shape

    def flat_mv(u):  # (n, S*C) -> (n, S*C)
        xb = jnp.moveaxis(u.reshape(n, s, c), 1, 0)
        yb = bank_matvec(xb)
        return jnp.moveaxis(yb, 0, 1).reshape(n, s * c)

    def to_flat(v):  # (S, n, C) -> (n, S*C)
        return jnp.moveaxis(v, 0, 1).reshape(n, s * c)

    def from_flat(v):  # (n, S*C) -> (S, n, C)
        return jnp.moveaxis(v.reshape(n, s, c), 1, 0)

    x0f = None if x0 is None else to_flat(x0[..., None] if squeeze else x0)
    sol = solver(flat_mv, to_flat(b3), x0=x0f, **kwargs)
    x = from_flat(sol.x)
    stats = [a.reshape(s, c) for a in
             (sol.num_iters, sol.residual_norm, sol.converged)]
    if squeeze:
        x = x[..., 0]
        stats = [a[:, 0] for a in stats]
    return SolveResult(x, *stats)


def cg_bank(bank_matvec: Matvec, b: Array, *, x0: Array | None = None,
            tol: float = 1e-8, maxiter: int = 1000) -> SolveResult:
    """Lockstep CG over a bank axis: b (S, n) or (S, n, C).

    One bank matvec per iteration solves all S·C systems; per-system
    tolerance masks freeze converged systems; the true residual is
    recomputed at exit.  Result fields mirror the input layout: ``x``
    (S, n[, C]), ``num_iters``/``residual_norm``/``converged`` (S[, C]).
    """
    return _bank_solve(cg, bank_matvec, b, x0,
                       dict(tol=tol, maxiter=maxiter))


def minres_bank(bank_matvec: Matvec, b: Array, *, x0: Array | None = None,
                tol: float = 1e-8, maxiter: int = 1000) -> SolveResult:
    """Lockstep MINRES over a bank axis (see :func:`cg_bank`)."""
    return _bank_solve(minres, bank_matvec, b, x0,
                       dict(tol=tol, maxiter=maxiter))
