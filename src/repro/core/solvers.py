"""Krylov linear solvers driven by fast matvecs (paper Sections 4, 6.2.3, 6.3).

Conjugate Gradients (Hestenes–Stiefel) and MINRES (Paige–Saunders), both
matrix-free and jit-compatible (``lax.while_loop``).  Used for

    (I + beta L_s) u = f        (kernel SSL, Eq. 6.4)
    (K + beta I) alpha = f      (kernel ridge regression, Section 6.3)

with the matvec supplied by Algorithm 3.1/3.2 operators.

Batched right-hand sides ``b`` of shape (n, C) run C *independent*
recurrences in lockstep: per-column step sizes, per-column tolerances
(``tol * max(||b_c||, 1)``), and per-column convergence masks that freeze a
column's iterate once it converges while the others continue — one easy
column can no longer mask (or distort, through a shared global step size)
the convergence of the others.  The matvec is still invoked once per
iteration on the whole (n, C) block, so the fused fastsum engine amortizes
its spread/FFT/gather over all active systems.

``cg_bank`` / ``minres_bank`` lift the same lockstep machinery over a
*bank* axis: ``b`` of shape (S, n) or (S, n, C) with a bank matvec
``(S, n, C) -> (S, n, C)`` (e.g. ``FastsumOperatorBank.matvec``'s lockstep
flavor) solves all S·C systems with ONE bank matvec per iteration — the
execution shape of a hyperparameter sweep.

All solvers recompute the true residual ``||b - A x||`` (per column) at
exit: the recurrence residual drifts on ill-conditioned operators, so the
reported ``residual_norm`` / ``converged`` always describe the returned
iterate.

Guarded execution (``repro.runtime``): every solve also reports a
:class:`SolveHealth`.  A non-finite right-hand-side column is quarantined
*before* the loop (the solve returns immediately for it instead of
spinning to ``maxiter`` on NaNs); a column whose iterate goes non-finite
mid-solve — e.g. a poisoned operator member in a bank — is reverted to its
last finite iterate and frozen via the same per-column masks that freeze
converged columns, so one bad system can neither hang nor pollute its
lockstep siblings; a column whose residual stops improving for
``stall_window`` consecutive iterations is frozen as stagnated (Krylov
breakdown under inexact matvecs — the attainable-accuracy wall — no longer
burns the full ``maxiter`` budget).
"""

from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array
Matvec = Callable[[Array], Array]

# A column "improves" only when its residual beats its best-so-far by this
# relative margin; anything smaller feeds the stagnation counter.  Cumulative
# over the window, so a legitimately (if slowly) converging column resets
# the counter long before a default window expires.
_STALL_RTOL = 1e-3


class SolveHealth(NamedTuple):
    """Per-column solver guard flags (shapes mirror ``converged``).

    ``rhs_nonfinite``
        the right-hand side (or ``x0``) held NaN/Inf; the column was
        quarantined before the first iteration and ``x`` is 0 for it.
    ``nonfinite``
        the iterate went non-finite mid-solve (poisoned operator,
        breakdown); ``x`` is the last finite iterate.
    ``stagnated``
        the residual stopped improving for ``stall_window`` iterations.
    ``breakdown_iter``
        iteration index at which ``nonfinite`` tripped, -1 if never.
    """

    rhs_nonfinite: Array
    nonfinite: Array
    stagnated: Array
    breakdown_iter: Array

    @property
    def any_fault(self) -> Array:
        return self.rhs_nonfinite | self.nonfinite | self.stagnated


class SolveResult(NamedTuple):
    x: Array
    num_iters: Array
    residual_norm: Array
    converged: Array
    health: SolveHealth | None = None


def _col_norms(v: Array) -> Array:
    """Per-column 2-norms of (n, C) -> (C,); complex-safe (|v|^2)."""
    return jnp.sqrt(jnp.sum(jnp.real(v * jnp.conj(v)), axis=0))


def _col_dot(u: Array, v: Array) -> Array:
    """Per-column <u, v> (conjugating, real part) of (n, C) -> (C,).

    The column-wise analogue of ``jnp.vdot(u, v).real`` — keeps the
    complex-HPD case working; for real dtypes XLA folds conj/real away.
    """
    return jnp.real(jnp.sum(jnp.conj(u) * v, axis=0))


def _as_columns(matvec: Matvec, b: Array, x0: Array | None,
                preconditioner: Matvec | None):
    """Normalize a (n,)- or (n, C)-shaped solve to the (n, C) layout."""
    batched = b.ndim == 2
    if batched:
        return matvec, b, x0, preconditioner, True
    mv = lambda u: matvec(u[:, 0])[:, None]
    pc = None if preconditioner is None \
        else (lambda u: preconditioner(u[:, 0])[:, None])
    return mv, b[:, None], None if x0 is None else x0[:, None], pc, False


def _squeeze_result(res: SolveResult, batched: bool) -> SolveResult:
    if batched:
        return res
    health = None if res.health is None else \
        SolveHealth(*(f[0] for f in res.health))
    return SolveResult(x=res.x[:, 0], num_iters=res.num_iters[0],
                       residual_norm=res.residual_norm[0],
                       converged=res.converged[0], health=health)


def _validate_rhs(b: Array, x0: Array | None):
    """Quarantine non-finite rhs / x0 columns before the loop.

    Returns ``(rhs_bad (C,), b_safe, x0_safe)`` — bad columns get a zero
    rhs (and zero start), so their residual is 0 from iteration 0 and they
    never enter the active set: an all-NaN ``b`` exits immediately with
    ``num_iters == 0`` instead of spinning to ``maxiter``.
    """
    rhs_bad = ~jnp.all(jnp.isfinite(b), axis=0)  # (C,)
    if x0 is not None:
        rhs_bad = rhs_bad | ~jnp.all(jnp.isfinite(x0), axis=0)
        x0 = jnp.where(rhs_bad[None, :], 0.0, x0)
    b_safe = jnp.where(rhs_bad[None, :], 0.0, b)
    return rhs_bad, b_safe, x0


def _finish(matvec: Matvec, b_safe: Array, x: Array, tol_abs: Array,
            iters: Array, rhs_bad: Array, poisoned: Array, stalled: Array,
            bad_iter: Array, batched: bool) -> SolveResult:
    """Shared exit path: true residual + health assembly.

    The recurrence residual drifts from ``b - A x`` on ill-conditioned
    operators (finite-precision rounding breaks the exact update
    invariant), so one extra matvec recomputes the true residual at exit —
    ``residual_norm`` / ``converged`` always describe the returned iterate.
    Quarantined-rhs columns report ``inf`` (deterministic, not NaN).
    """
    res = _col_norms(b_safe - matvec(x))
    # a poisoned operator column emits NaN even on the reverted (finite)
    # iterate; normalize any non-finite exit residual to inf so downstream
    # comparisons are deterministic
    res = jnp.where(rhs_bad | ~jnp.isfinite(res), jnp.inf, res)
    health = SolveHealth(rhs_nonfinite=rhs_bad, nonfinite=poisoned,
                         stagnated=stalled, breakdown_iter=bad_iter)
    return _squeeze_result(
        SolveResult(x=x, num_iters=iters, residual_norm=res,
                    converged=res <= tol_abs, health=health), batched)


def cg(matvec: Matvec, b: Array, *, x0: Array | None = None,
       tol: float = 1e-8, maxiter: int = 1000,
       preconditioner: Matvec | None = None,
       stall_window: int = 250, implicit_diff: bool = True) -> SolveResult:
    """Preconditioned conjugate gradients for SPD operators.

    ``b`` (n,): scalar recurrence, scalar result fields.  ``b`` (n, C):
    per-column recurrences in lockstep (see module docstring); ``x``
    (n, C) and ``num_iters`` / ``residual_norm`` / ``converged`` (C,).

    ``stall_window`` > 0 freezes a column whose residual fails to improve
    (by a relative ``1e-3``) for that many consecutive iterations; 0
    disables stagnation detection.  Guard flags land in ``result.health``.

    With ``implicit_diff=True`` (the default) the solve is differentiable
    by the implicit function theorem instead of by unrolling the Krylov
    loop: for ``A x* = b`` with symmetric ``A``, the backward pass solves
    ``A w = x̄`` — one more CG on the *same* operator (same tolerance,
    preconditioner, and guard machinery) — giving ``b̄ = w`` and, for any
    operator parameters θ captured by the ``matvec`` closure,
    ``θ̄ = −∂θ⟨w, A(θ) x*⟩``.  Closed-over tracers are hoisted out of the
    closure via ``jax.closure_convert``, so gradients reach spectral
    multipliers / kernel parameters inside a fastsum matvec transparently.
    Only ``x`` is differentiable; the diagnostics (``residual_norm``,
    ``num_iters``, ``converged``, ``health``) are treated as
    non-differentiable outputs.  Quarantined columns (``health.any_fault``)
    propagate exactly zero cotangents — a faulted solve never emits NaN
    gradients.  ``implicit_diff=False`` restores the plain forward-only
    recurrence (matvecs that refuse abstract tracing also fall back to it
    automatically).
    """
    if implicit_diff:
        conv = _try_closure_convert(matvec, b, preconditioner)
        if conv is not None:
            mv_c, mv_args, pc_c, pc_args = conv
            return _cg_implicit(mv_c, pc_c, (tol, maxiter, stall_window),
                                b, x0, mv_args, pc_args)
    return _cg_plain(matvec, b, x0=x0, tol=tol, maxiter=maxiter,
                     preconditioner=preconditioner,
                     stall_window=stall_window)


def _try_closure_convert(matvec, b, preconditioner):
    """Hoist closed-over jax values out of the matvec/preconditioner.

    Returns ``(mv_c, mv_args, pc_c, pc_args)`` or None when the callables
    cannot be abstractly traced (host callbacks, shape-dependent Python
    control flow) — the caller then degrades to the forward-only solver.
    """
    example = jnp.zeros(b.shape, b.dtype)
    try:
        mv_c, mv_args = jax.closure_convert(matvec, example)
        if preconditioner is None:
            pc_c, pc_args = None, []
        else:
            pc_c, pc_args = jax.closure_convert(preconditioner, example)
        return mv_c, tuple(mv_args), pc_c, tuple(pc_args)
    except Exception:
        return None


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _cg_implicit(mv_c, pc_c, statics, b, x0, mv_args, pc_args):
    tol, maxiter, stall_window = statics
    mv = lambda v: mv_c(v, *mv_args)
    pc = None if pc_c is None else (lambda v: pc_c(v, *pc_args))
    return _cg_plain(mv, b, x0=x0, tol=tol, maxiter=maxiter,
                     preconditioner=pc, stall_window=stall_window)


def _cg_implicit_fwd(mv_c, pc_c, statics, b, x0, mv_args, pc_args):
    sol = _cg_implicit(mv_c, pc_c, statics, b, x0, mv_args, pc_args)
    return sol, (sol.x, sol.health, mv_args, pc_args)


def _cg_implicit_bwd(mv_c, pc_c, statics, res, ct):
    x_star, health, mv_args, pc_args = res
    tol, maxiter, stall_window = statics
    # Only x carries a cotangent; diagnostics are non-differentiable.
    xbar = ct.x
    # SolveHealth quarantine: zero the cotangents of faulted columns (their
    # primal iterate is not a solution of A x = b, so the implicit-function
    # identity does not hold there) and scrub non-finite cotangents — a
    # guarded solve never emits NaN gradients.
    keep = (~health.any_fault).astype(x_star.dtype)
    xbar = jnp.where(jnp.isfinite(xbar), xbar, 0.0) * keep
    mv = lambda v: mv_c(v, *mv_args)
    pc = None if pc_c is None else (lambda v: pc_c(v, *pc_args))
    wsol = _cg_plain(mv, xbar, tol=tol, maxiter=maxiter, preconditioner=pc,
                     stall_window=stall_window)
    w = jnp.where(jnp.isfinite(wsol.x), wsol.x, 0.0) * keep
    # b̄ = w;  θ̄ = −vjp_θ(θ ↦ A(θ) x*)(w)  for the hoisted closure args.
    _, pull_args = jax.vjp(lambda a: mv_c(x_star, *a), mv_args)
    (mv_args_bar,) = pull_args(w)
    mv_args_bar = jax.tree_util.tree_map(lambda t: -t, mv_args_bar)
    # The preconditioner changes the iteration, not the solution: zeros.
    pc_args_bar = jax.tree_util.tree_map(jnp.zeros_like, pc_args)
    return w, None, mv_args_bar, pc_args_bar


_cg_implicit.defvjp(_cg_implicit_fwd, _cg_implicit_bwd)


class CGLoopState(NamedTuple):
    """The complete CG loop state — a checkpointable pytree of arrays.

    Snapshotting this mid-solve and resuming reproduces the exact
    trajectory of an uninterrupted run: the loop body is a deterministic
    function of this state alone (the matvec is re-supplied by the caller
    on restart).  ``b``/``tol_abs``/``rhs_bad`` ride along so the exit path
    needs nothing beyond the state and the matvec.
    """

    x: Array
    r: Array
    z: Array
    p: Array
    rz: Array
    iters: Array
    best: Array       # best residual so far (stagnation reference)
    stall: Array      # consecutive non-improving iterations
    poisoned: Array   # SolveHealth.nonfinite accumulator
    stalled: Array    # SolveHealth.stagnated accumulator
    bad: Array        # SolveHealth.breakdown_iter accumulator
    i: Array          # global iteration counter (scalar int32)
    b: Array          # validated right-hand side
    tol_abs: Array
    rhs_bad: Array


class KrylovMachine(NamedTuple):
    """A Krylov solve in resumable form: ``state0`` + pure ``cond``/``body``
    step functions + ``finish``.

    ``while cond(s): s = body(s)`` followed by ``finish(s)`` IS the solver
    (:func:`cg` / :func:`minres` run exactly this); a driver may instead run
    the loop in bounded segments, checkpoint the state pytree between them
    (see :mod:`repro.runtime.durable`), and still produce a bit-identical
    trajectory.
    """

    state: NamedTuple
    cond: Callable
    body: Callable
    finish: Callable


def cg_machine(matvec: Matvec, b: Array, *, x0: Array | None = None,
               tol: float = 1e-8, maxiter: int = 1000,
               preconditioner: Matvec | None = None,
               stall_window: int = 250) -> KrylovMachine:
    """CG as a resumable machine (state pytree: :class:`CGLoopState`)."""
    matvec, b, x0, preconditioner, batched = _as_columns(
        matvec, b, x0, preconditioner)
    rhs_bad, b, x0 = _validate_rhs(b, x0)
    if x0 is None:
        # r0 = b - A·0 = b: skipping the matvec drops one of three copies
        # of the operator graph from the trace (faster compile, same math)
        x, r = jnp.zeros_like(b), b
    else:
        x, r = x0, b - matvec(x0)
    z = preconditioner(r) if preconditioner is not None else r
    p = z
    rz = _col_dot(r, z)  # (C,)
    resn0 = _col_norms(r)
    tol_abs = tol * jnp.maximum(_col_norms(b), 1.0)  # (C,)
    cshape = tol_abs.shape
    state0 = CGLoopState(
        x=x, r=r, z=z, p=p, rz=rz,
        iters=jnp.zeros(cshape, jnp.int32),
        best=resn0,  # best residual so far
        stall=jnp.zeros(cshape, jnp.int32),
        poisoned=jnp.zeros(cshape, bool),
        stalled=jnp.zeros(cshape, bool),
        bad=jnp.full(cshape, -1, jnp.int32),
        i=jnp.zeros((), jnp.int32),
        b=b, tol_abs=tol_abs, rhs_bad=rhs_bad)

    def cond(s: CGLoopState):
        alive = (_col_norms(s.r) > s.tol_abs) & ~s.poisoned & ~s.stalled
        return jnp.logical_and(s.i < maxiter, jnp.any(alive))

    def body(s: CGLoopState):
        x, r, z, p, rz = s.x, s.r, s.z, s.p, s.rz
        best, stall, poisoned, stalled, bad = (
            s.best, s.stall, s.poisoned, s.stalled, s.bad)
        active = (_col_norms(r) > s.tol_abs) & ~poisoned & ~stalled  # (C,)
        ap = matvec(p)
        denom = _col_dot(p, ap)
        alpha = rz / jnp.where(denom != 0, denom, 1.0)
        alpha = jnp.where(active, alpha, 0.0)
        x_new = x + alpha * p
        r_new = r - alpha * ap
        z_new = preconditioner(r_new) if preconditioner is not None else r_new
        rz_new = _col_dot(r_new, z_new)
        beta = jnp.where(active, rz_new / jnp.where(rz != 0, rz, 1.0), 0.0)
        p_new = z_new + beta * p

        # quarantine: a column whose update went non-finite reverts to its
        # last finite iterate and leaves the active set for good — frozen
        # columns never take (or emit) NaN values, so lockstep siblings
        # are untouched
        ok = (jnp.all(jnp.isfinite(x_new), axis=0)
              & jnp.all(jnp.isfinite(r_new), axis=0)
              & jnp.all(jnp.isfinite(p_new), axis=0))
        upd = active & ok
        trip = active & ~ok
        poisoned = poisoned | trip
        bad = jnp.where(trip & (bad < 0), s.i, bad)
        sel = lambda new, old: jnp.where(upd[None, :], new, old)
        x, r, z, p = (sel(x_new, x), sel(r_new, r), sel(z_new, z),
                      sel(p_new, p))
        rz = jnp.where(upd, rz_new, rz)

        # stagnation: no relative improvement over the best residual for
        # stall_window consecutive iterations -> freeze the column
        resn = _col_norms(r)
        improved = resn < best * (1.0 - _STALL_RTOL)
        best = jnp.minimum(best, resn)
        stall = jnp.where(upd & ~improved, stall + 1, 0)
        if stall_window:
            stalled = stalled | (stall >= stall_window)
        return CGLoopState(
            x=x, r=r, z=z, p=p, rz=rz, iters=s.iters + active,
            best=best, stall=stall, poisoned=poisoned, stalled=stalled,
            bad=bad, i=s.i + 1, b=s.b, tol_abs=s.tol_abs,
            rhs_bad=s.rhs_bad)

    def finish(s: CGLoopState) -> SolveResult:
        return _finish(matvec, s.b, s.x, s.tol_abs, s.iters, s.rhs_bad,
                       s.poisoned, s.stalled, s.bad, batched)

    return KrylovMachine(state=state0, cond=cond, body=body, finish=finish)


def _cg_plain(matvec: Matvec, b: Array, *, x0: Array | None = None,
              tol: float = 1e-8, maxiter: int = 1000,
              preconditioner: Matvec | None = None,
              stall_window: int = 250) -> SolveResult:
    """The forward-only CG recurrence (also the implicit VJP's inner solve)."""
    m = cg_machine(matvec, b, x0=x0, tol=tol, maxiter=maxiter,
                   preconditioner=preconditioner, stall_window=stall_window)
    return m.finish(jax.lax.while_loop(m.cond, m.body, m.state))


class MinresLoopState(NamedTuple):
    """The complete MINRES loop state (see :class:`CGLoopState`)."""

    x: Array
    v: Array
    v_prev: Array
    w: Array
    w_prev: Array
    phi_bar: Array
    delta1: Array
    eps_k: Array
    cs: Array
    sn: Array
    beta: Array
    iters: Array
    best: Array
    stall: Array
    poisoned: Array
    stalled: Array
    bad: Array
    i: Array
    b: Array
    tol_abs: Array
    rhs_bad: Array


def minres_machine(matvec: Matvec, b: Array, *, x0: Array | None = None,
                   tol: float = 1e-8, maxiter: int = 1000,
                   stall_window: int = 250) -> KrylovMachine:
    """MINRES as a resumable machine (state: :class:`MinresLoopState`)."""
    matvec, b, x0, _, batched = _as_columns(matvec, b, x0, None)
    rhs_bad, b, x0 = _validate_rhs(b, x0)
    if x0 is None:
        x, r = jnp.zeros_like(b), b  # r0 = b - A·0 (matvec elided)
    else:
        x, r = x0, b - matvec(x0)
    beta1 = _col_norms(r)  # (C,)
    tol_abs = tol * jnp.maximum(_col_norms(b), 1.0)
    dtype = b.dtype
    eps = jnp.finfo(dtype).tiny
    cshape = beta1.shape

    # Lanczos + Givens QR recurrences (standard MINRES state machine),
    # one independent recurrence per column
    v = r / jnp.maximum(beta1, eps)
    v_prev = jnp.zeros_like(b)
    w = jnp.zeros_like(b)
    w_prev = jnp.zeros_like(b)
    phi_bar = beta1
    delta1 = jnp.zeros(cshape, dtype)
    eps_k = jnp.zeros(cshape, dtype)
    cs = -jnp.ones(cshape, dtype)
    sn = jnp.zeros(cshape, dtype)
    beta = beta1
    state0 = MinresLoopState(
        x=x, v=v, v_prev=v_prev, w=w, w_prev=w_prev, phi_bar=phi_bar,
        delta1=delta1, eps_k=eps_k, cs=cs, sn=sn, beta=beta,
        iters=jnp.zeros(cshape, jnp.int32),
        best=beta1,  # best |phi_bar| so far
        stall=jnp.zeros(cshape, jnp.int32),
        poisoned=jnp.zeros(cshape, bool),
        stalled=jnp.zeros(cshape, bool),
        bad=jnp.full(cshape, -1, jnp.int32),
        i=jnp.zeros((), jnp.int32),
        b=b, tol_abs=tol_abs, rhs_bad=rhs_bad)

    def cond(s: MinresLoopState):
        alive = (jnp.abs(s.phi_bar) > s.tol_abs) & ~s.poisoned & ~s.stalled
        return jnp.logical_and(s.i < maxiter, jnp.any(alive))

    def body(s: MinresLoopState):
        (x, v, v_prev, w, w_prev, phi_bar, delta1, eps_k, cs, sn, beta) = (
            s.x, s.v, s.v_prev, s.w, s.w_prev, s.phi_bar, s.delta1,
            s.eps_k, s.cs, s.sn, s.beta)
        best, stall, poisoned, stalled, bad = (
            s.best, s.stall, s.poisoned, s.stalled, s.bad)
        i = s.i
        active = (jnp.abs(phi_bar) > s.tol_abs) & ~poisoned & ~stalled
        av = matvec(v)
        alpha = _col_dot(v, av).astype(dtype)
        av = av - alpha * v - beta * v_prev
        beta_new = _col_norms(av)
        v_new = av / jnp.maximum(beta_new, eps)

        # previous rotation
        delta2 = cs * delta1 + sn * alpha
        gamma1 = sn * delta1 - cs * alpha
        eps_next = sn * beta_new
        delta1_next = -cs * beta_new

        # new rotation
        gamma2 = jnp.sqrt(gamma1 * gamma1 + beta_new * beta_new)
        gamma2 = jnp.maximum(gamma2, eps)
        cs_new = gamma1 / gamma2
        sn_new = beta_new / gamma2
        tau = cs_new * phi_bar
        phi_bar_new = sn_new * phi_bar

        w_new = (v - delta2 * w - eps_k * w_prev) / gamma2
        x_new = x + tau * w_new

        # per-column freeze: only columns that are active AND whose update
        # stayed finite take the step — everything else (converged,
        # poisoned, stagnated, or tripping this iteration) keeps its whole
        # recurrence state, so NaNs never enter the carried arrays
        ok = (jnp.all(jnp.isfinite(x_new), axis=0)
              & jnp.all(jnp.isfinite(v_new), axis=0)
              & jnp.isfinite(phi_bar_new))
        upd = active & ok
        trip = active & ~ok
        poisoned = poisoned | trip
        bad = jnp.where(trip & (bad < 0), i, bad)
        seln = lambda new, old: jnp.where(upd[None, :], new, old)
        selc = lambda new, old: jnp.where(upd, new, old)
        x2, v2, vp2 = seln(x_new, x), seln(v_new, v), seln(v, v_prev)
        w2, wp2 = seln(w_new, w), seln(w, w_prev)
        phi_bar = selc(phi_bar_new, phi_bar)
        delta1, eps_k = selc(delta1_next, delta1), selc(eps_next, eps_k)
        cs, sn = selc(cs_new, cs), selc(sn_new, sn)
        beta = selc(beta_new, beta)

        # stagnation on the QR-recurrence residual |phi_bar|
        resn = jnp.abs(phi_bar)
        improved = resn < best * (1.0 - _STALL_RTOL)
        best = jnp.minimum(best, resn)
        stall = jnp.where(upd & ~improved, stall + 1, 0)
        if stall_window:
            stalled = stalled | (stall >= stall_window)
        return MinresLoopState(
            x=x2, v=v2, v_prev=vp2, w=w2, w_prev=wp2, phi_bar=phi_bar,
            delta1=delta1, eps_k=eps_k, cs=cs, sn=sn, beta=beta,
            iters=s.iters + active, best=best, stall=stall,
            poisoned=poisoned, stalled=stalled, bad=bad, i=i + 1,
            b=s.b, tol_abs=s.tol_abs, rhs_bad=s.rhs_bad)

    def finish(s: MinresLoopState) -> SolveResult:
        return _finish(matvec, s.b, s.x, s.tol_abs, s.iters, s.rhs_bad,
                       s.poisoned, s.stalled, s.bad, batched)

    return KrylovMachine(state=state0, cond=cond, body=body, finish=finish)


def minres(matvec: Matvec, b: Array, *, x0: Array | None = None,
           tol: float = 1e-8, maxiter: int = 1000,
           stall_window: int = 250) -> SolveResult:
    """MINRES for symmetric (possibly indefinite) operators.

    Batched ``b`` (n, C) runs per-column Lanczos + Givens recurrences in
    lockstep (all scalar recurrence state becomes (C,)-shaped); a frozen
    column — converged, poisoned, or stagnated — stops updating its whole
    recurrence (iterate *and* Lanczos state), so a non-finite column can
    never leak into its siblings.  Guard flags land in ``result.health``;
    ``stall_window=0`` disables stagnation detection.
    """
    m = minres_machine(matvec, b, x0=x0, tol=tol, maxiter=maxiter,
                       stall_window=stall_window)
    return m.finish(jax.lax.while_loop(m.cond, m.body, m.state))


# ---------------------------------------------------------------------------
# Lockstep bank solvers: one bank matvec per iteration for S·C systems.
# ---------------------------------------------------------------------------

def _bank_solve(solver, bank_matvec: Matvec, b: Array, x0: Array | None,
                kwargs) -> SolveResult:
    """Flatten the bank axis into the column axis and run a lockstep solve.

    ``bank_matvec`` maps (S, n, C) -> (S, n, C) applying operator ``s`` to
    ``x[s]`` (e.g. the lockstep flavor of ``FastsumOperatorBank.matvec``);
    the per-column machinery of :func:`cg`/:func:`minres` then gives every
    (s, c) system its own step sizes, tolerance ``tol * max(||b[s,:,c]||,
    1)``, and convergence mask — while each iteration costs exactly one bank
    matvec (one spread + one forward FFT for the whole sweep).
    """
    if b.ndim not in (2, 3):
        raise ValueError(f"bank rhs must be (S, n) or (S, n, C), got {b.shape}")
    squeeze = b.ndim == 2
    b3 = b[..., None] if squeeze else b
    s, n, c = b3.shape

    def flat_mv(u):  # (n, S*C) -> (n, S*C)
        xb = jnp.moveaxis(u.reshape(n, s, c), 1, 0)
        yb = bank_matvec(xb)
        return jnp.moveaxis(yb, 0, 1).reshape(n, s * c)

    def to_flat(v):  # (S, n, C) -> (n, S*C)
        return jnp.moveaxis(v, 0, 1).reshape(n, s * c)

    def from_flat(v):  # (n, S*C) -> (S, n, C)
        return jnp.moveaxis(v.reshape(n, s, c), 1, 0)

    x0f = None if x0 is None else to_flat(x0[..., None] if squeeze else x0)
    sol = solver(flat_mv, to_flat(b3), x0=x0f, **kwargs)
    x = from_flat(sol.x)
    stats = [a.reshape(s, c) for a in
             (sol.num_iters, sol.residual_norm, sol.converged)]
    health = SolveHealth(*(a.reshape(s, c) for a in sol.health))
    if squeeze:
        x = x[..., 0]
        stats = [a[:, 0] for a in stats]
        health = SolveHealth(*(a[:, 0] for a in health))
    return SolveResult(x, *stats, health=health)


def cg_bank(bank_matvec: Matvec, b: Array, *, x0: Array | None = None,
            tol: float = 1e-8, maxiter: int = 1000,
            stall_window: int = 250) -> SolveResult:
    """Lockstep CG over a bank axis: b (S, n) or (S, n, C).

    One bank matvec per iteration solves all S·C systems; per-system
    tolerance masks freeze converged systems; the true residual is
    recomputed at exit.  Result fields mirror the input layout: ``x``
    (S, n[, C]), ``num_iters``/``residual_norm``/``converged`` (S[, C]),
    and ``health`` fields likewise (S[, C]) — a poisoned tenant's system
    is quarantined without touching its bank siblings.
    """
    return _bank_solve(cg, bank_matvec, b, x0,
                       dict(tol=tol, maxiter=maxiter,
                            stall_window=stall_window))


def minres_bank(bank_matvec: Matvec, b: Array, *, x0: Array | None = None,
                tol: float = 1e-8, maxiter: int = 1000,
                stall_window: int = 250) -> SolveResult:
    """Lockstep MINRES over a bank axis (see :func:`cg_bank`)."""
    return _bank_solve(minres, bank_matvec, b, x0,
                       dict(tol=tol, maxiter=maxiter,
                            stall_window=stall_window))
