"""Krylov linear solvers driven by fast matvecs (paper Sections 4, 6.2.3, 6.3).

Conjugate Gradients (Hestenes–Stiefel) and MINRES (Paige–Saunders), both
matrix-free and jit-compatible (``lax.while_loop``).  Used for

    (I + beta L_s) u = f        (kernel SSL, Eq. 6.4)
    (K + beta I) alpha = f      (kernel ridge regression, Section 6.3)

with the matvec supplied by Algorithm 3.1/3.2 operators.
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array
Matvec = Callable[[Array], Array]


class SolveResult(NamedTuple):
    x: Array
    num_iters: Array
    residual_norm: Array
    converged: Array


def cg(matvec: Matvec, b: Array, *, x0: Array | None = None,
       tol: float = 1e-8, maxiter: int = 1000,
       preconditioner: Matvec | None = None) -> SolveResult:
    """Preconditioned conjugate gradients for SPD operators."""
    x = jnp.zeros_like(b) if x0 is None else x0
    r = b - matvec(x)
    z = preconditioner(r) if preconditioner is not None else r
    p = z
    rz = jnp.vdot(r, z).real
    b_norm = jnp.linalg.norm(b)
    tol_abs = tol * jnp.maximum(b_norm, 1.0)

    def cond(state):
        x, r, z, p, rz, i = state
        return jnp.logical_and(i < maxiter, jnp.linalg.norm(r) > tol_abs)

    def body(state):
        x, r, z, p, rz, i = state
        ap = matvec(p)
        denom = jnp.vdot(p, ap).real
        alpha = rz / jnp.where(denom != 0, denom, 1.0)
        x = x + alpha * p
        r = r - alpha * ap
        z_new = preconditioner(r) if preconditioner is not None else r
        rz_new = jnp.vdot(r, z_new).real
        beta = rz_new / jnp.where(rz != 0, rz, 1.0)
        p = z_new + beta * p
        return x, r, z_new, p, rz_new, i + 1

    x, r, z, p, rz, iters = jax.lax.while_loop(
        cond, body, (x, r, z, p, rz, jnp.zeros((), jnp.int32)))
    # The recurrence residual r drifts from b - A x on ill-conditioned
    # operators (finite-precision rounding breaks the exact update
    # invariant), so the loop can report convergence the iterate doesn't
    # have.  One extra matvec recomputes the true residual at exit so
    # residual_norm / converged reflect the returned x.
    res = jnp.linalg.norm(b - matvec(x))
    return SolveResult(x=x, num_iters=iters, residual_norm=res,
                       converged=res <= tol_abs)


def minres(matvec: Matvec, b: Array, *, x0: Array | None = None,
           tol: float = 1e-8, maxiter: int = 1000) -> SolveResult:
    """MINRES for symmetric (possibly indefinite) operators."""
    x = jnp.zeros_like(b) if x0 is None else x0
    r = b - matvec(x)
    beta1 = jnp.linalg.norm(r)
    b_norm = jnp.maximum(jnp.linalg.norm(b), 1.0)
    tol_abs = tol * b_norm
    dtype = b.dtype
    eps = jnp.finfo(dtype).tiny

    # Lanczos + Givens QR recurrences (standard MINRES state machine)
    v = r / jnp.maximum(beta1, eps)
    v_prev = jnp.zeros_like(b)
    w = jnp.zeros_like(b)
    w_prev = jnp.zeros_like(b)
    phi_bar = beta1
    delta1 = jnp.zeros((), dtype)
    eps_k = jnp.zeros((), dtype)
    cs = -jnp.ones((), dtype)
    sn = jnp.zeros((), dtype)
    beta = beta1

    def cond(state):
        (x, v, v_prev, w, w_prev, phi_bar, delta1, eps_k, cs, sn, beta, i) = state
        return jnp.logical_and(i < maxiter, jnp.abs(phi_bar) > tol_abs)

    def body(state):
        (x, v, v_prev, w, w_prev, phi_bar, delta1, eps_k, cs, sn, beta, i) = state
        av = matvec(v)
        alpha = jnp.vdot(v, av).real.astype(dtype)
        av = av - alpha * v - beta * v_prev
        beta_new = jnp.linalg.norm(av)
        v_new = av / jnp.maximum(beta_new, eps)

        # previous rotation
        delta2 = cs * delta1 + sn * alpha
        gamma1 = sn * delta1 - cs * alpha
        eps_next = sn * beta_new
        delta1_next = -cs * beta_new

        # new rotation
        gamma2 = jnp.sqrt(gamma1 * gamma1 + beta_new * beta_new)
        gamma2 = jnp.maximum(gamma2, eps)
        cs_new = gamma1 / gamma2
        sn_new = beta_new / gamma2
        tau = cs_new * phi_bar
        phi_bar_new = sn_new * phi_bar

        w_new = (v - delta2 * w - eps_k * w_prev) / gamma2
        x_new = x + tau * w_new
        return (x_new, v_new, v, w_new, w, phi_bar_new, delta1_next,
                eps_next, cs_new, sn_new, beta_new, i + 1)

    init = (x, v, v_prev, w, w_prev, phi_bar, delta1, eps_k, cs, sn, beta,
            jnp.zeros((), jnp.int32))
    (x, v, v_prev, w, w_prev, phi_bar, delta1, eps_k, cs, sn, beta, iters) = (
        jax.lax.while_loop(cond, body, init))
    # |phi_bar| is the QR-recurrence residual; like CG's it drifts from
    # ||b - A x|| in finite precision.  Recompute the true residual once at
    # exit (one matvec) so the reported norm matches the returned iterate.
    res = jnp.linalg.norm(b - matvec(x))
    return SolveResult(x=x, num_iters=iters, residual_norm=res,
                       converged=res <= tol_abs)
