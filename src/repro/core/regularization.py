"""Kernel regularization and Fourier coefficients (paper Section 3, Eq. (3.4)).

``K_R`` is the 1-periodic smooth continuation of the kernel:

    K_R(y) = K(y)            if ||y|| <= 1/2 - eps_B
           = T_B(||y||)      if 1/2 - eps_B < ||y|| <= 1/2
           = T_B(1/2)        otherwise (cube corners),

where ``T_B`` is a two-point Taylor (Hermite) transition polynomial.  We use
the unique polynomial of degree ``2p-2`` satisfying

    T_B^(j)(a) = K^(j)(a),  j = 0..p-1,   a = 1/2 - eps_B,
    T_B^(j)(b) = 0,         j = 1..p-1,   b = 1/2,

(the boundary *value* ``T_B(b)`` is left free and falls out of the solve; all
first ``p-1`` derivatives vanish at the boundary so the radial profile
continues smoothly into the constant corner region and across the periodic
boundary).  This differs from NFFT3's degree-``2p-1`` variant; both satisfy
the paper's smoothness requirement (``K_R`` is ``p-1`` times continuously
differentiable as a periodic function) — see DESIGN.md §8.

The Fourier coefficients of the trigonometric approximant ``K_RF`` are the
trapezoidal-rule/DFT approximation (Eq. (3.4)):

    b_hat[l] = (1/N^d) * sum_{j in I_N^d} K_R(j/N) e^{-2 pi i j.l / N}.

All coefficient arrays are kept in **FFT order** (numpy ``fftfreq``
convention) throughout the code base; no fftshift is ever applied.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kernels import Kernel


def kernel_radial_derivatives(kernel: Kernel, r0: float, order: int) -> jnp.ndarray:
    """Values ``[K(r0), K'(r0), ..., K^(order-1)(r0)]`` via nested jax.grad.

    Evaluated in float64 at setup time, *eagerly*: jitting the grad chain
    here compiled ``order`` fresh scalar XLA programs per kernel instance —
    ~150 ms of pure compile per member of a sigma sweep, for a computation
    that runs in microseconds op-by-op.  Returns a jnp vector so the chain
    stays differentiable w.r.t. traced kernel parameters (the nested
    ``jax.grad`` is over ``r`` only; parameter tracers captured by ``phi``
    flow through as constants of that inner differentiation).
    """
    derivs = []
    f = lambda r: kernel.phi(r)
    g = f
    for _ in range(order):
        derivs.append(g(jnp.float64(r0)))
        g = jax.grad(g)
    return jnp.stack([jnp.asarray(v, dtype=jnp.float64) for v in derivs])


def two_point_taylor(kernel: Kernel, p: int, eps_b: float) -> jnp.ndarray:
    """Coefficients (ascending, in t=(r-a)/(b-a)) of the transition poly T_B.

    Returns ``coeffs`` such that ``T_B(r) = sum_k coeffs[k] * t**k`` with
    ``t = (r - a)/(b - a)``, ``a = 1/2 - eps_B``, ``b = 1/2``.  The linear
    system matrix depends only on the static (p, eps_B) and stays numpy; the
    right-hand side carries the kernel derivatives, so the returned
    coefficients are differentiable w.r.t. traced kernel parameters.
    """
    assert p >= 1
    a = 0.5 - eps_b
    h = eps_b  # b - a
    n_coef = 2 * p - 1  # degree 2p-2
    A = np.zeros((n_coef, n_coef))

    # Conditions at t=0 (r=a): T^(j)(a) = K^(j)(a) * h^j (chain rule in t).
    kd = kernel_radial_derivatives(kernel, a, p)
    rhs_head = kd * jnp.asarray([h ** j for j in range(p)], dtype=kd.dtype)
    for j in range(p):
        # d^j/dt^j of t^k at t=0 is j! * [k == j]
        A[j, j] = float(_fact(j))

    # Conditions at t=1 (r=b): T^(j)(b) = 0 for j=1..p-1.
    for idx, j in enumerate(range(1, p)):
        row = p + idx
        for k in range(j, n_coef):
            A[row, k] = _falling(k, j)

    rhs = jnp.concatenate(
        [rhs_head, jnp.zeros(n_coef - p, dtype=rhs_head.dtype)])
    coeffs = jnp.linalg.solve(jnp.asarray(A, dtype=rhs.dtype), rhs)
    return coeffs


def _fact(j: int) -> int:
    out = 1
    for i in range(2, j + 1):
        out *= i
    return out


def _falling(k: int, j: int) -> float:
    out = 1.0
    for i in range(j):
        out *= (k - i)
    return out


def regularized_kernel_profile(kernel: Kernel, p: int, eps_b: float):
    """Returns a vectorized radial profile ``K_R(r)`` (JAX traceable).

    With ``eps_B == 0`` no transition is applied (``K_R = K`` inside the ball,
    constant ``K(1/2)`` outside) — the paper's setups #1–#3 use eps_B = 0.
    """
    a = 0.5 - eps_b
    if eps_b <= 0.0:
        edge = kernel.phi(jnp.float64(0.5))

        def profile(r):
            r = jnp.asarray(r)
            return jnp.where(r <= 0.5, kernel.phi(jnp.minimum(r, 0.5)), edge)

        return profile

    coeffs = jnp.asarray(two_point_taylor(kernel, p, eps_b))

    def t_poly(r):
        t = (r - a) / eps_b
        return jnp.polyval(coeffs[::-1], t)

    edge_val = t_poly(jnp.float64(0.5))

    def profile(r):
        r = jnp.asarray(r)
        inner = kernel.phi(jnp.minimum(r, a))
        trans = t_poly(jnp.clip(r, a, 0.5))
        return jnp.where(r <= a, inner, jnp.where(r <= 0.5, trans, edge_val))

    return profile


def kernel_fourier_coefficients(
    kernel: Kernel, d: int, n_bandwidth: int, p: int, eps_b: float
) -> jnp.ndarray:
    """Fourier coefficients ``b_hat`` of K_RF on the full I_N^d grid (Eq. 3.4).

    Returns a complex array of shape ``(N,)*d`` in FFT order.  For the paper's
    real even kernels the imaginary part is ~machine-eps; it is kept so that
    the fastsum operator stays exactly linear/Hermitian.
    """
    n = n_bandwidth
    profile = regularized_kernel_profile(kernel, p, eps_b)
    # Sample positions j/N for j in I_N = {-N/2, ..., N/2-1}, in FFT order.
    freqs = jnp.fft.fftfreq(n, d=1.0 / n)  # [0, 1, ..., N/2-1, -N/2, ..., -1]
    coords = freqs / n  # j/N in FFT order
    grids = jnp.meshgrid(*([coords] * d), indexing="ij")
    radius = jnp.sqrt(sum(g * g for g in grids))
    samples = profile(radius)
    return jnp.fft.fftn(samples) / (n ** d)


def trigonometric_eval(b_hat: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Direct evaluation of ``K_RF(y) = sum_l b_hat[l] e^{2 pi i l.y}``.

    Reference/oracle only — O(N^d) per point.  ``y``: (..., d).
    """
    d = b_hat.ndim
    n = b_hat.shape[0]
    freqs = jnp.fft.fftfreq(n, d=1.0 / n)  # integer frequencies, FFT order
    grids = jnp.meshgrid(*([freqs] * d), indexing="ij")
    l = jnp.stack([g.reshape(-1) for g in grids], axis=-1)  # (N^d, d)
    phase = 2j * jnp.pi * jnp.einsum("...d,ld->...l", y, l)
    return jnp.einsum("l,...l->...", b_hat.reshape(-1), jnp.exp(phase))
