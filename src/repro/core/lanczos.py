"""Lanczos method for extremal eigenpairs (paper Section 4).

``lanczos(matvec, n, k_iters)`` builds the tridiagonalization

    A Q_k = Q_k T_k + beta_{k+1} q_{k+1} e_k^T

with *full reorthogonalization* (two-pass classical Gram-Schmidt per step —
the tall-skinny ``Q^T v`` / ``Q y`` products are MXU-friendly matmuls, see
DESIGN.md §3).  Eigenpairs of A come from the Ritz pairs of T_k.

``eigsh`` is the user-facing driver: runs Lanczos to a fixed subspace size
(or until the residual bound ``|beta_{k+1} w_k|`` converges), then extracts
the ``k`` algebraically largest (or smallest) Ritz pairs.

Everything is jit-compatible: the iteration is a ``lax.fori_loop`` over a
preallocated basis, the matvec is an arbitrary traceable callable (dense,
fast-summation, or Pallas-backed).
"""

from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
Matvec = Callable[[Array], Array]


class LanczosResult(NamedTuple):
    alphas: Array  # (k,) diagonal of T
    betas: Array  # (k,) sub-diagonal; betas[i>=1] couples q_i to q_{i+1},
    #   betas[0] is never written and stays 0 (v0 is normalized before the
    #   iteration, so no ||r0|| is recorded anywhere)
    basis: Array  # (k, n) rows are the Lanczos vectors q_1..q_k
    residual_beta: Array  # beta_{k+1}
    breakdown_iter: Array | None = None  # first step with a non-finite
    #   recurrence (scalar int32); == num_iters when the run stayed clean.
    #   Steps at/after it never write into alphas/betas/basis.


class EigshHealth(NamedTuple):
    """Guard flags for an eigsh run (see :class:`repro.core.SolveHealth`).

    ``nonfinite`` — the Lanczos recurrence went non-finite (poisoned
    matvec, breakdown); the subspace was truncated at ``breakdown_iter``
    and the invalid tail of T was sentinel-masked out of the returned
    Ritz window, but ``residual_bounds`` are inf: do not trust the pairs.
    """

    nonfinite: Array  # bool scalar
    breakdown_iter: Array  # int32 scalar, == subspace size when clean


class LanczosLoopState(NamedTuple):
    """Checkpointable Lanczos iteration state (the ``fori_loop`` carry plus
    the step index).  ``lanczos_machine`` + segmented ``fori_loop`` runs
    reproduce :func:`lanczos` bit-identically: the body is a deterministic
    function of ``(i, carry)`` alone."""

    basis: Array      # (num_iters, n)
    alphas: Array     # (num_iters,)
    betas: Array      # (num_iters,)
    beta_next: Array  # scalar: coupling into the next step
    breakdown: Array  # scalar int32
    i: Array          # next step index (scalar int32)


def lanczos_machine(matvec: Matvec, v0: Array, num_iters: int,
                    *, reorthogonalize: bool = True):
    """Lanczos in resumable form: ``(state0, body, finish)``.

    ``body(i, carry)`` is a ``fori_loop`` body over the 5-tuple carry
    ``state[:-1]``; running steps ``[i0, i1)`` in any segmentation yields
    the same trajectory.  ``finish(state)`` wraps a :class:`LanczosResult`.
    """
    n = v0.shape[0]
    dtype = v0.dtype
    q = v0 / jnp.linalg.norm(v0)

    basis = jnp.zeros((num_iters, n), dtype=dtype).at[0].set(q)
    alphas = jnp.zeros((num_iters,), dtype=dtype)
    betas = jnp.zeros((num_iters,), dtype=dtype)

    def body(i, carry):
        basis, alphas, betas, beta_next, breakdown = carry
        alive = i < breakdown
        qi = basis[i]
        w = matvec(qi)
        alpha = jnp.vdot(qi, w).real.astype(dtype)
        w = w - alpha * qi - jnp.where(i > 0, betas[i], 0.0) * basis[jnp.maximum(i - 1, 0)]
        if reorthogonalize:
            # two-pass CGS against the filled part of the basis
            mask = (jnp.arange(num_iters) <= i)[:, None].astype(dtype)
            for _ in range(2):
                coeffs = (basis * mask) @ w
                w = w - ((basis * mask).T @ coeffs)
        beta = jnp.linalg.norm(w)
        # breakdown guard: a non-finite recurrence step (poisoned matvec)
        # truncates the factorization — nothing at/after it is ever
        # written, so NaNs cannot enter the carried basis or T entries
        ok = alive & jnp.isfinite(alpha) & jnp.isfinite(beta)
        breakdown = jnp.where(alive & ~ok, i, breakdown)
        alphas = alphas.at[i].set(jnp.where(ok, alpha, 0.0))
        write = jnp.logical_and(i + 1 < num_iters, ok)
        q_next = jnp.where(beta > 0, w / jnp.maximum(beta, jnp.finfo(dtype).tiny), 0.0)
        basis = jax.lax.cond(
            write,
            lambda b: b.at[i + 1].set(q_next),
            lambda b: b,
            basis,
        )
        betas = jax.lax.cond(
            write,
            lambda b: b.at[i + 1].set(beta),
            lambda b: b,
            betas,
        )
        return basis, alphas, betas, jnp.where(ok, beta, 0.0), breakdown

    state0 = LanczosLoopState(
        basis=basis, alphas=alphas, betas=betas,
        beta_next=jnp.zeros((), dtype),
        breakdown=jnp.asarray(num_iters, jnp.int32),
        i=jnp.zeros((), jnp.int32))

    def finish(state: LanczosLoopState) -> LanczosResult:
        return LanczosResult(alphas=state.alphas, betas=state.betas,
                             basis=state.basis,
                             residual_beta=state.beta_next,
                             breakdown_iter=state.breakdown)

    return state0, body, finish


def lanczos(matvec: Matvec, v0: Array, num_iters: int,
            *, reorthogonalize: bool = True) -> LanczosResult:
    """Run ``num_iters`` Lanczos steps from start vector ``v0``."""
    state0, body, finish = lanczos_machine(
        matvec, v0, num_iters, reorthogonalize=reorthogonalize)
    carry = jax.lax.fori_loop(0, num_iters, body, tuple(state0)[:-1])
    return finish(LanczosLoopState(*carry,
                                   i=jnp.asarray(num_iters, jnp.int32)))


class BlockLanczosResult(NamedTuple):
    t_matrix: Array  # (s, s) block-tridiagonal projection, s = blocks*b
    basis: Array  # (blocks, n, b) orthonormal block Lanczos basis
    residual_block: Array  # (b, b) B_{blocks+1} (R factor of the residual)
    breakdown_iter: Array | None = None  # first block step with a
    #   non-finite recurrence; == num_blocks when clean


class BlockLanczosLoopState(NamedTuple):
    """Checkpointable block-Lanczos iteration state (see
    :class:`LanczosLoopState`)."""

    basis: Array     # (num_blocks, n, b)
    a_blocks: Array  # (num_blocks, b, b)
    b_blocks: Array  # (num_blocks, b, b)
    resid: Array     # (b, b)
    breakdown: Array
    i: Array


def block_lanczos_machine(matvec: Matvec, v0: Array, num_blocks: int,
                          *, reorthogonalize: bool = True):
    """Block Lanczos in resumable ``(state0, body, finish)`` form."""
    n, b = v0.shape
    dtype = v0.dtype
    q0, _ = jnp.linalg.qr(v0)

    basis = jnp.zeros((num_blocks, n, b), dtype=dtype).at[0].set(q0)
    a_blocks = jnp.zeros((num_blocks, b, b), dtype=dtype)
    b_blocks = jnp.zeros((num_blocks, b, b), dtype=dtype)  # B_j couples j-1,j

    def body(j, carry):
        basis, a_blocks, b_blocks, resid, breakdown = carry
        qj = basis[j]
        w = matvec(qj)  # (n, b): one batched operator application
        a = qj.T @ w
        a = 0.5 * (a + a.T)  # exact symmetry of the diagonal block
        w = w - qj @ a
        w = w - jnp.where(j > 0, 1.0, 0.0) * (
            basis[jnp.maximum(j - 1, 0)] @ b_blocks[j].T)
        if reorthogonalize:
            # two-pass block CGS against the filled part of the basis
            mask = (jnp.arange(num_blocks) <= j)[:, None, None].astype(dtype)
            flat = jnp.moveaxis(basis * mask, 1, 0).reshape(n, num_blocks * b)
            for _ in range(2):
                coeffs = flat.T @ w  # (blocks*b, b)
                w = w - flat @ coeffs
        q_next, r_next = jnp.linalg.qr(w)
        # breakdown guard: truncate the factorization at the first block
        # step with a non-finite recurrence (see ``lanczos``)
        alive = j < breakdown
        ok = alive & jnp.all(jnp.isfinite(a)) & jnp.all(jnp.isfinite(r_next))
        breakdown = jnp.where(alive & ~ok, j, breakdown)
        write = jnp.logical_and(j + 1 < num_blocks, ok)
        basis = jax.lax.cond(
            write, lambda bb: bb.at[j + 1].set(q_next), lambda bb: bb, basis)
        b_blocks = jax.lax.cond(
            write, lambda bb: bb.at[j + 1].set(r_next), lambda bb: bb,
            b_blocks)
        a_blocks = a_blocks.at[j].set(jnp.where(ok, a, 0.0))
        return (basis, a_blocks, b_blocks,
                jnp.where(ok, r_next, 0.0), breakdown)

    state0 = BlockLanczosLoopState(
        basis=basis, a_blocks=a_blocks, b_blocks=b_blocks,
        resid=jnp.zeros((b, b), dtype),
        breakdown=jnp.asarray(num_blocks, jnp.int32),
        i=jnp.zeros((), jnp.int32))

    def finish(state: BlockLanczosLoopState) -> BlockLanczosResult:
        a_blocks, b_blocks, breakdown = (state.a_blocks, state.b_blocks,
                                         state.breakdown)
        s = num_blocks * b
        t = jnp.zeros((s, s), dtype=dtype)
        for j in range(num_blocks):
            t = jax.lax.dynamic_update_slice(t, a_blocks[j], (j * b, j * b))
            if j > 0:
                # A Q_{j-1} = ... + Q_j R_j  =>  lower block (j, j-1) is R_j;
                # the coupling into the first dead block is zeroed so the
                # sentinel-masked tail stays decoupled from the valid head
                bj = jnp.where(j < breakdown, 1.0, 0.0) * b_blocks[j]
                t = jax.lax.dynamic_update_slice(t, bj.T,
                                                 ((j - 1) * b, j * b))
                t = jax.lax.dynamic_update_slice(t, bj, (j * b, (j - 1) * b))
        return BlockLanczosResult(t_matrix=t, basis=state.basis,
                                  residual_block=state.resid,
                                  breakdown_iter=breakdown)

    return state0, body, finish


def block_lanczos(matvec: Matvec, v0: Array, num_blocks: int,
                  *, reorthogonalize: bool = True) -> BlockLanczosResult:
    """Block Lanczos with block size ``b = v0.shape[1]`` (paper Section 4).

    Each step applies the operator to a whole (n, b) block — a single fused
    multi-RHS matvec that amortizes spread/gather — and orthogonalizes with
    tall-skinny matmuls (MXU-friendly: (s*b, n) @ (n, b)).  Builds

        A Q = Q T + Q_{next} B_{next} E_last^T

    with T block-tridiagonal (diagonal blocks A_j, off-diagonal B_j^T/B_j).
    """
    state0, body, finish = block_lanczos_machine(
        matvec, v0, num_blocks, reorthogonalize=reorthogonalize)
    carry = jax.lax.fori_loop(0, num_blocks, body, tuple(state0)[:-1])
    return finish(BlockLanczosLoopState(
        *carry, i=jnp.asarray(num_blocks, jnp.int32)))


class EigshResult(NamedTuple):
    eigenvalues: Array  # (k,) sorted descending (largest) / ascending (smallest)
    eigenvectors: Array  # (n, k)
    residual_bounds: Array  # (k,) |beta_{m+1} w_m| per Ritz pair
    num_iters: int
    num_matvecs: int = 0  # operator applications (block counts as one)
    health: EigshHealth | None = None


def _sentinel_mask(t: Array, valid: Array, which: str) -> Array:
    """Push the dead (breakdown-truncated, all-zero) tail of T out of the
    requested Ritz window: its diagonal gets a sentinel far on the *wrong*
    side of the spectrum, so argsort never selects a dead pair while shapes
    stay static."""
    amax = jnp.max(jnp.abs(t))
    sentinel = (amax + 1.0) * 1e3
    if which == "LA":
        sentinel = -sentinel
    return t + jnp.diag(jnp.where(valid, 0.0, sentinel))


class EigshSetup(NamedTuple):
    """Resolved eigsh run configuration.

    A deterministic function of the :func:`eigsh` call arguments — shared
    with the durable driver (:mod:`repro.runtime.durable`) so a resumed run
    rebuilds the *identical* iteration (same subspace size, same shrunken
    block, same PRNG-derived start vectors) and only the loop state needs
    checkpointing.  ``num_blocks == 0`` marks the single-vector path.
    """

    k: int
    which: str
    num_iters: int
    block_size: int
    num_blocks: int
    v0: Array


def eigsh_setup(n: int, k: int, *, num_iters: int | None = None,
                which: str = "LA", key: Array | None = None,
                dtype=jnp.float64, v0: Array | None = None,
                block_size: int = 1) -> EigshSetup:
    """Resolve the full eigsh configuration (see :class:`EigshSetup`)."""
    if which not in ("LA", "SA"):
        raise ValueError(which)
    if num_iters is None:
        num_iters = min(n, max(2 * k + 20, 30))
    num_iters = min(num_iters, n)
    if key is None:
        key = jax.random.PRNGKey(0)

    if block_size > 1:
        if v0 is not None:
            block_size = v0.shape[1]
        # Shrink oversized blocks: the subspace dimension
        # num_blocks * block_size must not exceed n (past that the residual
        # is rank-deficient and QR manufactures orthonormal-but-meaningless
        # directions) yet must still reach min(k, n) so the caller gets the
        # k pairs it asked for.
        block_size = min(block_size, max(n // 2, 1))
        need = min(k, n)
        while block_size > 1 and (n // block_size) * block_size < need:
            block_size -= 1
        if v0 is not None and v0.shape[1] > block_size:
            # the shrinking above reduced the block below the caller's v0
            # width (small n, non-dividing block): keep the leading columns
            v0 = v0[:, :block_size]
        num_blocks = max(min(-(-num_iters // block_size), n // block_size),
                         -(-need // block_size))
        if v0 is None:
            v0 = jax.random.normal(key, (n, block_size), dtype=dtype)
        return EigshSetup(k=k, which=which, num_iters=num_iters,
                          block_size=block_size, num_blocks=num_blocks,
                          v0=v0)

    if v0 is None:
        v0 = jax.random.normal(key, (n,), dtype=dtype)
    return EigshSetup(k=k, which=which, num_iters=num_iters, block_size=1,
                      num_blocks=0, v0=v0)


def ritz_from_block(res: BlockLanczosResult, setup: EigshSetup,
                    n: int) -> EigshResult:
    """Ritz extraction from a finished block-Lanczos factorization."""
    k, which = setup.k, setup.which
    num_blocks, block_size = setup.num_blocks, setup.block_size
    broke = res.breakdown_iter < num_blocks
    valid = jnp.repeat(jnp.arange(num_blocks) < res.breakdown_iter,
                       block_size)
    theta, w = jnp.linalg.eigh(_sentinel_mask(res.t_matrix, valid, which))
    basis_flat = jnp.moveaxis(res.basis, 1, 0).reshape(
        n, num_blocks * block_size)
    order = (jnp.argsort(-theta) if which == "LA"
             else jnp.argsort(theta))[:k]
    theta_k = theta[order]
    w_k = w[:, order]
    vecs = basis_flat @ w_k
    bottom = w_k[-block_size:, :]  # (b, k) last-block Ritz components
    bounds = jnp.linalg.norm(res.residual_block @ bottom, axis=0)
    bounds = jnp.where(broke, jnp.inf, bounds)
    return EigshResult(eigenvalues=theta_k, eigenvectors=vecs,
                       residual_bounds=bounds,
                       num_iters=num_blocks * block_size,
                       num_matvecs=num_blocks,
                       health=EigshHealth(
                           nonfinite=broke,
                           breakdown_iter=res.breakdown_iter))


def ritz_from_lanczos(res: LanczosResult, setup: EigshSetup) -> EigshResult:
    """Ritz extraction from a finished single-vector Lanczos run."""
    k, which, num_iters = setup.k, setup.which, setup.num_iters
    broke = res.breakdown_iter < num_iters
    valid = jnp.arange(num_iters) < res.breakdown_iter
    # dead betas (coupling into the first dead step) are zeroed so the
    # sentinel tail stays decoupled from the valid leading block of T
    off = jnp.where(valid[1:], res.betas[1:], 0.0)
    # T_k is (num_iters x num_iters) tridiagonal
    t = jnp.diag(res.alphas) + jnp.diag(off, 1) + jnp.diag(off, -1)
    theta, w = jnp.linalg.eigh(_sentinel_mask(t, valid, which))  # ascending
    order = (jnp.argsort(-theta) if which == "LA"
             else jnp.argsort(theta))[:k]
    theta_k = theta[order]
    w_k = w[:, order]
    vecs = res.basis.T @ w_k  # (n, k)
    bounds = jnp.abs(res.residual_beta * w_k[-1, :])
    bounds = jnp.where(broke, jnp.inf, bounds)
    return EigshResult(eigenvalues=theta_k, eigenvectors=vecs,
                       residual_bounds=bounds, num_iters=num_iters,
                       num_matvecs=num_iters,
                       health=EigshHealth(nonfinite=broke,
                                          breakdown_iter=res.breakdown_iter))


def eigsh(matvec: Matvec, n: int, k: int, *, num_iters: int | None = None,
          which: str = "LA", key: Array | None = None,
          dtype=jnp.float64, v0: Array | None = None,
          block_size: int = 1) -> EigshResult:
    """Largest-/smallest-algebraic eigenpairs of a symmetric operator.

    ``which``: 'LA' (largest algebraic, the paper's use case for
    A = D^{-1/2} W D^{-1/2}) or 'SA' (smallest — e.g. for L_s directly).

    ``block_size > 1`` runs block Lanczos: ``num_iters`` still means the
    Krylov subspace dimension, but the operator is applied to (n, block)
    batches, so the number of matvec invocations drops by ~``block_size``
    (the fused fastsum engine executes a block in one spread/FFT/gather
    pass).  The matvec callable must accept (n, C) input in that case.
    """
    setup = eigsh_setup(n, k, num_iters=num_iters, which=which, key=key,
                        dtype=dtype, v0=v0, block_size=block_size)
    if setup.num_blocks:
        res = block_lanczos(matvec, setup.v0, setup.num_blocks)
        return ritz_from_block(res, setup, n)
    res = lanczos(matvec, setup.v0, setup.num_iters)
    return ritz_from_lanczos(res, setup)


def eigsh_smallest_laplacian(adjacency_matvec: Matvec, n: int, k: int,
                             **kw) -> EigshResult:
    """Smallest eigenpairs of L_s = I - A via largest of A (paper Section 2).

    Returns eigenvalues of L_s (= 1 - theta) with the same eigenvectors.
    """
    res = eigsh(adjacency_matvec, n, k, which="LA", **kw)
    return EigshResult(eigenvalues=1.0 - res.eigenvalues,
                       eigenvectors=res.eigenvectors,
                       residual_bounds=res.residual_bounds,
                       num_iters=res.num_iters,
                       num_matvecs=res.num_matvecs,
                       health=res.health)
