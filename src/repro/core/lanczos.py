"""Lanczos method for extremal eigenpairs (paper Section 4).

``lanczos(matvec, n, k_iters)`` builds the tridiagonalization

    A Q_k = Q_k T_k + beta_{k+1} q_{k+1} e_k^T

with *full reorthogonalization* (two-pass classical Gram-Schmidt per step —
the tall-skinny ``Q^T v`` / ``Q y`` products are MXU-friendly matmuls, see
DESIGN.md §3).  Eigenpairs of A come from the Ritz pairs of T_k.

``eigsh`` is the user-facing driver: runs Lanczos to a fixed subspace size
(or until the residual bound ``|beta_{k+1} w_k|`` converges), then extracts
the ``k`` algebraically largest (or smallest) Ritz pairs.

Everything is jit-compatible: the iteration is a ``lax.fori_loop`` over a
preallocated basis, the matvec is an arbitrary traceable callable (dense,
fast-summation, or Pallas-backed).
"""

from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
Matvec = Callable[[Array], Array]


class LanczosResult(NamedTuple):
    alphas: Array  # (k,) diagonal of T
    betas: Array  # (k,) sub-diagonal; betas[0] = ||r0||, betas[i>0] live
    basis: Array  # (k, n) rows are the Lanczos vectors q_1..q_k
    residual_beta: Array  # beta_{k+1}


def lanczos(matvec: Matvec, v0: Array, num_iters: int,
            *, reorthogonalize: bool = True) -> LanczosResult:
    """Run ``num_iters`` Lanczos steps from start vector ``v0``."""
    n = v0.shape[0]
    dtype = v0.dtype
    q = v0 / jnp.linalg.norm(v0)

    basis = jnp.zeros((num_iters, n), dtype=dtype).at[0].set(q)
    alphas = jnp.zeros((num_iters,), dtype=dtype)
    betas = jnp.zeros((num_iters,), dtype=dtype)

    def body(i, carry):
        basis, alphas, betas, beta_next = carry
        qi = basis[i]
        w = matvec(qi)
        alpha = jnp.vdot(qi, w).real.astype(dtype)
        w = w - alpha * qi - jnp.where(i > 0, betas[i], 0.0) * basis[jnp.maximum(i - 1, 0)]
        if reorthogonalize:
            # two-pass CGS against the filled part of the basis
            mask = (jnp.arange(num_iters) <= i)[:, None].astype(dtype)
            for _ in range(2):
                coeffs = (basis * mask) @ w
                w = w - ((basis * mask).T @ coeffs)
        beta = jnp.linalg.norm(w)
        alphas = alphas.at[i].set(alpha)
        write = i + 1 < num_iters
        q_next = jnp.where(beta > 0, w / jnp.maximum(beta, jnp.finfo(dtype).tiny), 0.0)
        basis = jax.lax.cond(
            write,
            lambda b: b.at[i + 1].set(q_next),
            lambda b: b,
            basis,
        )
        betas = jax.lax.cond(
            write,
            lambda b: b.at[i + 1].set(beta),
            lambda b: b,
            betas,
        )
        return basis, alphas, betas, beta

    basis, alphas, betas, beta_last = jax.lax.fori_loop(
        0, num_iters, body, (basis, alphas, betas, jnp.zeros((), dtype))
    )
    return LanczosResult(alphas=alphas, betas=betas, basis=basis,
                         residual_beta=beta_last)


class EigshResult(NamedTuple):
    eigenvalues: Array  # (k,) sorted descending (largest) / ascending (smallest)
    eigenvectors: Array  # (n, k)
    residual_bounds: Array  # (k,) |beta_{m+1} w_m| per Ritz pair
    num_iters: int


def eigsh(matvec: Matvec, n: int, k: int, *, num_iters: int | None = None,
          which: str = "LA", key: Array | None = None,
          dtype=jnp.float64, v0: Array | None = None) -> EigshResult:
    """Largest-/smallest-algebraic eigenpairs of a symmetric operator.

    ``which``: 'LA' (largest algebraic, the paper's use case for
    A = D^{-1/2} W D^{-1/2}) or 'SA' (smallest — e.g. for L_s directly).
    """
    if num_iters is None:
        num_iters = min(n, max(2 * k + 20, 30))
    num_iters = min(num_iters, n)
    if v0 is None:
        if key is None:
            key = jax.random.PRNGKey(0)
        v0 = jax.random.normal(key, (n,), dtype=dtype)

    res = lanczos(matvec, v0, num_iters)
    # T_k is (num_iters x num_iters) tridiagonal
    t = (jnp.diag(res.alphas)
         + jnp.diag(res.betas[1:], 1)
         + jnp.diag(res.betas[1:], -1))
    theta, w = jnp.linalg.eigh(t)  # ascending
    if which == "LA":
        order = jnp.argsort(-theta)[:k]
    elif which == "SA":
        order = jnp.argsort(theta)[:k]
    else:
        raise ValueError(which)
    theta_k = theta[order]
    w_k = w[:, order]
    vecs = res.basis.T @ w_k  # (n, k)
    bounds = jnp.abs(res.residual_beta * w_k[-1, :])
    return EigshResult(eigenvalues=theta_k, eigenvectors=vecs,
                       residual_bounds=bounds, num_iters=num_iters)


def eigsh_smallest_laplacian(adjacency_matvec: Matvec, n: int, k: int,
                             **kw) -> EigshResult:
    """Smallest eigenpairs of L_s = I - A via largest of A (paper Section 2).

    Returns eigenvalues of L_s (= 1 - theta) with the same eigenvectors.
    """
    res = eigsh(adjacency_matvec, n, k, which="LA", **kw)
    return EigshResult(eigenvalues=1.0 - res.eigenvalues,
                       eigenvectors=res.eigenvectors,
                       residual_bounds=res.residual_bounds,
                       num_iters=res.num_iters)
