"""NFFT-based fast summation — Algorithms 3.1 and 3.2 of the paper.

Algorithm 3.1 computes, for a rotation-invariant kernel ``K`` and nodes
``v_j``, the dense kernel sums

    (W̃ x)_j = sum_i x_i K(v_j - v_i)            (diagonal = K(0))

in ``O(n)`` for fixed accuracy:  adjoint NFFT -> multiply by the kernel
Fourier coefficients ``b_hat`` -> forward NFFT.  Separate source/target node
sets are supported (used by the NFFT kernel-attention decode path).

Algorithm 3.2 wraps this into the normalized adjacency operator
``A = D^{-1/2} W D^{-1/2}`` with ``D = diag(W 1)`` and ``W = W̃ - K(0) I``,
including the node rescaling by the correction factor ``rho``.

Note on multiquadric output scaling (Alg. 3.2 steps 4/5): the paper says
"scale output by rho for multiquadric and 1/rho for inverse multiquadric";
direct computation shows K_{c*rho}(rho*y) = rho * K_c(y) for the multiquadric
(so the output must be scaled by 1/rho) and = (1/rho) * K_c(y) for the
inverse multiquadric (scale by rho).  We implement the sign that our oracle
tests verify; see Kernel.output_scale_exponent.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fastsum_exec, nfft as nfft_mod
from repro.core.kernels import Kernel
from repro.core.nfft import (
    NfftGeometry, NfftPlan, WindowGeometry, build_geometry,
    build_window_geometry,
)
from repro.core.regularization import kernel_fourier_coefficients

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class FastsumParams:
    """Static fast-summation accuracy parameters (Figure 1 of the paper)."""

    n_bandwidth: int  # N
    m: int  # NFFT window cut-off
    p: int | None = None  # regularization smoothness (default: m)
    eps_b: float | None = None  # regularization region (default: p/N)
    sigma_os: float = 2.0
    window: str = nfft_mod.KAISER_BESSEL

    @property
    def p_eff(self) -> int:
        return self.m if self.p is None else self.p

    @property
    def eps_b_eff(self) -> float:
        return self.p_eff / self.n_bandwidth if self.eps_b is None else self.eps_b

    def nfft_plan(self, d: int) -> NfftPlan:
        return NfftPlan(d=d, n_bandwidth=self.n_bandwidth, m=self.m,
                        sigma_os=self.sigma_os, window=self.window)


# The paper's three accuracy tiers (Section 6.1).
SETUP_1 = FastsumParams(n_bandwidth=16, m=2, eps_b=0.0)
SETUP_2 = FastsumParams(n_bandwidth=32, m=4, eps_b=0.0)
SETUP_3 = FastsumParams(n_bandwidth=64, m=7, eps_b=0.0)


def scale_nodes(points: Array, eps_b: float, *, center: bool = True):
    """Shift/scale raw data into the admissible ball (Alg. 3.2 step 1).

    Returns (scaled_nodes, rho, shift): ``scaled = (points - shift) * rho``
    with ``||scaled||_2 <= 1/4 - eps_b/2``.

    Non-finite coordinates are rejected at plan time: a single NaN node
    would poison the min/max centering, collapse ``rho`` to NaN, and
    silently corrupt the Morton geometry and every operator planned from
    it.  (The check only runs on concrete arrays — all planners call this
    eagerly — so traced callers are unaffected.)
    """
    if not isinstance(points, jax.core.Tracer) and \
            not bool(jnp.all(jnp.isfinite(points))):
        raise ValueError(
            "non-finite coordinates in the point set; scrub the data or "
            "drop the offending nodes before planning")
    if center:
        lo = jnp.min(points, axis=0)
        hi = jnp.max(points, axis=0)
        shift = (lo + hi) / 2.0
    else:
        shift = jnp.zeros((points.shape[1],), points.dtype)
    centered = points - shift
    max_norm = jnp.max(jnp.linalg.norm(centered, axis=1))
    target = 0.25 - eps_b / 2.0
    rho = target / jnp.maximum(max_norm, jnp.finfo(points.dtype).tiny)
    return centered * rho, rho, shift


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class FastsumOperator:
    """Algorithm 3.1 as a linear operator  x -> W̃ x  (+ optional targets).

    Build with :func:`make_fastsum`.  ``matvec`` maps (n_src,) [or
    (n_src, C)] real vectors to (n_tgt,) [or (n_tgt, C)] real outputs.
    """

    plan: NfftPlan  # static
    b_hat: Array
    scaled_src: Array  # (n_src, d) nodes in the admissible ball
    scaled_tgt: Array  # (n_tgt, d), or None when targets == sources
    output_scale: Array  # rho**exponent correction (scalar)
    kernel_at_zero: Array  # K(0) for the *rescaled* kernel, already corrected
    # Fused-engine state (plan-once): combined spectral multiplier on the
    # oversampled half-spectrum + separable Morton-sorted window geometry.
    multiplier_half: Array = None
    src_window: WindowGeometry = None
    tgt_window: WindowGeometry = None
    # Re-spectralization state: the admissible-ball scale factor and the
    # accuracy parameters the operator was planned with.  Geometry (points,
    # rho, Morton windows) is fixed plan-time data with zero cotangents; the
    # spectral children above are the param-dependent, differentiable half —
    # :meth:`with_kernel` rebuilds exactly those for a new (possibly traced)
    # kernel without replanning.
    rho: Array = None
    fs_params: FastsumParams = None  # static

    def tree_flatten(self):
        children = (self.b_hat, self.scaled_src, self.scaled_tgt,
                    self.output_scale, self.kernel_at_zero,
                    self.multiplier_half, self.src_window, self.tgt_window,
                    self.rho)
        return children, (self.plan, self.fs_params)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(aux[0], *children, fs_params=aux[1])

    def with_kernel(self, kernel: Kernel) -> "FastsumOperator":
        """Same plan/geometry, new kernel: rebuild only the spectral data.

        Jit/grad-safe: ``kernel`` may carry traced parameters (sigma/c), in
        which case the returned operator's ``b_hat`` / ``multiplier_half`` /
        ``output_scale`` / ``kernel_at_zero`` are traced functions of them —
        the seam gradient-based model selection differentiates through.
        """
        if self.rho is None or self.fs_params is None:
            raise ValueError(
                "with_kernel needs the planning state (rho, fs_params); "
                "this operator was built by hand or by an older path — "
                "re-plan it with make_fastsum")
        b_hat, mult_half, out_scale, k0_corr = _member_spectral(
            kernel, self.rho, self.plan, self.fs_params)
        rdt = jnp.real(b_hat).dtype
        return dataclasses.replace(
            self, b_hat=b_hat, multiplier_half=mult_half,
            output_scale=jnp.asarray(out_scale, dtype=rdt),
            kernel_at_zero=jnp.asarray(k0_corr, dtype=rdt))

    @property
    def n_source(self) -> int:
        return self.scaled_src.shape[0]

    @property
    def n_target(self) -> int:
        return self.n_source if self.scaled_tgt is None else self.scaled_tgt.shape[0]

    def _cached_geometry(self, attr: str, nodes: Array) -> NfftGeometry:
        geom = self.__dict__.get(attr)
        if geom is None:
            geom = build_geometry(self.plan, nodes)
            if not isinstance(geom.indices, jax.core.Tracer):
                self.__dict__[attr] = geom  # never cache traced values
        return geom

    @property
    def src_geometry(self) -> NfftGeometry:
        """O(n*taps^d) tensor-product geometry, built lazily.

        Only the two-NFFT oracle path reads it; the fused hot path runs on
        the O(n*d*taps) ``src_window``, so operators that never call the
        reference matvec never pay the build time or memory.
        """
        return self._cached_geometry("_src_geom", self.scaled_src)

    @property
    def tgt_geometry(self) -> NfftGeometry:
        if self.scaled_tgt is None:
            return self.src_geometry
        return self._cached_geometry("_tgt_geom", self.scaled_tgt)

    def matvec_tilde(self, x: Array, *, backend: str | None = None) -> Array:
        """y = W̃ x  (diagonal K(0) included) — fused rfftn pipeline.

        ``backend`` selects the window-step backend ("auto"/"xla"/"pallas",
        see :func:`repro.core.fastsum_exec.resolve_backend`).
        """
        if self.multiplier_half is None:  # legacy operators built by hand
            fastsum_exec.resolve_backend(backend)  # validate even when unused
            return self.matvec_tilde_reference(x)
        f = fastsum_exec.fused_matvec_tilde(
            self.plan, self.multiplier_half, self.src_window,
            self.tgt_window, x, backend=backend)
        return f * self.output_scale

    def matvec_tilde_reference(self, x: Array) -> Array:
        """Seed two-NFFT path (adjoint -> multiply -> forward); the oracle
        the fused engine is tested against, and the benchmark baseline."""
        x_hat = nfft_mod.nfft_adjoint(self.plan, self.src_geometry, x)
        f_hat = self.b_hat[..., None] * x_hat if x.ndim == 2 else self.b_hat * x_hat
        f = nfft_mod.nfft_forward(self.plan, self.tgt_geometry, f_hat)
        return jnp.real(f) * self.output_scale

    def _require_square(self, name: str) -> None:
        if self.scaled_tgt is not None:
            raise ValueError(
                f"FastsumOperator.{name} subtracts the K(0) diagonal, which "
                "is only defined when source and target nodes coincide; this "
                "operator was built with target_points — use matvec_tilde "
                "for rectangular kernel sums.")

    def matvec(self, x: Array, *, backend: str | None = None) -> Array:
        """y = W x = (W̃ - K(0) I) x.  Requires src == tgt nodes."""
        self._require_square("matvec")
        return self.matvec_tilde(x, backend=backend) - self.kernel_at_zero * x

    def matvec_reference(self, x: Array) -> Array:
        """Two-NFFT W x (oracle/baseline counterpart of :meth:`matvec`)."""
        self._require_square("matvec_reference")
        return self.matvec_tilde_reference(x) - self.kernel_at_zero * x

    def degrees(self) -> Array:
        """d = W 1 (row sums of the zero-diagonal weight matrix)."""
        ones = jnp.ones((self.n_source,), dtype=jnp.real(self.b_hat).dtype)
        return self.matvec(ones)


def _scaled_plan(points: Array, params: FastsumParams,
                 target_points: Optional[Array]):
    """Kernel-independent plan-time setup, shared by single operators and
    banks: node scaling into the admissible ball, the NFFT plan, and the
    Morton-sorted window geometries.

    Returns ``(scaled_src, scaled_tgt_or_None, rho, plan, src_win,
    tgt_win)``.
    """
    d = points.shape[1]
    eps_b = params.eps_b_eff
    if target_points is None:
        scaled, rho, shift = scale_nodes(points, eps_b)
        scaled_src = scaled_tgt = scaled
    else:
        both = jnp.concatenate([points, target_points], axis=0)
        scaled, rho, shift = scale_nodes(both, eps_b)
        scaled_src = scaled[: points.shape[0]]
        scaled_tgt = scaled[points.shape[0]:]
    plan = params.nfft_plan(d)
    src_win = build_window_geometry(plan, scaled_src)
    tgt_win = src_win if target_points is None \
        else build_window_geometry(plan, scaled_tgt)
    return (scaled_src, None if target_points is None else scaled_tgt,
            rho, plan, src_win, tgt_win)


def _member_spectral(kernel: Kernel, rho, plan: NfftPlan,
                     params: FastsumParams):
    """Per-kernel spectral data: ``(b_hat, mult_half, out_scale, k0_corr)``.

    The only kernel-dependent plan-time work — everything else
    (:func:`_scaled_plan`) is shared across a bank's members.
    """
    # rho may be a concrete scalar (eager planning) or a tracer (operator
    # construction / re-spectralization under jit or grad) — Kernel carries
    # traced parameters natively, so no concretization is needed here.
    rescaled_kernel = kernel.rescaled(rho)
    b_hat = kernel_fourier_coefficients(rescaled_kernel, plan.d,
                                        params.n_bandwidth, params.p_eff,
                                        params.eps_b_eff)
    mult_half = fastsum_exec.fused_spectral_multiplier(plan, b_hat)
    exponent = kernel.output_scale_exponent
    out_scale = rho ** exponent if exponent != 0 else 1.0
    # K(0) is scale-invariant for all four kernels w/ parameter rescaling
    # *except* the multiquadrics, where K(0)=c resp. 1/c;
    # out_scale * K_rescaled(0) == K(0) holds for all four — use that:
    k0_corr = out_scale * rescaled_kernel.at_zero()
    return b_hat, mult_half, out_scale, k0_corr


def make_fastsum(
    kernel: Kernel,
    points: Array,
    params: FastsumParams,
    *,
    target_points: Optional[Array] = None,
) -> FastsumOperator:
    """Set up Algorithm 3.1 for ``points`` (n, d) in original coordinates."""
    scaled_src, scaled_tgt, rho, plan, src_win, tgt_win = _scaled_plan(
        points, params, target_points)
    b_hat, mult_half, out_scale, k0_corr = _member_spectral(
        kernel, rho, plan, params)
    rdt = jnp.real(b_hat).dtype
    return FastsumOperator(
        plan=plan,
        b_hat=b_hat,
        scaled_src=scaled_src,
        scaled_tgt=scaled_tgt,
        output_scale=jnp.asarray(out_scale, dtype=rdt),
        kernel_at_zero=jnp.asarray(k0_corr, dtype=rdt),
        multiplier_half=mult_half,
        src_window=src_win,
        tgt_window=tgt_win,
        rho=jnp.asarray(rho),
        fs_params=params,
    )


@dataclasses.dataclass(frozen=True)
class PredictionPlan:
    """Plan-once serving frame: a fixed node scaling over train ∪ domain.

    :func:`make_fastsum` with ``target_points`` rescales the *union* of
    sources and targets into the admissible ball, so the scale factor
    ``rho`` — and with it the rescaled kernel, its Fourier coefficients,
    and the fused spectral multiplier — depends on the target set.  That is
    fine for a one-shot predict, but it makes every new target set a full
    replan, which is exactly what a serving tick cannot afford.

    A ``PredictionPlan`` instead freezes ``(rho, shift)`` over the training
    points plus a declared serving *domain* (default: the training bounding
    box expanded by ``margin``).  Any query set inside the domain is then
    admissible under the frozen scaling, and serving it costs only an O(m)
    target window geometry (:meth:`target_window`) — the NFFT plan, source
    geometry, and every kernel's spectral multiplier
    (:func:`prediction_multiplier`) are reusable verbatim.  One plan is
    shared by every model fitted on the same training points (the
    multi-tenant group of the graph-predict engine).
    """

    plan: NfftPlan
    scaled_src: Array  # (n, d) training nodes under the frozen scaling
    src_window: WindowGeometry
    rho: float
    shift: np.ndarray  # (d,) — plain numpy so the plan hashes/pickles
    radius: float  # admissible ball radius for scaled nodes

    @property
    def n_source(self) -> int:
        return self.scaled_src.shape[0]

    def scale_targets(self, query_points: Array) -> Array:
        """Map raw query points into the frozen scaled frame."""
        q = jnp.asarray(query_points)
        return (q - jnp.asarray(self.shift, q.dtype)) * self.rho

    def admissible(self, scaled_targets: Array, *,
                   slack: float = 1e-9) -> Array:
        """Per-row mask: does a scaled query point fit the admissible ball?

        Points outside wrap around the torus the NFFT periodizes over and
        produce garbage kernel sums — callers must reject them (the serving
        engine fails such requests instead of serving wrong values).
        """
        return jnp.linalg.norm(scaled_targets, axis=-1) <= self.radius + slack

    def target_window(self, scaled_targets: Array) -> WindowGeometry:
        """O(m) per-tick work: window geometry for (already scaled) targets."""
        return build_window_geometry(self.plan, scaled_targets)


def _domain_corners(points: np.ndarray, margin: float) -> np.ndarray:
    """2^d corners of the training bounding box expanded by ``margin``."""
    lo, hi = points.min(axis=0), points.max(axis=0)
    mid, half = (lo + hi) / 2.0, np.maximum((hi - lo) / 2.0, 1e-12)
    half = half * (1.0 + margin)
    d = points.shape[1]
    corners = np.stack(np.meshgrid(*[[-1.0, 1.0]] * d, indexing="ij"),
                       axis=-1).reshape(-1, d)
    return mid[None, :] + corners * half[None, :]


def make_prediction_plan(points: Array, params: FastsumParams, *,
                         domain_points: Optional[Array] = None,
                         margin: float = 0.5) -> PredictionPlan:
    """Kernel-independent serving plan over ``points`` (n, d).

    ``domain_points`` declares the region query points may come from; when
    omitted it defaults to the training bounding box expanded by ``margin``
    per dimension.  The admissible-ball scaling is computed once over
    train ∪ domain and frozen, so serving never replans (see
    :class:`PredictionPlan`).
    """
    pts = jnp.asarray(points)
    if domain_points is None:
        domain = jnp.asarray(_domain_corners(np.asarray(pts), margin),
                             pts.dtype)
    else:
        domain = jnp.asarray(domain_points, pts.dtype)
    both = jnp.concatenate([pts, domain.reshape(-1, pts.shape[1])], axis=0)
    scaled, rho, shift = scale_nodes(both, params.eps_b_eff)
    scaled_src = scaled[: pts.shape[0]]
    plan = params.nfft_plan(pts.shape[1])
    return PredictionPlan(
        plan=plan,
        scaled_src=scaled_src,
        src_window=build_window_geometry(plan, scaled_src),
        rho=float(rho),
        shift=np.asarray(shift),
        radius=0.25 - params.eps_b_eff / 2.0,
    )


def prediction_multiplier(kernel: Kernel, pred: PredictionPlan,
                          params: FastsumParams) -> Array:
    """Fused serving multiplier for one kernel on a shared prediction plan.

    The ``rho**exponent`` output correction is folded in (the pipeline is
    linear), so gathered predictions need no per-column post-scaling —
    mirroring :func:`make_fastsum_bank`'s folded per-member multipliers.
    """
    _, mult_half, out_scale, _ = _member_spectral(
        kernel, pred.rho, pred.plan, params)
    return mult_half * out_scale


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class FastsumOperatorBank:
    """A bank of S Algorithm 3.1 operators sharing nodes, plan, and geometry.

    The members differ only in their kernel (and hence spectral multiplier);
    the plan and Morton-sorted window geometry depend only on the points, so
    a bank matvec shares one spread and one forward rfftn across all S
    members (:func:`repro.core.fastsum_exec.fused_pipeline_bank`).  This is
    the execution shape of a hyperparameter sweep (one operator per sigma)
    and of multilayer graphs (one operator per layer kernel).

    Per-member output scales are folded into ``multiplier_bank`` and
    ``b_hat_bank`` at build time (the pipeline is linear), so ``matvec``
    needs no per-member post-scaling and a fixed-weight mixture collapses to
    a plain weighted sum of multipliers (:meth:`mixture`).
    """

    plan: NfftPlan  # static
    b_hat_bank: Array  # (S,) + (N,)*d, output scale folded in
    scaled_src: Array
    scaled_tgt: Array  # or None when targets == sources
    kernel_at_zero: Array  # (S,) corrected K(0) per member
    multiplier_bank: Array  # (S,) + half-spectrum, output scale folded in
    src_window: WindowGeometry
    tgt_window: WindowGeometry

    def tree_flatten(self):
        children = (self.b_hat_bank, self.scaled_src, self.scaled_tgt,
                    self.kernel_at_zero, self.multiplier_bank,
                    self.src_window, self.tgt_window)
        return children, (self.plan,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(aux[0], *children)

    @property
    def size(self) -> int:
        return self.multiplier_bank.shape[0]

    @property
    def n_source(self) -> int:
        return self.scaled_src.shape[0]

    def member(self, s: int) -> FastsumOperator:
        """Single-member view (a plain :class:`FastsumOperator`).

        Shares the bank's geometry arrays; the member's output scale is
        already folded into its multiplier, so ``output_scale`` is 1.
        """
        one = jnp.ones((), jnp.real(self.b_hat_bank).dtype)
        return FastsumOperator(
            plan=self.plan, b_hat=self.b_hat_bank[s],
            scaled_src=self.scaled_src, scaled_tgt=self.scaled_tgt,
            output_scale=one, kernel_at_zero=self.kernel_at_zero[s],
            multiplier_half=self.multiplier_bank[s],
            src_window=self.src_window, tgt_window=self.tgt_window)

    def mixture(self, weights) -> FastsumOperator:
        """Collapse a fixed-weight mixture ``sum_s w_s W̃_s`` to ONE operator.

        The combined multiplier is the weighted sum of the member
        multipliers, so the whole mixture — e.g. an aggregated multilayer
        Laplacian's weighted sum of per-layer kernels — costs exactly one
        fused matvec per application, not S.
        """
        w = jnp.asarray(weights, jnp.real(self.b_hat_bank).dtype)
        if w.shape != (self.size,):
            raise ValueError(f"weights must have shape ({self.size},), "
                             f"got {w.shape}")
        one = jnp.ones((), w.dtype)
        return FastsumOperator(
            plan=self.plan,
            b_hat=jnp.tensordot(w.astype(self.b_hat_bank.dtype),
                                self.b_hat_bank, axes=1),
            scaled_src=self.scaled_src, scaled_tgt=self.scaled_tgt,
            output_scale=one,
            kernel_at_zero=jnp.dot(w, self.kernel_at_zero),
            multiplier_half=jnp.tensordot(
                w.astype(self.multiplier_bank.dtype), self.multiplier_bank,
                axes=1),
            src_window=self.src_window, tgt_window=self.tgt_window)

    def matvec_tilde(self, x: Array, *, backend: str | None = None) -> Array:
        """Bank kernel sums (diagonal K(0) included).

        ``x`` (n,) / (n, C): broadcast — every member applied to the same
        right-hand sides, returning (S, n) / (S, n, C).  ``x`` (S, n, C):
        lockstep — member ``s`` applied to ``x[s]`` (the bank Krylov shape).
        Either way: one spread, one forward rfftn, one batched irfftn, one
        gather.
        """
        return fastsum_exec.fused_matvec_tilde_bank(
            self.plan, self.multiplier_bank, self.src_window,
            self.tgt_window, x, backend=backend)

    def matvec_tilde_columns(self, u: Array, *,
                             backend: str | None = None) -> Array:
        """Lockstep bank matvec in flat column layout: (n, S*C) -> (n, S*C).

        Column ``s*C + j`` belongs to member ``s`` (bank-major) — the
        layout the per-column solvers iterate on.  Identical math to the
        (S, n, C) lockstep flavor with zero bank-axis transposes per call;
        :func:`repro.graph.krr.krr_fit_sweep` runs its whole CG on this.
        """
        return fastsum_exec.fused_matvec_tilde_bank_columns(
            self.plan, self.multiplier_bank, self.src_window,
            self.tgt_window, u, backend=backend)

    def _require_square(self, name: str) -> None:
        if self.scaled_tgt is not None:
            raise ValueError(
                f"FastsumOperatorBank.{name} subtracts the K(0) diagonal, "
                "which is only defined when source and target nodes "
                "coincide; this bank was built with target_points — use "
                "matvec_tilde for rectangular kernel sums.")

    def matvec(self, x: Array, *, backend: str | None = None) -> Array:
        """y[s] = (W̃_s - K_s(0) I) x  (or x[s] in lockstep flavor)."""
        self._require_square("matvec")
        out = self.matvec_tilde(x, backend=backend)  # (S, n[, C])
        # k0 aligned with out's bank axis broadcasts against both the
        # broadcast (x: (n[, C])) and lockstep (x: (S, n, C)) flavors
        k0 = self.kernel_at_zero.reshape((self.size,) + (1,) * (out.ndim - 1))
        return out - k0 * x


def make_fastsum_bank(
    kernels,
    points: Array,
    params: FastsumParams,
    *,
    target_points: Optional[Array] = None,
) -> FastsumOperatorBank:
    """Plan a bank of Algorithm 3.1 operators over shared ``points``.

    ``kernels`` is a sequence of :class:`~repro.core.kernels.Kernel` — one
    member per kernel/parameter combination (a sigma sweep, the per-layer
    kernels of a multilayer graph, ...).  Node scaling, the NFFT plan, and
    the window geometries are computed once; only the O(N^d) spectral
    multipliers are per-member.
    """
    kernels = tuple(kernels)
    if not kernels:
        raise ValueError("make_fastsum_bank needs at least one kernel")
    scaled_src, scaled_tgt, rho, plan, src_win, tgt_win = _scaled_plan(
        points, params, target_points)

    b_hats, mults, k0s = [], [], []
    for kernel in kernels:
        b_hat, mult_half, out_scale, k0_corr = _member_spectral(
            kernel, rho, plan, params)
        # fold the rho**exponent output correction into the (linear)
        # spectral data so bank members need no per-member post-scale
        b_hats.append(b_hat * out_scale)
        mults.append(mult_half * out_scale)
        k0s.append(k0_corr)
    b_hat_bank = jnp.stack(b_hats)
    return FastsumOperatorBank(
        plan=plan,
        b_hat_bank=b_hat_bank,
        scaled_src=scaled_src,
        scaled_tgt=scaled_tgt,
        kernel_at_zero=jnp.stack(
            [jnp.asarray(k) for k in k0s]).astype(
                jnp.real(b_hat_bank).dtype),
        multiplier_bank=jnp.stack(mults),
        src_window=src_win,
        tgt_window=tgt_win,
    )


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class NormalizedAdjacencyOperator:
    """Algorithm 3.2:  x -> A x,  A = D^{-1/2} W D^{-1/2} (exactly symmetric).

    Also exposes the graph Laplacian ``L_s x = x - A x`` and the row-stochastic
    ``L_w``-style matvec ``P x = D^{-1} W x`` (used by NFFT kernel attention).
    """

    fastsum: FastsumOperator
    inv_sqrt_deg: Array  # (n,)
    degrees: Array  # (n,)

    def tree_flatten(self):
        return (self.fastsum, self.inv_sqrt_deg, self.degrees), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def n(self) -> int:
        return self.inv_sqrt_deg.shape[0]

    def matvec(self, x: Array) -> Array:
        scale = self.inv_sqrt_deg if x.ndim == 1 else self.inv_sqrt_deg[:, None]
        return scale * self.fastsum.matvec(scale * x)

    def laplacian_matvec(self, x: Array) -> Array:
        return x - self.matvec(x)

    def stochastic_matvec(self, x: Array) -> Array:
        inv_deg = self.inv_sqrt_deg ** 2
        scale = inv_deg if x.ndim == 1 else inv_deg[:, None]
        return scale * self.fastsum.matvec(x)


def _normalized_adjacency_from(fs: FastsumOperator) -> NormalizedAdjacencyOperator:
    deg = fs.degrees()
    # Lemma 3.1 requires eps < eta, i.e. the approximation error below the
    # smallest degree; negative approximate degrees would make D^{-1/2}
    # imaginary (the classical-Nyström failure mode the paper highlights).
    deg = jnp.maximum(deg, jnp.finfo(deg.dtype).tiny)
    return NormalizedAdjacencyOperator(
        fastsum=fs, inv_sqrt_deg=1.0 / jnp.sqrt(deg), degrees=deg
    )


def make_normalized_adjacency(
    kernel: Kernel, points: Array, params: FastsumParams
) -> NormalizedAdjacencyOperator:
    return _normalized_adjacency_from(make_fastsum(kernel, points, params))


def make_normalized_adjacency_mixture(
    kernels, weights, points: Array, params: FastsumParams
) -> NormalizedAdjacencyOperator:
    """Algorithm 3.2 for an aggregated multilayer weight matrix.

    The multilayer extension (Bergermann–Stoll–Volkmer 2020) aggregates the
    per-layer kernels into ``W = sum_l w_l (W̃_l - K_l(0) I)`` before
    normalizing.  The mixture collapses to a *single* summed spectral
    multiplier (:meth:`FastsumOperatorBank.mixture`), so every matvec of the
    multilayer adjacency/Laplacian costs exactly one fused pipeline — the
    same price as a single-layer graph.
    """
    bank = make_fastsum_bank(kernels, points, params)
    return _normalized_adjacency_from(bank.mixture(weights))


# ---------------------------------------------------------------------------
# Dense references (oracles / "direct method" baselines).
# ---------------------------------------------------------------------------

def dense_weight_matrix(kernel: Kernel, points: Array) -> Array:
    """W with zero diagonal (Eq. 2.3).  O(n^2) memory — tests/baselines only."""
    diff = points[:, None, :] - points[None, :, :]
    r = jnp.sqrt(jnp.maximum(jnp.sum(diff * diff, axis=-1), 0.0))
    w = kernel.phi(r)
    return w - jnp.diag(jnp.diag(w))


def dense_normalized_adjacency(kernel: Kernel, points: Array) -> Array:
    w = dense_weight_matrix(kernel, points)
    deg = jnp.sum(w, axis=1)
    inv_sqrt = 1.0 / jnp.sqrt(deg)
    return inv_sqrt[:, None] * w * inv_sqrt[None, :]


@functools.partial(jax.jit, static_argnames=("kernel", "tile"))
def direct_matvec_tiled(kernel: Kernel, points: Array, x: Array,
                        tile: int = 2048) -> Array:
    """O(n^2) FLOPs, O(n*tile) memory direct matvec (the paper's baseline).

    Computes rows in tiles without materializing W; used by benchmarks for
    problem sizes where the dense matrix would not fit.  Jitted with the
    (frozen, hashable) kernel and tile size static, so repeated baseline
    timings measure compute rather than retracing.
    """
    n = points.shape[0]
    pad = (-n) % tile
    pts = jnp.pad(points, ((0, pad), (0, 0)))
    n_tiles = pts.shape[0] // tile

    def row_block(i):
        rows = jax.lax.dynamic_slice_in_dim(pts, i * tile, tile, axis=0)
        diff = rows[:, None, :] - points[None, :, :]
        r = jnp.sqrt(jnp.maximum(jnp.sum(diff * diff, axis=-1), 0.0))
        w = kernel.phi(r)
        # zero the true diagonal entries that fall inside this block
        row_ids = i * tile + jnp.arange(tile)
        col_ids = jnp.arange(n)
        w = jnp.where(row_ids[:, None] == col_ids[None, :], 0.0, w)
        return w @ x

    out = jax.lax.map(row_block, jnp.arange(n_tiles))
    return out.reshape(-1, *x.shape[1:])[:n]
