"""Fused fast-summation execution engine (plan once, execute many).

The seed implementation of Algorithm 3.1 ran two independent NFFTs per
matvec: spread -> complex FFT -> extract I_N -> deconvolve, then deconvolve
-> embed I_N -> complex IFFT -> gather, rebuilding the deconvolution grid
and paying an O(n * taps^d) scalar scatter/gather against tensor-product
geometry arrays each call.  This module fuses the whole pipeline into

    spread -> rfftn -> multiply -> irfftn -> gather

around one precomputed spectral multiplier on the full oversampled grid:

    C[k] = b_hat[k] / (M^d * phi_hat[k]^2)   for k in I_N^d (zero-padded
                                              into I_M^d, FFT order)

Hermitian-symmetrized so that the real-to-complex FFT pair computes exactly
the real part the two-NFFT path produced: for real input the adjoint's
spectrum is Hermitian, and

    Re(ifftn(C . fftn(g))) = irfftn(sym(C) . rfftn(g)),
    sym(C)[k] = (C[k] + conj(C[-k])) / 2,

where the only asymmetric bins of C are the I_N Nyquist rows that have no
mirror inside I_N.  No embed/extract scatter, no per-call deconvolution,
and the two full complex FFTs become one real FFT pair (half the flops and
spectrum memory).

The window step uses the separable geometry of :class:`~repro.core.nfft.
WindowGeometry`: one `lax.scatter_add` / `lax.gather` of a whole
``(taps,)^d`` window per node into a wrap-padded grid, with the tensor
product of per-dimension weights recomputed on the fly.  That replaces the
seed's O(n * taps^d) scalar scatter (the dominant cost on CPU — XLA emits a
serial loop per element) with n windowed vector updates, and shrinks the
geometry the matvec streams from O(n * taps^d) to O(n * d * taps) values.
Nodes are Morton-sorted (see ``build_window_geometry``) so consecutive
windows touch neighbouring grid tiles.

Everything is natively multi-RHS: ``x`` of shape (n,) or (n, C) flows
through with a trailing channel dimension on the grid, so block Lanczos /
multi-column solves amortize spread and gather over the batch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.nfft import (
    NfftPlan, WindowGeometry, _embed_map, padded_grid_size, window_shift,
)

Array = jax.Array


def fused_spectral_multiplier(plan: NfftPlan, b_hat: Array) -> Array:
    """Combined multiplier, Hermitian-symmetrized, as an rfftn half-spectrum.

    Returns shape ``(M,)*(d-1) + (M//2 + 1,)`` complex, FFT order.
    """
    d, grid = plan.d, plan.grid_size
    phi_hat = plan.deconvolution_grid()  # (N,)*d real
    small = b_hat / ((grid ** d) * phi_hat * phi_hat)
    emb = _embed_map(plan)
    mesh = jnp.meshgrid(*([emb] * d), indexing="ij")
    big = jnp.zeros((grid,) * d, dtype=small.dtype).at[tuple(mesh)].set(small)
    # conj-reflect: rev[k] = big[(-k) mod M] along every axis
    rev = big
    for ax in range(d):
        rev = jnp.roll(jnp.flip(rev, axis=ax), 1, axis=ax)
    sym = 0.5 * (big + jnp.conj(rev))
    return sym[..., : grid // 2 + 1]


@functools.lru_cache(maxsize=None)
def spectral_support(plan: NfftPlan) -> tuple:
    """Per-dim indices where the fused multiplier is nonzero (half-spectrum).

    The symmetrized zero-padded I_N block occupies ``[0..N/2]`` and
    ``[M-N/2..M-1]`` per leading dimension and ``[0..N/2]`` along the rfft
    axis — about N^d/2 coefficients, the minimal block a distributed matvec
    has to all-reduce (half the seed's N^d complex psum payload).
    """
    n, grid = plan.n_bandwidth, plan.grid_size
    # plain numpy: jnp values built here would be staged into (and leak out
    # of) whichever jit trace first populates the cache
    full = np.concatenate([np.arange(n // 2 + 1),
                           np.arange(grid - n // 2, grid)]).astype(np.int32)
    half = np.arange(n // 2 + 1, dtype=np.int32)
    return tuple([full] * (plan.d - 1) + [half])


def _weight_cube(geometry: WindowGeometry, d: int):
    """Tensor product of per-dim weights: (n,) + (taps,)*d, built on the fly."""
    w = geometry.weights  # (n, d, taps)
    n, _, taps = w.shape
    cube = w[:, 0]
    for t in range(1, d):
        cube = cube[..., None] * w[:, t].reshape((n,) + (1,) * t + (taps,))
    return cube


def window_spread(plan: NfftPlan, geometry: WindowGeometry, x: Array) -> Array:
    """Spread node values (n, C) onto the oversampled grid -> (M,)*d + (C,).

    One ``scatter_add`` of a (taps,)^d window per node into a wrap-padded
    grid, followed by folding the pad back and aligning to FFT order.
    """
    d, grid, taps = plan.d, plan.grid_size, plan.taps
    pad_n = padded_grid_size(plan)
    c = x.shape[-1]
    cube = _weight_cube(geometry, d)  # (n,) + (taps,)*d
    updates = cube[..., None] * x[geometry.perm][
        (slice(None),) + (None,) * d + (slice(None),)]
    dnums = jax.lax.ScatterDimensionNumbers(
        update_window_dims=tuple(range(1, d + 2)),
        inserted_window_dims=(),
        scatter_dims_to_operand_dims=tuple(range(d)))
    gpad = jnp.zeros((pad_n,) * d + (c,), dtype=x.dtype)
    gpad = jax.lax.scatter_add(
        gpad, geometry.base, updates, dnums,
        mode=jax.lax.GatherScatterMode.PROMISE_IN_BOUNDS)
    # fold the periodic pad back: unwrapped u and u - M are the same cell
    ext = taps - 1
    for ax in range(d):
        main = jax.lax.slice_in_dim(gpad, 0, grid, axis=ax)
        tail = jax.lax.slice_in_dim(gpad, grid, pad_n, axis=ax)
        idx = (slice(None),) * ax + (slice(0, ext),)
        gpad = main.at[idx].add(tail)
    # padded coordinate u <-> FFT-order index (u - shift) mod M
    return jnp.roll(gpad, (-window_shift(plan),) * d, axis=tuple(range(d)))


def window_gather(plan: NfftPlan, geometry: WindowGeometry, g: Array) -> Array:
    """Gather node values from the grid (M,)*d + (C,) -> (n, C).

    Exact transpose of :func:`window_spread` (same geometry, same weights):
    wrap-pad the grid, one (taps,)^d window gather per node, contract with
    the on-the-fly weight cube, then restore node order.
    """
    d, grid, taps = plan.d, plan.grid_size, plan.taps
    c = g.shape[-1]
    rolled = jnp.roll(g, (window_shift(plan),) * d, axis=tuple(range(d)))
    gpad = jnp.pad(rolled, [(0, taps - 1)] * d + [(0, 0)], mode="wrap")
    dnums = jax.lax.GatherDimensionNumbers(
        offset_dims=tuple(range(1, d + 2)),
        collapsed_slice_dims=(),
        start_index_map=tuple(range(d)))
    vals = jax.lax.gather(
        gpad, geometry.base, dnums, slice_sizes=(taps,) * d + (c,),
        mode=jax.lax.GatherScatterMode.PROMISE_IN_BOUNDS)
    cube = _weight_cube(geometry, d)
    out = jnp.sum(vals * cube[..., None], axis=tuple(range(1, d + 1)))
    return jnp.zeros_like(out).at[geometry.perm].set(out)


def fused_pipeline(plan: NfftPlan, multiplier_half: Array,
                   src: WindowGeometry, tgt: WindowGeometry, x: Array,
                   spectral_reduce=None) -> Array:
    """spread -> rfftn -> multiply -> irfftn -> gather, one traceable body.

    ``spectral_reduce``, when given, is applied to the support block of the
    multiplied half-spectrum (see :func:`spectral_support`) — the hook the
    distributed matvec uses to psum the one cross-shard accumulation, so the
    local and distributed pipelines share this single implementation.
    """
    d = plan.d
    batched = x.ndim == 2
    xb = x if batched else x[:, None]
    g = window_spread(plan, src, xb)
    g_hat = jnp.fft.rfftn(g, axes=tuple(range(d)))
    g_hat = g_hat * multiplier_half.astype(g_hat.dtype)[..., None]
    if spectral_reduce is not None:
        sup = jnp.meshgrid(*spectral_support(plan), indexing="ij")
        block = spectral_reduce(g_hat[tuple(sup)])
        g_hat = jnp.zeros_like(g_hat).at[tuple(sup)].set(block)
    y = jnp.fft.irfftn(g_hat, s=(plan.grid_size,) * d, axes=tuple(range(d)))
    out = window_gather(plan, tgt, y.astype(xb.dtype))
    return out if batched else out[..., 0]


@functools.partial(jax.jit, static_argnames=("plan",))
def fused_matvec_tilde(plan: NfftPlan, multiplier_half: Array,
                       src: WindowGeometry, tgt: WindowGeometry,
                       x: Array) -> Array:
    """y = W̃ x via the fused pipeline; x: (n,) or (n, C) real."""
    return fused_pipeline(plan, multiplier_half, src, tgt, x)
