"""Fused fast-summation execution engine (plan once, execute many).

The seed implementation of Algorithm 3.1 ran two independent NFFTs per
matvec: spread -> complex FFT -> extract I_N -> deconvolve, then deconvolve
-> embed I_N -> complex IFFT -> gather, rebuilding the deconvolution grid
and paying an O(n * taps^d) scalar scatter/gather against tensor-product
geometry arrays each call.  This module fuses the whole pipeline into

    spread -> rfftn -> multiply -> irfftn -> gather

around one precomputed spectral multiplier on the full oversampled grid:

    C[k] = b_hat[k] / (M^d * phi_hat[k]^2)   for k in I_N^d (zero-padded
                                              into I_M^d, FFT order)

Hermitian-symmetrized so that the real-to-complex FFT pair computes exactly
the real part the two-NFFT path produced: for real input the adjoint's
spectrum is Hermitian, and

    Re(ifftn(C . fftn(g))) = irfftn(sym(C) . rfftn(g)),
    sym(C)[k] = (C[k] + conj(C[-k])) / 2,

where the only asymmetric bins of C are the I_N Nyquist rows that have no
mirror inside I_N.  No embed/extract scatter, no per-call deconvolution,
and the two full complex FFTs become one real FFT pair (half the flops and
spectrum memory).

The window step uses the separable geometry of :class:`~repro.core.nfft.
WindowGeometry` (per-dim patch corner + per-dim weights, O(n * d * taps)
values; nodes Morton-sorted by ``build_window_geometry`` so consecutive
windows touch neighbouring grid tiles) and runs on one of two streaming
backends selected by ``backend="auto"|"xla"|"pallas"``:

* ``"xla"`` (the CPU/portable fallback and the parity oracle): a
  ``fori_loop`` over Morton-sorted node tiles, each step one
  `lax.scatter_add` / `lax.gather` of the tile's whole (taps,)^d windows.
  Peak memory is O(tile * taps^d * C) with the tile sized to a fixed
  element budget — the (n, taps^d, C) update cube of the PR 2 whole-window
  path is never materialized.

* ``"pallas"`` (`repro.kernels.nfft_window`): Morton-sorted node tiles
  stream through VMEM against the resident padded grid; each node
  scatter-adds into / gathers from only the (taps,)^d patch it touches,
  with the weight tensor product and batched channels kept in-register.

``backend="auto"`` (the default everywhere) picks pallas on TPU and xla
elsewhere, so ``FastsumOperator.matvec``, block Lanczos, and the
distributed matvec pick the fast path up transparently.

Everything is natively multi-RHS: ``x`` of shape (n,) or (n, C) flows
through with a trailing channel dimension on the grid, so block Lanczos /
multi-column solves amortize spread and gather over the batch.
"""

from __future__ import annotations

import functools
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.nfft import (
    NfftPlan, WindowGeometry, _embed_map, padded_grid_size, window_shift,
)
from repro.kernels import nfft_window

Array = jax.Array

BACKENDS = ("auto", "xla", "pallas")


def resolve_backend(backend: str | None) -> str:
    """Resolve the window-step backend: auto -> pallas on TPU, xla elsewhere.

    An *explicit* ``"pallas"`` off-TPU runs the kernels in interpret mode —
    the per-node streaming loop executed by the Pallas emulator.  That is
    the parity-testing path (bit-identical semantics to the TPU lowering),
    not a performance path; benchmarks must not time it.

    Caveat: the TPU Mosaic lowering of these kernels has not yet been
    exercised on real hardware (ROADMAP follow-up) — on TPU, pass
    ``backend="xla"`` to opt out of the auto-selected pallas path.
    """
    if backend is None or backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    if backend not in ("xla", "pallas"):
        raise ValueError(
            f"backend must be one of {BACKENDS}, got {backend!r}")
    return backend


def _pallas_interpret() -> bool:
    return jax.default_backend() != "tpu"


# Sticky degradation state for the *auto-selected* pallas window backend:
# if its lowering fails (e.g. an unexercised Mosaic path on new hardware),
# fall back to the xla backend for the rest of the process with ONE warning
# instead of raising on every matvec.  An *explicit* ``backend="pallas"``
# still raises — asking for pallas by name means wanting the failure.
_PALLAS_FALLBACK = {"warned": False, "disabled": False}


def _auto_backend(backend: str | None) -> bool:
    return backend is None or backend == "auto"


def _note_pallas_fallback(exc: Exception) -> None:
    _PALLAS_FALLBACK["disabled"] = True
    if not _PALLAS_FALLBACK["warned"]:
        _PALLAS_FALLBACK["warned"] = True
        warnings.warn(
            "auto-selected pallas window backend failed to lower "
            f"({type(exc).__name__}: {exc}); degrading to the xla window "
            "backend for the rest of the process (pass backend='pallas' "
            "explicitly to make this an error)",
            RuntimeWarning, stacklevel=4)


def _window_backend(backend: str | None) -> str:
    """:func:`resolve_backend` plus the sticky auto-fallback state."""
    resolved = resolve_backend(backend)
    if (resolved == "pallas" and _auto_backend(backend)
            and _PALLAS_FALLBACK["disabled"]):
        return "xla"
    return resolved


def fused_spectral_multiplier(plan: NfftPlan, b_hat: Array) -> Array:
    """Combined multiplier, Hermitian-symmetrized, as an rfftn half-spectrum.

    Returns shape ``(M,)*(d-1) + (M//2 + 1,)`` complex, FFT order.
    """
    d, grid = plan.d, plan.grid_size
    phi_hat = plan.deconvolution_grid()  # (N,)*d real
    small = b_hat / ((grid ** d) * phi_hat * phi_hat)
    emb = _embed_map(plan)
    mesh = jnp.meshgrid(*([emb] * d), indexing="ij")
    big = jnp.zeros((grid,) * d, dtype=small.dtype).at[tuple(mesh)].set(small)
    # conj-reflect: rev[k] = big[(-k) mod M] along every axis
    rev = big
    for ax in range(d):
        rev = jnp.roll(jnp.flip(rev, axis=ax), 1, axis=ax)
    sym = 0.5 * (big + jnp.conj(rev))
    return sym[..., : grid // 2 + 1]


@functools.lru_cache(maxsize=None)
def spectral_support(plan: NfftPlan) -> tuple:
    """Per-dim indices where the fused multiplier is nonzero (half-spectrum).

    The symmetrized zero-padded I_N block occupies ``[0..N/2]`` and
    ``[M-N/2..M-1]`` per leading dimension and ``[0..N/2]`` along the rfft
    axis — about N^d/2 coefficients, the minimal block a distributed matvec
    has to all-reduce (half the seed's N^d complex psum payload).
    """
    n, grid = plan.n_bandwidth, plan.grid_size
    # plain numpy: jnp values built here would be staged into (and leak out
    # of) whichever jit trace first populates the cache
    full = np.concatenate([np.arange(n // 2 + 1),
                           np.arange(grid - n // 2, grid)]).astype(np.int32)
    half = np.arange(n // 2 + 1, dtype=np.int32)
    return tuple([full] * (plan.d - 1) + [half])


# Streamed-tile budget for the XLA window step, in weight-cube elements per
# tile (tile size = _XLA_TILE_ELEMS / taps^d nodes): bounds peak memory at
# ~1 MiB f64 per channel regardless of n, taps, d.
_XLA_TILE_ELEMS = 1 << 17


def _xla_node_tile(n: int, taps: int, d: int) -> int:
    return max(64, min(n, _XLA_TILE_ELEMS // taps ** d))


def _tile_weight_cube(w: Array, d: int) -> Array:
    """Tensor product of per-dim weights: (t, d, taps) -> (t,) + (taps,)*d."""
    t, _, taps = w.shape
    cube = w[:, 0]
    for ax in range(1, d):
        cube = cube[..., None] * w[:, ax].reshape((t,) + (1,) * ax + (taps,))
    return cube


def _xla_spread(plan: NfftPlan, geometry: WindowGeometry, xs: Array) -> Array:
    """Streaming tiled spread: fori_loop over Morton-sorted node tiles.

    ``xs`` is already in row (Morton) order.  Each step scatter-adds the
    whole-(taps,)^d windows of one node tile, so peak memory is
    O(tile * taps^d * C) (~:data:`_XLA_TILE_ELEMS` elements per channel) —
    never the full (n, taps^d, C) update cube.
    """
    d, taps = plan.d, plan.taps
    pad_n = padded_grid_size(plan)
    n, c = xs.shape
    tile = _xla_node_tile(n, taps, d)
    pad = (-n) % tile
    # padded rows carry zero weights: their windows add exact zeros at 0
    base = jnp.pad(geometry.base, ((0, pad), (0, 0)))
    w = jnp.pad(geometry.weights, ((0, pad), (0, 0), (0, 0)))
    xp = jnp.pad(xs, ((0, pad), (0, 0)))
    dnums = jax.lax.ScatterDimensionNumbers(
        update_window_dims=tuple(range(1, d + 2)),
        inserted_window_dims=(),
        scatter_dims_to_operand_dims=tuple(range(d)))

    def body(k, g):
        bt = jax.lax.dynamic_slice_in_dim(base, k * tile, tile, axis=0)
        wt = jax.lax.dynamic_slice_in_dim(w, k * tile, tile, axis=0)
        xt = jax.lax.dynamic_slice_in_dim(xp, k * tile, tile, axis=0)
        cube = _tile_weight_cube(wt, d)  # (tile,) + (taps,)*d
        updates = cube[..., None] * xt[
            (slice(None),) + (None,) * d + (slice(None),)]
        return jax.lax.scatter_add(
            g, bt, updates, dnums,
            mode=jax.lax.GatherScatterMode.PROMISE_IN_BOUNDS)

    gpad = jnp.zeros((pad_n,) * d + (c,), dtype=xs.dtype)
    num_tiles = (n + pad) // tile
    if num_tiles == 1:
        return body(0, gpad)
    return jax.lax.fori_loop(0, num_tiles, body, gpad)


def _xla_gather_windowed(plan: NfftPlan, geometry: WindowGeometry,
                         gpad: Array) -> Array:
    """Streaming tiled whole-window gather (transpose of :func:`_xla_spread`).

    The fast single-channel body: one `lax.gather` of (taps,)^d + (C,)
    window slices per node tile.  XLA CPU expands gathers to per-element
    loops, and this slice shape hits the cheap expansion only for C = 1 —
    multi-channel inputs route through :func:`_xla_gather` instead.
    """
    d, taps = plan.d, plan.taps
    c = gpad.shape[-1]
    n = geometry.base.shape[0]
    tile = _xla_node_tile(n, taps, d)
    pad = (-n) % tile
    base = jnp.pad(geometry.base, ((0, pad), (0, 0)))
    w = jnp.pad(geometry.weights, ((0, pad), (0, 0), (0, 0)))
    dnums = jax.lax.GatherDimensionNumbers(
        offset_dims=tuple(range(1, d + 2)),
        collapsed_slice_dims=(),
        start_index_map=tuple(range(d)))

    def body(k, acc):
        bt = jax.lax.dynamic_slice_in_dim(base, k * tile, tile, axis=0)
        wt = jax.lax.dynamic_slice_in_dim(w, k * tile, tile, axis=0)
        vals = jax.lax.gather(
            gpad, bt, dnums, slice_sizes=(taps,) * d + (c,),
            mode=jax.lax.GatherScatterMode.PROMISE_IN_BOUNDS)
        out = jnp.sum(vals * _tile_weight_cube(wt, d)[..., None],
                      axis=tuple(range(1, d + 1)))  # (tile, C)
        return jax.lax.dynamic_update_slice_in_dim(acc, out, k * tile, axis=0)

    acc = jnp.zeros((n + pad, c), dtype=gpad.dtype)
    num_tiles = (n + pad) // tile
    if num_tiles == 1:
        return body(0, acc)[:n]
    return jax.lax.fori_loop(0, num_tiles, body, acc)[:n]


# Multi-channel gather strategy thresholds, tuned empirically on CPU (see
# the PR 5 sweep benchmark): XLA expands every gather into a per-element
# loop, and the windowed (taps,)^d + (C,) slice expansion is ~3-5x slower
# per element for C >= 2 than for C = 1.  A per-channel lax.map of the fast
# C = 1 body restores the good constant (linear in C); for small d and
# enough channels, a flat-index row take is better still — its ~constant
# per-index overhead amortizes over the C contiguous channel values.
_XLA_GATHER_TAKE_MIN_C = 6
_XLA_GATHER_TAKE_MAX_D = 2
_XLA_TAKE_TILE_ELEMS = 1 << 18


def _xla_gather_take(plan: NfftPlan, geometry: WindowGeometry,
                     gpad: Array) -> Array:
    """Flat-index tiled gather: one row take per (node, window element).

    Gathers rows of the channel-flattened grid by precomputed flat indices
    (static per-plan cube offsets + per-node flat corners) and contracts the
    weight cube per tile.  Per-index cost is ~constant in C, so this wins
    for many channels when taps^d is small (d <= 2).
    """
    d, taps = plan.d, plan.taps
    pad_n = padded_grid_size(plan)
    c = gpad.shape[-1]
    n = geometry.base.shape[0]
    gflat = gpad.reshape(-1, c)
    # static flat offsets of the (taps,)^d window cube (numpy: jit-literal)
    offs = np.arange(taps)
    cube = offs
    for _ in range(d - 1):
        cube = cube[..., None] * pad_n + offs
    cube_off = jnp.asarray(cube.reshape(-1), jnp.int32)
    fb = geometry.base[:, 0]
    for t in range(1, d):
        fb = fb * pad_n + geometry.base[:, t]
    tile = max(64, min(n, _XLA_TAKE_TILE_ELEMS // taps ** d))
    pad = (-n) % tile
    fbp = jnp.pad(fb, (0, pad))
    w = jnp.pad(geometry.weights, ((0, pad), (0, 0), (0, 0)))

    def body(k, acc):
        fbt = jax.lax.dynamic_slice_in_dim(fbp, k * tile, tile)
        wt = jax.lax.dynamic_slice_in_dim(w, k * tile, tile, axis=0)
        idx = (fbt[:, None] + cube_off[None, :]).reshape(-1)
        vals = jnp.take(gflat, idx, axis=0,
                        unique_indices=False).reshape(tile, -1, c)
        wcube = _tile_weight_cube(wt, d).reshape(tile, -1)
        out = jnp.einsum("ntc,nt->nc", vals, wcube)
        return jax.lax.dynamic_update_slice_in_dim(acc, out, k * tile, axis=0)

    acc = jnp.zeros((n + pad, c), dtype=gpad.dtype)
    num_tiles = (n + pad) // tile
    if num_tiles == 1:
        return body(0, acc)[:n]
    return jax.lax.fori_loop(0, num_tiles, body, acc)[:n]


def _xla_gather(plan: NfftPlan, geometry: WindowGeometry,
                gpad: Array) -> Array:
    """Streaming tiled gather, row order — multi-channel aware.

    Dispatches between three equivalent bodies on the (static) channel
    count: the whole-window slice gather for C = 1 (XLA's cheap expansion),
    a flat-index row take for many channels at small d, and a per-channel
    ``lax.map`` of the C = 1 body otherwise.  The multi-channel paths keep
    the bank matvec's inverse half from dominating a sweep: the batched
    windowed gather costs ~3-5x more *per element* as soon as C >= 2.
    """
    c = gpad.shape[-1]
    if c == 1:
        return _xla_gather_windowed(plan, geometry, gpad)
    if plan.d <= _XLA_GATHER_TAKE_MAX_D and c >= _XLA_GATHER_TAKE_MIN_C:
        return _xla_gather_take(plan, geometry, gpad)
    gm = jnp.moveaxis(gpad, -1, 0)[..., None]  # (C,) + grid + (1,)
    out = jax.lax.map(
        lambda g1: _xla_gather_windowed(plan, geometry, g1)[..., 0], gm)
    return jnp.moveaxis(out, 0, 1)


def window_spread(plan: NfftPlan, geometry: WindowGeometry, x: Array, *,
                  backend: str | None = None) -> Array:
    """Spread node values (n, C) onto the oversampled grid -> (M,)*d + (C,).

    Streams separable (taps,)^d windows into a wrap-padded grid on the
    selected backend, then folds the pad back and aligns to FFT order.
    """
    d, grid, taps = plan.d, plan.grid_size, plan.taps
    pad_n = padded_grid_size(plan)
    xs = x[geometry.perm]  # align node values with the Morton-sorted rows
    if _window_backend(backend) == "pallas":
        try:
            gpad = nfft_window.window_spread(
                xs, geometry.base, geometry.weights, padded_size=pad_n,
                interpret=_pallas_interpret())
        except Exception as exc:  # lowering failure surfaces at trace time
            if not _auto_backend(backend):
                raise
            _note_pallas_fallback(exc)
            gpad = _xla_spread(plan, geometry, xs)
    else:
        gpad = _xla_spread(plan, geometry, xs)
    # fold the periodic pad back: unwrapped u and u - M are the same cell
    ext = taps - 1
    for ax in range(d):
        main = jax.lax.slice_in_dim(gpad, 0, grid, axis=ax)
        tail = jax.lax.slice_in_dim(gpad, grid, pad_n, axis=ax)
        idx = (slice(None),) * ax + (slice(0, ext),)
        gpad = main.at[idx].add(tail)
    # padded coordinate u <-> FFT-order index (u - shift) mod M
    return jnp.roll(gpad, (-window_shift(plan),) * d, axis=tuple(range(d)))


def window_gather(plan: NfftPlan, geometry: WindowGeometry, g: Array, *,
                  backend: str | None = None) -> Array:
    """Gather node values from the grid (M,)*d + (C,) -> (n, C).

    Exact transpose of :func:`window_spread` (same geometry, same weights):
    wrap-pad the grid, stream one (taps,)^d window gather per node on the
    selected backend, then restore node order.
    """
    d, taps = plan.d, plan.taps
    rolled = jnp.roll(g, (window_shift(plan),) * d, axis=tuple(range(d)))
    gpad = jnp.pad(rolled, [(0, taps - 1)] * d + [(0, 0)], mode="wrap")
    if _window_backend(backend) == "pallas":
        try:
            out = nfft_window.window_gather(
                gpad, geometry.base, geometry.weights,
                interpret=_pallas_interpret())
        except Exception as exc:  # lowering failure surfaces at trace time
            if not _auto_backend(backend):
                raise
            _note_pallas_fallback(exc)
            out = _xla_gather(plan, geometry, gpad)
    else:
        out = _xla_gather(plan, geometry, gpad)
    # restore node order via the inverse permutation as a row *take*: the
    # equivalent multi-channel row scatter costs ~10x more on XLA CPU, and
    # the (n,) int scatter building the inverse is single-channel (cheap)
    inv = jnp.zeros_like(geometry.perm).at[geometry.perm].set(
        jnp.arange(out.shape[0], dtype=geometry.perm.dtype))
    return out[inv]


# ---------------------------------------------------------------------------
# Differentiable core (custom VJP).
#
# The pipeline is linear in both x and the spectral multiplier, and
# window_spread / window_gather are exact mutual adjoints on a shared
# geometry (same base/weights/perm; verified to 1e-12 by the adjoint test
# suite).  That gives the whole matvec a closed-form transpose that never
# differentiates *through* the fori_loop scatter tiles or the Pallas
# kernels:
#
#     cotangent wrt x:  spread ybar on the TARGET geometry (gather-adjoint),
#                       run the adjoint spectral mid-section, gather on the
#                       SOURCE geometry — one extra pipeline pass;
#     cotangent wrt multiplier_half:  elementwise product of the forward
#                       spectrum rfftn(g) and the cotangent spectrum.  The
#                       rfftn half-spectrum stores each interior Hermitian
#                       bin once but it appears twice in the full spectrum,
#                       so interior bins (last-axis index not in {0, M/2})
#                       carry weight 2 and the product is conjugated per the
#                       complex chain rule.  Rather than hand-rolling those
#                       weights we take jax.vjp over the FFT-only
#                       mid-section (rfftn -> multiply -> irfftn contains no
#                       scatter/gather), which bakes in exactly that
#                       double-count via the native irfftn/rfftn transposes
#                       and is consistent with finite differences by
#                       construction.
#
# Plan-time geometry (points, Morton windows, permutations) is
# intentionally NON-differentiable: its cotangents are zero (None).  The
# distributed/faulted variants (spectral_reduce / spectral_op / grid_hook)
# bypass the custom VJP and stay forward-only.
# ---------------------------------------------------------------------------

def _spectral_mid(plan: NfftPlan, multiplier_half: Array, g: Array) -> Array:
    """rfftn -> multiply -> irfftn on the spread grid (single multiplier)."""
    d = plan.d
    g_hat = jnp.fft.rfftn(g, axes=tuple(range(d)))
    g_hat = g_hat * multiplier_half.astype(g_hat.dtype)[..., None]
    y = jnp.fft.irfftn(g_hat, s=(plan.grid_size,) * d, axes=tuple(range(d)))
    return y.astype(g.dtype)


def _bank_multiply(plan: NfftPlan, multiplier_bank: Array, g_hat: Array,
                   broadcast: bool) -> Array:
    """Bank spectral multiply -> flat (..., S*C) half-spectrum product."""
    d = plan.d
    nb = multiplier_bank.shape[0]
    mb = jnp.moveaxis(multiplier_bank, 0, -1)  # spectrum + (S,)
    if broadcast:
        gh = g_hat[..., None, :]  # spectrum + (1, C): broadcast over S
    else:
        c = g_hat.shape[-1] // nb
        gh = g_hat.reshape(g_hat.shape[:d] + (nb, c))
    prod = mb[..., :, None].astype(g_hat.dtype) * gh  # spectrum + (S, C)
    return prod.reshape(prod.shape[:d] + (-1,))


def _bank_spectral_mid(plan: NfftPlan, broadcast: bool,
                       multiplier_bank: Array, g: Array) -> Array:
    """Bank rfftn -> member-wise multiply -> irfftn (no reduce/op hooks)."""
    d = plan.d
    g_hat = jnp.fft.rfftn(g, axes=tuple(range(d)))
    flat = _bank_multiply(plan, multiplier_bank, g_hat, broadcast)
    y = jnp.fft.irfftn(flat, s=(plan.grid_size,) * d, axes=tuple(range(d)))
    return y.astype(g.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _diff_pipeline_columns(plan: NfftPlan, backend: str | None,
                           multiplier_half: Array, src: WindowGeometry,
                           tgt: WindowGeometry, xb: Array) -> Array:
    return window_gather(
        plan, tgt,
        _spectral_mid(plan, multiplier_half,
                      window_spread(plan, src, xb, backend=backend)),
        backend=backend)


def _diff_pipeline_columns_fwd(plan, backend, multiplier_half, src, tgt, xb):
    g = window_spread(plan, src, xb, backend=backend)
    y, mid_pull = jax.vjp(
        lambda m, gg: _spectral_mid(plan, m, gg), multiplier_half, g)
    out = window_gather(plan, tgt, y, backend=backend)
    return out, (mid_pull, src, tgt)


def _diff_pipeline_columns_bwd(plan, backend, res, ybar):
    mid_pull, src, tgt = res
    v = window_spread(plan, tgt, ybar, backend=backend)  # gather-adjoint
    mult_bar, g_bar = mid_pull(v)
    x_bar = window_gather(plan, src, g_bar, backend=backend)  # spread-adjoint
    return mult_bar, None, None, x_bar


_diff_pipeline_columns.defvjp(_diff_pipeline_columns_fwd,
                              _diff_pipeline_columns_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _diff_pipeline_bank_columns(plan: NfftPlan, backend: str | None,
                                broadcast: bool, multiplier_bank: Array,
                                src: WindowGeometry, tgt: WindowGeometry,
                                xb: Array) -> Array:
    return window_gather(
        plan, tgt,
        _bank_spectral_mid(plan, broadcast, multiplier_bank,
                           window_spread(plan, src, xb, backend=backend)),
        backend=backend)


def _diff_pipeline_bank_columns_fwd(plan, backend, broadcast,
                                    multiplier_bank, src, tgt, xb):
    g = window_spread(plan, src, xb, backend=backend)
    y, mid_pull = jax.vjp(
        lambda m, gg: _bank_spectral_mid(plan, broadcast, m, gg),
        multiplier_bank, g)
    out = window_gather(plan, tgt, y, backend=backend)
    return out, (mid_pull, src, tgt)


def _diff_pipeline_bank_columns_bwd(plan, backend, broadcast, res, ybar):
    mid_pull, src, tgt = res
    v = window_spread(plan, tgt, ybar, backend=backend)
    bank_bar, g_bar = mid_pull(v)
    x_bar = window_gather(plan, src, g_bar, backend=backend)
    return bank_bar, None, None, x_bar


_diff_pipeline_bank_columns.defvjp(_diff_pipeline_bank_columns_fwd,
                                   _diff_pipeline_bank_columns_bwd)


def fused_pipeline(plan: NfftPlan, multiplier_half: Array,
                   src: WindowGeometry, tgt: WindowGeometry, x: Array,
                   spectral_reduce=None, backend: str | None = None,
                   spectral_op=None, grid_hook=None) -> Array:
    """spread -> rfftn -> multiply -> irfftn -> gather, one traceable body.

    Two hooks let the distributed matvec reuse this single implementation
    (so the local and distributed pipelines cannot drift apart):

    * ``spectral_reduce`` is applied to the support block of the multiplied
      half-spectrum (see :func:`spectral_support`) — the psum spectral mode's
      one cross-shard accumulation.
    * ``spectral_op``, when given, replaces the whole rfftn -> multiply ->
      irfftn mid-section: it maps the spread grid ``(M,)*d + (C,)`` (real,
      FFT order) to the inverse-transformed grid of the same shape.  The
      pencil spectral mode uses it to run the reduce-scattered, slab-sharded
      transform of :mod:`repro.dist.pencil_fft`; ``multiplier_half`` and
      ``spectral_reduce`` are ignored in that case (the op owns the
      multiply).

    ``backend`` selects the window-step backend (see :func:`resolve_backend`).
    ``grid_hook``, when given, maps the spread grid ``(M,)*d + (C,)`` to a
    grid of the same shape before the spectral section — the deterministic
    fault-injection seam (:mod:`repro.runtime.faultinject` poisons it to
    model grid memory corruption); production callers leave it ``None``.

    With no hooks this routes through the custom-VJP differentiable core:
    gradients flow to ``x`` and ``multiplier_half`` via the closed-form
    transpose pipeline (one extra pass), never through the window scatter
    loops.  The hooked (distributed / fault-injected) variants stay
    forward-only.
    """
    d = plan.d
    batched = x.ndim == 2
    xb = x if batched else x[:, None]
    if spectral_reduce is None and spectral_op is None and grid_hook is None:
        out = _diff_pipeline_columns(plan, backend, multiplier_half,
                                     src, tgt, xb)
        return out if batched else out[..., 0]
    g = window_spread(plan, src, xb, backend=backend)
    if grid_hook is not None:
        g = grid_hook(g)
    if spectral_op is not None:
        y = spectral_op(g)
    else:
        g_hat = jnp.fft.rfftn(g, axes=tuple(range(d)))
        g_hat = g_hat * multiplier_half.astype(g_hat.dtype)[..., None]
        if spectral_reduce is not None:
            sup = jnp.meshgrid(*spectral_support(plan), indexing="ij")
            block = spectral_reduce(g_hat[tuple(sup)])
            g_hat = jnp.zeros_like(g_hat).at[tuple(sup)].set(block)
        y = jnp.fft.irfftn(g_hat, s=(plan.grid_size,) * d,
                           axes=tuple(range(d)))
    out = window_gather(plan, tgt, y.astype(xb.dtype), backend=backend)
    return out if batched else out[..., 0]


@functools.partial(jax.jit, static_argnames=("plan", "backend"))
def fused_matvec_tilde(plan: NfftPlan, multiplier_half: Array,
                       src: WindowGeometry, tgt: WindowGeometry,
                       x: Array, backend: str | None = None) -> Array:
    """y = W̃ x via the fused pipeline; x: (n,) or (n, C) real."""
    return fused_pipeline(plan, multiplier_half, src, tgt, x, backend=backend)


# ---------------------------------------------------------------------------
# Multiplier banks: amortize spread + forward FFT across S operators.
# ---------------------------------------------------------------------------

def stack_multipliers(plan: NfftPlan, b_hats) -> Array:
    """Stack per-member fused multipliers into an ``(S,) + half-spectrum`` bank.

    All members share the plan (and hence the window geometry): only the
    kernel Fourier coefficients differ, so a whole bank of operators can ride
    on one spread and one forward transform (:func:`fused_pipeline_bank`).
    """
    return jnp.stack([fused_spectral_multiplier(plan, bh) for bh in b_hats])


def fused_pipeline_bank(plan: NfftPlan, multiplier_bank: Array,
                        src: WindowGeometry, tgt: WindowGeometry, x: Array,
                        spectral_reduce=None, backend: str | None = None,
                        spectral_op=None) -> Array:
    """Bank matvec: one spread + one forward rfftn shared by S multipliers.

    ``multiplier_bank`` has shape ``(S,) + (M,)*(d-1) + (M//2+1,)`` (see
    :func:`stack_multipliers`).  Two input flavors, distinguished by rank:

    * **broadcast** — ``x`` of shape (n,) or (n, C): every member is applied
      to the same right-hand sides.  The spread and forward rfftn run once
      with C channels; the S cheap diagonal multiplies, one *batched* irfftn
      over S*C channels, and one gather with S*C channels produce
      ``(S, n)`` / ``(S, n, C)``.  An S-point multiplier sweep costs ~one
      matvec plus S spectral multiplies instead of S full pipelines.

    * **lockstep** — ``x`` of shape (S, n, C): member ``s`` is applied to
      ``x[s]`` (the shape a bank Krylov solver iterates on).  The S*C system
      columns ride the channel axis end to end — still exactly one spread,
      one forward rfftn, one irfftn, one gather.

    ``spectral_reduce`` / ``spectral_op`` mirror :func:`fused_pipeline`:
    the reduce hits the support block of the multiplied half-spectrum with
    the bank stacked into the channel axis (the distributed psum mode's one
    collective); ``spectral_op``, when given, replaces the whole rfftn ->
    multiply -> irfftn mid-section and must map the spread grid to an
    inverse-transformed grid with ``S*C`` trailing channels (it owns the
    bank multiply — the pencil mode's per-device multiplier slabs).
    """
    nb = multiplier_bank.shape[0]
    lockstep = x.ndim == 3
    if lockstep:
        if x.shape[0] != nb:
            raise ValueError(
                f"lockstep x has bank axis {x.shape[0]}, bank has {nb}")
        c = x.shape[-1]
        xb = jnp.moveaxis(x, 0, 1).reshape(x.shape[1], nb * c)
    else:
        batched = x.ndim == 2
        xb = x if batched else x[:, None]
        c = xb.shape[-1]
    out = _bank_columns_core(plan, multiplier_bank, src, tgt, xb,
                             broadcast=not lockstep,
                             spectral_reduce=spectral_reduce,
                             backend=backend, spectral_op=spectral_op)
    out = jnp.moveaxis(out.reshape(out.shape[0], nb, c), 0, 1)  # (S, n, C)
    if lockstep:
        return out
    return out if batched else out[..., 0]


def _bank_columns_transform(plan: NfftPlan, multiplier_bank: Array,
                            src: WindowGeometry, xb: Array,
                            *, broadcast: bool, spectral_reduce=None,
                            backend: str | None = None,
                            spectral_op=None) -> Array:
    """Gather-free half of the bank pipeline: spread -> rfftn -> multiply ->
    irfftn, returning the inverse-transformed grid (FFT order).

    ``xb`` is (n, K): the spread/FFT channel lanes.  ``broadcast=True``
    treats all K columns as shared right-hand sides and expands them
    against every member (output K*S channels, S-major); ``broadcast=False``
    treats K = S*C bank-major lockstep columns (column ``s*C + j`` belongs
    to member ``s``) and multiplies member-wise (output K channels).

    The grid this returns depends only on the source side (nodes, spectral
    multipliers, right-hand sides) — any number of target sets can be
    gathered from it afterwards (:func:`window_gather` /
    :func:`fused_gather_columns`), which is what the serving tier caches
    per (model, dual-vector) column.
    """
    d = plan.d
    g = window_spread(plan, src, xb, backend=backend)
    if spectral_op is not None:
        y = spectral_op(g)  # (M,)*d + (S*C,): the op owns the bank multiply
    else:
        g_hat = jnp.fft.rfftn(g, axes=tuple(range(d)))
        flat = _bank_multiply(plan, multiplier_bank, g_hat, broadcast)
        if spectral_reduce is not None:
            sup = jnp.meshgrid(*spectral_support(plan), indexing="ij")
            block = spectral_reduce(flat[tuple(sup)])
            flat = jnp.zeros_like(flat).at[tuple(sup)].set(block)
        y = jnp.fft.irfftn(flat, s=(plan.grid_size,) * d,
                           axes=tuple(range(d)))
    return y.astype(xb.dtype)


def _bank_columns_core(plan: NfftPlan, multiplier_bank: Array,
                       src: WindowGeometry, tgt: WindowGeometry, xb: Array,
                       *, broadcast: bool, spectral_reduce=None,
                       backend: str | None = None, spectral_op=None) -> Array:
    """Full bank pipeline body in flat column layout (transform + gather).

    Hook-free calls route through the custom-VJP differentiable bank core
    (gradients to ``multiplier_bank`` and ``xb`` via the transpose
    pipeline); the distributed variants stay forward-only.
    """
    if spectral_reduce is None and spectral_op is None:
        return _diff_pipeline_bank_columns(plan, backend, broadcast,
                                           multiplier_bank, src, tgt, xb)
    y = _bank_columns_transform(plan, multiplier_bank, src, xb,
                                broadcast=broadcast,
                                spectral_reduce=spectral_reduce,
                                backend=backend, spectral_op=spectral_op)
    return window_gather(plan, tgt, y, backend=backend)


@functools.partial(jax.jit, static_argnames=("plan", "backend"))
def fused_transform_columns(plan: NfftPlan, multiplier_columns: Array,
                            src: WindowGeometry, xb: Array,
                            backend: str | None = None) -> Array:
    """Per-column transform-to-grid: column ``j`` of ``xb`` (n, K) through
    multiplier ``j`` of ``multiplier_columns`` ((K,) + half-spectrum) ->
    grid ``(M,)*d + (K,)`` (real, FFT order).

    One spread + one forward rfftn + one batched irfftn for all K columns;
    the result is the gather-ready state of the prediction pipeline, so a
    serving tick that caches it per (model, dual-vector) column pays only
    a target-geometry build and one packed gather per tick
    (:func:`fused_gather_columns`).
    """
    return _bank_columns_transform(plan, multiplier_columns, src, xb,
                                   broadcast=False, backend=backend)


@functools.partial(jax.jit, static_argnames=("plan", "backend"))
def fused_gather_columns(plan: NfftPlan, tgt: WindowGeometry, grid: Array,
                         col_index: Array,
                         backend: str | None = None) -> Array:
    """Ragged-packed gather: row ``r`` of the packed target geometry reads
    channel ``col_index[r]`` of ``grid`` ((M,)*d + (K,)) -> (m,).

    This is how a predict tick packs many users' query points into ONE
    gather: concatenate every request's (scaled) query points into one
    target set, label each row with the grid channel of its (model,
    dual-vector) column, gather once, and split the output back per
    request on the host.
    """
    out = window_gather(plan, tgt, grid, backend=backend)  # (m, K)
    idx = col_index.astype(jnp.int32)[:, None]
    return jnp.take_along_axis(out, idx, axis=1)[:, 0]


def fused_pipeline_bank_columns(plan: NfftPlan, multiplier_bank: Array,
                                src: WindowGeometry, tgt: WindowGeometry,
                                u: Array, spectral_reduce=None,
                                backend: str | None = None,
                                spectral_op=None) -> Array:
    """Lockstep bank matvec in flat column-major layout: (n, S*C) -> same.

    Column ``s*C + j`` belongs to member ``s`` — exactly the layout the
    lockstep solvers iterate on, so a bank Krylov iteration runs with ZERO
    bank-axis transposes (the (S, n, C) flavor of
    :func:`fused_pipeline_bank` costs four (n, S*C)-sized copies per call
    just moving the bank axis in and out).
    """
    nb = multiplier_bank.shape[0]
    if u.ndim != 2 or u.shape[-1] % nb:
        raise ValueError(
            f"columns input must be (n, S*C) with S={nb}, got {u.shape}")
    return _bank_columns_core(plan, multiplier_bank, src, tgt, u,
                              broadcast=False,
                              spectral_reduce=spectral_reduce,
                              backend=backend, spectral_op=spectral_op)


@functools.partial(jax.jit, static_argnames=("plan", "backend"))
def fused_matvec_tilde_bank(plan: NfftPlan, multiplier_bank: Array,
                            src: WindowGeometry, tgt: WindowGeometry,
                            x: Array, backend: str | None = None) -> Array:
    """y[s] = W̃_s x (broadcast) or W̃_s x[s] (lockstep); see
    :func:`fused_pipeline_bank`."""
    return fused_pipeline_bank(plan, multiplier_bank, src, tgt, x,
                               backend=backend)


@functools.partial(jax.jit, static_argnames=("plan", "backend"))
def fused_matvec_tilde_bank_columns(plan: NfftPlan, multiplier_bank: Array,
                                    src: WindowGeometry,
                                    tgt: WindowGeometry, u: Array,
                                    backend: str | None = None) -> Array:
    """Jitted :func:`fused_pipeline_bank_columns` (the solver hot loop)."""
    return fused_pipeline_bank_columns(plan, multiplier_bank, src, tgt, u,
                                       backend=backend)
