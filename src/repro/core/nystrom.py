"""Nyström eigenvalue approximation (paper Section 5).

Traditional Nyström (§5.1): sub-sample L nodes, build the full-kernel blocks
W̃_XX and W̃_XY explicitly, approximate W̃ ≈ C^T W̃_XX^{-1} C with
C = [W̃_XX W̃_XY], recover the zero-diagonal adjacency as
W_E = W̃_E - diag(W̃_E), and extract the eigendecomposition of A_E via the
paper's QR variant (QR of D_E^{-1/2} C^T, then eigendecomposition of
R W̃_XX^{-1} R^T minus the span(Q)-projected diagonal correction).

Hybrid Nyström-Gaussian-NFFT (Algorithm 5.1): randomized range finder
Q = orth(A G) with the 2L matvecs A·G and A·Q computed *column-wise by the
NFFT fast summation*, then a rank-M truncated eigendecomposition of
(A Q)(Q^T A Q)^{-1}(A Q)^T.

Both return (eigenvalues, eigenvectors) of A := D^{-1/2} W D^{-1/2}.
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.fastsum import NormalizedAdjacencyOperator
from repro.core.kernels import Kernel

Array = jax.Array


class NystromResult(NamedTuple):
    eigenvalues: Array  # (k,) descending
    eigenvectors: Array  # (n, k)


def _kernel_block(kernel: Kernel, rows: Array, cols: Array) -> Array:
    """Full kernel block W̃ between row nodes and col nodes."""
    diff = rows[:, None, :] - cols[None, :, :]
    r = jnp.sqrt(jnp.maximum(jnp.sum(diff * diff, axis=-1), 0.0))
    return kernel.phi(r)


def nystrom_traditional(kernel: Kernel, points: Array, k: int, sample_size: int,
                        *, key: Array, jitter: float = 0.0) -> NystromResult:
    """Traditional Nyström (§5.1) with the paper's QR-based extraction.

    O(n L^2).  Only W_XX (L x L) and W_XY (L x (n-L)) are ever formed.
    """
    n = points.shape[0]
    l_size = sample_size
    perm = jax.random.permutation(key, n)
    inv_perm = jnp.argsort(perm)
    pts = points[perm]
    x_pts, y_pts = pts[:l_size], pts[l_size:]

    # Nyström factorizes the *full* kernel matrix W̃ (SPD for the Gaussian):
    # W̃_E = C^T W̃_XX^{-1} C with C = [W̃_XX  W̃_XY].  The zero-diagonal
    # adjacency is recovered afterwards as W_E = W̃_E - diag(W̃_E); running
    # Nyström directly on the indefinite zero-diagonal blocks (K - I) makes
    # the middle inverse meaningless and the eigenvalues drift O(1).
    wt_xx = _kernel_block(kernel, x_pts, x_pts)
    wt_xy = _kernel_block(kernel, x_pts, y_pts)
    c = jnp.concatenate([wt_xx, wt_xy], axis=1)  # (L, n)
    wt_reg = wt_xx + jitter * jnp.eye(l_size, dtype=wt_xx.dtype)
    # one LU factorization serves every solve (W̃_XX is not SPD for all
    # kernels — multiquadrics are conditionally definite — so LU, not
    # Cholesky)
    lu = jax.scipy.linalg.lu_factor(wt_reg)
    solve = lambda b: jax.scipy.linalg.lu_solve(lu, b)

    # diag(W̃_E)_i = c_i^T W̃_XX^{-1} c_i  and  deg = W_E 1, both O(n L^2).
    sc = solve(c)  # W̃_XX^{-1} C
    diag_e = jnp.sum(c * sc, axis=0)
    deg = c.T @ (sc @ jnp.ones((n,), c.dtype)) - diag_e
    # The paper notes negative entries in D_E cannot be ruled out — that is
    # the traditional method's failure mode.  We keep the sign (sqrt of a
    # negative degree poisons the run) but clamp |.| >= tiny to avoid 0-div,
    # mirroring the observed "failed runs" behaviour honestly.
    inv_sqrt_deg = jnp.sign(deg) / jnp.sqrt(jnp.maximum(jnp.abs(deg), jnp.finfo(deg.dtype).tiny))

    # QR variant:  A_E = Q (R W̃_XX^{-1} R^T - Q^T Δ Q) Q^T with
    # C D^{-1/2} = (QR)^T and Δ = D^{-1/2} diag(W̃_E) D^{-1/2}; the diagonal
    # correction is projected onto span(Q) (exact up to (I - QQ^T) Δ).
    q_hat, r_hat = jnp.linalg.qr((c * inv_sqrt_deg[None, :]).T)  # n x L, L x L
    delta = diag_e * inv_sqrt_deg ** 2
    middle = r_hat @ solve(r_hat.T) - q_hat.T @ (delta[:, None] * q_hat)
    middle = (middle + middle.T) / 2.0
    theta, u = jnp.linalg.eigh(middle)
    order = jnp.argsort(-theta)[:k]
    vecs = q_hat @ u[:, order]
    return NystromResult(eigenvalues=theta[order], eigenvectors=vecs[inv_perm])


def nystrom_gaussian_nfft(adjacency: NormalizedAdjacencyOperator, k: int,
                          *, num_columns: int, rank: int | None = None,
                          key: Array,
                          sigma_tol: float | None = None) -> NystromResult:
    """Algorithm 5.1 — hybrid Nyström-Gaussian-NFFT.

    ``num_columns`` = L Gaussian probe columns, ``rank`` = M >= k (default k).
    All 2L matvecs with A go through the NFFT fast summation.

    ``sigma_tol``: relative floor for the core-matrix inversion.  A is
    indefinite, so trailing Ritz values ``sigma_m`` of ``Q^T A Q`` can land
    near zero by +/- cancellation (or go negative) — with ``|A Q u_j|``
    *not* correspondingly small — and ``R diag(1/sigma_m) R^T`` blows up by
    ``1/sigma`` (observed: eigenvalue 3.8 from a normalized adjacency whose
    spectrum lies in [-1, 1]).  Directions with ``sigma <= sigma_tol *
    sigma_max`` are truncated pseudo-inverse style (their inverse set to 0 —
    shape-stable, jit-friendly).  The default 1e-3 sits below anything a
    tens-of-columns sketch resolves credibly but above the cancellation
    band; pass a smaller tol for large-L high-accuracy PSD-like runs.
    """
    m_rank = k if rank is None else rank
    n = adjacency.n
    dtype = adjacency.inv_sqrt_deg.dtype

    # steps 1-2 are inside `adjacency` (fastsum params + degrees).
    g = jax.random.normal(key, (n, num_columns), dtype=dtype)  # step 3
    y = adjacency.matvec(g)  # batched column-wise fast summation
    q, _ = jnp.linalg.qr(y)

    b1 = adjacency.matvec(q)  # step 4
    b2 = q.T @ b1
    b2 = (b2 + b2.T) / 2.0

    theta, u = jnp.linalg.eigh(b2)  # step 5
    order = jnp.argsort(-theta)[:m_rank]
    sigma_m = theta[order]
    u_m = u[:, order]

    q_hat, r_hat = jnp.linalg.qr(b1 @ u_m)  # step 6
    # adaptive rank truncation: only sigma above the tol * sigma_max floor
    # are inverted; near-zero / negative trailing Ritz values would
    # otherwise dominate the core matrix by 1/sigma.
    tol = 1e-3 if sigma_tol is None else sigma_tol
    keep = sigma_m > tol * jnp.max(jnp.abs(sigma_m))
    inv_sigma = jnp.where(keep, 1.0 / jnp.where(keep, sigma_m, 1.0), 0.0)
    core = (r_hat * inv_sigma[None, :]) @ r_hat.T  # step 7
    core = (core + core.T) / 2.0
    lam, u_hat = jnp.linalg.eigh(core)
    order2 = jnp.argsort(-lam)[:k]  # step 8
    return NystromResult(eigenvalues=lam[order2],
                         eigenvectors=q_hat @ u_hat[:, order2])
