"""Nyström eigenvalue approximation (paper Section 5).

Traditional Nyström (§5.1): sub-sample L nodes, build the blocks W_XX and
W_XY explicitly, approximate W ≈ [W_XX; W_XY^T] W_XX^{-1} [W_XX W_XY], and
extract a rank-L eigendecomposition of A_E via the paper's QR variant
(QR of D_E^{-1/2}[W_XX W_XY]^T, then eigendecomposition of R W_XX^{-1} R^T).

Hybrid Nyström-Gaussian-NFFT (Algorithm 5.1): randomized range finder
Q = orth(A G) with the 2L matvecs A·G and A·Q computed *column-wise by the
NFFT fast summation*, then a rank-M truncated eigendecomposition of
(A Q)(Q^T A Q)^{-1}(A Q)^T.

Both return (eigenvalues, eigenvectors) of A := D^{-1/2} W D^{-1/2}.
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.fastsum import NormalizedAdjacencyOperator
from repro.core.kernels import Kernel

Array = jax.Array


class NystromResult(NamedTuple):
    eigenvalues: Array  # (k,) descending
    eigenvectors: Array  # (n, k)


def _kernel_block(kernel: Kernel, rows: Array, cols: Array,
                  zero_diag_offset: int | None = None) -> Array:
    """W block between row nodes and col nodes (zero diagonal if aligned).

    ``zero_diag_offset``: if not None, entry (i, j) with ``i == j + offset``
    is a true diagonal element of W and is zeroed.
    """
    diff = rows[:, None, :] - cols[None, :, :]
    r = jnp.sqrt(jnp.maximum(jnp.sum(diff * diff, axis=-1), 0.0))
    w = kernel.phi(r)
    if zero_diag_offset is not None:
        i = jnp.arange(rows.shape[0])[:, None]
        j = jnp.arange(cols.shape[0])[None, :]
        w = jnp.where(i == j + zero_diag_offset, 0.0, w)
    return w


def nystrom_traditional(kernel: Kernel, points: Array, k: int, sample_size: int,
                        *, key: Array, jitter: float = 0.0) -> NystromResult:
    """Traditional Nyström (§5.1) with the paper's QR-based extraction.

    O(n L^2).  Only W_XX (L x L) and W_XY (L x (n-L)) are ever formed.
    """
    n = points.shape[0]
    l_size = sample_size
    perm = jax.random.permutation(key, n)
    inv_perm = jnp.argsort(perm)
    pts = points[perm]
    x_pts, y_pts = pts[:l_size], pts[l_size:]

    w_xx = _kernel_block(kernel, x_pts, x_pts, zero_diag_offset=0)
    w_xy = _kernel_block(kernel, x_pts, y_pts)

    # Degree approximation D_E = diag(W_E 1) with
    # W_E = [W_XX; W_XY^T] W_XX^{-1} [W_XX W_XY]:
    ones_x = jnp.sum(w_xx, axis=1) + jnp.sum(w_xy, axis=1)  # exact rows (X)
    # rows in Y:  W_XY^T 1_X + W_XY^T W_XX^{-1} W_XY 1_Y
    rhs = jnp.sum(w_xy, axis=1)  # W_XY 1_Y  (L,)
    w_xx_reg = w_xx + jitter * jnp.eye(l_size, dtype=w_xx.dtype)
    solve = lambda b: jnp.linalg.solve(w_xx_reg, b)
    ones_y = w_xy.T @ jnp.ones((l_size,), w_xx.dtype) + w_xy.T @ solve(rhs)
    deg = jnp.concatenate([ones_x, ones_y])
    # The paper notes negative entries in D_E cannot be ruled out — that is
    # the traditional method's failure mode.  We keep the sign (sqrt of a
    # negative degree poisons the run) but clamp |.| >= tiny to avoid 0-div,
    # mirroring the observed "failed runs" behaviour honestly.
    inv_sqrt_deg = jnp.sign(deg) / jnp.sqrt(jnp.maximum(jnp.abs(deg), jnp.finfo(deg.dtype).tiny))

    # QR variant:  C = D_E^{-1/2} [W_XX W_XY]^T   (n x L)
    c = jnp.concatenate([w_xx, w_xy], axis=1).T * inv_sqrt_deg[:, None]
    q_hat, r_hat = jnp.linalg.qr(c)  # n x L, L x L
    middle = r_hat @ solve(r_hat.T)
    middle = (middle + middle.T) / 2.0
    theta, u = jnp.linalg.eigh(middle)
    order = jnp.argsort(-theta)[:k]
    vecs = q_hat @ u[:, order]
    return NystromResult(eigenvalues=theta[order], eigenvectors=vecs[inv_perm])


def nystrom_gaussian_nfft(adjacency: NormalizedAdjacencyOperator, k: int,
                          *, num_columns: int, rank: int | None = None,
                          key: Array) -> NystromResult:
    """Algorithm 5.1 — hybrid Nyström-Gaussian-NFFT.

    ``num_columns`` = L Gaussian probe columns, ``rank`` = M >= k (default k).
    All 2L matvecs with A go through the NFFT fast summation.
    """
    m_rank = k if rank is None else rank
    n = adjacency.n
    dtype = adjacency.inv_sqrt_deg.dtype

    # steps 1-2 are inside `adjacency` (fastsum params + degrees).
    g = jax.random.normal(key, (n, num_columns), dtype=dtype)  # step 3
    y = adjacency.matvec(g)  # batched column-wise fast summation
    q, _ = jnp.linalg.qr(y)

    b1 = adjacency.matvec(q)  # step 4
    b2 = q.T @ b1
    b2 = (b2 + b2.T) / 2.0

    theta, u = jnp.linalg.eigh(b2)  # step 5
    order = jnp.argsort(-theta)[:m_rank]
    sigma_m = theta[order]
    u_m = u[:, order]

    q_hat, r_hat = jnp.linalg.qr(b1 @ u_m)  # step 6
    core = r_hat @ jnp.diag(1.0 / sigma_m) @ r_hat.T  # step 7
    core = (core + core.T) / 2.0
    lam, u_hat = jnp.linalg.eigh(core)
    order2 = jnp.argsort(-lam)[:k]  # step 8
    return NystromResult(eigenvalues=lam[order2],
                         eigenvectors=q_hat @ u_hat[:, order2])
