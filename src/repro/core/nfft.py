"""d-dimensional NFFT (nonequispaced fast Fourier transform) in pure JAX.

Conventions (matching the paper, Section 3):

    forward :  f_j    = sum_{l in I_N^d} f_hat[l] * e^{+2 pi i l . v_j}
    adjoint :  x_hat[l] = sum_j x_j * e^{-2 pi i l . v_j}

with ``I_N = {-N/2, ..., N/2-1}`` and nodes ``v_j in [-1/2, 1/2)^d``.
Coefficient arrays have shape ``(N,)*d`` in FFT order (no fftshift anywhere).

Algorithm (Keiner–Kunis–Potts): oversampled grid of size ``M = sigma_os * N``
per dimension, compactly supported window ``phi`` with cut-off ``m``
(support ``|x| <= m/M``), Kaiser–Bessel by default.

    forward:  deconvolve (divide by phi_hat) -> embed I_N into I_M ->
              unnormalized inverse FFT scaled by 1/M^d (= jnp.fft.ifftn) ->
              gather with window taps at each node.
    adjoint:  exact matrix adjoint of the forward: spread (scatter-add) ->
              fftn -> extract I_N -> deconvolve (divide by M^d * phi_hat).

Because the two transforms are *exact* matrix adjoints of one another, the
fast-summation operator  F . diag(b_hat) . F^H  is exactly Hermitian for real
``b_hat`` — the Lanczos method below operates on a genuinely symmetric
operator, not an approximately-symmetric one.

TPU adaptation (DESIGN.md §3): node sets are static across Krylov iterations,
so window geometry is precomputed once and reused by every matvec.  The hot
path uses the *separable* :class:`WindowGeometry` (O(n*d*taps) values)
consumed by the streaming window backends in ``repro.core.fastsum_exec`` /
``repro.kernels.nfft_window``; the flattened tensor-product
:class:`NfftGeometry` (O(n*taps^d) values) survives only for the two-NFFT
oracle transforms below.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

KAISER_BESSEL = "kaiser_bessel"
GAUSSIAN_WINDOW = "gaussian"


@dataclasses.dataclass(frozen=True)
class NfftPlan:
    """Static NFFT parameters (hashable; used as a jit static argument)."""

    d: int
    n_bandwidth: int  # N, even
    m: int  # window cut-off
    sigma_os: float = 2.0  # oversampling factor
    window: str = KAISER_BESSEL

    def __post_init__(self):
        assert self.n_bandwidth % 2 == 0, "bandwidth N must be even"
        assert self.d >= 1 and self.m >= 1

    @property
    def grid_size(self) -> int:
        """Oversampled grid size M per dimension (even, >= sigma_os*N)."""
        m_grid = int(np.ceil(self.sigma_os * self.n_bandwidth / 2) * 2)
        return max(m_grid, self.n_bandwidth + 2 * self.m + 2)

    @property
    def taps(self) -> int:
        return 2 * self.m + 1

    # -- window ------------------------------------------------------------
    def window_b(self) -> float:
        sigma = self.grid_size / self.n_bandwidth
        if self.window == KAISER_BESSEL:
            return float(np.pi * (2.0 - 1.0 / sigma))
        if self.window == GAUSSIAN_WINDOW:
            return float((2.0 * sigma / (2.0 * sigma - 1.0)) * self.m / np.pi)
        raise ValueError(self.window)

    def window_spatial(self, x: Array) -> Array:
        """phi(x), normalized by e^{-b m} (KB) to stay finite in f32.

        The normalization cancels inside each transform because ``phi`` is
        always paired with a division by ``phi_hat`` carrying the same factor.
        """
        m, grid = self.m, self.grid_size
        b = self.window_b()
        if self.window == KAISER_BESSEL:
            t = m * m - (grid * x) ** 2
            s = jnp.sqrt(jnp.maximum(t, 0.0))
            # sinh(b s)/(pi s) * e^{-b m}, computed overflow-free:
            #   = e^{b(s-m)} (1 - e^{-2 b s}) / (2 pi s)
            num = jnp.exp(b * (s - m)) * (1.0 - jnp.exp(-2.0 * b * s))
            safe_s = jnp.where(s > 1e-12, s, 1.0)
            val = jnp.where(s > 1e-12, num / (2.0 * jnp.pi * safe_s), b * jnp.exp(-b * m) / jnp.pi)
            return jnp.where(t >= 0, val, 0.0)
        if self.window == GAUSSIAN_WINDOW:
            val = jnp.exp(-((grid * x) ** 2) / b) / jnp.sqrt(jnp.pi * b)
            return jnp.where(jnp.abs(grid * x) <= m, val, 0.0)
        raise ValueError(self.window)

    def window_fourier_1d(self, k: Array) -> Array:
        """phi_hat(k) per dimension, same e^{-b m} normalization as spatial."""
        m, grid = self.m, self.grid_size
        b = self.window_b()
        if self.window == KAISER_BESSEL:
            arg = b * b - (2.0 * jnp.pi * k / grid) ** 2
            s = jnp.sqrt(jnp.maximum(arg, 0.0))
            # I_0(m s) e^{-b m} = i0e(m s) e^{m s - b m};  m s <= b m.
            val = jax.scipy.special.i0e(m * s) * jnp.exp(m * s - b * m)
            # |k| beyond the valid band never occurs for |k| <= N/2 < M/2 when
            # sigma_os >= 1.5; clamp defensively.
            return jnp.where(arg >= 0, val, jnp.exp(-b * m)) / grid
        if self.window == GAUSSIAN_WINDOW:
            return jnp.exp(-b * (jnp.pi * k / grid) ** 2) / grid
        raise ValueError(self.window)

    def deconvolution_grid(self) -> np.ndarray:
        """prod_t phi_hat(l_t) on the (N,)*d coefficient grid, FFT order.

        Cached per plan (the plan is frozen/hashable) as a numpy constant —
        callers no longer rebuild the grid per transform, and jit traces
        embed it as a literal instead of re-staging the window evaluation.
        """
        return _deconvolution_grid_cached(self)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class NfftGeometry:
    """Flattened tensor-product window geometry (oracle transforms only).

    The fused engine and both streaming window backends run on the separable
    :class:`WindowGeometry`; this O(n*taps^d) layout is kept for the
    two-NFFT reference path (`nfft_forward`/`nfft_adjoint`) and the dry-run
    cells.

    indices: (n, taps^d) int32 — flattened oversampled-grid indices.
    weights: (n, taps^d) float — tensor-product window values.
    perm: optional (n,) int32 — when present, row ``r`` holds the geometry of
      node ``perm[r]`` (rows are sorted in Morton/tile order so the window
      gather/spread kernels get spatial locality).  ``None`` means rows are in
      node order.
    """

    indices: Array
    weights: Array
    perm: Array | None = None

    def tree_flatten(self):
        return (self.indices, self.weights, self.perm), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def n_nodes(self) -> int:
        return self.indices.shape[0]


def morton_codes(cells: Array, grid_size: int, dtype=jnp.int32) -> Array:
    """Z-order (Morton) codes for integer cell coordinates (n, d).

    Interleaves the bits of the per-dimension cell indices; sorting by the
    code orders nodes in tiles so neighbouring rows touch neighbouring grid
    memory.  The caller must pick a ``dtype`` wide enough for
    ``bits(grid_size) * d`` interleaved bits (int32 covers every paper
    setup: e.g. grid 128, d=3 -> 21 bits).
    """
    n, d = cells.shape
    bits = max(1, int(grid_size - 1).bit_length())
    assert bits * d <= jnp.iinfo(dtype).bits - 2, (bits, d, dtype)
    code = jnp.zeros((n,), dtype=dtype)
    cells = cells.astype(dtype)
    for b in range(bits):
        for t in range(d):
            code = code | (((cells[:, t] >> b) & 1) << (b * d + t))
    return code


def _morton_perm(cells: Array, grid_size: int) -> Array:
    """argsort by Morton code, falling back gracefully for huge grids.

    Plans whose interleaved code would overflow int32 use int64 when x64 is
    enabled; otherwise sorting is skipped (identity order) — ordering is a
    layout optimization, never a semantic requirement.
    """
    n, d = cells.shape
    bits = max(1, int(grid_size - 1).bit_length())
    if bits * d <= 30:
        codes = morton_codes(cells, grid_size)
    elif jax.config.jax_enable_x64 and bits * d <= 62:
        codes = morton_codes(cells, grid_size, dtype=jnp.int64)
    else:
        return jnp.arange(n, dtype=jnp.int32)
    return jnp.argsort(codes).astype(jnp.int32)


def _window_taps_1d(plan: NfftPlan, nodes: Array):
    """Per-dim tap indices (unwrapped) and window values for nodes (n, d).

    Returns (base, idx_d, w_d): base (n, d) int32 leftmost tap per dim,
    idx_d (n, d, taps) unwrapped grid indices, w_d (n, d, taps) weights.
    """
    grid, m, taps = plan.grid_size, plan.m, plan.taps
    y = nodes * grid  # grid-scaled positions, per dim
    base = jnp.floor(y).astype(jnp.int32) - m  # (n, d)
    offs = jnp.arange(taps, dtype=jnp.int32)  # (taps,)
    idx_d = base[:, :, None] + offs[None, None, :]  # (n, d, taps)
    dist = nodes[:, :, None] - idx_d.astype(nodes.dtype) / grid
    w_d = plan.window_spatial(dist)  # (n, d, taps)
    return base, idx_d, w_d


@functools.partial(jax.jit, static_argnames=("plan", "sort"))
def build_geometry(plan: NfftPlan, nodes: Array, *,
                   sort: bool = True) -> NfftGeometry:
    """Window geometry for nodes (n, d) in [-1/2, 1/2)^d.

    With ``sort=True`` (default) rows are ordered by the Morton code of the
    node's base grid cell and the permutation is recorded in ``perm``; the
    transforms below undo it, so results are independent of ``sort``.
    """
    n, d = nodes.shape
    assert d == plan.d, (d, plan.d)
    grid = plan.grid_size

    base, idx_d, w_d = _window_taps_1d(plan, nodes)
    idx_mod = jnp.mod(idx_d, grid)  # periodic wrap

    # tensor product across dims -> (n, taps^d)
    flat_idx = idx_mod[:, 0, :]
    flat_w = w_d[:, 0, :]
    for t in range(1, d):
        flat_idx = flat_idx[:, :, None] * grid + idx_mod[:, t, None, :]
        flat_w = flat_w[:, :, None] * w_d[:, t, None, :]
        flat_idx = flat_idx.reshape(n, -1)
        flat_w = flat_w.reshape(n, -1)
    perm = None
    if sort:
        cells = jnp.mod(base + plan.m, grid)  # node cell, in [0, grid)
        perm = _morton_perm(cells, grid)
        flat_idx = flat_idx[perm]
        flat_w = flat_w[perm]
    return NfftGeometry(indices=flat_idx, weights=flat_w, perm=perm)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class WindowGeometry:
    """Separable window geometry for the fused fastsum engine.

    Stores O(n*d*taps) data instead of the O(n*taps^d) tensor-product arrays
    of :class:`NfftGeometry` — the fused spread/gather recompute the tensor
    product on the fly and address the padded grid with whole (taps,)^d
    windows (one `lax.scatter_add`/`lax.gather` window per node).

    base: (n, d) int32 — leftmost tap corner, shifted into [0, grid_size)
      (the padded-grid coordinate system; see ``pad_width``).
    weights: (n, d, taps) — per-dimension window values.
    perm: (n,) int32 — rows are Morton-sorted; row ``r`` is node ``perm[r]``.
    """

    base: Array
    weights: Array
    perm: Array

    def tree_flatten(self):
        return (self.base, self.weights, self.perm), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def n_nodes(self) -> int:
        return self.base.shape[0]


def window_shift(plan: NfftPlan) -> int:
    """Offset from unwrapped tap coordinates to padded-grid coordinates."""
    return plan.grid_size // 2 + plan.m


def padded_grid_size(plan: NfftPlan) -> int:
    """Per-dim size of the wrap-padded grid the fused engine scatters into."""
    return plan.grid_size + plan.taps - 1


@functools.partial(jax.jit, static_argnames=("plan", "sort"))
def build_window_geometry(plan: NfftPlan, nodes: Array, *,
                          sort: bool = True) -> WindowGeometry:
    """Separable (fused-engine) window geometry for nodes in [-1/2, 1/2)^d."""
    n, d = nodes.shape
    assert d == plan.d, (d, plan.d)
    base, _, w_d = _window_taps_1d(plan, nodes)
    base = base + window_shift(plan)  # into [0, grid_size)
    if sort:
        perm = _morton_perm(base, plan.grid_size)
    else:
        perm = jnp.arange(n, dtype=jnp.int32)
    return WindowGeometry(base=base[perm], weights=w_d[perm], perm=perm)


def _window_fourier_1d_np(plan: NfftPlan, k: np.ndarray) -> np.ndarray:
    """Numpy twin of :meth:`NfftPlan.window_fourier_1d`.

    The cached grids below must be plain numpy: a jnp computation would be
    staged into whichever jit trace first touches the cache, and the cached
    tracer would leak into every later trace.
    """
    import scipy.special

    m, grid = plan.m, plan.grid_size
    b = plan.window_b()
    if plan.window == KAISER_BESSEL:
        arg = b * b - (2.0 * np.pi * k / grid) ** 2
        s = np.sqrt(np.maximum(arg, 0.0))
        val = scipy.special.i0e(m * s) * np.exp(m * s - b * m)
        return np.where(arg >= 0, val, np.exp(-b * m)) / grid
    if plan.window == GAUSSIAN_WINDOW:
        return np.exp(-b * (np.pi * k / grid) ** 2) / grid
    raise ValueError(plan.window)


@functools.lru_cache(maxsize=None)
def _deconvolution_grid_cached(plan: NfftPlan) -> np.ndarray:
    freqs = np.fft.fftfreq(plan.n_bandwidth, d=1.0 / plan.n_bandwidth)
    one_d = _window_fourier_1d_np(plan, freqs)
    out = one_d
    for _ in range(plan.d - 1):
        out = out[..., None] * one_d
    return out


@functools.lru_cache(maxsize=None)
def _embed_map(plan: NfftPlan) -> np.ndarray:
    """Per-dim index map from FFT-order I_N positions to I_M positions."""
    n, grid = plan.n_bandwidth, plan.grid_size
    k = np.fft.fftfreq(n, d=1.0 / n).astype(np.int32)  # signed freqs
    return np.mod(k, grid)


@functools.partial(jax.jit, static_argnames=("plan",))
def nfft_forward(plan: NfftPlan, geometry: NfftGeometry, f_hat: Array) -> Array:
    """Forward NFFT.  f_hat: (N,)*d [+ trailing batch dim C] -> (n,) [ ,C]."""
    d, n_bw, grid = plan.d, plan.n_bandwidth, plan.grid_size
    batched = f_hat.ndim == d + 1
    if not batched:
        f_hat = f_hat[..., None]
    c = f_hat.shape[-1]

    phi_hat = plan.deconvolution_grid()
    g_hat = f_hat / phi_hat[..., None]

    emb = _embed_map(plan)
    # place the (N,)*d block into the (M,)*d grid via advanced indexing
    mesh = jnp.meshgrid(*([emb] * d), indexing="ij")
    big = jnp.zeros((grid,) * d + (c,), dtype=g_hat.dtype)
    big = big.at[tuple(mesh)].set(g_hat)

    g = jnp.fft.ifftn(big, axes=tuple(range(d)))  # (M,)*d + (C,)
    g_flat = g.reshape(-1, c)

    vals = g_flat[geometry.indices.reshape(-1)].reshape(*geometry.indices.shape, c)
    out = jnp.sum(vals * geometry.weights[..., None].astype(vals.dtype), axis=1)
    if geometry.perm is not None:  # rows are Morton-sorted: restore node order
        out = jnp.zeros_like(out).at[geometry.perm].set(out)
    return out if batched else out[..., 0]


@functools.partial(jax.jit, static_argnames=("plan",))
def nfft_adjoint(plan: NfftPlan, geometry: NfftGeometry, x: Array) -> Array:
    """Adjoint NFFT.  x: (n,) [+ trailing batch dim C] -> (N,)*d [ ,C]."""
    d, n_bw, grid = plan.d, plan.n_bandwidth, plan.grid_size
    batched = x.ndim == 2
    if not batched:
        x = x[..., None]
    c = x.shape[-1]

    if geometry.perm is not None:  # rows are Morton-sorted: align x with rows
        x = x[geometry.perm]
    vals = geometry.weights[..., None].astype(jnp.result_type(x, geometry.weights)) * x[:, None, :]
    g_flat = jnp.zeros((grid ** d, c), dtype=vals.dtype)
    g_flat = g_flat.at[geometry.indices.reshape(-1)].add(vals.reshape(-1, c))

    g_hat = jnp.fft.fftn(g_flat.reshape((grid,) * d + (c,)), axes=tuple(range(d)))

    emb = _embed_map(plan)
    mesh = jnp.meshgrid(*([emb] * d), indexing="ij")
    small = g_hat[tuple(mesh)]

    phi_hat = plan.deconvolution_grid()
    out = small / ((grid ** d) * phi_hat)[..., None]
    return out if batched else out[..., 0]


# ---------------------------------------------------------------------------
# Reference (oracle) implementations — O(n N^d), used only in tests.
# ---------------------------------------------------------------------------

def ndft_forward(n_bandwidth: int, nodes: Array, f_hat: Array) -> Array:
    d = nodes.shape[1]
    freqs = jnp.fft.fftfreq(n_bandwidth, d=1.0 / n_bandwidth)
    grids = jnp.meshgrid(*([freqs] * d), indexing="ij")
    l = jnp.stack([g.reshape(-1) for g in grids], axis=-1)  # (N^d, d)
    phase = jnp.exp(2j * jnp.pi * (nodes @ l.T))  # (n, N^d)
    flat = f_hat.reshape(n_bandwidth ** d, *f_hat.shape[d:])
    return phase @ flat.astype(phase.dtype)


def ndft_adjoint(n_bandwidth: int, nodes: Array, x: Array) -> Array:
    d = nodes.shape[1]
    freqs = jnp.fft.fftfreq(n_bandwidth, d=1.0 / n_bandwidth)
    grids = jnp.meshgrid(*([freqs] * d), indexing="ij")
    l = jnp.stack([g.reshape(-1) for g in grids], axis=-1)
    phase = jnp.exp(-2j * jnp.pi * (l @ nodes.T))  # (N^d, n)
    out = phase @ x.astype(phase.dtype)
    return out.reshape((n_bandwidth,) * d + x.shape[1:])
