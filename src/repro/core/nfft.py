"""d-dimensional NFFT (nonequispaced fast Fourier transform) in pure JAX.

Conventions (matching the paper, Section 3):

    forward :  f_j    = sum_{l in I_N^d} f_hat[l] * e^{+2 pi i l . v_j}
    adjoint :  x_hat[l] = sum_j x_j * e^{-2 pi i l . v_j}

with ``I_N = {-N/2, ..., N/2-1}`` and nodes ``v_j in [-1/2, 1/2)^d``.
Coefficient arrays have shape ``(N,)*d`` in FFT order (no fftshift anywhere).

Algorithm (Keiner–Kunis–Potts): oversampled grid of size ``M = sigma_os * N``
per dimension, compactly supported window ``phi`` with cut-off ``m``
(support ``|x| <= m/M``), Kaiser–Bessel by default.

    forward:  deconvolve (divide by phi_hat) -> embed I_N into I_M ->
              unnormalized inverse FFT scaled by 1/M^d (= jnp.fft.ifftn) ->
              gather with window taps at each node.
    adjoint:  exact matrix adjoint of the forward: spread (scatter-add) ->
              fftn -> extract I_N -> deconvolve (divide by M^d * phi_hat).

Because the two transforms are *exact* matrix adjoints of one another, the
fast-summation operator  F . diag(b_hat) . F^H  is exactly Hermitian for real
``b_hat`` — the Lanczos method below operates on a genuinely symmetric
operator, not an approximately-symmetric one.

TPU adaptation (DESIGN.md §3): node sets are static across Krylov iterations,
so the window geometry — flattened grid indices and tensor-product weights,
``(2m+1)^d`` taps per node — is precomputed once (:class:`NfftGeometry`) and
reused by every matvec.  The gather path has a Pallas kernel
(`repro.kernels.nfft_window`); the scatter path uses XLA ``.at[].add`` which
lowers to an efficient sorted segment-sum on TPU.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

KAISER_BESSEL = "kaiser_bessel"
GAUSSIAN_WINDOW = "gaussian"


@dataclasses.dataclass(frozen=True)
class NfftPlan:
    """Static NFFT parameters (hashable; used as a jit static argument)."""

    d: int
    n_bandwidth: int  # N, even
    m: int  # window cut-off
    sigma_os: float = 2.0  # oversampling factor
    window: str = KAISER_BESSEL

    def __post_init__(self):
        assert self.n_bandwidth % 2 == 0, "bandwidth N must be even"
        assert self.d >= 1 and self.m >= 1

    @property
    def grid_size(self) -> int:
        """Oversampled grid size M per dimension (even, >= sigma_os*N)."""
        m_grid = int(np.ceil(self.sigma_os * self.n_bandwidth / 2) * 2)
        return max(m_grid, self.n_bandwidth + 2 * self.m + 2)

    @property
    def taps(self) -> int:
        return 2 * self.m + 1

    # -- window ------------------------------------------------------------
    def window_b(self) -> float:
        sigma = self.grid_size / self.n_bandwidth
        if self.window == KAISER_BESSEL:
            return float(np.pi * (2.0 - 1.0 / sigma))
        if self.window == GAUSSIAN_WINDOW:
            return float((2.0 * sigma / (2.0 * sigma - 1.0)) * self.m / np.pi)
        raise ValueError(self.window)

    def window_spatial(self, x: Array) -> Array:
        """phi(x), normalized by e^{-b m} (KB) to stay finite in f32.

        The normalization cancels inside each transform because ``phi`` is
        always paired with a division by ``phi_hat`` carrying the same factor.
        """
        m, grid = self.m, self.grid_size
        b = self.window_b()
        if self.window == KAISER_BESSEL:
            t = m * m - (grid * x) ** 2
            s = jnp.sqrt(jnp.maximum(t, 0.0))
            # sinh(b s)/(pi s) * e^{-b m}, computed overflow-free:
            #   = e^{b(s-m)} (1 - e^{-2 b s}) / (2 pi s)
            num = jnp.exp(b * (s - m)) * (1.0 - jnp.exp(-2.0 * b * s))
            safe_s = jnp.where(s > 1e-12, s, 1.0)
            val = jnp.where(s > 1e-12, num / (2.0 * jnp.pi * safe_s), b * jnp.exp(-b * m) / jnp.pi)
            return jnp.where(t >= 0, val, 0.0)
        if self.window == GAUSSIAN_WINDOW:
            val = jnp.exp(-((grid * x) ** 2) / b) / jnp.sqrt(jnp.pi * b)
            return jnp.where(jnp.abs(grid * x) <= m, val, 0.0)
        raise ValueError(self.window)

    def window_fourier_1d(self, k: Array) -> Array:
        """phi_hat(k) per dimension, same e^{-b m} normalization as spatial."""
        m, grid = self.m, self.grid_size
        b = self.window_b()
        if self.window == KAISER_BESSEL:
            arg = b * b - (2.0 * jnp.pi * k / grid) ** 2
            s = jnp.sqrt(jnp.maximum(arg, 0.0))
            # I_0(m s) e^{-b m} = i0e(m s) e^{m s - b m};  m s <= b m.
            val = jax.scipy.special.i0e(m * s) * jnp.exp(m * s - b * m)
            # |k| beyond the valid band never occurs for |k| <= N/2 < M/2 when
            # sigma_os >= 1.5; clamp defensively.
            return jnp.where(arg >= 0, val, jnp.exp(-b * m)) / grid
        if self.window == GAUSSIAN_WINDOW:
            return jnp.exp(-b * (jnp.pi * k / grid) ** 2) / grid
        raise ValueError(self.window)

    def deconvolution_grid(self) -> Array:
        """prod_t phi_hat(l_t) on the (N,)*d coefficient grid, FFT order."""
        freqs = jnp.fft.fftfreq(self.n_bandwidth, d=1.0 / self.n_bandwidth)
        one_d = self.window_fourier_1d(freqs)
        out = one_d
        for _ in range(self.d - 1):
            out = out[..., None] * one_d
        return out


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class NfftGeometry:
    """Precomputed window geometry for a fixed node set.

    indices: (n, taps^d) int32 — flattened oversampled-grid indices.
    weights: (n, taps^d) float — tensor-product window values.
    """

    indices: Array
    weights: Array

    def tree_flatten(self):
        return (self.indices, self.weights), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def n_nodes(self) -> int:
        return self.indices.shape[0]


@functools.partial(jax.jit, static_argnames=("plan",))
def build_geometry(plan: NfftPlan, nodes: Array) -> NfftGeometry:
    """Window geometry for nodes (n, d) in [-1/2, 1/2)^d."""
    n, d = nodes.shape
    assert d == plan.d, (d, plan.d)
    grid = plan.grid_size
    m = plan.m
    taps = plan.taps

    y = nodes * grid  # grid-scaled positions, per dim
    base = jnp.floor(y).astype(jnp.int32) - m  # (n, d)
    offs = jnp.arange(taps, dtype=jnp.int32)  # (taps,)
    # per-dim tap indices and window values
    idx_d = base[:, :, None] + offs[None, None, :]  # (n, d, taps)
    dist = nodes[:, :, None] - idx_d.astype(nodes.dtype) / grid
    w_d = plan.window_spatial(dist)  # (n, d, taps)
    idx_mod = jnp.mod(idx_d, grid)  # periodic wrap

    # tensor product across dims -> (n, taps^d)
    flat_idx = idx_mod[:, 0, :]
    flat_w = w_d[:, 0, :]
    for t in range(1, d):
        flat_idx = flat_idx[:, :, None] * grid + idx_mod[:, t, None, :]
        flat_w = flat_w[:, :, None] * w_d[:, t, None, :]
        flat_idx = flat_idx.reshape(n, -1)
        flat_w = flat_w.reshape(n, -1)
    return NfftGeometry(indices=flat_idx, weights=flat_w)


def _embed_map(plan: NfftPlan) -> Array:
    """Per-dim index map from FFT-order I_N positions to I_M positions."""
    n, grid = plan.n_bandwidth, plan.grid_size
    k = np.fft.fftfreq(n, d=1.0 / n).astype(np.int32)  # signed freqs
    return jnp.asarray(np.mod(k, grid))


@functools.partial(jax.jit, static_argnames=("plan",))
def nfft_forward(plan: NfftPlan, geometry: NfftGeometry, f_hat: Array) -> Array:
    """Forward NFFT.  f_hat: (N,)*d [+ trailing batch dim C] -> (n,) [ ,C]."""
    d, n_bw, grid = plan.d, plan.n_bandwidth, plan.grid_size
    batched = f_hat.ndim == d + 1
    if not batched:
        f_hat = f_hat[..., None]
    c = f_hat.shape[-1]

    phi_hat = plan.deconvolution_grid()
    g_hat = f_hat / phi_hat[..., None]

    emb = _embed_map(plan)
    # place the (N,)*d block into the (M,)*d grid via advanced indexing
    mesh = jnp.meshgrid(*([emb] * d), indexing="ij")
    big = jnp.zeros((grid,) * d + (c,), dtype=g_hat.dtype)
    big = big.at[tuple(mesh)].set(g_hat)

    g = jnp.fft.ifftn(big, axes=tuple(range(d)))  # (M,)*d + (C,)
    g_flat = g.reshape(-1, c)

    vals = g_flat[geometry.indices.reshape(-1)].reshape(*geometry.indices.shape, c)
    out = jnp.sum(vals * geometry.weights[..., None].astype(vals.dtype), axis=1)
    return out if batched else out[..., 0]


@functools.partial(jax.jit, static_argnames=("plan",))
def nfft_adjoint(plan: NfftPlan, geometry: NfftGeometry, x: Array) -> Array:
    """Adjoint NFFT.  x: (n,) [+ trailing batch dim C] -> (N,)*d [ ,C]."""
    d, n_bw, grid = plan.d, plan.n_bandwidth, plan.grid_size
    batched = x.ndim == 2
    if not batched:
        x = x[..., None]
    c = x.shape[-1]

    vals = geometry.weights[..., None].astype(jnp.result_type(x, geometry.weights)) * x[:, None, :]
    g_flat = jnp.zeros((grid ** d, c), dtype=vals.dtype)
    g_flat = g_flat.at[geometry.indices.reshape(-1)].add(vals.reshape(-1, c))

    g_hat = jnp.fft.fftn(g_flat.reshape((grid,) * d + (c,)), axes=tuple(range(d)))

    emb = _embed_map(plan)
    mesh = jnp.meshgrid(*([emb] * d), indexing="ij")
    small = g_hat[tuple(mesh)]

    phi_hat = plan.deconvolution_grid()
    out = small / ((grid ** d) * phi_hat)[..., None]
    return out if batched else out[..., 0]


# ---------------------------------------------------------------------------
# Reference (oracle) implementations — O(n N^d), used only in tests.
# ---------------------------------------------------------------------------

def ndft_forward(n_bandwidth: int, nodes: Array, f_hat: Array) -> Array:
    d = nodes.shape[1]
    freqs = jnp.fft.fftfreq(n_bandwidth, d=1.0 / n_bandwidth)
    grids = jnp.meshgrid(*([freqs] * d), indexing="ij")
    l = jnp.stack([g.reshape(-1) for g in grids], axis=-1)  # (N^d, d)
    phase = jnp.exp(2j * jnp.pi * (nodes @ l.T))  # (n, N^d)
    flat = f_hat.reshape(n_bandwidth ** d, *f_hat.shape[d:])
    return phase @ flat.astype(phase.dtype)


def ndft_adjoint(n_bandwidth: int, nodes: Array, x: Array) -> Array:
    d = nodes.shape[1]
    freqs = jnp.fft.fftfreq(n_bandwidth, d=1.0 / n_bandwidth)
    grids = jnp.meshgrid(*([freqs] * d), indexing="ij")
    l = jnp.stack([g.reshape(-1) for g in grids], axis=-1)
    phase = jnp.exp(-2j * jnp.pi * (l @ nodes.T))  # (N^d, n)
    out = phase @ x.astype(phase.dtype)
    return out.reshape((n_bandwidth,) * d + x.shape[1:])
