"""Core paper contribution: NFFT fast summation + Krylov methods.

Public API re-exports.
"""

from repro.core.kernels import (  # noqa: F401
    Kernel, kernel_from_param, make_kernel, GAUSSIAN, LAPLACIAN_RBF,
    MULTIQUADRIC, INVERSE_MULTIQUADRIC, ALL_KERNELS, KERNEL_PARAM_NAME,
)
from repro.core.fastsum import (  # noqa: F401
    FastsumParams, FastsumOperator, FastsumOperatorBank,
    NormalizedAdjacencyOperator, PredictionPlan,
    make_fastsum, make_fastsum_bank, make_normalized_adjacency,
    make_normalized_adjacency_mixture, make_prediction_plan,
    prediction_multiplier,
    SETUP_1, SETUP_2, SETUP_3,
    dense_weight_matrix, dense_normalized_adjacency, direct_matvec_tiled,
)
from repro.core.nfft import (  # noqa: F401
    NfftPlan, NfftGeometry, WindowGeometry, build_geometry,
    build_window_geometry, nfft_forward, nfft_adjoint,
)
# The fused window kernels stay namespaced (repro.core.fastsum_exec.
# window_spread/window_gather): re-exporting them here would shadow the
# same-named, different-signature Pallas kernels in repro.kernels.ops.
from repro.core.fastsum_exec import (  # noqa: F401
    fused_gather_columns, fused_matvec_tilde, fused_matvec_tilde_bank,
    fused_pipeline, fused_pipeline_bank, fused_spectral_multiplier,
    fused_transform_columns, spectral_support, stack_multipliers,
)
from repro.core.lanczos import (  # noqa: F401
    lanczos, block_lanczos, eigsh, eigsh_smallest_laplacian,
    BlockLanczosResult, EigshResult,
)
from repro.core.solvers import (  # noqa: F401
    cg, cg_bank, minres, minres_bank, SolveResult,
)
from repro.core.nystrom import (  # noqa: F401
    nystrom_traditional, nystrom_gaussian_nfft, NystromResult,
)
from repro.core.error import lemma31_bound, aposteriori_report  # noqa: F401
