"""Jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True on non-TPU backends so the same call sites work
on CPU (kernel body executed in Python) and TPU (Mosaic lowering).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import kernel_matvec as _km
from repro.kernels import nfft_window as _nw

Array = jax.Array


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def kernel_matvec(points_out: Array, points_in: Array, x: Array, *,
                  kernel_name: str = "gaussian", param: float = 1.0,
                  zero_diagonal: bool = True, tile_j: int | None = None,
                  tile_i: int | None = None,
                  interpret: bool | None = None) -> Array:
    kw = {}
    if tile_j is not None:
        kw["tile_j"] = tile_j
    if tile_i is not None:
        kw["tile_i"] = tile_i
    return _km.kernel_matvec(
        points_out, points_in, x, kernel_name=kernel_name, param=param,
        zero_diagonal=zero_diagonal,
        interpret=_default_interpret() if interpret is None else interpret,
        **kw)


def window_gather(grid: Array, base: Array, weights: Array, *,
                  interpret: bool | None = None, **kw) -> Array:
    """Separable-geometry window gather; see repro.kernels.nfft_window."""
    return _nw.window_gather(
        grid, base, weights,
        interpret=_default_interpret() if interpret is None else interpret,
        **kw)


def window_spread(x: Array, base: Array, weights: Array, *, padded_size: int,
                  interpret: bool | None = None, **kw) -> Array:
    """Separable-geometry window spread; see repro.kernels.nfft_window."""
    return _nw.window_spread(
        x, base, weights, padded_size=padded_size,
        interpret=_default_interpret() if interpret is None else interpret,
        **kw)


def flash_attention(q: Array, k: Array, v: Array, *, causal: bool = False,
                    scale: float | None = None,
                    interpret: bool | None = None, **kw) -> Array:
    return _fa.flash_attention(
        q, k, v, causal=causal, scale=scale,
        interpret=_default_interpret() if interpret is None else interpret,
        **kw)
