"""NFFT window gathering/spreading — Pallas TPU kernels.

The O(m^d n) window step of the NFFT (DESIGN.md §3).  Node geometry (grid
indices + tensor-product weights) is precomputed once per node set, so both
kernels operate on a *static* sparsity pattern:

* gather:  f[j] = sum_t w[j,t] * grid[idx[j,t]]  — node tiles stream through
  VMEM while the oversampled grid stays resident (valid for d <= 2 at the
  paper's bandwidths: M^d complex <= ~4 MiB).  The inner gather uses vector
  ``jnp.take``; on TPU this lowers to Mosaic's dynamic-gather.

* spread:  the transpose — scatter-add of weighted node values into the
  grid.  Implemented as read-modify-write accumulation over sequential node
  tiles (the output block index map is constant, so the grid tile is
  revisited).  On TPU, unsorted scatter vectorizes poorly; the production
  path for d = 3 is the XLA sorted segment-sum in repro.core.nfft — this
  kernel is the VMEM-resident alternative for d <= 2.

Complex values are carried as separate real/imag float arrays (Mosaic has no
complex dtype).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

DEFAULT_NODE_TILE = 1024


def _gather_kernel(grid_ref, idx_ref, w_ref, o_ref):
    grid = grid_ref[...]  # (G, C) resident
    idx = idx_ref[...]  # (TN, taps)
    w = w_ref[...]  # (TN, taps)
    vals = jnp.take(grid, idx, axis=0)  # (TN, taps, C)
    o_ref[...] = jnp.sum(vals * w[..., None], axis=1)


@functools.partial(jax.jit, static_argnames=("node_tile", "interpret"))
def window_gather(grid: Array, indices: Array, weights: Array, *,
                  node_tile: int = DEFAULT_NODE_TILE,
                  interpret: bool = False) -> Array:
    """f[j] = sum_t weights[j, t] * grid[indices[j, t]].

    grid: (G,) or (G, C) real — batched channels share one index/weight
    stream (the fused engine's multi-RHS layout), so the geometry traffic is
    amortized over C.  Returns (n,) or (n, C) to match.
    """
    n, taps = indices.shape
    batched = grid.ndim == 2
    g2 = grid if batched else grid[:, None]
    c = g2.shape[1]
    tn = min(node_tile, max(8, n))
    pad = (-n) % tn
    idx = jnp.pad(indices, ((0, pad), (0, 0)))  # padded rows gather grid[0]*w
    w = jnp.pad(weights, ((0, pad), (0, 0)))  # w=0 -> contribution 0

    out = pl.pallas_call(
        _gather_kernel,
        grid=(idx.shape[0] // tn,),
        in_specs=[
            pl.BlockSpec(g2.shape, lambda j: (0, 0)),
            pl.BlockSpec((tn, taps), lambda j: (j, 0)),
            pl.BlockSpec((tn, taps), lambda j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tn, c), lambda j: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((idx.shape[0], c), g2.dtype),
        interpret=interpret,
    )(g2, idx, w)
    out = out[:n]
    return out if batched else out[:, 0]


def _spread_kernel(x_ref, idx_ref, w_ref, o_ref, *, grid_size: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]  # (TN, C)
    idx = idx_ref[...]  # (TN, taps)
    w = w_ref[...]  # (TN, taps)
    c = x.shape[-1]
    vals = (w[..., None] * x[:, None, :]).reshape(-1, c)
    g = o_ref[...]
    o_ref[...] = g.at[idx.reshape(-1)].add(vals)


@functools.partial(jax.jit, static_argnames=("grid_size", "node_tile",
                                             "interpret"))
def window_spread(x: Array, indices: Array, weights: Array, *, grid_size: int,
                  node_tile: int = DEFAULT_NODE_TILE,
                  interpret: bool = False) -> Array:
    """g = scatter-add of weighted node values (transpose of window_gather).

    x: (n,) or (n, C); returns (grid_size,) or (grid_size, C).
    """
    n, taps = indices.shape
    batched = x.ndim == 2
    x2 = x if batched else x[:, None]
    c = x2.shape[1]
    tn = min(node_tile, max(8, n))
    pad = (-n) % tn
    xp = jnp.pad(x2, ((0, pad), (0, 0)))
    idx = jnp.pad(indices, ((0, pad), (0, 0)))
    w = jnp.pad(weights, ((0, pad), (0, 0)))  # zero weights: no contribution

    out = pl.pallas_call(
        functools.partial(_spread_kernel, grid_size=grid_size),
        grid=(idx.shape[0] // tn,),
        in_specs=[
            pl.BlockSpec((tn, c), lambda j: (j, 0)),
            pl.BlockSpec((tn, taps), lambda j: (j, 0)),
            pl.BlockSpec((tn, taps), lambda j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((grid_size, c), lambda j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((grid_size, c), x2.dtype),
        interpret=interpret,
    )(xp, idx, w)
    return out if batched else out[:, 0]
