"""NFFT window spreading/gathering — streaming tiled Pallas backend.

The O(taps^d n) window step of the NFFT, operating directly on the fused
engine's *separable* window geometry (:class:`repro.core.nfft.
WindowGeometry`): per-node patch corner ``base`` (n, d) in padded-grid
coordinates and per-dimension weights (n, d, taps).  The tensor product
across dimensions is computed in registers inside the kernel — the
``(n, taps^d, C)`` update cube of the whole-window XLA path is never
materialized.

* spread:  Morton-sorted node tiles stream through VMEM while the
  wrap-padded oversampled grid stays resident as the kernel's revisited
  output block.  Each node scatter-adds its ``(taps,)^d`` window into only
  the grid patch it touches, via dynamic-slice read-modify-write; Morton
  order makes consecutive patches overlap, so the RMW traffic stays in
  cache/VMEM-local lines.

* gather:  the exact transpose — each node dynamic-slices its ``(taps,)^d``
  patch out of the resident grid and contracts it with the in-register
  weight cube.

Batched channels (the fused engine's multi-RHS layout) ride on the
innermost dimension of both the grid and the node values, so one geometry
stream is amortized over C right-hand sides.  ``d`` is 1..3 (the paper's
range); the grid is the *padded* grid (``repro.core.nfft.padded_grid_size``)
so no wrapping logic lives in the kernel — the fold-back of the periodic pad
is the caller's (cheap, backend-independent) job.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

DEFAULT_NODE_TILE = 1024


def _weight_cube(w: Array, d: int) -> Array:
    """Tensor product of one node's per-dim weights: (d, taps) -> (taps,)*d."""
    cube = w[0]
    for t in range(1, d):
        cube = cube[..., None] * w[t]
    return cube


def _spread_kernel(base_ref, w_ref, x_ref, o_ref, *, d: int, taps: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    def body(r, carry):
        b = base_ref[pl.ds(r, 1), :][0]  # (d,) patch corner
        w = w_ref[pl.ds(r, 1)][0]  # (d, taps)
        xr = x_ref[pl.ds(r, 1), :][0]  # (C,) channels in-register
        cube = _weight_cube(w, d)  # (taps,)*d
        patch = tuple(pl.ds(b[t], taps) for t in range(d)) + (slice(None),)
        o_ref[patch] = o_ref[patch] + cube[..., None] * xr
        return carry

    jax.lax.fori_loop(0, x_ref.shape[0], body, 0)


@functools.partial(jax.jit, static_argnames=("padded_size", "node_tile",
                                             "interpret"))
def window_spread(x: Array, base: Array, weights: Array, *, padded_size: int,
                  node_tile: int = DEFAULT_NODE_TILE,
                  interpret: bool = False) -> Array:
    """Scatter-add separable node windows onto the padded grid.

    x: (n,) or (n, C); base: (n, d) int32 patch corners with
    ``0 <= base`` and ``base + taps <= padded_size``; weights: (n, d, taps).
    Returns the padded grid, shape ``(padded_size,)*d`` [+ ``(C,)``].
    """
    n, d, taps = weights.shape
    batched = x.ndim == 2
    x2 = x if batched else x[:, None]
    c = x2.shape[1]
    tn = min(node_tile, max(8, n))
    pad = (-n) % tn
    # padded rows carry zero weights: their windows add exact zeros
    xp = jnp.pad(x2, ((0, pad), (0, 0)))
    bp = jnp.pad(base, ((0, pad), (0, 0)))
    wp = jnp.pad(weights, ((0, pad), (0, 0), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_spread_kernel, d=d, taps=taps),
        grid=(xp.shape[0] // tn,),
        in_specs=[
            pl.BlockSpec((tn, d), lambda j: (j, 0)),
            pl.BlockSpec((tn, d, taps), lambda j: (j, 0, 0)),
            pl.BlockSpec((tn, c), lambda j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((padded_size,) * d + (c,),
                               lambda j: (0,) * (d + 1)),
        out_shape=jax.ShapeDtypeStruct((padded_size,) * d + (c,), x2.dtype),
        interpret=interpret,
    )(bp, wp, xp)
    return out if batched else out[..., 0]


def _gather_kernel(g_ref, base_ref, w_ref, o_ref, *, d: int, taps: int):
    def body(r, carry):
        b = base_ref[pl.ds(r, 1), :][0]
        w = w_ref[pl.ds(r, 1)][0]
        cube = _weight_cube(w, d)
        patch = tuple(pl.ds(b[t], taps) for t in range(d)) + (slice(None),)
        vals = g_ref[patch]  # (taps,)*d + (C,)
        o_ref[pl.ds(r, 1), :] = jnp.sum(
            vals * cube[..., None], axis=tuple(range(d)))[None]
        return carry

    jax.lax.fori_loop(0, o_ref.shape[0], body, 0)


@functools.partial(jax.jit, static_argnames=("node_tile", "interpret"))
def window_gather(grid: Array, base: Array, weights: Array, *,
                  node_tile: int = DEFAULT_NODE_TILE,
                  interpret: bool = False) -> Array:
    """Gather separable node windows from the padded grid (spread transpose).

    grid: (padded_size,)*d [+ (C,)]; base/weights as in
    :func:`window_spread`.  Returns (n,) or (n, C) to match ``grid``.
    """
    n, d, taps = weights.shape
    batched = grid.ndim == d + 1
    g2 = grid if batched else grid[..., None]
    c = g2.shape[-1]
    padded_size = g2.shape[0]
    tn = min(node_tile, max(8, n))
    pad = (-n) % tn
    bp = jnp.pad(base, ((0, pad), (0, 0)))  # padded rows read patch 0 * w=0
    wp = jnp.pad(weights, ((0, pad), (0, 0), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_gather_kernel, d=d, taps=taps),
        grid=(bp.shape[0] // tn,),
        in_specs=[
            pl.BlockSpec((padded_size,) * d + (c,), lambda j: (0,) * (d + 1)),
            pl.BlockSpec((tn, d), lambda j: (j, 0)),
            pl.BlockSpec((tn, d, taps), lambda j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tn, c), lambda j: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((bp.shape[0], c), g2.dtype),
        interpret=interpret,
    )(g2, bp, wp)
    out = out[:n]
    return out if batched else out[:, 0]
