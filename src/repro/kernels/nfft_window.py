"""NFFT window gathering/spreading — Pallas TPU kernels.

The O(m^d n) window step of the NFFT (DESIGN.md §3).  Node geometry (grid
indices + tensor-product weights) is precomputed once per node set, so both
kernels operate on a *static* sparsity pattern:

* gather:  f[j] = sum_t w[j,t] * grid[idx[j,t]]  — node tiles stream through
  VMEM while the oversampled grid stays resident (valid for d <= 2 at the
  paper's bandwidths: M^d complex <= ~4 MiB).  The inner gather uses vector
  ``jnp.take``; on TPU this lowers to Mosaic's dynamic-gather.

* spread:  the transpose — scatter-add of weighted node values into the
  grid.  Implemented as read-modify-write accumulation over sequential node
  tiles (the output block index map is constant, so the grid tile is
  revisited).  On TPU, unsorted scatter vectorizes poorly; the production
  path for d = 3 is the XLA sorted segment-sum in repro.core.nfft — this
  kernel is the VMEM-resident alternative for d <= 2.

Complex values are carried as separate real/imag float arrays (Mosaic has no
complex dtype).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

DEFAULT_NODE_TILE = 1024


def _gather_kernel(grid_ref, idx_ref, w_ref, o_ref):
    grid = grid_ref[...]  # (G,) resident
    idx = idx_ref[...]  # (TN, taps)
    w = w_ref[...]  # (TN, taps)
    vals = jnp.take(grid, idx, axis=0)  # (TN, taps)
    o_ref[...] = jnp.sum(vals * w, axis=1)


@functools.partial(jax.jit, static_argnames=("node_tile", "interpret"))
def window_gather(grid: Array, indices: Array, weights: Array, *,
                  node_tile: int = DEFAULT_NODE_TILE,
                  interpret: bool = False) -> Array:
    """f[j] = sum_t weights[j, t] * grid[indices[j, t]].  grid: (G,) real."""
    n, taps = indices.shape
    tn = min(node_tile, max(8, n))
    pad = (-n) % tn
    idx = jnp.pad(indices, ((0, pad), (0, 0)))  # padded rows gather grid[0]*w
    w = jnp.pad(weights, ((0, pad), (0, 0)))  # w=0 -> contribution 0

    out = pl.pallas_call(
        _gather_kernel,
        grid=(idx.shape[0] // tn,),
        in_specs=[
            pl.BlockSpec(grid.shape, lambda j: (0,) * grid.ndim),
            pl.BlockSpec((tn, taps), lambda j: (j, 0)),
            pl.BlockSpec((tn, taps), lambda j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tn,), lambda j: (j,)),
        out_shape=jax.ShapeDtypeStruct((idx.shape[0],), grid.dtype),
        interpret=interpret,
    )(grid, idx, w)
    return out[:n]


def _spread_kernel(x_ref, idx_ref, w_ref, o_ref, *, grid_size: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]  # (TN,)
    idx = idx_ref[...]  # (TN, taps)
    w = w_ref[...]  # (TN, taps)
    vals = (w * x[:, None]).reshape(-1)
    g = o_ref[...]
    o_ref[...] = g.at[idx.reshape(-1)].add(vals)


@functools.partial(jax.jit, static_argnames=("grid_size", "node_tile",
                                             "interpret"))
def window_spread(x: Array, indices: Array, weights: Array, *, grid_size: int,
                  node_tile: int = DEFAULT_NODE_TILE,
                  interpret: bool = False) -> Array:
    """g = scatter-add of weighted node values (transpose of window_gather)."""
    n, taps = indices.shape
    tn = min(node_tile, max(8, n))
    pad = (-n) % tn
    xp = jnp.pad(x, (0, pad))
    idx = jnp.pad(indices, ((0, pad), (0, 0)))
    w = jnp.pad(weights, ((0, pad), (0, 0)))  # zero weights: no contribution

    out = pl.pallas_call(
        functools.partial(_spread_kernel, grid_size=grid_size),
        grid=(idx.shape[0] // tn,),
        in_specs=[
            pl.BlockSpec((tn,), lambda j: (j,)),
            pl.BlockSpec((tn, taps), lambda j: (j, 0)),
            pl.BlockSpec((tn, taps), lambda j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((grid_size,), lambda j: (0,)),
        out_shape=jax.ShapeDtypeStruct((grid_size,), x.dtype),
        interpret=interpret,
    )(xp, idx, w)
    return out
