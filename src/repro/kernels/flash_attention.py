"""Flash attention (forward) — Pallas TPU kernel.

Single-pass online-softmax attention over KV tiles: for each (batch*head,
q-tile) the kernel iterates KV tiles (innermost grid dim), maintaining the
running max ``m``, normalizer ``l`` and accumulator in VMEM scratch.  GQA is
handled in the BlockSpec index maps (q-head h reads kv-head h // group), so
K/V are never materialized per-q-head.

Causal masking skips fully-masked KV tiles via the grid (no wasted tiles) and
applies the triangular mask on the diagonal tile only.

Used by the LM framework's attention layer when ``use_pallas=True`` (real
TPU); the dry-run / CPU path uses the XLA einsum reference
(repro.kernels.ref.flash_attention_ref) — Mosaic kernels do not lower on the
CPU backend except in interpret mode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_K = 512
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  seq_q: int, seq_k: int, num_k_blocks: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]  # (bq, dh)
    k = k_ref[0]  # (bk, dh)
    v = v_ref[0]  # (bk, dh)

    logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    q_ids = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_ids = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = (q_ids < seq_q) & (k_ids < seq_k)
    if causal:
        # decode-style alignment: query t attends keys <= t + (seq_k - seq_q)
        mask &= q_ids + (seq_k - seq_q) >= k_ids
    logits = jnp.where(mask, logits, NEG_INF)

    m_prev = m_scr[...]  # (bq, 1)
    l_prev = l_scr[...]
    m_cur = jnp.max(logits, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new)
    l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc = acc_scr[...] * alpha + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(ki == num_k_blocks - 1)
    def _finalize():
        norm = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / norm).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "block_q", "block_k", "interpret"))
def flash_attention(q: Array, k: Array, v: Array, *, causal: bool = False,
                    scale: float | None = None,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False) -> Array:
    """Flash attention forward.  q: (b, hq, sq, dh); k, v: (b, hkv, sk, dh)."""
    b, hq, sq, dh = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    if scale is None:
        scale = 1.0 / (dh ** 0.5)

    bq = min(block_q, max(8, sq))
    bk = min(block_k, max(8, sk))
    pad_q = (-sq) % bq
    pad_k = (-sk) % bk
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))

    # flatten (batch, q-head) into one grid axis
    qf = qp.reshape(b * hq, qp.shape[2], dh)
    kf = kp.reshape(b * hkv, kp.shape[2], dh)
    vf = vp.reshape(b * hkv, vp.shape[2], dh)

    num_q_blocks = qp.shape[2] // bq
    num_k_blocks = kp.shape[2] // bk
    grid = (b * hq, num_q_blocks, num_k_blocks)

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, scale=float(scale), causal=causal, block_q=bq,
            block_k=bk, seq_q=sq, seq_k=sk, num_k_blocks=num_k_blocks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda h, qi, ki: (h, qi, 0)),
            pl.BlockSpec((1, bk, dh),
                         lambda h, qi, ki, g=group: (h // g, ki, 0)),
            pl.BlockSpec((1, bk, dh),
                         lambda h, qi, ki, g=group: (h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda h, qi, ki: (h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(qf.shape, q.dtype),
        scratch_shapes=[
            # running max / normalizer / accumulator, resident in VMEM
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)

    out = out.reshape(b, hq, qp.shape[2], dh)[:, :, :sq]
    return out
