"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth that the corresponding Pallas
kernel must match (asserted across shape/dtype sweeps in
tests/test_kernels_pallas.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def kernel_matvec_ref(points_out: Array, points_in: Array, x: Array,
                      kernel_name: str, param: float,
                      zero_diagonal: bool = True) -> Array:
    """y_j = sum_i K(||p_out_j - p_in_i||) x_i, optional zero diagonal.

    points_out: (n_out, d), points_in: (n_in, d), x: (n_in,) or (n_in, c).
    """
    diff = points_out[:, None, :] - points_in[None, :, :]
    r2 = jnp.sum(diff * diff, axis=-1)
    w = kernel_profile_r2(r2, kernel_name, param)
    if zero_diagonal:
        i = jnp.arange(points_out.shape[0])[:, None]
        j = jnp.arange(points_in.shape[0])[None, :]
        w = jnp.where(i == j, 0.0, w)
    return w @ x


def kernel_profile_r2(r2: Array, kernel_name: str, param: float) -> Array:
    """Kernel profile evaluated on *squared* radii (all four paper kernels)."""
    if kernel_name == "gaussian":
        return jnp.exp(-r2 / (param * param))
    if kernel_name == "laplacian_rbf":
        return jnp.exp(-jnp.sqrt(jnp.maximum(r2, 0.0)) / param)
    if kernel_name == "multiquadric":
        return jnp.sqrt(r2 + param * param)
    if kernel_name == "inverse_multiquadric":
        return 1.0 / jnp.sqrt(r2 + param * param)
    raise ValueError(kernel_name)


def window_gather_ref(grid: Array, indices: Array, weights: Array) -> Array:
    """f_j = sum_t weights[j,t] * grid[indices[j,t]]  (NFFT gathering).

    grid: (G,) or (G, c); indices/weights: (n, taps).
    """
    vals = grid[indices]  # (n, taps) or (n, taps, c)
    if grid.ndim == 2:
        return jnp.sum(vals * weights[..., None], axis=1)
    return jnp.sum(vals * weights, axis=1)


def window_spread_ref(x: Array, indices: Array, weights: Array,
                      grid_size: int) -> Array:
    """g = sum_j x_j * weights[j, :] scattered at indices[j, :]  (spreading).

    x: (n,) or (n, c); returns (G,) or (G, c).
    """
    if x.ndim == 2:
        vals = weights[..., None] * x[:, None, :]
        out = jnp.zeros((grid_size, x.shape[1]), dtype=vals.dtype)
        return out.at[indices.reshape(-1)].add(vals.reshape(-1, x.shape[1]))
    vals = weights * x[:, None]
    out = jnp.zeros((grid_size,), dtype=vals.dtype)
    return out.at[indices.reshape(-1)].add(vals.reshape(-1))


def flash_attention_ref(q: Array, k: Array, v: Array, *, causal: bool = False,
                        scale: float | None = None,
                        bias: Array | None = None) -> Array:
    """Reference softmax attention with GQA head-group broadcasting.

    q: (b, hq, sq, dh), k/v: (b, hkv, skv, dh) with hq % hkv == 0.
    """
    b, hq, sq, dh = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    if scale is None:
        scale = 1.0 / (dh ** 0.5)
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, kk) * scale
    if bias is not None:
        logits = logits + bias
    if causal:
        skv = k.shape[2]
        mask = jnp.arange(sq)[:, None] >= jnp.arange(skv)[None, :] - (skv - sq)
        logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, vv)
