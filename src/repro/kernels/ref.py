"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth that the corresponding Pallas
kernel must match (asserted across shape/dtype sweeps in
tests/test_kernels_pallas.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def kernel_matvec_ref(points_out: Array, points_in: Array, x: Array,
                      kernel_name: str, param: float,
                      zero_diagonal: bool = True) -> Array:
    """y_j = sum_i K(||p_out_j - p_in_i||) x_i, optional zero diagonal.

    points_out: (n_out, d), points_in: (n_in, d), x: (n_in,) or (n_in, c).
    """
    diff = points_out[:, None, :] - points_in[None, :, :]
    r2 = jnp.sum(diff * diff, axis=-1)
    w = kernel_profile_r2(r2, kernel_name, param)
    if zero_diagonal:
        i = jnp.arange(points_out.shape[0])[:, None]
        j = jnp.arange(points_in.shape[0])[None, :]
        w = jnp.where(i == j, 0.0, w)
    return w @ x


def kernel_profile_r2(r2: Array, kernel_name: str, param: float) -> Array:
    """Kernel profile evaluated on *squared* radii (all four paper kernels)."""
    if kernel_name == "gaussian":
        return jnp.exp(-r2 / (param * param))
    if kernel_name == "laplacian_rbf":
        return jnp.exp(-jnp.sqrt(jnp.maximum(r2, 0.0)) / param)
    if kernel_name == "multiquadric":
        return jnp.sqrt(r2 + param * param)
    if kernel_name == "inverse_multiquadric":
        return 1.0 / jnp.sqrt(r2 + param * param)
    raise ValueError(kernel_name)


def _weight_cubes(weights: Array) -> Array:
    """Tensor product of per-dim weights: (n, d, taps) -> (n,) + (taps,)*d.

    Deliberately materializes the full cube — these are the oracles the
    streaming kernels (which never build it) are checked against.
    """
    n, d, taps = weights.shape
    cube = weights[:, 0]
    for t in range(1, d):
        cube = cube[..., None] * weights[:, t].reshape(
            (n,) + (1,) * t + (taps,))
    return cube


def window_gather_ref(grid: Array, base: Array, weights: Array) -> Array:
    """f_j = sum over the (taps,)^d window of grid patches at ``base[j]``
    weighted by the tensor product of per-dim weights (NFFT gathering).

    grid: (P,)*d or (P,)*d + (c,); base: (n, d); weights: (n, d, taps).
    """
    n, d, taps = weights.shape
    batched = grid.ndim == d + 1
    g2 = grid if batched else grid[..., None]
    dnums = jax.lax.GatherDimensionNumbers(
        offset_dims=tuple(range(1, d + 2)),
        collapsed_slice_dims=(),
        start_index_map=tuple(range(d)))
    vals = jax.lax.gather(g2, base, dnums,
                          slice_sizes=(taps,) * d + (g2.shape[-1],))
    out = jnp.sum(vals * _weight_cubes(weights)[..., None],
                  axis=tuple(range(1, d + 1)))
    return out if batched else out[:, 0]


def window_spread_ref(x: Array, base: Array, weights: Array,
                      padded_size: int) -> Array:
    """g = separable (taps,)^d windows of x scattered at ``base`` (spreading).

    x: (n,) or (n, c); returns (P,)*d or (P,)*d + (c,).
    """
    n, d, taps = weights.shape
    batched = x.ndim == 2
    x2 = x if batched else x[:, None]
    cube = _weight_cubes(weights)
    updates = cube[..., None] * x2[(slice(None),) + (None,) * d]
    dnums = jax.lax.ScatterDimensionNumbers(
        update_window_dims=tuple(range(1, d + 2)),
        inserted_window_dims=(),
        scatter_dims_to_operand_dims=tuple(range(d)))
    out = jnp.zeros((padded_size,) * d + (x2.shape[1],), dtype=updates.dtype)
    out = jax.lax.scatter_add(out, base, updates, dnums)
    return out if batched else out[..., 0]


def flash_attention_ref(q: Array, k: Array, v: Array, *, causal: bool = False,
                        scale: float | None = None,
                        bias: Array | None = None) -> Array:
    """Reference softmax attention with GQA head-group broadcasting.

    q: (b, hq, sq, dh), k/v: (b, hkv, skv, dh) with hq % hkv == 0.
    """
    b, hq, sq, dh = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    if scale is None:
        scale = 1.0 / (dh ** 0.5)
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, kk) * scale
    if bias is not None:
        logits = logits + bias
    if causal:
        skv = k.shape[2]
        mask = jnp.arange(sq)[:, None] >= jnp.arange(skv)[None, :] - (skv - sq)
        logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, vv)
