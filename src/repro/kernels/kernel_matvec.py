"""Tiled dense kernel matvec — Pallas TPU kernel.

Computes  y_j = sum_i K(||a_j - b_i||) x_i  in (TJ x TI) tiles without ever
materializing the n x n kernel matrix: each grid step loads a (TJ, d) tile of
target points, a (TI, d) tile of source points and a (TI, C) tile of the
input vectors into VMEM, forms the tile of squared distances with the
broadcast formulation (d <= 3, VPU work), applies the kernel profile, and
accumulates the (TJ, C) partial matvec into the output tile.

This is the paper's "direct method" baseline restructured for TPU: O(n^2)
FLOPs but streamed through VMEM at compute roofline instead of O(n^2) HBM
traffic for a stored matrix.  It is also used for the Nyström W_XY blocks.

Grid layout: (j_tiles, i_tiles) with i innermost; the output BlockSpec index
map ignores i so the same output tile is revisited and accumulated across the
i dimension (standard Pallas reduction pattern).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import kernel_profile_r2

Array = jax.Array

DEFAULT_TILE_J = 256
DEFAULT_TILE_I = 512


def _matvec_kernel(a_ref, b_ref, x_ref, o_ref, *, kernel_name: str,
                   param: float, zero_diagonal: bool, tile_j: int,
                   tile_i: int, n_out: int, n_in: int):
    j = pl.program_id(0)
    i = pl.program_id(1)

    a = a_ref[...]  # (TJ, d)
    b = b_ref[...]  # (TI, d)
    x = x_ref[...]  # (TI, C)

    # ||a - b||^2 via broadcasting (d is tiny; stays in VREGs)
    diff = a[:, None, :] - b[None, :, :]
    r2 = jnp.sum(diff * diff, axis=-1)  # (TJ, TI)
    w = kernel_profile_r2(r2, kernel_name, param)

    row_ids = j * tile_j + jax.lax.broadcasted_iota(jnp.int32, (tile_j, tile_i), 0)
    col_ids = i * tile_i + jax.lax.broadcasted_iota(jnp.int32, (tile_j, tile_i), 1)
    valid = (row_ids < n_out) & (col_ids < n_in)
    if zero_diagonal:
        valid = valid & (row_ids != col_ids)
    w = jnp.where(valid, w, 0.0)

    partial = jnp.dot(w, x, preferred_element_type=o_ref.dtype)  # (TJ, C)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = partial

    @pl.when(i > 0)
    def _acc():
        o_ref[...] += partial


@functools.partial(
    jax.jit,
    static_argnames=("kernel_name", "param", "zero_diagonal", "tile_j",
                     "tile_i", "interpret"),
)
def kernel_matvec(points_out: Array, points_in: Array, x: Array, *,
                  kernel_name: str = "gaussian", param: float = 1.0,
                  zero_diagonal: bool = True, tile_j: int = DEFAULT_TILE_J,
                  tile_i: int = DEFAULT_TILE_I, interpret: bool = False) -> Array:
    """Pallas tiled kernel matvec.  See module docstring.

    points_out: (n_out, d); points_in: (n_in, d); x: (n_in,) or (n_in, c).
    """
    squeeze = x.ndim == 1
    if squeeze:
        x = x[:, None]
    n_out, d = points_out.shape
    n_in = points_in.shape[0]
    c = x.shape[1]

    tj = min(tile_j, max(8, n_out))
    ti = min(tile_i, max(8, n_in))
    pad_j = (-n_out) % tj
    pad_i = (-n_in) % ti
    a = jnp.pad(points_out, ((0, pad_j), (0, 0)))
    b = jnp.pad(points_in, ((0, pad_i), (0, 0)))
    xp = jnp.pad(x, ((0, pad_i), (0, 0)))

    grid = (a.shape[0] // tj, b.shape[0] // ti)

    out = pl.pallas_call(
        functools.partial(
            _matvec_kernel, kernel_name=kernel_name, param=float(param),
            zero_diagonal=zero_diagonal, tile_j=tj, tile_i=ti,
            n_out=n_out, n_in=n_in),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tj, d), lambda j, i: (j, 0)),
            pl.BlockSpec((ti, d), lambda j, i: (i, 0)),
            pl.BlockSpec((ti, c), lambda j, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tj, c), lambda j, i: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((a.shape[0], c), x.dtype),
        interpret=interpret,
    )(a, b, xp)

    out = out[:n_out]
    return out[:, 0] if squeeze else out
