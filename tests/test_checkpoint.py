"""Checkpoint + fault-tolerance tests: round trip, atomicity, GC, CRC
corruption torture, multi-host sharded writes, resume equivalence with
injected faults."""

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.data.pipeline import batch_for_step
from repro.training import checkpoint as ckpt
from repro.training.fault_tolerance import (
    InjectedFault, StepTimer, run_resilient)
from repro.training.train_loop import (
    TrainConfig, init_train_state, make_train_step)


def _tiny_state():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.asarray([1, 2, 3], jnp.int32)},
            "step": jnp.asarray(7)}


def test_save_restore_roundtrip(tmp_path):
    state = _tiny_state()
    ckpt.save_checkpoint(str(tmp_path), 7, state)
    assert ckpt.latest_step(str(tmp_path)) == 7
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        state)
    restored = ckpt.restore_checkpoint(str(tmp_path), 7, like)
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_garbage_collection(tmp_path):
    state = _tiny_state()
    for s in (1, 2, 3, 4, 5):
        ckpt.save_checkpoint(str(tmp_path), s, state, keep=2)
    assert ckpt.all_steps(str(tmp_path)) == [4, 5]


def test_tmp_dirs_ignored(tmp_path):
    state = _tiny_state()
    ckpt.save_checkpoint(str(tmp_path), 3, state)
    os.makedirs(tmp_path / "step_00000009.tmp")  # simulated torn write
    assert ckpt.latest_step(str(tmp_path)) == 3


def test_async_save(tmp_path):
    state = _tiny_state()
    t = ckpt.save_checkpoint(str(tmp_path), 11, state, blocking=False)
    t.join()
    assert ckpt.latest_step(str(tmp_path)) == 11


def test_step_timer_flags_stragglers():
    t = StepTimer(warmup=2, threshold_sigmas=3.0)
    flagged = [t.observe(dt) for dt in
               [1.0, 1.0, 1.05, 0.95, 1.02, 0.98, 1.03, 5.0, 1.0]]
    assert flagged[7] is True
    assert sum(flagged) == 1


# ---------------------------------------------------------------------------
# Validation errors (satellite: informative CheckpointError naming the leaf)
# ---------------------------------------------------------------------------

def test_restore_rejects_wrong_dtype(tmp_path):
    state = _tiny_state()
    ckpt.save_checkpoint(str(tmp_path), 1, state)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        state)
    like["b"]["c"] = jax.ShapeDtypeStruct((3,), jnp.float64)  # drifted dtype
    with pytest.raises(ckpt.CheckpointError, match=r"\['b'\]\['c'\].*dtype"):
        ckpt.restore_checkpoint(str(tmp_path), 1, like)


def test_restore_rejects_wrong_shape(tmp_path):
    state = _tiny_state()
    ckpt.save_checkpoint(str(tmp_path), 1, state)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        state)
    like["a"] = jax.ShapeDtypeStruct((3, 2), jnp.float32)
    with pytest.raises(ckpt.CheckpointError, match=r"\['a'\].*shape"):
        ckpt.restore_checkpoint(str(tmp_path), 1, like)


def test_restore_rejects_drifted_tree_paths(tmp_path):
    """Renamed state fields must not restore silently into wrong leaves."""
    state = _tiny_state()
    ckpt.save_checkpoint(str(tmp_path), 1, state)
    drifted = {"a": state["a"], "b": {"renamed": state["b"]["c"]},
               "step": state["step"]}
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        drifted)
    with pytest.raises(ckpt.CheckpointError, match="tree path"):
        ckpt.restore_checkpoint(str(tmp_path), 1, like)


def test_restore_rejects_leaf_count_mismatch(tmp_path):
    state = _tiny_state()
    ckpt.save_checkpoint(str(tmp_path), 1, state)
    with pytest.raises(ckpt.CheckpointError, match="leaves"):
        ckpt.restore_checkpoint(str(tmp_path), 1, {"a": state["a"]})


# ---------------------------------------------------------------------------
# Corruption torture (satellite: CRC detection + previous-step fallback)
# ---------------------------------------------------------------------------

def _like(state):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        state)


def _step_dir(tmp_path, step):
    return tmp_path / f"step_{step:08d}"


@pytest.mark.parametrize("damage", ["truncate", "bitflip", "delete_leaf",
                                    "delete_manifest"])
def test_corruption_recovers_previous_step(tmp_path, damage):
    """Damage the newest step in four ways; restore_latest_valid must skip
    it and recover the intact previous step, never return partial data."""
    state = _tiny_state()
    ckpt.save_checkpoint(str(tmp_path), 1, state)
    state2 = jax.tree.map(lambda x: x + 1, state)
    ckpt.save_checkpoint(str(tmp_path), 2, state2)

    leaf = _step_dir(tmp_path, 2) / "leaf_0.npy"
    if damage == "truncate":
        raw = leaf.read_bytes()
        leaf.write_bytes(raw[:len(raw) // 2])
    elif damage == "bitflip":
        raw = bytearray(leaf.read_bytes())
        raw[-1] ^= 0x40  # flip a bit inside the float payload
        leaf.write_bytes(bytes(raw))
    elif damage == "delete_leaf":
        os.remove(leaf)
    else:
        os.remove(_step_dir(tmp_path, 2) / "manifest.json")

    if damage == "delete_manifest":
        # a manifest-less step is not even listed (torn-write semantics)
        assert ckpt.latest_step(str(tmp_path)) == 1
    else:
        with pytest.raises(ckpt.CheckpointError):
            ckpt.restore_checkpoint(str(tmp_path), 2, _like(state))

    step, restored = ckpt.restore_latest_valid(str(tmp_path), _like(state))
    assert step == 1
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_bitflip_raises_corruption_error_naming_leaf(tmp_path):
    state = _tiny_state()
    ckpt.save_checkpoint(str(tmp_path), 5, state)
    leaf = _step_dir(tmp_path, 5) / "leaf_1.npy"  # ['b']['c']
    raw = bytearray(leaf.read_bytes())
    raw[-1] ^= 0x01
    leaf.write_bytes(bytes(raw))
    with pytest.raises(ckpt.CheckpointCorruptionError,
                       match=r"\['b'\]\['c'\].*CRC32"):
        ckpt.restore_checkpoint(str(tmp_path), 5, _like(state))


def test_all_steps_corrupt_returns_none(tmp_path):
    state = _tiny_state()
    ckpt.save_checkpoint(str(tmp_path), 1, state)
    os.remove(_step_dir(tmp_path, 1) / "leaf_0.npy")
    step, restored = ckpt.restore_latest_valid(str(tmp_path), _like(state))
    assert step is None and restored is None


# ---------------------------------------------------------------------------
# Orphaned tmp sweep + mid-flight writer death (satellite)
# ---------------------------------------------------------------------------

def test_killed_writer_orphan_swept_by_next_save(tmp_path, monkeypatch):
    """Kill a save mid-write (np.save raises partway); the torn .tmp dir
    must never publish, and the next save (after the TTL) sweeps it."""
    state = _tiny_state()
    calls = {"n": 0}
    real_save = np.save

    def dying_save(path, arr, *a, **k):
        calls["n"] += 1
        if calls["n"] == 2:
            raise OSError("simulated writer death mid-flight")
        return real_save(path, arr, *a, **k)

    monkeypatch.setattr(np, "save", dying_save)
    with pytest.raises(OSError, match="mid-flight"):
        ckpt.save_checkpoint(str(tmp_path), 1, state)
    monkeypatch.setattr(np, "save", real_save)

    # the torn write left a .tmp dir and no published step
    orphans = [n for n in os.listdir(tmp_path) if n.endswith(".tmp")]
    assert orphans == ["step_00000001.tmp"]
    assert ckpt.latest_step(str(tmp_path)) is None

    # age the orphan past the TTL; the next save sweeps it and publishes
    old = time.time() - 2 * ckpt.TMP_SWEEP_TTL_S
    os.utime(tmp_path / orphans[0], (old, old))
    ckpt.save_checkpoint(str(tmp_path), 2, state)
    assert ckpt.latest_step(str(tmp_path)) == 2
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))


def test_young_tmp_of_live_writer_not_swept(tmp_path):
    """A fresh .tmp dir belongs to a live concurrent non-blocking writer:
    the sweep must leave it alone."""
    state = _tiny_state()
    live = tmp_path / "step_00000009.tmp"
    os.makedirs(live)
    ckpt.save_checkpoint(str(tmp_path), 1, state)
    assert live.is_dir()  # younger than the TTL: protected
    # and GC never touches .tmp dirs either
    for s in (2, 3, 4):
        ckpt.save_checkpoint(str(tmp_path), s, state, keep=2)
    assert live.is_dir()


# ---------------------------------------------------------------------------
# Multi-host leaf-sharded save (tentpole: per-process I/O)
# ---------------------------------------------------------------------------

def test_multihost_sharded_save_restores_identically(tmp_path):
    """Emulate a 2-process save: each process writes only its owned leaves
    (round-robin) plus a shard manifest; process 0 merges and publishes.
    The published step must restore exactly like a single-host save."""
    state = _tiny_state()  # 3 leaves -> proc0 owns {0, 2}, proc1 owns {1}
    t1 = ckpt.save_checkpoint(str(tmp_path), 3, state, blocking=False,
                              process_index=1, process_count=2)
    t0 = ckpt.save_checkpoint(str(tmp_path), 3, state, blocking=False,
                              process_index=0, process_count=2)
    t0.join(); t1.join()
    assert ckpt.latest_step(str(tmp_path)) == 3
    manifest = json.load(open(_step_dir(tmp_path, 3) / "manifest.json"))
    assert manifest["process_count"] == 2
    assert len(manifest["crc32"]) == 3  # every leaf checksummed post-merge
    restored = ckpt.restore_checkpoint(str(tmp_path), 3, _like(state))
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_multihost_barrier_times_out_without_peer(tmp_path):
    """Process 0 alone must not publish a half-written step: it waits for
    the missing shard and raises at the deadline."""
    state = _tiny_state()
    with pytest.raises(ckpt.CheckpointError, match="barrier timed out"):
        ckpt.save_checkpoint(str(tmp_path), 1, state, process_index=0,
                             process_count=2, barrier_timeout_s=0.2)
    assert ckpt.latest_step(str(tmp_path)) is None


def _setup_training(tmp_path, tag):
    cfg = reduced_config(get_config("granite-3-2b"))
    tc = TrainConfig(num_microbatches=1)
    state = init_train_state(jax.random.PRNGKey(0), cfg, tc)
    step = jax.jit(make_train_step(cfg, tc))
    batch_fn = lambda s: jax.tree.map(
        jnp.asarray, batch_for_step(cfg, cfg.shapes[0], s))
    return state, step, batch_fn


def test_resilient_resume_bit_identical(tmp_path):
    """A fault-interrupted run must produce the same final loss as an
    uninterrupted run (deterministic replay from checkpoint)."""
    state0, step, batch_fn = _setup_training(tmp_path, "a")

    # uninterrupted reference
    ref_state, ref_info = run_resilient(
        step, state0, batch_fn, total_steps=6,
        ckpt_dir=str(tmp_path / "ref"), ckpt_every=2, log_every=100)
    ref_loss = float(jax.device_get(ref_info["final_metrics"]["loss"]))

    # faulting run: dies at step 4 (after ckpt at 2), resumes, finishes
    fired = {"done": False}

    def fault_hook(s):
        if s == 4 and not fired["done"]:
            fired["done"] = True
            raise InjectedFault("simulated node failure")

    state1, info = run_resilient(
        step, state0, batch_fn, total_steps=6,
        ckpt_dir=str(tmp_path / "ft"), ckpt_every=2,
        fault_hook=fault_hook, log_every=100)
    assert info["restarts"] == 1
    loss = float(jax.device_get(info["final_metrics"]["loss"]))
    assert abs(loss - ref_loss) < 1e-6, (loss, ref_loss)
    # final params identical
    for a, b in zip(jax.tree_util.tree_leaves(ref_state.params),
                    jax.tree_util.tree_leaves(state1.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=0, atol=0)


def test_fault_before_first_checkpoint_restarts_from_initial(tmp_path):
    """Regression: a fault BEFORE the first checkpoint lands used to hit a
    dead-code path.  It must restart from the caller's initial state (replay
    from step 0 is deterministic) and still respect max_restarts."""
    state0 = {"x": jnp.zeros((4,), jnp.float32)}

    def train_step(state, batch):
        return {"x": state["x"] + batch}, {"loss": jnp.sum(batch)}

    batch_fn = lambda s: jnp.full((4,), float(s), jnp.float32)
    fired = {"n": 0}

    def fault_hook(s):
        if s == 1 and fired["n"] < 2:  # ckpt_every=5: no checkpoint yet
            fired["n"] += 1
            raise InjectedFault("fault before first checkpoint")

    state1, info = run_resilient(
        train_step, state0, batch_fn, total_steps=4,
        ckpt_dir=str(tmp_path), ckpt_every=5, fault_hook=fault_hook,
        log_every=100)
    assert info["restarts"] == 2
    np.testing.assert_allclose(np.asarray(state1["x"]),
                               np.full((4,), float(sum(range(4)))))
    # max_restarts still bounds the pre-first-checkpoint restart loop
    def always_fault(s):
        raise InjectedFault("always")

    with pytest.raises(InjectedFault):
        run_resilient(
            train_step, state0, batch_fn, total_steps=4,
            ckpt_dir=str(tmp_path / "cap"), ckpt_every=5, max_restarts=2,
            fault_hook=always_fault, log_every=100)


def test_straggler_detection_across_restore_and_replay(tmp_path):
    """StepTimer + run_resilient interaction: the latency monitor's EWMA
    state persists across a fault restart, so a synthetic straggler injected
    AFTER the restore-and-replay is still flagged against the statistics
    built before the fault — and the replayed steps are not misflagged.

    A synthetic (sleep-paced) train step keeps timings controlled: the real
    trainer's first-step compile time would pollute the warmup mean."""
    import time as _time

    state0 = {"x": jnp.zeros((4,), jnp.float32),
              "step": jnp.asarray(0, jnp.int32)}

    def train_step(state, batch):
        return ({"x": state["x"] + batch, "step": state["step"] + 1},
                {"loss": jnp.sum(batch)})

    def batch_fn(s):  # batch_fn runs inside the timed step window
        _time.sleep(0.30 if s == 5 else 0.01)
        return jnp.full((4,), float(s), jnp.float32)

    fired = {"done": False}

    def fault_hook(s):
        if s == 4 and not fired["done"]:
            fired["done"] = True
            raise InjectedFault("simulated node failure")

    flagged = []
    state1, info = run_resilient(
        train_step, state0, batch_fn, total_steps=8,
        ckpt_dir=str(tmp_path / "strag"), ckpt_every=2,
        fault_hook=fault_hook, log_every=100,
        on_straggler=lambda s, dt: flagged.append((s, dt)))
    assert info["restarts"] == 1
    # the post-restart straggler was flagged with its real latency ...
    assert any(s == 5 and dt > 0.25 for s, dt in flagged), flagged
    # ... and the replayed + steady steps were not misflagged
    assert all(s == 5 for s, dt in flagged), flagged
    # restore-and-replay really happened: the state is the step-8 state
    # replayed deterministically (batches are a function of the step index)
    assert int(np.asarray(state1["step"])) == 8
    np.testing.assert_allclose(
        np.asarray(state1["x"]), np.full((4,), float(sum(range(8)))))
