"""Checkpoint + fault-tolerance tests: round trip, atomicity, GC, resume
equivalence with injected faults."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.data.pipeline import batch_for_step
from repro.training import checkpoint as ckpt
from repro.training.fault_tolerance import (
    InjectedFault, StepTimer, run_resilient)
from repro.training.train_loop import (
    TrainConfig, init_train_state, make_train_step)


def _tiny_state():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.asarray([1, 2, 3], jnp.int32)},
            "step": jnp.asarray(7)}


def test_save_restore_roundtrip(tmp_path):
    state = _tiny_state()
    ckpt.save_checkpoint(str(tmp_path), 7, state)
    assert ckpt.latest_step(str(tmp_path)) == 7
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        state)
    restored = ckpt.restore_checkpoint(str(tmp_path), 7, like)
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_garbage_collection(tmp_path):
    state = _tiny_state()
    for s in (1, 2, 3, 4, 5):
        ckpt.save_checkpoint(str(tmp_path), s, state, keep=2)
    assert ckpt.all_steps(str(tmp_path)) == [4, 5]


def test_tmp_dirs_ignored(tmp_path):
    state = _tiny_state()
    ckpt.save_checkpoint(str(tmp_path), 3, state)
    os.makedirs(tmp_path / "step_00000009.tmp")  # simulated torn write
    assert ckpt.latest_step(str(tmp_path)) == 3


def test_async_save(tmp_path):
    state = _tiny_state()
    t = ckpt.save_checkpoint(str(tmp_path), 11, state, blocking=False)
    t.join()
    assert ckpt.latest_step(str(tmp_path)) == 11


def test_step_timer_flags_stragglers():
    t = StepTimer(warmup=2, threshold_sigmas=3.0)
    flagged = [t.observe(dt) for dt in
               [1.0, 1.0, 1.05, 0.95, 1.02, 0.98, 1.03, 5.0, 1.0]]
    assert flagged[7] is True
    assert sum(flagged) == 1


def _setup_training(tmp_path, tag):
    cfg = reduced_config(get_config("granite-3-2b"))
    tc = TrainConfig(num_microbatches=1)
    state = init_train_state(jax.random.PRNGKey(0), cfg, tc)
    step = jax.jit(make_train_step(cfg, tc))
    batch_fn = lambda s: jax.tree.map(
        jnp.asarray, batch_for_step(cfg, cfg.shapes[0], s))
    return state, step, batch_fn


def test_resilient_resume_bit_identical(tmp_path):
    """A fault-interrupted run must produce the same final loss as an
    uninterrupted run (deterministic replay from checkpoint)."""
    state0, step, batch_fn = _setup_training(tmp_path, "a")

    # uninterrupted reference
    ref_state, ref_info = run_resilient(
        step, state0, batch_fn, total_steps=6,
        ckpt_dir=str(tmp_path / "ref"), ckpt_every=2, log_every=100)
    ref_loss = float(jax.device_get(ref_info["final_metrics"]["loss"]))

    # faulting run: dies at step 4 (after ckpt at 2), resumes, finishes
    fired = {"done": False}

    def fault_hook(s):
        if s == 4 and not fired["done"]:
            fired["done"] = True
            raise InjectedFault("simulated node failure")

    state1, info = run_resilient(
        step, state0, batch_fn, total_steps=6,
        ckpt_dir=str(tmp_path / "ft"), ckpt_every=2,
        fault_hook=fault_hook, log_every=100)
    assert info["restarts"] == 1
    loss = float(jax.device_get(info["final_metrics"]["loss"]))
    assert abs(loss - ref_loss) < 1e-6, (loss, ref_loss)
    # final params identical
    for a, b in zip(jax.tree_util.tree_leaves(ref_state.params),
                    jax.tree_util.tree_leaves(state1.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=0, atol=0)


def test_straggler_detection_across_restore_and_replay(tmp_path):
    """StepTimer + run_resilient interaction: the latency monitor's EWMA
    state persists across a fault restart, so a synthetic straggler injected
    AFTER the restore-and-replay is still flagged against the statistics
    built before the fault — and the replayed steps are not misflagged.

    A synthetic (sleep-paced) train step keeps timings controlled: the real
    trainer's first-step compile time would pollute the warmup mean."""
    import time as _time

    state0 = {"x": jnp.zeros((4,), jnp.float32),
              "step": jnp.asarray(0, jnp.int32)}

    def train_step(state, batch):
        return ({"x": state["x"] + batch, "step": state["step"] + 1},
                {"loss": jnp.sum(batch)})

    def batch_fn(s):  # batch_fn runs inside the timed step window
        _time.sleep(0.30 if s == 5 else 0.01)
        return jnp.full((4,), float(s), jnp.float32)

    fired = {"done": False}

    def fault_hook(s):
        if s == 4 and not fired["done"]:
            fired["done"] = True
            raise InjectedFault("simulated node failure")

    flagged = []
    state1, info = run_resilient(
        train_step, state0, batch_fn, total_steps=8,
        ckpt_dir=str(tmp_path / "strag"), ckpt_every=2,
        fault_hook=fault_hook, log_every=100,
        on_straggler=lambda s, dt: flagged.append((s, dt)))
    assert info["restarts"] == 1
    # the post-restart straggler was flagged with its real latency ...
    assert any(s == 5 and dt > 0.25 for s, dt in flagged), flagged
    # ... and the replayed + steady steps were not misflagged
    assert all(s == 5 for s, dt in flagged), flagged
    # restore-and-replay really happened: the state is the step-8 state
    # replayed deterministically (batches are a function of the step index)
    assert int(np.asarray(state1["step"])) == 8
    np.testing.assert_allclose(
        np.asarray(state1["x"]), np.full((4,), float(sum(range(8)))))
