"""Property-based tests for the repro.dist subsystem.

Runs on the single real CPU device: shard_map over a size-1 mesh binds the
axis name without needing multiple devices, so these properties execute in
the main pytest process (the multi-shard behavior is covered by the
``multidevice`` subprocess tests).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.core import SETUP_1, make_fastsum, make_kernel
from repro.data.synthetic import spiral
from repro.dist.compat import shard_map
from repro.dist.compression import BLOCK, compress_psum
from repro.dist.fastsum_dist import distributed_matvec_fn


def _mesh1():
    return jax.make_mesh((1,), ("data",))


# ---------------------------------------------------------------------------
# compress_psum: idempotence on already-quantized inputs
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=20)
@given(seed=st.integers(0, 2 ** 31 - 1), exp=st.integers(-8, 8),
       n=st.integers(1, 3 * BLOCK))
def test_compress_psum_idempotent_on_lattice(seed, exp, n):
    """Inputs already on the int8 lattice pass through exactly.

    With a power-of-two scale every quantization step is exact in fp32:
    ``g = ints * 2^exp`` with ``max|int| = 127`` reproduces itself, the
    residual is exactly zero, and (on one shard) the psum-mean equals g.
    """
    rng = np.random.default_rng(seed)
    ints = rng.integers(-127, 128, size=n)
    ints[::BLOCK] = 127  # pin every block's scale to 2^exp exactly
    g = jnp.asarray(ints * (2.0 ** exp), jnp.float32)
    resid = jnp.zeros_like(g)

    mesh = _mesh1()

    @functools.partial(shard_map, mesh=mesh, in_specs=(P(), P()),
                       out_specs=(P(), P()), check_rep=False)
    def run(gs, rs):
        return compress_psum(gs, "data", rs)

    mean, new_resid = run(g, resid)
    assert bool(jnp.all(mean == g)), "lattice input must survive unchanged"
    assert bool(jnp.all(new_resid == 0.0))

    # and a second round is a fixed point too
    mean2, resid2 = run(mean, new_resid)
    assert bool(jnp.all(mean2 == mean))
    assert bool(jnp.all(resid2 == 0.0))


# ---------------------------------------------------------------------------
# distributed_matvec_fn: linearity + agreement with the local operator
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def dist_mv():
    n = 192  # deliberately not divisible by typical shard counts
    points, _ = spiral(n, seed=7)
    pts = jnp.asarray(points, jnp.float32)
    op = make_fastsum(make_kernel("gaussian", sigma=2.5), pts, SETUP_1)
    mesh = _mesh1()
    return op, distributed_matvec_fn(op, mesh, ("data",)), n


@settings(deadline=None, max_examples=10)
@given(a=st.floats(-3, 3), b=st.floats(-3, 3), seed=st.integers(0, 1000))
def test_distributed_matvec_linear(dist_mv, a, b, seed):
    """mv(a*x + b*y) == a*mv(x) + b*mv(y) up to fp32 roundoff."""
    op, mv, n = dist_mv
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(n), jnp.float32)
    y = jnp.asarray(rng.standard_normal(n), jnp.float32)
    lhs = mv(a * x + b * y)
    rhs = a * mv(x) + b * mv(y)
    scale = float(jnp.max(jnp.abs(rhs))) + float(jnp.max(jnp.abs(lhs))) + 1e-6
    assert float(jnp.max(jnp.abs(lhs - rhs))) / scale < 5e-5


@settings(deadline=None, max_examples=10)
@given(seed=st.integers(0, 1000))
def test_distributed_matvec_matches_local(dist_mv, seed):
    op, mv, n = dist_mv
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(n), jnp.float32)
    ref = op.matvec(x)
    out = mv(x)
    err = float(jnp.max(jnp.abs(out - ref)) /
                jnp.maximum(jnp.max(jnp.abs(ref)), 1e-30))
    assert err < 2e-5, err


def test_distributed_matvec_batched_columns(dist_mv):
    """The drop-in contract includes op.matvec's (n, C) batched form."""
    op, mv, n = dist_mv
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.standard_normal((n, 3)), jnp.float32)
    ref = op.matvec(x)
    out = mv(x)
    assert out.shape == ref.shape
    err = float(jnp.max(jnp.abs(out - ref)) /
                jnp.maximum(jnp.max(jnp.abs(ref)), 1e-30))
    assert err < 2e-5, err
