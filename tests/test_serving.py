"""Serving engine tests: greedy consistency, continuous batching."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.models import model as M
from repro.serving.engine import Request, ServeEngine


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config(get_config("granite-3-2b"))
    params = M.init_params(jax.random.PRNGKey(3), cfg)
    return cfg, params


def _manual_greedy(cfg, params, prompt, n_new, max_seq):
    caches = M.init_caches(cfg, 1, max_seq)
    toks = jnp.asarray(prompt, jnp.int32)[None, :]
    logits, caches = M.forward_prefill(params, cfg, {"tokens": toks}, caches)
    out = [int(jnp.argmax(logits[0, -1]))]
    pos = len(prompt)
    for _ in range(n_new - 1):
        logits, caches = M.forward_decode(
            params, cfg, jnp.asarray([[out[-1]]], jnp.int32),
            jnp.asarray([pos], jnp.int32), caches)
        out.append(int(jnp.argmax(logits[0, 0])))
        pos += 1
    return out


def test_engine_matches_manual_decode(setup):
    cfg, params = setup
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, int(rng.integers(3, 9))).tolist()
               for _ in range(3)]
    n_new = 6

    engine = ServeEngine(cfg, params, slots=2, max_seq=64)
    reqs = [Request(uid=i, tokens=p, max_new_tokens=n_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        engine.submit(r)
    engine.run_until_drained()

    for r, p in zip(reqs, prompts):
        ref = _manual_greedy(cfg, params, p, n_new, 64)
        assert r.output == ref, (r.uid, r.output, ref)


def test_continuous_batching_recycles_slots(setup):
    cfg, params = setup
    engine = ServeEngine(cfg, params, slots=2, max_seq=32)
    reqs = [Request(uid=i, tokens=[1, 2, 3], max_new_tokens=4)
            for i in range(5)]
    for r in reqs:
        engine.submit(r)
    engine.run_until_drained()
    assert all(r.done for r in reqs)
    assert all(len(r.output) == 4 for r in reqs)


def test_mixed_progress_batch(setup):
    """Requests admitted at different ticks share decode steps correctly."""
    cfg, params = setup
    engine = ServeEngine(cfg, params, slots=2, max_seq=64)
    r1 = Request(uid=0, tokens=[5, 6, 7, 8], max_new_tokens=8)
    engine.submit(r1)
    engine.step()
    engine.step()  # r1 two tokens in
    r2 = Request(uid=1, tokens=[9, 10], max_new_tokens=8)
    engine.submit(r2)
    engine.run_until_drained()
    assert r1.done and r2.done
    assert r1.output == _manual_greedy(cfg, params, [5, 6, 7, 8], 8, 64)
    assert r2.output == _manual_greedy(cfg, params, [9, 10], 8, 64)


def test_recycled_slot_has_no_stale_cache(setup):
    """Regression guard for slot recycling: a short request admitted into a
    slot that previously held a LONGER request must not read the earlier
    tenant's KV entries past its own position.  Interleave short and long
    requests so each slot is recycled several times at shrinking lengths,
    and require exact agreement with unbatched decoding."""
    cfg, params = setup
    rng = np.random.default_rng(4)
    # long first (fills deep cache rows), then progressively shorter ones
    # recycled into the same slots; distinct prompts per request
    specs = [(14, 10), (3, 4), (12, 8), (2, 3), (5, 6), (2, 8)]
    prompts = [rng.integers(0, cfg.vocab_size, n).tolist()
               for n, _ in specs]
    engine = ServeEngine(cfg, params, slots=2, max_seq=64)
    reqs = [Request(uid=i, tokens=p, max_new_tokens=specs[i][1])
            for i, p in enumerate(prompts)]
    for r in reqs:
        engine.submit(r)
    engine.run_until_drained()
    for r, p, (_, n_new) in zip(reqs, prompts, specs):
        assert r.done
        ref = _manual_greedy(cfg, params, p, n_new, 64)
        assert r.output == ref, (r.uid, r.output, ref)
