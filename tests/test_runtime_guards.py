"""Runtime accuracy guards: the Lemma 3.1 probe consulted live.

Small point sets keep the dense oracles cheap; the probe itself never
builds a dense matrix (that is the point), so its behavior is cross-checked
against ``core.error``'s exact O(n^2) machinery.  The Monte-Carlo eps
estimator samples the whole admissible ball — including the regularization
band actual point pairs never reach — so the probe bound is *conservative*
(>= the exact bound): the guard can over-escalate, never under-protect.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FastsumParams, dense_normalized_adjacency, dense_weight_matrix,
    make_fastsum, make_kernel,
)
from repro.core.error import aposteriori_report, lemma31_bound
from repro.runtime import (
    DirectKernelOperator, GuardPolicy, guarded_fastsum,
    guarded_normalized_adjacency, probe_fastsum,
)

KERNEL = make_kernel("gaussian", sigma=3.5)
# bound_tol with margin: at n=200 the probe bound is ~0.04 for N=16 and
# inf for N=8 (degrees there are contaminated enough to zero out eta)
TOL = 0.1


def _points(n=200, d=2, seed=7):
    return jnp.asarray(np.random.default_rng(seed).normal(size=(n, d)))


def _vec(n, seed=100):
    return jnp.asarray(np.random.default_rng(seed).normal(size=(n,)))


def test_lemma31_bound_degenerate_inputs_read_as_worst_case():
    assert lemma31_bound(float("nan"), 0.1) == float("inf")
    assert lemma31_bound(0.5, float("nan")) == float("inf")
    assert lemma31_bound(0.0, 0.0) == float("inf")
    assert lemma31_bound(-0.1, 0.01) == float("inf")
    assert np.isfinite(lemma31_bound(0.5, 0.01))


def test_probe_matches_dense_aposteriori():
    """The cheap probe's eta agrees with the exact dense report; its bound
    is finite, conservative (>= exact), and exactly Lemma 3.1 of its own
    (eta, eps)."""
    pts = _points()
    params = FastsumParams(n_bandwidth=32, m=4)
    fs = make_fastsum(KERNEL, pts, params)
    probe = probe_fastsum(KERNEL, pts, params, fs, n_samples=4096)
    exact = aposteriori_report(KERNEL, pts, fs)
    np.testing.assert_allclose(probe.eta, exact["eta"], rtol=1e-3)
    assert probe.eps > 0 and np.isfinite(probe.bound)
    assert probe.bound >= exact["bound"]  # never optimistic
    np.testing.assert_allclose(probe.bound,
                               lemma31_bound(probe.eta, probe.eps))


def test_guard_accepts_adequate_bandwidth():
    pts = _points()
    op, report = guarded_fastsum(
        KERNEL, pts, FastsumParams(n_bandwidth=16, m=4),
        policy=GuardPolicy(bound_tol=TOL, max_bandwidth=256))
    assert report.ok and report.fallback == "none"
    assert report.escalations == 0
    assert report.final.bound <= TOL
    # the returned operator is a working fastsum
    x = _vec(pts.shape[0])
    assert np.all(np.isfinite(np.asarray(op.matvec(x))))


def test_guard_escalates_bandwidth_until_bound_met():
    """An undersized N must be doubled until the Lemma 3.1 bound passes."""
    pts = _points()
    op, report = guarded_fastsum(
        KERNEL, pts, FastsumParams(n_bandwidth=8, m=4),
        policy=GuardPolicy(bound_tol=TOL, max_bandwidth=256))
    assert report.ok and report.fallback == "none"
    assert report.escalations >= 1
    assert report.final.bound <= TOL
    assert report.final.n_bandwidth > 8
    # attempts record the whole ladder, strictly increasing in N, and the
    # rejected attempts all exceeded the tolerance
    ns = [a.n_bandwidth for a in report.attempts]
    assert ns == sorted(ns) and len(set(ns)) == len(ns)
    assert all(a.bound > TOL for a in report.attempts[:-1])


def test_guard_direct_fallback_below_threshold():
    """Escalation ceiling reached + small problem -> the exact dense-math
    operator, which matches the dense oracle to machine precision."""
    pts = _points(n=150, seed=8)
    op, report = guarded_fastsum(
        KERNEL, pts, FastsumParams(n_bandwidth=8, m=4),
        policy=GuardPolicy(bound_tol=0.0,  # unreachable: bound > 0 always
                           max_bandwidth=16, direct_threshold=1024))
    assert report.ok and report.fallback == "direct"
    assert isinstance(op, DirectKernelOperator)
    x = _vec(150)
    ref = dense_weight_matrix(KERNEL, pts) @ x
    np.testing.assert_allclose(np.asarray(op.matvec(x)), np.asarray(ref),
                               rtol=1e-10, atol=1e-10)
    # matvec_tilde adds the diagonal back; degrees = W @ 1
    np.testing.assert_allclose(
        np.asarray(op.matvec_tilde(x)),
        np.asarray(ref + KERNEL.at_zero() * x), rtol=1e-10, atol=1e-10)
    np.testing.assert_allclose(
        np.asarray(op.degrees()),
        np.asarray(dense_weight_matrix(KERNEL, pts)
                   @ jnp.ones((150,), pts.dtype)),
        rtol=1e-10, atol=1e-10)


def test_guard_warns_unguarded_past_threshold():
    """No tolerance met, problem too big for direct: the best attempt comes
    back with ok=False and a RuntimeWarning — degraded, never silent."""
    pts = _points(n=150, seed=9)
    with pytest.warns(RuntimeWarning, match="UNGUARDED"):
        op, report = guarded_fastsum(
            KERNEL, pts, FastsumParams(n_bandwidth=8, m=4),
            policy=GuardPolicy(bound_tol=0.0, max_bandwidth=16,
                               direct_threshold=0))
    assert not report.ok and report.fallback == "none"
    assert report.final.n_bandwidth == 16  # best (largest-N) attempt


def test_guarded_normalized_adjacency_matches_dense():
    pts = _points(n=150, seed=10)
    adj, report = guarded_normalized_adjacency(
        KERNEL, pts, FastsumParams(n_bandwidth=32, m=4),
        policy=GuardPolicy(bound_tol=TOL))
    assert report.ok
    x = _vec(150)
    ref = dense_normalized_adjacency(KERNEL, pts) @ x
    np.testing.assert_allclose(np.asarray(adj.matvec(x)), np.asarray(ref),
                               atol=1e-4)


def test_guarded_normalized_adjacency_direct_floor_matches_dense():
    """The degradation-ladder floor also serves Algorithm 3.2: a direct
    operator under the normalized adjacency equals the dense oracle."""
    pts = _points(n=120, seed=11)
    adj, report = guarded_normalized_adjacency(
        KERNEL, pts, FastsumParams(n_bandwidth=8, m=4),
        policy=GuardPolicy(bound_tol=0.0, max_bandwidth=8,
                           direct_threshold=1024))
    assert report.fallback == "direct"
    x = _vec(120)
    ref = dense_normalized_adjacency(KERNEL, pts) @ x
    np.testing.assert_allclose(np.asarray(adj.matvec(x)), np.asarray(ref),
                               rtol=1e-9, atol=1e-9)
