"""Operator-bank execution path (PR 5): parity, HLO no-rework, apps.

The bank pipeline shares one spread + one forward rfftn across S spectral
multipliers; every member's output must match an independent single-operator
fused pipeline near machine precision (same algebra, batched execution), and
the lowered HLO must contain exactly ONE forward real FFT and ONE spread
scatter loop regardless of S.
"""

import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FastsumParams, SETUP_1, SETUP_2, cg_bank, dense_weight_matrix,
    make_fastsum, make_fastsum_bank, make_kernel,
    make_normalized_adjacency_mixture, minres_bank,
)
from repro.core import fastsum_exec
from repro.graph import krr_fit, krr_fit_sweep, krr_predict_direct, krr_sweep_model

RNG = np.random.default_rng(11)
N_PTS = 250

KERNELS = [
    ("gaussian", dict(sigma=3.5)),
    ("laplacian_rbf", dict(sigma=2.0)),
    ("multiquadric", dict(c=1.0)),
    ("inverse_multiquadric", dict(c=1.0)),
]


def _points(d, n=N_PTS):
    return jnp.asarray(RNG.normal(size=(n, d)) * 2.0)


def _bank_and_members(d, params=None, kernels=KERNELS):
    params = params or FastsumParams(n_bandwidth=16, m=4)
    pts = _points(d)
    ks = [make_kernel(name, **kw) for name, kw in kernels]
    bank = make_fastsum_bank(ks, pts, params)
    members = [make_fastsum(k, pts, params) for k in ks]
    return bank, members


# ------------------------------------------------------------ matvec parity
@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("d", [1, 2, 3])
def test_bank_matches_independent_pipelines(d, backend):
    """All four kernels in one bank vs four independent fused matvecs,
    broadcast flavor, single and batched RHS, both window backends."""
    bank, members = _bank_and_members(d)
    for shape in [(N_PTS,), (N_PTS, 3)]:
        x = jnp.asarray(RNG.normal(size=shape))
        out = bank.matvec_tilde(x, backend=backend)
        for s, op in enumerate(members):
            ref = op.matvec_tilde(x, backend=backend)
            rel = float(jnp.max(jnp.abs(out[s] - ref))
                        / jnp.max(jnp.abs(ref)))
            assert rel < 1e-12, (KERNELS[s][0], d, backend, shape, rel)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("d", [1, 2, 3])
def test_bank_lockstep_matches_independent_pipelines(d, backend):
    """Lockstep flavor: member s applied to its own x[s] (the bank Krylov
    iteration shape)."""
    bank, members = _bank_and_members(d)
    xs = jnp.asarray(RNG.normal(size=(len(members), N_PTS, 2)))
    out = bank.matvec_tilde(xs, backend=backend)
    for s, op in enumerate(members):
        ref = op.matvec_tilde(xs[s], backend=backend)
        rel = float(jnp.max(jnp.abs(out[s] - ref)) / jnp.max(jnp.abs(ref)))
        assert rel < 1e-12, (KERNELS[s][0], d, backend, rel)


def test_bank_matvec_subtracts_per_member_diagonal():
    bank, members = _bank_and_members(2)
    x = jnp.asarray(RNG.normal(size=(N_PTS,)))
    out = bank.matvec(x)
    for s, op in enumerate(members):
        np.testing.assert_allclose(np.asarray(out[s]),
                                   np.asarray(op.matvec(x)),
                                   rtol=1e-11, atol=1e-11)


def test_bank_member_view_is_plain_operator():
    bank, members = _bank_and_members(3)
    x = jnp.asarray(RNG.normal(size=(N_PTS,)))
    for s, op in enumerate(members):
        mem = bank.member(s)
        np.testing.assert_allclose(np.asarray(mem.matvec(x)),
                                   np.asarray(op.matvec(x)),
                                   rtol=1e-11, atol=1e-11)
        # the member's reference (two-NFFT) path works too: scale folded
        np.testing.assert_allclose(np.asarray(mem.matvec_reference(x)),
                                   np.asarray(op.matvec_reference(x)),
                                   rtol=1e-11, atol=1e-11)


def test_bank_rejects_mismatched_lockstep_rank():
    bank, _ = _bank_and_members(2)
    bad = jnp.zeros((bank.size + 1, N_PTS, 1))
    with pytest.raises(ValueError):
        bank.matvec_tilde(bad)


# ------------------------------------------------------------------ mixture
def test_mixture_collapses_to_weighted_sum():
    """mixture(w).matvec == sum_s w_s member_s.matvec at machine precision,
    via ONE fused pipeline (it is a plain FastsumOperator)."""
    bank, _ = _bank_and_members(2)
    w = np.array([0.4, 0.3, 0.2, 0.1])
    mix = bank.mixture(w)
    x = jnp.asarray(RNG.normal(size=(N_PTS,)))
    ref = jnp.tensordot(jnp.asarray(w), bank.matvec(x), axes=1)
    got = mix.matvec(x)
    rel = float(jnp.max(jnp.abs(got - ref)) / jnp.max(jnp.abs(ref)))
    assert rel < 1e-12, rel
    # and the collapsed operator's own two-NFFT reference agrees (b_hat
    # collapsed consistently with the multiplier)
    refr = mix.matvec_reference(x)
    rel = float(jnp.max(jnp.abs(got - refr)) / jnp.max(jnp.abs(refr)))
    assert rel < 1e-12, rel


def test_mixture_matches_dense_multilayer_weight_matrix():
    """Gaussian two-layer mixture vs the dense weighted sum of per-layer W."""
    pts = _points(2)
    ks = [make_kernel("gaussian", sigma=3.5), make_kernel("gaussian", sigma=2.0)]
    w = [0.6, 0.4]
    bank = make_fastsum_bank(ks, pts, SETUP_2)
    x = jnp.asarray(RNG.normal(size=(N_PTS,)))
    dense = sum(wi * (dense_weight_matrix(k, pts) @ x)
                for wi, k in zip(w, ks))
    got = bank.mixture(w).matvec(x)
    rel = float(jnp.max(jnp.abs(got - dense)) / jnp.max(jnp.abs(dense)))
    assert rel < 1e-5, rel


def test_mixture_adjacency_symmetric():
    pts = _points(3)
    ks = [make_kernel("gaussian", sigma=3.5),
          make_kernel("laplacian_rbf", sigma=2.0)]
    adj = make_normalized_adjacency_mixture(ks, [0.7, 0.3], pts, SETUP_1)
    x = jnp.asarray(RNG.normal(size=(N_PTS,)))
    y = jnp.asarray(RNG.normal(size=(N_PTS,)))
    lhs = float(jnp.vdot(adj.matvec(x), y))
    rhs = float(jnp.vdot(x, adj.matvec(y)))
    assert abs(lhs - rhs) / abs(lhs) < 1e-12


def test_mixture_rejects_bad_weight_shape():
    bank, _ = _bank_and_members(1)
    with pytest.raises(ValueError):
        bank.mixture([0.5, 0.5])  # bank has 4 members


# ------------------------------------------------- HLO no-rework assertions
def _count_ops(lowered_text, pattern):
    return len(re.findall(pattern, lowered_text))


@pytest.mark.parametrize("nb", [1, 4])
def test_bank_lowers_one_forward_rfft_and_one_spread(nb):
    """The no-rework analogue of PR 3's no-cube test: a bank matvec lowers
    exactly ONE forward real FFT and ONE spread scatter-add regardless of S
    — the whole point of the bank is that the forward half is never
    re-executed per member."""
    kern = make_kernel("gaussian", sigma=3.5)
    pts = _points(2, n=2000)
    params = FastsumParams(n_bandwidth=16, m=4)
    ks = [make_kernel("gaussian", sigma=3.5 + 0.5 * s) for s in range(nb)]
    bank = make_fastsum_bank(ks, pts, params)
    x = jnp.asarray(RNG.normal(size=(2000, 2)))
    lowered = jax.jit(
        lambda mult, src, tgt, xx: fastsum_exec.fused_pipeline_bank(
            bank.plan, mult, src, tgt, xx, backend="xla")
    ).lower(bank.multiplier_bank, bank.src_window, bank.tgt_window, x)
    text = lowered.as_text()
    # stablehlo.fft lowers as `stablehlo.fft %x, type = RFFT, ...`; the
    # regex requires R immediately after `=`, so IRFFT never matches it
    n_rfft = _count_ops(text, r"type\s*=\s*RFFT")
    n_irfft = _count_ops(text, r"type\s*=\s*IRFFT")
    assert n_rfft == 1, (nb, n_rfft)
    assert n_irfft == 1, (nb, n_irfft)  # inverse is batched over S*C, not S ops
    # one spread: scatter-add count must not grow with S.  The constant
    # population is the spread body, the d periodic-pad fold-backs, and the
    # O(n) int inverse-permutation build — the gather side uses takes.
    n_scatter = _count_ops(text, r"\"stablehlo\.scatter\"\(")
    assert n_scatter <= bank.plan.d + 2, (nb, n_scatter)


def test_bank_scatter_count_independent_of_s():
    """Same lowering at S=1 and S=4 must contain the same number of FFT and
    scatter ops — S only widens tensors, it never replays pipeline stages."""
    pts = _points(2, n=1500)
    params = FastsumParams(n_bandwidth=16, m=4)
    x = jnp.asarray(RNG.normal(size=(1500, 2)))
    texts = {}
    for nb in (1, 4):
        ks = [make_kernel("gaussian", sigma=3.0 + s) for s in range(nb)]
        bank = make_fastsum_bank(ks, pts, params)
        texts[nb] = jax.jit(
            lambda mult, src, tgt, xx, plan=bank.plan:
            fastsum_exec.fused_pipeline_bank(plan, mult, src, tgt, xx,
                                             backend="xla")
        ).lower(bank.multiplier_bank, bank.src_window, bank.tgt_window,
                x).as_text()
    for pat in (r"type\s*=\s*RFFT", r"type\s*=\s*IRFFT",
                r"\"stablehlo\.scatter\"\("):
        assert _count_ops(texts[1], pat) == _count_ops(texts[4], pat), pat


# --------------------------------------------------- multi-channel gather
@pytest.mark.parametrize("d", [1, 2, 3])
@pytest.mark.parametrize("c", [2, 5, 8])
def test_multichannel_gather_matches_per_column(d, c):
    """The channel-count-dispatched xla gather bodies (windowed / row-take /
    per-channel map) agree with C independent single-column gathers."""
    kern = make_kernel("gaussian", sigma=3.5)
    pts = _points(d, n=300)
    fs = make_fastsum(kern, pts, FastsumParams(n_bandwidth=16, m=3))
    plan, win = fs.plan, fs.src_window
    grid = plan.grid_size
    g = jnp.asarray(RNG.normal(size=(grid,) * d + (c,)))
    out = fastsum_exec.window_gather(plan, win, g, backend="xla")
    for j in range(c):
        ref = fastsum_exec.window_gather(plan, win, g[..., j:j + 1],
                                         backend="xla")[..., 0]
        np.testing.assert_allclose(np.asarray(out[:, j]), np.asarray(ref),
                                   rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("c", [2, 8])
def test_multichannel_spread_gather_adjoint(c):
    """<gather(g), x> == <g, spread(x)> holds on every multi-channel path."""
    kern = make_kernel("gaussian", sigma=3.5)
    pts = _points(2, n=300)
    fs = make_fastsum(kern, pts, FastsumParams(n_bandwidth=16, m=3))
    plan, win = fs.plan, fs.src_window
    grid = plan.grid_size
    x = jnp.asarray(RNG.normal(size=(300, c)))
    g = jnp.asarray(RNG.normal(size=(grid, grid, c)))
    lhs = float(jnp.vdot(
        fastsum_exec.window_gather(plan, win, g, backend="xla"), x))
    rhs = float(jnp.vdot(
        g, fastsum_exec.window_spread(plan, win, x, backend="xla")))
    assert abs(lhs - rhs) / abs(lhs) < 1e-12


# ----------------------------------------------------------- bank solvers
def test_cg_bank_on_fastsum_bank():
    """Lockstep bank CG against per-member dense solves on real operators."""
    pts = _points(2, n=200)
    sigmas = (2.0, 3.5, 5.0)
    ks = [make_kernel("gaussian", sigma=s) for s in sigmas]
    bank = make_fastsum_bank(ks, pts, SETUP_2)
    beta = 0.5
    f = jnp.asarray(RNG.normal(size=(200,)))
    rhs = jnp.broadcast_to(f[None, :, None], (3, 200, 1))
    sol = cg_bank(lambda x: bank.matvec_tilde(x) + beta * x, rhs,
                  tol=1e-10, maxiter=500)
    assert bool(jnp.all(sol.converged)), np.asarray(sol.residual_norm)
    for s, k in enumerate(ks):
        kd = dense_weight_matrix(k, pts) + (float(k.at_zero()) + beta) * jnp.eye(200)
        ref = np.linalg.solve(np.asarray(kd), np.asarray(f))
        rel = float(np.max(np.abs(np.asarray(sol.x[s, :, 0]) - ref))
                    / np.max(np.abs(ref)))
        # fastsum-approximate Gram vs dense Gram: kernel-approximation tier
        assert rel < 1e-3, (sigmas[s], rel)


def test_minres_bank_matches_cg_bank():
    mats = [np.random.default_rng(s).normal(size=(80, 80)) for s in range(3)]
    bank = jnp.stack([jnp.asarray(m @ m.T + 80 * np.eye(80)) for m in mats])
    b = jnp.asarray(RNG.normal(size=(3, 80, 2)))
    mv = lambda x: jnp.einsum("sij,sjc->sic", bank, x)
    s1 = cg_bank(mv, b, tol=1e-12, maxiter=500)
    s2 = minres_bank(mv, b, tol=1e-12, maxiter=500)
    np.testing.assert_allclose(np.asarray(s1.x), np.asarray(s2.x),
                               rtol=1e-7, atol=1e-7)
    assert s1.x.shape == (3, 80, 2)
    assert s1.num_iters.shape == (3, 2)


# -------------------------------------------------------------- krr sweep
def test_krr_fit_sweep_matches_sequential_fits():
    rng = np.random.default_rng(5)
    n = 400
    xtr = jnp.asarray(rng.uniform(-1, 1, size=(n, 2)))
    ytr = jnp.asarray(np.sin(3 * np.asarray(xtr[:, 0]))
                      + np.asarray(xtr[:, 1]) ** 2)
    params = FastsumParams(n_bandwidth=32, m=4)
    sigmas, betas = (0.8, 1.5), (1e-2, 1e-1)
    sweep = krr_fit_sweep("gaussian", xtr, ytr, betas, sigmas, params,
                          tol=1e-10, maxiter=400)
    assert sweep.alphas.shape == (2, n, 2)
    assert bool(jnp.all(sweep.converged))
    for i, s in enumerate(sigmas):
        for j, b in enumerate(betas):
            m = krr_fit(make_kernel("gaussian", sigma=s), xtr, ytr, b,
                        params, tol=1e-10, maxiter=400)
            rel = float(jnp.max(jnp.abs(sweep.alphas[i, :, j] - m.alpha))
                        / jnp.max(jnp.abs(m.alpha)))
            assert rel < 1e-6, (i, j, rel)


def test_krr_sweep_model_serves_cell():
    rng = np.random.default_rng(6)
    n = 400
    xtr = jnp.asarray(rng.uniform(-1, 1, size=(n, 2)))
    ytr = jnp.asarray(np.sin(2 * np.asarray(xtr[:, 0])))
    params = FastsumParams(n_bandwidth=32, m=4)
    sweep = krr_fit_sweep("gaussian", xtr, ytr, [1e-2], (0.7, 1.2), params,
                          tol=1e-10, maxiter=400)
    model = krr_sweep_model(sweep, 1, 0)
    assert model.kernel.params["sigma"] == 1.2
    xte = jnp.asarray(rng.uniform(-1, 1, size=(60, 2)))
    from repro.graph import krr_predict
    p = krr_predict(model, xte)
    pd = krr_predict_direct(model, xte)
    rel = float(jnp.max(jnp.abs(p - pd)) / jnp.max(jnp.abs(pd)))
    assert rel < 1e-4, rel
