"""Fused fastsum engine vs the two-NFFT path / dense oracles + block Lanczos.

The fused pipeline (spread -> rfftn -> multiply -> irfftn -> gather) is
algebraically the real part of the seed two-NFFT matvec, so agreement is
asserted near machine precision — not at kernel-approximation tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    SETUP_1, SETUP_2, FastsumParams, dense_normalized_adjacency,
    dense_weight_matrix, eigsh, fused_spectral_multiplier, make_fastsum,
    make_kernel, make_normalized_adjacency, spectral_support,
)
from repro.core.nfft import build_window_geometry, morton_codes
from repro.core import fastsum_exec
from repro.data import spiral

RNG = np.random.default_rng(3)
N_PTS = 300

KERNELS = [
    ("gaussian", dict(sigma=3.5)),
    ("laplacian_rbf", dict(sigma=2.0)),
    ("multiquadric", dict(c=1.0)),
    ("inverse_multiquadric", dict(c=1.0)),
]


def _points(d, n=N_PTS):
    return jnp.asarray(RNG.normal(size=(n, d)) * 2.0)


# --------------------------------------------------- fused vs two-NFFT oracle
@pytest.mark.parametrize("kname,kw", KERNELS)
@pytest.mark.parametrize("d", [1, 2, 3])
def test_fused_matches_two_nfft_path(kname, kw, d):
    """Same operator, two execution engines: agreement ~ machine eps."""
    kern = make_kernel(kname, **kw)
    pts = _points(d)
    params = FastsumParams(n_bandwidth=16, m=4)
    fs = make_fastsum(kern, pts, params)
    x = jnp.asarray(RNG.normal(size=(N_PTS,)))
    fused = fs.matvec_tilde(x)
    ref = fs.matvec_tilde_reference(x)
    rel = float(jnp.max(jnp.abs(fused - ref)) / jnp.max(jnp.abs(ref)))
    assert rel < 1e-12, rel


@pytest.mark.parametrize("kname,kw", KERNELS)
@pytest.mark.parametrize("d", [1, 2, 3])
def test_fused_batched_matches_two_nfft_path(kname, kw, d):
    kern = make_kernel(kname, **kw)
    pts = _points(d)
    params = FastsumParams(n_bandwidth=16, m=4)
    fs = make_fastsum(kern, pts, params)
    cols = jnp.asarray(RNG.normal(size=(N_PTS, 5)))
    fused = fs.matvec_tilde(cols)
    ref = fs.matvec_tilde_reference(cols)
    rel = float(jnp.max(jnp.abs(fused - ref)) / jnp.max(jnp.abs(ref)))
    assert rel < 1e-12, rel
    # batched columns equal the single-RHS fused matvec
    for i in range(5):
        np.testing.assert_allclose(np.asarray(fused[:, i]),
                                   np.asarray(fs.matvec_tilde(cols[:, i])),
                                   rtol=1e-11, atol=1e-11)


@pytest.mark.parametrize("d,tol", [(1, 1e-5), (2, 1e-5), (3, 1e-5)])
def test_fused_matches_dense_oracle(d, tol):
    """End-to-end accuracy against the dense W (same tier as test_fastsum)."""
    kern = make_kernel("gaussian", sigma=3.5)
    pts = _points(d)
    fs = make_fastsum(kern, pts, SETUP_2)
    x = jnp.asarray(RNG.normal(size=(N_PTS,)))
    ref = dense_weight_matrix(kern, pts) @ x
    out = fs.matvec(x)
    rel = float(jnp.max(jnp.abs(out - ref)) / jnp.max(jnp.abs(ref)))
    assert rel < tol, rel


def test_fused_separate_targets_matches_reference():
    kern = make_kernel("gaussian", sigma=3.5)
    pts = _points(3)
    tgt = jnp.asarray(RNG.normal(size=(80, 3)) * 2.0)
    fs = make_fastsum(kern, pts, SETUP_2, target_points=tgt)
    x = jnp.asarray(RNG.normal(size=(N_PTS,)))
    np.testing.assert_allclose(np.asarray(fs.matvec_tilde(x)),
                               np.asarray(fs.matvec_tilde_reference(x)),
                               rtol=1e-11, atol=1e-11)


def test_fused_operator_symmetry():
    """The symmetrized multiplier keeps A = D^-1/2 W D^-1/2 Hermitian."""
    kern = make_kernel("gaussian", sigma=3.5)
    pts = _points(3)
    op = make_normalized_adjacency(kern, pts, SETUP_1)
    x = jnp.asarray(RNG.normal(size=(N_PTS,)))
    y = jnp.asarray(RNG.normal(size=(N_PTS,)))
    lhs = float(jnp.vdot(op.matvec(x), y))
    rhs = float(jnp.vdot(x, op.matvec(y)))
    assert abs(lhs - rhs) / abs(lhs) < 1e-12


# ------------------------------------------------- multiplier / geometry unit
def test_multiplier_support_covers_all_nonzeros():
    """The distributed psum block is exactly the multiplier's support."""
    kern = make_kernel("gaussian", sigma=3.5)
    pts = _points(3)
    fs = make_fastsum(kern, pts, SETUP_1)
    mult = np.asarray(fs.multiplier_half)
    mask = np.zeros_like(mult, dtype=bool)
    sup = np.ix_(*[np.asarray(s) for s in spectral_support(fs.plan)])
    mask[sup] = True
    assert np.all(mult[~mask] == 0.0)
    # and the block is at most ~half the seed's N^d psum payload
    n_bw = fs.plan.n_bandwidth
    assert mask.sum() <= (n_bw + 1) ** 2 * (n_bw // 2 + 1)


def test_multiplier_is_hermitian_half_spectrum():
    """irfftn(sym(C) . rfftn(g)) must equal Re(ifftn(C . fftn(g)))."""
    kern = make_kernel("gaussian", sigma=3.5)
    pts = _points(2)
    fs = make_fastsum(kern, pts, SETUP_1)
    plan = fs.plan
    grid = plan.grid_size
    g = RNG.normal(size=(grid, grid))
    mult_half = np.asarray(fs.multiplier_half)
    out_half = np.fft.irfftn(np.fft.rfftn(g) * mult_half, s=(grid, grid),
                             axes=(0, 1))
    # full-spectrum reference with the *unsymmetrized* embedded multiplier
    phi = np.asarray(plan.deconvolution_grid())
    small = np.asarray(fs.b_hat) / (grid ** 2 * phi * phi)
    emb = np.asarray(jnp.fft.fftfreq(plan.n_bandwidth,
                                     1.0 / plan.n_bandwidth)).astype(int) % grid
    big = np.zeros((grid, grid), dtype=complex)
    big[np.ix_(emb, emb)] = small
    out_full = np.real(np.fft.ifftn(big * np.fft.fftn(g)))
    scale = np.max(np.abs(out_full))
    np.testing.assert_allclose(out_half, out_full, rtol=0, atol=1e-13 * scale)


def test_window_geometry_morton_sorted():
    kern = make_kernel("gaussian", sigma=3.5)
    pts = _points(3)
    fs = make_fastsum(kern, pts, SETUP_1)
    win = fs.src_window
    perm = np.asarray(win.perm)
    assert sorted(perm.tolist()) == list(range(N_PTS))  # a true permutation
    codes = np.asarray(morton_codes(win.base, fs.plan.grid_size))
    assert np.all(np.diff(codes) >= 0)  # rows in Morton order


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_window_spread_gather_adjoint(backend):
    """<gather(g), x> == <g, spread(x)> for the fused window step, on both
    streaming backends."""
    kern = make_kernel("gaussian", sigma=3.5)
    pts = _points(2)
    fs = make_fastsum(kern, pts, SETUP_1)
    plan, win = fs.plan, fs.src_window
    grid = plan.grid_size
    x = jnp.asarray(RNG.normal(size=(N_PTS, 1)))
    g = jnp.asarray(RNG.normal(size=(grid, grid, 1)))
    lhs = float(jnp.vdot(
        fastsum_exec.window_gather(plan, win, g, backend=backend), x))
    rhs = float(jnp.vdot(
        g, fastsum_exec.window_spread(plan, win, x, backend=backend)))
    assert abs(lhs - rhs) / abs(lhs) < 1e-12


# ------------------------------------------------- streaming window backends
@pytest.mark.parametrize("kname,kw", KERNELS)
@pytest.mark.parametrize("d", [1, 2, 3])
def test_pallas_backend_matches_xla(kname, kw, d):
    """Fused matvec parity: streaming pallas (interpret) vs streaming xla,
    all four kernels, d=1..3, single and batched RHS."""
    kern = make_kernel(kname, **kw)
    pts = _points(d, n=150)
    params = FastsumParams(n_bandwidth=16, m=3)
    fs = make_fastsum(kern, pts, params)
    for x in (jnp.asarray(RNG.normal(size=(150,))),
              jnp.asarray(RNG.normal(size=(150, 3)))):
        via_xla = fs.matvec(x, backend="xla")
        via_pallas = fs.matvec(x, backend="pallas")
        rel = float(jnp.max(jnp.abs(via_pallas - via_xla))
                    / jnp.max(jnp.abs(via_xla)))
        assert rel < 1e-10, (kname, d, x.shape, rel)


def test_backend_auto_resolves_and_rejects():
    assert fastsum_exec.resolve_backend(None) in ("xla", "pallas")
    assert fastsum_exec.resolve_backend("auto") == \
        fastsum_exec.resolve_backend(None)
    assert fastsum_exec.resolve_backend("xla") == "xla"
    with pytest.raises(ValueError):
        fastsum_exec.resolve_backend("cuda")


def _lowered_shapes(lowered_text):
    """All tensor element counts appearing in a lowered StableHLO module."""
    import re
    counts = []
    for m in re.finditer(r"tensor<((?:\d+x)+)(?:f|i|u|complex)", lowered_text):
        dims = [int(t) for t in m.group(1).split("x") if t]
        counts.append(int(np.prod(dims)))
    return counts


@pytest.mark.parametrize("d,n", [(2, 4000), (3, 1200)])
def test_xla_window_step_never_materializes_update_cube(d, n):
    """The streaming xla path must stay O(tile * taps^d * C): no buffer of
    the retired whole-window path's (n, taps^d, C) update-cube size may
    appear anywhere in the lowered fused matvec.  ``n`` is chosen above the
    tile size so the cube and the streamed tile differ."""
    kern = make_kernel("gaussian", sigma=3.5)
    pts = _points(d, n=n)
    params = FastsumParams(n_bandwidth=16, m=4)
    fs = make_fastsum(kern, pts, params)
    assert fastsum_exec._xla_node_tile(n, fs.plan.taps, d) < n
    x = jnp.asarray(RNG.normal(size=(n, 2)))
    lowered = jax.jit(
        lambda mult, src, tgt, xx: fastsum_exec.fused_pipeline(
            fs.plan, mult, src, tgt, xx, backend="xla")
    ).lower(fs.multiplier_half, fs.src_window, fs.tgt_window, x)
    cube_elems = n * fs.plan.taps ** d  # x C would be bigger still
    shapes = _lowered_shapes(lowered.as_text())
    assert shapes, "no tensor shapes parsed from the lowered module"
    assert max(shapes) < cube_elems, (
        f"buffer with {max(shapes)} elements >= cube size {cube_elems}")


def test_unsorted_window_geometry_same_result():
    """Morton ordering is an internal layout choice, not a semantic one."""
    kern = make_kernel("gaussian", sigma=3.5)
    pts = _points(2)
    fs = make_fastsum(kern, pts, SETUP_1)
    plan = fs.plan
    # rebuild the geometry unsorted on the same scaled nodes via the perm
    x = jnp.asarray(RNG.normal(size=(N_PTS,)))
    out = fastsum_exec.fused_matvec_tilde(
        plan, fs.multiplier_half, fs.src_window, fs.tgt_window, x)
    ref = fs.matvec_tilde_reference(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-11, atol=1e-11)


# ----------------------------------------------------------- block Lanczos
class TestBlockLanczos:
    @classmethod
    def setup_class(cls):
        pts, _ = spiral(1000, seed=0)
        cls.pts = jnp.asarray(pts)
        cls.kern = make_kernel("gaussian", sigma=3.5)
        cls.a_dense = dense_normalized_adjacency(cls.kern, cls.pts)
        cls.ref = jnp.sort(jnp.linalg.eigvalsh(cls.a_dense))[::-1][:10]

    @pytest.mark.parametrize("setup,eig_tol,block", [
        (SETUP_1, 5e-3, 4),
        (SETUP_2, 5e-8, 4),
        (SETUP_2, 5e-8, 8),
    ])
    def test_fig3_tier_with_fewer_matvecs(self, setup, eig_tol, block):
        """Block Lanczos hits the Fig. 3 accuracy tiers with ~block_size
        fewer operator invocations than scalar Lanczos."""
        op = make_normalized_adjacency(self.kern, self.pts, setup)
        scalar = eigsh(op.matvec, 1000, 10, num_iters=80,
                       key=jax.random.PRNGKey(0))
        blocked = eigsh(op.matvec, 1000, 10, num_iters=80,
                        key=jax.random.PRNGKey(0), block_size=block)
        err = float(jnp.max(jnp.abs(blocked.eigenvalues - self.ref)))
        assert err < eig_tol, err
        assert blocked.num_matvecs < scalar.num_matvecs
        assert blocked.num_matvecs <= -(-80 // block)

    def test_block_residuals(self):
        op = make_normalized_adjacency(self.kern, self.pts, SETUP_2)
        res = eigsh(op.matvec, 1000, 10, num_iters=80,
                    key=jax.random.PRNGKey(0), block_size=4)
        r = (self.a_dense @ res.eigenvectors
             - res.eigenvectors * res.eigenvalues[None, :])
        rn = float(jnp.max(jnp.linalg.norm(r, axis=0)))
        assert rn < 5e-7, rn

    def test_block_matches_dense_eigsh_smallest(self):
        rng = np.random.default_rng(5)
        n = 200
        m = rng.normal(size=(n, n))
        a = jnp.asarray((m + m.T) / 2.0)
        ref = np.sort(np.linalg.eigvalsh(np.asarray(a)))[:4]
        res = eigsh(lambda x: a @ x, n, 4, which="SA", num_iters=160,
                    key=jax.random.PRNGKey(2), block_size=4)
        np.testing.assert_allclose(np.asarray(res.eigenvalues), ref,
                                   rtol=1e-7, atol=1e-7)


# ------------------------------------------------ auto pallas -> xla fallback
def test_auto_pallas_lowering_failure_degrades_to_xla(monkeypatch):
    """backend="auto" resolving to pallas must degrade to xla with ONE
    RuntimeWarning when the kernel fails to lower, and stay degraded
    (sticky) for the rest of the process instead of re-raising per call."""
    from repro.kernels import nfft_window

    kern = make_kernel("gaussian", sigma=3.5)
    pts = _points(2, n=64)
    fs = make_fastsum(kern, pts, FastsumParams(n_bandwidth=16, m=4))
    x = jnp.asarray(RNG.normal(size=(64, 2)))
    plan, geom = fs.plan, fs.src_window

    monkeypatch.setattr(fastsum_exec, "_PALLAS_FALLBACK",
                        {"warned": False, "disabled": False})
    monkeypatch.setattr(fastsum_exec, "resolve_backend",
                        lambda backend: "pallas"
                        if backend in (None, "auto") else backend)

    def boom(*a, **k):
        raise RuntimeError("forced Mosaic lowering failure")

    monkeypatch.setattr(nfft_window, "window_spread", boom)
    monkeypatch.setattr(nfft_window, "window_gather", boom)

    with pytest.warns(RuntimeWarning, match="degrading to the xla"):
        out = fastsum_exec.window_spread(plan, geom, x, backend="auto")
    # the fallback produced the xla result (spread includes fold + roll,
    # so compare end-to-end against an explicit-xla run instead)
    ref = fastsum_exec.window_spread(plan, geom, x, backend="xla")
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    # sticky: later calls skip pallas entirely — no warning, no raise
    assert fastsum_exec._PALLAS_FALLBACK["disabled"]
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error")
        out2 = fastsum_exec.window_spread(plan, geom, x, backend=None)
        g = fastsum_exec.window_gather(plan, geom, ref)
    np.testing.assert_array_equal(np.asarray(out2), np.asarray(ref))
    assert np.all(np.isfinite(np.asarray(g)))


def test_explicit_pallas_lowering_failure_still_raises(monkeypatch):
    """Asking for pallas by name must surface the failure, not degrade."""
    from repro.kernels import nfft_window

    kern = make_kernel("gaussian", sigma=3.5)
    pts = _points(2, n=64)
    fs = make_fastsum(kern, pts, FastsumParams(n_bandwidth=16, m=4))
    x = jnp.asarray(RNG.normal(size=(64, 1)))

    monkeypatch.setattr(fastsum_exec, "_PALLAS_FALLBACK",
                        {"warned": False, "disabled": False})

    def boom(*a, **k):
        raise RuntimeError("forced Mosaic lowering failure")

    monkeypatch.setattr(nfft_window, "window_spread", boom)
    with pytest.raises(RuntimeError, match="forced Mosaic"):
        fastsum_exec.window_spread(fs.plan, fs.src_window, x,
                                   backend="pallas")
