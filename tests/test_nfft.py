"""NFFT forward/adjoint vs. direct NDFT oracles, across dims/windows/batch."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.nfft import (
    GAUSSIAN_WINDOW, KAISER_BESSEL, NfftPlan, build_geometry, ndft_adjoint,
    ndft_forward, nfft_adjoint, nfft_forward,
)

# window accuracy: KB with m taps gives roughly 1e-(m) .. machine precision
TOL = {2: 5e-3, 4: 5e-6, 8: 1e-12}


def _setup(d, m, n=150, N=None, seed=0, window=KAISER_BESSEL):
    N = N or (16 if d == 3 else 32)
    rng = np.random.default_rng(seed)
    plan = NfftPlan(d=d, n_bandwidth=N, m=m, window=window)
    nodes = jnp.asarray(rng.uniform(-0.5, 0.5, size=(n, d)))
    geom = build_geometry(plan, nodes)
    return plan, nodes, geom, rng, N


@pytest.mark.parametrize("d", [1, 2, 3])
@pytest.mark.parametrize("m", [2, 4, 8])
def test_forward_matches_ndft(d, m):
    plan, nodes, geom, rng, N = _setup(d, m)
    fhat = jnp.asarray(rng.normal(size=(N,) * d) + 1j * rng.normal(size=(N,) * d))
    fast = nfft_forward(plan, geom, fhat)
    ref = ndft_forward(N, nodes, fhat)
    rel = float(jnp.max(jnp.abs(fast - ref)) / jnp.max(jnp.abs(ref)))
    assert rel < TOL[m], rel


@pytest.mark.parametrize("d", [1, 2, 3])
@pytest.mark.parametrize("m", [2, 4, 8])
def test_adjoint_matches_ndft(d, m):
    plan, nodes, geom, rng, N = _setup(d, m)
    x = jnp.asarray(rng.normal(size=(nodes.shape[0],)))
    fast = nfft_adjoint(plan, geom, x)
    ref = ndft_adjoint(N, nodes, x)
    rel = float(jnp.max(jnp.abs(fast - ref)) / jnp.max(jnp.abs(ref)))
    assert rel < TOL[m], rel


@pytest.mark.parametrize("d", [1, 2])
def test_exact_adjointness(d):
    """forward and adjoint are exact matrix adjoints (DESIGN.md §3)."""
    plan, nodes, geom, rng, N = _setup(d, m=4)
    fhat = jnp.asarray(rng.normal(size=(N,) * d) + 1j * rng.normal(size=(N,) * d))
    x = jnp.asarray(rng.normal(size=(nodes.shape[0],)) + 1j * rng.normal(size=(nodes.shape[0],)))
    lhs = jnp.vdot(nfft_forward(plan, geom, fhat), x)
    rhs = jnp.vdot(fhat, nfft_adjoint(plan, geom, x))
    assert abs(complex(lhs - rhs)) / abs(complex(lhs)) < 1e-13


def test_batched_columns_match_loop():
    plan, nodes, geom, rng, N = _setup(2, m=4)
    cols = jnp.asarray(rng.normal(size=(nodes.shape[0], 5)))
    batched = nfft_adjoint(plan, geom, cols)
    for i in range(5):
        single = nfft_adjoint(plan, geom, cols[:, i])
        np.testing.assert_allclose(np.asarray(batched[..., i]),
                                   np.asarray(single), rtol=1e-12, atol=1e-12)
    fhat = jnp.asarray(rng.normal(size=(N, N, 5)))
    fb = nfft_forward(plan, geom, fhat.astype(jnp.complex128))
    for i in range(5):
        fs = nfft_forward(plan, geom, fhat[..., i].astype(jnp.complex128))
        np.testing.assert_allclose(np.asarray(fb[:, i]), np.asarray(fs),
                                   rtol=1e-12, atol=1e-12)


def test_gaussian_window_works():
    plan, nodes, geom, rng, N = _setup(2, m=6, window=GAUSSIAN_WINDOW)
    fhat = jnp.asarray(rng.normal(size=(N,) * 2) + 0j)
    fast = nfft_forward(plan, geom, fhat)
    ref = ndft_forward(N, nodes, fhat)
    rel = float(jnp.max(jnp.abs(fast - ref)) / jnp.max(jnp.abs(ref)))
    assert rel < 1e-4, rel  # Gaussian window converges slower than KB


def test_linearity():
    plan, nodes, geom, rng, N = _setup(1, m=4)
    x1 = jnp.asarray(rng.normal(size=(nodes.shape[0],)))
    x2 = jnp.asarray(rng.normal(size=(nodes.shape[0],)))
    a, b = 2.5, -1.25
    lhs = nfft_adjoint(plan, geom, a * x1 + b * x2)
    rhs = a * nfft_adjoint(plan, geom, x1) + b * nfft_adjoint(plan, geom, x2)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                               rtol=1e-12, atol=1e-12)


def test_m8_reaches_double_precision():
    """Paper Figure 1: m=8 gives approximately IEEE double precision."""
    plan, nodes, geom, rng, N = _setup(2, m=8)
    fhat = jnp.asarray(rng.normal(size=(N,) * 2) + 0j)
    fast = nfft_forward(plan, geom, fhat)
    ref = ndft_forward(N, nodes, fhat)
    rel = float(jnp.max(jnp.abs(fast - ref)) / jnp.max(jnp.abs(ref)))
    assert rel < 5e-14, rel
