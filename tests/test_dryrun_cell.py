"""End-to-end dry-run machinery test on a small forced mesh (subprocess).

Exercises launch/steps.py + launch/dryrun.py + the loop-aware analyzer on a
reduced-config train cell with 16 host devices — the same code path the
512-device production dry-run uses, cheap enough for CI.
"""

import os
import subprocess
import sys
import textwrap

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_reduced_cell_lower_compile_roofline():
    code = """
        import dataclasses, jax, jax.numpy as jnp
        from repro.configs import get_config, reduced_config
        from repro.launch import hlo_analysis as H
        from repro.launch.steps import lower_cell
        from repro.training.train_loop import TrainConfig

        cfg = reduced_config(get_config("granite-3-2b"), seq_len=64,
                             global_batch=8)
        # give the smoke config its real shape list entry
        shape = cfg.shapes[0]
        mesh = jax.make_mesh((4, 4), ("data", "model"))
        tc = TrainConfig(num_microbatches=2)
        lowered, kind = lower_cell(cfg, shape, mesh, tc=tc)
        assert kind == "train"
        compiled = lowered.compile()
        ma = compiled.memory_analysis()
        assert ma is not None
        stats = H.analyze(compiled.as_text(), pod_boundary=8)
        # scan over 4 layers x 2 microbatches -> trip counts visible
        assert any(t == 4 for t in stats.while_trip_counts), \\
            stats.while_trip_counts
        assert stats.flops > 0
        assert stats.collective_bytes > 0  # TP/FSDP collectives exist
        print("dryrun cell OK", stats.while_trip_counts,
              f"{stats.flops:.3e}")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    env["PYTHONPATH"] = REPO_SRC
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, timeout=600,
                          env=env)
    assert proc.returncode == 0, \
        f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr[-4000:]}"


def test_graph_cell_pencil_payload_scales_inverse_p():
    """The dry-run pencil cells' per-device collective payload scales ~1/P
    while the psum cells' stays flat (and pencil wins at the larger mesh).

    Lowers the shipped fused matvec body (not the retired seed
    `_spectral_matvec_local`) on 8- and 32-chip meshes via
    `run_graph_cell(..., spectral_mode=...)` — the same code path as the
    512-chip `graph-fastsum-pencil-*` production cells.
    """
    code = """
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro.launch.dryrun import run_graph_cell

        devs = np.array(jax.devices())
        mesh8 = Mesh(devs[:8].reshape(2, 4), ("data", "model"))
        mesh32 = Mesh(devs[:32].reshape(8, 4), ("data", "model"))

        def cell(mesh, mode):
            rec = run_graph_cell(4096, 3, False, setup_name="setup2",
                                 spectral_mode=mode, mesh=mesh)
            assert rec["status"] == "ok", rec.get("error")
            return rec

        psum8, psum32 = cell(mesh8, "psum"), cell(mesh32, "psum")
        pen8, pen32 = cell(mesh8, "pencil"), cell(mesh32, "pencil")
        assert pen32["spectral_mode_effective"] == "pencil", pen32
        pay = lambda r: r["hlo_stats"]["collective_payload_bytes"]
        kinds = lambda r: r["hlo_stats"]["collective_by_kind"]

        # the pencil path is reduce-scatter/all-to-all/all-gather, no psum
        assert "all-reduce" in kinds(psum32), kinds(psum32)
        assert "all-to-all" in kinds(pen32), kinds(pen32)
        assert "reduce-scatter" in kinds(pen32), kinds(pen32)
        assert "all-reduce" not in kinds(pen32), kinds(pen32)

        # psum payload is flat in P; pencil payload drops ~1/P (4x here)
        assert abs(pay(psum8) / pay(psum32) - 1.0) < 0.05, \\
            (pay(psum8), pay(psum32))
        ratio = pay(pen8) / pay(pen32)
        assert 3.0 < ratio < 5.0, (pay(pen8), pay(pen32), ratio)
        # past the crossover the sharded spectrum beats the flat psum
        assert pay(pen32) < 0.6 * pay(psum32), (pay(pen32), pay(psum32))
        print("pencil payload OK",
              pay(psum8), pay(psum32), pay(pen8), pay(pen32))
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=32"
    env["PYTHONPATH"] = REPO_SRC
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, timeout=600,
                          env=env)
    assert proc.returncode == 0, \
        f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr[-4000:]}"


def test_graph_cell_bank_payload_scales_with_s():
    """The bank dry-run cells lower the shipped bank body: the one
    cross-shard collective carries the S stacked channel lanes, so its
    per-device payload is ~S x the matching S=1 cell's — while the cell
    still lowers (and the S=1/S=8 comparison confirms) a single spread +
    forward-FFT stage, not S of them."""
    code = """
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro.launch.dryrun import run_graph_cell

        devs = np.array(jax.devices())
        mesh = Mesh(devs[:8].reshape(2, 4), ("data", "model"))
        for mode in ("psum", "pencil"):
            r1 = run_graph_cell(4096, 3, False, setup_name="setup1",
                                spectral_mode=mode, mesh=mesh, bank_size=1)
            rb = run_graph_cell(4096, 3, False, setup_name="setup1",
                                spectral_mode=mode, mesh=mesh, bank_size=8)
            assert r1["status"] == "ok", r1.get("error")
            assert rb["status"] == "ok", rb.get("error")
            assert rb["bank"] == 8 and "bank8" in rb["arch"], rb["arch"]
            p1 = r1["hlo_stats"]["collective_payload_bytes"]
            pb = rb["hlo_stats"]["collective_payload_bytes"]
            ratio = pb / p1
            assert 7.0 < ratio < 9.0, (mode, p1, pb, ratio)
            print(mode, "bank payload OK", p1, pb)
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = REPO_SRC
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, timeout=600,
                          env=env)
    assert proc.returncode == 0, \
        f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr[-4000:]}"


def test_decode_cell_serve_sharding():
    code = """
        import dataclasses, jax
        from repro.configs import get_config, reduced_config
        from repro.launch.steps import lower_cell, _serve_replicated
        from repro.training.train_loop import TrainConfig

        cfg = reduced_config(get_config("granite-3-2b"), seq_len=64,
                             global_batch=8)
        mesh = jax.make_mesh((4, 4), ("data", "model"))
        assert _serve_replicated(cfg, mesh)  # tiny model: TP-resident
        decode = [s for s in cfg.shapes if s.kind == "decode"
                  and not s.skip_reason][0]
        lowered, kind = lower_cell(cfg, decode, mesh,
                                   tc=TrainConfig(num_microbatches=1))
        assert kind == "decode"
        lowered.compile()
        print("decode cell OK")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    env["PYTHONPATH"] = REPO_SRC
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, timeout=600,
                          env=env)
    assert proc.returncode == 0, \
        f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr[-4000:]}"


def test_graph_serve_cell_lowers_tick_body():
    """The serving-tier dry-run cell lowers the steady-state tick body
    (packed target geometry + ragged column gather) on a forced mesh: it
    compiles, rows pad to shard evenly, and — the serving property — the
    only cross-shard traffic is the O(rows) Morton sort of the packed
    query points themselves (the tiny per-tick working set), never a
    spectrum- or node-count-sized reduction like the training matvec's
    psum: payload stays bounded by a small multiple of the pack size, and
    no all-reduce appears at either pack size."""
    code = """
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro.launch.dryrun import run_graph_serve_cell

        devs = np.array(jax.devices())
        mesh = Mesh(devs[:8].reshape(2, 4), ("data", "model"))
        def cell(chunk):
            rec = run_graph_serve_cell(8, chunk, 3, False,
                                       setup_name="setup1", mesh=mesh)
            assert rec["status"] == "ok", rec.get("error")
            return rec
        rec = cell(100)
        assert rec["kind"] == "graph_serve_tick"
        assert rec["rows"] % 8 == 0 and rec["rows"] >= 800, rec["rows"]
        assert rec["channels"] == 8
        rec2 = cell(200)
        for r in (rec, rec2):
            kinds = r["hlo_stats"]["collective_by_kind"]
            assert "all-reduce" not in kinds, kinds
            pay = r["hlo_stats"]["collective_payload_bytes"]
            # O(rows) working set, never spectrum/node-sized: the
            # distributed sort moves a few hundred bytes/row, orders of
            # magnitude below the training matvec's half-spectrum psum
            assert 0 < pay < 512 * r["rows"], (pay, r["rows"], kinds)
        print("serve cell OK", rec["rows"], rec2["rows"])
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = REPO_SRC
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, timeout=600,
                          env=env)
    assert proc.returncode == 0, \
        f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr[-4000:]}"
