"""Lemma 3.1 — property-based verification + a-posteriori monitor checks."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import SETUP_1, SETUP_2, make_fastsum, make_kernel
from repro.core.error import aposteriori_report, lemma31_bound, normalized_from_dense


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(5, 40),
       scale=st.floats(1e-6, 1e-2))
def test_lemma31_inequality(seed, n, scale):
    """||A - A_E||_inf <= eps(1+eta)/(eta(eta-eps)) for random W, E."""
    rng = np.random.default_rng(seed)
    w = rng.uniform(0.1, 1.0, (n, n))
    w = (w + w.T) / 2.0
    np.fill_diagonal(w, 0.0)
    e = rng.uniform(-1.0, 1.0, (n, n)) * scale
    w_e = w + e

    deg = w.sum(1)
    w_inf = np.abs(w).sum(1).max()
    eta = deg.min() / w_inf
    eps = np.abs(e).sum(1).max() / w_inf
    if eps >= eta:  # lemma precondition
        return
    deg_e = w_e.sum(1)
    if (deg_e <= 0).any():
        return
    a = np.asarray(normalized_from_dense(jnp.asarray(w)))
    a_e = np.asarray(normalized_from_dense(jnp.asarray(w_e)))
    lhs = np.abs(a - a_e).sum(1).max()
    rhs = lemma31_bound(eta, eps)
    assert lhs <= rhs * (1 + 1e-9), (lhs, rhs)


def test_lemma31_bound_diverges_at_eta():
    assert lemma31_bound(0.5, 0.5) == float("inf")
    assert lemma31_bound(0.5, 0.6) == float("inf")
    assert lemma31_bound(0.5, 0.25) > 0


def test_aposteriori_report_on_fastsum():
    """The measured ||A - A_E||_inf obeys the Lemma 3.1 bound computed from
    the measured eta/eps of the actual NFFT fast-summation operator.

    Note: SETUP_1 on sparse-density data can genuinely violate the eps < eta
    precondition (the paper's own caveat, Section 3.1) — the report then
    returns bound = inf, which is also correct behaviour and asserted below.
    """
    rng = np.random.default_rng(3)
    # uniform density keeps d_min (and thus eta) well away from zero
    pts = jnp.asarray(rng.uniform(-5.0, 5.0, size=(200, 3)))
    kern = make_kernel("gaussian", sigma=3.5)
    for setup in (SETUP_1, SETUP_2):
        fs = make_fastsum(kern, pts, setup)
        rep = aposteriori_report(kern, pts, fs)
        assert rep["eps"] < rep["eta"], rep
        assert rep["a_err_inf"] <= rep["bound"] * (1 + 1e-9), rep
    # higher-accuracy setup must give smaller eps
    fs1 = make_fastsum(kern, pts, SETUP_1)
    fs2 = make_fastsum(kern, pts, SETUP_2)
    eps1 = aposteriori_report(kern, pts, fs1)["eps"]
    eps2 = aposteriori_report(kern, pts, fs2)["eps"]
    assert eps2 < eps1


def test_lemma31_precondition_violation_returns_inf():
    """Clustered data + coarse setup: eps >= eta -> bound inf (no guarantee)."""
    rng = np.random.default_rng(4)
    pts = jnp.asarray(np.concatenate([
        rng.normal(size=(100, 3)) * 0.5,
        rng.normal(size=(100, 3)) * 0.5 + 12.0,
    ]))
    kern = make_kernel("gaussian", sigma=1.0)
    fs = make_fastsum(kern, pts, SETUP_1)
    rep = aposteriori_report(kern, pts, fs)
    if rep["eps"] >= rep["eta"]:
        assert rep["bound"] == float("inf")
