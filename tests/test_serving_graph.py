"""Graph-predict serving tier: correctness, continuous batching, zero-replan
steady state, multi-tenant grid sharing, admission control.

Small models (n=300, d=2, n_bandwidth=64) keep the suite tier-1 fast while
the NFFT prediction error stays ~1e-4, far below the assertion tolerances.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FastsumParams, make_kernel
from repro.graph import krr_fit, krr_predict_direct
from repro.serving import GraphModelRegistry, GraphServeEngine, PredictRequest

PARAMS = FastsumParams(n_bandwidth=64, m=4)
TOL = 1e-3  # NFFT prediction error at these settings is ~1e-4


@pytest.fixture(scope="module")
def models():
    rng = np.random.default_rng(11)
    xtr = jnp.asarray(rng.uniform(-3, 3, (300, 2)))
    ytr = jnp.asarray(np.sign(rng.standard_normal(300)))
    # two tenants sharing train points (one group, bank-shared transform) …
    m_a = krr_fit(make_kernel("gaussian", sigma=1.0), xtr, ytr, 1e-2, PARAMS)
    m_b = krr_fit(make_kernel("gaussian", sigma=1.5), xtr, ytr, 1e-2, PARAMS)
    # … and one on different train points (its own group)
    xtr2 = jnp.asarray(rng.uniform(-3, 3, (200, 2)))
    ytr2 = jnp.asarray(np.sign(rng.standard_normal(200)))
    m_c = krr_fit(make_kernel("gaussian", sigma=1.2), xtr2, ytr2, 1e-2,
                  PARAMS)
    return {"a": m_a, "b": m_b, "c": m_c}


@pytest.fixture()
def registry(models):
    reg = GraphModelRegistry()
    for mid, model in models.items():
        reg.register(mid, model)
    return reg


def _submit(engine, uid, mid, q, rhs=None):
    req = PredictRequest(uid=uid, model_id=mid, query_points=np.asarray(q),
                         rhs=None if rhs is None else np.asarray(rhs))
    engine.submit(req)
    return req


def test_engine_matches_direct_oracle(models, registry):
    """Batched, chunked, multi-tenant predictions == dense oracle, including
    custom per-request dual vectors and requests spanning several ticks."""
    rng = np.random.default_rng(0)
    engine = GraphServeEngine(registry, slots=3, chunk=16)
    reqs = []
    for i, mid in enumerate(["a", "b", "c", "a", "b", "c", "a"]):
        m = int(rng.integers(5, 60))  # some span 4 ticks at chunk=16
        q = rng.uniform(-2.5, 2.5, (m, 2))
        rhs = None
        if i == 3:  # a custom dual vector on model "a"
            rhs = rng.standard_normal(
                models[mid].train_points.shape[0])
        reqs.append((_submit(engine, i, mid, q, rhs), mid, rhs))
    engine.run_until_drained()
    for req, mid, rhs in reqs:
        assert req.done and req.error is None, (req.uid, req.error)
        model = models[mid]
        if rhs is not None:
            model = model._replace(alpha=jnp.asarray(rhs))
        ref = np.asarray(
            krr_predict_direct(model, jnp.asarray(req.query_points)))
        np.testing.assert_allclose(req.output, ref, atol=TOL)


def test_zero_replans_in_steady_state(models, registry):
    """The acceptance-criterion counter test: after the warmup tick builds
    the (model, alpha) grids, a steady stream of requests with FRESH query
    arrays every tick triggers zero plan/multiplier/grid builds — only the
    O(m) per-tick target geometry and the packed gather run."""
    rng = np.random.default_rng(1)
    engine = GraphServeEngine(registry, slots=4, chunk=32)
    # warmup: one wave touching both tenants of the shared group
    for i, mid in enumerate(["a", "b"]):
        _submit(engine, i, mid, rng.uniform(-2, 2, (20, 2)))
    engine.run_until_drained()
    warm = registry.stats()
    assert warm["grid_builds"] == 2  # one per (model, alpha) column
    assert warm["bank_transforms"] == 1  # both built by ONE bank transform

    # steady state: 6 waves of brand-new query arrays
    uid = 10
    for _ in range(6):
        reqs = [_submit(engine, uid + k, mid,
                        rng.uniform(-2, 2, (25, 2)))
                for k, mid in enumerate(["a", "b", "a"])]
        uid += len(reqs)
        engine.run_until_drained()
        assert all(r.done and r.error is None for r in reqs)
    steady = registry.stats()
    assert steady["plan_builds"] == warm["plan_builds"]
    assert steady["multiplier_builds"] == warm["multiplier_builds"]
    assert steady["grid_builds"] == warm["grid_builds"]  # ZERO replans
    assert steady["grid_hits"] > warm["grid_hits"]  # traffic was served


def test_slot_recycling_never_drains(models, registry):
    """More requests than slots: recycled slots are refilled the same tick
    (occupancy stays at capacity while the queue is non-empty), and every
    request is eventually served correctly."""
    rng = np.random.default_rng(2)
    engine = GraphServeEngine(registry, slots=2, chunk=8)
    # short and long requests interleaved through the same two slots
    lengths = [4, 40, 6, 30, 5, 20]
    reqs = [_submit(engine, i, "a", rng.uniform(-2, 2, (m, 2)))
            for i, m in enumerate(lengths)]
    engine.run_until_drained()
    assert all(r.done and r.error is None for r in reqs)
    for r in reqs:
        ref = np.asarray(
            krr_predict_direct(models["a"], jnp.asarray(r.query_points)))
        np.testing.assert_allclose(r.output, ref, atol=TOL)
    # while work remained, every tick ran with both slots occupied
    busy = [t for t in engine.tick_log if t.queue_depth > 0]
    assert busy and all(t.occupancy == 2 for t in busy)


def test_out_of_domain_request_rejected(registry):
    """Query points outside the registered serving domain would wrap around
    the NFFT torus and produce garbage — the engine fails the request
    instead of serving wrong values."""
    engine = GraphServeEngine(registry, slots=2, chunk=8)
    bad = _submit(engine, 0, "a", np.full((3, 2), 50.0))
    unknown = _submit(engine, 1, "nope", np.zeros((3, 2)))
    wrong_d = _submit(engine, 2, "a", np.zeros((3, 5)))
    engine.step()
    assert bad.done and "domain" in bad.error
    assert unknown.done and "unknown model_id" in unknown.error
    assert wrong_d.done and "does not match" in wrong_d.error
    assert engine.counters["rejected"] == 3


def test_tick_stats_observability(models, registry):
    """Queue depth / occupancy / rows counters describe the tick loop."""
    rng = np.random.default_rng(3)
    engine = GraphServeEngine(registry, slots=2, chunk=8)
    reqs = [_submit(engine, i, "a", rng.uniform(-2, 2, (8, 2)))
            for i in range(4)]
    s1 = engine.step()
    assert s1.occupancy <= 2 and s1.queue_depth == 2
    assert s1.rows == 16  # two slots x one full chunk
    engine.run_until_drained()
    assert all(r.done for r in reqs)
    assert engine.counters["rows"] == sum(
        r.query_points.shape[0] for r in reqs)
    assert engine.counters["finished"] == 4
    # per-request latency is recorded
    assert all(r.latency > 0 for r in reqs)


def test_custom_rhs_grid_cache_reuse(models, registry):
    """A repeated custom dual vector hits the grid cache (content-keyed):
    the second wave with byte-identical rhs builds nothing new."""
    rng = np.random.default_rng(4)
    engine = GraphServeEngine(registry, slots=2, chunk=32)
    rhs = rng.standard_normal(models["a"].train_points.shape[0])
    _submit(engine, 0, "a", rng.uniform(-2, 2, (10, 2)), rhs)
    engine.run_until_drained()
    builds = registry.stats()["grid_builds"]
    # round-tripped copy of the same rhs: content key -> cache hit
    _submit(engine, 1, "a", rng.uniform(-2, 2, (12, 2)), rhs.copy())
    engine.run_until_drained()
    assert registry.stats()["grid_builds"] == builds
