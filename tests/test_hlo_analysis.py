"""Loop-aware HLO analyzer tests: synthetic module + real compiled programs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_analysis as H

SYNTH = """\
HloModule test, num_partitions=4

%cond.1 (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %bound = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %bound), direction=LT
}

%body.1 (p2: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p2 = (s32[], f32[8,8]) parameter(0)
  %j = s32[] get-tuple-element(%p2), index=0
  %one = s32[] constant(1)
  %next = s32[] add(%j, %one)
  %x = f32[8,8] get-tuple-element(%p2), index=1
  %d = f32[8,8] dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8] all-reduce(%d), replica_groups={{0,1,2,3}}, to_apply=%sum.1
  ROOT %t = (s32[], f32[8,8]) tuple(%next, %ar)
}

%sum.1 (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (arg: f32[8,8]) -> f32[8,8] {
  %arg = f32[8,8] parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,8]) tuple(%zero, %arg)
  %w = (s32[], f32[8,8]) while(%init), condition=%cond.1, body=%body.1
  %res = f32[8,8] get-tuple-element(%w), index=1
  %ag = f32[32,8] all-gather(%res), replica_groups={{0,1,2,3}}, dimensions={0}
  %sl = f32[8,8] slice(%ag), slice={[0:8], [0:8]}
  ROOT %out = f32[8,8] copy(%sl)
}
"""


def test_synthetic_module_trip_counts_and_flops():
    stats = H.analyze(SYNTH, pod_boundary=2)
    # one while with trip count 10; dot inside: 2*8*8*8 = 1024 flops x 10
    assert stats.while_trip_counts == [10]
    assert stats.flops == pytest.approx(1024 * 10)
    # all-reduce inside loop: 2 * 256 bytes * 10; all-gather outside: 1024B
    ar = stats.collective_by_kind["all-reduce"]
    ag = stats.collective_by_kind["all-gather"]
    assert ar == pytest.approx(2 * 8 * 8 * 4 * 10)
    assert ag == pytest.approx(32 * 8 * 4)
    # replica group {0,1,2,3} crosses pod boundary 2
    assert stats.dci_bytes == pytest.approx(ar + ag)


def test_real_compiled_loop_flops():
    """Compile an actual lax.fori_loop matmul chain; analyzer must multiply
    the body flops by the trip count."""
    n, trips = 64, 7

    def f(x):
        return jax.lax.fori_loop(0, trips, lambda i, a: a @ a_const, x)

    a_const = jnp.eye(n, dtype=jnp.float32)
    compiled = jax.jit(f).lower(
        jax.ShapeDtypeStruct((n, n), jnp.float32)).compile()
    stats = H.analyze(compiled.as_text())
    expected = 2.0 * n * n * n * trips
    assert stats.flops == pytest.approx(expected, rel=0.01), \
        (stats.flops, expected, stats.while_trip_counts)


def test_real_scan_with_stacked_params():
    """lax.scan over stacked weights — the dominant dry-run pattern."""
    layers, n = 5, 32
    ws = jnp.ones((layers, n, n), jnp.float32)

    def f(x, ws):
        def body(carry, w):
            return carry @ w, None
        out, _ = jax.lax.scan(body, x, ws)
        return out

    compiled = jax.jit(f).lower(
        jax.ShapeDtypeStruct((n, n), jnp.float32),
        jax.ShapeDtypeStruct((layers, n, n), jnp.float32)).compile()
    stats = H.analyze(compiled.as_text())
    expected = 2.0 * n * n * n * layers
    assert stats.flops == pytest.approx(expected, rel=0.01), \
        (stats.flops, expected)
    # slice-aware memory: the fusion that dynamic-slices one layer's weight
    # out of the stacked array must be charged the SLICE (n*n), not the full
    # (layers,n,n) stack, per iteration.
    comps, by_name, entry = H.parse_module(compiled.as_text())
    H.assign_multipliers(comps, entry)
    slice_bytes = n * n * 4
    stack_bytes = layers * slice_bytes
    found = False
    for comp in comps.values():
        for ins in comp.instructions:
            if ins.opcode != "fusion" or "dynamic-slice" not in ins.line:
                continue
            traffic = H._fusion_traffic(ins, comps, by_name)
            assert traffic <= 3 * slice_bytes, (traffic, stack_bytes)
            found = True
    assert found, "no dynamic-slice fusion located"
    # and the total stays far below slice-unaware accounting, which would
    # add ~stack_bytes per iteration on top of the working set
    working_set = 6 * slice_bytes * layers  # slice r/w + dot opnds + copies
    assert stats.hbm_bytes < working_set + 0.5 * layers * stack_bytes, \
        stats.hbm_bytes


def test_dtype_bytes_table():
    assert H._token_bytes("bf16", "4,4") == 32
    assert H._token_bytes("f32", "") == 4
    assert H._token_bytes("pred", "10") == 10


def test_collective_parse_iota_groups():
    # [16,32]<=[512]: consecutive groups of 32 — none mixes ids across 256
    line = ("%ag = f32[64]{0} all-gather(%x), channel_id=1, "
            "replica_groups=[16,32]<=[512], dimensions={0}")
    assert H._crosses_pod(line, 256) is False
    # transposed iota: group members stride 32 (0,32,...,480) — crosses
    line2 = ("%ag = f32[64]{0} all-gather(%x), channel_id=1, "
             "replica_groups=[32,16]<=[16,32]T(1,0), dimensions={0}")
    assert H._crosses_pod(line2, 256) is True
    # whole-mesh group crosses by definition
    line3 = ("%ar = f32[64]{0} all-reduce(%x), "
             "replica_groups=[1,512]<=[512], to_apply=%add")
    assert H._crosses_pod(line3, 256) is True
