"""Pencil-decomposed distributed FFT + spectral-mode parity (multidevice).

Subprocess tests (see tests/test_distributed.py for why): 8 forced host
devices, float64 so the <= 1e-10 parity bound against the single-device
fused matvec is meaningful.

Covers the PR-4 acceptance matrix: d = 2 and d = 3, single and batched
(n, C) RHS, ghost-node padding (n % P != 0), in *both* spectral modes
("psum" and "pencil"), the two-group (row x col) pencil split, and
adjoint/roundtrip/parity identities for pencil_rfftn / pencil_irfftn.
"""

import pytest

from test_distributed import run_in_subprocess

pytestmark = pytest.mark.multidevice


def test_pencil_matvec_matches_single_device():
    """distributed_matvec_fn parity vs op.matvec, both modes, d=2/3,
    single + batched RHS, n not divisible by the shard count."""
    run_in_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import SETUP_1, SETUP_2, make_fastsum, make_kernel
        from repro.data.synthetic import spiral
        from repro.dist.fastsum_dist import distributed_matvec_fn

        assert jax.config.jax_enable_x64
        rng = np.random.default_rng(0)
        n = 4099  # 4099 % 8 != 0 -> ghost-node padding in play
        mesh = jax.make_mesh((8,), ("data",))
        for d, setup in ((3, SETUP_1), (2, SETUP_2)):
            pts = (spiral(n, seed=3)[0] if d == 3
                   else rng.uniform(-3, 3, (n, 2)))
            op = make_fastsum(make_kernel("gaussian", sigma=3.5),
                              jnp.asarray(pts, jnp.float64), setup)
            for mode in ("psum", "pencil"):
                mv = distributed_matvec_fn(op, mesh, ("data",),
                                           spectral_mode=mode)
                for shape in ((n,), (n, 3)):
                    x = jnp.asarray(rng.standard_normal(shape))
                    ref = op.matvec(x)
                    err = float(jnp.max(jnp.abs(mv(x) - ref)) /
                                jnp.max(jnp.abs(ref)))
                    assert err < 1e-10, (d, mode, shape, err)
        print("pencil/psum matvec parity OK")
    """, x64=True)


def test_pencil_two_group_split():
    """Row x col pencil (the past-64-devices layout): grid axis 0 sharded
    over one mesh axis, the rfft axis over the other."""
    run_in_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import SETUP_1, make_fastsum, make_kernel
        from repro.data.synthetic import spiral
        from repro.dist.fastsum_dist import distributed_matvec_fn
        from repro.dist.pencil_fft import make_pencil_spec

        n = 2053
        pts, _ = spiral(n, seed=5)
        op = make_fastsum(make_kernel("gaussian", sigma=3.5),
                          jnp.asarray(pts, jnp.float64), SETUP_1)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        spec = make_pencil_spec(mesh, ("data", "model"), op.plan.grid_size,
                                3, pencil_axes=(("data",), ("model",)))
        assert spec.row_size == 4 and spec.col_size == 2, spec
        mv = distributed_matvec_fn(op, mesh, ("data", "model"),
                                   spectral_mode="pencil",
                                   pencil_axes=(("data",), ("model",)))
        rng = np.random.default_rng(1)
        for shape in ((n,), (n, 2)):
            x = jnp.asarray(rng.standard_normal(shape))
            ref = op.matvec(x)
            err = float(jnp.max(jnp.abs(mv(x) - ref)) /
                        jnp.max(jnp.abs(ref)))
            assert err < 1e-10, (shape, err)
        print("two-group pencil OK")
    """, x64=True)


def test_pencil_rfftn_adjoint_roundtrip_parity():
    """pencil_rfftn/pencil_irfftn: parity vs jnp.fft.rfftn, exact
    roundtrip, and adjointness (symmetry of the multiplier sandwich)."""
    run_in_subprocess("""
        import functools, jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.core.fastsum import SETUP_1
        from repro.core.fastsum_exec import fused_spectral_multiplier
        from repro.dist import pencil_fft
        from repro.dist.compat import shard_map

        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        for d in (2, 3):
            plan = SETUP_1.nfft_plan(d)
            grid, half = plan.grid_size, plan.grid_size // 2 + 1
            spec = pencil_fft.make_pencil_spec(mesh, ("data",), grid, d)
            assert spec.row_size == 8
            # radial (even) coefficients, like every production kernel's
            # b_hat: evenness is what makes the multiplier sandwich a
            # symmetric operator (the property the adjoint check asserts)
            freqs = jnp.fft.fftfreq(plan.n_bandwidth,
                                    d=1.0 / plan.n_bandwidth)
            k2 = sum(jnp.meshgrid(*([freqs ** 2] * d), indexing="ij"))
            b_hat = jnp.exp(-k2 / 7.0).astype(complex)
            mult = fused_spectral_multiplier(plan, b_hat)
            x = jnp.asarray(rng.standard_normal((grid,) * d + (1,)))
            y = jnp.asarray(rng.standard_normal((grid,) * d + (1,)))

            @functools.partial(shard_map, mesh=mesh,
                               in_specs=(P(), P(), P()),
                               out_specs=(P(), P(), P()),
                               check_rep=False)
            def run(mult_, x_, y_):
                rows = grid // spec.row_size
                r = pencil_fft.group_index(spec.row_axes, spec.row_sizes)
                sl = lambda v: jax.lax.dynamic_slice_in_dim(
                    v, r * rows, rows, axis=0)
                fwd = pencil_fft.pencil_rfftn(sl(x_), spec)
                # roundtrip on the pencil (worst error across all shards)
                rt_err = jax.lax.pmax(jnp.max(jnp.abs(
                    pencil_fft.pencil_irfftn(fwd, spec) - sl(x_))),
                    spec.row_axes)
                # parity: reassemble the (padded) half-spectrum
                gather_ax = 1
                full = jax.lax.all_gather(fwd, spec.row_axes, axis=gather_ax,
                                          tiled=True)
                if d == 2:
                    full = full[:, :half]
                par_err = jnp.max(jnp.abs(
                    full - jnp.fft.rfftn(x_, axes=tuple(range(d)))))
                # adjointness: S = irfftn . mult . rfftn is symmetric for the
                # Hermitian-symmetrized production multiplier
                slab = pencil_fft.multiplier_slab(mult_, spec)

                def s_op(v):
                    gh = pencil_fft.pencil_rfftn(sl(v), spec)
                    out = pencil_fft.pencil_irfftn(
                        gh * slab.astype(gh.dtype)[..., None], spec)
                    return jax.lax.all_gather(out, spec.row_axes, axis=0,
                                              tiled=True)

                lhs = jnp.vdot(y_, s_op(x_))
                adj_err = (jnp.abs(lhs - jnp.vdot(x_, s_op(y_)))
                           / jnp.maximum(jnp.abs(lhs), 1.0))
                scale = jnp.maximum(jnp.max(jnp.abs(full)), 1.0)
                return (rt_err[None], par_err[None] / scale, adj_err[None])

            rt, par, adj = (float(v[0]) for v in run(mult, x, y))
            assert rt < 1e-12, (d, rt)
            assert par < 1e-12, (d, par)
            assert adj < 1e-12, (d, adj)
        print("pencil fft adjoint/roundtrip/parity OK")
    """, x64=True)
