"""Durable Krylov execution: kill-and-resume determinism (chaos suite).

The acceptance contract: a ``resumable_solve`` / ``resumable_eigsh`` killed
at a random iteration by a faultinject kill-point and resumed from its
latest snapshot produces results *bit-identical* to an uninterrupted run
(the loop bodies are deterministic functions of the checkpointed state
pytree, and segmenting the loop does not change the body sequence).
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.lanczos import eigsh
from repro.core.solvers import cg, cg_bank, minres
from repro.runtime import (
    DurablePolicy, KillPoint, KillSchedule, Preemption, resumable_eigsh,
    resumable_solve,
)

pytestmark = pytest.mark.chaos


@pytest.fixture(scope="module")
def spd():
    rng = np.random.default_rng(0)
    n, c = 48, 2
    a = rng.standard_normal((n, n))
    a = jnp.asarray(a @ a.T + n * np.eye(n))
    b = jnp.asarray(rng.standard_normal((n, c)))
    return a, b


def _mv(a):
    return lambda x: a @ x


POLICY = DurablePolicy(snapshot_every=5)


@pytest.mark.parametrize("kill_at", [3, 7, 12])
def test_cg_kill_and_resume_bit_identical(tmp_path, spd, kill_at):
    a, b = spd
    ref = cg(_mv(a), b, tol=1e-10, maxiter=100)
    sol, rep = resumable_solve(
        _mv(a), b, ckpt_dir=str(tmp_path), tol=1e-10, maxiter=100,
        policy=POLICY, fault_hook=KillPoint(at_iteration=kill_at))
    assert rep.restarts == 1
    np.testing.assert_array_equal(np.asarray(sol.x), np.asarray(ref.x))
    assert int(np.max(np.asarray(sol.num_iters))) == int(np.max(np.asarray(ref.num_iters)))


def test_minres_kill_and_resume_bit_identical(tmp_path, spd):
    a, b = spd
    ref = minres(_mv(a), b, tol=1e-10, maxiter=100)
    sol, rep = resumable_solve(
        _mv(a), b, ckpt_dir=str(tmp_path), method="minres", tol=1e-10,
        maxiter=100, policy=POLICY, fault_hook=KillPoint(at_iteration=8))
    assert rep.restarts == 1
    np.testing.assert_array_equal(np.asarray(sol.x), np.asarray(ref.x))


def test_cg_cross_process_resume(tmp_path, spd):
    """max_restarts=0 turns the injected kill into a real process death;
    invoking the same solve again must resume from the snapshot (not
    iteration 0) and still match the uninterrupted run exactly."""
    a, b = spd
    ref = cg(_mv(a), b, tol=1e-10, maxiter=100)
    with pytest.raises(Preemption):
        resumable_solve(
            _mv(a), b, ckpt_dir=str(tmp_path), tol=1e-10, maxiter=100,
            policy=DurablePolicy(snapshot_every=5, max_restarts=0),
            fault_hook=KillPoint(at_iteration=12))
    sol, rep = resumable_solve(
        _mv(a), b, ckpt_dir=str(tmp_path), tol=1e-10, maxiter=100,
        policy=POLICY)
    assert rep.resumed_from is not None and rep.resumed_from >= 5
    np.testing.assert_array_equal(np.asarray(sol.x), np.asarray(ref.x))


def test_preemption_storm_backoff_and_cap(tmp_path, spd):
    """A storm of kills is absorbed up to max_restarts (with backoff), and
    one kill beyond the cap propagates."""
    a, b = spd
    ref = cg(_mv(a), b, tol=1e-10, maxiter=100)
    storm = KillSchedule(at_iterations=(3, 8, 12))
    sol, rep = resumable_solve(
        _mv(a), b, ckpt_dir=str(tmp_path / "ok"), tol=1e-10, maxiter=100,
        policy=DurablePolicy(snapshot_every=5, max_restarts=3,
                             backoff_base_s=1e-3),
        fault_hook=storm)
    assert rep.restarts == 3
    np.testing.assert_array_equal(np.asarray(sol.x), np.asarray(ref.x))
    with pytest.raises(Preemption):
        resumable_solve(
            _mv(a), b, ckpt_dir=str(tmp_path / "cap"), tol=1e-10,
            maxiter=100,
            policy=DurablePolicy(snapshot_every=5, max_restarts=2),
            fault_hook=KillSchedule(at_iterations=(3, 8, 12)))


def test_bank_kill_and_resume(tmp_path, spd):
    a, b = spd
    n = a.shape[0]
    shifts = jnp.asarray([0.5, 2.0])
    bank_mv = lambda xb: (jnp.einsum("ij,sjc->sic", a, xb)
                          + shifts[:, None, None] * xb)
    bb = jnp.stack([b, 2.0 * b])  # (S, n, C)
    ref = cg_bank(bank_mv, bb, tol=1e-10, maxiter=100)
    sol, rep = resumable_solve(
        bank_mv, bb, ckpt_dir=str(tmp_path), bank=True, tol=1e-10,
        maxiter=100, policy=POLICY, fault_hook=KillPoint(at_iteration=9))
    assert rep.restarts == 1
    np.testing.assert_array_equal(np.asarray(sol.x), np.asarray(ref.x))


@pytest.mark.parametrize("block_size,kill_at", [(1, 11), (2, 6)])
def test_eigsh_kill_and_resume_bit_identical(tmp_path, spd, block_size,
                                             kill_at):
    a, _ = spd
    n = a.shape[0]
    key = jax.random.PRNGKey(3)
    ref = eigsh(_mv(a), n, 4, key=key, num_iters=30, block_size=block_size)
    res, rep = resumable_eigsh(
        _mv(a), n, 4, ckpt_dir=str(tmp_path), key=key, num_iters=30,
        block_size=block_size, policy=POLICY,
        fault_hook=KillPoint(at_iteration=kill_at))
    assert rep.restarts == 1
    np.testing.assert_array_equal(np.asarray(res.eigenvalues),
                                  np.asarray(ref.eigenvalues))
    np.testing.assert_array_equal(np.asarray(res.eigenvectors),
                                  np.asarray(ref.eigenvectors))


def test_eigsh_cross_process_resume(tmp_path, spd):
    a, _ = spd
    n = a.shape[0]
    key = jax.random.PRNGKey(5)
    ref = eigsh(_mv(a), n, 3, key=key, num_iters=30)
    with pytest.raises(Preemption):
        resumable_eigsh(
            _mv(a), n, 3, ckpt_dir=str(tmp_path), key=key, num_iters=30,
            policy=DurablePolicy(snapshot_every=6, max_restarts=0),
            fault_hook=KillPoint(at_iteration=14))
    res, rep = resumable_eigsh(
        _mv(a), n, 3, ckpt_dir=str(tmp_path), key=key, num_iters=30,
        policy=DurablePolicy(snapshot_every=6))
    assert rep.resumed_from is not None and rep.resumed_from >= 6
    np.testing.assert_array_equal(np.asarray(res.eigenvalues),
                                  np.asarray(ref.eigenvalues))


def test_stale_foreign_snapshot_is_rejected(tmp_path, spd):
    """A ckpt_dir holding snapshots from a *different* problem must not be
    restored into this solve: the checkpoint validators reject the mismatch
    and the solve starts fresh — and still gets the right answer."""
    a, b = spd
    other = jnp.asarray(np.eye(12) * 3.0)
    resumable_solve(_mv(other), jnp.ones((12, 1)), ckpt_dir=str(tmp_path),
                    tol=1e-10, maxiter=50, policy=POLICY)
    ref = cg(_mv(a), b, tol=1e-10, maxiter=100)
    sol, rep = resumable_solve(
        _mv(a), b, ckpt_dir=str(tmp_path), tol=1e-10, maxiter=100,
        policy=POLICY)
    assert rep.resumed_from is None  # foreign snapshots were not usable
    np.testing.assert_array_equal(np.asarray(sol.x), np.asarray(ref.x))


def test_uninterrupted_durable_solve_matches_plain(tmp_path, spd):
    """With no faults at all, the segmented durable path is the plain
    solver: identical solution, identical iteration count."""
    a, b = spd
    ref = cg(_mv(a), b, tol=1e-10, maxiter=100)
    sol, rep = resumable_solve(
        _mv(a), b, ckpt_dir=str(tmp_path), tol=1e-10, maxiter=100,
        policy=POLICY)
    assert rep.restarts == 0 and rep.snapshots >= 1
    np.testing.assert_array_equal(np.asarray(sol.x), np.asarray(ref.x))
    assert int(np.max(np.asarray(sol.num_iters))) == int(np.max(np.asarray(ref.num_iters)))
