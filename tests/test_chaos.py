"""Fault-injection (chaos) suite: seeded failures through solve + serve.

Every test drives the stack through a :mod:`repro.runtime.faultinject`
injector and asserts three things: the fault is *detected* (health flags /
counters / request errors), its blast radius is *contained* (siblings,
other tenants, and later traffic are unaffected), and the system
*recovers* (clean state is rebuilt from the source of truth).  Correct
outputs are always asserted against dense oracles — a guard that silently
serves wrong values is worse than no guard.

Marked ``chaos``: CI runs this file as its own job (``pytest -m chaos``);
it also rides the default tier-1 run.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FastsumParams, cg, cg_bank, eigsh, fused_spectral_multiplier,
    make_fastsum, make_kernel, minres,
)
from repro.core import fastsum_exec
from repro.graph import krr_fit, krr_predict_direct
from repro.runtime import (
    TickChaos, corrupt_group_plan, poison_bank_member, poison_columns,
    poison_registry_grids,
)
from repro.serving import GraphModelRegistry, GraphServeEngine, PredictRequest

pytestmark = pytest.mark.chaos

PARAMS = FastsumParams(n_bandwidth=64, m=4)
TOL = 1e-3  # NFFT prediction error at these settings is ~1e-4


# ---------------------------------------------------------------------------
# Solver-side chaos
# ---------------------------------------------------------------------------

def _spd(n, seed=0):
    rng = np.random.default_rng(seed)
    m = rng.normal(size=(n, n))
    return jnp.asarray(m @ m.T + n * np.eye(n))


@pytest.mark.parametrize("solver", [cg, minres])
def test_poisoned_column_quarantined_not_contagious(solver):
    """A per-column NaN operator fault must quarantine exactly that column
    (health.nonfinite) while lockstep siblings converge to the oracle."""
    a = _spd(80, seed=1)
    mv = poison_columns(lambda x: a @ x, [1])
    b = np.random.default_rng(2).normal(size=(80, 3))
    sol = solver(mv, jnp.asarray(b), tol=1e-10, maxiter=2000)
    h = sol.health
    assert list(np.asarray(h.nonfinite)) == [False, True, False]
    assert not np.any(np.asarray(h.rhs_nonfinite))
    assert int(np.asarray(h.breakdown_iter)[1]) == 0  # caught immediately
    for c in (0, 2):
        assert bool(np.asarray(sol.converged)[c])
        ref = np.linalg.solve(np.asarray(a), b[:, c])
        np.testing.assert_allclose(np.asarray(sol.x[:, c]), ref,
                                   rtol=1e-7, atol=1e-7)
    # the poisoned column froze at its (finite) initial state
    assert not bool(np.asarray(sol.converged)[1])
    assert np.all(np.isfinite(np.asarray(sol.x)))


def test_poisoned_bank_member_isolated_in_bank_solve():
    """One bad tenant's operator in a lockstep bank sweep: all its columns
    quarantined, sibling *systems* untouched."""
    mats = [_spd(50, seed=s) for s in (3, 4, 5)]
    stack = jnp.stack(mats)

    def bank_mv(xb):  # (S, n, C) -> (S, n, C)
        return jnp.einsum("sij,sjc->sic", stack, xb)

    mv = poison_bank_member(bank_mv, [1])
    b = np.random.default_rng(6).normal(size=(3, 50, 2))
    sol = cg_bank(mv, jnp.asarray(b), tol=1e-10, maxiter=2000)
    h = sol.health
    assert h.nonfinite.shape == (3, 2)
    assert np.all(np.asarray(h.nonfinite)[1])
    assert not np.any(np.asarray(h.nonfinite)[[0, 2]])
    for s in (0, 2):
        for c in range(2):
            ref = np.linalg.solve(np.asarray(mats[s]), b[s, :, c])
            np.testing.assert_allclose(np.asarray(sol.x[s, :, c]), ref,
                                       rtol=1e-7, atol=1e-7)
    assert np.all(np.isfinite(np.asarray(sol.x)))


def test_eigsh_poisoned_operator_flagged_not_trusted():
    """A fully poisoned operator: eigsh returns finite sentinel values but
    flags health.nonfinite with inf residual bounds — detectably broken,
    never NaN-silent."""
    res = eigsh(lambda x: jnp.full_like(x, jnp.nan), n=40, k=3,
                num_iters=20)
    assert bool(np.asarray(res.health.nonfinite))
    assert int(np.asarray(res.health.breakdown_iter)) == 0
    assert np.all(np.isinf(np.asarray(res.residual_bounds)))
    assert np.all(np.isfinite(np.asarray(res.eigenvalues)))


def test_grid_hook_is_the_fault_seam():
    """``fused_pipeline(grid_hook=...)``: identity hook changes nothing;
    a poisoning hook propagates NaN to the output (which the serving
    guard then catches)."""
    rng = np.random.default_rng(8)
    pts = jnp.asarray(rng.normal(size=(100, 2)))
    fs = make_fastsum(make_kernel("gaussian", sigma=3.5), pts,
                      FastsumParams(n_bandwidth=16, m=4))
    mult = fused_spectral_multiplier(fs.plan, fs.b_hat)
    x = jnp.asarray(rng.normal(size=(100,)))
    base = fastsum_exec.fused_pipeline(fs.plan, mult, fs.src_window,
                                       fs.src_window, x)
    same = fastsum_exec.fused_pipeline(fs.plan, mult, fs.src_window,
                                       fs.src_window, x,
                                       grid_hook=lambda g: g)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(same))
    bad = fastsum_exec.fused_pipeline(
        fs.plan, mult, fs.src_window, fs.src_window, x,
        grid_hook=lambda g: jnp.full_like(g, jnp.nan))
    assert not np.any(np.isfinite(np.asarray(bad)))


# ---------------------------------------------------------------------------
# Serving-side chaos
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def models():
    rng = np.random.default_rng(11)
    xtr = jnp.asarray(rng.uniform(-3, 3, (300, 2)))
    ytr = jnp.asarray(np.sign(rng.standard_normal(300)))
    m_a = krr_fit(make_kernel("gaussian", sigma=1.0), xtr, ytr, 1e-2, PARAMS)
    m_b = krr_fit(make_kernel("gaussian", sigma=1.5), xtr, ytr, 1e-2, PARAMS)
    return {"a": m_a, "b": m_b}


@pytest.fixture()
def registry(models):
    reg = GraphModelRegistry()
    for mid, model in models.items():
        reg.register(mid, model)
    return reg


def _submit(engine, uid, mid, q, rhs=None, deadline_s=None):
    req = PredictRequest(uid=uid, model_id=mid, query_points=np.asarray(q),
                         rhs=None if rhs is None else np.asarray(rhs),
                         deadline_s=deadline_s)
    engine.submit(req)
    return req


def _oracle(models, mid, q):
    return np.asarray(krr_predict_direct(models[mid], jnp.asarray(q)))


def test_poisoned_grids_evict_trip_breaker_and_recover(models, registry):
    """NaN-poisoned cached grids: affected requests fail with the
    non-finite guard (never serve NaN), the tenant's breaker trips and
    invalidates its grids, the circuit sheds load during cooldown, and
    post-cooldown traffic is served correctly from rebuilt grids.  The
    sibling tenant sharing the group is never affected."""
    rng = np.random.default_rng(20)
    engine = GraphServeEngine(registry, slots=2, chunk=16,
                              breaker_threshold=2, breaker_cooldown=2)
    # warm: build the (a, alpha) grid
    warm = _submit(engine, 0, "a", rng.uniform(-2, 2, (8, 2)))
    engine.run_until_drained()
    assert warm.done and warm.error is None

    assert poison_registry_grids(registry, "a", frac=0.5, seed=1) == 1
    r1 = _submit(engine, 1, "a", rng.uniform(-2, 2, (8, 2)))
    r2 = _submit(engine, 2, "a", rng.uniform(-2, 2, (8, 2)))
    stats = engine.step()
    assert r1.done and "non-finite" in r1.error
    assert r2.done and "non-finite" in r2.error
    assert stats.nonfinite == 2
    assert engine.counters["nonfinite"] == 2
    assert engine.counters["breaker_trips"] == 1
    assert registry.counters["grid_invalidations"] >= 1

    # circuit open: tenant "a" load is shed at admission …
    r3 = _submit(engine, 3, "a", rng.uniform(-2, 2, (8, 2)))
    engine.step()
    assert r3.done and "circuit open" in r3.error
    assert engine.counters["breaker_rejections"] == 1
    # … while the sibling tenant in the SAME group keeps being served
    qb = rng.uniform(-2, 2, (10, 2))
    rb = _submit(engine, 4, "b", qb)
    engine.run_until_drained()
    assert rb.done and rb.error is None
    np.testing.assert_allclose(rb.output, _oracle(models, "b", qb),
                               atol=TOL)

    # past the cooldown: clean grids rebuilt from the registered alpha
    for _ in range(4):
        engine.step()
    qa = rng.uniform(-2, 2, (12, 2))
    r5 = _submit(engine, 5, "a", qa)
    engine.run_until_drained()
    assert r5.done and r5.error is None, r5.error
    np.testing.assert_allclose(r5.output, _oracle(models, "a", qa),
                               atol=TOL)


def test_corrupted_plan_detected_rebuilt_and_served(models, registry):
    """A corrupted frozen PredictionPlan makes in-domain queries look
    inadmissible; the engine must detect the violated plan invariant,
    rebuild the tenant group from its registered models, and serve the
    request correctly in the same admission."""
    rng = np.random.default_rng(21)
    engine = GraphServeEngine(registry, slots=2, chunk=16)
    assert corrupt_group_plan(registry, "a")
    qa = rng.uniform(-2, 2, (10, 2))
    qb = rng.uniform(-2, 2, (10, 2))
    ra = _submit(engine, 0, "a", qa)
    rb = _submit(engine, 1, "b", qb)  # same group: rides the same rebuild
    engine.run_until_drained()
    assert ra.done and ra.error is None, ra.error
    assert rb.done and rb.error is None, rb.error
    np.testing.assert_allclose(ra.output, _oracle(models, "a", qa),
                               atol=TOL)
    np.testing.assert_allclose(rb.output, _oracle(models, "b", qb),
                               atol=TOL)
    assert engine.counters["plan_rebuilds"] == 1
    assert registry.counters["group_rebuilds"] == 1
    assert any(t.rebuilds for t in engine.tick_log)


def test_deadline_expiry_evicts_and_recycles_slot(models, registry):
    """An in-flight request whose deadline passes is evicted with its slot
    recycled the same tick; queued requests with expired deadlines never
    occupy a slot at all."""
    rng = np.random.default_rng(22)
    engine = GraphServeEngine(registry, slots=1, chunk=4)
    long = _submit(engine, 0, "a", rng.uniform(-2, 2, (64, 2)),
                   deadline_s=3600.0)
    engine.step()
    assert not long.done  # mid-flight (64 rows at chunk 4)
    long.submitted_at -= 7200.0  # deterministically expire the deadline
    qn = rng.uniform(-2, 2, (6, 2))
    nxt = _submit(engine, 1, "a", qn)
    stats = engine.step()
    assert long.done and "deadline" in long.error
    assert stats.evicted == 1
    # the freed slot admitted the next request in the SAME tick
    assert stats.occupancy == 1 and stats.rows > 0
    engine.run_until_drained()
    assert nxt.done and nxt.error is None
    np.testing.assert_allclose(nxt.output, _oracle(models, "a", qn),
                               atol=TOL)
    # queued-expiry path: deadline already passed when admission runs
    dead = _submit(engine, 2, "a", rng.uniform(-2, 2, (4, 2)),
                   deadline_s=1e-9)
    engine.step()
    assert dead.done and "deadline" in dead.error
    assert engine.counters["deadline_evicted"] == 2


def test_out_of_domain_rejected_or_replanned_never_wrong(models, registry):
    """Out-of-domain queries would wrap the NFFT torus into silently wrong
    values.  reject mode fails them; replan mode serves them through the
    exact slow path — asserted against the dense oracle."""
    # just past the registered domain (train ∪ margin): far enough to be
    # inadmissible, near enough that the replan's joint rescaling keeps the
    # NFFT error well under TOL and the oracle values are meaningfully
    # nonzero (a zeros-vs-zeros comparison would prove nothing)
    q_out = np.array([[4.5, -4.0], [5.0, 5.0], [4.2, 0.0]])
    rej = GraphServeEngine(registry, slots=2, chunk=8,
                           out_of_domain="reject")
    r = _submit(rej, 0, "a", q_out)
    rej.step()
    assert r.done and "domain" in r.error and r.output is None
    assert rej.counters["out_of_domain"] == 1
    assert any(t.out_of_domain for t in rej.tick_log)

    rep = GraphServeEngine(registry, slots=2, chunk=8,
                           out_of_domain="replan")
    r2 = _submit(rep, 1, "a", q_out)
    rep.step()
    assert r2.done and r2.error is None
    np.testing.assert_allclose(r2.output, _oracle(models, "a", q_out),
                               atol=TOL)
    assert rep.counters["replans"] == 1
    # non-finite queries are rejected even in replan mode
    r3 = _submit(rep, 2, "a", np.full((3, 2), np.nan))
    rep.step()
    assert r3.done and "non-finite query" in r3.error


def test_dropped_ticks_delay_but_never_corrupt(models, registry):
    """Dropped ticks (injected at the chaos hook) stall progress for that
    tick only; every request still completes with oracle-correct output
    and the drops are counted."""
    rng = np.random.default_rng(23)
    chaos = TickChaos(drop_ticks=frozenset({0, 2}))
    engine = GraphServeEngine(registry, slots=2, chunk=4, chaos=chaos)
    qs = [rng.uniform(-2, 2, (10, 2)) for _ in range(3)]
    reqs = [_submit(engine, i, "a", q) for i, q in enumerate(qs)]
    engine.run_until_drained()
    assert engine.counters["dropped_ticks"] == 2
    assert sum(t.dropped for t in engine.tick_log) == 2
    for req, q in zip(reqs, qs):
        assert req.done and req.error is None
        np.testing.assert_allclose(req.output, _oracle(models, "a", q),
                                   atol=TOL)


def test_bounded_queue_backpressure(registry):
    """submit() rejects instead of growing the queue without bound."""
    rng = np.random.default_rng(24)
    engine = GraphServeEngine(registry, slots=1, chunk=8, max_queue=2)
    ok1 = engine.submit(PredictRequest(
        uid=0, model_id="a", query_points=rng.uniform(-2, 2, (4, 2))))
    ok2 = engine.submit(PredictRequest(
        uid=1, model_id="a", query_points=rng.uniform(-2, 2, (4, 2))))
    shed = PredictRequest(uid=2, model_id="a",
                          query_points=rng.uniform(-2, 2, (4, 2)))
    ok3 = engine.submit(shed)
    assert ok1 and ok2 and not ok3
    assert shed.done and "backpressure" in shed.error
    assert engine.counters["backpressure"] == 1
    engine.run_until_drained()  # the admitted two still drain fine
    assert engine.counters["finished"] == 2


def test_chaos_schedule_is_deterministic():
    from repro.runtime import chaos_schedule
    a = chaos_schedule(5, ticks=200, p_drop=0.1, p_slow=0.1)
    b = chaos_schedule(5, ticks=200, p_drop=0.1, p_slow=0.1)
    assert a.drop_ticks == b.drop_ticks
    assert a.slow_ticks == b.slow_ticks
    assert a.drop_ticks  # 200 ticks at p=0.1: some drops scheduled
