"""Traditional Nyström vs hybrid Nyström-Gaussian-NFFT (paper Section 5/6.1)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    SETUP_2, dense_normalized_adjacency, make_kernel,
    make_normalized_adjacency, nystrom_gaussian_nfft, nystrom_traditional,
)
from repro.data import spiral


def _problem(n=800):
    pts, _ = spiral(n, seed=4)
    pts = jnp.asarray(pts)
    kern = make_kernel("gaussian", sigma=3.5)
    a = dense_normalized_adjacency(kern, pts)
    ref = jnp.sort(jnp.linalg.eigvalsh(a))[::-1][:10]
    return pts, kern, ref


def test_traditional_nystrom_reasonable_at_large_l():
    pts, kern, ref = _problem()
    res = nystrom_traditional(kern, pts, 10, pts.shape[0] // 4,
                              key=jax.random.PRNGKey(0))
    err = float(jnp.max(jnp.abs(res.eigenvalues - ref)))
    # paper: averages above 1e-2 even at L = n/4
    assert err < 0.5, err


def test_hybrid_beats_traditional_at_small_l():
    """Paper Section 6.1: hybrid with L=50 ~ 1e-5..1e-4, far better than
    traditional even at L=n/4 (~1e-2)."""
    pts, kern, ref = _problem()
    adj = make_normalized_adjacency(kern, pts, SETUP_2)
    hybrid = nystrom_gaussian_nfft(adj, 10, num_columns=50, rank=10,
                                   key=jax.random.PRNGKey(1))
    err_h = float(jnp.max(jnp.abs(hybrid.eigenvalues - ref)))
    trad = nystrom_traditional(kern, pts, 10, pts.shape[0] // 10,
                               key=jax.random.PRNGKey(2))
    err_t = float(jnp.max(jnp.abs(trad.eigenvalues - ref)))
    assert err_h < 1e-2, err_h
    assert err_h < err_t, (err_h, err_t)


def test_hybrid_eigenvectors_orthonormal():
    pts, kern, ref = _problem(500)
    adj = make_normalized_adjacency(kern, pts, SETUP_2)
    res = nystrom_gaussian_nfft(adj, 8, num_columns=30, rank=8,
                                key=jax.random.PRNGKey(3))
    gram = res.eigenvectors.T @ res.eigenvectors
    np.testing.assert_allclose(np.asarray(gram), np.eye(8), atol=1e-10)


class _DenseSymOp:
    """Duck-typed stand-in for NormalizedAdjacencyOperator (n, dtype, matvec)."""

    def __init__(self, a):
        self.a = a
        self.inv_sqrt_deg = jnp.ones((a.shape[0],), a.dtype)

    @property
    def n(self):
        return self.a.shape[0]

    def matvec(self, x):
        return self.a @ x


def test_hybrid_truncates_tiny_trailing_sigma():
    """A spectrum with tiny trailing sigma: rank-5 operator sketched at
    rank 15.  The trailing Ritz values of Q^T A Q sit at roundoff, and
    unguarded 1/sigma would poison the core matrix; the adaptive rank
    truncation keeps the top block exact and zeroes the rest."""
    rng = np.random.default_rng(0)
    n = 200
    q, _ = np.linalg.qr(rng.normal(size=(n, n)))
    vals = np.zeros(n)
    vals[:5] = [1.0, 0.8, 0.6, 0.4, 0.2]
    op = _DenseSymOp(jnp.asarray(q @ np.diag(vals) @ q.T))
    res = nystrom_gaussian_nfft(op, 8, num_columns=20, rank=15,
                                key=jax.random.PRNGKey(0))
    ev = np.asarray(res.eigenvalues)
    assert np.all(np.isfinite(ev))
    np.testing.assert_allclose(ev[:5], vals[:5], atol=1e-10)
    np.testing.assert_allclose(ev[5:], 0.0, atol=1e-10)


def test_hybrid_indefinite_cancellation_guard():
    """Regression for the indefinite blow-up: A's spectrum lives in [-1, 1],
    but a Ritz value of Q^T A Q landing near zero by +/- cancellation (with
    |A Q u| not small) used to inject a spurious eigenvalue ~3.8 through
    1/sigma.  With the sigma_tol floor every returned eigenvalue stays
    inside the spectral range and the top-10 stay accurate."""
    pts, kern, ref = _problem()
    adj = make_normalized_adjacency(kern, pts, SETUP_2)
    # rank == num_columns drives the sketch all the way into the
    # cancellation band; seed 1 is the observed blow-up
    res = nystrom_gaussian_nfft(adj, 10, num_columns=50, rank=50,
                                key=jax.random.PRNGKey(1))
    # healthy runs overshoot the spectral range only by approximation error
    # (~1e-4 here); the unguarded cancellation injected 3.76
    assert float(jnp.max(jnp.abs(res.eigenvalues))) <= 1.01
    err = float(jnp.max(jnp.abs(res.eigenvalues - ref)))
    assert err < 1e-2, err


def test_hybrid_l20_tier():
    """Paper: L=20 gives eig errors ~1e-3..1e-2."""
    pts, kern, ref = _problem()
    adj = make_normalized_adjacency(kern, pts, SETUP_2)
    res = nystrom_gaussian_nfft(adj, 10, num_columns=20, rank=10,
                                key=jax.random.PRNGKey(4))
    err = float(jnp.max(jnp.abs(res.eigenvalues - ref)))
    assert err < 5e-2, err
