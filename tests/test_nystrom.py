"""Traditional Nyström vs hybrid Nyström-Gaussian-NFFT (paper Section 5/6.1)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    SETUP_2, dense_normalized_adjacency, make_kernel,
    make_normalized_adjacency, nystrom_gaussian_nfft, nystrom_traditional,
)
from repro.data import spiral


def _problem(n=800):
    pts, _ = spiral(n, seed=4)
    pts = jnp.asarray(pts)
    kern = make_kernel("gaussian", sigma=3.5)
    a = dense_normalized_adjacency(kern, pts)
    ref = jnp.sort(jnp.linalg.eigvalsh(a))[::-1][:10]
    return pts, kern, ref


def test_traditional_nystrom_reasonable_at_large_l():
    pts, kern, ref = _problem()
    res = nystrom_traditional(kern, pts, 10, pts.shape[0] // 4,
                              key=jax.random.PRNGKey(0))
    err = float(jnp.max(jnp.abs(res.eigenvalues - ref)))
    # paper: averages above 1e-2 even at L = n/4
    assert err < 0.5, err


def test_hybrid_beats_traditional_at_small_l():
    """Paper Section 6.1: hybrid with L=50 ~ 1e-5..1e-4, far better than
    traditional even at L=n/4 (~1e-2)."""
    pts, kern, ref = _problem()
    adj = make_normalized_adjacency(kern, pts, SETUP_2)
    hybrid = nystrom_gaussian_nfft(adj, 10, num_columns=50, rank=10,
                                   key=jax.random.PRNGKey(1))
    err_h = float(jnp.max(jnp.abs(hybrid.eigenvalues - ref)))
    trad = nystrom_traditional(kern, pts, 10, pts.shape[0] // 10,
                               key=jax.random.PRNGKey(2))
    err_t = float(jnp.max(jnp.abs(trad.eigenvalues - ref)))
    assert err_h < 1e-2, err_h
    assert err_h < err_t, (err_h, err_t)


def test_hybrid_eigenvectors_orthonormal():
    pts, kern, ref = _problem(500)
    adj = make_normalized_adjacency(kern, pts, SETUP_2)
    res = nystrom_gaussian_nfft(adj, 8, num_columns=30, rank=8,
                                key=jax.random.PRNGKey(3))
    gram = res.eigenvectors.T @ res.eigenvectors
    np.testing.assert_allclose(np.asarray(gram), np.eye(8), atol=1e-10)


def test_hybrid_l20_tier():
    """Paper: L=20 gives eig errors ~1e-3..1e-2."""
    pts, kern, ref = _problem()
    adj = make_normalized_adjacency(kern, pts, SETUP_2)
    res = nystrom_gaussian_nfft(adj, 10, num_columns=20, rank=10,
                                key=jax.random.PRNGKey(4))
    err = float(jnp.max(jnp.abs(res.eigenvalues - ref)))
    assert err < 5e-2, err
