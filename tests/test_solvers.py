"""CG / MINRES vs numpy direct solves."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cg, minres


def _spd(n, seed=0):
    rng = np.random.default_rng(seed)
    m = rng.normal(size=(n, n))
    return jnp.asarray(m @ m.T + n * np.eye(n))


def test_cg_spd():
    a = _spd(120)
    b = jnp.asarray(np.random.default_rng(1).normal(size=120))
    sol = cg(lambda x: a @ x, b, tol=1e-12, maxiter=500)
    assert bool(sol.converged)
    ref = np.linalg.solve(np.asarray(a), np.asarray(b))
    np.testing.assert_allclose(np.asarray(sol.x), ref, rtol=1e-8, atol=1e-8)


def test_cg_preconditioned():
    a = _spd(120, seed=2)
    d = jnp.diag(a)
    b = jnp.asarray(np.random.default_rng(3).normal(size=120))
    sol_pc = cg(lambda x: a @ x, b, tol=1e-12, maxiter=500,
                preconditioner=lambda r: r / d)
    sol = cg(lambda x: a @ x, b, tol=1e-12, maxiter=500)
    assert bool(sol_pc.converged)
    np.testing.assert_allclose(np.asarray(sol_pc.x), np.asarray(sol.x),
                               rtol=1e-7, atol=1e-7)


def test_minres_spd_matches_cg():
    a = _spd(100, seed=4)
    b = jnp.asarray(np.random.default_rng(5).normal(size=100))
    s1 = cg(lambda x: a @ x, b, tol=1e-12, maxiter=500)
    s2 = minres(lambda x: a @ x, b, tol=1e-12, maxiter=500)
    np.testing.assert_allclose(np.asarray(s1.x), np.asarray(s2.x),
                               rtol=1e-7, atol=1e-7)


def _ill_conditioned_spd(n=150, decades=6, seed=1):
    rng = np.random.default_rng(seed)
    q, _ = np.linalg.qr(rng.normal(size=(n, n)))
    vals = np.logspace(-decades, 0, n)
    return jnp.asarray(q @ np.diag(vals) @ q.T), \
        jnp.asarray(rng.normal(size=n))


def test_cg_reports_true_residual_on_ill_conditioned():
    """The recurrence residual drifts below the attainable accuracy on an
    ill-conditioned operator (cond ~1e6, tol below the final-accuracy
    limit): the recurrence used to claim ~1e-10 convergence while
    ||b - A x|| stagnates ~1e-9.  The exit recompute makes residual_norm
    and converged describe the returned iterate."""
    a, b = _ill_conditioned_spd()
    tol = 1e-11
    sol = cg(lambda x: a @ x, b, tol=tol, maxiter=20000)
    true_res = float(jnp.linalg.norm(b - a @ sol.x))
    assert abs(float(sol.residual_norm) - true_res) <= 1e-6 * true_res
    tol_abs = tol * max(float(jnp.linalg.norm(b)), 1.0)
    assert bool(sol.converged) == (true_res <= tol_abs)
    # the drift is real: the solve stalled above the requested tolerance
    assert true_res > tol_abs, (true_res, tol_abs)


def test_minres_reports_true_residual_on_ill_conditioned():
    """Same as the CG test; MINRES's |phi_bar| shrinks monotonically by
    construction (a product of Givens sines), so it is guaranteed to drift
    below the true residual — here by ~3 orders of magnitude."""
    a, b = _ill_conditioned_spd()
    tol = 1e-11
    sol = minres(lambda x: a @ x, b, tol=tol, maxiter=20000)
    true_res = float(jnp.linalg.norm(b - a @ sol.x))
    assert abs(float(sol.residual_norm) - true_res) <= 1e-6 * true_res
    tol_abs = tol * max(float(jnp.linalg.norm(b)), 1.0)
    assert bool(sol.converged) == (true_res <= tol_abs)
    assert true_res > tol_abs, (true_res, tol_abs)


def test_batched_per_column_convergence_wildly_different_scales():
    """Batched (n, C) solves keep independent per-column bookkeeping: with
    columns spanning 12 orders of magnitude, every column must satisfy its
    OWN tolerance ``tol * max(||b_c||, 1)``.  The old global-norm
    bookkeeping let the 1e6-scale column dominate the convergence test (the
    tiny columns stopped at absolute residuals far above their own
    tolerance) and coupled all columns through a single step size."""
    a = _spd(120, seed=7)
    scales = np.array([1e-6, 1.0, 1e6])
    b = jnp.asarray(np.random.default_rng(8).normal(size=(120, 3)) * scales)
    tol = 1e-10
    for solver in (cg, minres):
        sol = solver(lambda x: a @ x, b, tol=tol, maxiter=2000)
        assert sol.x.shape == (120, 3)
        assert sol.num_iters.shape == (3,)
        tol_abs = tol * np.maximum(
            np.linalg.norm(np.asarray(b), axis=0), 1.0)
        true_res = np.linalg.norm(
            np.asarray(b) - np.asarray(a) @ np.asarray(sol.x), axis=0)
        np.testing.assert_allclose(np.asarray(sol.residual_norm), true_res,
                                   rtol=1e-6)
        assert np.all(true_res <= tol_abs), (solver.__name__, true_res,
                                             tol_abs)
        assert bool(jnp.all(sol.converged))
        # columns converge at different iteration counts — the easy tiny
        # column froze early instead of riding along to the global stop
        assert int(sol.num_iters[0]) < int(sol.num_iters[2])


def test_maxiter_exhaustion_exit_reporting_mixed_scales():
    """When ``maxiter`` runs out with only SOME columns converged, the exit
    report must stay per-column consistent: ``residual_norm`` is the true
    recomputed ``||b_c - A x_c||``, ``converged`` is derived from it against
    the column's own tolerance, and ``num_iters`` shows which columns froze
    early vs. rode to the iteration cap.  Mixed per-column scales make the
    recurrence residuals drift by very different amounts, which is exactly
    where stale-recurrence reporting used to lie."""
    # ill-conditioned SPD: diag spectrum over 10 orders of magnitude
    n = 100
    rng = np.random.default_rng(12)
    q, _ = np.linalg.qr(rng.normal(size=(n, n)))
    eig = np.logspace(-8, 2, n)
    a = jnp.asarray(q @ np.diag(eig) @ q.T)
    scales = np.array([1e-5, 1.0, 1e5])
    b = jnp.asarray(rng.normal(size=(n, 3)) * scales)
    tol = 1e-9
    maxiter = 25  # far too few for this conditioning
    for solver in (cg, minres):
        sol = solver(lambda x: a @ x, b, tol=tol, maxiter=maxiter)
        res = np.asarray(sol.residual_norm)
        iters = np.asarray(sol.num_iters)
        conv = np.asarray(sol.converged)
        # 1. residual_norm is the TRUE residual of the returned x, not the
        #    drifted recurrence scalar
        true_res = np.linalg.norm(
            np.asarray(b) - np.asarray(a) @ np.asarray(sol.x), axis=0)
        np.testing.assert_allclose(res, true_res, rtol=1e-6,
                                   err_msg=solver.__name__)
        # 2. converged agrees with the true residual per column, against
        #    that column's own tolerance
        tol_abs = tol * np.maximum(
            np.linalg.norm(np.asarray(b), axis=0), 1.0)
        np.testing.assert_array_equal(conv, true_res <= tol_abs,
                                      err_msg=solver.__name__)
        # 3. the cap was genuinely hit — this test exercises the exhaustion
        #    path, not ordinary convergence
        assert not conv.all(), (solver.__name__, res, tol_abs)
        assert iters.max() == maxiter, (solver.__name__, iters)
        # 4. num_iters is per-column: an unconverged column reports the full
        #    cap; a converged one reports where it froze
        assert np.all(iters[~conv] == maxiter), (solver.__name__, iters)
        assert np.all(iters <= maxiter)
        # 5. the returned x is still the best-so-far iterate, finite
        assert np.all(np.isfinite(np.asarray(sol.x)))


def test_batched_columns_match_independent_solves():
    """Each column of a lockstep batched solve equals its own 1-D solve."""
    a = _spd(100, seed=9)
    b = jnp.asarray(np.random.default_rng(10).normal(size=(100, 4)))
    for solver in (cg, minres):
        batched = solver(lambda x: a @ x, b, tol=1e-12, maxiter=1000)
        for c in range(4):
            single = solver(lambda x: a @ x, b[:, c], tol=1e-12,
                            maxiter=1000)
            np.testing.assert_allclose(np.asarray(batched.x[:, c]),
                                       np.asarray(single.x),
                                       rtol=1e-8, atol=1e-8)


def test_cg_complex_hpd():
    """The per-column rewrite must keep complex Hermitian-positive-definite
    operators working (conjugating inner products, modulus norms)."""
    rng = np.random.default_rng(11)
    m = rng.normal(size=(60, 60)) + 1j * rng.normal(size=(60, 60))
    a = jnp.asarray(m @ m.conj().T + 60 * np.eye(60))
    b = jnp.asarray(rng.normal(size=60) + 1j * rng.normal(size=60))
    sol = cg(lambda x: a @ x, b, tol=1e-12, maxiter=500)
    assert bool(sol.converged)
    ref = np.linalg.solve(np.asarray(a), np.asarray(b))
    np.testing.assert_allclose(np.asarray(sol.x), ref, rtol=1e-8, atol=1e-8)


def test_minres_indefinite():
    rng = np.random.default_rng(6)
    n = 100
    m = rng.normal(size=(n, n))
    a = jnp.asarray((m + m.T) / 2.0 + 0.5 * np.eye(n))  # symmetric indefinite
    b = jnp.asarray(rng.normal(size=n))
    sol = minres(lambda x: a @ x, b, tol=1e-10, maxiter=2000)
    ref = np.linalg.solve(np.asarray(a), np.asarray(b))
    np.testing.assert_allclose(np.asarray(sol.x), ref, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Guarded execution (PR 7): non-finite rhs, quarantine, stagnation
# ---------------------------------------------------------------------------

def test_nonfinite_rhs_returns_immediately():
    """Regression: a NaN/Inf rhs used to iterate to maxiter (every norm
    comparison with NaN is False, so the active mask never cleared).  The
    up-front validation must return at once with converged=False and the
    per-column rhs_nonfinite flag set."""
    a = _spd(60, seed=20)
    for bad in (np.nan, np.inf):
        b = jnp.asarray(np.full((60,), bad))
        for solver in (cg, minres):
            sol = solver(lambda x: a @ x, b, tol=1e-10, maxiter=5000)
            assert int(sol.num_iters) == 0
            assert not bool(sol.converged)
            assert bool(sol.health.rhs_nonfinite)
            assert not np.isfinite(float(sol.residual_norm))
            assert np.all(np.isfinite(np.asarray(sol.x)))


def test_nonfinite_rhs_column_isolated_in_batch():
    """One poisoned rhs column must not affect its lockstep siblings."""
    a = _spd(80, seed=21)
    rng = np.random.default_rng(22)
    b = rng.normal(size=(80, 3))
    b[:, 1] = np.nan
    bj = jnp.asarray(b)
    for solver in (cg, minres):
        sol = solver(lambda x: a @ x, bj, tol=1e-12, maxiter=1000)
        health = sol.health
        assert list(np.asarray(health.rhs_nonfinite)) == [False, True, False]
        for c in (0, 2):
            ref = np.linalg.solve(np.asarray(a), b[:, c])
            np.testing.assert_allclose(np.asarray(sol.x[:, c]), ref,
                                       rtol=1e-8, atol=1e-8)
        assert np.all(np.asarray(sol.x[:, 1]) == 0.0)
        assert np.all(np.isfinite(np.asarray(sol.x)))


def test_healthy_solves_report_clean_health():
    a = _spd(50, seed=23)
    b = jnp.asarray(np.random.default_rng(24).normal(size=(50, 2)))
    for solver in (cg, minres):
        sol = solver(lambda x: a @ x, b, tol=1e-12, maxiter=500)
        assert np.all(np.asarray(sol.converged))
        h = sol.health
        assert not np.any(np.asarray(h.any_fault))
        assert np.all(np.asarray(h.breakdown_iter) == -1)
