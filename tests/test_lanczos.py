"""Lanczos / eigsh correctness + reproduction of the paper's Fig. 3 tiers."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    SETUP_1, SETUP_2, SETUP_3, dense_normalized_adjacency, eigsh,
    eigsh_smallest_laplacian, make_kernel, make_normalized_adjacency,
)
from repro.data import spiral


def test_eigsh_matches_numpy_dense():
    rng = np.random.default_rng(0)
    n = 300
    m = rng.normal(size=(n, n))
    a = jnp.asarray((m + m.T) / 2.0)
    ref = np.sort(np.linalg.eigvalsh(np.asarray(a)))[::-1][:6]
    res = eigsh(lambda x: a @ x, n, 6, num_iters=120, key=jax.random.PRNGKey(1))
    np.testing.assert_allclose(np.asarray(res.eigenvalues), ref, rtol=1e-10, atol=1e-10)
    # eigenvector residuals
    r = a @ res.eigenvectors - res.eigenvectors * res.eigenvalues[None, :]
    assert float(jnp.max(jnp.linalg.norm(r, axis=0))) < 1e-8


def test_eigsh_smallest():
    rng = np.random.default_rng(1)
    n = 200
    m = rng.normal(size=(n, n))
    a = jnp.asarray((m + m.T) / 2.0)
    ref = np.sort(np.linalg.eigvalsh(np.asarray(a)))[:4]
    res = eigsh(lambda x: a @ x, n, 4, which="SA", num_iters=120,
                key=jax.random.PRNGKey(2))
    np.testing.assert_allclose(np.asarray(res.eigenvalues), ref, rtol=1e-9, atol=1e-9)


class TestPaperFigure3Tiers:
    """NFFT-based Lanczos reproduces the paper's three accuracy setups.

    Paper values (spiral data, sigma=3.5, 10 largest eigenpairs of A):
      setup #1 (N=16, m=2): eig err ~1e-4..1e-3, residuals ~1e-4..1e-3
      setup #2 (N=32, m=4): eig err ~1e-10..1e-9, residuals ~1e-8
      setup #3 (N=64, m=7): eig err <1e-14,      residuals 1e-15..1e-13
    """

    @classmethod
    def setup_class(cls):
        pts, _ = spiral(1000, seed=0)
        cls.pts = jnp.asarray(pts)
        cls.kern = make_kernel("gaussian", sigma=3.5)
        cls.a_dense = dense_normalized_adjacency(cls.kern, cls.pts)
        cls.ref = jnp.sort(jnp.linalg.eigvalsh(cls.a_dense))[::-1][:10]

    @pytest.mark.parametrize("setup,eig_tol,res_tol", [
        (SETUP_1, 5e-3, 1e-2),
        (SETUP_2, 5e-8, 5e-7),
        (SETUP_3, 1e-13, 1e-12),
    ])
    def test_tier(self, setup, eig_tol, res_tol):
        op = make_normalized_adjacency(self.kern, self.pts, setup)
        res = eigsh(op.matvec, self.pts.shape[0], 10, num_iters=80,
                    key=jax.random.PRNGKey(0))
        err = float(jnp.max(jnp.abs(res.eigenvalues - self.ref)))
        assert err < eig_tol, err
        r = (self.a_dense @ res.eigenvectors
             - res.eigenvectors * res.eigenvalues[None, :])
        rn = float(jnp.max(jnp.linalg.norm(r, axis=0)))
        assert rn < res_tol, rn

    def test_smallest_laplacian_equals_one_minus_largest(self):
        op = make_normalized_adjacency(self.kern, self.pts, SETUP_2)
        res = eigsh_smallest_laplacian(op.matvec, self.pts.shape[0], 5,
                                       num_iters=60, key=jax.random.PRNGKey(3))
        np.testing.assert_allclose(np.asarray(res.eigenvalues),
                                   1.0 - np.asarray(self.ref[:5]),
                                   rtol=0, atol=1e-7)
        # lambda_1(L_s) = 0 within accuracy
        assert abs(float(res.eigenvalues[0])) < 1e-7


def test_block_eigsh_v0_wider_than_shrunk_block():
    """eigsh(block_size>1, v0=...) used to raise a bare AssertionError when
    the block-shrinking loop reduced the block below v0's column count
    (small n, non-dividing block); v0 must be sliced instead."""
    rng = np.random.default_rng(7)
    n, k = 10, 4
    m = rng.normal(size=(n, n))
    a = jnp.asarray((m + m.T) / 2.0)
    v0 = jnp.asarray(rng.normal(size=(n, 8)))  # shrinks to block_size=5
    res = eigsh(lambda x: a @ x, n, k, v0=v0, block_size=8, num_iters=n)
    ref = np.sort(np.linalg.eigvalsh(np.asarray(a)))[::-1][:k]
    np.testing.assert_allclose(np.asarray(res.eigenvalues), ref,
                               rtol=1e-8, atol=1e-8)
