"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(11)


def _tol(dtype):
    return 2e-5 if dtype == jnp.float32 else 1e-12


# ---------------------------------------------------------------- kernel_matvec
@pytest.mark.parametrize("n,d,c", [(64, 1, 1), (200, 2, 1), (300, 3, 2),
                                   (257, 3, 1), (128, 2, 4)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_kernel_matvec_shapes(n, d, c, dtype):
    pts = jnp.asarray(RNG.normal(size=(n, d)), dtype)
    x = jnp.asarray(RNG.normal(size=(n, c)), dtype)
    out = ops.kernel_matvec(pts, pts, x, kernel_name="gaussian", param=1.5,
                            tile_j=64, tile_i=128, interpret=True)
    want = ref.kernel_matvec_ref(pts, pts, x, "gaussian", 1.5)
    rel = float(jnp.max(jnp.abs(out - want)) / jnp.max(jnp.abs(want)))
    assert rel < _tol(dtype), rel


@pytest.mark.parametrize("kname,param", [
    ("gaussian", 2.0), ("laplacian_rbf", 0.7),
    ("multiquadric", 1.0), ("inverse_multiquadric", 1.0)])
def test_kernel_matvec_all_kernels(kname, param):
    pts = jnp.asarray(RNG.normal(size=(200, 3)), jnp.float32)
    x = jnp.asarray(RNG.normal(size=(200,)), jnp.float32)
    out = ops.kernel_matvec(pts, pts, x, kernel_name=kname, param=param,
                            tile_j=64, tile_i=64, interpret=True)
    want = ref.kernel_matvec_ref(pts, pts, x, kname, param)
    rel = float(jnp.max(jnp.abs(out - want)) / jnp.max(jnp.abs(want)))
    assert rel < 2e-5, rel


def test_kernel_matvec_rectangular():
    """Separate source/target sets (Nyström W_XY blocks, KRR prediction)."""
    a = jnp.asarray(RNG.normal(size=(150, 2)), jnp.float32)
    b = jnp.asarray(RNG.normal(size=(220, 2)), jnp.float32)
    x = jnp.asarray(RNG.normal(size=(220,)), jnp.float32)
    out = ops.kernel_matvec(a, b, x, kernel_name="gaussian", param=1.0,
                            zero_diagonal=False, tile_j=64, tile_i=64,
                            interpret=True)
    want = ref.kernel_matvec_ref(a, b, x, "gaussian", 1.0, zero_diagonal=False)
    rel = float(jnp.max(jnp.abs(out - want)) / jnp.max(jnp.abs(want)))
    assert rel < 2e-5, rel


# --------------------------------------------------------------- window kernels
@pytest.mark.parametrize("n,taps,grid", [(100, 9, 512), (500, 25, 4096),
                                         (333, 125, 2048)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_window_gather_sweep(n, taps, grid, dtype):
    idx = jnp.asarray(RNG.integers(0, grid, (n, taps)), jnp.int32)
    w = jnp.asarray(RNG.normal(size=(n, taps)), dtype)
    g = jnp.asarray(RNG.normal(size=(grid,)), dtype)
    out = ops.window_gather(g, idx, w, node_tile=128, interpret=True)
    want = ref.window_gather_ref(g, idx, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5 if dtype == jnp.float32 else 1e-12,
                               atol=1e-5 if dtype == jnp.float32 else 1e-12)


@pytest.mark.parametrize("n,taps,grid", [(100, 9, 512), (400, 25, 2048)])
def test_window_spread_sweep(n, taps, grid):
    idx = jnp.asarray(RNG.integers(0, grid, (n, taps)), jnp.int32)
    w = jnp.asarray(RNG.normal(size=(n, taps)), jnp.float32)
    x = jnp.asarray(RNG.normal(size=(n,)), jnp.float32)
    out = ops.window_spread(x, idx, w, grid_size=grid, node_tile=128,
                            interpret=True)
    want = ref.window_spread_ref(x, idx, w, grid)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n,taps,grid,c", [(100, 9, 512, 3), (257, 25, 2048, 4)])
def test_window_gather_batched_channels(n, taps, grid, c):
    """(G, C) grids share one index/weight stream across channels."""
    idx = jnp.asarray(RNG.integers(0, grid, (n, taps)), jnp.int32)
    w = jnp.asarray(RNG.normal(size=(n, taps)), jnp.float64)
    g = jnp.asarray(RNG.normal(size=(grid, c)), jnp.float64)
    out = ops.window_gather(g, idx, w, node_tile=128, interpret=True)
    want = ref.window_gather_ref(g, idx, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-12, atol=1e-12)
    for i in range(c):
        single = ops.window_gather(g[:, i], idx, w, node_tile=128,
                                   interpret=True)
        np.testing.assert_allclose(np.asarray(out[:, i]), np.asarray(single),
                                   rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("n,taps,grid,c", [(100, 9, 512, 3), (200, 25, 1024, 2)])
def test_window_spread_batched_channels(n, taps, grid, c):
    idx = jnp.asarray(RNG.integers(0, grid, (n, taps)), jnp.int32)
    w = jnp.asarray(RNG.normal(size=(n, taps)), jnp.float32)
    x = jnp.asarray(RNG.normal(size=(n, c)), jnp.float32)
    out = ops.window_spread(x, idx, w, grid_size=grid, node_tile=128,
                            interpret=True)
    want = ref.window_spread_ref(x, idx, w, grid)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_spread_gather_adjoint():
    """<gather(g), x> == <g, spread(x)> — the NFFT adjointness at tile level."""
    n, taps, grid = 256, 27, 1024
    idx = jnp.asarray(RNG.integers(0, grid, (n, taps)), jnp.int32)
    w = jnp.asarray(RNG.normal(size=(n, taps)), jnp.float64)
    g = jnp.asarray(RNG.normal(size=(grid,)), jnp.float64)
    x = jnp.asarray(RNG.normal(size=(n,)), jnp.float64)
    lhs = float(jnp.vdot(ops.window_gather(g, idx, w, interpret=True), x))
    rhs = float(jnp.vdot(g, ops.window_spread(x, idx, w, grid_size=grid,
                                              interpret=True)))
    assert abs(lhs - rhs) / abs(lhs) < 1e-12


# -------------------------------------------------------------- flash attention
@pytest.mark.parametrize("b,hq,hkv,sq,sk,dh", [
    (2, 4, 2, 128, 128, 64),
    (1, 8, 1, 100, 100, 64),   # MQA, ragged seq
    (1, 2, 2, 64, 192, 32),    # cross-length
    (1, 16, 8, 96, 96, 128),   # GQA group 2
])
@pytest.mark.parametrize("causal", [False, True])
def test_flash_attention_sweep(b, hq, hkv, sq, sk, dh, causal):
    q = jnp.asarray(RNG.normal(size=(b, hq, sq, dh)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, hkv, sk, dh)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, hkv, sk, dh)), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                              interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    assert float(jnp.max(jnp.abs(out - want))) < 2e-5


def test_flash_attention_bf16():
    q = jnp.asarray(RNG.normal(size=(1, 4, 128, 64)), jnp.bfloat16)
    k = jnp.asarray(RNG.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
    v = jnp.asarray(RNG.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
    out = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                              interpret=True)
    want = ref.flash_attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                                   v.astype(jnp.float32), causal=True)
    assert float(jnp.max(jnp.abs(out.astype(jnp.float32) - want))) < 5e-2


def test_flash_attention_decode_alignment():
    """Decode shape: one query against a long KV cache, causal offset."""
    q = jnp.asarray(RNG.normal(size=(2, 4, 1, 64)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(2, 4, 256, 64)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(2, 4, 256, 64)), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, block_q=8, block_k=64,
                              interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    assert float(jnp.max(jnp.abs(out - want))) < 2e-5
