"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(11)


def _tol(dtype):
    return 2e-5 if dtype == jnp.float32 else 1e-12


# ---------------------------------------------------------------- kernel_matvec
@pytest.mark.parametrize("n,d,c", [(64, 1, 1), (200, 2, 1), (300, 3, 2),
                                   (257, 3, 1), (128, 2, 4)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_kernel_matvec_shapes(n, d, c, dtype):
    pts = jnp.asarray(RNG.normal(size=(n, d)), dtype)
    x = jnp.asarray(RNG.normal(size=(n, c)), dtype)
    out = ops.kernel_matvec(pts, pts, x, kernel_name="gaussian", param=1.5,
                            tile_j=64, tile_i=128, interpret=True)
    want = ref.kernel_matvec_ref(pts, pts, x, "gaussian", 1.5)
    rel = float(jnp.max(jnp.abs(out - want)) / jnp.max(jnp.abs(want)))
    assert rel < _tol(dtype), rel


@pytest.mark.parametrize("kname,param", [
    ("gaussian", 2.0), ("laplacian_rbf", 0.7),
    ("multiquadric", 1.0), ("inverse_multiquadric", 1.0)])
def test_kernel_matvec_all_kernels(kname, param):
    pts = jnp.asarray(RNG.normal(size=(200, 3)), jnp.float32)
    x = jnp.asarray(RNG.normal(size=(200,)), jnp.float32)
    out = ops.kernel_matvec(pts, pts, x, kernel_name=kname, param=param,
                            tile_j=64, tile_i=64, interpret=True)
    want = ref.kernel_matvec_ref(pts, pts, x, kname, param)
    rel = float(jnp.max(jnp.abs(out - want)) / jnp.max(jnp.abs(want)))
    assert rel < 2e-5, rel


def test_kernel_matvec_rectangular():
    """Separate source/target sets (Nyström W_XY blocks, KRR prediction)."""
    a = jnp.asarray(RNG.normal(size=(150, 2)), jnp.float32)
    b = jnp.asarray(RNG.normal(size=(220, 2)), jnp.float32)
    x = jnp.asarray(RNG.normal(size=(220,)), jnp.float32)
    out = ops.kernel_matvec(a, b, x, kernel_name="gaussian", param=1.0,
                            zero_diagonal=False, tile_j=64, tile_i=64,
                            interpret=True)
    want = ref.kernel_matvec_ref(a, b, x, "gaussian", 1.0, zero_diagonal=False)
    rel = float(jnp.max(jnp.abs(out - want)) / jnp.max(jnp.abs(want)))
    assert rel < 2e-5, rel


# --------------------------------------------------------------- window kernels
# Separable streaming geometry: per-node patch corner (n, d) + per-dim
# weights (n, d, taps) — the fused engine's WindowGeometry layout.
def _sep_geom(n, d, taps, padded, dtype=jnp.float64):
    base = jnp.asarray(RNG.integers(0, padded - taps + 1, (n, d)), jnp.int32)
    w = jnp.asarray(RNG.normal(size=(n, d, taps)), dtype)
    return base, w


@pytest.mark.parametrize("n,d,taps,padded", [(100, 1, 9, 512), (257, 2, 9, 64),
                                             (120, 3, 5, 40)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_window_gather_sweep(n, d, taps, padded, dtype):
    base, w = _sep_geom(n, d, taps, padded, dtype)
    g = jnp.asarray(RNG.normal(size=(padded,) * d), dtype)
    out = ops.window_gather(g, base, w, node_tile=128, interpret=True)
    want = ref.window_gather_ref(g, base, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4 if dtype == jnp.float32 else 1e-12,
                               atol=1e-4 if dtype == jnp.float32 else 1e-12)


@pytest.mark.parametrize("n,d,taps,padded", [(100, 1, 9, 512), (257, 2, 9, 64),
                                             (120, 3, 5, 40)])
def test_window_spread_sweep(n, d, taps, padded):
    base, w = _sep_geom(n, d, taps, padded)
    x = jnp.asarray(RNG.normal(size=(n,)))
    out = ops.window_spread(x, base, w, padded_size=padded, node_tile=128,
                            interpret=True)
    want = ref.window_spread_ref(x, base, w, padded)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("n,d,taps,padded,c", [(100, 1, 9, 512, 3),
                                               (140, 2, 9, 64, 4),
                                               (90, 3, 5, 40, 2)])
def test_window_gather_batched_channels(n, d, taps, padded, c):
    """(P,)*d + (C,) grids share one geometry stream across channels."""
    base, w = _sep_geom(n, d, taps, padded)
    g = jnp.asarray(RNG.normal(size=(padded,) * d + (c,)))
    out = ops.window_gather(g, base, w, node_tile=128, interpret=True)
    want = ref.window_gather_ref(g, base, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-12, atol=1e-12)
    for i in range(c):
        single = ops.window_gather(g[..., i], base, w, node_tile=128,
                                   interpret=True)
        np.testing.assert_allclose(np.asarray(out[:, i]), np.asarray(single),
                                   rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("n,d,taps,padded,c", [(100, 1, 9, 512, 3),
                                               (140, 2, 9, 64, 2),
                                               (90, 3, 5, 40, 2)])
def test_window_spread_batched_channels(n, d, taps, padded, c):
    base, w = _sep_geom(n, d, taps, padded)
    x = jnp.asarray(RNG.normal(size=(n, c)))
    out = ops.window_spread(x, base, w, padded_size=padded, node_tile=128,
                            interpret=True)
    want = ref.window_spread_ref(x, base, w, padded)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("d,taps,padded", [(1, 9, 256), (2, 9, 64),
                                           (3, 5, 40)])
def test_spread_gather_adjoint(d, taps, padded):
    """<gather(g), x> == <g, spread(x)> — the NFFT adjointness at tile level."""
    n = 200
    base, w = _sep_geom(n, d, taps, padded)
    g = jnp.asarray(RNG.normal(size=(padded,) * d))
    x = jnp.asarray(RNG.normal(size=(n,)))
    lhs = float(jnp.vdot(ops.window_gather(g, base, w, interpret=True), x))
    rhs = float(jnp.vdot(g, ops.window_spread(x, base, w, padded_size=padded,
                                              interpret=True)))
    assert abs(lhs - rhs) / abs(lhs) < 1e-12


# -------------------------------------------------------------- flash attention
@pytest.mark.parametrize("b,hq,hkv,sq,sk,dh", [
    (2, 4, 2, 128, 128, 64),
    (1, 8, 1, 100, 100, 64),   # MQA, ragged seq
    (1, 2, 2, 64, 192, 32),    # cross-length
    (1, 16, 8, 96, 96, 128),   # GQA group 2
])
@pytest.mark.parametrize("causal", [False, True])
def test_flash_attention_sweep(b, hq, hkv, sq, sk, dh, causal):
    q = jnp.asarray(RNG.normal(size=(b, hq, sq, dh)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, hkv, sk, dh)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, hkv, sk, dh)), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                              interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    assert float(jnp.max(jnp.abs(out - want))) < 2e-5


def test_flash_attention_bf16():
    q = jnp.asarray(RNG.normal(size=(1, 4, 128, 64)), jnp.bfloat16)
    k = jnp.asarray(RNG.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
    v = jnp.asarray(RNG.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
    out = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                              interpret=True)
    want = ref.flash_attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                                   v.astype(jnp.float32), causal=True)
    assert float(jnp.max(jnp.abs(out.astype(jnp.float32) - want))) < 5e-2


def test_flash_attention_decode_alignment():
    """Decode shape: one query against a long KV cache, causal offset."""
    q = jnp.asarray(RNG.normal(size=(2, 4, 1, 64)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(2, 4, 256, 64)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(2, 4, 256, 64)), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, block_q=8, block_k=64,
                              interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    assert float(jnp.max(jnp.abs(out - want))) < 2e-5
