"""Int8 error-feedback gradient compression tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.dist.compression import (
    BLOCK, apply_error_feedback, compress_decompress,
    init_compression_state, _dequantize, _quantize)


@settings(deadline=None, max_examples=25)
@given(st.integers(1, 5000), st.integers(0, 2 ** 31 - 1),
       st.floats(1e-6, 1e6))
def test_quantize_error_bound(n, seed, scale):
    """|x - deq(q(x))| <= max|block| / 127 per element (half-step: /254)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(n) * scale, jnp.float32)
    q, s = _quantize(x)
    deq = _dequantize(q, s, n)
    pad = (-n) % BLOCK
    blocks = jnp.pad(x, (0, pad)).reshape(-1, BLOCK)
    bound = jnp.max(jnp.abs(blocks), axis=1) / 127.0 * 0.5 + 1e-30
    err = jnp.abs(jnp.pad(x - deq, (0, pad)).reshape(-1, BLOCK))
    assert bool(jnp.all(err <= bound[:, None] * 1.001))


def test_error_feedback_accumulates_residual():
    g = jnp.asarray([1.0, 1e-6, -1e-6, 0.5])
    out, resid = compress_decompress(g, jnp.zeros_like(g))
    # residual = exactly what was lost
    assert jnp.allclose(out + resid, g, atol=1e-7)


def test_error_feedback_converges_quadratic():
    """SGD on a quadratic with compressed grads + EF reaches the optimum."""
    target = jnp.asarray([3.0, -2.0, 0.5, 10.0])
    params = {"w": jnp.zeros(4)}
    state = init_compression_state(params)
    lr = 0.1
    for _ in range(400):
        grads = {"w": params["w"] - target}
        cgrads, state = apply_error_feedback(grads, state)
        params = {"w": params["w"] - lr * cgrads["w"]}
    assert jnp.allclose(params["w"], target, atol=1e-3), params["w"]


def test_error_feedback_beats_no_feedback():
    """Without EF, tiny gradients are lost forever; with EF they accumulate."""
    # gradient much smaller than the block max -> quantizes to 0 alone
    big = 1000.0
    g = jnp.asarray([big] + [0.1] * 63)
    no_ef = jnp.zeros_like(g)
    with_ef, resid = compress_decompress(g, jnp.zeros_like(g))
    # second application with residual recovers the small entries
    with_ef2, _ = compress_decompress(g, resid)
    small_err_1 = float(jnp.abs(with_ef[1:] - 0.1).max())
    small_err_2 = float(jnp.abs((with_ef + with_ef2)[1:] / 2 - 0.1).max())
    assert small_err_2 <= small_err_1 + 1e-9
