"""Unit tests for the repro.dist.sharding placement rules.

Runs on the single real CPU device (1x1 mesh) — no subprocess needed; the
multi-device behavior of the same rules is covered by the ``multidevice``
tests in test_distributed.py.  Divisibility fallback logic is exercised
directly through ``_fit_entry`` with synthetic mesh sizes.
"""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import (
    FSDP_AXES, MODEL_AXIS, _fit_entry, _rule_for, batch_specs, cache_specs,
    named, param_specs)


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_fit_entry_drops_non_dividing_axes():
    sizes = {"pod": 2, "data": 16, "model": 16}
    # 64 % (2*16) == 0: full tuple kept
    assert _fit_entry(64, ("pod", "data"), sizes) == ("pod", "data")
    # 48 % 32 != 0 but 48 % 16 == 0: "pod" dropped
    assert _fit_entry(48, ("pod", "data"), sizes) == "data"
    # 7 divides nothing: replicate
    assert _fit_entry(7, ("pod", "data"), sizes) is None
    # axis absent from the mesh is filtered before the divisibility check
    assert _fit_entry(48, ("pod", "data"), {"data": 16}) == "data"
    assert _fit_entry(100, None, sizes) is None


def test_named_filters_and_truncates(mesh):
    # axes not in the mesh ("pod") are dropped; spec truncates to rank
    sh = named(mesh, P(FSDP_AXES, MODEL_AXIS), (8, 4))
    assert sh.spec == P("data", "model")
    sh1 = named(mesh, P(FSDP_AXES, MODEL_AXIS), (8,))
    assert sh1.spec == P("data")
    # shape-free form keeps mesh axes only
    assert named(mesh, P()).spec == P()
    assert named(mesh, P(("pod",))).spec == P(None)


def test_rule_for_shapes(mesh):
    w = jnp.zeros((8, 4))
    vec = jnp.zeros((4,))
    scalar = jnp.zeros(())
    path_w = (jax.tree_util.DictKey("mlp"), jax.tree_util.DictKey("w_up"))
    assert _rule_for(path_w, w) == P(FSDP_AXES, MODEL_AXIS)
    assert _rule_for(path_w, vec) == P()
    assert _rule_for(path_w, scalar) == P()
    # embed tables feed token gathers: replicated
    path_e = (jax.tree_util.DictKey("embed"),)
    assert _rule_for(path_e, w) == P()
    # stacked (scan-over-periods) leaves: leading n_periods dim unsharded
    path_s = (jax.tree_util.DictKey("stack"), jax.tree_util.SequenceKey(0),
              jax.tree_util.DictKey("w_up"))
    stacked = jnp.zeros((3, 8, 4))
    assert _rule_for(path_s, stacked) == P(None, FSDP_AXES, MODEL_AXIS)


def test_param_specs_tree(mesh):
    params = {
        "embed": jnp.zeros((16, 8)),
        "prefix": [{"norm": jnp.zeros((8,)), "w": jnp.zeros((8, 8))}],
        "stack": [{"w_up": jnp.zeros((2, 8, 8))}],
    }
    specs = param_specs(params, mesh)
    assert specs["embed"].spec == P()
    assert specs["prefix"][0]["norm"].spec == P()
    assert specs["prefix"][0]["w"].spec == P("data", "model")
    assert specs["stack"][0]["w_up"].spec == P(None, "data", "model")
    # every sharding is usable: device_put round-trips
    placed = jax.tree.map(jax.device_put, params, specs)
    assert jax.tree.map(lambda a: a.shape, placed) == \
        jax.tree.map(lambda a: a.shape, params)


def test_param_specs_serve_replicated(mesh):
    params = {"w": jnp.zeros((8, 8))}
    specs = param_specs(params, mesh, serve_replicated=True)
    assert specs["w"].spec == P(None, "model")


def test_batch_and_cache_specs(mesh):
    batch = {"tokens": jnp.zeros((4, 8), jnp.int32),
             "labels": jnp.zeros((4, 8), jnp.int32)}
    bs = batch_specs(batch, mesh)
    assert bs["tokens"].spec == P("data")
    caches = {"prefix": {0: jnp.zeros((4, 8, 2, 2))},
              "stack": [jnp.zeros((3, 4, 8, 2, 2))]}
    cs = cache_specs(caches, mesh)
    assert cs["prefix"][0].spec == P("data")
    assert cs["stack"][0].spec == P(None, "data")
