"""End-to-end graph applications (paper Section 6.2/6.3)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import FastsumParams, SETUP_2, make_kernel, make_normalized_adjacency
from repro.data import crescent_fullmoon, gaussian_blobs, spiral, synthetic_image
from repro.graph import (
    allen_cahn_multiclass, clustering_agreement, kernel_ssl_cg, kernel_ssl_eig,
    krr_fit, krr_predict, krr_predict_direct, make_training_vector,
    spectral_clustering,
)
from repro.core.lanczos import eigsh

KEY = jax.random.PRNGKey(0)


def test_spectral_clustering_blobs():
    pts, labs = gaussian_blobs(1200, n_classes=4, d=3, spread=8.0, seed=3)
    op = make_normalized_adjacency(make_kernel("gaussian", sigma=3.0),
                                   jnp.asarray(pts), SETUP_2)
    sc = spectral_clustering(op, 4, key=KEY)
    assert clustering_agreement(labs, sc.assignments, 4) > 0.95


def test_spectral_clustering_image():
    """Fig. 5 analogue: segment a synthetic RGB image by color-channel graph."""
    img, lab = synthetic_image(40, 60, noise=6.0, seed=0)
    pixels = jnp.asarray(img.reshape(-1, 3))
    op = make_normalized_adjacency(make_kernel("gaussian", sigma=90.0),
                                   pixels, FastsumParams(n_bandwidth=16, m=2, p=2, eps_b=0.125))
    sc = spectral_clustering(op, 4, key=KEY)
    agree = clustering_agreement(lab.reshape(-1), sc.assignments, 4)
    assert agree > 0.9, agree


def test_phase_field_ssl():
    """Fig. 6 analogue: multiclass Allen-Cahn on Gaussian-blob data."""
    pts, labs = gaussian_blobs(1500, n_classes=5, d=3, spread=7.0, seed=2)
    op = make_normalized_adjacency(make_kernel("gaussian", sigma=3.5),
                                   jnp.asarray(pts), SETUP_2)
    pred = allen_cahn_multiclass(op, jnp.asarray(labs), 5, 5, k=5, key=KEY)
    acc = float(jnp.mean(pred == jnp.asarray(labs)))
    assert acc > 0.9, acc


def test_kernel_ssl():
    """Fig. 7 analogue: crescent-fullmoon misclassification ~ paper levels."""
    pts, labs = crescent_fullmoon(4000, seed=1)
    op = make_normalized_adjacency(make_kernel("gaussian", sigma=0.5),
                                   jnp.asarray(pts),
                                   FastsumParams(n_bandwidth=128, m=4, eps_b=0.0))
    f, _ = make_training_vector(jnp.asarray(labs), 25, 2, key=KEY,
                                positive_class=1)
    res = kernel_ssl_cg(op, f, beta=1e3)
    assert bool(res.converged)
    pred = (res.u > 0).astype(np.int32)
    mis = float(jnp.mean(pred != jnp.asarray(labs)))
    assert mis < 0.02, mis


def test_kernel_ssl_laplacian_rbf():
    """Fig. 8: the Laplacian RBF kernel gives similar classification."""
    pts, labs = crescent_fullmoon(3000, seed=2)
    op = make_normalized_adjacency(make_kernel("laplacian_rbf", sigma=0.35),
                                   jnp.asarray(pts),
                                   FastsumParams(n_bandwidth=256, m=3, eps_b=0.0))
    f, _ = make_training_vector(jnp.asarray(labs), 25, 2, key=KEY,
                                positive_class=1)
    res = kernel_ssl_cg(op, f, beta=1e3)
    pred = (res.u > 0).astype(np.int32)
    mis = float(jnp.mean(pred != jnp.asarray(labs)))
    assert mis < 0.05, mis


def test_kernel_ssl_eig_matches_cg():
    """Truncated-eigenbasis solve approximates the CG solve (Section 6.2.3)."""
    pts, labs = crescent_fullmoon(2000, seed=3)
    op = make_normalized_adjacency(make_kernel("gaussian", sigma=0.8),
                                   jnp.asarray(pts),
                                   FastsumParams(n_bandwidth=128, m=4, eps_b=0.0))
    f, _ = make_training_vector(jnp.asarray(labs), 25, 2, key=KEY,
                                positive_class=1)
    beta = 1e3
    res_cg = kernel_ssl_cg(op, f, beta=beta, tol=1e-8)
    eig = eigsh(op.matvec, op.n, 20, num_iters=100, key=KEY)
    u_eig = kernel_ssl_eig(eig.eigenvalues, eig.eigenvectors, f, beta)
    pred_cg = np.asarray(res_cg.u > 0)
    pred_eig = np.asarray(u_eig > 0)
    assert float(np.mean(pred_cg == pred_eig)) > 0.97


def test_krr_gaussian_and_inverse_multiquadric():
    rng = np.random.default_rng(5)
    xtr = rng.uniform(-3, 3, (600, 2))
    ytr = np.sign(xtr[:, 0] ** 2 + xtr[:, 1] ** 2 - 4.0)
    xte = rng.uniform(-3, 3, (300, 2))
    yte = np.sign(xte[:, 0] ** 2 + xte[:, 1] ** 2 - 4.0)
    for kern, params in [
        (make_kernel("gaussian", sigma=1.0), FastsumParams(n_bandwidth=64, m=4, eps_b=0.0)),
        (make_kernel("inverse_multiquadric", c=1.0), FastsumParams(n_bandwidth=128, m=5)),
    ]:
        model = krr_fit(kern, jnp.asarray(xtr), jnp.asarray(ytr), 1e-2, params)
        assert bool(model.converged)
        pred = krr_predict(model, jnp.asarray(xte))
        acc = float(np.mean(np.sign(np.asarray(pred)) == yte))
        assert acc > 0.95, (kern.name, acc)
        # fast prediction matches dense prediction
        pred_d = krr_predict_direct(model, jnp.asarray(xte))
        assert float(jnp.max(jnp.abs(pred - pred_d))) < 1e-2


def test_krr_predict_plans_once(monkeypatch):
    """Serving path: the prediction operator (kernel Fourier coefficients,
    Morton sort, spectral multiplier) is planned on the first predict and
    reused for repeated predicts on the same target set — no rebuild on the
    second call."""
    from repro.graph import krr as krr_mod
    from repro.graph import krr_prediction_operator

    rng = np.random.default_rng(7)
    xtr = jnp.asarray(rng.uniform(-3, 3, (300, 2)))
    ytr = jnp.asarray(np.sign(rng.standard_normal(300)))
    xte = jnp.asarray(rng.uniform(-3, 3, (100, 2)))
    model = krr_fit(make_kernel("gaussian", sigma=1.0), xtr, ytr, 1e-2,
                    FastsumParams(n_bandwidth=32, m=3, eps_b=0.0))

    calls = []
    real = krr_mod.make_fastsum
    monkeypatch.setattr(krr_mod, "make_fastsum",
                        lambda *a, **k: (calls.append(1), real(*a, **k))[1])
    p1 = krr_predict(model, xte)
    assert len(calls) == 1
    p2 = krr_predict(model, xte)  # same target set: cache hit, no rebuild
    assert len(calls) == 1
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))

    xte2 = jnp.asarray(rng.uniform(-3, 3, (50, 2)))
    krr_predict(model, xte2)  # new target set: plans again
    assert len(calls) == 2

    # prebuilt-operator path bypasses the model cache entirely
    op = krr_prediction_operator(model, xte)
    n_after_build = len(calls)
    p3 = krr_predict(model, xte, op=op)
    assert len(calls) == n_after_build
    np.testing.assert_allclose(np.asarray(p3), np.asarray(p1), atol=1e-12)


def test_krr_pred_cache_lru_alternation(monkeypatch):
    """Alternating serving target sets must not evict each other: the PR 4
    single-slot cache re-planned on every switch; the keyed LRU keeps the
    last few target sets resident (zero re-plans on alternation), and only
    genuinely new sets evict the least recently used entry."""
    from repro.graph import krr as krr_mod

    rng = np.random.default_rng(8)
    xtr = jnp.asarray(rng.uniform(-3, 3, (300, 2)))
    ytr = jnp.asarray(np.sign(rng.standard_normal(300)))
    model = krr_fit(make_kernel("gaussian", sigma=1.0), xtr, ytr, 1e-2,
                    FastsumParams(n_bandwidth=32, m=3, eps_b=0.0))
    val_set = jnp.asarray(rng.uniform(-3, 3, (100, 2)))
    live_set = jnp.asarray(rng.uniform(-3, 3, (80, 2)))

    calls = []
    real = krr_mod.make_fastsum
    monkeypatch.setattr(krr_mod, "make_fastsum",
                        lambda *a, **k: (calls.append(1), real(*a, **k))[1])
    krr_predict(model, val_set)
    krr_predict(model, live_set)
    assert len(calls) == 2
    for _ in range(3):  # two-target alternation: zero re-plans
        krr_predict(model, val_set)
        krr_predict(model, live_set)
    assert len(calls) == 2

    # capacity: PRED_CACHE_SLOTS distinct sets stay resident...
    extras = [jnp.asarray(rng.uniform(-3, 3, (60 + i, 2)))
              for i in range(krr_mod.PRED_CACHE_SLOTS - 1)]
    for e in extras:
        krr_predict(model, e)
    n_now = len(calls)
    krr_predict(model, live_set)  # most recent survivors still cached
    krr_predict(model, extras[-1])
    assert len(calls) == n_now
    # ...but val_set (least recently used) was evicted and re-plans
    krr_predict(model, val_set)
    assert len(calls) == n_now + 1


def test_krr_pred_cache_content_keyed(monkeypatch):
    """Regression: the cache used to key on array *object identity*, so a
    round-tripped copy of the same target set (e.g. deserialized from a
    request payload) re-planned every time.  Content keying makes any
    byte-identical array a hit; an explicit ``cache_key`` skips hashing."""
    from repro.graph import krr as krr_mod
    from repro.graph import krr_pred_cache_stats

    rng = np.random.default_rng(9)
    xtr = jnp.asarray(rng.uniform(-3, 3, (200, 2)))
    ytr = jnp.asarray(np.sign(rng.standard_normal(200)))
    model = krr_fit(make_kernel("gaussian", sigma=1.0), xtr, ytr, 1e-2,
                    FastsumParams(n_bandwidth=32, m=3, eps_b=0.0))
    xte = rng.uniform(-3, 3, (60, 2))

    calls = []
    real = krr_mod.make_fastsum
    monkeypatch.setattr(krr_mod, "make_fastsum",
                        lambda *a, **k: (calls.append(1), real(*a, **k))[1])
    p1 = krr_predict(model, jnp.asarray(xte))
    assert len(calls) == 1
    # a distinct array object with the same contents: round trip through
    # bytes, as a network/serialization boundary would produce
    copy = jnp.asarray(np.frombuffer(
        np.asarray(xte).tobytes(), np.asarray(xte).dtype).reshape(xte.shape))
    p2 = krr_predict(model, copy)
    assert len(calls) == 1  # content hit, no re-plan
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))

    # explicit cache_key path: caller-supplied key, no content hashing
    p3 = krr_predict(model, jnp.asarray(xte), cache_key="live")
    assert len(calls) == 2  # different key -> its own entry
    krr_predict(model, jnp.asarray(rng.uniform(-3, 3, (60, 2))),
                cache_key="live")  # same key: hit even for other contents
    assert len(calls) == 2
    np.testing.assert_allclose(np.asarray(p3), np.asarray(p1), atol=1e-12)

    stats = krr_pred_cache_stats(model)
    assert stats["hits"] == 2 and stats["plans"] == 2


def test_krr_pred_cache_thread_safety():
    """Regression: the shared ``pred_cache`` dict was mutated from serving
    threads with no synchronization.  Hammer one model from many threads
    with more rotating target sets than cache slots (constant insert +
    evict churn) and check nothing corrupts and results stay exact."""
    import threading

    from repro.graph import krr as krr_mod

    rng = np.random.default_rng(10)
    xtr = jnp.asarray(rng.uniform(-3, 3, (150, 2)))
    ytr = jnp.asarray(np.sign(rng.standard_normal(150)))
    model = krr_fit(make_kernel("gaussian", sigma=1.0), xtr, ytr, 1e-2,
                    FastsumParams(n_bandwidth=32, m=3, eps_b=0.0))
    n_sets = krr_mod.PRED_CACHE_SLOTS + 3  # force eviction churn
    sets = [jnp.asarray(rng.uniform(-3, 3, (40, 2))) for _ in range(n_sets)]
    expected = [np.asarray(krr_predict_direct(model, s)) for s in sets]

    errors = []

    def worker(seed):
        order = np.random.default_rng(seed).permutation(n_sets)
        try:
            for i in np.tile(order, 3):
                got = np.asarray(krr_predict(model, sets[i]))
                np.testing.assert_allclose(got, expected[i], atol=1e-2)
        except Exception as exc:  # pragma: no cover - failure path
            errors.append(exc)

    threads = [threading.Thread(target=worker, args=(s,)) for s in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    # cache respected its capacity bound throughout
    assert len(model.pred_cache["targets"]) <= krr_mod.PRED_CACHE_SLOTS


def test_krr_predict_many_single_plan(monkeypatch):
    """Batched serving: predictions for several query sets (and per-request
    dual vectors) pack into ONE planned operator + ONE multi-RHS matvec,
    and match per-request predictions."""
    from repro.graph import krr as krr_mod
    from repro.graph import krr_predict_many

    rng = np.random.default_rng(11)
    xtr = jnp.asarray(rng.uniform(-3, 3, (200, 2)))
    ytr = jnp.asarray(np.sign(rng.standard_normal(200)))
    model = krr_fit(make_kernel("gaussian", sigma=1.0), xtr, ytr, 1e-2,
                    FastsumParams(n_bandwidth=32, m=3, eps_b=0.0))
    queries = [jnp.asarray(rng.uniform(-3, 3, (m, 2))) for m in (30, 7, 55)]
    custom = jnp.asarray(rng.standard_normal(200))
    rhs = [None, custom, None]

    calls = []
    real = krr_mod.make_fastsum
    monkeypatch.setattr(krr_mod, "make_fastsum",
                        lambda *a, **k: (calls.append(1), real(*a, **k))[1])
    outs = krr_predict_many(model, queries, rhs=rhs)
    assert len(calls) == 1  # one packed plan for all three requests
    assert [o.shape[0] for o in outs] == [30, 7, 55]
    np.testing.assert_allclose(
        np.asarray(outs[0]),
        np.asarray(krr_predict_direct(model, queries[0])), atol=1e-2)
    np.testing.assert_allclose(
        np.asarray(outs[2]),
        np.asarray(krr_predict_direct(model, queries[2])), atol=1e-2)
    np.testing.assert_allclose(
        np.asarray(outs[1]),
        np.asarray(krr_predict_direct(
            model._replace(alpha=custom), queries[1])), atol=1e-2)


def test_kernel_ssl_multilayer_crescent():
    """Aggregated two-layer kernel SSL (Gaussian + Laplacian RBF mixture):
    one matvec per CG iteration for the whole layer sum, paper-level
    misclassification on the crescent-fullmoon data."""
    from repro.graph import kernel_ssl_cg_multilayer

    pts, labs = crescent_fullmoon(2000, seed=3)
    kernels = [make_kernel("gaussian", sigma=0.5),
               make_kernel("laplacian_rbf", sigma=0.35)]
    f, _ = make_training_vector(jnp.asarray(labs), 25, 2, key=KEY,
                                positive_class=1)
    res = kernel_ssl_cg_multilayer(
        kernels, [0.7, 0.3], jnp.asarray(pts),
        FastsumParams(n_bandwidth=128, m=4, eps_b=0.0), f, beta=1e3)
    assert bool(res.converged)
    pred = (res.u > 0).astype(np.int32)
    mis = float(jnp.mean(pred != jnp.asarray(labs)))
    assert mis < 0.05, mis


def test_training_vector_clamps_small_classes():
    """A class smaller than n_samples_per_class contributes all its members
    and nothing else — the argsort over the 2.0 sentinel used to spill into
    wrong-class nodes and silently label them."""
    labels = jnp.asarray(np.array([0] * 40 + [1] * 3))
    f, mask = make_training_vector(labels, 25, 2, key=KEY, positive_class=1)
    f, mask, labs = np.asarray(f), np.asarray(mask), np.asarray(labels)
    assert (f[labs == 1] == 1.0).all()          # every class-1 member labeled
    assert ((f == 1.0) & (labs == 0)).sum() == 0  # no wrong-class positives
    assert (f[labs == 0] == -1.0).sum() == 25   # class 0 still fully sampled
    assert mask.sum() == 28 and (f[~mask] == 0.0).all()
