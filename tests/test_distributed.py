"""Multi-device sharding tests.

These MUST run in a subprocess: the host-platform device count is locked at
first jax init, and the main pytest process must keep seeing 1 device (the
smoke tests depend on it).  Each test spawns ``python -c`` with
XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""

import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.multidevice

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_in_subprocess(code: str, devices: int = 8, timeout: int = 600,
                      x64: bool = False) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = REPO_SRC
    if x64:  # pencil/fused parity tests assert <= 1e-10: needs float64
        env["JAX_ENABLE_X64"] = "1"
    else:
        env.pop("JAX_ENABLE_X64", None)
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert proc.returncode == 0, \
        f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr[-4000:]}"
    return proc.stdout


def test_distributed_fastsum_matches_single_device():
    run_in_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.core import SETUP_2, make_fastsum, make_kernel
        from repro.data.synthetic import spiral
        from repro.dist.fastsum_dist import distributed_matvec_fn

        assert jax.device_count() == 8, jax.device_count()
        n = 4096
        points, _ = spiral(n, seed=3)
        pts = jnp.asarray(points, jnp.float32)
        kernel = make_kernel("gaussian", sigma=3.5)
        op = make_fastsum(kernel, pts, SETUP_2)
        x = jnp.asarray(np.random.default_rng(0).standard_normal(n),
                        jnp.float32)
        ref = op.matvec(x)

        mesh = jax.make_mesh((8,), ("data",))
        mv = distributed_matvec_fn(op, mesh, ("data",))
        out = mv(x)
        err = float(jnp.max(jnp.abs(out - ref)) /
                    jnp.maximum(jnp.max(jnp.abs(ref)), 1e-30))
        assert err < 2e-5, err
        print("fastsum dist OK", err)
    """)


def test_distributed_bank_matvec_matches_single_device():
    """Operator-bank routing through the sharded matvec (PR 5): both
    spectral modes, broadcast and lockstep flavors, ghost-padded n, parity
    <=1e-10 vs the single-device bank in float64."""
    run_in_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import SETUP_2, make_fastsum_bank, make_kernel
        from repro.data.synthetic import spiral
        from repro.dist.fastsum_dist import distributed_matvec_bank_fn

        assert jax.device_count() == 8
        n = 1000  # not divisible by 8 -> ghost-node padding
        points, _ = spiral(n, seed=3)
        pts = jnp.asarray(points)
        kernels = [make_kernel("gaussian", sigma=s) for s in (2.0, 3.5, 5.0)]
        bank = make_fastsum_bank(kernels, pts, SETUP_2)
        rng = np.random.default_rng(0)
        cases = [jnp.asarray(rng.standard_normal(n)),
                 jnp.asarray(rng.standard_normal((n, 2))),
                 jnp.asarray(rng.standard_normal((3, n, 2)))]
        mesh = jax.make_mesh((8,), ("data",))
        for mode in ("psum", "pencil"):
            mv = distributed_matvec_bank_fn(bank, mesh, ("data",),
                                            spectral_mode=mode)
            for x in cases:
                ref = bank.matvec(x)
                out = mv(x)
                err = float(jnp.max(jnp.abs(out - ref))
                            / jnp.max(jnp.abs(ref)))
                assert err < 1e-10, (mode, x.shape, err)
        print("dist bank OK")
    """, x64=True)


def test_distributed_lanczos_eigs():
    run_in_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import (SETUP_2, dense_normalized_adjacency, eigsh,
                                make_kernel, make_normalized_adjacency,
                                make_fastsum)
        from repro.data.synthetic import spiral
        from repro.dist.fastsum_dist import distributed_matvec_fn

        n = 2048
        points, _ = spiral(n, seed=4)
        pts = jnp.asarray(points, jnp.float32)
        kernel = make_kernel("gaussian", sigma=3.5)
        op = make_normalized_adjacency(kernel, pts, SETUP_2)
        mesh = jax.make_mesh((8,), ("data",))
        mv_w = distributed_matvec_fn(op.fastsum, mesh, ("data",))
        inv_sqrt = op.inv_sqrt_deg
        mv_a = lambda x: inv_sqrt * mv_w(inv_sqrt * x)
        res = eigsh(mv_a, n, 5, key=jax.random.PRNGKey(0), dtype=pts.dtype)

        a = dense_normalized_adjacency(kernel, pts)
        lam = jnp.linalg.eigvalsh(a)[::-1][:5]
        err = float(jnp.max(jnp.abs(res.eigenvalues - lam)))
        assert err < 5e-4, err
        print("dist lanczos OK", err)
    """)


def test_sharded_train_step_matches_single_device():
    run_in_subprocess("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.configs import get_config, reduced_config
        from repro.data.pipeline import batch_for_step
        from repro.dist import sharding as shr
        from repro.launch.steps import shardings_for
        from repro.models.common import set_mesh
        from repro.training.train_loop import (TrainConfig, init_train_state,
                                               make_train_step)

        cfg = reduced_config(get_config("granite-3-2b"), global_batch=8)
        tc = TrainConfig(num_microbatches=2)
        state = init_train_state(jax.random.PRNGKey(0), cfg, tc)
        batch = jax.tree.map(jnp.asarray,
                             batch_for_step(cfg, cfg.shapes[0], 0))
        # single-device reference
        _, ref = jax.jit(make_train_step(cfg, tc))(state, batch)
        ref_loss = float(ref["loss"])

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        in_sh = shardings_for("train", (state, batch), mesh)
        with mesh, set_mesh(mesh):
            step = jax.jit(make_train_step(cfg, tc), in_shardings=in_sh)
            new_state, metrics = step(state, batch)
        loss = float(metrics["loss"])
        assert abs(loss - ref_loss) < 1e-4, (loss, ref_loss)
        print("sharded train OK", loss, ref_loss)
    """)


def test_compress_psum_shard_map():
    run_in_subprocess("""
        import functools, jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.dist.compat import shard_map
        from repro.dist.compression import compress_psum

        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.standard_normal((8, 1000)), jnp.float32)
        resid = jnp.zeros_like(g)

        @functools.partial(shard_map, mesh=mesh,
                           in_specs=(P("data"), P("data")),
                           out_specs=(P("data"), P("data")),
                           check_vma=False)
        def reduce_grads(gs, rs):
            mean, new_r = compress_psum(gs[0], "data", rs[0])
            return mean[None], new_r[None]

        mean, new_resid = reduce_grads(g, resid)
        ref = jnp.mean(g, axis=0)
        # every worker's copy approximates the exact mean
        err = float(jnp.max(jnp.abs(mean - ref[None, :])))
        scale = float(jnp.max(jnp.abs(g))) / 127.0
        assert err <= scale * 1.01, (err, scale)
        print("compress psum OK", err)
    """)


def test_elastic_restore_across_meshes():
    """Checkpoint saved under one sharding restores + trains on another —
    the elastic-rescale contract of the checkpoint format."""
    run_in_subprocess("""
        import os, tempfile, jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config, reduced_config
        from repro.data.pipeline import batch_for_step
        from repro.dist import sharding as shr
        from repro.launch.steps import shardings_for
        from repro.models.common import set_mesh
        from repro.training import checkpoint as ckpt
        from repro.training.train_loop import (TrainConfig, init_train_state,
                                               make_train_step)

        cfg = reduced_config(get_config("granite-3-2b"), global_batch=8)
        tc = TrainConfig(num_microbatches=1)
        state = init_train_state(jax.random.PRNGKey(0), cfg, tc)
        batch = jax.tree.map(jnp.asarray,
                             batch_for_step(cfg, cfg.shapes[0], 0))

        tmp = tempfile.mkdtemp()
        # phase 1: train 2 steps on an (8,1) data-parallel mesh, checkpoint
        mesh1 = jax.make_mesh((8, 1), ("data", "model"))
        sh1 = shardings_for("train", (state, batch), mesh1)
        with mesh1, set_mesh(mesh1):
            step1 = jax.jit(make_train_step(cfg, tc), in_shardings=sh1)
            state = jax.device_put(state, sh1[0])
            for s in range(2):
                state, m = step1(state, jax.tree.map(
                    jnp.asarray, batch_for_step(cfg, cfg.shapes[0], s)))
        ckpt.save_checkpoint(tmp, 2, state)
        loss_ref = None

        # phase 2: restore onto a (2,4) mesh (different DP/TP split), train
        mesh2 = jax.make_mesh((2, 4), ("data", "model"))
        abstract = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
        sh2 = shardings_for("train", (abstract, batch), mesh2)
        restored = ckpt.restore_checkpoint(tmp, 2, abstract,
                                           shardings=sh2[0])
        with mesh2, set_mesh(mesh2):
            step2 = jax.jit(make_train_step(cfg, tc), in_shardings=sh2)
            restored, m2 = step2(restored, jax.tree.map(
                jnp.asarray, batch_for_step(cfg, cfg.shapes[0], 2)))
        # reference: continue on mesh1 without the restore round-trip
        with mesh1, set_mesh(mesh1):
            state, m1 = step1(state, jax.tree.map(
                jnp.asarray, batch_for_step(cfg, cfg.shapes[0], 2)))
        l1, l2 = float(m1["loss"]), float(m2["loss"])
        assert abs(l1 - l2) < 1e-4, (l1, l2)
        print("elastic restore OK", l1, l2)
    """)


def test_production_mesh_shapes():
    run_in_subprocess("""
        from repro.launch.mesh import make_production_mesh, mesh_chip_count
        m1 = make_production_mesh()
        assert dict(m1.shape) == {"data": 16, "model": 16}
        m2 = make_production_mesh(multi_pod=True)
        assert dict(m2.shape) == {"pod": 2, "data": 16, "model": 16}
        assert mesh_chip_count(m2) == 512
        print("mesh OK")
    """, devices=512)
