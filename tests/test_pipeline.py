"""Data-pipeline determinism + MoE routing correctness tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config, reduced_config
from repro.configs.base import ArchConfig, MoEConfig
from repro.data.pipeline import Prefetcher, batch_for_step
from repro.models.mlp import init_moe, moe_forward


def test_batch_deterministic_per_step():
    cfg = reduced_config(get_config("granite-3-2b"))
    a = batch_for_step(cfg, cfg.shapes[0], 5)
    b = batch_for_step(cfg, cfg.shapes[0], 5)
    for x, y in zip(jax.tree_util.tree_leaves(a),
                    jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(x, y)
    c = batch_for_step(cfg, cfg.shapes[0], 6)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_labels_are_shifted_tokens():
    cfg = reduced_config(get_config("granite-3-2b"))
    b = batch_for_step(cfg, cfg.shapes[0], 0)
    # labels[t] = tokens[t+1] within the same underlying stream
    assert b["tokens"].shape == b["labels"].shape
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_prefetcher_matches_direct():
    cfg = reduced_config(get_config("granite-3-2b"))
    pf = Prefetcher(cfg, cfg.shapes[0], start=0, depth=2)
    try:
        for step in range(4):
            got = pf.get(step)
            ref = batch_for_step(cfg, cfg.shapes[0], step)
            np.testing.assert_array_equal(got["tokens"], ref["tokens"])
    finally:
        pf.close()


def test_prefetcher_rewind_after_restart():
    cfg = reduced_config(get_config("granite-3-2b"))
    pf = Prefetcher(cfg, cfg.shapes[0], start=3, depth=2)
    try:
        pf.get(3)
        pf.get(4)
        # simulated restart rewind to step 3
        got = pf.get(3)
        ref = batch_for_step(cfg, cfg.shapes[0], 3)
        np.testing.assert_array_equal(got["tokens"], ref["tokens"])
    finally:
        pf.close()


# ---------------------------------------------------------------------------
# MoE routing correctness: gather/scatter routing == brute-force per-token
# ---------------------------------------------------------------------------

def _brute_force_moe(params, x, cfg):
    """Apply each token to its top-k experts directly (no capacity)."""
    moe = cfg.moe
    b, s, d = x.shape
    xt = x.reshape(-1, d)
    logits = xt.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    top_p, top_idx = jax.lax.top_k(probs, moe.top_k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    outs = jnp.zeros_like(xt)
    for t in range(xt.shape[0]):
        acc = jnp.zeros((d,), xt.dtype)
        for j in range(moe.top_k):
            e = int(top_idx[t, j])
            h = xt[t] @ params["w_up"][e]
            if "w_gate" in params:
                h = jax.nn.silu(xt[t] @ params["w_gate"][e]) * h
            acc = acc + top_p[t, j] * (h @ params["w_down"][e])
        outs = outs.at[t].set(acc)
    return outs.reshape(b, s, d)


def test_moe_routing_matches_brute_force():
    cfg = reduced_config(get_config("olmoe-1b-7b"))
    # capacity large enough that nothing is dropped
    moe = MoEConfig(num_experts=4, top_k=2, d_ff_expert=16,
                    capacity_factor=8.0)
    import dataclasses
    cfg = dataclasses.replace(cfg, moe=moe, d_model=8)
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 8), jnp.float32)
    out, aux = moe_forward(params, x, cfg)
    ref = _brute_force_moe(params, x, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)
    assert float(aux) > 0


@settings(deadline=None, max_examples=10)
@given(st.integers(0, 1000))
def test_moe_capacity_drops_dont_crash(seed):
    """Tiny capacity: overflowing tokens are dropped, output stays finite."""
    cfg = reduced_config(get_config("olmoe-1b-7b"))
    import dataclasses
    moe = MoEConfig(num_experts=4, top_k=2, d_ff_expert=16,
                    capacity_factor=0.3)
    cfg = dataclasses.replace(cfg, moe=moe, d_model=8)
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed), (2, 8, 8), jnp.float32)
    out, aux = moe_forward(params, x, cfg)
    assert bool(jnp.all(jnp.isfinite(out)))
