"""Algorithm 3.1/3.2 vs dense oracles, for all four paper kernels."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    FastsumParams, SETUP_1, SETUP_2, SETUP_3, dense_normalized_adjacency,
    dense_weight_matrix, direct_matvec_tiled, make_fastsum, make_kernel,
    make_normalized_adjacency,
)

RNG = np.random.default_rng(7)
N_PTS = 600
POINTS_3D = jnp.asarray(RNG.normal(size=(N_PTS, 3)) * 3.0)
POINTS_2D = jnp.asarray(RNG.uniform(-8, 8, size=(N_PTS, 2)))
X = jnp.asarray(RNG.normal(size=(N_PTS,)))


@pytest.mark.parametrize("setup,tol", [(SETUP_1, 5e-2), (SETUP_2, 1e-5), (SETUP_3, 1e-10)])
def test_gaussian_matvec_accuracy_tiers(setup, tol):
    kern = make_kernel("gaussian", sigma=3.5)
    ref = dense_weight_matrix(kern, POINTS_3D) @ X
    fs = make_fastsum(kern, POINTS_3D, setup)
    out = fs.matvec(X)
    rel = float(jnp.max(jnp.abs(out - ref)) / jnp.max(jnp.abs(ref)))
    assert rel < tol, rel


@pytest.mark.parametrize("kname,kw,params,tol", [
    ("laplacian_rbf", dict(sigma=2.0), FastsumParams(n_bandwidth=256, m=4, eps_b=0.0), 5e-2),
    ("multiquadric", dict(c=1.0), FastsumParams(n_bandwidth=128, m=5), 5e-4),
    ("inverse_multiquadric", dict(c=1.0), FastsumParams(n_bandwidth=128, m=5), 5e-4),
])
def test_other_kernels(kname, kw, params, tol):
    kern = make_kernel(kname, **kw)
    ref = dense_weight_matrix(kern, POINTS_2D) @ X
    fs = make_fastsum(kern, POINTS_2D, params)
    out = fs.matvec(X)
    rel = float(jnp.max(jnp.abs(out - ref)) / jnp.max(jnp.abs(ref)))
    assert rel < tol, rel


def test_degrees_match_dense():
    kern = make_kernel("gaussian", sigma=3.5)
    fs = make_fastsum(kern, POINTS_3D, SETUP_2)
    ref = jnp.sum(dense_weight_matrix(kern, POINTS_3D), axis=1)
    rel = float(jnp.max(jnp.abs(fs.degrees() - ref)) / jnp.max(ref))
    assert rel < 1e-5


def test_normalized_adjacency_matches_dense():
    kern = make_kernel("gaussian", sigma=3.5)
    op = make_normalized_adjacency(kern, POINTS_3D, SETUP_3)
    a_ref = dense_normalized_adjacency(kern, POINTS_3D)
    np.testing.assert_allclose(np.asarray(op.matvec(X)), np.asarray(a_ref @ X),
                               rtol=0, atol=1e-9)


def test_operator_exact_symmetry():
    """F diag(b) F^H structure makes the operator exactly Hermitian."""
    kern = make_kernel("gaussian", sigma=3.5)
    op = make_normalized_adjacency(kern, POINTS_3D, SETUP_1)
    y = jnp.asarray(RNG.normal(size=(N_PTS,)))
    lhs = float(jnp.vdot(op.matvec(X), y))
    rhs = float(jnp.vdot(X, op.matvec(y)))
    assert abs(lhs - rhs) / abs(lhs) < 1e-13


def test_batched_matvec_matches_loop():
    kern = make_kernel("gaussian", sigma=3.5)
    fs = make_fastsum(kern, POINTS_3D, SETUP_1)
    cols = jnp.asarray(RNG.normal(size=(N_PTS, 4)))
    batched = fs.matvec(cols)
    for i in range(4):
        np.testing.assert_allclose(np.asarray(batched[:, i]),
                                   np.asarray(fs.matvec(cols[:, i])),
                                   rtol=1e-11, atol=1e-11)


def test_separate_targets():
    kern = make_kernel("gaussian", sigma=3.5)
    tgt = jnp.asarray(RNG.normal(size=(100, 3)) * 3.0)
    fs = make_fastsum(kern, POINTS_3D, SETUP_2, target_points=tgt)
    out = fs.matvec_tilde(X)
    diff = tgt[:, None, :] - POINTS_3D[None, :, :]
    ref = kern.phi(jnp.linalg.norm(diff, axis=-1)) @ X
    rel = float(jnp.max(jnp.abs(out - ref)) / jnp.max(jnp.abs(ref)))
    assert rel < 1e-5, rel


def test_separate_targets_matvec_fails_loudly():
    """The K(0)-diagonal subtraction is undefined for src != tgt operators:
    matvec/matvec_reference must raise, not silently subtract."""
    kern = make_kernel("gaussian", sigma=3.5)
    tgt = jnp.asarray(RNG.normal(size=(100, 3)) * 3.0)
    fs = make_fastsum(kern, POINTS_3D, SETUP_2, target_points=tgt)
    with pytest.raises(ValueError, match="target_points"):
        fs.matvec(X)
    with pytest.raises(ValueError, match="target_points"):
        fs.matvec_reference(X)
    fs.matvec_tilde(X)  # the rectangular kernel sum itself stays available


def test_direct_matvec_tiled_matches_dense():
    kern = make_kernel("gaussian", sigma=3.5)
    ref = dense_weight_matrix(kern, POINTS_3D) @ X
    out = direct_matvec_tiled(kern, POINTS_3D, X, tile=128)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-12, atol=1e-12)


@settings(max_examples=10, deadline=None)
@given(a=st.floats(-3, 3), b=st.floats(-3, 3), seed=st.integers(0, 100))
def test_linearity_property(a, b, seed):
    """Algorithm 3.1 is a deterministic linear operator (paper Section 3)."""
    kern = make_kernel("gaussian", sigma=3.5)
    fs = make_fastsum(kern, POINTS_3D, SETUP_1)
    r = np.random.default_rng(seed)
    x1 = jnp.asarray(r.normal(size=(N_PTS,)))
    x2 = jnp.asarray(r.normal(size=(N_PTS,)))
    lhs = fs.matvec(a * x1 + b * x2)
    rhs = a * fs.matvec(x1) + b * fs.matvec(x2)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                               rtol=1e-9, atol=1e-9)


def test_nonfinite_points_rejected_at_plan_time():
    """A single NaN node would poison the min/max centering, collapse rho,
    and silently corrupt the Morton geometry — planning must refuse it."""
    kern = make_kernel("gaussian", sigma=3.5)
    pts = np.asarray(RNG.normal(size=(50, 2)))
    for bad in (np.nan, np.inf):
        poisoned = pts.copy()
        poisoned[17, 1] = bad
        with pytest.raises(ValueError, match="non-finite coordinates"):
            make_fastsum(kern, jnp.asarray(poisoned), SETUP_1)
    # the clean set still plans
    assert make_fastsum(kern, jnp.asarray(pts), SETUP_1) is not None
