"""Per-architecture smoke tests on reduced same-family configs (CPU).

Each assigned arch: one train step (loss finite, grads applied), prefill and
decode steps (output shapes, no NaNs), and scan-backbone == per-layer-loop
reference equivalence.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ALL_ARCHS, EXTRA_ARCHS, get_config, reduced_config
from repro.data.pipeline import batch_for_step
from repro.models import model as M
from repro.training.train_loop import (
    TrainConfig, init_train_state, make_train_step)

ARCH_NAMES = [c.name for c in ALL_ARCHS + EXTRA_ARCHS]


@pytest.fixture(scope="module")
def arch_state():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = reduced_config(get_config(name))
            tc = TrainConfig(num_microbatches=1)
            state = init_train_state(jax.random.PRNGKey(0), cfg, tc)
            cache[name] = (cfg, tc, state)
        return cache[name]

    return get


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_step(name, arch_state):
    cfg, tc, state = arch_state(name)
    batch = jax.tree.map(jnp.asarray, batch_for_step(cfg, cfg.shapes[0], 0))
    step = jax.jit(make_train_step(cfg, tc))
    new_state, metrics = step(state, batch)
    loss = float(metrics["loss"])
    assert jnp.isfinite(loss), name
    assert jnp.isfinite(float(metrics["grad_norm"])), name
    assert int(new_state.step) == 1
    # params actually moved
    moved = jax.tree_util.tree_reduce(
        lambda acc, pair: acc or bool(jnp.any(pair[0] != pair[1])),
        jax.tree.map(lambda a, b: (a, b), state.params, new_state.params),
        False, is_leaf=lambda x: isinstance(x, tuple))
    assert moved, name


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_scan_matches_reference(name, arch_state):
    cfg, tc, state = arch_state(name)
    batch = jax.tree.map(jnp.asarray, batch_for_step(cfg, cfg.shapes[0], 3))
    loss, _ = M.forward_train(state.params, cfg, batch)
    loss_ref, _ = M.forward_train_reference(state.params, cfg, batch)
    assert abs(float(loss) - float(loss_ref)) < 1e-4, (name, loss, loss_ref)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_prefill(name, arch_state):
    cfg, tc, state = arch_state(name)
    shapes = [s for s in cfg.shapes if s.kind == "prefill"
              and not s.skip_reason]
    if not shapes:
        pytest.skip("no prefill cell")
    s0 = shapes[0]
    caches = M.init_caches(cfg, s0.global_batch, s0.seq_len)
    batch = batch_for_step(cfg, s0, 1)
    batch.pop("labels", None)
    batch = jax.tree.map(jnp.asarray, batch)
    logits, caches2 = jax.jit(
        lambda p, b, c: M.forward_prefill(p, cfg, b, c))(
            state.params, batch, caches)
    assert logits.shape == (s0.global_batch, 1, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any()), name
    # caches must have been written (any nonzero leaf)
    nonzero = any(bool(jnp.any(v != 0))
                  for v in jax.tree_util.tree_leaves(caches2))
    assert nonzero, name


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_decode(name, arch_state):
    cfg, tc, state = arch_state(name)
    shapes = [s for s in cfg.shapes if s.kind == "decode"
              and not s.skip_reason]
    if not shapes:
        pytest.skip("encoder-only: no decode cell")
    b, s_max = 2, shapes[0].seq_len
    caches = M.init_caches(cfg, b, s_max)
    tok = jnp.ones((b, 1), jnp.int32)
    decode = jax.jit(lambda p, t, q, c: M.forward_decode(p, cfg, t, q, c))
    pos = jnp.zeros((b,), jnp.int32)
    for i in range(3):
        logits, caches = decode(state.params, tok, pos + i, caches)
        assert logits.shape == (b, 1, cfg.vocab_size)
        assert not bool(jnp.isnan(logits).any()), (name, i)
        tok = jnp.argmax(logits[:, :, :32], axis=-1).astype(jnp.int32)


def test_prefill_decode_consistency():
    """Prefill-then-decode must equal all-at-once forward (granite, causal)."""
    cfg = reduced_config(get_config("granite-3-2b"))
    state_params = M.init_params(jax.random.PRNGKey(1), cfg)
    b, s = 2, 16
    rng = jax.random.PRNGKey(7)
    toks = jax.random.randint(rng, (b, s + 1), 0, cfg.vocab_size)

    # teacher-forced full forward logits at position s-1
    batch = {"tokens": toks[:, :s], "labels": toks[:, 1:s + 1]}
    x, positions, _ = M.embed_inputs(state_params, cfg, batch)
    h, _, _ = M._run_backbone(state_params, cfg, x, positions, mode="train")
    full_logits = M.lm_logits(state_params, cfg, h)

    # prefill s-1 tokens, then decode token s-1
    caches = M.init_caches(cfg, b, s)
    pre_batch = {"tokens": toks[:, :s - 1]}
    _, caches = M.forward_prefill(state_params, cfg, pre_batch, caches)
    logits_dec, _ = M.forward_decode(
        state_params, cfg, toks[:, s - 1:s],
        jnp.full((b,), s - 1, jnp.int32), caches)

    ref = full_logits[:, -1, :]
    got = logits_dec[:, 0, :]
    assert jnp.allclose(ref.astype(jnp.float32), got.astype(jnp.float32),
                        atol=2e-3, rtol=2e-3), float(jnp.abs(ref - got).max())


def test_mamba_prefill_decode_consistency():
    """SSD prefill state handoff -> recurrent decode == full forward."""
    cfg = reduced_config(get_config("mamba2-1.3b"))
    params = M.init_params(jax.random.PRNGKey(2), cfg)
    b, s = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(8), (b, s + 1), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks[:, :s], "labels": toks[:, 1:s + 1]}
    x, positions, _ = M.embed_inputs(params, cfg, batch)
    h, _, _ = M._run_backbone(params, cfg, x, positions, mode="train")
    full_logits = M.lm_logits(params, cfg, h)

    caches = M.init_caches(cfg, b, s)
    _, caches = M.forward_prefill(params, cfg, {"tokens": toks[:, :s - 1]},
                                  caches)
    logits_dec, _ = M.forward_decode(params, cfg, toks[:, s - 1:s],
                                     jnp.full((b,), s - 1, jnp.int32), caches)
    ref = full_logits[:, -1, :].astype(jnp.float32)
    got = logits_dec[:, 0, :].astype(jnp.float32)
    assert jnp.allclose(ref, got, atol=2e-3, rtol=2e-3), \
        float(jnp.abs(ref - got).max())


def test_layer_plan_shapes():
    """Layer plans reconstruct the exact per-layer signature sequence."""
    for c in ALL_ARCHS + EXTRA_ARCHS:
        plan = M.make_layer_plan(c)
        assert plan.num_layers == c.num_layers, c.name
        flat = list(plan.prefix) + list(plan.period) * plan.n_periods
        expect = [M.layer_signature(c, i) for i in range(c.num_layers)]
        assert flat == expect, c.name
        # scan period stays small — HLO compactness invariant
        assert len(plan.period) <= 16 and len(plan.prefix) <= 8, c.name


def test_jamba_interleave():
    cfg = get_config("jamba-1.5-large-398b")
    sigs = [M.layer_signature(cfg, i) for i in range(cfg.num_layers)]
    n_attn = sum(s.mixer == "attn" for s in sigs)
    n_mamba = sum(s.mixer == "mamba" for s in sigs)
    assert n_attn * 7 == n_mamba  # 1:7 interleave
    n_moe = sum(s.ffn == "moe" for s in sigs)
    assert n_moe == cfg.num_layers // 2  # MoE every other layer


def test_deepseek_dense_prefix():
    cfg = get_config("deepseek-v3-671b")
    sigs = [M.layer_signature(cfg, i) for i in range(cfg.num_layers)]
    assert all(s.ffn == "dense" for s in sigs[:3])
    assert all(s.ffn == "moe" for s in sigs[3:])
    assert all(s.mixer == "mla" for s in sigs)


def test_assigned_config_figures():
    """Exact figures from the assignment table."""
    table = {
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
        "deepseek-v3-671b": (61, 7168, 128, 128, None, 129280),
        "olmoe-1b-7b": (16, 2048, 16, 16, None, 50304),
        "llama3-405b": (126, 16384, 128, 8, 53248, 128256),
        "granite-3-2b": (40, 2048, 32, 8, 8192, 49155),
        "gemma-7b": (28, 3072, 16, 16, 24576, 256000),
        "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
        "mamba2-1.3b": (48, 2048, 0, 0, 0, 50280),
        "paligemma-3b": (18, 2048, 8, 1, 16384, 257216),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
    }
    for name, (L, d, h, kv, ff, v) in table.items():
        c = get_config(name)
        assert c.num_layers == L and c.d_model == d, name
        assert c.num_heads == h and c.num_kv_heads == kv, name
        if ff is not None:
            assert c.d_ff == ff, name
        assert c.vocab_size == v, name
    assert get_config("deepseek-v3-671b").moe.num_experts == 256
    assert get_config("deepseek-v3-671b").moe.top_k == 8
    assert get_config("olmoe-1b-7b").moe.num_experts == 64
    assert get_config("jamba-1.5-large-398b").moe.num_experts == 16
    assert get_config("jamba-1.5-large-398b").moe.top_k == 2
    assert get_config("mamba2-1.3b").mamba.d_state == 128


def test_param_counts_plausible():
    """Model-card scale checks (rough: within 2x of nameplate)."""
    expect = {"llama3-405b": 405e9, "deepseek-v3-671b": 671e9,
              "gemma-7b": 8.5e9, "mamba2-1.3b": 1.3e9,
              "olmoe-1b-7b": 6.9e9, "qwen1.5-32b": 32e9}
    for name, target in expect.items():
        n = get_config(name).param_count()
        assert 0.5 * target < n < 2.0 * target, (name, n, target)
