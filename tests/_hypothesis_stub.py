"""Minimal deterministic stand-in for ``hypothesis``.

Installed by ``conftest.py`` only when the real package is absent (the CI
image installs the real one via the ``test`` extra in pyproject.toml).
Covers exactly the subset this suite uses: ``@settings(max_examples=...,
deadline=...)``, ``@given(*strategies, **kw_strategies)``, and the
``integers`` / ``floats`` / ``booleans`` / ``sampled_from`` strategies.

Example draws are deterministic (seeded per test name); the first two
examples pin every strategy to its min/max edge so boundary cases are always
exercised, the rest are uniform random.  No shrinking — a failing example is
reported as-is by pytest.
"""

from __future__ import annotations

import functools
import inspect
import random
import sys
import types
import zlib


class _Strategy:
    def __init__(self, draw_fn, edges=()):
        self._draw = draw_fn
        self.edges = tuple(edges)

    def draw(self, rng, example_idx):
        if example_idx < len(self.edges):
            return self.edges[example_idx]
        return self._draw(rng)


def integers(min_value, max_value):
    return _Strategy(lambda rng: rng.randint(min_value, max_value),
                     edges=(min_value, max_value))


def floats(min_value, max_value, **_):
    return _Strategy(lambda rng: rng.uniform(min_value, max_value),
                     edges=(min_value, max_value))


def booleans():
    return _Strategy(lambda rng: rng.random() < 0.5, edges=(False, True))


def sampled_from(elements):
    seq = list(elements)
    return _Strategy(lambda rng: rng.choice(seq), edges=(seq[0], seq[-1]))


def settings(max_examples: int = 20, deadline=None, **_):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(*arg_strats, **kw_strats):
    def deco(fn):
        # Like real hypothesis, positional strategies bind right-aligned to
        # the trailing parameters; leading parameters stay pytest fixtures.
        params = list(inspect.signature(fn).parameters.values())
        n_pos = len(arg_strats)
        pos_names = [p.name for p in params[len(params) - n_pos:]]
        remaining = params[: len(params) - n_pos]
        remaining = [p for p in remaining if p.name not in kw_strats]

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_stub_max_examples",
                        getattr(fn, "_stub_max_examples", 20))
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = random.Random(seed)
            for i in range(n):
                drawn = {name: s.draw(rng, i)
                         for name, s in zip(pos_names, arg_strats)}
                drawn.update((k, s.draw(rng, i))
                             for k, s in kw_strats.items())
                fn(*args, **kwargs, **drawn)

        # Hide strategy-provided parameters from pytest's fixture resolution.
        wrapper.__signature__ = inspect.Signature(remaining)
        del wrapper.__wrapped__
        return wrapper
    return deco


def install() -> None:
    """Register stub ``hypothesis`` / ``hypothesis.strategies`` modules."""
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    strategies = types.ModuleType("hypothesis.strategies")
    strategies.integers = integers
    strategies.floats = floats
    strategies.booleans = booleans
    strategies.sampled_from = sampled_from
    mod.strategies = strategies
    mod.__stub__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies
