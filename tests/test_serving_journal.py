"""Journaled serving registry: warm-restart recovery, checksummed records,
corruption surfacing.

Acceptance contract: a GraphServeEngine warm-restarted from the journal
serves predictions identical (<= 1e-12) to the pre-crash engine with zero
replans after warmup; a checksum-corrupted journal record is detected,
skipped, and surfaced in the recovery report.
"""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FastsumParams, make_kernel
from repro.graph import krr_fit
from repro.serving import (
    GraphModelRegistry, GraphServeEngine, PredictRequest, RegistryJournal,
    recover_registry,
)
from repro.serving import journal as journal_mod

PARAMS = FastsumParams(n_bandwidth=32, m=4)


@pytest.fixture(scope="module")
def models():
    rng = np.random.default_rng(11)
    xtr = jnp.asarray(rng.uniform(-3, 3, (150, 2)))
    ytr = jnp.asarray(np.sign(rng.standard_normal(150)))
    m_a = krr_fit(make_kernel("gaussian", sigma=1.0), xtr, ytr, 1e-2, PARAMS)
    m_b = krr_fit(make_kernel("gaussian", sigma=1.5), xtr, ytr, 1e-2, PARAMS)
    return {"a": m_a, "b": m_b}


def _journaled_registry(tmp_path, models):
    jpath = str(tmp_path / "registry.journal")
    reg = GraphModelRegistry(journal=RegistryJournal(jpath))
    for mid, model in models.items():
        reg.register(mid, model)
    return reg, jpath


def _serve_all(registry, queries):
    engine = GraphServeEngine(registry, slots=4, chunk=32)
    reqs = [PredictRequest(uid=i, model_id=mid, query_points=q)
            for i, (mid, q) in enumerate(queries)]
    for r in reqs:
        engine.submit(r)
    engine.run_until_drained()
    assert all(r.done and r.error is None for r in reqs), \
        [(r.uid, r.error) for r in reqs]
    return [r.output for r in reqs], engine


def test_record_roundtrip(models):
    rec = journal_mod.register_record("a", models["a"], margin=0.75)
    rec["crc"] = journal_mod.record_crc(rec)
    rt = json.loads(json.dumps(rec))
    assert journal_mod.record_crc(rt) == rt["crc"]
    model, domain, margin = journal_mod.decode_register(rt)
    np.testing.assert_array_equal(np.asarray(model.alpha),
                                  np.asarray(models["a"].alpha))
    np.testing.assert_array_equal(np.asarray(model.train_points),
                                  np.asarray(models["a"].train_points))
    assert model.kernel.name == "gaussian"
    assert float(model.kernel.params["sigma"]) == 1.0
    assert model.params == models["a"].params
    assert domain is None and margin == 0.75


def test_warm_restart_identical_predictions(tmp_path, models):
    """The acceptance test: kill the process (drop the registry), recover
    from the journal, and the warm-restarted engine serves identical
    predictions with zero replans after warmup."""
    reg, jpath = _journaled_registry(tmp_path, models)
    rng = np.random.default_rng(0)
    queries = [(mid, rng.uniform(-2.5, 2.5, (40, 2)))
               for mid in ("a", "b", "a")]
    out_before, _ = _serve_all(reg, queries)

    reg2, report = recover_registry(jpath)
    assert report.clean, report.summary()
    assert report.tenants == {"a": "recovered", "b": "recovered"}
    out_after, engine = _serve_all(reg2, queries)
    for before, after in zip(out_before, out_after):
        np.testing.assert_allclose(after, before, rtol=0, atol=1e-12)
    assert engine.counters["replans"] == 0
    # shared train points -> recovery rebuilt ONE plan for the group
    assert reg2.stats()["plan_builds"] == 1


def test_recovery_replay_appends_nothing(tmp_path, models):
    _, jpath = _journaled_registry(tmp_path, models)
    n_lines = len(open(jpath).read().splitlines())
    reg2, _ = recover_registry(jpath)
    assert len(open(jpath).read().splitlines()) == n_lines
    # ... but post-recovery registrations continue the same journal
    reg2.register("a2", models["a"])
    assert len(open(jpath).read().splitlines()) == n_lines + 1


def test_eviction_is_journaled_and_replayed(tmp_path, models):
    reg, jpath = _journaled_registry(tmp_path, models)
    assert reg.unregister("a")
    assert not reg.unregister("nope")
    assert reg.model_ids() == ["b"]
    reg2, report = recover_registry(jpath)
    assert reg2.model_ids() == ["b"]
    assert report.tenants["a"] == "evicted"
    assert report.tenants["b"] == "recovered"


def test_corrupt_record_detected_skipped_surfaced(tmp_path, models):
    """A bit-flipped journal record must cost exactly its tenant: the CRC
    catches it, replay skips it, the report surfaces it, and the sibling
    tenant recovers fully."""
    _, jpath = _journaled_registry(tmp_path, models)
    lines = open(jpath).read().splitlines()
    # flip one character inside the first (register "a") record's payload
    bad = lines[0].replace('"op":"register"', '"op":"registeR"', 1)
    with open(jpath, "w") as fh:
        fh.write("\n".join([bad] + lines[1:]) + "\n")

    reg, report = recover_registry(jpath)
    assert not report.clean
    assert report.records_skipped == 1
    assert any("checksum mismatch" in reason for _, reason in report.corrupt)
    assert reg.model_ids() == ["b"]
    assert "[DEGRADED]" in report.summary()


def test_torn_final_line_skipped(tmp_path, models):
    """A crash mid-append leaves a torn last line; replay must recover
    every complete record and surface the torn one."""
    _, jpath = _journaled_registry(tmp_path, models)
    with open(jpath, "a") as fh:
        fh.write('{"op":"register","model_id":"half')  # no newline, torn
    reg, report = recover_registry(jpath)
    assert sorted(reg.model_ids()) == ["a", "b"]
    assert report.records_skipped == 1
    assert any("unparseable" in reason for _, reason in report.corrupt)


def test_rebuild_group_appends_no_duplicate_records(tmp_path, models):
    """Internal re-registrations (corrupted-plan group rebuild) must not
    grow the journal — the source-of-truth records already exist."""
    reg, jpath = _journaled_registry(tmp_path, models)
    n_lines = len(open(jpath).read().splitlines())
    assert reg.rebuild_group("a")
    assert len(open(jpath).read().splitlines()) == n_lines


def test_missing_journal_recovers_empty(tmp_path):
    reg, report = recover_registry(str(tmp_path / "absent.journal"))
    assert reg.model_ids() == []
    assert report.records_total == 0 and report.clean
