"""Differentiable fastsum: adjoints, custom-VJP gradchecks, implicit diff.

Covers the ISSUE-8 satellite/acceptance surface:
  * spread/gather mutual-adjoint identity per window backend,
  * gradcheck of the fused matvec against central finite differences and
    against the dense ``direct_matvec_tiled`` oracle,
  * jit-safe operator construction (no silent ``rho = 1.0`` under tracing),
  * implicit-diff CG: primal parity, parameter/rhs gradients vs FD,
    quarantined (faulted) solves emitting zero — never NaN — cotangents,
  * KRR validation-loss gradients vs FD (all four kernels, d = 1..2),
  * ``krr_fit_grad`` recovering the ``krr_fit_sweep`` grid optimum,
  * a train step through ``nfft_attention`` with a learnable sigma.

Finite-difference comparisons assume x64 (enabled in conftest.py).
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FastsumParams, SETUP_2, direct_matvec_tiled, kernel_from_param,
    make_fastsum, make_kernel,
)
from repro.core import fastsum_exec
from repro.core.solvers import cg, cg_bank
from repro.graph import krr_fit_grad, krr_fit_sweep, krr_validation_loss

RNG = np.random.default_rng(11)

KERNELS = [
    ("gaussian", 3.5),
    ("laplacian_rbf", 2.0),
    ("multiquadric", 1.0),
    ("inverse_multiquadric", 1.0),
]


def _points(d, n, scale=2.0, rng=RNG):
    return jnp.asarray(rng.normal(size=(n, d)) * scale)


# ------------------------------------------------------- adjoint identities
@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("kname,kparam", KERNELS)
@pytest.mark.parametrize("d", [1, 2, 3])
def test_spread_gather_adjoint(kname, kparam, d, backend):
    """<spread(x), g> == <x, gather(g)> — the transpose the custom VJP uses.

    Off-TPU the explicit "pallas" backend runs in interpret mode, which is
    the bit-identical parity path for the TPU lowering.
    """
    kern = kernel_from_param(kname, kparam)
    pts = _points(d, 90)
    fs = make_fastsum(kern, pts, FastsumParams(n_bandwidth=8, m=2))
    plan, geom = fs.plan, fs.src_window
    x = jnp.asarray(RNG.normal(size=(pts.shape[0], 2)))
    g = jnp.asarray(RNG.normal(size=(plan.grid_size,) * d + (2,)))
    lhs = float(jnp.vdot(fastsum_exec.window_spread(
        plan, geom, x, backend=backend), g))
    rhs = float(jnp.vdot(x, fastsum_exec.window_gather(
        plan, geom, g, backend=backend)))
    assert abs(lhs - rhs) / max(abs(lhs), 1e-30) < 1e-12, (lhs, rhs)


# ----------------------------------------------------- fused-matvec gradcheck
def test_fused_matvec_input_gradient_is_transpose():
    """grad_x <c, W̃x> == W̃^T c == W̃c (symmetric operator): machine eps."""
    kern = make_kernel("gaussian", sigma=3.5)
    pts = _points(2, 200)
    fs = make_fastsum(kern, pts, FastsumParams(n_bandwidth=16, m=4))
    c = jnp.asarray(RNG.normal(size=(200,)))
    x = jnp.asarray(RNG.normal(size=(200,)))
    g = jax.grad(lambda v: jnp.vdot(c, fs.matvec_tilde(v)))(x)
    ref = fs.matvec_tilde(c)
    rel = float(jnp.max(jnp.abs(g - ref)) / jnp.max(jnp.abs(ref)))
    assert rel < 1e-12, rel


@pytest.mark.parametrize("kname,kparam", KERNELS)
@pytest.mark.parametrize("d", [1, 2])
def test_fused_matvec_param_gradcheck_vs_fd(kname, kparam, d):
    """d/dp sum(w * (W̃_p x)) via autodiff vs central finite differences."""
    pts = _points(d, 150)
    x = jnp.asarray(RNG.normal(size=(150,)))
    w = jnp.asarray(RNG.normal(size=(150,)))
    op = make_fastsum(kernel_from_param(kname, kparam), pts,
                      FastsumParams(n_bandwidth=16, m=4))

    def loss(p):
        return jnp.vdot(w, op.with_kernel(
            kernel_from_param(kname, p)).matvec_tilde(x))

    p0 = jnp.asarray(float(kparam))
    g = jax.grad(loss)(p0)
    eps = 1e-5 * float(kparam)
    fd = (loss(p0 + eps) - loss(p0 - eps)) / (2 * eps)
    rel = abs(float(g) - float(fd)) / max(abs(float(fd)), 1e-30)
    assert rel < 1e-6, (kname, d, float(g), float(fd), rel)


def test_fused_matvec_param_gradcheck_vs_dense_oracle():
    """Autodiff grad of the fused W matvec tracks the O(n^2) dense oracle.

    The fused operator applies the regularized, bandwidth-truncated K_RF;
    at SETUP_2 accuracy its sigma-gradient agrees with finite differences
    of the exact-kernel ``direct_matvec_tiled`` at approximation tolerance.
    """
    pts = _points(2, 150)
    x = jnp.asarray(RNG.normal(size=(150,)))
    w = jnp.asarray(RNG.normal(size=(150,)))
    op = make_fastsum(make_kernel("gaussian", sigma=3.5), pts, SETUP_2)

    def loss_fast(p):
        return jnp.vdot(w, op.with_kernel(
            make_kernel("gaussian", sigma=p)).matvec(x))

    def loss_dense(p):
        return jnp.vdot(w, direct_matvec_tiled(
            make_kernel("gaussian", sigma=float(p)), pts, x, tile=256))

    g = float(jax.grad(loss_fast)(jnp.asarray(3.5)))
    eps = 1e-4
    fd_dense = float((loss_dense(3.5 + eps) - loss_dense(3.5 - eps))
                     / (2 * eps))
    rel = abs(g - fd_dense) / max(abs(fd_dense), 1e-30)
    assert rel < 1e-4, (g, fd_dense, rel)


# ------------------------------------------------- jit-safe plan construction
def test_operator_construction_under_jit():
    """make_fastsum under jit (traced points/rho) == eager construction.

    Before the refactor the Tracer fail-soft branch silently used
    ``rho = 1.0``; points scaled well outside the admissible box make that
    substitution catastrophic, so parity here proves the fix.
    """
    kern = make_kernel("gaussian", sigma=3.5)
    pts = _points(2, 160, scale=5.0)
    x = jnp.asarray(RNG.normal(size=(160,)))
    params = FastsumParams(n_bandwidth=16, m=4)

    @jax.jit
    def traced(p, v):
        return make_fastsum(kern, p, params).matvec_tilde(v)

    eager = make_fastsum(kern, pts, params).matvec_tilde(x)
    jitted = traced(pts, x)
    rel = float(jnp.max(jnp.abs(jitted - eager)) / jnp.max(jnp.abs(eager)))
    assert rel < 1e-12, rel


# ------------------------------------------------------------ implicit-diff CG
def _spd_matvec(theta, scale):
    def mv(v):  # scale*I + 0.01*theta*C^T C with C = cumsum: SPD for theta>0
        t = jnp.cumsum(v, axis=0)
        return scale * v + 0.01 * theta * jnp.cumsum(t[::-1], axis=0)[::-1]
    return mv


def test_cg_implicit_diff_primal_parity():
    b = jnp.asarray(RNG.normal(size=(40,)))
    mv = _spd_matvec(jnp.asarray(1.3), 4.0)
    x_imp = cg(mv, b, tol=1e-12, implicit_diff=True).x
    x_pln = cg(mv, b, tol=1e-12, implicit_diff=False).x
    np.testing.assert_allclose(np.asarray(x_imp), np.asarray(x_pln),
                               rtol=0, atol=0)


def test_cg_implicit_diff_grads_vs_fd():
    """theta- and b-gradients through the solve match finite differences."""
    b = jnp.asarray(RNG.normal(size=(40,)))
    w = jnp.asarray(RNG.normal(size=(40,)))

    def loss(theta, rhs):
        return jnp.vdot(w, cg(_spd_matvec(theta, 4.0), rhs, tol=1e-13).x)

    th0 = jnp.asarray(1.3)
    g_th, g_b = jax.grad(loss, argnums=(0, 1))(th0, b)
    eps = 1e-6
    fd_th = (loss(th0 + eps, b) - loss(th0 - eps, b)) / (2 * eps)
    assert abs(float(g_th) - float(fd_th)) / abs(float(fd_th)) < 1e-6
    e0 = jnp.zeros_like(b).at[7].set(1.0)
    fd_b = (loss(th0, b + eps * e0) - loss(th0, b - eps * e0)) / (2 * eps)
    assert abs(float(g_b[7]) - float(fd_b)) / abs(float(fd_b)) < 1e-6


def test_cg_bank_implicit_diff_grads_vs_fd():
    bs = jnp.asarray(RNG.normal(size=(3, 30)))
    w = jnp.asarray(RNG.normal(size=(3, 30)))

    def loss(theta):
        mv = jax.vmap(_spd_matvec(theta, 4.0))
        return jnp.vdot(w, cg_bank(mv, bs, tol=1e-13).x)

    th0 = jnp.asarray(0.9)
    g = float(jax.grad(loss)(th0))
    eps = 1e-6
    fd = float((loss(th0 + eps) - loss(th0 - eps)) / (2 * eps))
    assert abs(g - fd) / abs(fd) < 1e-6, (g, fd)


def test_cg_quarantined_solve_emits_zero_cotangents():
    """A faulted (NaN-poisoned) solve must yield finite — zero — gradients."""
    b_bad = jnp.asarray(RNG.normal(size=(20,))).at[3].set(jnp.nan)

    def loss(theta):
        sol = cg(_spd_matvec(theta, 4.0), b_bad, tol=1e-10)
        return jnp.sum(jnp.where(jnp.isfinite(sol.x), sol.x, 0.0) ** 2)

    g = jax.grad(loss)(jnp.asarray(1.1))
    assert bool(jnp.isfinite(g)), float(g)
    assert float(jnp.abs(g)) == 0.0, float(g)


# --------------------------------------------------------------- KRR gradients
def _krr_problem(d, n_train=120, n_val=60, seed=7):
    rng = np.random.default_rng(seed)
    xtr = rng.uniform(-2, 2, (n_train, d))
    xva = rng.uniform(-2, 2, (n_val, d))
    fun = lambda x: np.sin(x[:, 0]) + (np.cos(2 * x[:, 1]) if d > 1 else 0.0)
    return (jnp.asarray(xtr), jnp.asarray(fun(xtr)),
            jnp.asarray(xva), jnp.asarray(fun(xva)))


# the multiquadric Gram matrix is conditionally negative definite — a large
# beta keeps K + beta I SPD so CG (and the implicit-diff bwd solve) converge
KRR_CASES = [
    ("gaussian", 0.8, 1e-2),
    ("laplacian_rbf", 0.8, 1e-2),
    ("multiquadric", 0.8, 50.0),
    ("inverse_multiquadric", 0.8, 1e-2),
]


@pytest.mark.parametrize("kname,sigma,beta", KRR_CASES)
@pytest.mark.parametrize("d", [1, 2])
def test_krr_validation_loss_gradcheck(kname, sigma, beta, d):
    """Acceptance: grad w.r.t. (log sigma, log beta) vs central FD, x64."""
    xtr, ftr, xva, fva = _krr_problem(d)
    params = FastsumParams(n_bandwidth=16, m=4)
    kern = kernel_from_param(kname, sigma)
    gram_op = make_fastsum(kern, xtr, params)
    pred_op = make_fastsum(kern, xtr, params, target_points=xva)

    def loss(ls, lb):
        return krr_validation_loss(kname, gram_op, pred_op, ftr, fva,
                                   ls, lb, tol=1e-12, maxiter=4000)

    ls0 = jnp.asarray(np.log(sigma))
    lb0 = jnp.asarray(np.log(beta))
    g_ls, g_lb = jax.grad(loss, argnums=(0, 1))(ls0, lb0)
    eps = 1e-5
    fd_ls = (loss(ls0 + eps, lb0) - loss(ls0 - eps, lb0)) / (2 * eps)
    fd_lb = (loss(ls0, lb0 + eps) - loss(ls0, lb0 - eps)) / (2 * eps)
    for g, fd in ((g_ls, fd_ls), (g_lb, fd_lb)):
        rel = abs(float(g) - float(fd)) / max(abs(float(fd)), 1e-12)
        assert rel < 1e-5, (kname, d, float(g), float(fd), rel)


def test_krr_fit_grad_recovers_sweep_optimum():
    """Gradient model selection lands within one grid cell of the sweep.

    A high-frequency target makes the validation loss sharply peaked in
    sigma, so the grid optimum is well-defined (a flat landscape would make
    "within one cell" meaningless).
    """
    from repro.graph import krr_predict
    from repro.graph.krr import krr_sweep_model

    rng = np.random.default_rng(3)
    n, n_val = 300, 120
    xtr = jnp.asarray(rng.uniform(-0.25, 0.25, (n, 1)))
    xva = jnp.asarray(rng.uniform(-0.25, 0.25, (n_val, 1)))
    truth = lambda p: jnp.sin(8 * p[:, 0]) + 0.3 * jnp.cos(20 * p[:, 0])
    ftr = truth(xtr) + 0.05 * jnp.asarray(rng.normal(size=n))
    fva = truth(xva)
    params = FastsumParams(n_bandwidth=32, m=4, eps_b=0.0)
    sigmas = [0.05, 0.1, 0.2, 0.4, 0.8]
    betas = [1e-4, 1e-3, 1e-2, 1e-1]
    sweep = krr_fit_sweep("gaussian", xtr, ftr, betas, sigmas, params,
                          tol=1e-10, maxiter=600)
    losses = np.zeros((len(sigmas), len(betas)))
    for i in range(len(sigmas)):
        for j in range(len(betas)):
            pred = krr_predict(krr_sweep_model(sweep, i, j), xva)
            losses[i, j] = float(jnp.mean((pred - fva) ** 2))
    i_best, j_best = np.unravel_index(np.argmin(losses), losses.shape)

    res = krr_fit_grad("gaussian", xtr, ftr, xva, fva, params,
                       init_sigma=0.4, init_beta=1e-2, steps=25, lr=0.3,
                       tol=1e-10, maxiter=600)
    # within one log-grid cell of the grid optimum, and no worse than the
    # best grid loss by more than a grid-resolution factor
    cell_ls = np.log(sigmas[1]) - np.log(sigmas[0])
    dist = abs(np.log(res.sigma) - np.log(sigmas[i_best])) / cell_ls
    assert dist <= 1.0, (res.sigma, sigmas[i_best], dist)
    assert res.val_loss <= 1.5 * losses[i_best, j_best], (
        res.val_loss, losses[i_best, j_best])


def test_krr_grad_finite_through_guarded_path():
    """A poisoned training vector faults the solve; grads stay finite."""
    xtr, ftr, xva, fva = _krr_problem(1)
    ftr = ftr.at[5].set(jnp.nan)
    params = FastsumParams(n_bandwidth=16, m=4)
    kern = make_kernel("gaussian", sigma=0.5)
    gram_op = make_fastsum(kern, xtr, params)
    pred_op = make_fastsum(kern, xtr, params, target_points=xva)

    g_ls, g_lb = jax.grad(
        lambda ls, lb: krr_validation_loss(
            "gaussian", gram_op, pred_op, ftr, fva, ls, lb, tol=1e-10),
        argnums=(0, 1))(jnp.asarray(np.log(0.5)), jnp.asarray(np.log(1e-2)))
    assert bool(jnp.isfinite(g_ls)), float(g_ls)
    assert bool(jnp.isfinite(g_lb)), float(g_lb)


# ------------------------------------------------ learnable-sigma attention
def test_nfft_attention_learn_sigma_train_step():
    """One train step: finite grads for every leaf, log_sigma included."""
    from repro.configs import get_config, reduced_config
    from repro.data.pipeline import batch_for_step
    from repro.models import model as M
    from repro.training.train_loop import (
        TrainConfig, init_train_state, make_train_step)

    cfg = reduced_config(get_config("granite-3-2b-nfft"))
    cfg = dataclasses.replace(
        cfg, nfft_attention=dataclasses.replace(
            cfg.nfft_attention, learn_sigma=True))
    tc = TrainConfig(num_microbatches=1)
    state = init_train_state(jax.random.PRNGKey(0), cfg, tc)

    sigma_leaves = [p for p in jax.tree_util.tree_leaves_with_path(
        state.params) if "log_sigma" in jax.tree_util.keystr(p[0])]
    assert sigma_leaves, "learn_sigma did not add a log_sigma param leaf"

    batch = jax.tree.map(jnp.asarray, batch_for_step(cfg, cfg.shapes[0], 0))
    grads = jax.grad(
        lambda p: M.forward_train(p, cfg, batch)[0])(state.params)
    for path, leaf in jax.tree_util.tree_leaves_with_path(grads):
        assert bool(jnp.all(jnp.isfinite(leaf))), jax.tree_util.keystr(path)
    g_sigma = [leaf for path, leaf in jax.tree_util.tree_leaves_with_path(
        grads) if "log_sigma" in jax.tree_util.keystr(path)]
    assert g_sigma and bool(jnp.any(g_sigma[0] != 0.0))

    step = jax.jit(make_train_step(cfg, tc))
    new_state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    moved = jax.tree_util.tree_leaves_with_path(new_state.params)
    old = dict(jax.tree_util.tree_leaves_with_path(state.params))
    changed = any("log_sigma" in jax.tree_util.keystr(path)
                  and bool(jnp.any(leaf != old[path]))
                  for path, leaf in moved)
    assert changed, "optimizer did not move log_sigma"
