"""Causality property tests: for causal architectures, logits at position p
must be invariant to any perturbation of tokens at positions > p.

This is the strongest single invariant across the mixer zoo — it catches
mask bugs in GQA/MLA attention, decay-segment bugs in the Mamba2 SSD, and
prefix-sum bugs in the NFFT kernel attention with one assertion.  The
encoder (hubert) is checked for the OPPOSITE: bidirectional mixing.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.models import model as M

CAUSAL_ARCHS = ["granite-3-2b", "deepseek-v3-671b", "mamba2-1.3b",
                "jamba-1.5-large-398b", "granite-3-2b-nfft", "olmoe-1b-7b"]


def _logits(params, cfg, tokens):
    batch = {"tokens": tokens, "labels": tokens}
    x, positions, prefix_len = M.embed_inputs(params, cfg, batch)
    h, _, _ = M._run_backbone(params, cfg, x, positions, mode="train",
                              prefix_len=prefix_len)
    return M.lm_logits(params, cfg, h)


@pytest.mark.parametrize("name", CAUSAL_ARCHS)
def test_future_tokens_dont_affect_past(name):
    cfg = reduced_config(get_config(name))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    b, s, cut = 2, 32, 17
    rng = np.random.default_rng(5)
    toks = rng.integers(0, cfg.vocab_size, (b, s)).astype(np.int32)
    toks2 = toks.copy()
    toks2[:, cut:] = rng.integers(0, cfg.vocab_size, (b, s - cut))

    la = _logits(params, cfg, jnp.asarray(toks))
    lb = _logits(params, cfg, jnp.asarray(toks2))
    diff_past = float(jnp.abs(la[:, :cut] - lb[:, :cut]).max())
    assert diff_past < 1e-4, (name, diff_past)
    # sanity: the perturbation must actually change future logits
    diff_future = float(jnp.abs(la[:, cut:] - lb[:, cut:]).max())
    assert diff_future > 1e-4, (name, "perturbation had no effect at all")


def test_encoder_is_bidirectional():
    cfg = reduced_config(get_config("hubert-xlarge"))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    b, s, cut = 2, 16, 9
    rng = np.random.default_rng(6)
    embeds = rng.standard_normal((b, s, cfg.frontend_dim)).astype(np.float32)
    embeds2 = embeds.copy()
    embeds2[:, cut:] += rng.standard_normal((b, s - cut, cfg.frontend_dim))

    def logits(e):
        batch = {"embeds": jnp.asarray(e),
                 "labels": jnp.zeros((b, s), jnp.int32)}
        x, positions, _ = M.embed_inputs(params, cfg, batch)
        h, _, _ = M._run_backbone(params, cfg, x, positions, mode="train")
        return M.lm_logits(params, cfg, h)

    la, lb = logits(embeds), logits(embeds2)
    # encoder: future frames DO affect earlier positions
    assert float(jnp.abs(la[:, :cut] - lb[:, :cut]).max()) > 1e-4


def test_paligemma_prefix_lm_mask():
    """Image prefix is bidirectional; text suffix stays causal."""
    cfg = reduced_config(get_config("paligemma-3b"))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    b, text = 2, 24
    npx = cfg.num_prefix_embeds
    rng = np.random.default_rng(7)
    img = rng.standard_normal((b, npx, cfg.frontend_dim)).astype(np.float32)
    toks = rng.integers(0, cfg.vocab_size, (b, text)).astype(np.int32)

    def logits(image, tokens):
        batch = {"image_embeds": jnp.asarray(image),
                 "tokens": jnp.asarray(tokens),
                 "labels": jnp.asarray(tokens)}
        x, positions, prefix_len = M.embed_inputs(params, cfg, batch)
        h, _, _ = M._run_backbone(params, cfg, x, positions, mode="train",
                                  prefix_len=prefix_len)
        return M.lm_logits(params, cfg, h)

    base = logits(img, toks)
    # 1) perturbing a LATE image patch changes EARLY image positions
    img2 = img.copy()
    img2[:, -1] += 1.0
    alt = logits(img2, toks)
    assert float(jnp.abs(base[:, :2] - alt[:, :2]).max()) > 1e-4
    # 2) perturbing late TEXT must not change earlier text logits
    cut = 10
    toks2 = toks.copy()
    toks2[:, cut:] = rng.integers(0, cfg.vocab_size, (b, text - cut))
    alt2 = logits(img, toks2)
    text_logits_a = base[:, npx:npx + cut]
    text_logits_b = alt2[:, npx:npx + cut]
    assert float(jnp.abs(text_logits_a - text_logits_b).max()) < 1e-4
