"""Shared test configuration.

NOTE: do NOT set XLA_FLAGS / host device count here — smoke tests and
benchmarks must see the single real CPU device.  Multi-device sharding tests
spawn subprocesses with their own XLA_FLAGS (see tests/test_distributed.py).
"""

import jax

# The paper's accuracy claims (1e-14 eigenvalue errors) require float64.
jax.config.update("jax_enable_x64", True)
