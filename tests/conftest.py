"""Shared test configuration.

NOTE: do NOT set XLA_FLAGS / host device count here — smoke tests and
benchmarks must see the single real CPU device.  Multi-device sharding tests
spawn subprocesses with their own XLA_FLAGS (see tests/test_distributed.py);
they carry the ``multidevice`` marker, so a quick local run can skip them
with ``pytest -m "not multidevice"``.
"""

import jax

# The paper's accuracy claims (1e-14 eigenvalue errors) require float64.
jax.config.update("jax_enable_x64", True)

try:
    import hypothesis  # noqa: F401  — real package, if installed
except ImportError:  # container without the `test` extra: use the stub
    import _hypothesis_stub
    _hypothesis_stub.install()


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "multidevice: spawns subprocesses with "
        "XLA_FLAGS=--xla_force_host_platform_device_count=N (slow); "
        "deselect with -m 'not multidevice' for quick local runs")
    config.addinivalue_line(
        "markers",
        "chaos: fault-injection suite (repro.runtime.faultinject) — drives "
        "solvers and the serving engine through seeded failures and asserts "
        "recovery, isolation, and counters; run with -m chaos")
