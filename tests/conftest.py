"""Shared test configuration.

NOTE: do NOT set XLA_FLAGS / host device count here — smoke tests and
benchmarks must see the single real CPU device.  Multi-device sharding tests
spawn subprocesses with their own XLA_FLAGS (see tests/test_distributed.py);
they carry the ``multidevice`` marker, so a quick local run can skip them
with ``pytest -m "not multidevice"``.
"""

import jax
import pytest

# The paper's accuracy claims (1e-14 eigenvalue errors) require float64.
jax.config.update("jax_enable_x64", True)

# Every XLA:CPU executable JAX caches holds ~3 anonymous mmaps (code page +
# rodata + guard), and the cache lives for the whole pytest process.  The
# full suite compiles tens of thousands of programs, which walks the process
# straight into the kernel's vm.max_map_count ceiling (65530 by default) —
# past it, mmap fails inside XLA's compiler and the interpreter segfaults.
# Dropping the caches when the map count gets close trades a handful of
# recompiles for a bounded map footprint.
_MAP_COUNT_SOFT_LIMIT = 40_000


@pytest.fixture(autouse=True)
def _bound_xla_map_count():
    yield
    try:
        with open("/proc/self/maps") as f:
            n_maps = sum(1 for _ in f)
    except OSError:  # non-Linux: no procfs, and no 65530 default either
        return
    if n_maps > _MAP_COUNT_SOFT_LIMIT:
        jax.clear_caches()

try:
    import hypothesis  # noqa: F401  — real package, if installed
except ImportError:  # container without the `test` extra: use the stub
    import _hypothesis_stub
    _hypothesis_stub.install()


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "multidevice: spawns subprocesses with "
        "XLA_FLAGS=--xla_force_host_platform_device_count=N (slow); "
        "deselect with -m 'not multidevice' for quick local runs")
    config.addinivalue_line(
        "markers",
        "chaos: fault-injection suite (repro.runtime.faultinject) — drives "
        "solvers and the serving engine through seeded failures and asserts "
        "recovery, isolation, and counters; run with -m chaos")
