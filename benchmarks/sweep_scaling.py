"""Hyperparameter-sweep scaling — the operator-bank execution path (PR 5).

Times a model-selection grid of kernel ridge regression — S = 8 sigmas x 2
betas at n = 50k nodes — end to end, two ways:

* **sequential** — 16 independent ``krr_fit`` calls, what a model-selection
  loop looked like before the bank: each fit pays its own operator setup
  (kernel Fourier coefficients + spectral multiplier), its own eager-CG
  trace, and ``iters`` full fused matvecs.
* **bank** — one ``krr_fit_sweep``: a single :class:`FastsumOperatorBank`
  (plan/geometry shared, one multiplier per sigma) driven by lockstep
  per-column CG in the flat bank-major column layout
  (``matvec_tilde_columns``) — every iteration runs ONE spread, ONE forward
  rfftn, S spectral multiplies, one batched inverse transform, and one
  multi-channel gather for all S·B systems, with per-system tolerance masks
  freezing converged cells; the beta axis rides the channel lanes for the
  price of channels, not pipelines.

The bank's advantage is largest where the matvec is overhead-dominated
(small taps^d: d = 1, then d = 2) and shrinks as the window step becomes
madd-bound (d = 3: taps^3 = 729 madds/node/channel scale linearly in S·B
on CPU).  The per-d speedups are recorded — not averaged away — in
``BENCH_sweep.json`` (path overridable via REPRO_BENCH_SWEEP_JSON), the
trajectory artifact future PRs regress against.  Alphas from the two paths
are cross-checked to 1e-6 relative before any timing is reported.
"""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Reporter, quick
from repro.core import FastsumParams, make_fastsum, make_kernel
from repro.data.synthetic import crescent_fullmoon, spiral
from repro.graph import krr_fit, krr_fit_sweep

BENCH_JSON = os.environ.get("REPRO_BENCH_SWEEP_JSON", "BENCH_sweep.json")

N_NODES = 50_000
N_SIGMAS = 8
TOL = 1e-8
MAXITER = 600

# Per-dimension sweep configs: a fine sigma grid around a plausible center
# (the grid-refinement step of model selection) x two ridge strengths.  The
# bandwidth follows the paper's per-d practice (higher N at low d, where
# the grid is cheap and the kernel needs resolving); beta is chosen so CG
# converges in ~1e2 iterations — lightly regularized KRR, the regime where
# model selection actually operates.
CONFIGS = {
    1: dict(params=FastsumParams(n_bandwidth=64, m=4),
            sigma_scale=1.0, betas=(0.02, 0.08)),
    2: dict(params=FastsumParams(n_bandwidth=32, m=4),
            sigma_scale=1.0, betas=(10.0, 40.0)),
    3: dict(params=FastsumParams(n_bandwidth=32, m=4),
            sigma_scale=3.0, betas=(100.0, 400.0)),
}


def _dataset(d: int, n: int):
    rng = np.random.default_rng(7)
    if d == 1:
        x = np.sort(rng.normal(size=(n, 1)) * 2.0, axis=0)
    elif d == 2:
        x, _ = crescent_fullmoon(n, seed=2)
    else:
        x, _ = spiral(n, seed=2)
    x = np.asarray(x)
    # smooth regression target + noise (the solve cost only depends on the
    # operator spectrum, but a plausible f keeps the workload honest)
    f = np.sin(3.0 * x[:, 0]) + 0.1 * rng.standard_normal(n)
    return jnp.asarray(x), jnp.asarray(f)


def run(report: Reporter | None = None) -> None:
    rep = report or Reporter("sweep_scaling")
    dims = (1, 2) if quick() else (1, 2, 3)
    records: list[dict] = []

    for d in dims:
        cfg = CONFIGS[d]
        params, betas = cfg["params"], cfg["betas"]
        sigmas = tuple(float(s) for s in
                       cfg["sigma_scale"] * np.geomspace(0.8, 1.25, N_SIGMAS))
        pts, f = _dataset(d, N_NODES)
        n_systems = len(sigmas) * len(betas)

        # Warm the *shared* plan-time jit caches (geometry build at these
        # shapes) so neither path is billed for the other's first-compile;
        # each path still pays its own CG trace/compile — that asymmetry is
        # exactly what the bank amortizes and belongs in the measurement.
        warm = make_fastsum(make_kernel("gaussian", sigma=sigmas[0] * 1.01),
                            pts, params)
        jax.block_until_ready(warm.matvec_tilde(f))

        t0 = time.perf_counter()
        seq_alphas, seq_iters = {}, []
        for i, s in enumerate(sigmas):
            for j, b in enumerate(betas):
                model = krr_fit(make_kernel("gaussian", sigma=s), pts, f, b,
                                params, tol=TOL, maxiter=MAXITER)
                jax.block_until_ready(model.alpha)
                seq_alphas[i, j] = model.alpha
                seq_iters.append(int(model.num_iters))
        t_seq = time.perf_counter() - t0

        t0 = time.perf_counter()
        sweep = krr_fit_sweep("gaussian", pts, f, betas, sigmas, params,
                              tol=TOL, maxiter=MAXITER)
        jax.block_until_ready(sweep.alphas)
        t_bank = time.perf_counter() - t0

        # correctness guard: both paths solved the same systems
        rel = max(
            float(jnp.max(jnp.abs(sweep.alphas[i, :, j] - a))
                  / jnp.maximum(jnp.max(jnp.abs(a)), 1e-30))
            for (i, j), a in seq_alphas.items())
        # two independent CG runs agree only to ~residual/beta relative
        # (attainable accuracy at tol=1e-8, beta=2e-2), not machine eps
        assert rel < 1e-5, f"bank/sequential alpha divergence: {rel}"
        assert bool(jnp.all(sweep.converged)), "bank sweep did not converge"

        speedup = t_seq / t_bank
        rep.add(f"sequential d={d} n={N_NODES} grid={N_SIGMAS}x{len(betas)}",
                t_seq, "s", iters=sum(seq_iters))
        rep.add(f"bank d={d} n={N_NODES} grid={N_SIGMAS}x{len(betas)}",
                t_bank, "s",
                iters=int(np.max(np.asarray(sweep.num_iters))))
        rep.add(f"speedup d={d}", speedup, "x")
        base = {"d": d, "n": N_NODES, "S": N_SIGMAS, "betas": len(betas),
                "systems": n_systems,
                "n_bandwidth": params.n_bandwidth}
        records.append(dict(base, path="sequential", seconds=t_seq,
                            iters_total=sum(seq_iters)))
        records.append(dict(
            base, path="bank", seconds=t_bank,
            iters_max=int(np.max(np.asarray(sweep.num_iters))),
            speedup=round(speedup, 2), alpha_parity=rel))

    rep.save()
    with open(BENCH_JSON, "w") as fh:
        json.dump({"bench": "sweep_scaling", "unit": "s", "quick": quick(),
                   "tol": TOL, "maxiter": MAXITER, "rows": records}, fh,
                  indent=1)
    print(f"wrote {BENCH_JSON} ({len(records)} rows)")


if __name__ == "__main__":
    run()
