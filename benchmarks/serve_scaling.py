"""Graph-predict serving scaling — batched ticks vs per-request predicts.

Serves a burst of concurrent prediction requests (fresh query points per
request, two tenant models sharing one training set) two ways:

* **sequential** — one ``krr_predict`` per request, the pre-engine serving
  path: every request's target set is new, so each call re-plans a full
  prediction operator (joint source+target rescale, kernel Fourier
  coefficients, spectral multiplier, source geometry) before its gather.
  Request latency is the time-to-completion with all requests queued at
  t=0: request i waits for requests 0..i-1.
* **engine** — one :class:`~repro.serving.GraphServeEngine` over a
  :class:`~repro.serving.GraphModelRegistry`: the tenants' grids are built
  once at warmup (one bank transform), then every tick packs the active
  slots' query chunks into ONE O(m) target geometry + ONE ragged gather.
  Steady state replans nothing — asserted against the registry's build
  counters before any timing is reported.

``BENCH_serve.json`` (path overridable via REPRO_BENCH_SERVE_JSON) records
p50/p99 latency and requests/s throughput for both paths plus the speedup,
the trajectory artifact future serving PRs regress against.  Outputs of
the two paths are cross-checked before timing counts.
"""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Reporter, quick
from repro.core import FastsumParams, make_kernel
from repro.graph import krr_fit, krr_predict
from repro.serving import GraphModelRegistry, GraphServeEngine, PredictRequest

BENCH_JSON = os.environ.get("REPRO_BENCH_SERVE_JSON", "BENCH_serve.json")

PARAMS = FastsumParams(n_bandwidth=64, m=4)
SIGMAS = (1.0, 1.5)  # two tenants sharing the training set
REG = 1e-2


def _requests(rng, n_requests: int, m_query: int):
    """Concurrent burst: fresh query points, tenants round-robin."""
    return [(f"tenant{i % len(SIGMAS)}",
             rng.uniform(-2.5, 2.5, (m_query, 2)))
            for i in range(n_requests)]


def run(report: Reporter | None = None) -> None:
    rep = report or Reporter("serve_scaling")
    if quick():
        n_train, n_requests, m_query = 4_000, 32, 128
    else:
        n_train, n_requests, m_query = 20_000, 64, 256
    slots, chunk = 8, m_query  # one tick per request chunk

    rng = np.random.default_rng(3)
    xtr = jnp.asarray(rng.uniform(-3, 3, (n_train, 2)))
    ytr = jnp.asarray(np.sign(rng.standard_normal(n_train)))
    models = {f"tenant{i}": krr_fit(make_kernel("gaussian", sigma=s),
                                    xtr, ytr, REG, PARAMS)
              for i, s in enumerate(SIGMAS)}
    burst = _requests(rng, n_requests, m_query)

    # -- sequential baseline -------------------------------------------------
    # warm the per-shape jit caches so neither path pays first-compile in
    # the timed region; the per-request RE-PLAN (new target set every
    # request) stays in the measurement — that is the cost under test
    jax.block_until_ready(krr_predict(
        models["tenant0"], jnp.asarray(rng.uniform(-2.5, 2.5,
                                                   (m_query, 2)))))
    seq_out, seq_latency = [], []
    t0 = time.perf_counter()
    for mid, q in burst:
        out = krr_predict(models[mid], jnp.asarray(q))
        jax.block_until_ready(out)
        seq_out.append(np.asarray(out))
        seq_latency.append(time.perf_counter() - t0)  # queued-at-t0 latency
    t_seq = time.perf_counter() - t0

    # -- batched engine ------------------------------------------------------
    registry = GraphModelRegistry()
    for mid, model in models.items():
        registry.register(mid, model)
    engine = GraphServeEngine(registry, slots=slots, chunk=chunk)
    # warmup tick: builds both tenants' grids (ONE bank transform) and
    # compiles the packed geometry+gather bodies at their fixed shapes
    for i, (mid, _) in enumerate(burst[:2]):
        engine.submit(PredictRequest(uid=-1 - i, model_id=mid,
                                     query_points=rng.uniform(
                                         -2.5, 2.5, (m_query, 2))))
    engine.run_until_drained()
    warm = registry.stats()

    reqs = [PredictRequest(uid=i, model_id=mid, query_points=q)
            for i, (mid, q) in enumerate(burst)]
    t0 = time.perf_counter()
    for r in reqs:
        engine.submit(r)
    engine.run_until_drained()
    t_eng = time.perf_counter() - t0

    # correctness + zero-replan guards BEFORE reporting any timing
    steady = registry.stats()
    assert steady["grid_builds"] == warm["grid_builds"], \
        "engine re-planned during the timed burst"
    assert all(r.done and r.error is None for r in reqs)
    parity = max(
        float(np.max(np.abs(r.output - ref)) / max(np.max(np.abs(ref)), 1e-30))
        for r, ref in zip(reqs, seq_out))
    assert parity < 1e-2, f"engine/sequential divergence: {parity}"

    eng_latency = [r.latency for r in reqs]
    rows = []
    for path, total, lats in (("sequential", t_seq, seq_latency),
                              ("engine", t_eng, eng_latency)):
        thr = n_requests / total
        p50, p99 = (float(np.percentile(lats, p)) for p in (50, 99))
        rep.add(f"{path} n={n_train} r={n_requests} m={m_query}",
                thr, "req/s", p50_ms=round(p50 * 1e3, 2),
                p99_ms=round(p99 * 1e3, 2))
        rows.append({"path": path, "n_train": n_train,
                     "requests": n_requests, "m_query": m_query,
                     "slots": slots, "seconds": total,
                     "throughput_rps": thr, "p50_s": p50, "p99_s": p99})
    speedup = rows[1]["throughput_rps"] / rows[0]["throughput_rps"]
    rows[1]["speedup"] = round(speedup, 2)
    rows[1]["parity"] = parity
    rows[1]["ticks"] = engine.counters["ticks"]
    rows[1]["grid_builds_timed"] = steady["grid_builds"] - warm["grid_builds"]
    rep.add("speedup", speedup, "x", requests=n_requests)
    assert speedup >= 3.0, \
        f"batched serving speedup {speedup:.2f}x < 3x at {n_requests} reqs"

    # -- guard overhead ------------------------------------------------------
    # the runtime guards (deadline checks, non-finite output scan, circuit
    # breaker, plan validation) ride the hot tick path; the acceptance gate
    # is that guarded throughput stays within 5% of unguarded.  Best-of-3
    # per mode keeps scheduler noise out of the ratio; registry grids are
    # already warm so both modes time pure tick work.
    def _burst_time(guards: bool) -> float:
        eng = GraphServeEngine(registry, slots=slots, chunk=chunk,
                               guards=guards)
        eng.submit(PredictRequest(uid=-9, model_id="tenant0",
                                  query_points=rng.uniform(
                                      -2.5, 2.5, (m_query, 2))))
        eng.run_until_drained()  # compile warmup for this engine
        best = float("inf")
        for rep_i in range(3):
            rs = [PredictRequest(uid=1000 * rep_i + i, model_id=mid,
                                 query_points=q)
                  for i, (mid, q) in enumerate(burst)]
            t0 = time.perf_counter()
            for r in rs:
                eng.submit(r)
            eng.run_until_drained()
            best = min(best, time.perf_counter() - t0)
            assert all(r.done and r.error is None for r in rs)
        return best

    t_unguarded = _burst_time(False)
    t_guarded = _burst_time(True)
    overhead = t_guarded / t_unguarded - 1.0
    rows.append({"path": "guard_overhead", "n_train": n_train,
                 "requests": n_requests, "m_query": m_query,
                 "guarded_s": t_guarded, "unguarded_s": t_unguarded,
                 "overhead_frac": round(overhead, 4)})
    rep.add("guard overhead", overhead * 100.0, "%", requests=n_requests)
    assert overhead <= 0.05, \
        f"runtime guards cost {overhead * 100:.1f}% > 5% of tick throughput"

    rep.save()
    with open(BENCH_JSON, "w") as fh:
        json.dump({"bench": "serve_scaling", "unit": "req/s",
                   "quick": quick(), "rows": rows}, fh, indent=1)
    print(f"wrote {BENCH_JSON} ({len(rows)} rows)")


if __name__ == "__main__":
    run()
