"""Figure 9 — kernel ridge regression with Gaussian and inverse multiquadric.

Paper protocol (Section 6.3): alpha = (K + beta I)^{-1} f via preconditioned
CG with NFFT matvecs on the Gram matrix K (diagonal = K(0), i.e. W̃); the
decision function F(x) = sum_i alpha_i K(x_i, x) classifies a 2-D two-class
set; both kernels should give a clean decision boundary.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Reporter, quick, timeit
from repro.core import FastsumParams, make_kernel
from repro.data.synthetic import crescent_fullmoon
from repro.graph.krr import krr_fit, krr_predict, krr_predict_direct

# sigma/c = 2.0 on data spanning radius ~13 -> box-scaled sigma ~0.04,
# resolved by N = 256 (2-D grid, 65k coefficients); beta = 1e-2 keeps the
# Gram system well-conditioned (CG converges in a few hundred iterations,
# keeping ||alpha||_1 — the Eq. (3.5) error amplifier — bounded).
PARAMS = FastsumParams(n_bandwidth=256, m=5, eps_b=None)
BETA = 1e-2


def run(report: Reporter | None = None) -> None:
    rep = report or Reporter("fig9_krr")
    n = 1000 if quick() else 10000
    n_test = 400
    points, labels = crescent_fullmoon(n + n_test, seed=5)
    x_train = jnp.asarray(points[:n])
    y_train = jnp.asarray(2.0 * labels[:n] - 1.0)
    x_test = jnp.asarray(points[n:])
    y_test = np.asarray(labels[n:])

    for kernel_name, sigma in (("gaussian", 2.0),
                               ("inverse_multiquadric", 2.0)):
        kern = (make_kernel(kernel_name, sigma=sigma)
                if kernel_name == "gaussian"
                else make_kernel(kernel_name, c=sigma))

        def fit(kern=kern):
            return krr_fit(kern, x_train, y_train, BETA, PARAMS,
                           tol=1e-8, maxiter=2000)
        t_fit, model = timeit(fit, repeats=1)
        pred = krr_predict(model, x_test)
        acc = float(np.mean((np.asarray(pred) > 0) == (y_test == 1)))
        rep.add(f"{kernel_name} test-accuracy", acc, "frac",
                fit_time=f"{t_fit:.2f}s", cg_iters=int(model.num_iters))
        # fast prediction vs direct oracle
        direct = krr_predict_direct(model, x_test)
        err = float(jnp.max(jnp.abs(pred - direct))
                    / jnp.maximum(jnp.max(jnp.abs(direct)), 1e-30))
        rep.add(f"{kernel_name} predict-vs-direct relerr", err, "rel")
    rep.save()


if __name__ == "__main__":
    run()
