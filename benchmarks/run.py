"""Benchmark aggregator: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--only fig3,...] [--full]``

Default sizes are CPU-scaled (quick mode); set REPRO_BENCH_FULL=1 or --full
for the paper-scale protocol (hours).
"""

from __future__ import annotations

import argparse
import os
import sys
import time
import traceback

MODULES = [
    ("fig3", "benchmarks.fig3_eigenvalues",
     "Fig 3: eigenvalue accuracy + runtime (NFFT-Lanczos / Nyström / hybrid)"),
    ("fig5", "benchmarks.fig5_segmentation",
     "Fig 5: image segmentation via spectral clustering"),
    ("fig6", "benchmarks.fig6_phasefield",
     "Fig 6: Allen-Cahn phase-field SSL accuracy"),
    ("fig7", "benchmarks.fig7_kernel_ssl",
     "Fig 7: kernel SSL misclassification (Gaussian)"),
    ("fig8", "benchmarks.fig8_kernel_ssl_laplacian",
     "Fig 8: kernel SSL misclassification (Laplacian RBF)"),
    ("fig9", "benchmarks.fig9_krr",
     "Fig 9: kernel ridge regression decision boundaries"),
    ("scaling", "benchmarks.matvec_scaling",
     "Fig 3d core claim: O(n) NFFT matvec vs O(n^2) direct"),
    ("sweep", "benchmarks.sweep_scaling",
     "Operator-bank sigma sweep: lockstep bank CG vs sequential solves"),
    ("grad", "benchmarks.grad_scaling",
     "Differentiable fastsum: value-and-grad step vs forward-only matvec"),
    ("roofline", "benchmarks.roofline_report",
     "Roofline tables from the multi-pod dry-run"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list of keys: fig3,fig5,...,scaling,roofline")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (slow)")
    args = ap.parse_args()
    if args.full:
        os.environ["REPRO_BENCH_FULL"] = "1"

    keys = args.only.split(",") if args.only else [k for k, _, _ in MODULES]
    failures = []
    for key, module, desc in MODULES:
        if key not in keys:
            continue
        print(f"\n=== {key}: {desc} ===", flush=True)
        t0 = time.perf_counter()
        try:
            mod = __import__(module, fromlist=["run"])
            mod.run()
            print(f"--- {key} done in {time.perf_counter() - t0:.1f}s")
        except Exception:  # noqa: BLE001
            failures.append(key)
            traceback.print_exc()
    if failures:
        print(f"\nFAILED benchmarks: {failures}")
        sys.exit(1)
    print("\nall benchmarks complete")


if __name__ == "__main__":
    main()
