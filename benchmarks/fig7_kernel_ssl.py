"""Figures 7 & 8 — kernel SSL on crescent-fullmoon, Gaussian + Laplacian RBF.

Paper protocol (Section 6.2.3): solve (I + beta L_s) u = f by CG (tol 1e-4,
maxiter 1000) with NFFT matvecs; n = 100,000 (CPU-scaled here), sigma = 0.1
Gaussian (Fig. 7) and sigma = 0.05 Laplacian RBF (Fig. 8);
s in {1,2,5,10,25} samples/class, beta in {1e3, 3e3, 1e4, 3e4, 1e5}.
Metric: misclassification rate of sign(u).

Claims reproduced: rates decrease with s; best around beta ~ 1e4; Laplacian
RBF gives comparable rates (the method is kernel-agnostic).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Reporter, quick
from repro.core import FastsumParams, make_kernel, make_normalized_adjacency
from repro.data.synthetic import crescent_fullmoon
from repro.graph.ssl import kernel_ssl_cg, make_training_vector


def run_kernel(rep: Reporter, kernel_name: str, sigma: float,
               params: FastsumParams, tag: str) -> None:
    # Our crescent coordinates differ from the paper's MATLAB generator by a
    # scale factor, which shifts the optimal beta ~10x down (beta multiplies
    # L_s whose spectrum depends on the kernel width relative to the data
    # diameter).  The protocol (grid shapes, trends) is unchanged.
    n = 2000 if quick() else 20000
    samples = (1, 5, 25) if quick() else (1, 2, 5, 10, 25)
    betas = (1e2, 1e3, 1e4) if quick() else (1e2, 3e2, 1e3, 3e3, 1e4)
    instances = 2 if quick() else 5
    reps_per = 2 if quick() else 10

    kernel = make_kernel(kernel_name, sigma=sigma)
    for s in samples:
        for beta in betas:
            rates = []
            iters = []
            for inst in range(instances):
                points, labels = crescent_fullmoon(n, seed=60 + inst)
                pts = jnp.asarray(points)
                labs = jnp.asarray(labels)
                op = make_normalized_adjacency(kernel, pts, params)
                for r in range(reps_per):
                    key = jax.random.PRNGKey(17 * inst + r)
                    f, _ = make_training_vector(labs, s, 2, key=key,
                                                positive_class=1)
                    res = kernel_ssl_cg(op, f, beta, tol=1e-4, maxiter=1000)
                    pred = (res.u > 0).astype(jnp.int32)
                    rates.append(float(jnp.mean(pred != labs)))
                    iters.append(int(res.num_iters))
            rep.add(f"{tag} s={s} beta={beta:g} misclass",
                    float(np.mean(rates)), "frac",
                    max=f"{max(rates):.4f}", cg_iters=int(np.mean(iters)))


def run_truncated_eig(rep: Reporter) -> None:
    """Paper §6.2.3 second method: k=10 truncated eigenapproximation of A
    (NFFT-Lanczos) + Sherman-Morrison-Woodbury solve — 'similar results,
    solve time ~0.15s vs CG's minutes' claim."""
    import time

    from repro.core.lanczos import eigsh
    from repro.graph.ssl import kernel_ssl_eig

    n = 2000 if quick() else 20000
    points, labels = crescent_fullmoon(n, seed=60)
    pts = jnp.asarray(points)
    labs = jnp.asarray(labels)
    kernel = make_kernel("gaussian", sigma=0.75)
    op = make_normalized_adjacency(
        kernel, pts, FastsumParams(n_bandwidth=64 if quick() else 128,
                                   m=3, eps_b=0.0))
    t0 = time.perf_counter()
    eig = eigsh(op.matvec, op.n, 10, key=jax.random.PRNGKey(3),
                dtype=pts.dtype)
    t_eig = time.perf_counter() - t0
    for s in ((5, 25) if quick() else (1, 2, 5, 10, 25)):
        rates = []
        t_solve = 0.0
        for r in range(4):
            f, _ = make_training_vector(labs, s, 2,
                                        key=jax.random.PRNGKey(7 * r),
                                        positive_class=1)
            t0 = time.perf_counter()
            u = kernel_ssl_eig(eig.eigenvalues, eig.eigenvectors, f, 1e3)
            u.block_until_ready()
            t_solve += time.perf_counter() - t0
            rates.append(float(jnp.mean((u > 0).astype(jnp.int32) != labs)))
        rep.add(f"trunc-eig k=10 s={s} beta=1e3 misclass",
                float(np.mean(rates)), "frac",
                eig_time=f"{t_eig:.2f}s", solve_time=f"{t_solve / 4:.4f}s")


def run(report: Reporter | None = None) -> None:
    rep = report or Reporter("fig7_kernel_ssl")
    # paper scales: sigma=0.1 on the raw crescent coordinates ~ radius 13;
    # our generator spans the same range so we keep sigma proportional.
    run_kernel(rep, "gaussian", 0.75,
               FastsumParams(n_bandwidth=64 if quick() else 128, m=3,
                             eps_b=0.0), "gaussian")
    run_truncated_eig(rep)
    rep.save()


if __name__ == "__main__":
    run()
