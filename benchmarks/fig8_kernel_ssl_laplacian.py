"""Figure 8 — kernel SSL with the Laplacian RBF kernel (non-Gaussian).

Same protocol as Figure 7; demonstrates the NFFT fast summation's kernel
flexibility (Section 3: any K well-approximated by a trigonometric
polynomial works — the Laplacian RBF needs the two-point-Taylor boundary
regularization since it has a kink at 0 handled by p-smoothing).
"""

from __future__ import annotations

from benchmarks.common import Reporter
from benchmarks.fig7_kernel_ssl import run_kernel
from repro.core import FastsumParams


def run(report: Reporter | None = None) -> None:
    rep = report or Reporter("fig8_kernel_ssl_laplacian")
    run_kernel(rep, "laplacian_rbf", 0.4,
               FastsumParams(n_bandwidth=128, m=4, p=4, eps_b=None),
               "laplacian-rbf")
    rep.save()


if __name__ == "__main__":
    run()
