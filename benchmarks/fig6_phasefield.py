"""Figure 6 — semi-supervised learning via the Allen–Cahn phase-field method.

Paper protocol (Section 6.2.2): 5-class Gaussian-blob data (relabeled
spiral), k = 5 smallest eigenpairs of L_s; NFFT-Lanczos (N=32, m=4,
eps_B=0) vs traditional Nyström (L scaled), tau=0.1, eps=10, omega0=1e4,
c = 2/eps + omega0; classification accuracy vs samples-per-class s.

Claim reproduced: NFFT eigenvectors give consistently higher accuracy than
Nyström's, especially at small s, and the worst runs are far less bad.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Reporter, quick
from repro.core import SETUP_2, make_kernel, make_normalized_adjacency
from repro.core.nystrom import nystrom_traditional
from repro.data.synthetic import gaussian_blobs
from repro.graph.ssl import allen_cahn_multiclass

SIGMA = 3.5


def run(report: Reporter | None = None) -> None:
    rep = report or Reporter("fig6_phasefield")
    n = 2000 if quick() else 20000
    n_classes = 5
    samples = (1, 2, 3, 5) if quick() else (1, 2, 3, 4, 5, 7, 10)
    instances = 3 if quick() else 10
    kernel = make_kernel("gaussian", sigma=SIGMA)

    acc_nfft: dict[int, list] = {s: [] for s in samples}
    acc_nys: dict[int, list] = {s: [] for s in samples}
    for inst in range(instances):
        points, labels = gaussian_blobs(n, n_classes, seed=40 + inst)
        pts = jnp.asarray(points)
        labs = jnp.asarray(labels)
        op = make_normalized_adjacency(kernel, pts, SETUP_2)

        nys = nystrom_traditional(kernel, pts, n_classes,
                                  max(n // 20, 20),
                                  key=jax.random.PRNGKey(inst))

        for s in samples:
            key = jax.random.PRNGKey(1000 * inst + s)
            pred = allen_cahn_multiclass(op, labs, n_classes, s, k=n_classes,
                                         key=key)
            acc_nfft[s].append(float(jnp.mean(pred == labs)))

            class R:  # adapt Nyström output to the eigsh result shape
                eigenvalues = nys.eigenvalues
                eigenvectors = nys.eigenvectors
            pred2 = allen_cahn_multiclass(op, labs, n_classes, s,
                                          k=n_classes, key=key,
                                          eigsh_fn=lambda: R)
            acc_nys[s].append(float(jnp.mean(pred2 == labs)))

    for s in samples:
        rep.add(f"nfft s={s} accuracy", float(np.mean(acc_nfft[s])), "frac",
                worst=f"{min(acc_nfft[s]):.3f}")
        rep.add(f"nystrom s={s} accuracy", float(np.mean(acc_nys[s])), "frac",
                worst=f"{min(acc_nys[s]):.3f}")
    rep.save()


if __name__ == "__main__":
    run()
