"""Gradient-step cost — the differentiable-fastsum overhead (ISSUE 8).

Times one value-and-grad step of a scalar loss through the custom-VJP fused
pipeline — kernel-parameter re-spectralization (``with_kernel``), forward
matvec, transpose-pipeline backward — against the forward-only fused matvec,
over growing n.  The backward pass is one extra pipeline traversal plus the
spectral-mid VJP, so the ratio should stay well under the 3.5x target (and
flat in n: both legs are O(n)).

Also times a full KRR validation-loss gradient (implicit-diff CG: forward
solve + one adjoint solve) against the forward-only loss evaluation, the
quantity ``krr_fit_grad`` pays per optimization step.

Emits ``BENCH_grad.json`` (path overridable via REPRO_BENCH_GRAD_JSON) with
seconds per step for every (case, n) — the grad-path perf baseline future
PRs regress against.
"""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Reporter, quick, timeit
from repro.core import SETUP_2, make_fastsum, make_kernel
from repro.data.synthetic import spiral
from repro.graph import krr_validation_loss

SIGMA = 3.5
RATIO_TARGET = 3.5  # value-and-grad step <= 3.5x the forward-only matvec
BENCH_JSON = os.environ.get("REPRO_BENCH_GRAD_JSON", "BENCH_grad.json")


@jax.jit
def _forward_loss(op, sigma, x, w):
    kern = make_kernel("gaussian", sigma=sigma)
    return jnp.vdot(w, op.with_kernel(kern).matvec_tilde(x))


_value_and_grad = jax.jit(jax.value_and_grad(_forward_loss, argnums=(1, 2)))


def run(report: Reporter | None = None) -> None:
    rep = report or Reporter("grad_scaling")
    sizes = [2000, 8000] if quick() else [2000, 8000, 20000, 50000]
    records: list[dict] = []

    def record(name: str, n: int, t: float, **extra) -> None:
        rep.add(f"{name} n={n}", t, "s", **extra)
        records.append({"path": name, "n": n, "seconds": t, **extra})

    for n in sizes:
        points, _ = spiral(n, seed=2)
        pts = jnp.asarray(points)
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal(n))
        w = jnp.asarray(rng.standard_normal(n))
        sigma = jnp.asarray(SIGMA)
        kernel = make_kernel("gaussian", sigma=SIGMA)
        op = make_fastsum(kernel, pts, SETUP_2)

        # forward-only baseline: same jitted spectralize+matvec composite the
        # grad step differentiates, so the ratio isolates the backward cost
        t_fwd, _ = timeit(lambda: _forward_loss(op, sigma, x, w))
        record("forward-loss", n, t_fwd)
        t_vag, _ = timeit(lambda: _value_and_grad(op, sigma, x, w))
        ratio = t_vag / t_fwd
        record("value-and-grad", n, t_vag, ratio=round(ratio, 2),
               target=RATIO_TARGET, within_target=bool(ratio <= RATIO_TARGET))

        # raw fused matvec (no respectralization) for context
        t_mv, _ = timeit(lambda: op.matvec_tilde(x))
        record("matvec-only", n, t_mv)

    # one KRR validation-loss gradient step (implicit-diff CG) at the
    # smallest size: the per-step cost of krr_fit_grad
    n = sizes[0]
    rng = np.random.default_rng(1)
    xtr = jnp.asarray(rng.uniform(-0.25, 0.25, (n, 2)))
    xva = jnp.asarray(rng.uniform(-0.25, 0.25, (n // 4, 2)))
    ftr = jnp.sin(8 * xtr[:, 0]) + jnp.cos(8 * xtr[:, 1])
    fva = jnp.sin(8 * xva[:, 0]) + jnp.cos(8 * xva[:, 1])
    kern = make_kernel("gaussian", sigma=0.4)
    gop = make_fastsum(kern, xtr, SETUP_2)
    pop = make_fastsum(kern, xtr, SETUP_2, target_points=xva)

    def val_loss(ls, lb):
        return krr_validation_loss("gaussian", gop, pop, ftr, fva, ls, lb,
                                   tol=1e-8, maxiter=400)

    loss_fn = jax.jit(val_loss)
    grad_fn = jax.jit(jax.value_and_grad(val_loss, argnums=(0, 1)))
    ls, lb = jnp.asarray(np.log(0.4)), jnp.asarray(np.log(1e-2))
    t_loss, _ = timeit(lambda: loss_fn(ls, lb))
    record("krr-val-loss", n, t_loss)
    t_grad, _ = timeit(lambda: grad_fn(ls, lb))
    record("krr-val-grad", n, t_grad, ratio=round(t_grad / t_loss, 2))

    rep.save()
    with open(BENCH_JSON, "w") as f:
        json.dump({"bench": "grad_scaling", "unit": "s", "quick": quick(),
                   "ratio_target": RATIO_TARGET, "rows": records}, f,
                  indent=1)
    print(f"wrote {BENCH_JSON} ({len(records)} rows)")


if __name__ == "__main__":
    run()
