"""Durable-execution overhead — the resumable-Krylov acceptance gate.

Times `cg` / `eigsh` against their durable drivers (`resumable_solve` /
`resumable_eigsh`) snapshotting every 25 iterations (the default
DurablePolicy cadence) on an NFFT fastsum operator, where iteration cost
dominates — the workload the durable layer exists for.  The acceptance
criterion is <= 5% wall-clock overhead; this script ASSERTS the gate and
emits ``BENCH_resume.json`` (path overridable via REPRO_BENCH_RESUME_JSON)
so CI archives the evidence and future PRs regress against it.

Snapshot writes are asynchronous (the durable driver uses
``blocking=False``), so the measured overhead is the host device_get of the
loop state plus segment-boundary sync — not disk latency.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Reporter, quick
from repro.core import FastsumParams, make_fastsum, make_kernel
from repro.core.lanczos import eigsh
from repro.core.solvers import cg
from repro.runtime import DurablePolicy, resumable_eigsh, resumable_solve

BENCH_JSON = os.environ.get("REPRO_BENCH_RESUME_JSON", "BENCH_resume.json")
OVERHEAD_GATE_PCT = 5.0
SNAPSHOT_EVERY = 25


def _operator(n: int):
    rng = np.random.default_rng(0)
    pts = jnp.asarray(rng.uniform(-3.0, 3.0, (n, 2)))
    kern = make_kernel("gaussian", sigma=2.5)
    params = FastsumParams(n_bandwidth=32, m=4)
    gram = make_fastsum(kern, pts, params)
    beta = 1e-2
    return lambda x: gram.matvec_tilde(x) + beta * x


def _median_time(fn, *, repeats: int) -> float:
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def _time_durable(run_fn, *, repeats: int) -> float:
    """Each durable run gets a FRESH ckpt_dir: resuming a finished solve
    from its own snapshots would time the restore path, not the solve."""
    times = []
    for _ in range(repeats):
        d = tempfile.mkdtemp(prefix="bench_resume_")
        try:
            t0 = time.perf_counter()
            out = run_fn(d)
            jax.block_until_ready(out)
            times.append(time.perf_counter() - t0)
        finally:
            shutil.rmtree(d, ignore_errors=True)
    return float(np.median(times))


def run(report: Reporter | None = None) -> None:
    rep = report or Reporter("resume_overhead")
    n = 3000 if quick() else 20_000
    repeats = 3 if quick() else 5
    maxiter = 150
    num_iters = 60
    policy = DurablePolicy(snapshot_every=SNAPSHOT_EVERY)
    mv = _operator(n)
    rng = np.random.default_rng(0)
    b = jnp.asarray(rng.standard_normal((n, 4)))
    key = jax.random.PRNGKey(0)
    results = {"n": n, "snapshot_every": SNAPSHOT_EVERY,
               "gate_pct": OVERHEAD_GATE_PCT, "cases": {}}

    cases = {
        "cg": (
            lambda: cg(mv, b, tol=1e-10, maxiter=maxiter),
            lambda d: resumable_solve(mv, b, ckpt_dir=d, tol=1e-10,
                                      maxiter=maxiter, policy=policy)[0],
        ),
        "eigsh": (
            lambda: eigsh(mv, n, 6, key=key, num_iters=num_iters),
            lambda d: resumable_eigsh(mv, n, 6, ckpt_dir=d, key=key,
                                      num_iters=num_iters, policy=policy)[0],
        ),
    }
    for name, (plain, durable) in cases.items():
        plain()  # warm both compile caches before timing
        _time_durable(durable, repeats=1)
        t_plain = _median_time(plain, repeats=repeats)
        t_durable = _time_durable(durable, repeats=repeats)
        overhead_pct = 100.0 * (t_durable - t_plain) / t_plain
        rep.add(f"{name}[n={n}]/plain", t_plain, "s")
        rep.add(f"{name}[n={n}]/durable", t_durable, "s",
                overhead_pct=round(overhead_pct, 2),
                snapshot_every=SNAPSHOT_EVERY)
        results["cases"][name] = {
            "plain_s": t_plain,
            "durable_s": t_durable,
            "overhead_pct": overhead_pct,
        }

    with open(BENCH_JSON, "w") as f:
        json.dump(results, f, indent=1)
    print(f"wrote {BENCH_JSON}")

    for name, case in results["cases"].items():
        assert case["overhead_pct"] <= OVERHEAD_GATE_PCT, (
            f"durable {name} overhead {case['overhead_pct']:.2f}% exceeds "
            f"the {OVERHEAD_GATE_PCT}% acceptance gate "
            f"(snapshots every {SNAPSHOT_EVERY} iterations)")
    rep.save()


if __name__ == "__main__":
    run()
