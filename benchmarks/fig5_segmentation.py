"""Figure 5 — image segmentation via spectral clustering.

Paper protocol (Section 6.2.1): every pixel is a node, v_j in RGB space,
Gaussian sigma = 90, k = 2 / 4 clusters on the smallest eigenvectors of
L_s; NFFT-Lanczos parameters N=16, m=2, p=2, eps_B=1/8.

CPU-scaled stand-in image (60x90 = 5,400 nodes; the paper's 426,400-pixel
photo needs minutes, not CI seconds); the comparison structure is identical:
NFFT-based result vs dense ground truth (% label disagreement) and the
traditional Nyström failure statistics over repeated runs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Reporter, quick, timeit
from repro.core import (
    FastsumParams, dense_normalized_adjacency, eigsh, make_kernel,
    make_normalized_adjacency, nystrom_traditional,
)
from repro.data.synthetic import synthetic_image
from repro.graph.spectral import clustering_agreement, spectral_clustering

SIGMA = 90.0
PARAMS = FastsumParams(n_bandwidth=16, m=2, p=2, eps_b=1.0 / 8.0)


def run(report: Reporter | None = None) -> None:
    rep = report or Reporter("fig5_segmentation")
    h, w = (40, 60) if quick() else (60, 90)
    img, _ = synthetic_image(h, w)
    pixels = jnp.asarray(img.reshape(-1, 3))
    n = pixels.shape[0]
    kernel = make_kernel("gaussian", sigma=SIGMA)
    key = jax.random.PRNGKey(0)

    # ground truth: dense eigensolver on the full A
    a_dense = dense_normalized_adjacency(kernel, pixels)
    lam, vec = jnp.linalg.eigh(a_dense)
    lam_ref = lam[::-1][:4]
    vec_ref = vec[:, ::-1][:, :4]

    for k in (2, 4):
        from repro.graph.spectral import kmeans
        rows_ref = vec_ref[:, :k] / jnp.maximum(
            jnp.linalg.norm(vec_ref[:, :k], axis=1, keepdims=True), 1e-30)
        ref_assign = kmeans(key, rows_ref, k).assignments

        def nfft_pipeline(k=k):
            op = make_normalized_adjacency(kernel, pixels, PARAMS)
            return spectral_clustering(op, k, key=key)
        t, res = timeit(nfft_pipeline, repeats=1)
        agree = clustering_agreement(np.asarray(ref_assign),
                                     np.asarray(res.assignments), k)
        rep.add(f"nfft k={k} n={n} disagreement", 1.0 - agree, "frac",
                time=f"{t:.2f}s")

    # Nyström repeated-run failure statistics (paper: 13/100 "failed" runs)
    k = 4
    l_size = max(25, n // 40)
    reps = 10 if quick() else 50
    diffs = []
    for r in range(reps):
        res = nystrom_traditional(kernel, pixels, k, l_size,
                                  key=jax.random.PRNGKey(300 + r))
        rows = res.eigenvectors[:, :k] / jnp.maximum(
            jnp.linalg.norm(res.eigenvectors[:, :k], axis=1, keepdims=True),
            1e-30)
        from repro.graph.spectral import kmeans
        assign = kmeans(key, rows, k).assignments
        rows_ref = vec_ref[:, :k] / jnp.maximum(
            jnp.linalg.norm(vec_ref[:, :k], axis=1, keepdims=True), 1e-30)
        ref_assign = kmeans(key, rows_ref, k).assignments
        diffs.append(1.0 - clustering_agreement(
            np.asarray(ref_assign), np.asarray(assign), k))
    diffs = np.asarray(diffs)
    rep.add(f"nystrom k=4 L={l_size} mean-disagreement",
            float(diffs.mean()), "frac")
    rep.add(f"nystrom k=4 L={l_size} failed-runs(>20%)",
            float(np.mean(diffs > 0.20)), "frac", runs=reps)
    rep.save()


if __name__ == "__main__":
    run()
