"""Matvec scaling — the paper's core O(n) claim (supports Fig. 3d).

Times one W̃x product: NFFT fast summation (setups #1-#3) vs the O(n^2)
tiled direct matvec vs the Pallas streaming kernel-matvec (interpret mode on
CPU), over growing n.  Reports seconds and the empirical scaling exponent
log(t_2n / t_n) / log 2 — the NFFT column should sit near 1, direct near 2.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Reporter, quick, timeit
from repro.core import (
    SETUP_1, SETUP_2, SETUP_3, direct_matvec_tiled, make_fastsum, make_kernel,
)
from repro.data.synthetic import spiral

SIGMA = 3.5


def run(report: Reporter | None = None) -> None:
    rep = report or Reporter("matvec_scaling")
    sizes = [2000, 8000, 32000] if quick() else [2000, 5000, 10000, 20000,
                                                 50000, 100000]
    kernel = make_kernel("gaussian", sigma=SIGMA)
    times: dict[str, list] = {}
    for n in sizes:
        points, _ = spiral(n, seed=2)
        pts = jnp.asarray(points)
        x = jnp.asarray(np.random.default_rng(0).standard_normal(n))

        for name, setup in (("setup1", SETUP_1), ("setup2", SETUP_2),
                            ("setup3", SETUP_3)):
            op = make_fastsum(kernel, pts, setup)
            mv = jax.jit(op.matvec)
            t, _ = timeit(lambda: mv(x))
            times.setdefault(f"nfft-{name}", []).append(t)
            rep.add(f"nfft-{name} n={n}", t, "s")

        t, _ = timeit(lambda: direct_matvec_tiled(kernel, pts, x, tile=1024),
                      repeats=1)
        times.setdefault("direct", []).append(t)
        rep.add(f"direct n={n}", t, "s")

    for name, ts in times.items():
        if len(ts) >= 2:
            expo = float(np.polyfit(np.log(sizes[:len(ts)]), np.log(ts), 1)[0])
            rep.add(f"{name} scaling-exponent", expo, "log-slope")
    rep.save()


if __name__ == "__main__":
    run()
