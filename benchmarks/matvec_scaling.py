"""Matvec scaling — the paper's core O(n) claim (supports Fig. 3d).

Times one W̃x product: the fused real-FFT fastsum engine (setups #1-#3)
vs the seed two-NFFT path vs the O(n^2) tiled direct matvec, over growing
n.  Reports seconds, the fused-over-seed speedup, and the empirical scaling
exponent log(t_2n / t_n) / log 2 — the NFFT columns should sit near 1,
direct near 2.

Besides the Reporter CSV/JSON, emits ``BENCH_matvec.json`` (path
overridable via REPRO_BENCH_MATVEC_JSON) with seconds per matvec for every
(setup, n, path, backend) — the perf baseline future PRs regress against.
The fused rows carry a ``backend`` column ("xla"/"pallas", the streaming
window-step backends of ``repro.core.fastsum_exec``); the pallas backend is
timed only on a real TPU — interpret-mode timings would measure the
emulator, not the kernel.
"""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Reporter, quick, timeit
from repro.core import (
    SETUP_1, SETUP_2, SETUP_3, direct_matvec_tiled, make_fastsum, make_kernel,
)
from repro.data.synthetic import spiral

SIGMA = 3.5
# the acceptance point every PR regresses against: SETUP_2, n = 50_000
BENCH_JSON = os.environ.get("REPRO_BENCH_MATVEC_JSON", "BENCH_matvec.json")
DIRECT_MAX_N = 8000  # O(n^2) baseline cap in quick mode (CI smoke budget)


def run(report: Reporter | None = None) -> None:
    rep = report or Reporter("matvec_scaling")
    sizes = [2000, 8000, 50000] if quick() else [2000, 5000, 10000, 20000,
                                                 50000, 100000]
    kernel = make_kernel("gaussian", sigma=SIGMA)
    times: dict[str, list] = {}
    records: list[dict] = []

    def record(name: str, n: int, t: float, **extra) -> None:
        # scaling fits are per (path, backend) series
        series = name + (f"-{extra['backend']}" if "backend" in extra else "")
        times.setdefault(series, []).append(t)
        rep.add(f"{name} n={n}", t, "s", **extra)
        records.append({"path": name, "n": n, "seconds": t, **extra})

    for n in sizes:
        points, _ = spiral(n, seed=2)
        pts = jnp.asarray(points)
        x = jnp.asarray(np.random.default_rng(0).standard_normal(n))

        backends = ["xla"] + (["pallas"] if jax.default_backend() == "tpu"
                              else [])
        for name, setup in (("setup1", SETUP_1), ("setup2", SETUP_2),
                            ("setup3", SETUP_3)):
            op = make_fastsum(kernel, pts, setup)
            # No outer jax.jit: both paths are jitted internally with the
            # geometry passed as *arguments*.  Closing over the operator
            # would embed the O(n*taps^d) seed geometry as XLA constants,
            # which trips a pathological constant-scatter rewrite and times
            # the compiler, not the matvec.
            t_fused = {}
            for be in backends:
                t_fused[be], _ = timeit(lambda: op.matvec(x, backend=be))
                record(f"nfft-fused-{name}", n, t_fused[be], backend=be)
            # seed rows carry no backend column: the two-NFFT path predates
            # (and bypasses) the streaming window backends
            t_seed, _ = timeit(lambda: op.matvec_reference(x), repeats=1)
            record(f"nfft-seed-{name}", n, t_seed,
                   speedup=round(t_seed / t_fused["xla"], 2))

        if n <= DIRECT_MAX_N or not quick():
            t, _ = timeit(lambda: direct_matvec_tiled(kernel, pts, x,
                                                      tile=1024),
                          repeats=1)
            record("direct", n, t)

    for name, ts in times.items():
        if len(ts) >= 2:
            expo = float(np.polyfit(np.log(sizes[:len(ts)]), np.log(ts), 1)[0])
            rep.add(f"{name} scaling-exponent", expo, "log-slope")
    rep.save()

    with open(BENCH_JSON, "w") as f:
        json.dump({"bench": "matvec_scaling", "unit": "s",
                   "quick": quick(), "rows": records}, f, indent=1)
    print(f"wrote {BENCH_JSON} ({len(records)} rows)")


if __name__ == "__main__":
    run()
