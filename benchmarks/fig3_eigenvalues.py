"""Figure 3 — eigenvalue accuracy + runtime on spiral data.

Paper protocol (Section 6.1): 10 largest eigenpairs of
A = D^{-1/2} W D^{-1/2}, Gaussian sigma = 3.5, methods:
  * NFFT-based Lanczos, setups #1 (N=16,m=2) / #2 (N=32,m=4) / #3 (N=64,m=7)
  * traditional Nyström, L in {n/10, n/4}
  * hybrid Nyström-Gaussian-NFFT (Alg. 5.1), L in {20, 50}, M = 10
  * direct Lanczos (dense matvec) as ground truth
Metrics: max eigenvalue error (6.1), max residual norm (6.2), runtime.

Paper claims reproduced (CPU-scaled n): setup #1 ~1e-4..1e-3, setup #2
~1e-10..1e-9, setup #3 <1e-14 eigenvalue error; Nyström errors > 1e-2 with
high variance; hybrid L=50 between setup #1 and Nyström; NFFT runtime ~n.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Reporter, quick, timeit
from repro.core import (
    SETUP_1, SETUP_2, SETUP_3, dense_normalized_adjacency, eigsh, make_kernel,
    make_normalized_adjacency, nystrom_gaussian_nfft, nystrom_traditional,
)

SIGMA = 3.5
K_EIGS = 10


def direct_eigs(points):
    a = dense_normalized_adjacency(make_kernel("gaussian", sigma=SIGMA),
                                   jnp.asarray(points))
    lam, vec = jnp.linalg.eigh(a)
    return lam[::-1][:K_EIGS], vec[:, ::-1][:, :K_EIGS], a


def residual_norm(a_dense, lam, vec):
    r = a_dense @ vec - vec * lam[None, :]
    return float(jnp.max(jnp.linalg.norm(r, axis=0)))


def run(report: Reporter | None = None) -> None:
    rep = report or Reporter("fig3_eigenvalues")
    sizes = [500, 1000, 2000] if quick() else [2000, 5000, 10000, 20000]
    from repro.data.synthetic import spiral

    for n in sizes:
        points, _ = spiral(n, seed=1)
        pts = jnp.asarray(points)
        lam_ref, _, a_dense = direct_eigs(points)

        t_direct, _ = timeit(lambda: jnp.linalg.eigh(a_dense)[0], repeats=1)
        rep.add(f"direct n={n}", t_direct, "s")

        kernel = make_kernel("gaussian", sigma=SIGMA)
        for name, setup in (("setup1", SETUP_1), ("setup2", SETUP_2),
                            ("setup3", SETUP_3)):
            def solve(setup=setup):
                op = make_normalized_adjacency(kernel, pts, setup)
                return eigsh(op.matvec, op.n, K_EIGS,
                             key=jax.random.PRNGKey(0),
                             dtype=pts.dtype)
            t, res = timeit(solve, repeats=1)
            err = float(jnp.max(jnp.abs(res.eigenvalues - lam_ref)))
            resid = residual_norm(a_dense, res.eigenvalues, res.eigenvectors)
            rep.add(f"nfft-lanczos-{name} n={n} eigerr", err, "abs",
                    resid=f"{resid:.2e}")
            rep.add(f"nfft-lanczos-{name} n={n} time", t, "s")

        for frac_name, l_size in (("L=n/10", max(n // 10, K_EIGS + 2)),
                                  ("L=n/4", n // 4)):
            errs, resids = [], []
            t_total = 0.0
            reps = 3 if quick() else 10
            for r in range(reps):
                def solve(r=r):
                    return nystrom_traditional(
                        kernel, pts, K_EIGS, l_size,
                        key=jax.random.PRNGKey(100 + r))
                t, res = timeit(solve, warmup=0, repeats=1)
                t_total += t
                errs.append(float(jnp.max(jnp.abs(
                    res.eigenvalues - lam_ref))))
                resids.append(residual_norm(a_dense, res.eigenvalues,
                                            res.eigenvectors))
            rep.add(f"nystrom-{frac_name} n={n} eigerr", float(np.mean(errs)),
                    "abs", min=f"{min(errs):.2e}", max=f"{max(errs):.2e}")
            rep.add(f"nystrom-{frac_name} n={n} resid",
                    float(np.mean(resids)), "abs", max=f"{max(resids):.2e}")
            rep.add(f"nystrom-{frac_name} n={n} time", t_total / reps, "s")

        # block Lanczos through the fused multi-RHS engine: same subspace,
        # ~block_size fewer operator invocations
        def solve_block():
            op = make_normalized_adjacency(kernel, pts, SETUP_2)
            return eigsh(op.matvec, op.n, K_EIGS, key=jax.random.PRNGKey(0),
                         dtype=pts.dtype, num_iters=80, block_size=8)
        t, res = timeit(solve_block, repeats=1)
        err = float(jnp.max(jnp.abs(res.eigenvalues - lam_ref)))
        rep.add(f"nfft-block-lanczos-setup2 n={n} eigerr", err, "abs",
                matvecs=res.num_matvecs)
        rep.add(f"nfft-block-lanczos-setup2 n={n} time", t, "s")

        op_nfft = make_normalized_adjacency(kernel, pts, SETUP_2)
        for l_size in (20, 50):
            errs, resids = [], []
            t_total = 0.0
            reps = 3 if quick() else 10
            for r in range(reps):
                def solve(r=r):
                    return nystrom_gaussian_nfft(
                        op_nfft, K_EIGS, num_columns=l_size,
                        key=jax.random.PRNGKey(200 + r), rank=K_EIGS)
                t, res = timeit(solve, warmup=0, repeats=1)
                t_total += t
                errs.append(float(jnp.max(jnp.abs(
                    res.eigenvalues - lam_ref))))
                resids.append(residual_norm(a_dense, res.eigenvalues,
                                            res.eigenvectors))
            rep.add(f"hybrid-L={l_size} n={n} eigerr", float(np.mean(errs)),
                    "abs", min=f"{min(errs):.2e}", max=f"{max(errs):.2e}")
            rep.add(f"hybrid-L={l_size} n={n} time", t_total / reps, "s")

    rep.save()


if __name__ == "__main__":
    run()
