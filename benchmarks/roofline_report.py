"""Roofline report — renders the dry-run JSON into the EXPERIMENTS.md tables.

Reads experiments/dryrun/dryrun_<tag>.json (produced by
``python -m repro.launch.dryrun``) and emits:
  * per-(arch x shape x mesh) table of the three roofline terms, dominant
    bottleneck, MODEL_FLOPS/HLO_FLOPS ratio, per-device memory;
  * a skipped-cells table with reasons;
  * markdown to stdout / file.
"""

from __future__ import annotations

import argparse
import json
import os


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    for unit, scale in (("s", 1.0), ("ms", 1e-3), ("us", 1e-6)):
        if x >= scale:
            return f"{x / scale:.2f}{unit}"
    return f"{x:.1e}s"


def fmt_b(x: float) -> str:
    for unit, scale in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if x >= scale:
            return f"{x / scale:.2f}{unit}"
    return f"{x:.0f}B"


def render(records: list, *, include_graph: bool = True) -> str:
    lines = []
    # "payload" = per-device shard payload of the collectives
    # (hlo_stats.collective_payload_bytes): flat in P for the psum spectral
    # mode, ~1/P for the pencil cells — the column that shows the drop.
    # "S" = multiplier-bank size of the graph-fastsum-bank cells (1 for the
    # single-operator matvec): a bank cell's payload should sit near S times
    # the matching S=1 cell's while its spread/forward-FFT work stays flat.
    lines.append("| arch | shape | mesh | kind | S | compute | memory | "
                 "collective | payload | dominant | useful/HLO | HBM/dev "
                 "| DCI |")
    lines.append("|---|---|---|---|---|---|---|---|---|---|---|---|---|")
    for r in records:
        if r["status"] != "ok":
            continue
        if r["kind"] == "graph_matvec" and not include_graph:
            continue
        roof = r["roofline"]
        mem = r.get("memory", {})
        hbm = mem.get("temp_size_in_bytes", 0) + mem.get(
            "argument_size_in_bytes", 0)
        payload = r.get("hlo_stats", {}).get("collective_payload_bytes", 0.0)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['kind']} "
            f"| {r.get('bank', 1)} "
            f"| {fmt_s(roof['compute_s'])} | {fmt_s(roof['memory_s'])} "
            f"| {fmt_s(roof['collective_s'])} | {fmt_b(payload)} "
            f"| **{roof['dominant']}** "
            f"| {roof['useful_flop_ratio']:.3f} | {fmt_b(hbm)} "
            f"| {fmt_b(roof['dci_bytes'])} |")
    skipped = [r for r in records if r["status"] == "skipped"]
    if skipped:
        lines.append("")
        lines.append("Skipped cells (per assignment rules):")
        lines.append("")
        lines.append("| arch | shape | mesh | reason |")
        lines.append("|---|---|---|---|")
        for r in skipped:
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                         f"| {r['reason'][:90]} |")
    errors = [r for r in records if r["status"] == "error"]
    if errors:
        lines.append("")
        lines.append(f"ERRORS: {len(errors)} cells failed")
        for r in errors:
            lines.append(f"  - {r['arch']} x {r['shape']} @ {r['mesh']}: "
                         f"{r['error'][:140]}")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="experiments/dryrun/dryrun_baseline.json")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    with open(args.json) as f:
        records = json.load(f)
    md = render(records)
    if args.out:
        with open(args.out, "w") as f:
            f.write(md + "\n")
    print(md)


def run(report=None) -> None:
    """Bench-runner entry: render the most recent dry-run table."""
    path = None
    for tag in ("final", "baseline"):
        cand = f"experiments/dryrun/dryrun_{tag}.json"
        if os.path.exists(cand):
            path = cand
            break
    if path is None:
        print("roofline_report: no dry-run JSON yet — run "
              "`python -m repro.launch.dryrun` first")
        return
    with open(path) as f:
        records = json.load(f)
    ok = sum(r["status"] == "ok" for r in records)
    err = sum(r["status"] == "error" for r in records)
    print(f"roofline_report [{path}]: {ok} ok cells, {err} errors "
          f"(full table in EXPERIMENTS.md)")
    print(render(records, include_graph=True)[:4000])


if __name__ == "__main__":
    main()
