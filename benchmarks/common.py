"""Shared benchmark harness: timing, CSV emission, result registry."""

from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import time
from typing import Any, Callable

import jax

# The paper's accuracy tiers (setup #3: <1e-14 eigenvalue error) require f64.
jax.config.update("jax_enable_x64", True)

import numpy as np

RESULTS_DIR = os.environ.get("REPRO_BENCH_DIR", "experiments/bench")


@dataclasses.dataclass
class Row:
    bench: str
    case: str
    value: float
    unit: str
    extra: dict = dataclasses.field(default_factory=dict)

    def format(self) -> str:
        extras = " ".join(f"{k}={v}" for k, v in self.extra.items())
        return f"{self.bench:28s} {self.case:42s} {self.value:>12.6g} {self.unit:10s} {extras}"


class Reporter:
    def __init__(self, name: str):
        self.name = name
        self.rows: list[Row] = []

    def add(self, case: str, value: float, unit: str, **extra) -> None:
        row = Row(self.name, case, float(value), unit, extra)
        self.rows.append(row)
        print(row.format(), flush=True)

    def save(self) -> str:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        path = os.path.join(RESULTS_DIR, f"{self.name}.json")
        with open(path, "w") as f:
            json.dump([dataclasses.asdict(r) for r in self.rows], f, indent=1)
        return path


def timeit(fn: Callable[[], Any], *, warmup: int = 1, repeats: int = 3
           ) -> tuple[float, Any]:
    """Median wall time (s) of fn(); blocks on jax arrays."""
    out = None
    for _ in range(warmup):
        out = fn()
        jax.block_until_ready(out)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times)), out


def quick() -> bool:
    """Quick mode is the default; REPRO_BENCH_FULL=1 opts into full sweeps.

    ``QUICK=1`` (the CI smoke job's convention) forces quick mode even if
    REPRO_BENCH_FULL is set.
    """
    if os.environ.get("QUICK") == "1":
        return True
    return os.environ.get("REPRO_BENCH_FULL", "0") != "1"
