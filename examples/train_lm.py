"""LM training driver — exercises the full training substrate end-to-end:
config -> sharded model -> microbatched train step -> fault-tolerant loop
with async checkpointing -> resume.

Default is a CPU-sized model for CI; ``--params 100m --steps 300`` runs the
~100M-parameter few-hundred-step protocol (hours on CPU, minutes on a real
accelerator — the script is identical).

    PYTHONPATH=src python examples/train_lm.py --steps 30
    PYTHONPATH=src python examples/train_lm.py --params 100m --steps 300
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced_config
from repro.data.pipeline import batch_for_step
from repro.training.fault_tolerance import run_resilient
from repro.training.optimizer import OptimizerConfig
from repro.training.train_loop import (
    TrainConfig, init_train_state, make_train_step)


def build_config(size: str):
    base = get_config("granite-3-2b")  # GQA + SwiGLU family
    if size == "tiny":
        cfg = reduced_config(base, seq_len=128, global_batch=8)
    elif size == "20m":
        cfg = dataclasses.replace(
            reduced_config(base, seq_len=256, global_batch=8),
            name="granite-20m", num_layers=6, d_model=384, num_heads=6,
            num_kv_heads=2, head_dim=64, d_ff=1536, vocab_size=8192)
    elif size == "100m":
        cfg = dataclasses.replace(
            reduced_config(base, seq_len=512, global_batch=16),
            name="granite-100m", num_layers=12, d_model=768, num_heads=12,
            num_kv_heads=4, head_dim=64, d_ff=3072, vocab_size=32768)
    else:
        raise SystemExit(f"unknown --params {size}")
    return cfg


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--params", default="tiny", choices=["tiny", "20m", "100m"])
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()

    cfg = build_config(args.params)
    tc = TrainConfig(
        optimizer=OptimizerConfig(peak_lr=args.lr, total_steps=args.steps,
                                  warmup_steps=max(args.steps // 10, 2)),
        num_microbatches=args.microbatches)
    state = init_train_state(jax.random.PRNGKey(0), cfg, tc)
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(state.params))
    print(f"model {cfg.name}: {n_params / 1e6:.1f}M params, "
          f"{cfg.num_layers}L d={cfg.d_model}, seq={cfg.shapes[0].seq_len}, "
          f"batch={cfg.shapes[0].global_batch}")

    # NOTE: no donate_argnums — freshly-initialized optimizer moments can be
    # deduplicated to one buffer by XLA, and donating aliased buffers errors.
    step_fn = jax.jit(make_train_step(cfg, tc))
    batch_fn = lambda s: jax.tree.map(
        jnp.asarray, batch_for_step(cfg, cfg.shapes[0], s))

    t0 = time.perf_counter()
    state, info = run_resilient(
        step_fn, state, batch_fn, total_steps=args.steps,
        ckpt_dir=args.ckpt_dir, ckpt_every=max(args.steps // 4, 5),
        log_every=max(args.steps // 10, 1))
    dt = time.perf_counter() - t0

    loss = float(jax.device_get(info["final_metrics"]["loss"]))
    toks = cfg.shapes[0].global_batch * cfg.shapes[0].seq_len
    print(f"\n{info['steps']} steps in {dt:.1f}s "
          f"({dt / max(info['steps'] - 0, 1):.2f}s/step, "
          f"{toks * info['steps'] / dt:.0f} tok/s) "
          f"final loss {loss:.4f} "
          f"(restarts={info['restarts']}, stragglers={info['stragglers']})")
    import math
    from repro.models import model as M
    init_loss = float(M.forward_train(
        init_train_state(jax.random.PRNGKey(0), cfg, tc).params,
        cfg, batch_fn(0))[0])
    print(f"loss {init_loss:.3f} -> {loss:.3f} "
          f"(uniform baseline ln V = {math.log(cfg.vocab_size):.3f}; the "
          f"structured stream's floor is ~{0.33:.2f})")
    assert loss < init_loss - 0.02, (loss, init_loss)


if __name__ == "__main__":
    main()
