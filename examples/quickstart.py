"""Quickstart — the paper in 60 seconds.

Builds a fully connected Gaussian graph on 3-D spiral data, computes the 10
largest eigenpairs of A = D^{-1/2} W D^{-1/2} with the NFFT-based Lanczos
method (never forming the n x n matrix), validates against the dense solver,
and runs spectral clustering on the eigenvectors.

    PYTHONPATH=src python examples/quickstart.py [--n 4000]
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import (
    SETUP_1, SETUP_2, SETUP_3, dense_normalized_adjacency, eigsh, make_kernel,
    make_normalized_adjacency,
)
from repro.data.synthetic import spiral
from repro.graph.spectral import clustering_agreement, spectral_clustering


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4000)
    ap.add_argument("--sigma", type=float, default=3.5)
    args = ap.parse_args()

    points, labels = spiral(args.n, n_classes=5, seed=0)
    pts = jnp.asarray(points)
    kernel = make_kernel("gaussian", sigma=args.sigma)
    print(f"spiral data: n={args.n}, d=3, 5 classes, sigma={args.sigma}")

    # --- NFFT-based Lanczos at the paper's three accuracy tiers -----------
    lam_ref = None
    if args.n <= 8000:
        a = dense_normalized_adjacency(kernel, pts)
        lam_ref = jnp.linalg.eigvalsh(a)[::-1][:10]

    for name, setup in (("setup#1 (N=16,m=2)", SETUP_1),
                        ("setup#2 (N=32,m=4)", SETUP_2),
                        ("setup#3 (N=64,m=7)", SETUP_3)):
        t0 = time.perf_counter()
        op = make_normalized_adjacency(kernel, pts, setup)
        res = eigsh(op.matvec, op.n, 10, key=jax.random.PRNGKey(0),
                    dtype=pts.dtype)
        jax.block_until_ready(res.eigenvalues)
        dt = time.perf_counter() - t0
        msg = f"  {name}: 10 eigenpairs in {dt:5.2f}s"
        if lam_ref is not None:
            err = float(jnp.max(jnp.abs(res.eigenvalues - lam_ref)))
            msg += f"   max eigenvalue error vs dense: {err:.2e}"
        print(msg)

    # --- spectral clustering on the NFFT eigenvectors ---------------------
    op = make_normalized_adjacency(kernel, pts, SETUP_2)
    t0 = time.perf_counter()
    res = spectral_clustering(op, 5, key=jax.random.PRNGKey(1))
    dt = time.perf_counter() - t0
    agree = clustering_agreement(labels, jax.device_get(res.assignments), 5)
    print(f"spectral clustering: {dt:.2f}s, agreement with true arms: "
          f"{agree:.3f}")
    print(f"top eigenvalues: {jax.device_get(res.eigenvalues)[:5]}")


if __name__ == "__main__":
    main()
