"""Semi-supervised learning on crescent-fullmoon (paper Section 6.2.3).

Solves (I + beta L_s) u = f by CG with NFFT matvecs for a handful of
labeled samples per class, and prints the misclassification rate; also runs
the Laplacian-RBF variant to show kernel flexibility (Fig. 8).

    PYTHONPATH=src python examples/ssl_crescent.py --n 20000 --samples 5
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import FastsumParams, make_kernel, make_normalized_adjacency
from repro.data.synthetic import crescent_fullmoon
from repro.graph.ssl import kernel_ssl_cg, make_training_vector


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20000)
    ap.add_argument("--samples", type=int, default=5)
    ap.add_argument("--beta", type=float, default=1e3)
    args = ap.parse_args()

    points, labels = crescent_fullmoon(args.n, seed=0)
    pts = jnp.asarray(points)
    labs = jnp.asarray(labels)

    for kname, sigma, params in (
            ("gaussian", 0.75, FastsumParams(n_bandwidth=64, m=3, eps_b=0.0)),
            ("laplacian_rbf", 0.4, FastsumParams(n_bandwidth=128, m=4))):
        kernel = make_kernel(kname, sigma=sigma)
        t0 = time.perf_counter()
        op = make_normalized_adjacency(kernel, pts, params)
        f, _ = make_training_vector(labs, args.samples, 2,
                                    key=jax.random.PRNGKey(0),
                                    positive_class=1)
        res = kernel_ssl_cg(op, f, args.beta, tol=1e-4, maxiter=1000)
        dt = time.perf_counter() - t0
        pred = (res.u > 0).astype(jnp.int32)
        rate = float(jnp.mean(pred != labs))
        print(f"{kname:15s} sigma={sigma}: misclassification "
              f"{rate * 100:.2f}%  (CG iters={int(res.num_iters)}, "
              f"{dt:.2f}s, n={args.n}, s={args.samples}/class, "
              f"beta={args.beta:g})")


if __name__ == "__main__":
    main()
