"""End-to-end driver — image segmentation via NFFT spectral clustering
(paper Section 6.2.1, the paper's flagship application).

Every pixel is a graph node with its RGB vector; the dense
(H*W) x (H*W) graph Laplacian is never formed — eigenvectors come from the
NFFT-based Lanczos method with the paper's parameters (N=16, m=2, p=2,
eps_B=1/8, sigma=90).  Writes PPM images of the input and the k=2 / k=4
segmentations.

    PYTHONPATH=src python examples/image_segmentation.py --height 100 --width 150
"""

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import FastsumParams, make_kernel, make_normalized_adjacency
from repro.data.synthetic import synthetic_image
from repro.graph.spectral import spectral_clustering

PALETTE = np.asarray([
    (230, 60, 60), (60, 160, 230), (240, 200, 60), (110, 200, 110),
    (180, 110, 220), (240, 140, 60)], np.uint8)


def write_ppm(path: str, img: np.ndarray) -> None:
    h, w, _ = img.shape
    with open(path, "wb") as f:
        f.write(f"P6 {w} {h} 255\n".encode())
        f.write(img.astype(np.uint8).tobytes())


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--height", type=int, default=100)
    ap.add_argument("--width", type=int, default=150)
    ap.add_argument("--sigma", type=float, default=90.0)
    ap.add_argument("--out", default="experiments/segmentation")
    args = ap.parse_args()

    img, truth = synthetic_image(args.height, args.width)
    n = args.height * args.width
    pixels = jnp.asarray(img.reshape(-1, 3))
    print(f"image {args.height}x{args.width} -> fully connected graph with "
          f"n={n} nodes (dense W would be {n * n * 8 / 1e9:.1f} GB)")

    kernel = make_kernel("gaussian", sigma=args.sigma)
    params = FastsumParams(n_bandwidth=16, m=2, p=2, eps_b=1.0 / 8.0)

    os.makedirs(args.out, exist_ok=True)
    write_ppm(os.path.join(args.out, "input.ppm"), img)

    t0 = time.perf_counter()
    op = make_normalized_adjacency(kernel, pixels, params)
    print(f"operator setup (incl. degrees by fast summation): "
          f"{time.perf_counter() - t0:.2f}s")

    for k in (2, 4):
        t0 = time.perf_counter()
        res = spectral_clustering(op, k, key=jax.random.PRNGKey(0))
        dt = time.perf_counter() - t0
        seg = PALETTE[np.asarray(res.assignments) % len(PALETTE)]
        path = os.path.join(args.out, f"segmentation_k{k}.ppm")
        write_ppm(path, seg.reshape(args.height, args.width, 3))
        print(f"k={k}: clustered in {dt:.2f}s -> {path}")
        print(f"   eigenvalues: {np.asarray(res.eigenvalues)}")


if __name__ == "__main__":
    main()
